"""Observability probe overhead + off-mode bit-identity gate.

The :mod:`repro.obs` telemetry seam makes two promises
(docs/observability.md):

1. **Zero-cost when off** — with no ``sample_window_ns`` set, the only
   hot-loop residue is one always-false float compare per event-loop
   iteration, and results are *bit-identical* to the pre-obs engine.
   Asserted here structurally: every trace of the 20-trace facade suite
   produces byte-for-byte equal finish times and command counts with
   sampling off vs on (sampling may add a ``samples`` list, never change
   a result), and the off-mode run carries ``samples=None``.
2. **Bounded cost when on** — windowed sampling slows the cycle engine
   by at most 5 %. Measured on the two long-stream engine workloads
   (HBM4 sequential, RoMe sequential) as min-of-repeats wall time on /
   off; the headline ``overhead_frac_max`` is asserted ≤ 0.05 here and
   gated against the committed baseline in CI
   (benchmarks/baselines/obs_overhead_reduced.json — identity flags
   exact, overhead within the band).

Wall-time note: the measurement uses *short* runs (hundreds of ms) with
a warmup pass and min-of-many-repeats — on multi-second runs CPU
frequency drift alone swings single measurements by ±5 %, drowning the
signal; many short paired repeats keep the minima stable enough for the
band. The identity checks are exact and carry the real
regression-catching weight.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.sched import (facade_trace_suite, make_channel_sim,
                              sequential_read_txns_hbm4,
                              sequential_read_txns_rome)

#: Sampling window for the overhead measurement: fine enough to produce
#: hundreds of windows over the measured streams (a realistic probe
#: setting), coarse enough that dict-copy cost stays amortized.
WINDOW_NS = 500.0

OVERHEAD_BUDGET = 0.05

#: (label, kind, txn builder) for the timed runs. RoMe moves 4 KB per
#: txn (vs 32 B), so its stream gets 64x the bytes for a comparable
#: event-loop iteration count.
TIMED = (
    ("hbm4_stream", "hbm4", lambda n: sequential_read_txns_hbm4(n)),
    ("rome_stream", "rome", lambda n: sequential_read_txns_rome(n << 6)),
)


def _identity_suite() -> dict:
    """Facade-suite bit-identity: sampling on vs off never changes a
    result. Returns exact int flags (bench_compare gates ints, not
    bools)."""
    n_traces = 0
    finish_ok = counts_ok = off_no_samples = on_sampled = 1
    for label, kind, kwargs, txns in facade_trace_suite():
        n_traces += 1
        off = make_channel_sim(kind, **kwargs).run(txns)
        on = make_channel_sim(kind, sample_window_ns=WINDOW_NS,
                              **kwargs).run(txns)
        if not np.array_equal(off.finish_ns, on.finish_ns):
            finish_ok = 0
        if off.cmd_counts != on.cmd_counts:
            counts_ok = 0
        if off.samples is not None:
            off_no_samples = 0
        if on.samples is None:
            on_sampled = 0
        assert finish_ok and counts_ok, (
            f"{label}: sampling changed the simulated result")
    return {
        "identity_traces": n_traces,
        "identity_finish": finish_ok,
        "identity_counts": counts_ok,
        "identity_off_no_samples": off_no_samples,
        "identity_on_sampled": on_sampled,
    }


def _measure(kind: str, txns, repeats: int) -> tuple[float, float, int]:
    """(off_s, on_s, n_windows): min-of-repeats wall per mode, with an
    untimed warmup pass and interleaved timing so machine drift hits
    both modes alike."""
    make_channel_sim(kind, refresh=False).run(txns)          # warmup
    make_channel_sim(kind, refresh=False,
                     sample_window_ns=WINDOW_NS).run(txns)
    off_s = on_s = float("inf")
    n_windows = 0
    for _ in range(repeats):
        t0 = time.perf_counter()
        make_channel_sim(kind, refresh=False).run(txns)
        off_s = min(off_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        r = make_channel_sim(kind, refresh=False,
                             sample_window_ns=WINDOW_NS).run(txns)
        on_s = min(on_s, time.perf_counter() - t0)
        n_windows = len(r.samples or [])
    return off_s, on_s, n_windows


def run(reduced: bool = False) -> dict:
    out: dict = dict(_identity_suite())
    assert out["identity_off_no_samples"] == 1, (
        "off-mode run grew a samples list — the zero-cost contract "
        "requires samples=None when no window is set")
    assert out["identity_on_sampled"] == 1, (
        "sampled run produced no samples — the probe would be blind")

    nbytes = 1 << 16 if reduced else 1 << 17
    repeats = 3 if reduced else 6
    worst = 0.0
    for label, kind, build in TIMED:
        txns = build(nbytes)
        off_s, on_s, n_windows = _measure(kind, txns, repeats)
        frac = on_s / off_s - 1.0
        worst = max(worst, frac)
        out[f"{label}_off_s"] = round(off_s, 4)
        out[f"{label}_on_s"] = round(on_s, 4)
        out[f"{label}_windows"] = n_windows
        out[f"{label}_overhead_frac"] = round(frac, 4)
    out["overhead_frac_max"] = round(worst, 4)
    assert worst <= OVERHEAD_BUDGET, (
        f"windowed sampling costs {worst:.1%} on the cycle engine — "
        f"budget is {OVERHEAD_BUDGET:.0%}; a hot-loop regression "
        f"(docs/observability.md)")
    return out


if __name__ == "__main__":
    import argparse
    import json
    import traceback

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--reduced", action="store_true",
                   help="CI-smoke miniature (shorter streams, fewer "
                        "repeats; same gates)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write a benchmarks.run-shaped payload to PATH "
                        "(gateable by scripts/bench_compare.py)")
    args = p.parse_args()
    name = "obs_overhead_reduced" if args.reduced else "obs_overhead"
    t0 = time.time()
    try:
        results = run(reduced=args.reduced)
        status = "PASS"
    except AssertionError as e:
        results = {"error": str(e)}
        status = "FAIL"
    except Exception:
        results = {"error": traceback.format_exc()[-800:]}
        status = "ERROR"
    wall = round(time.time() - t0, 2)
    print(json.dumps(results, indent=1, default=str))
    print(f"[{status}] {name} ({wall:.1f}s)", flush=True)
    if args.json:
        payload = {"status": "pass" if status == "PASS" else "fail",
                   "benchmarks": {name: {"status": status, "wall_s": wall,
                                         "results": results}},
                   "total_wall_s": wall,
                   "failures": int(status != "PASS"),
                   "completed": True}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print(f"wrote {args.json}")
    raise SystemExit(0 if status == "PASS" else 1)
