"""Fig 10: command-issue latency vs C/A pin count; the 5-pin minimum.

Reproduces the paper's §IV-D result: the tightest command interval RoMe
must sustain is 2*tRRDS (REF immediately after RD_row/WR_row); five C/A
pins still issue a command faster than that, eliminating 72 % of the
baseline's 18 pins; the freed pins fund 4 extra channels (+12 pins).
"""
from __future__ import annotations

from repro.core import (RoMeTiming, command_issue_latency_ns, extra_channels,
                        freed_pins_per_channel, min_ca_pins,
                        min_required_interval_ns)
from repro.core.command_generator import HBM4_CA_PINS, ROME_CA_PINS


def run() -> dict:
    lim = min_required_interval_ns()
    curve = {p: command_issue_latency_ns(p) for p in range(1, 19)}
    n_min = min_ca_pins()
    n_extra, extra_pins = extra_channels()
    assert n_min == ROME_CA_PINS == 5
    assert curve[5] < lim <= curve[4]
    # Sanity vs the scheduler policy's own pacing: the tightest Table III
    # row-to-row gap the RoMe policy ever enforces (tX2XS/tX2XR >= 64 ns)
    # is far above the 5-pin issue latency, so for data commands C/A
    # serialization is never the bottleneck — only the REF-after-row case
    # (2*tRRDS) binds, which is exactly `lim`.
    t = RoMeTiming()
    min_gap = min(t.tR2RS, t.tR2RR, t.tR2WS, t.tR2WR,
                  t.tW2RS, t.tW2RR, t.tW2WS, t.tW2WR)
    assert curve[5] < lim < min_gap
    assert freed_pins_per_channel() == 13
    assert n_extra == 4 and extra_pins == 12
    reduction = 1 - ROME_CA_PINS / HBM4_CA_PINS
    return {
        "issue_latency_ns_by_pins": curve,
        "min_required_interval_ns": lim,
        "min_pins": n_min,
        "pin_reduction": f"{reduction:.0%} (paper: 72%)",
        "extra_channels": n_extra,
        "extra_pins_needed": extra_pins,
        "bandwidth_gain": f"{n_extra / 32:.1%} (paper: 12.5%)",
    }


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
