"""§V-B refresh optimization: VBA-paired per-bank refresh.

The MC issues one VBA refresh every 2*tREFIpb; the command generator fans
out two REFpb commands tRREFpb apart. Stall per VBA drops from 2*tRFCpb
(2 x 280 ns if the MC issued them serially) to tRFCpb + tRREFpb (288 ns).
Also measures the end-to-end bandwidth cost of refresh for both systems.
"""
from __future__ import annotations

from repro.core import CommandGenerator
from repro.core import sched as eng


def run() -> dict:
    cg = CommandGenerator()
    opt = cg.refresh_stall_ns()
    naive = cg.naive_refresh_stall_ns()
    assert opt == 280.0 + 8.0 and naive == 560.0

    def bw(sim_cls, txns, **kw):
        sim = sim_cls(**kw)
        return sim.run(txns).bandwidth_gbps / sim.g.bandwidth_gbps

    n = 1 << 20
    rome_txns = eng.sequential_read_txns_rome(n)
    hbm4_txns = eng.sequential_read_txns_hbm4(n // 4)
    out = {
        "stall_ns_optimized": opt,
        "stall_ns_naive": naive,
        "stall_reduction": f"{1 - opt / naive:.1%}",
        "rome_eff_no_refresh": bw(eng.RoMeChannelSim, rome_txns,
                                  refresh=False),
        "rome_eff_refresh": bw(eng.RoMeChannelSim, rome_txns, refresh=True),
        "hbm4_eff_no_refresh": bw(eng.HBM4ChannelSim, hbm4_txns,
                                  refresh=False),
        "hbm4_eff_refresh": bw(eng.HBM4ChannelSim, hbm4_txns, refresh=True),
    }
    # Refresh must cost RoMe < 5 % of bandwidth on a bulk stream.
    assert out["rome_eff_refresh"] > 0.95 * out["rome_eff_no_refresh"]
    return {k: (round(v, 4) if isinstance(v, float) else v)
            for k, v in out.items()}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
