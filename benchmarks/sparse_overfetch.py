"""§VII (Discussion): RoMe under hostile fine-grained access — DSA-style
sparse attention that gathers top-k scattered tokens.

RoMe moves whole 4 KB rows; a sparse gather of 32 B-ish tokens from random
rows overfetches by up to row/token_bytes. This benchmark quantifies the
effective-bandwidth penalty vs HBM4 for (a) the paper's bulk-sequential
case (penalty ~0) and (b) top-2048-of-128K sparse KV gather (the paper's
stated weakness — reproduced, not hidden).
"""
from __future__ import annotations

import numpy as np

from repro.core import engine as eng


def run() -> dict:
    rng = np.random.default_rng(0)
    kv_token_bytes = 512            # one head-group's K per token
    seq = 1 << 17                   # 128K history
    topk = 2048

    # (a) bulk sequential: read the whole 128K history (prefill-style)
    bulk_bytes = seq * kv_token_bytes
    rome_bulk = eng.RoMeChannelSim(refresh=False)
    r_bulk = rome_bulk.run(eng.sequential_read_txns_rome(bulk_bytes))

    # (b) sparse: top-2048 random tokens -> distinct rows (worst case)
    tokens = rng.choice(seq, size=topk, replace=False)
    rows = np.unique(tokens * kv_token_bytes // 4096)
    useful = topk * kv_token_bytes
    fetched_rome = len(rows) * 4096
    overfetch = fetched_rome / useful - 1.0

    rome_sparse = eng.RoMeChannelSim(refresh=False)
    txns = [eng.Txn(0.0, bank=int(r) % 16, row=int(r) // 16)
            for r in rows]
    r_sparse = rome_sparse.run(txns)
    # HBM4 fetches exactly the tokens: 16 consecutive 32 B columns per
    # 512 B token (one row activation amortized over the 16 hits).
    hbm4 = eng.HBM4ChannelSim(refresh=False)
    cols = []
    for tok in tokens:
        base = int(tok) * kv_token_bytes
        for c in range(kv_token_bytes // 32):
            addr = base + c * 32
            cols.append(eng.Txn(0.0, bank=(addr // 1024) % 128,
                                row=addr // 1024 // 128,
                                col=(addr % 1024) // 32))
    h_sparse = hbm4.run(cols[: 16384])

    eff_rome_useful = (useful / r_sparse.total_ns) / \
        rome_sparse.g.bandwidth_gbps
    eff_hbm4_useful = (min(len(cols), 16384) * 32 / h_sparse.total_ns) / \
        hbm4.g.bandwidth_gbps
    out = {
        "bulk_eff": round(r_bulk.bandwidth_gbps
                          / rome_bulk.g.bandwidth_gbps, 4),
        "sparse_overfetch_frac": round(overfetch, 3),
        "sparse_useful_eff_rome": round(eff_rome_useful, 4),
        "sparse_useful_eff_hbm4": round(eff_hbm4_useful, 4),
        "note": "DSA-style sparse access is RoMe's stated weakness (§VII);"
                " bulk LLM streams see none of it",
    }
    assert out["bulk_eff"] > 0.95
    assert overfetch > 4.0          # 4 KB rows vs 512 B tokens
    assert eff_rome_useful < eff_hbm4_useful
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
