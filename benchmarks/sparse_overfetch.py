"""§VII (Discussion): RoMe under hostile fine-grained access — DSA-style
sparse attention that gathers top-k scattered tokens.

RoMe moves whole 4 KB rows; a sparse gather of 32 B-ish tokens from random
rows overfetches by up to row/token_bytes. This benchmark quantifies the
effective-bandwidth penalty vs HBM4 for (a) the paper's bulk-sequential
case (penalty ~0) and (b) top-2048-of-128K sparse KV gather (the paper's
stated weakness — reproduced, not hidden). Both workloads are expressed
as :class:`repro.workloads.ExtentStream` objects through the same
:class:`SystemSim` decomposition the rest of the repo uses: the gather is
:func:`~repro.workloads.sparse_stream`, row-coalesced for RoMe's MC
(:meth:`~repro.workloads.ExtentStream.coalesced`), and the over-fetch
falls out of the decomposition's whole-unit rule.
"""
from __future__ import annotations

from repro.core.system_sim import SystemSim
from repro.core.timing import hbm4_config, rome_config
from repro.workloads import bulk_stream, sparse_stream


def run() -> dict:
    kv_token_bytes = 512            # one head-group's K per token
    seq = 1 << 17                   # 128K history
    topk = 2048
    rome_cfg, hbm4_cfg = rome_config(), hbm4_config()

    # (a) bulk sequential: read the whole 128K history (prefill-style)
    rome_bulk = SystemSim(rome_cfg, n_channels=1, refresh=False)
    r_bulk = rome_bulk.run(bulk_stream(seq * kv_token_bytes))
    bulk_eff = r_bulk.bandwidth_gbps / rome_cfg.channel_bw_gbps

    # (b) sparse: top-2048 random tokens over the history. RoMe's MC
    # coalesces requests at row granularity and then moves whole rows —
    # bytes_moved over useful bytes IS the over-fetch.
    gather = sparse_stream(topk, kv_token_bytes, seq * kv_token_bytes,
                           seed=0)
    useful = gather.total_bytes
    rome_sparse = SystemSim(rome_cfg, n_channels=1, refresh=False)
    r_sparse = rome_sparse.run(gather.coalesced(granularity=4096))
    overfetch = r_sparse.bytes_moved / useful - 1.0
    eff_rome_useful = (useful / r_sparse.total_ns) / rome_cfg.channel_bw_gbps

    # HBM4 fetches exactly the tokens (16 consecutive 32 B columns per
    # 512 B token); cap the cycle-level run at 1024 tokens for runtime —
    # a fresh uniform sample over the full history, not an address-sorted
    # prefix of the RoMe gather (which would double the spatial density).
    sub = sparse_stream(1024, kv_token_bytes, seq * kv_token_bytes, seed=1)
    hbm4 = SystemSim(hbm4_cfg, n_channels=1, refresh=False)
    h_sparse = hbm4.run(sub)
    eff_hbm4_useful = (sub.total_bytes / h_sparse.total_ns) \
        / hbm4_cfg.channel_bw_gbps

    out = {
        "bulk_eff": round(bulk_eff, 4),
        "sparse_overfetch_frac": round(overfetch, 3),
        "sparse_useful_eff_rome": round(eff_rome_useful, 4),
        "sparse_useful_eff_hbm4": round(eff_hbm4_useful, 4),
        "note": "DSA-style sparse access is RoMe's stated weakness (§VII);"
                " bulk LLM streams see none of it",
    }
    assert out["bulk_eff"] > 0.95
    assert overfetch > 4.0          # 4 KB rows vs 512 B tokens
    assert eff_rome_useful < eff_hbm4_useful
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
