"""Cross-validation: vectorized analytic service-time model vs the
cycle-level engine on overlapping regimes (DESIGN.md §2 requirement).
"""
from __future__ import annotations

from repro.core import analytic, engine as eng
from repro.core.address_map import make_address_map
from repro.core.timing import hbm4_config, rome_config


def run() -> dict:
    out = {}
    for name, cfg, mk in (
            ("hbm4", hbm4_config(),
             lambda n: eng.sequential_read_txns_hbm4(n)),
            ("rome", rome_config(),
             lambda n: eng.sequential_read_txns_rome(n))):
        # Same settings the analytic calibration uses (well-tuned MC:
        # deep queue, pooled refresh).
        sim = (eng.HBM4ChannelSim(max_ref_postpone=32) if name == "hbm4"
               else eng.RoMeChannelSim())
        rows = {}
        for nbytes in (1 << 16, 1 << 18, 1 << 20):
            r = sim.run(mk(nbytes))
            engine_ns = r.total_ns
            amap = make_address_map(cfg, n_cubes=1)
            # Single-channel view: scale to the one channel being modeled.
            eff = analytic.calibrate(cfg)
            e = eff.read_eff
            analytic_ns = nbytes / (cfg.channel_bw_gbps * e)
            rel = abs(engine_ns - analytic_ns) / engine_ns
            rows[nbytes] = {"engine_ns": round(engine_ns, 1),
                            "analytic_ns": round(analytic_ns, 1),
                            "rel_err": round(rel, 4)}
            assert rel < 0.08, (name, nbytes, rel)
        out[name] = rows
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
