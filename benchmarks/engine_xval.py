"""Cross-validation: vectorized analytic service-time model vs the
cycle-level engine (DESIGN.md §2 requirement), at three levels:

1. single-channel bulk streams (the calibration regime itself),
2. multi-channel (addr, nbytes) extents through :class:`SystemSim` — the
   extent-level path the TPOT model consumes, checked against
   ``analytic.transfer_time_ns`` for reads and writes,
3. timed :class:`~repro.workloads.ExtentStream` workloads — the decode
   TPOT memory time (``perfmodel.tpot.stream_mem_ns``) against the
   measured multi-channel makespan of the *actual* paper-LLM decode
   trace (byte-scaled so the cycle-level run is tractable), and the
   mixed read/write multi-tenant regime with the ACT-inflation roofline.
"""
from __future__ import annotations

from repro.configs.paper_workloads import PAPER_WORKLOADS
from repro.core import analytic
from repro.core import sched as eng
from repro.core.system_sim import SystemSim, bulk_stream_extents
from repro.core.timing import hbm4_config, rome_config
from repro.perfmodel.tpot import stream_mem_ns, xval_decode_stream
from repro.workloads import interleave, strided_stream

# The scaled-slice regime itself (layers, scale, channel count) is defined
# once in perfmodel.tpot.xval_decode_stream, shared with the tier-1 test
# and the example.
XVAL_WORKLOADS = ("deepseek-v3", "llama-3-405b")


def _tenant_mix(n_tenants=4, n_ops=4, op_bytes=1 << 16, n_writers=1,
                stagger_ns=200.0, fine_rec_bytes=0):
    """Multi-tenant mixed read/write stream. ``fine_rec_bytes=0`` issues
    op-granularity records (the regime the closed form claims);
    non-zero chops every tenant into `fine_rec_bytes` records with
    interleaved arrivals (the row-thrash regime)."""
    streams = []
    for t in range(n_tenants):
        kind = "write" if t < n_writers else "read"
        base = t * (64 << 20)
        if fine_rec_bytes:
            streams.append(strided_stream(
                n_ops * op_bytes // fine_rec_bytes, fine_rec_bytes,
                fine_rec_bytes, kind=kind, base_addr=base,
                inter_arrival_ns=1.0, stream_id=t))
        else:
            streams.append(strided_stream(
                n_ops, op_bytes, op_bytes, kind=kind, base_addr=base,
                arrival_ns=t * stagger_ns,
                inter_arrival_ns=n_tenants * stagger_ns, stream_id=t))
    return interleave(streams)


def run() -> dict:
    out = {}
    for name, cfg, mk in (
            ("hbm4", hbm4_config(),
             lambda n: eng.sequential_read_txns_hbm4(n)),
            ("rome", rome_config(),
             lambda n: eng.sequential_read_txns_rome(n))):
        # Same settings the analytic calibration uses (well-tuned MC:
        # deep queue, pooled refresh).
        sim = (eng.HBM4ChannelSim(max_ref_postpone=32) if name == "hbm4"
               else eng.RoMeChannelSim())
        rows = {}
        for nbytes in (1 << 16, 1 << 18, 1 << 20):
            r = sim.run(mk(nbytes))
            engine_ns = r.total_ns
            eff = analytic.calibrate(cfg)
            e = eff.read_eff
            analytic_ns = nbytes / (cfg.channel_bw_gbps * e)
            rel = abs(engine_ns - analytic_ns) / engine_ns
            rows[nbytes] = {"engine_ns": round(engine_ns, 1),
                            "analytic_ns": round(analytic_ns, 1),
                            "rel_err": round(rel, 4)}
            assert rel < 0.08, (name, nbytes, rel)
        out[name] = rows

    # Extent-level: SystemSim vs transfer_time_ns on multi-channel
    # bulk-stream regimes (reads and writes).
    sysrows = {}
    for name, cfg in (("hbm4", hbm4_config()), ("rome", rome_config())):
        for nch, extents, is_write in (
                (2, bulk_stream_extents(1 << 18), False),
                (4, bulk_stream_extents(1 << 19, n_extents=2), False),
                (2, bulk_stream_extents(1 << 18), True)):
            sim = SystemSim(cfg, n_channels=nch)
            r = sim.run_extents(extents, is_write=is_write)
            ana = analytic.transfer_time_ns(extents, cfg, sim.amap,
                                            is_write=is_write)
            rel = abs(r.total_ns - ana) / r.total_ns
            key = f"{name}_ch{nch}_{'wr' if is_write else 'rd'}"
            sysrows[key] = {"system_ns": round(r.total_ns, 1),
                            "analytic_ns": round(ana, 1),
                            "lbr": round(r.load_balance_ratio, 4),
                            "rel_err": round(rel, 4)}
            assert rel < 0.10, (key, rel)
    out["system_sim"] = sysrows

    # Stream-level, trace-driven: SystemSim makespan on the from_layer_ops
    # decode stream vs the TPOT model's memory time, per paper workload
    # and memory system (the acceptance band is 15 %).
    tpot_rows = {}
    for wname in XVAL_WORKLOADS:
        w = PAPER_WORKLOADS[wname]
        for mem in ("hbm4", "rome"):
            stream, acc = xval_decode_stream(w, mem)
            res = SystemSim(acc.mem_cfg, n_channels=acc.n_channels).run(stream)
            model_ns = stream_mem_ns(stream, acc)
            rel = abs(res.total_ns - model_ns) / model_ns
            key = f"{wname}_{mem}"
            tpot_rows[key] = {"makespan_ns": round(res.total_ns, 1),
                              "tpot_mem_ns": round(model_ns, 1),
                              "stream_records": len(stream),
                              "stream_kb": stream.total_bytes >> 10,
                              "rel_err": round(rel, 4)}
            assert rel < 0.15, (key, res.total_ns, model_ns, rel)
    out["tpot_stream"] = tpot_rows

    # Mixed read/write multi-tenant streams at op granularity — the regime
    # the closed form claims. bg_striped bulk decomposition keeps the
    # measured ACT rate at the calibrated baseline (inflation ~1), and the
    # summed read+write closed form must match the makespan.
    mixed = {}
    for name, cfg in (("hbm4", hbm4_config()), ("rome", rome_config())):
        stream = _tenant_mix()
        sim = SystemSim(cfg, n_channels=2)
        res = sim.run(stream)
        eff = analytic.calibrate(cfg)
        kb = res.bytes_moved / 1024
        infl = ((res.cmd_counts.get("ACT", 0) / kb) / eff.act_per_kb
                if name == "hbm4" else 1.0)
        ana = analytic.stream_time_ns(stream, cfg, sim.amap,
                                      act_inflation=max(infl, 1.0))
        rel = abs(res.total_ns - ana) / res.total_ns
        mixed[name] = {"system_ns": round(res.total_ns, 1),
                       "analytic_ns": round(ana, 1),
                       "measured_act_inflation": round(infl, 3),
                       "rel_err": round(rel, 4)}
        assert rel < 0.15, (name, res.total_ns, ana, rel)
        if name == "hbm4":
            assert infl < 1.5, ("op-granularity mixes must stay ACT-lean",
                                infl)
    out["mixed_stream"] = mixed

    # ACT-inflation roofline, fine-grained interleave (row-thrash regime):
    # feeding the *measured* inflation into the closed form must move the
    # prediction strictly toward the measured makespan. The residual gap is
    # queue-window serialization the roofline does not model — reported,
    # not hidden.
    cfg = hbm4_config()
    stream = _tenant_mix(n_tenants=8, n_ops=2, op_bytes=1 << 15,
                         n_writers=2, fine_rec_bytes=1024)
    sim = SystemSim(cfg, n_channels=2)
    res = sim.run(stream)
    eff = analytic.calibrate(cfg)
    kb = res.bytes_moved / 1024
    infl = (res.cmd_counts.get("ACT", 0) / kb) / eff.act_per_kb
    ana_infl = analytic.stream_time_ns(stream, cfg, sim.amap,
                                       act_inflation=infl)
    ana_flat = analytic.stream_time_ns(stream, cfg, sim.amap)
    err_infl = abs(res.total_ns - ana_infl) / res.total_ns
    err_flat = abs(res.total_ns - ana_flat) / res.total_ns
    out["act_inflation_fine"] = {
        "system_ns": round(res.total_ns, 1),
        "measured_act_inflation": round(infl, 2),
        "analytic_inflated_ns": round(ana_infl, 1),
        "analytic_flat_ns": round(ana_flat, 1),
        "rel_err_inflated": round(err_infl, 4),
        "rel_err_flat": round(err_flat, 4),
        "note": "heavy row-thrash exceeds the roofline's validity "
                "(queue-window serialization unmodeled); inflation must "
                "still strictly improve the prediction",
    }
    assert infl > 4.0, ("fine interleave must inflate the ACT rate", infl)
    assert err_infl < err_flat, (err_infl, err_flat)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
