"""Cross-validation: vectorized analytic service-time model vs the
cycle-level engine (DESIGN.md §2 requirement), at two levels:

1. single-channel bulk streams (the calibration regime itself), and
2. multi-channel (addr, nbytes) extents through :class:`SystemSim` — the
   extent-level path the TPOT model consumes, checked against
   ``analytic.transfer_time_ns`` for reads and writes.
"""
from __future__ import annotations

from repro.core import analytic
from repro.core import sched as eng
from repro.core.system_sim import SystemSim, bulk_stream_extents
from repro.core.timing import hbm4_config, rome_config


def run() -> dict:
    out = {}
    for name, cfg, mk in (
            ("hbm4", hbm4_config(),
             lambda n: eng.sequential_read_txns_hbm4(n)),
            ("rome", rome_config(),
             lambda n: eng.sequential_read_txns_rome(n))):
        # Same settings the analytic calibration uses (well-tuned MC:
        # deep queue, pooled refresh).
        sim = (eng.HBM4ChannelSim(max_ref_postpone=32) if name == "hbm4"
               else eng.RoMeChannelSim())
        rows = {}
        for nbytes in (1 << 16, 1 << 18, 1 << 20):
            r = sim.run(mk(nbytes))
            engine_ns = r.total_ns
            eff = analytic.calibrate(cfg)
            e = eff.read_eff
            analytic_ns = nbytes / (cfg.channel_bw_gbps * e)
            rel = abs(engine_ns - analytic_ns) / engine_ns
            rows[nbytes] = {"engine_ns": round(engine_ns, 1),
                            "analytic_ns": round(analytic_ns, 1),
                            "rel_err": round(rel, 4)}
            assert rel < 0.08, (name, nbytes, rel)
        out[name] = rows

    # Extent-level: SystemSim vs transfer_time_ns on multi-channel
    # bulk-stream regimes (reads and writes).
    sysrows = {}
    for name, cfg in (("hbm4", hbm4_config()), ("rome", rome_config())):
        for nch, extents, is_write in (
                (2, bulk_stream_extents(1 << 18), False),
                (4, bulk_stream_extents(1 << 19, n_extents=2), False),
                (2, bulk_stream_extents(1 << 18), True)):
            sim = SystemSim(cfg, n_channels=nch)
            r = sim.run_extents(extents, is_write=is_write)
            ana = analytic.transfer_time_ns(extents, cfg, sim.amap,
                                            is_write=is_write)
            rel = abs(r.total_ns - ana) / r.total_ns
            key = f"{name}_ch{nch}_{'wr' if is_write else 'rd'}"
            sysrows[key] = {"system_ns": round(r.total_ns, 1),
                            "analytic_ns": round(ana, 1),
                            "lbr": round(r.load_balance_ratio, 4),
                            "rel_err": round(rel, 4)}
            assert rel < 0.10, (key, rel)
    out["system_sim"] = sysrows
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
