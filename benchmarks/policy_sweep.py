"""Scheduler-policy design-space sweep (ROADMAP: policy sweeps over
streams; Table IV / Fig 9 context).

Every policy registered in ``repro.core.sched.registry`` runs every
workload class through :class:`~repro.core.system_sim.SystemSim` and
emits one record per (policy, workload, config) cell — the repo's
standing evidence that RoMe's simplified scheduling holds across the
design space rather than at three hand-picked points.

Policy -> Table IV row mapping (census read out of each policy's own
``state_footprint()`` via ``mc.complexity_of_policy``; the sweep result
carries it under ``"census"``):

``hbm4_frfcfs``
    The conventional-MC row exactly: 15 managed timing parameters, 64
    seven-state bank FSMs per PC, open page, row-locality + BG/PC
    interleaving, 64-entry request queue.
``hbm4_closed``
    Conventional row minus the row-buffer-locality machinery (closed
    page): same FSM census, pays ACT+PRE per 32 B column.
``hbm4_writedrain``
    Conventional row *plus* posted-write hardware: drain-mode FSM,
    hi/lo occupancy comparators, write-age compare (``aux_state``).
    The write-drain lineage of FR-FCFS (cf. PAPERS.md).
``hbm4_sidgroup``
    Conventional row plus a per-PC last-SID register (``aux_state``):
    tCCDR-aware cross-SID burst grouping. Measured bandwidth-neutral —
    the sweep's evidence that conventional scheduling tricks buy
    margins, not multiples.
``rome_qd2``
    The RoMe row exactly: 10 timing parameters, 5 four-state VBA FSMs,
    no page policy, queue depth 2.
``rome_qd3`` / ``rome_qd4`` / ``rome_qd8``
    RoMe row at deeper queues — the census is *invariant* (no new FSM
    state), and the sweep shows bandwidth is too (saturation at depth
    2, the §V-A claim, now swept instead of asserted at one point).
``rome_eager_refresh``
    RoMe row with the refresh governor never postponing — census
    invariant; the bandwidth cost of zero refresh debt is measured.

Workload classes (all via SystemSim over timed ExtentStreams):

* ``bulk_synthetic`` — contiguous 2-channel stream, the
  benchmarks/queue_depth.py calibration regime at extent level.
* ``decode_trace`` — ``from_layer_ops`` DeepSeek-V3 / Llama-3-405B
  scaled decode slices (the perfmodel.tpot.xval_decode_stream regime).
* ``tenant_mix`` — multi-tenant ``interleave`` of mixed read/write
  strided streams in distinct 64 MB (= distinct-SID) regions,
  decomposed with ``sids=4`` so the cross-SID (tCCDR / tX2XR) and
  turnaround paths are exercised. Deliberately adversarial for
  kind-batched scheduling (all tenants alias the same bank set).
* ``read_trickle`` — open-loop paced read stream with a posted-write
  trickle, the write-drain design regime.

Headline finding the bands pin: the conventional-MC scheduling tricks
are *margins, not multiples* — SID grouping is bandwidth-neutral
everywhere, write draining is neutral on streams and bounded-cost on
the adversarial mix — while RoMe's queue-depth/refresh variants are
bandwidth-invariant (saturation at depth 2, §V-A) with an unchanged
4-FSM census. The contrast that moves bandwidth is the granularity
change itself (benchmarks/full_cube.py).
"""
from __future__ import annotations

import dataclasses

from repro.configs.paper_workloads import PAPER_WORKLOADS
from repro.core.mc import registry_census
from repro.core.sched import registered_policies
from repro.core.system_sim import SystemSim
from repro.core.timing import hbm4_config, rome_config
from repro.perfmodel.tpot import xval_decode_stream
from repro.workloads import bulk_stream, interleave, strided_stream

BULK_BYTES = 1 << 19
N_CHANNELS = 2
DECODE_WORKLOADS = ("deepseek-v3", "llama-3-405b")
TENANT_SIDS = 4


def tenant_mix_stream(n_tenants: int = 4, n_ops: int = 32,
                      op_bytes: int = 1 << 11, n_writers: int = 2,
                      stagger_ns: float = 50.0):
    """Multi-tenant mixed read/write stream; tenants live in distinct
    64 MB regions, so ``sids=4`` decomposition puts them in distinct
    stack levels (SIDs)."""
    streams = []
    for t in range(n_tenants):
        kind = "write" if t < n_writers else "read"
        streams.append(strided_stream(
            n_ops, op_bytes, op_bytes, kind=kind, base_addr=t * (64 << 20),
            arrival_ns=t * stagger_ns,
            inter_arrival_ns=n_tenants * stagger_ns, stream_id=t))
    return interleave(streams)


def read_trickle_stream(n_reads: int = 4096, read_gap_ns: float = 3.2,
                        write_ratio: int = 8):
    """Open-loop paced reads + a posted-write trickle (1 write per
    ``write_ratio`` reads, in a distinct 64 MB region / SID)."""
    reads = strided_stream(n_reads, 64, 64, inter_arrival_ns=read_gap_ns,
                           stream_id=0)
    writes = strided_stream(n_reads // write_ratio, 64, 64, kind="write",
                            base_addr=64 << 20,
                            inter_arrival_ns=read_gap_ns * write_ratio,
                            stream_id=1)
    return interleave([reads, writes])


def _cell(spec, workload: str, sim: SystemSim, stream) -> dict:
    res = sim.run(stream)
    loaded = int((res.channel_bytes > 0).sum())
    ch_bw = sim.cfg.channel_bw_gbps
    counts = res.cmd_counts
    # Per-kind service metrics: the result carries each channel's txn
    # list in finish-array order, so read latency (finish - arrival)
    # falls out without re-running decompose().
    lats = []
    for c, txns in res.channel_txns.items():
        fin = res.channel_results[c].finish_ns
        lats.extend(float(f - tx.arrival_ns)
                    for f, tx in zip(fin, txns) if not tx.is_write)
    read_mean = sum(lats) / len(lats) if lats else 0.0
    return {
        "read_mean_lat_ns": round(read_mean, 1),
        "read_max_lat_ns": round(max(lats), 1) if lats else 0.0,
        "policy": spec.name,
        "family": spec.family,
        "workload": workload,
        "config": {"n_channels": sim.amap.n_channels,
                   "queue_depth": spec.queue_depth,
                   "sids": sim.sids},
        "makespan_ns": round(res.total_ns, 1),
        "bandwidth_gbps": round(res.bandwidth_gbps, 2),
        "peak_frac": round(res.bandwidth_gbps / (loaded * ch_bw), 4),
        "lbr": round(res.load_balance_ratio, 4),
        "bytes_moved": res.bytes_moved,
        "acts": counts.get("ACT", 0),
        # Derived property, not a hand-rolled (RD+WR-ACT) expression:
        # repro.obs and every benchmark must agree on one definition
        # (0.0 by construction for row-granular RoMe policies).
        "row_hit_rate": round(res.row_hit_rate, 4),
        "sid_switches": counts.get("sid_switches", 0),
        "drain_entries": counts.get("drain_entries", 0),
    }


def run() -> dict:
    specs = registered_policies()
    cfgs = {"hbm4": hbm4_config(), "rome": rome_config()}
    decode = {(w, fam): xval_decode_stream(PAPER_WORKLOADS[w], fam,
                                           n_channels=N_CHANNELS)
              for w in DECODE_WORKLOADS for fam in cfgs}

    records = []
    for spec in specs.values():
        cfg = cfgs[spec.family]
        kindkw = dict(channel_kind=spec.sim_kind,
                      channel_kwargs=dict(spec.sim_kwargs))

        sim = SystemSim(cfg, n_channels=N_CHANNELS, **kindkw)
        records.append(_cell(spec, "bulk_synthetic", sim,
                             bulk_stream(BULK_BYTES)))

        for w in DECODE_WORKLOADS:
            stream, acc = decode[(w, spec.family)]
            sim = SystemSim(acc.mem_cfg, n_channels=acc.n_channels, **kindkw)
            records.append(_cell(spec, f"decode_trace:{w}", sim, stream))

        sim = SystemSim(cfg, n_channels=N_CHANNELS, sids=TENANT_SIDS,
                        **kindkw)
        records.append(_cell(spec, "tenant_mix", sim, tenant_mix_stream()))

        sim = SystemSim(cfg, n_channels=N_CHANNELS, sids=TENANT_SIDS,
                        **kindkw)
        records.append(_cell(spec, "read_trickle", sim,
                             read_trickle_stream()))

    by = {(r["policy"], r["workload"]): r for r in records}
    classes = sorted({r["workload"].split(":")[0] for r in records})

    # -- reproduction bands -------------------------------------------------
    # Acceptance floor: >= 5 policies x >= 3 workload classes.
    assert len(specs) >= 5, sorted(specs)
    assert len(classes) >= 3, classes

    # RoMe saturates at queue depth 2 on bulk streams (§V-A), and the
    # sweep shows depth 3..8 buys nothing: census invariant AND
    # bandwidth invariant.
    rome_bulk = {n: by[(n, "bulk_synthetic")]["peak_frac"]
                 for n in specs if specs[n].family == "rome"
                 and "refresh" not in n}
    assert rome_bulk["rome_qd2"] >= 0.95, rome_bulk
    spread = max(rome_bulk.values()) / min(rome_bulk.values()) - 1
    assert spread < 0.02, (rome_bulk, spread)

    # ... and the decode traces agree (qd-invariance on real streams).
    for w in DECODE_WORKLOADS:
        mks = [by[(n, f"decode_trace:{w}")]["makespan_ns"]
               for n in rome_bulk]
        assert max(mks) / min(mks) - 1 < 0.02, (w, mks)

    # Eager refresh costs bounded bandwidth (zero refresh debt is cheap
    # at RoMe granularity — the governor knob, not the FSM census, is
    # what moves).
    eager = by[("rome_eager_refresh", "bulk_synthetic")]["peak_frac"]
    assert eager >= rome_bulk["rome_qd2"] - 0.05, (eager, rome_bulk)

    # Closed page never saturates (always-precharge at 32 B granularity).
    hb = by[("hbm4_frfcfs", "bulk_synthetic")]["bandwidth_gbps"]
    assert by[("hbm4_closed", "bulk_synthetic")]["bandwidth_gbps"] < 0.5 * hb

    # Row-hit rate (SystemResult.row_hit_rate) separates the families
    # structurally: open-page FR-FCFS rides the row buffer on bulk
    # streams, closed page precharges every column (rate 0), and RoMe
    # has no column reuse to hit at all — 0.0 by construction.
    assert by[("hbm4_frfcfs", "bulk_synthetic")]["row_hit_rate"] > 0.8, \
        by[("hbm4_frfcfs", "bulk_synthetic")]
    assert by[("hbm4_closed", "bulk_synthetic")]["row_hit_rate"] == 0.0
    for n in rome_bulk:
        assert by[(n, "bulk_synthetic")]["row_hit_rate"] == 0.0, n

    # Write draining and SID grouping are bandwidth-neutral on the
    # read-only bulk stream (no writes to drain, one SID) — the added
    # scheduler state must not perturb the read path at all.
    for n in ("hbm4_writedrain", "hbm4_sidgroup"):
        assert abs(by[(n, "bulk_synthetic")]["makespan_ns"] -
                   by[("hbm4_frfcfs", "bulk_synthetic")]["makespan_ns"]) \
            < 1e-6, n

    # Margins, not multiples (the sweep's structural point; RoMe's
    # granularity change is what moves bandwidth, cf. full_cube):
    # SID grouping is makespan-neutral within 2% on every workload;
    # write draining is neutral on streaming workloads (decode,
    # trickle) and bounded-cost — not unbounded starvation — on the
    # deliberately adversarial same-bank tenant mix.
    workloads = sorted({r["workload"] for r in records})
    for w in workloads:
        fr = by[("hbm4_frfcfs", w)]["makespan_ns"]
        sg = by[("hbm4_sidgroup", w)]["makespan_ns"]
        assert abs(sg / fr - 1) < 0.02, (w, sg, fr)
        wd = by[("hbm4_writedrain", w)]["makespan_ns"]
        band = 2.0 if w == "tenant_mix" else 1.2
        assert wd / fr < band, (w, wd, fr)
    wd_tr = by[("hbm4_writedrain", "read_trickle")]
    fr_tr = by[("hbm4_frfcfs", "read_trickle")]
    assert wd_tr["makespan_ns"] / fr_tr["makespan_ns"] < 1.02, \
        (wd_tr["makespan_ns"], fr_tr["makespan_ns"])
    # The posted-write machinery must actually engage on its design
    # regime (batched drains, not per-write turnarounds).
    assert wd_tr["drain_entries"] > 0, wd_tr

    census = {name: dataclasses.asdict(c)
              for name, c in registry_census().items()}
    return {
        "n_policies": len(specs),
        "workload_classes": classes,
        "n_records": len(records),
        # Keyed by "<policy>/<workload>" (not a positional list) so a
        # future registry addition extends the baseline instead of
        # shifting every index and invalidating it.
        "records": {f"{r['policy']}/{r['workload']}": r for r in records},
        "census": census,
    }


if __name__ == "__main__":
    import argparse
    import json
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--json", metavar="PATH", default=None,
                   help="also write the sweep results to PATH")
    args = p.parse_args()
    out = run()
    text = json.dumps(out, indent=1, default=str)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text)
