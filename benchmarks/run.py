"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run                 # everything
    PYTHONPATH=src python -m benchmarks.run fig12           # substring filter
    PYTHONPATH=src python -m benchmarks.run --json out.json # machine-readable

Each module exposes run() -> dict and asserts its reproduction bands
internally; this driver reports PASS/FAIL per benchmark and dumps the
numbers. ``--json PATH`` additionally writes the per-benchmark results
dict (with status and wall time) to a file, so bench trajectories
(BENCH_*.json) can be recorded instead of scraping stdout.

The JSON payload carries an explicit top-level ``"status"`` field
("pass" only when every selected benchmark passed AND the driver loop
ran to completion) — written via try/finally so even a crash mid-run
leaves a parseable record. scripts/bench_compare.py refuses any payload
whose status is not "pass", so a band failure can never hide behind an
``always()`` artifact-upload step in CI.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from . import (cluster_sweep, engine_dequeue, engine_xval,
               fig09_command_schedule, fig10_ca_pins, fig12_tpot,
               fig13_lbr, fig14_energy, full_cube, hybrid_xval,
               obs_overhead, policy_sweep, queue_depth, refresh_stall,
               serve_trace, sparse_overfetch, tab_mc_complexity,
               timing_conformance, vba_design_space)

ALL = [
    ("fig09_command_schedule", fig09_command_schedule),
    ("fig10_ca_pins", fig10_ca_pins),
    ("tab_mc_complexity", tab_mc_complexity),
    ("queue_depth", queue_depth),
    ("engine_dequeue", engine_dequeue),
    ("vba_design_space", vba_design_space),
    ("engine_xval", engine_xval),
    ("fig12_tpot", fig12_tpot),
    ("fig13_lbr", fig13_lbr),
    ("fig14_energy", fig14_energy),
    ("refresh_stall", refresh_stall),
    ("sparse_overfetch", sparse_overfetch),
    ("timing_conformance", timing_conformance),
    ("policy_sweep", policy_sweep),
    ("hybrid_xval", hybrid_xval),
    ("full_cube", full_cube),
    ("serve_trace", serve_trace),
    ("cluster_sweep", cluster_sweep),
    ("obs_overhead", obs_overhead),
]


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="benchmarks.run",
                                     description=__doc__.splitlines()[0])
    parser.add_argument("pattern", nargs="?", default="",
                        help="substring filter on benchmark names")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="write results (status, wall time, numbers) "
                             "to PATH as JSON")
    args = parser.parse_args(sys.argv[1:] if argv is None else argv)

    failures = 0
    results = {}
    report = {}
    completed = False
    t_start = time.time()
    try:
        for name, mod in ALL:
            if args.pattern and args.pattern not in name:
                continue
            t0 = time.time()
            try:
                results[name] = mod.run()
                status = "PASS"
            except AssertionError as e:
                results[name] = {"error": str(e)}
                status = "FAIL"
                failures += 1
            except Exception:
                results[name] = {"error": traceback.format_exc()[-800:]}
                status = "ERROR"
                failures += 1
            wall = time.time() - t0
            report[name] = {"status": status, "wall_s": round(wall, 2),
                            "results": results[name]}
            print(f"[{status}] {name} ({wall:.1f}s)", flush=True)
        completed = True
    finally:
        # The JSON record must exist (and say "fail") even when the
        # driver itself dies mid-run — a partial record with a "pass"
        # default, or no record at all, would let always()-style CI
        # artifact steps mask the failure.
        if args.json:
            ok = completed and failures == 0
            payload = {"status": "pass" if ok else "fail",
                       "benchmarks": report,
                       "total_wall_s": round(time.time() - t_start, 2),
                       "failures": failures,
                       "completed": completed}
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=1, default=str)
            print(f"\nwrote {args.json}")
    print()
    print(json.dumps(results, indent=1, default=str))
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
