"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig12      # substring filter

Each module exposes run() -> dict and asserts its reproduction bands
internally; this driver reports PASS/FAIL per benchmark and dumps the
numbers.
"""
from __future__ import annotations

import json
import sys
import time
import traceback

from . import (engine_dequeue, engine_xval, fig09_command_schedule,
               fig10_ca_pins, fig12_tpot, fig13_lbr, fig14_energy,
               queue_depth, refresh_stall, sparse_overfetch,
               tab_mc_complexity, vba_design_space)

ALL = [
    ("fig09_command_schedule", fig09_command_schedule),
    ("fig10_ca_pins", fig10_ca_pins),
    ("tab_mc_complexity", tab_mc_complexity),
    ("queue_depth", queue_depth),
    ("engine_dequeue", engine_dequeue),
    ("vba_design_space", vba_design_space),
    ("engine_xval", engine_xval),
    ("fig12_tpot", fig12_tpot),
    ("fig13_lbr", fig13_lbr),
    ("fig14_energy", fig14_energy),
    ("refresh_stall", refresh_stall),
    ("sparse_overfetch", sparse_overfetch),
]


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    pat = argv[0] if argv else ""
    failures = 0
    results = {}
    for name, mod in ALL:
        if pat and pat not in name:
            continue
        t0 = time.time()
        try:
            results[name] = mod.run()
            status = "PASS"
        except AssertionError as e:
            results[name] = {"error": str(e)}
            status = "FAIL"
            failures += 1
        except Exception:
            results[name] = {"error": traceback.format_exc()[-800:]}
            status = "ERROR"
            failures += 1
        print(f"[{status}] {name} ({time.time()-t0:.1f}s)", flush=True)
    print()
    print(json.dumps(results, indent=1, default=str))
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
