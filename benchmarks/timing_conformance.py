"""Timing-protocol conformance census (ISSUE: sanitizer over every
registered scheduler policy).

Every policy in ``repro.core.sched.registry`` replays its family's
facade trace suite plus adversarial stressors with command-trace
emission on, and the independent :mod:`repro.analysis.timing_checker`
re-derives legality of the full command stream from the timing
dataclasses alone (JEDEC Table V rules for the HBM4 policies, RoMe
Table III row-command rules for the RoMe policies — see
docs/timing_sanitizer.md).

The benchmark asserts **zero violations** across every (policy, trace)
cell; the committed baseline additionally pins the exact per-policy
command census (``rel_tol`` 0), so a scheduler change that silently
alters command streams — even a legal one — shows up in the
bench_compare gate rather than only in downstream bandwidth drift.

``--reduced`` sweeps one policy per distinct sim kind with shorter
stressors (the PR-CI smoke); the nightly job runs the full 9-policy
sweep. Both are gated against their own baseline
(``timing_conformance[_reduced].json``).
"""
from __future__ import annotations

from repro.analysis.conformance import conformance_report


def run(reduced: bool = False) -> dict:
    rep = conformance_report(reduced=reduced)
    assert rep["n_commands"] > 0, "conformance sweep replayed no commands"
    for name, pol in rep["policies"].items():
        assert pol["clean"], (
            f"{name}: {pol['total_violations']} timing violations "
            f"{pol['violations']}"
            + (f"; examples: {pol['examples'][:3]}"
               if "examples" in pol else ""))
    assert rep["clean"]
    return rep


if __name__ == "__main__":
    import argparse
    import json
    import time
    import traceback
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--reduced", action="store_true",
                   help="one policy per sim kind, shorter stressors")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write a benchmarks.run-shaped payload to PATH "
                        "(gateable by scripts/bench_compare.py)")
    args = p.parse_args()
    name = ("timing_conformance_reduced" if args.reduced
            else "timing_conformance")
    t0 = time.time()
    try:
        results = run(reduced=args.reduced)
        status = "PASS"
    except AssertionError as e:
        results = {"error": str(e)}
        status = "FAIL"
    except Exception:
        results = {"error": traceback.format_exc()[-800:]}
        status = "ERROR"
    wall = round(time.time() - t0, 2)
    print(json.dumps(results, indent=1, default=str))
    print(f"[{status}] {name} ({wall:.1f}s)", flush=True)
    if args.json:
        payload = {"status": "pass" if status == "PASS" else "fail",
                   "benchmarks": {name: {"status": status, "wall_s": wall,
                                         "results": results}},
                   "total_wall_s": wall,
                   "failures": int(status != "PASS"),
                   "completed": True}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print(f"wrote {args.json}")
    raise SystemExit(0 if status == "PASS" else 1)
