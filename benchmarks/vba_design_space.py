"""§IV-B VBA design-space exploration: 6 configurations (Fig 7 b/c/d x
Fig 8 a/b).

Paper: all six deliver full bandwidth from a single VBA and perform within
3.6 % of each other; they differ sharply in DRAM-internal datapath area.
7(d)+8(b) — interleaved banks from different BGs + lockstep PCs — is the
only point with NO internal DRAM change, and is adopted.
"""
from __future__ import annotations

from repro.core import ADOPTED, ALL_VBA_CONFIGS
from repro.core import sched as eng


def run() -> dict:
    perf = {}
    for cfg in ALL_VBA_CONFIGS:
        # Performance model: every VBA point feeds the full channel; the
        # geometry differences (VBA count, effective row size) shift only
        # the interleave pattern. Simulate a 1 MB stream with the point's
        # geometry.
        n_vbas = cfg.vbas_per_channel
        row = cfg.effective_row_bytes
        sim = eng.RoMeChannelSim(n_vbas=max(2, n_vbas // 8), refresh=False)
        r = sim.run(eng.sequential_read_txns_rome(1 << 20,
                                                  n_vbas=max(2, n_vbas // 8),
                                                  row_bytes=4096))
        perf[cfg.name] = r.bandwidth_gbps / sim.g.bandwidth_gbps

    spread = (max(perf.values()) - min(perf.values())) / max(perf.values())
    assert spread <= 0.036 + 1e-6, f"perf spread {spread:.3f} > 3.6%"
    assert not ADOPTED.dram_internal_change
    others = [c for c in ALL_VBA_CONFIGS if c is not ADOPTED]
    assert all(c.dram_internal_change or c.pc_mode is ADOPTED.pc_mode
               for c in others if c.bank_mode is ADOPTED.bank_mode)
    return {
        "bandwidth_eff": {k: round(v, 4) for k, v in perf.items()},
        "perf_spread": f"{spread:.2%} (paper: <=3.6%)",
        "geometry": {c.name: {"row_bytes": c.effective_row_bytes,
                              "vbas_per_channel": c.vbas_per_channel,
                              "internal_change": c.dram_internal_change,
                              "area_overhead": f"{c.area_overhead_frac:.0%}"}
                     for c in ALL_VBA_CONFIGS},
        "adopted": ADOPTED.name,
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
