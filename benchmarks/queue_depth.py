"""§V-A queue-depth sweep: bandwidth vs request-queue depth.

Paper claim: HBM4 needs >= 45 in-flight entries to saturate a channel
(tCCDS:tRC ratio > 40x forces deep lookahead under a page-interleaved map);
RoMe saturates with a depth of TWO (tR2RS:tRD_row < 2x).
"""
from __future__ import annotations

from repro.core import sched as eng

HBM4_DEPTHS = (2, 4, 8, 16, 32, 45, 64, 96)
CLOSED_DEPTHS = (2, 16, 64)
ROME_DEPTHS = (1, 2, 3, 4, 8)
NBYTES = 1 << 18


def run() -> dict:
    hbm4 = {}
    for d in HBM4_DEPTHS:
        sim = eng.HBM4ChannelSim(queue_depth=d, refresh=False)
        # row_linear = page-interleaved streaming: saturation requires the
        # scheduler to overlap rows from different bank groups (the regime
        # behind the >=45-entry claim).
        r = sim.run(eng.sequential_read_txns_hbm4(NBYTES,
                                                  layout="row_linear"))
        hbm4[d] = r.bandwidth_gbps / sim.g.bandwidth_gbps
    closed = {}
    for d in CLOSED_DEPTHS:
        # Closed-page comparison point: sheds the row-locality state but
        # pays ACT+PRE per 32 B column — simplicity without RoMe's
        # granularity change caps far below peak at every depth.
        sim = eng.HBM4ClosedPageChannelSim(queue_depth=d, refresh=False)
        r = sim.run(eng.sequential_read_txns_hbm4(NBYTES // 8,
                                                  layout="row_linear"))
        closed[d] = r.bandwidth_gbps / sim.g.bandwidth_gbps
    rome = {}
    for d in ROME_DEPTHS:
        sim = eng.RoMeChannelSim(queue_depth=d, refresh=False)
        r = sim.run(eng.sequential_read_txns_rome(NBYTES * 4))
        rome[d] = r.bandwidth_gbps / sim.g.bandwidth_gbps

    # RoMe with depth 2 must be at (or above) HBM4's best efficiency.
    assert rome[2] >= 0.95, rome
    assert rome[2] >= max(hbm4.values()) - 0.02
    # Shallow HBM4 queues lose substantial bandwidth.
    assert hbm4[2] < 0.70 * max(hbm4.values()), hbm4
    # Closed page never saturates: always-precharge at column granularity.
    assert max(closed.values()) < 0.5 * max(hbm4.values()), closed
    return {
        "hbm4_eff_by_depth": {k: round(v, 4) for k, v in hbm4.items()},
        "hbm4_closed_eff_by_depth": {k: round(v, 4)
                                     for k, v in closed.items()},
        "rome_eff_by_depth": {k: round(v, 4) for k, v in rome.items()},
        "rome_saturation_depth": min(d for d, e in rome.items()
                                     if e >= 0.95),
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
