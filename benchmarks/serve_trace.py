"""Serving-trace replay sweep: offered load x policy -> SLO metrics
(ROADMAP: serving traces end to end).

End to end from *generated requests* — no hand-built Txn lists anywhere:
a seeded Poisson :class:`~repro.serve.replay.ArrivalProcess` feeds the
real :class:`~repro.serve.batching.ContinuousBatcher` +
:class:`~repro.serve.kv_cache.RowPagedKVCache`; every decode step's
multi-tenant extent stream runs through
:class:`~repro.core.system_sim.SystemSim` under the policy under test,
and the measured makespans fold back into request timelines
(:mod:`repro.serve.replay`). Cells are {FR-FCFS open-page HBM4, RoMe row
policy} x {near-zero load, rho=0.7, rho=1.4} of an estimated saturation
throughput, reporting per-request TTFT/TPOT p50/p99, occupancy, and
goodput vs offered load.

Reproduction bands asserted:

* near-zero-load TPOT matches the analytic ``perfmodel.tpot`` path
  (``stream_mem_ns`` over the same recorded streams) within the
  established 15 % engine_xval band, for both families;
* KV byte conservation on the recorded near-zero trace (every admitted
  request's appends/reads appear exactly once);
* queueing physics: goodput grows with offered load, the rho=1.4 point
  is saturated (offered > goodput), occupancy rises with load;
* at *equal channel width* the granularity change alone is p99-TPOT
  neutral (within 10 %) — the serving-side echo of the policy sweep's
  margins-not-multiples finding, with RoMe's whole-row append overfetch
  visibly taxing ``bytes_moved``;
* the SLO headline: at *equal CA-pin budget* — HBM4 x 8 channels vs
  RoMe x 9, the paper's 32:36 full-cube ratio scaled down — RoMe wins
  p99 TPOT at the saturated load point. This is the +12.5 % bandwidth
  mechanism (pin savings reinvested as channels,
  benchmarks/full_cube.py) cashed out as a measured tail-latency delta
  under serving load.

The load sweep uses the band-valid step scale (2^-12, data-bound steps;
see ``build_replay``). The equal-pin pair spreads the same steps over
4x the channels (per-channel load below the analytic band's regime), so
it carries the headline delta but no xval assertion. ``--reduced`` runs
a structurally identical ACT-bound miniature for CI smoke — bands that
assume the analytic regime are skipped there.

Beyond the Poisson axis, the ``arrival_kinds`` section sweeps the other
two :class:`~repro.serve.replay.ArrivalProcess` disciplines — bursty
(burst admissions co-schedule tenants in one window) and closed-loop
(load self-regulates with service time) — and the ``unscaled`` section
replays the *unscaled* (``scale=1.0``) weight slice end to end through
the hybrid SystemSim: GB-scale decode steps priced by the calibrated
queue-window model (``hybrid_fraction`` reported), the CI-feasibility
proof for production-size traces. Every cell carries its wall-clock
``sim_seconds`` so the regression gate tracks the speedup trajectory.

The ``prefill`` section turns prompt ingestion on
(``prefill_chunk_tokens``): prompts stream through the memory system in
chunks — chunk-attention prefix reads plus row-granular K/V appends —
either packed into the concurrent decode step
(``prefill_overlap=True``, packing-prefetch) or claiming dedicated
prefill-only steps that stall decode. Steps run warm
(:meth:`SystemSim.warm_session`): saturated prefill leaves channel
queues draining across step boundaries. Gated claim: at rho >= 1.5,
overlap measurably reduces p99 TTFT vs stalling, per policy. The
full run adds the equal-pin prefill headline — HBM4 x 8 vs RoMe x 9
channels under bursty arrivals with chunked prefill — answering
whether the paper's goodput edge survives prefill contending with
decode (``prefill_headline``).
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.paper_workloads import REPLAY_SWEEP_MIX
from repro.obs import MetricsProbe, ObsCollector
from repro.obs.export import chrome_trace_events, trace_total_bytes
from repro.perfmodel.tpot import stream_mem_ns
from repro.serve.replay import build_replay

WORKLOAD = "deepseek-v3"
POLICIES = ("hbm4_frfcfs", "rome_qd2")
# Scaled serving mix: median-32-token prompts, mean-8-token outputs at
# the 1/16 length scale (shared with examples/serve_replay.py).
MIX = REPLAY_SWEEP_MIX
LENGTH_SCALE = 1 / 16
NEAR_ZERO_RPS = 1e3          # inter-arrival ~1 ms >> service: serial regime
RHOS = (0.7, 1.4)            # offered load as a fraction of estimated cap
N_SLOTS = 4                  # batch slots per cell (passed to build_replay)
SEED = 0


#: Equal-pin channel widths: the paper's 32 HBM4 vs 36 RoMe channels per
#: cube (same CA-pin budget, fig10_ca_pins) at quarter scale.
EQUAL_PIN_CHANNELS = {"hbm4_frfcfs": 8, "rome_qd2": 9}


def _cell(policy: str, rate_rps: float, n_requests: int, *,
          scale: float, n_channels: int = 2, keep_traces: bool = False,
          kind: str = "poisson", sim_mode: str = "cycle", **arrival_kw):
    eng, acc = build_replay(
        workload=WORKLOAD, policy=policy, rate_rps=rate_rps,
        n_requests=n_requests, kind=kind, seed=SEED, mix=MIX,
        length_scale=LENGTH_SCALE, scale=scale, n_slots=N_SLOTS,
        n_channels=n_channels, keep_traces=keep_traces,
        sim_mode=sim_mode, **arrival_kw)
    t0 = time.perf_counter()
    res = eng.run()
    return res, acc, round(time.perf_counter() - t0, 3)


def _check_conservation(res) -> int:
    """Recorded KV bytes == what the request lengths dictate; returns the
    total KV bytes for the report."""
    total = 0
    assert res.requests
    for r in res.requests:
        recs = [rec for tr in res.traces for rec in tr.stream
                if rec.stream_id == r.rid]
        writes = sum(rec.nbytes for rec in recs if rec.is_write)
        reads = sum(rec.nbytes for rec in recs if not rec.is_write)
        total += writes + reads
        assert r.n_out == r.max_new_tokens, r
        # the cache geometry is not carried on the result; KV reads are
        # whole pages by construction, so the smallest read is one page
        pb = min((rec.nbytes for rec in recs if not rec.is_write),
                 default=0)
        assert pb > 0 and reads % pb == 0, (r.rid, reads, pb)
        assert writes > 0 and writes % (2 * r.n_out) == 0, (r.rid, writes)
    return total


def _obs_section(scale: float, n_requests: int) -> dict:
    """Observation-is-free check on the full serving loop: the same
    seeded replay with the repro.obs stack attached must be
    bit-identical to the bare run, and the exported Chrome-trace
    counter tracks must conserve bytes (integral == the result's
    ``bytes_moved``). Complements benchmarks/obs_overhead.py, which
    gates the same contract at the channel-engine level."""
    out: dict = {}
    for policy in POLICIES:
        kw = dict(scale=scale, kind="bursty", burst_size=4)
        bare, _, _ = _cell(policy, 2e5, n_requests, **kw)
        col = ObsCollector(probe=MetricsProbe(window_ns=200.0))
        obs, _, _ = _cell(policy, 2e5, n_requests, collector=col, **kw)
        assert bare.summary() == obs.summary(), policy
        assert ([s.dur_ns for s in bare.steps]
                == [s.dur_ns for s in obs.steps]), policy
        trace = {"traceEvents": chrome_trace_events(col, col.probe)}
        s = obs.summary()
        tb = trace_total_bytes(trace)
        assert tb == s["bytes_moved"], (policy, tb, s["bytes_moved"])
        spans = col.request_spans()
        assert len(spans) == n_requests, (policy, len(spans))
        out[policy] = {"identity": 1, "trace_bytes": tb,
                       "row_hit_rate": round(col.probe.row_hit_rate(), 4),
                       "n_spans": len(spans)}
    assert out["hbm4_frfcfs"]["row_hit_rate"] > 0.5, out
    assert out["rome_qd2"]["row_hit_rate"] == 0.0, out
    return out


def run(reduced: bool = False) -> dict:
    t_run0 = time.perf_counter()
    scale = 2 ** -13 if reduced else 2 ** -12
    n_req = {"near": 2, "sweep": 5} if reduced else {"near": 4, "sweep": 10}

    out: dict = {"config": {
        "workload": WORKLOAD, "policies": list(POLICIES),
        "length_scale": LENGTH_SCALE, "step_scale_log2": int(np.log2(scale)),
        "reduced": reduced,
    }}

    # --- near-zero load: the analytic cross-validation anchor -------------
    xval = {}
    near = {}
    for policy in POLICIES:
        res, acc, secs = _cell(policy, NEAR_ZERO_RPS, n_req["near"],
                               scale=scale, keep_traces=True)
        assert res.completed == n_req["near"], (policy, res.completed)
        assert max(s.n_active for s in res.steps) == 1, policy
        meas = float(np.mean([s.dur_ns for s in res.steps]))
        model = float(np.mean([stream_mem_ns(tr.stream, acc)
                               for tr in res.traces]))
        rel = abs(meas - model) / model
        kv_bytes = _check_conservation(res)
        xval[policy] = {"mean_step_ns": round(meas, 1),
                        "analytic_step_ns": round(model, 1),
                        "rel_err": round(rel, 4),
                        "kv_bytes": kv_bytes,
                        "sim_seconds": secs}
        if not reduced:
            # The established engine_xval band, now reached from a full
            # serving loop instead of a hand-built decode slice.
            assert rel < 0.15, (policy, meas, model, rel)
        near[policy] = res
    out["xval"] = xval

    # --- offered-load sweep ----------------------------------------------
    # Capacity estimate from the near-zero HBM4 TPOT: slots / (TPOT x
    # mean output tokens). Both policies sweep the same absolute loads.
    tpots0 = near["hbm4_frfcfs"].tpots_ns
    tpot0 = (float(np.mean(tpots0)) if tpots0
             else xval["hbm4_frfcfs"]["mean_step_ns"])
    mean_out = MIX.out_mean * LENGTH_SCALE
    cap_rps = N_SLOTS / (tpot0 * 1e-9 * mean_out)
    out["capacity_rps_est"] = round(cap_rps, 1)

    cells = {}
    for policy in POLICIES:
        res0 = near[policy]
        cells[f"{policy}/near_zero"] = dict(
            offered_rps=NEAR_ZERO_RPS, **res0.summary())
        for rho in RHOS:
            rate = rho * cap_rps
            res, _, secs = _cell(policy, rate, n_req["sweep"], scale=scale)
            assert res.completed == n_req["sweep"], (policy, rho)
            cells[f"{policy}/rho{rho}"] = dict(
                offered_rps=round(rate, 1), sim_seconds=secs,
                **res.summary())
    out["cells"] = cells

    # --- bursty / closed-loop arrival disciplines --------------------------
    # The other two ArrivalProcess generators, swept at the same absolute
    # load as the rho sweep's lower point (closed-loop load self-regulates;
    # rate_rps only seeds its think-time scale).
    kinds = {}
    for policy in POLICIES:
        rate = RHOS[0] * cap_rps
        res, _, secs = _cell(policy, rate, n_req["sweep"], scale=scale,
                             kind="bursty", burst_size=4)
        assert res.completed == n_req["sweep"], (policy, "bursty")
        # A whole burst lands in one admission window: the batch fills
        # deeper than the near-zero (serial) regime ever does.
        assert max(s.n_active for s in res.steps) > 1, (policy, "bursty")
        kinds[f"{policy}/bursty"] = dict(
            offered_rps=round(rate, 1), sim_seconds=secs, **res.summary())
        res, _, secs = _cell(policy, rate, n_req["sweep"], scale=scale,
                             kind="closed", n_users=N_SLOTS,
                             think_ns=1e9 / rate)
        assert res.completed == n_req["sweep"], (policy, "closed")
        # Closed loop seeds n_users at t=0: the batch starts full.
        assert res.steps[0].n_active == min(N_SLOTS, n_req["sweep"]), \
            (policy, "closed")
        kinds[f"{policy}/closed"] = dict(
            offered_rps=round(rate, 1), sim_seconds=secs, **res.summary())
    out["arrival_kinds"] = kinds

    # --- observability: attach-and-compare (repro.obs) ---------------------
    out["obs"] = _obs_section(scale, n_req["near"])

    # --- unscaled replay via the hybrid fast path --------------------------
    # scale=1.0: each decode step reads the full (tens-of-GB) weight
    # slice — ~1e9 decomposed transactions per step, unrunnable by the
    # cycle engine. The hybrid SystemSim prices every step with the
    # calibrated queue-window model; completing here (in seconds) IS the
    # CI-feasibility result, and sim_seconds tracks it in the baseline.
    unscaled = {}
    for policy in POLICIES:
        res, _, secs = _cell(policy, NEAR_ZERO_RPS, n_req["near"],
                             scale=1.0, sim_mode="hybrid")
        assert res.completed == n_req["near"], (policy, "unscaled")
        s = res.summary()
        assert s["hybrid_fraction"] == 1.0, (policy, s["hybrid_fraction"])
        unscaled[policy] = dict(sim_seconds=secs, **s)
    out["unscaled"] = unscaled

    # --- chunked prefill + packing-prefetch (warm sessions) ----------------
    # Prompts stream through the memory system in chunks; steps carry
    # channel state across boundaries (warm=True) — saturated prefill
    # leaves queues draining when the next step launches. Cells run the
    # band-validated *hybrid* path at the run scale: the packing-
    # prefetch effect is that every dedicated prefill-only step re-pays
    # the full weight-slice read without emitting a token, which only
    # bites when the weight slice dominates the step — the run-scale
    # regime, minutes per cell in the cycle engine but ~1 s priced by
    # the queue-window model (cross-checked against the cycle engine at
    # this exact operating point in tests/test_serve_replay.py's scaled
    # smoke and by benchmarks/hybrid_xval.py's band).
    chunks = (4, 16) if reduced else (8, 32)
    n_pf = 24 if reduced else 32
    prefill = {}
    for policy in POLICIES:
        res0, _, _ = _cell(policy, NEAR_ZERO_RPS, n_req["near"],
                           scale=scale, sim_mode="hybrid", warm=True,
                           prefill_chunk_tokens=chunks[0])
        tpot0p = (float(np.mean(res0.tpots_ns)) if res0.tpots_ns
                  else float(np.mean([s.dur_ns for s in res0.steps])))
        rate = 1.5 * N_SLOTS / (tpot0p * 1e-9 * mean_out)
        for chunk in chunks:
            for overlap in (False, True):
                res, _, secs = _cell(policy, rate, n_pf, scale=scale,
                                     sim_mode="hybrid", warm=True,
                                     prefill_chunk_tokens=chunk,
                                     prefill_overlap=overlap)
                assert res.completed == n_pf, (policy, chunk, overlap)
                # Every request clears prefill before its first token.
                assert all(r.prefill_done_ns >= 0 for r in res.requests)
                assert all(r.first_token_ns >= r.prefill_done_ns
                           for r in res.requests), (policy, chunk, overlap)
                s = res.summary()
                assert s["n_prefill_steps"] + s["n_mixed_steps"] > 0, \
                    (policy, chunk, overlap)
                key = (f"{policy}/chunk{chunk}/"
                       f"{'overlap' if overlap else 'stall'}")
                prefill[key] = dict(offered_rps=round(rate, 1),
                                    sim_seconds=secs, **s)
        # Packing-prefetch gate: at rho >= 1.5, overlapping prefill chunk
        # fetch with decode compute beats stalling decode on the TTFT
        # tail — dedicated prefill-only steps serialize the queue.
        ov = prefill[f"{policy}/chunk{chunks[0]}/overlap"]
        st = prefill[f"{policy}/chunk{chunks[0]}/stall"]
        assert ov["ttft_p99_ns"] < st["ttft_p99_ns"], \
            (policy, chunks[0], ov["ttft_p99_ns"], st["ttft_p99_ns"])
    out["prefill"] = prefill

    # --- bands -------------------------------------------------------------
    for policy in POLICIES:
        lo = cells[f"{policy}/rho{RHOS[0]}"]
        hi = cells[f"{policy}/rho{RHOS[1]}"]
        nz = cells[f"{policy}/near_zero"]
        # goodput rises with offered load; the top point is saturated
        assert hi["goodput_rps"] > lo["goodput_rps"] > nz["goodput_rps"], \
            policy
        assert hi["offered_rps"] > 1.05 * hi["goodput_rps"], (policy, hi)
        # queueing shows up in the TTFT tail, occupancy in the slots
        assert hi["ttft_p99_ns"] > nz["ttft_p99_ns"], policy
        assert hi["occupancy"] > nz["occupancy"], policy

    # Equal channel width: granularity alone is a margin, not a multiple
    # (cf. policy_sweep) — and RoMe pays whole-row append overfetch.
    hbm4_hi = cells[f"hbm4_frfcfs/rho{RHOS[1]}"]
    rome_hi = cells[f"rome_qd2/rho{RHOS[1]}"]
    eq_width_delta = hbm4_hi["tpot_p99_ns"] / rome_hi["tpot_p99_ns"] - 1
    out["equal_width"] = {
        "p99_tpot_hbm4_ns": hbm4_hi["tpot_p99_ns"],
        "p99_tpot_rome_ns": rome_hi["tpot_p99_ns"],
        "p99_tpot_delta_frac": round(eq_width_delta, 4),
    }
    if not reduced:
        assert abs(eq_width_delta) < 0.10, out["equal_width"]

    # --- equal-pin headline (HBM4 x 8ch vs RoMe x 9ch) ---------------------
    if reduced:
        out["sim_seconds"] = round(time.perf_counter() - t_run0, 3)
        return out
    pin = {}
    for policy, nch in EQUAL_PIN_CHANNELS.items():
        res0, _, _ = _cell(policy, NEAR_ZERO_RPS, n_req["near"],
                           scale=scale, n_channels=nch)
        tpot_nz = (float(np.mean(res0.tpots_ns)) if res0.tpots_ns
                   else float(np.mean([s.dur_ns for s in res0.steps])))
        rate = RHOS[1] * N_SLOTS / (tpot_nz * 1e-9 * mean_out)
        res, _, secs = _cell(policy, rate, n_req["sweep"], scale=scale,
                             n_channels=nch)
        assert res.completed == n_req["sweep"], (policy, nch)
        pin[policy] = dict(n_channels=nch, offered_rps=round(rate, 1),
                           tpot_nz_ns=round(tpot_nz, 1), sim_seconds=secs,
                           **res.summary())
        cells[f"{policy}/equal_pin_rho{RHOS[1]}"] = pin[policy]
    delta = (pin["hbm4_frfcfs"]["tpot_p99_ns"]
             / pin["rome_qd2"]["tpot_p99_ns"] - 1)
    out["headline"] = {
        "p99_tpot_hbm4_ns": pin["hbm4_frfcfs"]["tpot_p99_ns"],
        "p99_tpot_rome_ns": pin["rome_qd2"]["tpot_p99_ns"],
        "p99_tpot_delta_frac": round(delta, 4),
        "goodput_hbm4_rps": pin["hbm4_frfcfs"]["goodput_rps"],
        "goodput_rome_rps": pin["rome_qd2"]["goodput_rps"],
    }
    # The pin-equivalent system must cash the bandwidth edge out as a
    # positive, bounded tail-latency win under load.
    assert 0.0 < delta < 0.5, out["headline"]

    # --- equal-pin goodput with bursty chunked prefill ---------------------
    # The ISSUE's equal-pin question: does the reinvested-pins goodput
    # edge survive once bursty prefill contends with decode? Same
    # 8-vs-9-channel budget, bursty arrivals, chunked prefill with
    # packing-prefetch on, warm sessions.
    pinp = {}
    for policy, nch in EQUAL_PIN_CHANNELS.items():
        res0, _, _ = _cell(policy, NEAR_ZERO_RPS, n_req["near"],
                           scale=scale, n_channels=nch, sim_mode="hybrid",
                           warm=True, prefill_chunk_tokens=chunks[0])
        tpot0p = (float(np.mean(res0.tpots_ns)) if res0.tpots_ns
                  else float(np.mean([s.dur_ns for s in res0.steps])))
        rate = 1.5 * N_SLOTS / (tpot0p * 1e-9 * mean_out)
        res, _, secs = _cell(policy, rate, n_pf, scale=scale,
                             n_channels=nch, sim_mode="hybrid", warm=True,
                             prefill_chunk_tokens=chunks[0],
                             prefill_overlap=True,
                             kind="bursty", burst_size=4)
        assert res.completed == n_pf, (policy, nch, "prefill_pin")
        pinp[policy] = dict(n_channels=nch, offered_rps=round(rate, 1),
                            sim_seconds=secs, **res.summary())
        prefill[f"{policy}/equal_pin"] = pinp[policy]
    pdelta = (pinp["rome_qd2"]["goodput_rps"]
              / pinp["hbm4_frfcfs"]["goodput_rps"] - 1)
    out["prefill_headline"] = {
        "goodput_rome_rps": pinp["rome_qd2"]["goodput_rps"],
        "goodput_hbm4_rps": pinp["hbm4_frfcfs"]["goodput_rps"],
        "goodput_delta_frac": round(pdelta, 4),
        "ttft_p99_rome_ns": pinp["rome_qd2"]["ttft_p99_ns"],
        "ttft_p99_hbm4_ns": pinp["hbm4_frfcfs"]["ttft_p99_ns"],
    }
    # Sanity bound only: the *direction* of the answer is the result the
    # baseline records, not an assumption the gate bakes in.
    assert abs(pdelta) < 0.5, out["prefill_headline"]

    out["sim_seconds"] = round(time.perf_counter() - t_run0, 3)
    return out


if __name__ == "__main__":
    import argparse
    import json
    import traceback
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--reduced", action="store_true",
                   help="CI-smoke miniature (skips analytic-regime bands)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write a benchmarks.run-shaped payload to PATH "
                        "(gateable by scripts/bench_compare.py)")
    args = p.parse_args()
    name = "serve_trace_reduced" if args.reduced else "serve_trace"
    t0 = time.time()
    try:
        results = run(reduced=args.reduced)
        status = "PASS"
    except AssertionError as e:
        results = {"error": str(e)}
        status = "FAIL"
    except Exception:
        results = {"error": traceback.format_exc()[-800:]}
        status = "ERROR"
    wall = round(time.time() - t0, 2)
    print(json.dumps(results, indent=1, default=str))
    print(f"[{status}] {name} ({wall:.1f}s)", flush=True)
    if args.json:
        payload = {"status": "pass" if status == "PASS" else "fail",
                   "benchmarks": {name: {"status": status, "wall_s": wall,
                                         "results": results}},
                   "total_wall_s": wall,
                   "failures": int(status != "PASS"),
                   "completed": True}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print(f"wrote {args.json}")
    raise SystemExit(0 if status == "PASS" else 1)
