"""Fig 12: decode TPOT, HBM4 vs RoMe, for DeepSeek-V3 / Grok-1 / Llama-3
across batch sizes at sequence length 8K.

Paper: RoMe reduces TPOT by 10.4 / 10.2 / 9.0 % at the capacity-limited
batch; prefill is insensitive (<0.1 %, compute-bound).
"""
from __future__ import annotations

from repro.configs.paper_workloads import PAPER_WORKLOADS
from repro.perfmodel.accelerator import paper_accelerator
from repro.perfmodel.tpot import max_batch, prefill_ns, tpot_ns

BATCHES = (16, 64, 256)
PAPER_DELTAS = {"deepseek-v3": 0.104, "grok-1": 0.102, "llama-3-405b": 0.090}


def run() -> dict:
    acc_h = paper_accelerator("hbm4")
    acc_r = paper_accelerator("rome")
    out = {}
    for name, w in PAPER_WORKLOADS.items():
        rows = {}
        for b in BATCHES:
            th = tpot_ns(w, acc_h, batch=b).total_ns
            tr = tpot_ns(w, acc_r, batch=b).total_ns
            rows[b] = {"hbm4_ms": th / 1e6, "rome_ms": tr / 1e6,
                       "delta": 1 - tr / th}
        ph = prefill_ns(w, acc_h, batch=8).total_ns
        pr = prefill_ns(w, acc_r, batch=8).total_ns
        d256 = rows[256]["delta"]
        paper = PAPER_DELTAS[name]
        # Reproduction band: within 3 percentage points of the paper.
        assert abs(d256 - paper) < 0.03, (name, d256, paper)
        assert abs(1 - pr / ph) < 0.001, "prefill must be insensitive"
        out[name] = {"tpot": rows,
                     "prefill_delta": 1 - pr / ph,
                     "paper_delta": paper,
                     "max_batch": max_batch(w)}
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
