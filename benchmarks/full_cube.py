"""Full-cube cycle-level runs at paper system width (ROADMAP item).

32-channel HBM4 vs 36-channel RoMe (§IV-E: the C/A pins RoMe frees fund
4 extra channels per cube, +12.5 % peak bandwidth), simulated
cycle-level via ``SystemSim.run(stream, workers=N)`` — the process-pool
path is what makes cube-width runs practical, and this benchmark is the
standing proof plus its wall-time tracker (the ``--json`` record CI
keeps as an artifact).

Two regimes:

* ``bulk`` — contiguous read stream loading every channel: the paper
  headline band. RoMe's aggregate bandwidth must exceed HBM4's by
  ~12.5 % (channel count; per-channel efficiency is a wash at row
  granularity).
* ``decode`` — the scaled DeepSeek-V3 ``from_layer_ops`` decode trace
  at cube width, cross-checked against the TPOT memory-time model
  (``perfmodel.tpot.stream_mem_ns``) and the address map's load
  balance.
"""
from __future__ import annotations

import os
import time

from repro.configs.paper_workloads import PAPER_WORKLOADS
from repro.core.system_sim import SystemSim
from repro.core.timing import hbm4_config, rome_config
from repro.perfmodel.tpot import stream_mem_ns, xval_decode_stream
from repro.workloads import bulk_stream

BULK_BYTES_PER_CHANNEL = 256 << 10
DECODE_WORKLOAD = "deepseek-v3"
DECODE_SCALE = 2 ** -9
DECODE_OPS = 16


def default_workers() -> int:
    return max(1, os.cpu_count() or 1)


def run(workers: int | None = None) -> dict:
    workers = workers or default_workers()
    t_all = time.time()
    cfgs = {"hbm4": hbm4_config(), "rome": rome_config()}

    bulk = {}
    for name, cfg in cfgs.items():
        nch = cfg.channels_per_cube
        t0 = time.time()
        sim = SystemSim(cfg, n_channels=nch)
        stream = bulk_stream(nch * BULK_BYTES_PER_CHANNEL)
        t_sim = time.time()
        res = sim.run(stream, workers=workers)
        sim_secs = time.time() - t_sim
        bulk[name] = {
            "n_channels": nch,
            "makespan_ns": round(res.total_ns, 1),
            "bandwidth_gbps": round(res.bandwidth_gbps, 1),
            "peak_cube_gbps": round(cfg.cube_bw_gbps, 1),
            "lbr": round(res.load_balance_ratio, 4),
            "wall_s": round(time.time() - t0, 2),
            # Engine time alone (stream build / setup excluded): the
            # wall-time tracker this benchmark exists to record.
            "sim_seconds": round(sim_secs, 3),
        }

    # Paper headline: +12.5 % aggregate bandwidth from the 4 extra
    # channels (36/32); per-channel efficiency is a wash, so the
    # measured ratio must sit in the headline band.
    ratio = bulk["rome"]["bandwidth_gbps"] / bulk["hbm4"]["bandwidth_gbps"]
    assert 1.08 < ratio < 1.18, (ratio, bulk)

    decode = {}
    w = PAPER_WORKLOADS[DECODE_WORKLOAD]
    for name, cfg in cfgs.items():
        nch = cfg.channels_per_cube
        stream, acc = xval_decode_stream(w, name, n_channels=nch,
                                         scale=DECODE_SCALE,
                                         n_ops=DECODE_OPS)
        t0 = time.time()
        t_sim = time.time()
        res = SystemSim(acc.mem_cfg, n_channels=acc.n_channels).run(
            stream, workers=workers)
        sim_secs = time.time() - t_sim
        model_ns = stream_mem_ns(stream, acc)
        rel = abs(res.total_ns - model_ns) / model_ns
        decode[name] = {
            "n_channels": nch,
            "stream_records": len(stream),
            "stream_mb": round(stream.total_bytes / 2 ** 20, 1),
            "makespan_ns": round(res.total_ns, 1),
            "tpot_mem_ns": round(model_ns, 1),
            "rel_err": round(rel, 4),
            "lbr": round(res.load_balance_ratio, 4),
            "wall_s": round(time.time() - t0, 2),
            "sim_seconds": round(sim_secs, 3),
        }
        # The TPOT cross-validation band holds at full cube width, and
        # the address map keeps the cube balanced.
        assert rel < 0.15, (name, res.total_ns, model_ns, rel)
        assert decode[name]["lbr"] > 0.95, decode[name]

    return {
        "workers": workers,
        "bulk": bulk,
        "bulk_bw_ratio": round(ratio, 4),
        "decode": decode,
        "total_wall_s": round(time.time() - t_all, 2),
    }


if __name__ == "__main__":
    import argparse
    import json
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--workers", type=int, default=None,
                   help="process-pool width (default: cpu count)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="also write the results to PATH")
    args = p.parse_args()
    out = run(workers=args.workers)
    text = json.dumps(out, indent=1, default=str)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text)
