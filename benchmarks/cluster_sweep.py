"""Fleet goodput vs offered load: equal-pin HBM4 vs RoMe across router
policies, plus the batched-pricing speedup that makes the sweep feasible.

Three claims, each carried by the record:

* **fleet curves** — ``ClusterSim`` sweeps of N replicas behind a
  router, equal-pin HBM4 (8 channels) vs RoMe (9 channels, the paper's
  pin-neutral comparison), over bursty open-loop *and* closed-loop
  arrivals and ≥2 placement policies. Per cell: goodput, TTFT/TPOT
  tails, rejection counts, conservation checks. The record notes
  whether RoMe's single-cube goodput edge compounds or washes out per
  router at fleet scale.
* **speedup** — pricing the fleet's decode steps through the batched
  census + signature memo cache (``StepPricer``) must beat the per-step
  unbatched path (the pre-batching implementation: per-extent Python
  loop censuses, one call per step, no cache — reproduced verbatim
  below as the reference) by ≥10× wall-clock on steps sampled from the
  real sweep. Also recorded: the intermediate vectorized-per-step time,
  so the ledger separates the census rewrite's win from the memo
  cache's win. A correctness guard asserts the reference and the
  batched path price identical features before timing anything.
* **scale** (full mode only) — a 1M-request, 8-replica hybrid-mode
  sweep completes in minutes of wall-clock; the measured request and
  step throughput are stamped in the record.

``--reduced`` shrinks the grid for PR-CI smoke; the standalone
``--json`` payload mimics ``benchmarks.run --json`` (one benchmark
entry named ``cluster_sweep_reduced``) so the same
``scripts/bench_compare.py`` gate applies to both sizes.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.analytic import calibrate, stream_time_ns
from repro.core.queue_model import StepPricer, queue_window_params
from repro.core.sched.registry import policy_spec
from repro.core.timing import hbm4_config, rome_config
from repro.serve.cluster import REJECTED, ClusterSim

#: Equal-pin channel counts (paper §VI): RoMe's narrower CA interface
#: buys one extra channel on the same pin budget.
EQUAL_PIN = {"hbm4_frfcfs": 8, "rome_qd2": 9}
ROUTERS = ("round_robin", "least_kv")
SPEEDUP_FLOOR = 10.0
N_SAMPLE_STREAMS = 128

#: Fleet-sweep sizing shared by every curve cell.
CELL = dict(workload="deepseek-v3", scale=1.0, sim_mode="hybrid",
            length_scale=1 / 64, n_slots=8, seed=0)


# ---------------------------------------------------------------------------
# Reference: the pre-batching per-step pricing path (kept verbatim so the
# speedup claim is measured against real code, not a strawman)
# ---------------------------------------------------------------------------

def _loop_unit_counts(amap, extents):
    out = np.zeros(amap.n_channels, dtype=np.int64)
    g = amap.stripe_bytes
    for start, nbytes in extents:
        if nbytes <= 0:
            continue
        first_unit = start // g
        last_unit = (start + nbytes - 1) // g
        n_units = last_unit - first_unit + 1
        full, rem = divmod(n_units, amap.n_channels)
        if full:
            out += full
        if rem:
            ch0 = first_unit % amap.n_channels
            idx = (ch0 + np.arange(rem)) % amap.n_channels
            np.add.at(out, idx, 1)
    return out


def _loop_touch_counts(amap, extents):
    out = np.zeros(amap.n_channels, dtype=np.int64)
    g, nch = amap.stripe_bytes, amap.n_channels
    for start, nbytes in extents:
        if nbytes <= 0:
            continue
        first_unit = start // g
        last_unit = (start + nbytes - 1) // g
        n_units = last_unit - first_unit + 1
        if n_units >= nch:
            out += 1
        else:
            idx = (first_unit % nch + np.arange(n_units)) % nch
            out[np.unique(idx)] += 1
    return out


def _unbatched_features(stream, cfg, amap, eff):
    """The pre-batching ``stream_features``: one call per step, four
    per-extent loop censuses, no signature cache."""
    reads = stream.extents("read")
    writes = stream.extents("write")
    base_ns = stream_time_ns(stream, cfg, amap, eff=eff)
    counts = (_loop_unit_counts(amap, reads)
              + _loop_unit_counts(amap, writes))
    fine_reads = [(a, n) for a, n in reads if n < cfg.row_bytes]
    fine_writes = [(a, n) for a, n in writes if n < cfg.row_bytes]
    fine = (_loop_unit_counts(amap, fine_reads)
            + _loop_unit_counts(amap, fine_writes))
    ext = (_loop_touch_counts(amap, reads)
           + _loop_touch_counts(amap, writes))
    return {
        "base_ns": base_ns,
        "span_ns": stream.span_ns,
        "txns_gating": float(counts.max(initial=0)),
        "fine_txns_gating": float(fine.max(initial=0)),
        "ext_gating": float(ext.max(initial=0)),
        "total_txns": int(counts.sum()),
        "mc_channel_bytes": counts * amap.stripe_bytes,
    }


# ---------------------------------------------------------------------------
# Fleet curve cells
# ---------------------------------------------------------------------------

def _cell(policy, n_channels, router, kind, rate_rps, n_requests,
          n_replicas, keep_samples=0, **kw):
    params = dict(CELL, policy=policy, n_channels=n_channels,
                  router=router, kind=kind, rate_rps=rate_rps,
                  n_requests=n_requests, n_replicas=n_replicas,
                  keep_sample_streams=keep_samples, **kw)
    cs = ClusterSim(**params)
    t0 = time.perf_counter()
    r = cs.run()
    wall = time.perf_counter() - t0
    # Conservation: issued requests are placed exactly once; everything
    # placed completes (rejection only under an SLO router, absent here).
    assert r.issued == n_requests, (r.issued, n_requests)
    assert r.completed + r.rejected == r.issued
    assert r.rejected == 0, r.rejected      # no SLO router in the curves
    assert (r.replica_of != REJECTED).all()
    assert int(r.requests_per_replica.sum()) == r.issued
    s = r.summary()
    s["wall_s"] = round(wall, 3)
    s["offered_rps"] = rate_rps
    return cs, r, s


def _curves(reduced: bool) -> tuple[dict, list]:
    """goodput-vs-offered-load per (policy, router, arrival kind); also
    returns sampled step streams for the speedup measurement."""
    loads = [1e5, 3e5] if reduced else [1e5, 2e5, 4e5, 8e5]
    n_req = 96 if reduced else 600
    n_rep = 2 if reduced else 4
    samples: list = []
    out: dict = {}
    for policy, nch in EQUAL_PIN.items():
        out[policy] = {}
        for router in ROUTERS:
            cell_rows: dict = {"bursty": {}, "closed": {}}
            for rate in loads:
                # Sample real decode-step streams from the RoMe cells
                # (across loads and routers, the production step mix)
                # until the speedup measurement has enough of them.
                keep = (N_SAMPLE_STREAMS - len(samples)
                        if policy == "rome_qd2" else 0)
                cs, r, s = _cell(policy, nch, router, "bursty", rate,
                                 n_req, n_rep, burst_size=8,
                                 keep_samples=max(keep, 0))
                if keep > 0:
                    samples.extend(cs.sample_streams)
                cell_rows["bursty"][f"{rate:g}"] = s
            _, r, s = _cell(policy, nch, router, "closed", loads[-1],
                            n_req, n_rep, n_users=4 * n_rep,
                            think_ns=1e4)
            cell_rows["closed"]["steady"] = s
            out[policy][router] = cell_rows
    out["_samples_policy"] = "rome_qd2"
    return out, samples


def _compounding(curves: dict) -> dict:
    """Does RoMe's single-cube goodput edge survive fleet routing? Per
    (router, load): fleet goodput ratio RoMe / HBM4."""
    out = {}
    for router in ROUTERS:
        rows = {}
        for kind in ("bursty", "closed"):
            h = curves["hbm4_frfcfs"][router][kind]
            m = curves["rome_qd2"][router][kind]
            for load in h:
                denom = max(h[load]["goodput_rps"], 1e-9)
                rows[f"{kind}@{load}"] = round(
                    m[load]["goodput_rps"] / denom, 4)
        out[router] = rows
    return out


# ---------------------------------------------------------------------------
# Speedup: batched + memoized pricing vs the per-step unbatched path
# ---------------------------------------------------------------------------

def _speedup(samples, policy: str) -> dict:
    spec = policy_spec(policy)
    cfg = hbm4_config() if spec.family == "hbm4" else rome_config()
    amap = spec.system_sim(n_channels=EQUAL_PIN[policy]).amap
    eff = calibrate(cfg)
    params = queue_window_params(policy)
    assert len(samples) >= 32, len(samples)

    # Correctness first: the loop reference and the batched census price
    # identical features (bit-exact — same integer censuses, same IEEE
    # roofline op order) on a prefix of the sample.
    pricer = StepPricer(cfg, amap, params, eff=eff, recheck_every=0)
    batched = pricer.features_many(samples)
    for s, f in list(zip(samples, batched))[:8]:
        ref = _unbatched_features(s, cfg, amap, eff)
        for key in ("base_ns", "span_ns", "txns_gating",
                    "fine_txns_gating", "ext_gating", "total_txns"):
            assert ref[key] == f[key], (key, ref[key], f[key])
        assert np.array_equal(ref["mc_channel_bytes"],
                              f["mc_channel_bytes"])

    # Reference: one unbatched call per step (pre-batching code path).
    t0 = time.perf_counter()
    for s in samples:
        _unbatched_features(s, cfg, amap, eff)
    t_unbatched = time.perf_counter() - t0

    # Intermediate: the vectorized census, still one call per step and
    # no cache — isolates the census rewrite from the memo cache.
    from repro.core.queue_model import _features_batch
    t0 = time.perf_counter()
    for s in samples:
        s.memo.clear()
        _features_batch([s], cfg, amap, eff)
    t_per_step = time.perf_counter() - t0

    # Production path: fresh pricer, fleet-round-sized batches, memo
    # cache warm across rounds exactly as in ClusterSim.run.
    for s in samples:
        s.memo.clear()
    pricer = StepPricer(cfg, amap, params, eff=eff, recheck_every=0)
    round_size = 32
    t0 = time.perf_counter()
    for i in range(0, len(samples), round_size):
        pricer.features_many(samples[i:i + round_size])
    t_batched = max(time.perf_counter() - t0, 1e-9)

    speedup = t_unbatched / t_batched
    assert speedup >= SPEEDUP_FLOOR, (
        f"batched+memoized pricing only {speedup:.1f}x faster than the "
        f"per-step unbatched path (floor {SPEEDUP_FLOOR}x): "
        f"{t_unbatched:.4f}s vs {t_batched:.4f}s over {len(samples)} steps")
    return {
        "policy": policy,
        "n_steps": len(samples),
        "unbatched": {"wall_s": round(t_unbatched, 4)},
        "per_step_vectorized": {"wall_s": round(t_per_step, 4)},
        "batched_memoized": {"wall_s": round(t_batched, 5)},
        "speedup_vs_unbatched": round(speedup, 1),
        "speedup_vs_per_step": round(t_per_step / t_batched, 1),
        "cache": pricer.stats,
    }


# ---------------------------------------------------------------------------
# Scale: the million-request fleet cell
# ---------------------------------------------------------------------------

def _mega_cell() -> dict:
    n_requests = 1_000_000
    t0 = time.perf_counter()
    cs = ClusterSim(**dict(CELL, policy="rome_qd2",
                           n_channels=EQUAL_PIN["rome_qd2"],
                           router="least_kv", kind="bursty", burst_size=8,
                           rate_rps=5e6, n_requests=n_requests,
                           n_replicas=8))
    t_build = time.perf_counter() - t0
    t0 = time.perf_counter()
    r = cs.run()
    wall = time.perf_counter() - t0
    assert r.completed == n_requests, (r.completed, n_requests)
    assert int(r.requests_per_replica.sum()) == n_requests
    s = r.summary()
    s.update({
        "build_s": round(t_build, 1),
        "wall_s": round(wall, 1),
        "requests_per_wall_s": round(n_requests / wall, 0),
        "steps_per_wall_s": round(r.steps_total / wall, 0),
        "pricer": r.pricer_stats,
    })
    return s


def run(reduced: bool = False) -> dict:
    out: dict = {"config": {
        "reduced": reduced,
        "equal_pin_channels": dict(EQUAL_PIN),
        "routers": list(ROUTERS),
        "speedup_floor": SPEEDUP_FLOOR,
        **{k: v for k, v in CELL.items() if k != "workload"},
    }}
    curves, samples = _curves(reduced)
    out["curves"] = curves
    out["rome_over_hbm4_goodput"] = _compounding(curves)
    out["speedup"] = _speedup(samples, curves["_samples_policy"])
    if not reduced:
        out["mega"] = _mega_cell()
    return out


if __name__ == "__main__":
    import argparse
    import json
    import traceback
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--reduced", action="store_true",
                   help="PR-CI size: smaller grid, no 1M-request cell")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write a benchmarks.run-shaped payload to PATH "
                        "(gateable by scripts/bench_compare.py)")
    args = p.parse_args()
    name = "cluster_sweep_reduced" if args.reduced else "cluster_sweep"
    t0 = time.time()
    try:
        results = run(reduced=args.reduced)
        status = "PASS"
    except AssertionError as e:
        results = {"error": str(e)}
        status = "FAIL"
    except Exception:
        results = {"error": traceback.format_exc()[-800:]}
        status = "ERROR"
    wall = round(time.time() - t0, 2)
    print(json.dumps(results, indent=1, default=str))
    print(f"[{status}] {name} ({wall:.1f}s)", flush=True)
    if args.json:
        payload = {"status": "pass" if status == "PASS" else "fail",
                   "benchmarks": {name: {"status": status, "wall_s": wall,
                                         "results": results}},
                   "total_wall_s": wall,
                   "failures": int(status != "PASS"),
                   "completed": True}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print(f"wrote {args.json}")
    raise SystemExit(0 if status == "PASS" else 1)
