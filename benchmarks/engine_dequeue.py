"""Engine wall-clock guard for the O(1) dequeue (`_PendingQueue`).

The channel sims originally dequeued with ``list.remove`` — O(n)
worst-case per transaction and equality-based (wrong-object removal for
field-identical transactions). The identity-based tombstone queue must
keep simulator wall-clock no worse than the seed implementation.

The asserted guard is a throughput floor (txns simulated per second)
set ~4x below seed-measured throughput on the reference container
(2026-08, CPython 3.10: stream 12k, interleaved 10k, rome 140k txns/s),
so it trips on an engine regression but tolerates slower CI machines.
Seed wall-clock is reported alongside for eyeballing.
"""
from __future__ import annotations

import time

from repro.core import engine as eng

# label -> (txns, seed-measured seconds, min txns/s floor)
GUARDS = {
    "hbm4_stream": (1 << 14, 1.35, 3_000),
    "hbm4_interleaved": (1 << 14, 1.59, 2_500),
    "rome_stream": ((1 << 24) // 4096, 0.03, 35_000),
}


def run() -> dict:
    t0 = time.perf_counter()
    h = eng.HBM4ChannelSim(refresh=False)
    rh = h.run(eng.sequential_read_txns_hbm4(1 << 19))
    t1 = time.perf_counter()
    m = eng.HBM4ChannelSim(refresh=False)
    rm = m.run(eng.interleaved_stream_txns_hbm4(32, 1 << 14))
    t2 = time.perf_counter()
    r = eng.RoMeChannelSim(refresh=False)
    rr = r.run(eng.sequential_read_txns_rome(1 << 24))
    t3 = time.perf_counter()

    out = {
        "hbm4_stream_s": round(t1 - t0, 3),
        "hbm4_interleaved_s": round(t2 - t1, 3),
        "rome_stream_s": round(t3 - t2, 3),
        "hbm4_stream_bw": round(rh.bandwidth_gbps, 3),
        "hbm4_interleaved_acts": rm.cmd_counts["ACT"],
        "rome_stream_bw": round(rr.bandwidth_gbps, 3),
    }
    for key, (txns, seed_s, floor) in GUARDS.items():
        rate = txns / max(out[key + "_s"], 1e-9)
        out[key + "_txns"] = txns
        out[key + "_txns_per_s"] = round(rate)
        out[key + "_seed_s"] = seed_s
        assert rate >= floor, (
            f"{key}: {rate:.0f} txns/s below floor {floor} "
            f"(seed container: {txns / seed_s:.0f}) — engine dequeue "
            f"regressed")
    # Cross-check the dequeue change kept the *behavior* of the seed
    # engine: these are the seed-measured invariants on the same traces.
    assert abs(out["hbm4_stream_bw"] - 63.743) < 0.5
    assert abs(out["rome_stream_bw"] - 63.992) < 0.5
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
