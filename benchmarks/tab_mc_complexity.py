"""Table IV: MC complexity — timing params, bank FSMs, bank states, page
policy, scheduling — plus the §VI-C area ratio (RoMe scheduler = 9.1 % of
conventional).

Two independent sources must agree: the architectural census in
``repro.core.mc`` (prose facts) and the *introspected* state footprint of
the scheduler policies that actually run in the engine
(``SchedulerPolicy.state_footprint()``). Since the design-space sweep
the census also extends over *every* registered policy
(``mc.registry_census``): conventional-MC variants must declare the
extra hardware they add (``aux_state``), and no RoMe variant may grow
the 10-param / 5-FSM / 4-state row.
"""
from __future__ import annotations

import dataclasses

from repro.core import (FRFCFSOpenPagePolicy, RoMeRowPolicy,
                        complexity_of_policy, conventional_mc_complexity,
                        max_concurrent_refreshing, registry_census,
                        rome_mc_complexity)
from repro.core.area import (command_generator_overhead_frac,
                             conventional_mc_area, mc_area_ratio,
                             rome_mc_area)
from repro.core.sched import registered_policies


def run() -> dict:
    h = conventional_mc_complexity()
    r = rome_mc_complexity()
    assert h.n_timing_params == 15 and r.n_timing_params == 10
    assert h.n_bank_states == 7 and r.n_bank_states == 4
    assert r.n_bank_fsms == 5
    # The running schedulers must report the same census they are claimed
    # to have (one engine, N policies — the contrast is structural).
    hp = complexity_of_policy(FRFCFSOpenPagePolicy(), h.request_queue_depth)
    rp = complexity_of_policy(RoMeRowPolicy(), r.request_queue_depth)
    for census, pol in ((h, hp), (r, rp)):
        assert (census.n_timing_params, census.n_bank_fsms,
                census.n_bank_states, census.page_policy,
                census.scheduling) == \
               (pol.n_timing_params, pol.n_bank_fsms,
                pol.n_bank_states, pol.page_policy, pol.scheduling)
    # 2 active + up to 3 refreshing concurrently = 5 FSMs (§V-A)
    assert 2 + max_concurrent_refreshing() == r.n_bank_fsms
    # Extended census over the whole registered design space: every
    # conventional variant keeps the 15/64/7 row (plus declared
    # aux_state for its extra machinery); every RoMe variant keeps
    # 10/5/4 with *no* extra hardware — the §V-A claim that queue depth
    # and refresh priority are knobs, not state.
    extended = registry_census()
    for name, spec in registered_policies().items():
        c = extended[name]
        row = (c.n_timing_params, c.n_bank_fsms, c.n_bank_states)
        if spec.family == "hbm4":
            assert row == (15, 64, 7), (name, row)
        else:
            assert row == (10, 5, 4), (name, row)
            assert c.aux_state == (), (name, c.aux_state)
    assert extended["hbm4_writedrain"].aux_state
    assert extended["hbm4_sidgroup"].aux_state
    ratio = mc_area_ratio()
    return {
        "extended_census": {n: dataclasses.asdict(c)
                            for n, c in extended.items()},
        "hbm4": {"timing_params": h.n_timing_params,
                 "bank_fsms": h.n_bank_fsms,
                 "bank_states": h.n_bank_states,
                 "page_policy": h.page_policy,
                 "queue_depth": h.request_queue_depth,
                 "scheduling": list(h.scheduling),
                 "sched_area_um2": conventional_mc_area().total_um2},
        "rome": {"timing_params": r.n_timing_params,
                 "bank_fsms": r.n_bank_fsms,
                 "bank_states": r.n_bank_states,
                 "page_policy": r.page_policy,
                 "queue_depth": r.request_queue_depth,
                 "scheduling": list(r.scheduling),
                 "sched_area_um2": rome_mc_area().total_um2},
        "area_ratio": f"{ratio:.1%} (paper: 9.1%)",
        "cmdgen_die_frac": f"{command_generator_overhead_frac():.4%} "
                           f"(paper: 0.003%)",
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
