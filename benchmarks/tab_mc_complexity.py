"""Table IV: MC complexity — timing params, bank FSMs, bank states, page
policy, scheduling — plus the §VI-C area ratio (RoMe scheduler = 9.1 % of
conventional).

Two independent sources must agree: the architectural census in
``repro.core.mc`` (prose facts) and the *introspected* state footprint of
the scheduler policies that actually run in the engine
(``SchedulerPolicy.state_footprint()``).
"""
from __future__ import annotations

from repro.core import (FRFCFSOpenPagePolicy, RoMeRowPolicy,
                        complexity_of_policy, conventional_mc_complexity,
                        max_concurrent_refreshing, rome_mc_complexity)
from repro.core.area import (command_generator_overhead_frac,
                             conventional_mc_area, mc_area_ratio,
                             rome_mc_area)


def run() -> dict:
    h = conventional_mc_complexity()
    r = rome_mc_complexity()
    assert h.n_timing_params == 15 and r.n_timing_params == 10
    assert h.n_bank_states == 7 and r.n_bank_states == 4
    assert r.n_bank_fsms == 5
    # The running schedulers must report the same census they are claimed
    # to have (one engine, N policies — the contrast is structural).
    hp = complexity_of_policy(FRFCFSOpenPagePolicy(), h.request_queue_depth)
    rp = complexity_of_policy(RoMeRowPolicy(), r.request_queue_depth)
    for census, pol in ((h, hp), (r, rp)):
        assert (census.n_timing_params, census.n_bank_fsms,
                census.n_bank_states, census.page_policy,
                census.scheduling) == \
               (pol.n_timing_params, pol.n_bank_fsms,
                pol.n_bank_states, pol.page_policy, pol.scheduling)
    # 2 active + up to 3 refreshing concurrently = 5 FSMs (§V-A)
    assert 2 + max_concurrent_refreshing() == r.n_bank_fsms
    ratio = mc_area_ratio()
    return {
        "hbm4": {"timing_params": h.n_timing_params,
                 "bank_fsms": h.n_bank_fsms,
                 "bank_states": h.n_bank_states,
                 "page_policy": h.page_policy,
                 "queue_depth": h.request_queue_depth,
                 "scheduling": list(h.scheduling),
                 "sched_area_um2": conventional_mc_area().total_um2},
        "rome": {"timing_params": r.n_timing_params,
                 "bank_fsms": r.n_bank_fsms,
                 "bank_states": r.n_bank_states,
                 "page_policy": r.page_policy,
                 "queue_depth": r.request_queue_depth,
                 "scheduling": list(r.scheduling),
                 "sched_area_um2": rome_mc_area().total_um2},
        "area_ratio": f"{ratio:.1%} (paper: 9.1%)",
        "cmdgen_die_frac": f"{command_generator_overhead_frac():.4%} "
                           f"(paper: 0.003%)",
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
