"""Hybrid-path cross-validation: queue-window analytic band + vectorized
bit-identity + the wall-clock speedup that makes unscaled replay runnable.

Three guarantees, each load-bearing for the hybrid fast path
(``SystemSim(mode="hybrid")``, ROADMAP item):

* **band** — for every registered policy, every step the hybrid
  classifier prices *analytically* must land within the declared 15 %
  band of the cycle engine's makespan. Checked on the calibration
  stressor suite (``repro.core.queue_model.stressor_streams``) AND on
  seeded holdout streams the fit never saw. Steps the classifier routes
  to the cycle engine are exact by construction (same engine) — the
  benchmark records them at ``rel == 0`` as a structural check.
* **bit-identity** — the vectorized lockstep driver
  (``core.sched.vectorized.run_channels``) must reproduce the scalar
  event loop exactly (``finish_ns`` arrays equal, command censuses
  equal) on the 20-trace facade suite.
* **speedup** — pricing an uncontended bulk step analytically must beat
  the cycle engine by a wide margin (the property that turns tens-of-GB
  unscaled decode steps from ~hours into ~microseconds). Wall times are
  machine-dependent; the baseline gates the speedup only with a very
  loose band (sanity floor, not a perf SLO).

``--reduced`` shrinks the policy set and holdout count for PR-CI smoke;
the standalone ``--json`` payload mimics ``benchmarks.run --json`` (one
benchmark entry named ``hybrid_xval_reduced``) so the same
``scripts/bench_compare.py`` gate applies to both sizes.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.queue_model import (DEFAULT_PRESSURE_THRESHOLD,
                                    queue_window_params, stressor_streams)
from repro.core.sched import facade_trace_suite, run_channels
from repro.core.sched.channels import make_channel_sim
from repro.core.sched.registry import policy_names, policy_spec
from repro.core.timing import hbm4_config, rome_config
from repro.workloads import (bulk_stream, interleave, sparse_stream,
                             strided_stream)

#: The declared hybrid accuracy band — the same 15 % the established
#: engine_xval analytic/cycle cross-validation uses.
BAND = 0.15

REDUCED_POLICIES = ("hbm4_frfcfs", "rome_qd2")
N_CHANNELS = 2
SPEEDUP_POLICY = "hbm4_frfcfs"


def _holdout_streams(cfg, n: int, seed: int = 7):
    """Seeded mixed streams the calibration never saw: random sizes and
    compositions drawn from the same regime *families* the model claims
    (bulk weight slices, sub-row KV records, sparse sub-row gathers,
    write tails — the decode-step shape) at parameters off the stressor
    grid. Patterns outside the claimed regimes (e.g. random full-row
    gathers) are the cycle engine's job, via the pressure classifier."""
    rng = np.random.default_rng(seed)
    row = cfg.row_bytes
    fine = max(64, row // int(rng.integers(4, 16)))
    out = []
    for i in range(n):
        if i % 2 == 0:
            # Uncontended decode-step shape (bulk weight slice +
            # row-scale tenant strides + small write tail): should
            # classify analytic and land inside the band.
            parts = [
                bulk_stream(int(rng.integers(24, 96)) * row,
                            n_extents=int(rng.integers(1, 5))),
                strided_stream(int(rng.integers(8, 20)), 2 * row,
                               int(rng.integers(3, 6)) * row,
                               base_addr=1 << 21).retagged(1),
                bulk_stream(int(rng.integers(2, 8)) * row, kind="write",
                            base_addr=1 << 24).retagged(3),
            ]
        else:
            # Fine sub-row mix: high thrash pressure — the classifier
            # should route it to the cycle engine (exact).
            parts = [
                bulk_stream(int(rng.integers(24, 96)) * row,
                            n_extents=int(rng.integers(1, 5))),
                strided_stream(int(rng.integers(8, 24)), fine,
                               int(rng.integers(3, 6)) * row,
                               base_addr=1 << 21).retagged(1),
                sparse_stream(int(rng.integers(16, 48)), fine,
                              1 << 22, seed=int(rng.integers(1 << 20)),
                              stream_id=2),
            ]
        out.append((f"holdout_{i}", interleave(parts)))
    return out


#: Policies that MUST get analytic coverage on the stressor suite — the
#: serve-replay flagships whose unscaled path depends on it. Others may
#: legitimately classify everything as contended (e.g. ``hbm4_closed``
#: runs at the tRC random-row rate, far off the roofline, so its hybrid
#: degenerates to pure cycle — safe, just never fast).
ANALYTIC_REQUIRED = ("hbm4_frfcfs", "rome_qd2")


def _band_cell(spec, streams):
    """Hybrid vs cycle across labeled streams on one policy: per-stream
    {pressure, mode, rel}; asserts the band on analytically-priced steps
    and exactness on cycle-routed ones."""
    cfg = hbm4_config() if spec.family == "hbm4" else rome_config()
    cyc = spec.system_sim(n_channels=N_CHANNELS, mode="cycle")
    hyb = spec.system_sim(n_channels=N_CHANNELS, mode="hybrid")
    rows, worst, n_analytic = {}, 0.0, 0
    for label, stream in streams:
        ref = cyc.run(stream)
        res = hyb.run(stream)
        rel = abs(res.total_ns - ref.total_ns) / ref.total_ns
        rows[label] = {"mode": res.mode,
                       "pressure": round(res.queue_pressure, 4),
                       "rel_err": round(rel, 4)}
        if res.mode == "analytic":
            n_analytic += 1
            worst = max(worst, rel)
            assert rel < BAND, (spec.name, label, ref.total_ns,
                                res.total_ns, rel)
        else:
            # Cycle-routed steps reuse the exact engine: any drift here
            # means the hybrid dispatch changed the simulation itself.
            assert rel == 0.0, (spec.name, label, rel)
    if spec.name in ANALYTIC_REQUIRED:
        assert n_analytic > 0, (spec.name, "classifier sent every "
                                "stressor to the cycle engine")
    return rows, {
        "n_streams": len(rows),
        "n_analytic": n_analytic,
        "analytic_fraction": round(n_analytic / len(rows), 4),
        "worst_analytic_rel": round(worst, 4),
        "fit_resid_rel_max": round(
            queue_window_params(spec.name).resid_rel_max, 4),
    }


def _bit_identity() -> dict:
    """Scalar vs vectorized on the facade suite — grouped by simulator
    configuration so the lockstep driver advances several live channels
    together (the production shape), then compared trace by trace. Both
    paths run with command-trace emission on, so identity is asserted on
    the *full command stream* (every ACT/RD/WR/PRE/REF with its bank,
    SID and timestamp), not just finish times and command counts."""
    suite = facade_trace_suite()
    groups: dict = {}
    for label, kind, kwargs, txns in suite:
        kwargs = dict(kwargs, emit_trace=True)
        groups.setdefault((kind, tuple(sorted(kwargs.items()))),
                          []).append((label, kwargs, txns))
    t0 = time.perf_counter()
    scalar = {label: make_channel_sim(kind, **kwargs).run(txns)
              for (kind, _), members in groups.items()
              for label, kwargs, txns in members}
    t_scalar = time.perf_counter() - t0
    t0 = time.perf_counter()
    vec = {}
    n_commands = 0
    for (kind, _), members in groups.items():
        results = run_channels(kind, members[0][1],
                               [txns for _, _, txns in members])
        vec.update({label: r for (label, _, _), r
                    in zip(members, results)})
    t_vec = time.perf_counter() - t0
    for label, s in scalar.items():
        v = vec[label]
        assert np.array_equal(s.finish_ns, v.finish_ns), label
        assert s.total_ns == v.total_ns, label
        assert s.bytes_moved == v.bytes_moved, label
        assert s.cmd_counts == v.cmd_counts, (label, s.cmd_counts,
                                              v.cmd_counts)
        assert s.trace == v.trace, (label, len(s.trace), len(v.trace))
        n_commands += len(s.trace)
    return {"n_traces": len(scalar), "n_groups": len(groups),
            "n_commands": n_commands,
            "scalar": {"wall_s": round(t_scalar, 3)},
            "vectorized": {"wall_s": round(t_vec, 3)}}


def _speedup(reduced: bool) -> dict:
    """Analytic pricing vs cycle simulation of one uncontended bulk
    step: the wall-clock ratio that makes the unscaled replay path
    feasible. Both paths are warmed first (calibration caches)."""
    nbytes = 1 << 20 if reduced else 4 << 20
    spec = policy_spec(SPEEDUP_POLICY)
    stream = bulk_stream(nbytes)
    cyc = spec.system_sim(n_channels=N_CHANNELS, mode="cycle")
    hyb = spec.system_sim(n_channels=N_CHANNELS, mode="hybrid")
    ref = cyc.run(stream)                    # warm + reference makespan
    res = hyb.run(stream)                    # warm (lazy calibration)
    assert res.mode == "analytic", (res.mode, res.queue_pressure)
    t0 = time.perf_counter()
    ref = cyc.run(stream)
    t_cycle = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = hyb.run(stream)
    t_hybrid = max(time.perf_counter() - t0, 1e-9)
    rel = abs(res.total_ns - ref.total_ns) / ref.total_ns
    assert rel < BAND, (ref.total_ns, res.total_ns, rel)
    speedup = t_cycle / t_hybrid
    # The point of the hybrid path: orders of magnitude, not percent.
    assert speedup > 10, (t_cycle, t_hybrid)
    return {"policy": SPEEDUP_POLICY, "stream_mb": nbytes / 2 ** 20,
            "cycle": {"wall_s": round(t_cycle, 4)},
            "analytic": {"wall_s": round(t_hybrid, 6)},
            "speedup": round(speedup, 1),
            "rel_err": round(rel, 4),
            "makespan_ns": round(ref.total_ns, 1)}


def run(reduced: bool = False) -> dict:
    policies = REDUCED_POLICIES if reduced else policy_names()
    n_holdout = 2 if reduced else 6
    out: dict = {"config": {
        "reduced": reduced,
        "policies": list(policies),
        "band": BAND,
        "pressure_threshold": DEFAULT_PRESSURE_THRESHOLD,
        "n_channels": N_CHANNELS,
    }}

    band = {}
    for name in policies:
        spec = policy_spec(name)
        cfg = hbm4_config() if spec.family == "hbm4" else rome_config()
        streams = (stressor_streams(cfg)
                   + _holdout_streams(cfg, n_holdout))
        rows, summary = _band_cell(spec, streams)
        band[name] = {**summary, "streams": rows}
    out["band"] = band

    out["bit_identity"] = _bit_identity()
    out["speedup"] = _speedup(reduced)
    return out


if __name__ == "__main__":
    import argparse
    import json
    import traceback
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--reduced", action="store_true",
                   help="PR-CI size: 2 policies, fewer holdouts")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write a benchmarks.run-shaped payload to PATH "
                        "(gateable by scripts/bench_compare.py)")
    args = p.parse_args()
    name = "hybrid_xval_reduced" if args.reduced else "hybrid_xval"
    t0 = time.time()
    try:
        results = run(reduced=args.reduced)
        status = "PASS"
    except AssertionError as e:
        results = {"error": str(e)}
        status = "FAIL"
    except Exception:
        results = {"error": traceback.format_exc()[-800:]}
        status = "ERROR"
    wall = round(time.time() - t0, 2)
    print(json.dumps(results, indent=1, default=str))
    print(f"[{status}] {name} ({wall:.1f}s)", flush=True)
    if args.json:
        payload = {"status": "pass" if status == "PASS" else "fail",
                   "benchmarks": {name: {"status": status, "wall_s": wall,
                                         "results": results}},
                   "total_wall_s": wall,
                   "failures": int(status != "PASS"),
                   "completed": True}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print(f"wrote {args.json}")
    raise SystemExit(0 if status == "PASS" else 1)
