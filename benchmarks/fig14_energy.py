"""Fig 14: DRAM energy, HBM4 vs RoMe, batch 256 seq 8K.

Paper: RoMe total energy -1.9 / -0.7 / -0.7 % for DeepSeek / Grok / Llama;
ACT energy reduced to 55.5 / 86.0 / 84.4 % of baseline (stream-interleave
row conflicts inflate the baseline's ACT count; RoMe's is structural);
command-generator energy ~0.06 % of total.
"""
from __future__ import annotations

from repro.configs.paper_workloads import PAPER_WORKLOADS
from repro.perfmodel.energy_model import decode_energy

PAPER_ACT_RATIO = {"deepseek-v3": 0.555, "grok-1": 0.860,
                   "llama-3-405b": 0.844}


def run() -> dict:
    out = {}
    for name, w in PAPER_WORKLOADS.items():
        e = decode_energy(w, batch=256)
        total_ratio = e["total_ratio"]
        act_ratio = e["act_ratio"]
        cmdgen_frac = e["rome"].cmdgen_pj / e["rome"].total_pj
        # Bands: total saving 0-6 %, ACT ratio within 0.25 of paper,
        # command generator negligible.
        assert 0.90 <= total_ratio <= 1.0, (name, total_ratio)
        assert abs(act_ratio - PAPER_ACT_RATIO[name]) < 0.25, \
            (name, act_ratio)
        assert cmdgen_frac < 0.005, cmdgen_frac
        out[name] = {
            "hbm4_breakdown_pj": e["hbm4"].as_dict(),
            "rome_breakdown_pj": e["rome"].as_dict(),
            "total_ratio": round(total_ratio, 4),
            "paper_total_ratio": {"deepseek-v3": 0.981, "grok-1": 0.993,
                                  "llama-3-405b": 0.993}[name],
            "act_ratio": round(act_ratio, 3),
            "paper_act_ratio": PAPER_ACT_RATIO[name],
            "cmdgen_frac": f"{cmdgen_frac:.4%} (paper: ~0.06%)",
            "overfetch_frac": round(e["overfetch_frac"], 4),
        }
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=float))
