"""Fig 9: the command generator's static RD_row / WR_row expansion.

Asserts the structural properties the paper specifies:
  * one ACT per bank, staggered by tRRDS, with the (tRRDS - tCCDS)
    intentional lead delay before bank 0's ACT,
  * 2 x 32 perfectly interleaved RD/WR bursts at tCCDS spacing,
  * PRE per bank after tRTP (read) / tWR (write-recovery),
  * derived same-VBA row-to-row delays consistent with Table V
    (tRD_row = 95 ns, tWR_row = 115 ns) and the data-bus occupancy
    matching tR2RS = 64 ns.
"""
from __future__ import annotations

from repro.core import CommandGenerator, HBM4Timing, RoMeRowPolicy, RoMeTiming


def run() -> dict:
    cg = CommandGenerator()
    t = HBM4Timing()
    rd = cg.expand(is_write=False)
    wr = cg.expand(is_write=True)

    acts = [c for c in rd.commands if c.op == "ACT"]
    bursts = [c for c in rd.commands if c.op == "RD"]
    pres = [c for c in rd.commands if c.op == "PRE"]
    assert len(acts) == 2 and len(pres) == 2 and len(bursts) == 64
    assert abs((acts[1].t_ns - acts[0].t_ns) - t.tRRDS) < 1e-9
    assert abs(acts[0].t_ns - (t.tRRDS - t.tCCDS)) < 1e-9
    gaps = [b2.t_ns - b1.t_ns for b1, b2 in zip(bursts, bursts[1:])]
    assert all(abs(g - t.tCCDS) < 1e-9 for g in gaps), "perfect interleave"
    banks = [b.bank for b in bursts]
    assert banks == [0, 1] * 32, "alternating banks at tCCDS"

    table_v = RoMeTiming()
    d_rd = cg.derived_tRD_row()
    d_wr = cg.derived_tWR_row()
    d_r2rs = cg.derived_tR2RS()

    # The schedules the running RoMe policy services transactions with
    # must be these same static expansions (the policy delegates all
    # intra-row sequencing to the command generator).
    pol = RoMeRowPolicy()
    assert pol._sched_rd.last_data_ns == rd.last_data_ns
    assert pol._sched_wr.last_data_ns == wr.last_data_ns
    assert pol._bursts == 2 * cg.bursts_per_bank() == 64

    return {
        "rd_schedule_first3": [repr(c) for c in rd.commands[:3]],
        "derived_tRD_row_ns": d_rd, "table_tRD_row_ns": table_v.tRD_row,
        "derived_tWR_row_ns": d_wr, "table_tWR_row_ns": table_v.tWR_row,
        "derived_tR2RS_ns": d_r2rs, "table_tR2RS_ns": table_v.tR2RS,
        "rd_data_bus_ns": rd.data_bus_ns,
        "wr_bank_ready_ns": wr.bank_ready_ns,
    }


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
