"""Fig 13: channel load-balance ratio (LBR) of RoMe vs batch size for the
attention and FFN layer groups, normalized to HBM4.

Paper shape claims reproduced here:
  * LBR_attn grows with batch for all three models (KV/activations grow),
  * DeepSeek's DP attention keeps LBR_attn comparatively high at small
    batch; Grok/Llama TP-shard the weights and start lower,
  * MoE LBR_FFN is low until enough experts activate (DeepSeek ~batch 64,
    Grok ~batch 8), Llama's dense FFN stays high throughout.
"""
from __future__ import annotations

from repro.configs.paper_workloads import PAPER_WORKLOADS
from repro.core.system_sim import SystemSim
from repro.core.timing import hbm4_config, rome_config
from repro.perfmodel.lbr import lbr_sweep
from repro.workloads import bulk_stream

BATCHES = (1, 4, 16, 64, 256)


def row_locality() -> dict:
    """Intra-channel companion to the cross-channel LBR: the row-hit
    rate of a small bulk decode slice on the cycle engine, read off
    :attr:`~repro.core.system_sim.SystemResult.row_hit_rate` (the one
    shared definition — repro.obs counter tracks, policy_sweep cells
    and this figure must all agree). HBM4's balance story leans on the
    row buffer absorbing column reuse; RoMe's is 0.0 by construction
    (row-granular access has no open-row state to hit)."""
    out = {}
    for fam, cfg in (("hbm4", hbm4_config()), ("rome", rome_config())):
        res = SystemSim(cfg, n_channels=2).run(bulk_stream(1 << 16))
        out[fam] = round(res.row_hit_rate, 4)
    assert out["hbm4"] > 0.8, out
    assert out["rome"] == 0.0, out
    return out


def run() -> dict:
    out = {name: lbr_sweep(w, BATCHES) for name, w in
           PAPER_WORKLOADS.items()}

    ds, gk, ll = (out["deepseek-v3"], out["grok-1"], out["llama-3-405b"])
    # Directional claims reproduced (the *absolute* dips in Fig 13 depend
    # on the paper's unpublished allocator/address internals; our
    # row-aligned bump allocator keeps extents better packed, so our LBRs
    # sit closer to 1 — see EXPERIMENTS.md): attention LBR grows with
    # batch; FFN LBR never degrades with batch; everything ends near 1 at
    # batch 256.
    for m in (ds, gk, ll):
        assert m[256]["attn"] >= m[1]["attn"] - 1e-6
        assert m[256]["ffn"] >= m[1]["ffn"] - 1e-6
        assert m[256]["attn"] > 0.95 and m[256]["ffn"] > 0.9

    # Write path (now that KV-append/activation writes carry real
    # row-aligned extents): including writes must not degrade the
    # batch-256 LBR — the bump allocator packs them as tightly as reads.
    rw = {name: lbr_sweep(w, (256,), include_writes=True)
          for name, w in PAPER_WORKLOADS.items()}
    for name, m in rw.items():
        assert m[256]["attn"] > 0.95 and m[256]["ffn"] > 0.9, (name, m)

    res = {k: {b: {kk: round(vv, 3) for kk, vv in v.items()}
               for b, v in sweep.items()}
           for k, sweep in out.items()}
    res["with_writes_b256"] = {k: {kk: round(vv, 3)
                                   for kk, vv in m[256].items()}
                               for k, m in rw.items()}
    res["row_hit_rate"] = row_locality()
    return res


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
