"""repro.obs: off-mode bit-identity, warm-feed highwater semantics,
probe/exporter reconciliation, and span coverage.

The telemetry stack's contract has three legs (docs/observability.md):
observation never changes a result (bit-identity), every derived series
reconciles exactly with the engine's own accounting (bytes, row hits),
and the exported Chrome trace is self-sufficient — the report tooling
recomputes the headline numbers from the JSON alone.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core.sched import (counts_row_hit_rate, make_channel_sim,
                              sequential_read_txns_hbm4,
                              sequential_read_txns_rome)
from repro.core.system_sim import SystemSim
from repro.core.timing import hbm4_config, rome_config
from repro.obs import (MetricsProbe, ObsCollector, chrome_trace_events,
                       counter_series, slices, trace_row_hit_rate,
                       trace_total_bytes, write_chrome_trace,
                       write_metrics_jsonl)
from repro.obs.metrics import COUNTER_REGISTRY, is_highwater
from repro.serve.cluster import ClusterSim
from repro.serve.replay import build_replay
from repro.workloads import bulk_stream

WINDOW = 500.0


def _drain(state):
    while not state.advance(4096):
        pass
    return state.result()


# ---------------------------------------------------------------------------
# off-mode bit-identity + row_hit_rate property
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,txns", [
    ("hbm4", sequential_read_txns_hbm4(1 << 14)),
    ("rome", sequential_read_txns_rome(1 << 19)),
])
def test_sampling_never_changes_results(kind, txns):
    off = make_channel_sim(kind).run(txns)
    on = make_channel_sim(kind, sample_window_ns=WINDOW).run(txns)
    assert np.array_equal(off.finish_ns, on.finish_ns)
    assert off.cmd_counts == on.cmd_counts
    assert off.samples is None and on.samples is not None
    # the property and the free function agree, and RoMe is 0.0 by
    # construction (row-granular: no open-row state to hit)
    assert off.row_hit_rate == counts_row_hit_rate(off.cmd_counts)
    if kind == "rome":
        assert off.row_hit_rate == 0.0
    else:
        assert off.row_hit_rate > 0.5


def test_system_result_row_hit_rate_property():
    stream = bulk_stream(1 << 15)
    hb = SystemSim(hbm4_config(), n_channels=2).run(stream)
    rm = SystemSim(rome_config(), n_channels=2).run(stream)
    assert hb.row_hit_rate == counts_row_hit_rate(hb.cmd_counts) > 0.8
    assert rm.row_hit_rate == 0.0
    assert "row_commands" in rm.cmd_counts  # what marks it row-granular


# ---------------------------------------------------------------------------
# warm feed() boundaries: highwater vs per-feed counters, sample slices
# ---------------------------------------------------------------------------

def test_ref_backlog_max_is_session_highwater_across_feeds():
    """Pinned by the ChannelRunState.result() docstring: with sampling
    attached, ``ref_backlog_max`` stays a session-cumulative high-water
    mark across feed() boundaries — never diffed per feed, never
    perturbed by the probe — while true counters are per-feed deltas."""
    assert is_highwater("ref_backlog_max")

    def session(window):
        kw = {"sample_window_ns": window} if window else {}
        st = make_channel_sim("hbm4", **kw).start_run(
            sequential_read_txns_hbm4(1 << 14))
        r1 = _drain(st)
        txns2 = sequential_read_txns_hbm4(1 << 12)
        # second batch arrives after an idle gap on the session clock
        for tx in txns2:
            tx.arrival_ns += st.now + 10_000.0
        st.feed(txns2)
        return r1, _drain(st), st

    (r1, r2, st) = session(WINDOW)
    (b1, b2, _) = session(None)

    # the probe changes nothing: same counts with and without sampling
    assert r1.cmd_counts == b1.cmd_counts
    assert r2.cmd_counts == b2.cmd_counts
    # the stream is long enough that refresh debt actually accumulated
    hw1 = r1.cmd_counts["ref_backlog_max"]
    hw2 = r2.cmd_counts["ref_backlog_max"]
    assert hw1 > 0
    # high-water semantics: the later feed reports the session maximum
    # (>= an earlier feed's), not a per-feed delta ...
    assert hw2 >= hw1
    # ... while true counters ARE per-feed deltas: batch 2 is a quarter
    # of batch 1, and its RD count must not include batch 1's.
    assert 0 < r2.cmd_counts["RD"] < r1.cmd_counts["RD"]
    # per-feed sample slices: each result's leading sample is its feed's
    # baseline marker (cumulative snapshot at the feed time)
    assert r1.samples[0][0] == 0.0
    assert r2.samples[0][0] > r1.samples[-1][0]
    assert r2.samples[0][4]["RD"] == b1.cmd_counts["RD"]
    # every minted counter key is registered with the probe
    assert set(r2.cmd_counts) <= set(COUNTER_REGISTRY)


# ---------------------------------------------------------------------------
# probe fold: exact reconciliation with the engine's own accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg_fn", [hbm4_config, rome_config])
def test_probe_windows_reconcile_bytes_and_hits(cfg_fn):
    probe = MetricsProbe(window_ns=200.0)
    sim = SystemSim(cfg_fn(), n_channels=2)
    sim.attach_probe(probe)
    res = sim.run(bulk_stream(1 << 15))
    t = probe.totals()
    assert t["window_bytes"] == res.bytes_moved == t["step_bytes"]
    assert probe.row_hit_rate() == res.row_hit_rate
    for c in probe.channels():
        windows = probe.channel_series(c)
        ts = [w.t1_ns for w in windows]
        assert ts == sorted(ts)
        assert all(0.0 <= w.utilization <= 1.0 for w in windows)


# ---------------------------------------------------------------------------
# exporter round-trip on a seeded serve replay
# ---------------------------------------------------------------------------

REPLAY_KW = dict(rate_rps=2e5, n_requests=3, seed=0, scale=2 ** -14,
                 length_scale=1 / 32, n_channels=2, sim_mode="cycle",
                 kind="bursty", burst_size=3)


def _replay(policy, collector=None):
    eng, _ = build_replay(policy=policy, collector=collector, **REPLAY_KW)
    return eng.run()


def test_replay_observation_is_invisible():
    bare = _replay("rome_qd2")
    col = ObsCollector(probe=MetricsProbe(window_ns=200.0))
    obs = _replay("rome_qd2", collector=col)
    assert bare.summary() == obs.summary()
    assert [s.dur_ns for s in bare.steps] == [s.dur_ns for s in obs.steps]


def test_chrome_trace_round_trip(tmp_path):
    col = ObsCollector(probe=MetricsProbe(window_ns=200.0))
    res = _replay("hbm4_frfcfs", collector=col)
    path = tmp_path / "t.trace.json"
    write_chrome_trace(path, col, col.probe, label="hbm4_frfcfs")
    trace = json.loads(path.read_text())
    assert trace["otherData"]["label"] == "hbm4_frfcfs"

    sl = slices(trace)
    reqs = [e for e in sl if e.get("cat") == "request"]
    # span tree covers every request ...
    assert len(reqs) == res.completed == len(col.request_spans())
    # ... and nests correctly: every non-request slice on a request's
    # thread lies inside that request's root span (exporter clamps to
    # the parent, so containment is exact in the emitted JSON)
    by_track = {}
    for e in sl:
        by_track.setdefault((e["pid"], e["tid"]), []).append(e)
    for root in reqs:
        track = by_track[(root["pid"], root["tid"])]
        t0, t1 = root["ts"], root["ts"] + root["dur"]
        for e in track:
            assert e["ts"] >= t0 - 1e-9
            assert e["ts"] + e["dur"] <= t1 + 1e-9
    # counter samples are monotone in ts per track
    series = counter_series(trace)
    assert series
    for name, pts in series.items():
        ts = [t for t, _ in pts]
        assert ts == sorted(ts), name
    # byte conservation: the counter-track integral equals the summed
    # step attribution exactly (no float drift — integers end to end)
    assert trace_total_bytes(trace) == res.summary()["bytes_moved"]
    assert trace_row_hit_rate(trace) > 0.5


def test_metrics_jsonl_round_trip(tmp_path):
    col = ObsCollector(probe=MetricsProbe(window_ns=200.0))
    res = _replay("rome_qd2", collector=col)
    path = tmp_path / "t.metrics.jsonl"
    write_metrics_jsonl(path, col.probe, col)
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    kinds = {ln["type"] for ln in lines}
    assert kinds == {"window", "step", "request"}
    assert sum(ln["type"] == "request" for ln in lines) == res.completed
    wb = sum(ln["bytes"] for ln in lines if ln["type"] == "window")
    assert wb == res.summary()["bytes_moved"]


def test_equal_pin_gap_reproducible_from_traces_alone(tmp_path):
    """The obs_report headline: the HBM4-vs-RoMe row-hit-rate gap must
    fall out of the two exported traces with no simulator state."""
    hits = {}
    for policy in ("hbm4_frfcfs", "rome_qd2"):
        col = ObsCollector(probe=MetricsProbe(window_ns=200.0))
        _replay(policy, collector=col)
        trace = {"traceEvents": chrome_trace_events(col, col.probe)}
        hits[policy] = trace_row_hit_rate(trace)
    assert hits["rome_qd2"] == 0.0
    assert hits["hbm4_frfcfs"] - hits["rome_qd2"] > 0.5


# ---------------------------------------------------------------------------
# fleet runs: per-replica folding
# ---------------------------------------------------------------------------

def test_cluster_per_replica_folding():
    kw = dict(policy="rome_qd2", n_replicas=2, n_requests=6, rate_rps=2e5,
              kind="poisson", seed=0, scale=2 ** -12, sim_mode="hybrid",
              n_channels=2, length_scale=1 / 32, router="round_robin")
    bare = ClusterSim(**kw).run()
    col = ObsCollector(probe=MetricsProbe(window_ns=200.0))
    obs = ClusterSim(**kw, collector=col).run()
    assert bare.summary() == obs.summary()
    # steps fold per replica, and both replicas actually stepped
    replicas = {ev.replica for ev in col.steps}
    assert replicas == {0, 1}
    spans = col.request_spans()
    assert len(spans) == obs.completed
    # each request span lives on its owning replica's track
    owner = {}
    for ev in col.steps:
        for rid in ev.participants:
            owner[rid] = ev.replica
    for sp in spans:
        assert sp.replica == owner[sp.args["rid"]]
