"""Data pipeline: determinism, restart safety, host sharding."""
import numpy as np

from repro.data.pipeline import make_pipeline


def test_deterministic_per_step():
    p1 = make_pipeline(1000, 16, 4, seed=3)
    p2 = make_pipeline(1000, 16, 4, seed=3)
    b1 = p1.batch_at(7)
    b2 = p2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_steps_differ():
    p = make_pipeline(1000, 16, 4)
    assert not np.array_equal(p.batch_at(0)["tokens"],
                              p.batch_at(1)["tokens"])


def test_labels_are_shifted_tokens():
    p = make_pipeline(1000, 16, 4)
    b = p.batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_host_sharding_partitions_batch():
    g = make_pipeline(1000, 8, 8, seed=1)
    h0 = make_pipeline(1000, 8, 8, seed=1, n_hosts=2, host_id=0)
    h1 = make_pipeline(1000, 8, 8, seed=1, n_hosts=2, host_id=1)
    assert h0.host_batch == 4 and h1.host_batch == 4
    b0, b1 = h0.batch_at(0), h1.batch_at(0)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_tokens_in_vocab():
    p = make_pipeline(512, 32, 4)
    b = p.batch_at(5)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 512
    assert b["tokens"].dtype == np.int32
