"""Warm cross-step sessions vs per-step reset (SystemSim.run_steps).

The contract docs/serve_replay.md states and chunked-prefill replays
rely on:

* **bit-identity on uncontended sequences** — steps whose queues drain
  and whose inter-step gaps let channel state quiesce must price
  identically under ``warm=True`` and ``warm=False``. Exactness needs a
  page policy with no cross-step row-buffer memory (closed-page HBM4,
  RoMe's row-granular policy) and refresh off; open-page HBM4
  legitimately differs (warm holds rows open across the gap, so a later
  step's row miss pays a precharge the reset run never sees).
* **warm never finishes earlier on contended sequences** — carried
  backlog, refresh debt, and open-row state can only add time.
* ``ChannelRunState.feed`` suspend/resume mechanics: refusing to feed
  an undrained queue, per-feed result deltas, cumulative clock.
* hybrid warm sessions: analytic steps agree with reset when the
  carried-pressure correction is zero, and the carry is never negative.
"""
import numpy as np
import pytest

from _proptest import given, settings, strategies as st
from repro.core.sched import advance_states, facade_trace_suite, \
    make_channel_sim
from repro.core.system_sim import WARM_CARRY_FRAC, SystemSim, WarmRunState
from repro.core.timing import hbm4_config, rome_config
from repro.workloads import ExtentRecord, ExtentStream, bulk_stream

N_CHANNELS = 2
GAP_NS = 50_000.0          # inter-step gap: far beyond any drain time


def _step_stream(step: int, nbytes: int, row: int, start: float,
                 with_write: bool = True) -> ExtentStream:
    """One step's traffic at absolute time ``start``, in an address
    window disjoint from every other step's (23-bit windows)."""
    base = (step + 1) << 23
    recs = [ExtentRecord(base, nbytes, "read", start)]
    if with_write:
        recs.append(ExtentRecord(base + (1 << 22), max(row, nbytes // 4),
                                 "write", start))
    return ExtentStream(recs)


def _uncontended_steps(cfg, n_steps: int, nbytes: int):
    rows = cfg.row_bytes
    starts = [i * GAP_NS for i in range(n_steps)]
    streams = [_step_stream(i, nbytes, rows, t)
               for i, t in enumerate(starts)]
    return streams, starts


# ---------------------------------------------------------------------------
# Bit-identity on uncontended sequences
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_cfg,kw", [
    (hbm4_config, {"page_policy": "closed"}),
    (rome_config, {}),
], ids=["hbm4_closed", "rome"])
@given(seed=st.integers(0, 10 ** 6))
@settings(max_examples=3, deadline=None)
def test_warm_bit_identical_to_reset_uncontended(make_cfg, kw, seed):
    """Disjoint addresses, refresh off, 50 us gaps: warm and reset must
    agree exactly — makespan, per-channel finish, bytes, and the full
    command census, step by step."""
    rng = np.random.default_rng(seed)
    cfg = make_cfg()
    n_steps = int(rng.integers(2, 5))
    nbytes = int(rng.integers(4, 24)) * cfg.row_bytes
    streams, starts = _uncontended_steps(cfg, n_steps, nbytes)
    sim = SystemSim(cfg, n_channels=N_CHANNELS, refresh=False, **kw)
    reset = sim.run_steps(streams, starts_ns=starts)
    warm = sim.run_steps(streams, starts_ns=starts, warm=True)
    for i, (r, w) in enumerate(zip(reset, warm)):
        assert w.total_ns == r.total_ns, (i, w.total_ns, r.total_ns)
        assert np.array_equal(w.channel_finish_ns, r.channel_finish_ns), i
        assert np.array_equal(w.channel_bytes, r.channel_bytes), i
        assert w.bytes_moved == r.bytes_moved, i
        assert w.cmd_counts == r.cmd_counts, i


def test_warm_open_page_row_state_carries():
    """Open-page HBM4 is the documented exception: warm carries open
    rows across the gap, so later steps can pay precharges reset never
    sees. Totals must still never be *smaller* warm."""
    cfg = hbm4_config()
    streams, starts = _uncontended_steps(cfg, 4, 16 * cfg.row_bytes)
    sim = SystemSim(cfg, n_channels=N_CHANNELS, refresh=False)
    reset = sim.run_steps(streams, starts_ns=starts)
    warm = sim.run_steps(streams, starts_ns=starts, warm=True)
    assert all(w.total_ns >= r.total_ns for r, w in zip(reset, warm))


# ---------------------------------------------------------------------------
# Contended sequences: warm can only lose
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_cfg", [hbm4_config, rome_config],
                         ids=["hbm4", "rome"])
def test_warm_never_earlier_when_contended(make_cfg):
    """Back-to-back steps (zero gap, refresh on): the carried backlog
    must surface as strictly later finishes on some later step, and no
    step may ever finish earlier warm than reset."""
    cfg = make_cfg()
    streams = [_step_stream(i, 32 * cfg.row_bytes, cfg.row_bytes, 0.0)
               for i in range(3)]
    starts = [0.0, 0.0, 0.0]
    sim = SystemSim(cfg, n_channels=N_CHANNELS)
    reset = sim.run_steps(streams, starts_ns=starts)
    warm = sim.run_steps(streams, starts_ns=starts, warm=True)
    assert all(w.total_ns >= r.total_ns for r, w in zip(reset, warm))
    assert warm[-1].total_ns > reset[-1].total_ns


def test_warm_steps_must_be_clock_ordered():
    cfg = hbm4_config()
    sim = SystemSim(cfg, n_channels=N_CHANNELS)
    streams = [_step_stream(i, 4 * cfg.row_bytes, cfg.row_bytes, 0.0)
               for i in range(2)]
    with pytest.raises(ValueError, match="clock"):
        sim.run_steps(streams, starts_ns=[GAP_NS, 0.0], warm=True)


def test_warm_session_sanitizer_runs():
    """check_timing=True replays the *cumulative* warm trace through the
    independent timing checker at session close — a clean sequence must
    pass, and the session must have actually simulated commands."""
    cfg = rome_config()
    streams, starts = _uncontended_steps(cfg, 3, 8 * cfg.row_bytes)
    sim = SystemSim(cfg, n_channels=N_CHANNELS, check_timing=True)
    out = sim.run_steps(streams, starts_ns=starts, warm=True)
    assert len(out) == 3 and all(r.total_ns > 0 for r in out)


# ---------------------------------------------------------------------------
# ChannelRunState.feed: suspend/resume mechanics
# ---------------------------------------------------------------------------

def _first_trace(kind_want: str):
    for label, kind, kwargs, txns in facade_trace_suite():
        if kind == kind_want and len(txns) >= 4:
            return label, kind, kwargs, txns
    raise AssertionError(f"no {kind_want} facade trace")


@pytest.mark.parametrize("kind", ["hbm4", "rome"])
def test_feed_refuses_undrained_queue(kind):
    _, _, kwargs, txns = _first_trace(kind)
    state = make_channel_sim(kind, **kwargs).start_run(txns)
    with pytest.raises(RuntimeError, match="undrained"):
        state.feed(txns)


@pytest.mark.parametrize("kind", ["hbm4", "rome"])
def test_feed_result_is_per_feed_delta(kind):
    """After a feed, result() reports only the new batch: its bytes and
    command deltas, on a clock that keeps running forward."""
    _, _, kwargs, txns = _first_trace(kind)
    state = make_channel_sim(kind, **kwargs).start_run(txns)
    advance_states([state])
    r1 = state.result()
    t1 = state.now
    state.feed(txns)
    advance_states([state])
    r2 = state.result()
    assert state.now > t1
    assert r2.bytes_moved == r1.bytes_moved        # same batch re-fed
    assert len(r2.finish_ns) == len(txns)
    # deltas, not cumulative: the re-fed batch issues exactly the same
    # number of data commands as the first one did (row-state-dependent
    # ACT/PRE may differ; the data census may not)
    for cmd in ("RD", "WR"):
        assert r2.cmd_counts.get(cmd, 0) == r1.cmd_counts.get(cmd, 0), cmd


# ---------------------------------------------------------------------------
# Hybrid warm sessions: carried-pressure correction
# ---------------------------------------------------------------------------

def _analytic_stream(cfg, step: int, start: float) -> ExtentStream:
    """A data-bound bulk slice big enough that the hybrid classifier
    prices it analytically (low modeled queue pressure)."""
    return bulk_stream(256 * cfg.row_bytes,
                       base_addr=(step + 1) << 24).shifted(start)


def test_hybrid_warm_matches_reset_when_uncontended():
    """All-analytic sequences carry zero pressure: warm == reset
    exactly, and every step stays on the analytic path."""
    cfg = hbm4_config()
    sim = SystemSim(cfg, n_channels=N_CHANNELS, mode="hybrid",
                    policy_name="hbm4_frfcfs")
    streams = [_analytic_stream(cfg, i, i * GAP_NS) for i in range(4)]
    starts = [i * GAP_NS for i in range(4)]
    reset = sim.run_steps(streams, starts_ns=starts)
    warm = sim.run_steps(streams, starts_ns=starts, warm=True)
    assert all(r.mode == "analytic" for r in reset)
    for i, (r, w) in enumerate(zip(reset, warm)):
        assert w.mode == "analytic", i
        assert w.total_ns == pytest.approx(r.total_ns), i


def test_hybrid_warm_carry_nonnegative_and_decaying():
    """The carried-pressure correction is never negative, inflates the
    analytic price when positive, and only a cycle-priced step resets
    it."""
    cfg = hbm4_config()
    sim = SystemSim(cfg, n_channels=N_CHANNELS, mode="hybrid",
                    policy_name="hbm4_frfcfs")
    sess = sim.warm_session()
    assert isinstance(sess, WarmRunState)
    assert sess.carry == 0.0
    last = 0.0
    for i in range(4):
        res = sess.step(_analytic_stream(cfg, i, i * GAP_NS),
                        start_ns=i * GAP_NS)
        assert sess.carry >= 0.0
        if res.mode == "analytic":
            # carry = frac * max(0, pressure_eff - threshold): bounded by
            # the step's own effective pressure
            assert sess.carry <= WARM_CARRY_FRAC * res.queue_pressure + 1e-12
        last = res.total_ns
    assert last > 0.0
    sess.check()
