"""MoE routing invariants + the gather/einsum equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _proptest import given, settings, strategies as st

from repro.configs.base import reduced
from repro.configs.registry_configs import ALL_ARCHS
from repro.models import moe as moe_lib

CFG = reduced(ALL_ARCHS["granite-moe-3b-a800m"])
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def params():
    return moe_lib.moe_params(KEY, CFG, jnp.float32)


def test_gather_equals_einsum(params):
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, CFG.d_model))
    y1 = moe_lib.moe_ffn(params, x, CFG, impl="einsum")
    y2 = moe_lib.moe_ffn(params, x, CFG, impl="gather")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(min_value=0, max_value=999))
def test_gather_equals_einsum_property(seed):
    params = moe_lib.moe_params(jax.random.PRNGKey(seed), CFG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 32, CFG.d_model))
    y1 = moe_lib.moe_ffn(params, x, CFG, impl="einsum")
    y2 = moe_lib.moe_ffn(params, x, CFG, impl="gather")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_output_is_convex_in_gates(params):
    """With capacity >= demand, output = weighted sum of expert outputs;
    scaling x scales y (experts are homogeneous-ish through silu*linear).
    Sanity: zero input -> zero output."""
    x = jnp.zeros((1, 8, CFG.d_model))
    y = moe_lib.moe_ffn(params, x, CFG)
    assert float(jnp.abs(y).max()) == 0.0


def test_capacity_drops_overflow(params):
    """With capacity_factor -> tiny, most tokens drop; output magnitude
    shrinks but stays finite (dropped tokens contribute zero)."""
    import dataclasses
    small = dataclasses.replace(
        CFG, moe=dataclasses.replace(CFG.moe, capacity_factor=0.05))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, CFG.d_model))
    y_small = moe_lib.moe_ffn(params, x, small)
    y_full = moe_lib.moe_ffn(params, x, CFG)
    assert bool(jnp.isfinite(y_small).all())
    assert float(jnp.abs(y_small).sum()) < float(jnp.abs(y_full).sum())


def test_pick_group_size_bounds_dispatch_overhead():
    from repro.models.moe import pick_group_size
    for arch in ("granite-moe-3b-a800m", "phi3.5-moe-42b-a6.6b"):
        cfg = ALL_ARCHS[arch]
        g = pick_group_size(cfg)
        m = cfg.moe
        ratio = m.capacity_factor * g / (3 * m.expert_d_ff)
        assert ratio <= 0.15, (arch, g, ratio)
        assert g >= 64
