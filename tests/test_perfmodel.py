"""Perf model: Fig 12/13/14 reproduction bands + analytic/engine x-val."""
import pytest

from repro.configs.paper_workloads import PAPER_WORKLOADS
from repro.core.analytic import calibrate, calibrate_hbm4, calibrate_rome
from repro.perfmodel.accelerator import paper_accelerator, tpu_v5e
from repro.perfmodel.lbr import lbr_by_kind
from repro.perfmodel.tpot import prefill_ns, tpot_ns


def test_accelerator_arithmetic_intensity():
    acc = paper_accelerator()
    assert acc.op_per_byte == pytest.approx(280.0, rel=0.10)
    assert acc.peak_bw_gbps == pytest.approx(16_384, rel=0.01)  # 16 TB/s


def test_channel_efficiencies():
    h = calibrate_hbm4()
    r = calibrate_rome()
    assert 0.90 < h.read_eff <= 1.0
    assert 0.95 < r.read_eff <= 1.0
    # RoMe ACT rate is the structural minimum (2 per 4 KB = 0.5/KB);
    # the baseline's is ~1/KB on a clean stream.
    assert r.act_per_kb == pytest.approx(0.5, rel=0.05)
    assert h.act_per_kb == pytest.approx(1.0, rel=0.10)


@pytest.mark.parametrize("name,paper_delta",
                         [("deepseek-v3", 0.104), ("grok-1", 0.102),
                          ("llama-3-405b", 0.090)])
def test_fig12_tpot_band(name, paper_delta):
    w = PAPER_WORKLOADS[name]
    th = tpot_ns(w, paper_accelerator("hbm4"), batch=256).total_ns
    tr = tpot_ns(w, paper_accelerator("rome"), batch=256).total_ns
    delta = 1 - tr / th
    assert abs(delta - paper_delta) < 0.03, (delta, paper_delta)


def test_prefill_insensitive():
    w = PAPER_WORKLOADS["grok-1"]
    ph = prefill_ns(w, paper_accelerator("hbm4"), batch=8).total_ns
    pr = prefill_ns(w, paper_accelerator("rome"), batch=8).total_ns
    assert abs(1 - pr / ph) < 0.001


def test_lbr_in_range():
    for w in PAPER_WORKLOADS.values():
        d = lbr_by_kind(w, batch=64)
        assert 0.5 < d["attn"] <= 1.001
        assert 0.5 < d["ffn"] <= 1.001


def test_tpu_target_spec():
    acc = tpu_v5e()
    assert acc.bf16_tflops == 197.0
