"""Multi-device behaviours (subprocess with forced device count — the
brief forbids setting XLA_FLAGS globally for tests)."""
import subprocess
import sys
import textwrap

import pytest


def _run(code: str, devices: int = 8) -> str:
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    import os
    env["HOME"] = os.environ.get("HOME", "/root")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env={**os.environ, **env})
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_cp_attention_matches_local():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh, set_mesh
        from repro.models.layers import cached_attention_update
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        b, hq, hkv, S, hd = 2, 8, 2, 32, 16
        q = jax.random.normal(ks[0], (b, hq, 1, hd))
        kn = jax.random.normal(ks[1], (b, hkv, 1, hd))
        vn = jax.random.normal(ks[2], (b, hkv, 1, hd))
        kc = jax.random.normal(ks[3], (b, hkv, S, hd))
        vc = jax.random.normal(ks[4], (b, hkv, S, hd))
        pos = jnp.array(20, jnp.int32)
        o_ref, kc_ref, vc_ref = cached_attention_update(
            q, kn, vn, kc, vc, pos, pos)
        mesh = make_mesh((2, 4), ('data', 'model'))
        with set_mesh(mesh):
            spec = NamedSharding(mesh, P('data', None, 'model', None))
            kc_s, vc_s = jax.device_put(kc, spec), jax.device_put(vc, spec)
            o, kc2, vc2 = jax.jit(cached_attention_update)(
                q, kn, vn, kc_s, vc_s, pos, pos)
        assert float(jnp.abs(o - o_ref).max()) < 1e-5
        assert float(jnp.abs(kc2 - kc_ref).max()) == 0.0
        print('CP-OK')
    """)
    assert "CP-OK" in out


def test_elastic_remesh_roundtrip():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.elastic import shrink_mesh, reshard, \\
            viable_meshes
        assert viable_meshes(8)[0] == (1, 8)
        tree = {'w': jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                'b': jnp.ones((8,))}
        specs = {'w': (None, 'model'), 'b': (None,)}
        m8 = shrink_mesh(8, model_divisibility=16)
        t8 = reshard(tree, specs, m8)
        # simulate losing half the devices
        m4 = shrink_mesh(4, model_divisibility=16)
        t4 = reshard(jax.tree.map(np.asarray, t8), specs, m4)
        np.testing.assert_array_equal(np.asarray(t4['w']),
                                      np.asarray(tree['w']))
        print('ELASTIC-OK', m8.devices.shape, m4.devices.shape)
    """)
    assert "ELASTIC-OK" in out


def test_spmd_train_step_runs_on_mesh():
    """Integration: a reduced arch takes a real optimizer step on a 4x2
    mesh with FSDP+TP shardings and finite loss."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.compat import make_mesh, set_mesh
        from repro.configs.base import reduced
        from repro.configs.registry_configs import ALL_ARCHS
        from repro.models.registry import get_adapter
        from repro.train.train_step import make_train_step, train_state_init
        cfg = reduced(ALL_ARCHS['qwen2-7b'])
        ad = get_adapter(cfg)
        mesh = make_mesh((4, 2), ('data', 'model'))
        with set_mesh(mesh):
            params = ad.init(jax.random.PRNGKey(0), tp=2)
            state = train_state_init(params)
            step = make_train_step(lambda p, b: ad.loss(p, b, remat=True),
                                   microbatches=2, lr=1e-3)
            batch = {'tokens': jnp.ones((8, 16), jnp.int32),
                     'labels': jnp.ones((8, 16), jnp.int32)}
            state, m = jax.jit(step, donate_argnums=(0,))(state, batch)
            l0 = float(m['loss'])
            state, m = jax.jit(step, donate_argnums=(0,))(state, batch)
        import math
        assert math.isfinite(l0) and math.isfinite(float(m['loss']))
        print('SPMD-TRAIN-OK', l0, float(m['loss']))
    """, devices=8)
    assert "SPMD-TRAIN-OK" in out
