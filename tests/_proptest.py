"""Minimal hypothesis-compatible property-testing shim.

The tier-1 suite must run in offline containers where `hypothesis` cannot
be installed. This module re-exports the real hypothesis when it is
importable and otherwise provides a small drop-in subset:

  * ``given(**strategies)`` / ``settings(max_examples=, deadline=)``
  * ``strategies.integers | floats | booleans | sampled_from | lists |
    tuples``

The shim draws examples from a PRNG seeded by the test's qualified name
(deterministic across runs), always tries the strategy-space boundary
points first (min/max for scalars, min/max size for lists), and reports
the falsifying example on failure. No shrinking.

Usage in tests:  ``from _proptest import given, settings, strategies as st``
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    """A draw function plus an optional list of boundary examples tried
    before any random draws."""

    def __init__(self, draw, boundaries=()):
        self._draw = draw
        self.boundaries = tuple(boundaries)

    def example(self, rng: random.Random, i: int):
        if i < len(self.boundaries):
            return self.boundaries[i]
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: int = -(1 << 16), max_value: int = 1 << 16):
        return _Strategy(lambda r: r.randint(min_value, max_value),
                         boundaries=(min_value, max_value))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0,
               allow_nan: bool = False, allow_infinity: bool = False):
        return _Strategy(lambda r: r.uniform(min_value, max_value),
                         boundaries=(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: bool(r.getrandbits(1)),
                         boundaries=(False, True))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        if not elements:
            raise ValueError("sampled_from requires a non-empty sequence")
        return _Strategy(lambda r: r.choice(elements),
                         boundaries=(elements[0], elements[-1]))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10):
        def draw(r):
            n = r.randint(min_size, max_size)
            return [elements.example(r, len(elements.boundaries) + k)
                    for k in range(n)]

        def sized(n):
            # boundary lists themselves use boundary elements where possible
            return lambda r: [elements.example(r, k) for k in range(n)]

        return _Strategy(draw, boundaries=()) if min_size == max_size == 0 \
            else _BoundaryCallable(draw, (sized(min_size), sized(max_size)))

    @staticmethod
    def tuples(*elements: _Strategy):
        def draw(r):
            return tuple(e.example(r, len(e.boundaries)) for e in elements)

        lo = tuple(e.boundaries[0] if e.boundaries else None
                   for e in elements)
        hi = tuple(e.boundaries[-1] if e.boundaries else None
                   for e in elements)
        if any(b is None for b in lo + hi):
            return _Strategy(draw)
        return _Strategy(draw, boundaries=(lo, hi))


class _BoundaryCallable(_Strategy):
    """Strategy whose boundary examples need the RNG (sized lists)."""

    def __init__(self, draw, boundary_fns):
        super().__init__(draw)
        self._boundary_fns = tuple(boundary_fns)

    def example(self, rng: random.Random, i: int):
        if i < len(self._boundary_fns):
            return self._boundary_fns[i](rng)
        return self._draw(rng)


def settings(*, max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator recording run settings on the given-wrapped test."""

    def deco(fn):
        fn._proptest_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(**strategy_kwargs):
    """Run the test once per drawn example. Strategy-provided parameters
    are removed from the wrapper's signature so pytest does not try to
    resolve them as fixtures."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_proptest_settings", None) or {}
            n = cfg.get("max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                drawn = {name: s.example(rng, i)
                         for name, s in strategy_kwargs.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"Falsifying example ({fn.__name__}, "
                        f"example {i + 1}/{n}): {drawn!r}") from e

        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategy_kwargs])
        return wrapper

    return deco


class _StrategiesModule(_Strategies):
    pass


strategies = _StrategiesModule()

try:                                        # defer to real hypothesis
    from hypothesis import given, settings, strategies  # noqa: F811,F401
except ImportError:
    pass
