"""Design-space policies: write-drain hysteresis & starvation bounds,
SID-group tCCDR regression, registry census, and the conservation
property every registered policy must satisfy."""
import numpy as np
import pytest

from _proptest import given, settings, strategies as st
from repro.core import sched
from repro.core.mc import complexity_of_policy, registry_census
from repro.core.sched import Txn
from repro.core.sched.core import ChannelSimCore
from repro.core.sched.policies import (FRFCFSOpenPagePolicy,
                                       FRFCFSWriteDrainPolicy)
from repro.core.system_sim import SystemSim
from repro.core.timing import hbm4_config
from repro.workloads import ExtentRecord, ExtentStream


# ---------------------------------------------------------------------------
# Write drain: hysteresis, starvation bound, and the posted-write win
# ---------------------------------------------------------------------------

def _write_burst(n, arrival=0.0):
    return [Txn(arrival, bank=32 + (i % 4) * 8, row=0, col=(i // 4) % 32,
                is_write=True) for i in range(n)]


def test_writedrain_hysteresis_batches_writes():
    """Drains trigger at the high watermark and each batch is bounded by
    drain_budget: a 96-write burst must take ceil-ish 96/budget drains,
    not one drain per write."""
    n, budget = 96, 16
    sim = sched.HBM4WriteDrainChannelSim(refresh=False, drain_budget=budget)
    r = sim.run(_write_burst(n))
    drains = r.cmd_counts["drain_entries"]
    assert drains >= (n - sim.policy.low_watermark) // (budget + 1)
    assert drains <= -(-n // budget) + 1, drains
    assert np.all(r.finish_ns > 0)


def test_writedrain_reads_never_starve_past_drain_budget():
    """A read queued behind an arbitrarily large write backlog is
    serviced after at most one drain batch (<= drain_budget writes),
    not after the whole backlog."""
    budget = 16
    txns = _write_burst(200)
    read = Txn(0.0, bank=0, row=0, col=0)
    txns.insert(0, read)
    sim = sched.HBM4WriteDrainChannelSim(refresh=False, drain_budget=budget)
    r = sim.run(txns)
    read_finish = r.finish_ns[0]
    writes_before_read = int(sum(f < read_finish for f in r.finish_ns[1:]))
    assert writes_before_read <= budget + 4, writes_before_read
    # ... while plain FR-FCFS (kind-blind) gives no such guarantee on
    # this trace shape beyond readiness accidents.
    assert r.finish_ns.max() > read_finish  # the backlog finishes after


def _trickle_trace(n_reads=600, read_pace=2.0, w_every=4):
    """Open-loop paced reads + a 1-in-`w_every` posted-write trickle —
    the regime write draining is designed for (the lone-write
    gap-slotting trap for plain FR-FCFS)."""
    txns, nw = [], 0
    for i in range(n_reads):
        txns.append(Txn(i * read_pace, bank=(i % 4) * 8, row=0,
                        col=(i // 4) % 32))
        if i % w_every == 0:
            txns.append(Txn(i * read_pace + 0.3, bank=32 + (nw % 4) * 8,
                            row=0, col=(nw // 4) % 32, is_write=True))
            nw += 1
    txns.sort(key=lambda t: t.arrival_ns)
    return txns


def _read_latencies(r, txns):
    return [f - tx.arrival_ns for f, tx in zip(r.finish_ns, txns)
            if not tx.is_write]


def test_writedrain_beats_frfcfs_on_posted_write_trickle():
    """On the paced-read + write-trickle regime, batching posted writes
    beats FR-FCFS's lone-write gap slotting on read latency without
    costing makespan."""
    t_fr, t_wd = _trickle_trace(), _trickle_trace()
    fr = sched.HBM4ChannelSim(refresh=False).run(t_fr)
    wd = sched.HBM4WriteDrainChannelSim(refresh=False).run(t_wd)
    assert np.mean(_read_latencies(wd, t_wd)) < \
        np.mean(_read_latencies(fr, t_fr))
    assert wd.total_ns <= fr.total_ns * 1.01
    assert wd.cmd_counts["drain_entries"] > 0


def test_writedrain_read_only_is_bit_identical_to_frfcfs():
    txns = sched.sequential_read_txns_hbm4(1 << 14)
    fr = sched.HBM4ChannelSim(refresh=False).run(list(txns))
    wd = sched.HBM4WriteDrainChannelSim(refresh=False).run(list(txns))
    assert np.array_equal(fr.finish_ns, wd.finish_ns)


def test_writedrain_parameter_validation():
    with pytest.raises(ValueError):
        FRFCFSWriteDrainPolicy(high_watermark=2, low_watermark=4)
    with pytest.raises(ValueError):
        FRFCFSWriteDrainPolicy(drain_budget=0)


# ---------------------------------------------------------------------------
# SID grouping: tCCDR regression on a two-SID trace
# ---------------------------------------------------------------------------

class _CountingFR(FRFCFSOpenPagePolicy):
    """Plain FR-FCFS instrumented with the same sid_switches stat, so the
    grouping claim is measured against the baseline, not asserted."""

    count_keys = FRFCFSOpenPagePolicy.count_keys + ("sid_switches",)

    def begin(self, counts):
        super().begin(counts)
        self._cur = [-1] * self.g.pseudo_channels

    def _after_column(self, tx, b, cmd_t):
        pc = self._pc(tx.bank)
        if 0 <= self._cur[pc] != tx.sid:
            self.counts["sid_switches"] += 1
        self._cur[pc] = tx.sid


def _two_sid_trace(n=400, pace=1.7):
    """Two tenants in different SIDs, bank groups disjoint, arrivals
    interleaved — the cross-SID (tCCDR) pacing regime."""
    txns = []
    for i in range(n):
        txns.append(Txn(i * pace, bank=(i % 4) * 8, row=0,
                        col=(i // 4) % 32, sid=0))
        txns.append(Txn(pace / 2 + i * pace, bank=32 + (i % 4) * 8, row=0,
                        col=(i // 4) % 32, sid=1))
    txns.sort(key=lambda t: t.arrival_ns)
    return txns


def test_sidgroup_enforces_tccdr_spacing():
    """Cross-SID bursts must still be tCCDR-spaced under the grouping
    policy (the regression the test pins: grouping may reorder, never
    violate)."""
    sim = sched.HBM4SIDGroupChannelSim(refresh=False)
    t = sim.t
    txns = [Txn(0.0, bank=8 * (i % 2), row=0, col=i // 2, sid=i % 2)
            for i in range(64)]
    r = sim.run(txns)
    # Adjacent completions of different SIDs must be >= tCCDR apart.
    order = np.argsort(r.finish_ns)
    fins = r.finish_ns[order]
    sids = np.array([txns[i].sid for i in order])
    gaps = np.diff(fins)
    cross = sids[1:] != sids[:-1]
    assert gaps[cross].min() >= t.tCCDR - 1e-9


def test_sidgroup_reduces_switches_at_neutral_bandwidth():
    """Grouping must not cost bandwidth (margin-bounded deferral) and
    must not switch SIDs more often than plain FR-FCFS — the honest
    claim the sweep documents: a guaranteed bound on switch events,
    not a bandwidth multiple."""
    geo = hbm4_config().geometry.channel
    fr = ChannelSimCore(_CountingFR(geometry=geo), 8, refresh=False)
    sg = sched.HBM4SIDGroupChannelSim(queue_depth=8, refresh=False)
    r_fr = fr.run(_two_sid_trace())
    r_sg = sg.run(_two_sid_trace())
    assert r_sg.total_ns <= r_fr.total_ns * 1.01
    assert r_sg.cmd_counts["sid_switches"] <= r_fr.cmd_counts["sid_switches"]


def test_sidgroup_single_sid_identical_to_frfcfs():
    txns = sched.sequential_read_txns_hbm4(1 << 14)
    fr = sched.HBM4ChannelSim(refresh=False).run(list(txns))
    sg = sched.HBM4SIDGroupChannelSim(refresh=False).run(list(txns))
    assert np.array_equal(fr.finish_ns, sg.finish_ns)


# ---------------------------------------------------------------------------
# Registry: census introspection and the conservation property
# ---------------------------------------------------------------------------

def test_registry_default_catalogue():
    names = sched.policy_names()
    assert len(names) >= 5
    for required in ("hbm4_frfcfs", "hbm4_writedrain", "hbm4_sidgroup",
                     "rome_qd2", "rome_eager_refresh"):
        assert required in names
    with pytest.raises(ValueError):
        sched.policy_spec("no_such_policy")
    with pytest.raises(ValueError):
        sched.register_policy(sched.policy_spec("rome_qd2"))  # duplicate


def test_registry_census_rows():
    census = registry_census()
    # The two canonical Table IV rows survive across the design space.
    for name, spec in sched.registered_policies().items():
        c = census[name]
        if spec.family == "hbm4":
            assert (c.n_timing_params, c.n_bank_fsms, c.n_bank_states) == \
                (15, 64, 7), name
        else:
            assert (c.n_timing_params, c.n_bank_fsms, c.n_bank_states) == \
                (10, 5, 4), name
    # Variants must declare their extra hardware, the paper rows none.
    assert census["hbm4_writedrain"].aux_state
    assert census["hbm4_sidgroup"].aux_state
    assert census["hbm4_frfcfs"].aux_state == ()
    assert census["rome_qd2"].aux_state == ()


def test_registry_specs_build_running_sims():
    for name, spec in sched.registered_policies().items():
        sim = spec.make_sim(refresh=False)
        assert isinstance(sim, ChannelSimCore)
        assert sim.queue_depth == spec.queue_depth, name
        fp = spec.make_policy().state_footprint()
        assert complexity_of_policy(spec.make_policy(),
                                    spec.queue_depth).name == fp["name"]


def _random_trace(seed, n, family):
    rng = np.random.default_rng(seed)
    n_banks = 128 if family == "hbm4" else 16
    return [Txn(arrival_ns=float(rng.uniform(0, 50.0 * n)),
                bank=int(rng.integers(0, n_banks)),
                row=int(rng.integers(0, 8)),
                col=int(rng.integers(0, 32)),
                is_write=bool(rng.integers(0, 2)),
                sid=int(rng.integers(0, 2)))
            for _ in range(n)]


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_every_registered_policy_conserves_txns_and_bytes(seed):
    """Conservation on a shared random mixed stream: every registered
    policy must complete every transaction exactly once, with finite
    positive finish times and byte accounting at its own granularity."""
    for name, spec in sched.registered_policies().items():
        trace = _random_trace(seed, 48, spec.family)
        sim = spec.make_sim()
        r = sim.run(trace)
        assert len(r.finish_ns) == len(trace), name
        assert np.all(np.isfinite(r.finish_ns)), name
        assert np.all(r.finish_ns > 0), name
        assert r.bytes_moved == len(trace) * sim.policy.bytes_per_txn, name
        assert r.total_ns == pytest.approx(r.finish_ns.max()), name


# ---------------------------------------------------------------------------
# SystemSim: SID decomposition and registered-kind plumbing
# ---------------------------------------------------------------------------

def test_systemsim_sid_decomposition_defaults_to_zero():
    cfg = hbm4_config()
    sim = SystemSim(cfg, n_channels=2)
    stream = ExtentStream([ExtentRecord(0, 4096), ExtentRecord(96 << 20, 4096)])
    txns = [tx for ch in sim.decompose(stream).values() for tx in ch]
    assert all(tx.sid == 0 for tx in txns)


def test_systemsim_sid_decomposition_by_region():
    cfg = hbm4_config()
    sim = SystemSim(cfg, n_channels=2, sids=4)
    stream = ExtentStream([ExtentRecord(0, 4096),
                           ExtentRecord(64 << 20, 4096),
                           ExtentRecord(5 * (64 << 20), 4096)])
    sids = {tx.sid for ch in sim.decompose(stream).values() for tx in ch}
    assert sids == {0, 1}  # region 0 -> 0, region 1 -> 1, region 5 -> 1
    with pytest.raises(ValueError):
        SystemSim(cfg, n_channels=2, sids=0)


def test_systemsim_rejects_cross_family_channel_kind():
    with pytest.raises(ValueError):
        SystemSim(hbm4_config(), n_channels=2, channel_kind="rome")


def test_systemsim_channel_kind_kwargs_reach_the_policy():
    cfg = hbm4_config()
    sim = SystemSim(cfg, n_channels=2, channel_kind="hbm4_writedrain",
                    channel_kwargs={"queue_depth": 32, "drain_budget": 5})
    ch = sim._make_sim()
    assert isinstance(ch.policy, FRFCFSWriteDrainPolicy)
    assert ch.queue_depth == 32
    assert ch.policy.drain_budget == 5
