"""Layer-level math: chunked attention exactness, masks, rope, energy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _proptest import given, settings, strategies as st

from repro.core.energy import EnergyParams, hbm4_energy, rome_energy
from repro.models.layers import (apply_rope, attention_scores, causal_mask,
                                 chunked_attention, repeat_kv, rmsnorm)

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("s,qc,kc", [(100, 32, 32), (256, 64, 128),
                                     (64, 64, 64), (130, 32, 48)])
def test_chunked_attention_exact(s, qc, kc):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, s, 16))
    k = jax.random.normal(ks[1], (1, 2, s, 16))
    v = jax.random.normal(ks[2], (1, 2, s, 16))
    ref = attention_scores(q, k, v, causal_mask(s, s))
    out = chunked_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(deadline=None, max_examples=10)
@given(s=st.integers(min_value=8, max_value=96),
       win=st.integers(min_value=2, max_value=64))
def test_chunked_attention_sliding_window_property(s, win):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 1, s, 8))
    k = jax.random.normal(ks[1], (1, 1, s, 8))
    v = jax.random.normal(ks[2], (1, 1, s, 8))
    ref = attention_scores(q, k, v, causal_mask(s, s, win))
    out = chunked_attention(q, k, v, sliding_window=win, q_chunk=16,
                            kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_causal_mask_swa():
    m = causal_mask(5, 5, sliding_window=2)
    expect = np.array([[1, 0, 0, 0, 0],
                       [1, 1, 0, 0, 0],
                       [0, 1, 1, 0, 0],
                       [0, 0, 1, 1, 0],
                       [0, 0, 0, 1, 1]], bool)
    np.testing.assert_array_equal(np.asarray(m), expect)


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(KEY, (1, 1, 8, 32))
    pos = jnp.arange(8)[None, None, :]
    y = apply_rope(x, pos, theta=1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[[i]]]), theta=1e4)
        kj = apply_rope(k, jnp.array([[[j]]]), theta=1e4)
        return float(jnp.sum(qi * kj))
    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), rel=1e-4)


def test_repeat_kv():
    x = jnp.arange(2 * 2 * 3 * 4).reshape(2, 2, 3, 4).astype(jnp.float32)
    y = repeat_kv(x, 3)
    assert y.shape == (2, 6, 3, 4)
    np.testing.assert_array_equal(np.asarray(y[:, 0]), np.asarray(y[:, 2]))


def test_rmsnorm_unit_scale():
    x = jax.random.normal(KEY, (4, 64)) * 10
    y = rmsnorm(x, jnp.ones((64,)))
    rms = np.sqrt(np.mean(np.asarray(y, np.float32) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=0.05)


# --- energy model -------------------------------------------------------------

def test_rome_energy_act_structural():
    p = EnergyParams()
    nbytes = 1 << 20
    n_rows = nbytes // 4096
    e = rome_energy(nbytes, n_rows, 0, 1000.0, 36, p=p)
    assert e.act_pj == 4 * n_rows * p.e_act_pj
    # one row command vs 32 column commands per KB on the interposer
    h = hbm4_energy(nbytes, nbytes // 1024, nbytes // 32, 0, 1000.0, 32,
                    p=p)
    assert e.ca_pj < h.ca_pj / 20


def test_overfetch_increases_data_energy():
    e0 = rome_energy(1 << 20, 256, 0, 1000.0, 36, overfetch_frac=0.0)
    e1 = rome_energy(1 << 20, 256, 0, 1000.0, 36, overfetch_frac=1.0)
    assert e1.data_core_pj == pytest.approx(2 * e0.data_core_pj)
