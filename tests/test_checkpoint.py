"""Checkpoint: round-trip, atomicity, resume, async, exotic dtypes."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import tree_map
from repro.distributed import checkpoint as ckpt


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16) * 1.5,
                  "d": jnp.array(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    like = tree_map(lambda x: jnp.zeros_like(x), t)
    r = ckpt.restore(str(tmp_path), 3, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_bf16_dtype_survives(tmp_path):
    t = {"w": jnp.full((4,), 1.25, jnp.bfloat16)}
    ckpt.save(str(tmp_path), 0, t)
    r = ckpt.restore(str(tmp_path), 0, t)
    assert r["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(r["w"], np.float32),
                                  np.asarray(t["w"], np.float32))


def test_latest_step_skips_torn_saves(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    ckpt.save(str(tmp_path), 5, t)
    # torn save: directory without a complete manifest
    os.makedirs(tmp_path / "step_000009")
    with open(tmp_path / "step_000009" / "manifest.json", "w") as f:
        json.dump({"step": 9, "status": "writing"}, f)
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_structure_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 0, _tree())
    with pytest.raises(ValueError, match="structure mismatch"):
        ckpt.restore(str(tmp_path), 0, {"only": jnp.zeros(3)})


def test_async_checkpointer(tmp_path):
    t = _tree()
    saver = ckpt.AsyncCheckpointer()
    saver.save(str(tmp_path), 2, t)
    saver.save(str(tmp_path), 4, t)     # joins the in-flight save first
    saver.close()
    assert ckpt.latest_step(str(tmp_path)) == 4
    r = ckpt.restore(str(tmp_path), 2, t)
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(t["a"]))


def test_overwrite_same_step(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    t2 = tree_map(lambda x: x + 1 if x.dtype != jnp.bfloat16 else x, t)
    ckpt.save(str(tmp_path), 7, t2)
    r = ckpt.restore(str(tmp_path), 7, t)
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(t2["a"]))
