"""Repo-invariant AST lints: each rule fires on the pattern it names,
stays quiet on the sanctioned alternative, and the tree itself is clean.
"""
from __future__ import annotations

from pathlib import Path

from repro.analysis.lints import (ALL_RULES, lint_paths, lint_source,
                                  rules_for_path)

REPO = Path(__file__).resolve().parent.parent


def _rules(src: str) -> list[str]:
    return [f.rule for f in lint_source(src)]


# ---------------------------------------------------------------------------
# jax-drift
# ---------------------------------------------------------------------------

def test_drifted_tree_map_flagged():
    assert _rules("import jax\njax.tree.map(f, x)\n") == ["jax-drift"]
    assert _rules("import jax\njax.tree_util.tree_map(f, x)\n") \
        == ["jax-drift"]


def test_drifted_mesh_apis_flagged():
    assert _rules("import jax\njax.sharding.get_abstract_mesh()\n") \
        == ["jax-drift"]
    assert _rules("import jax\njax.make_mesh((2,), ('x',))\n") \
        == ["jax-drift"]
    assert _rules("import jax\njax.shard_map(f, mesh, a, b)\n") \
        == ["jax-drift"]
    assert "jax-drift" in _rules("sizes = dict(zip(m.axis_names, "
                                 "m.axis_sizes))\n")


def test_drifted_import_and_method_flagged():
    assert _rules("from jax.tree_util import tree_map\n") == ["jax-drift"]
    assert _rules("pltpu.TPUCompilerParams(x=1)\n") == ["jax-drift"]
    assert _rules("c = compiled.cost_analysis()\n") == ["jax-drift"]


def test_compat_spellings_not_flagged():
    clean = ("from repro.compat import tree_map, active_mesh\n"
             "tree_map(f, x)\nactive_mesh()\n")
    assert _rules(clean) == []
    # self-attribute access with a drifted *name* is not the JAX API
    assert _rules("class A:\n"
                  "    def f(self):\n"
                  "        return self.axis_sizes\n") == []


# ---------------------------------------------------------------------------
# version-compare
# ---------------------------------------------------------------------------

def test_version_compare_flagged():
    assert _rules("import jax\nok = jax.__version__ >= '0.5'\n") \
        == ["version-compare"]
    assert _rules("if __version__ < '2.0':\n    pass\n") \
        == ["version-compare"]


def test_version_use_without_compare_ok():
    assert _rules("import jax\nprint(jax.__version__)\n") == []


# ---------------------------------------------------------------------------
# unseeded-random
# ---------------------------------------------------------------------------

def test_global_numpy_rng_flagged():
    assert _rules("import numpy as np\nx = np.random.rand(3)\n") \
        == ["unseeded-random"]
    assert _rules("import numpy as np\nr = np.random.default_rng()\n") \
        == ["unseeded-random"]


def test_seeded_generator_ok():
    assert _rules("import numpy as np\nr = np.random.default_rng(7)\n"
                  "x = r.normal(size=3)\n") == []


def test_stdlib_random_module_flagged_only_when_imported():
    assert _rules("import random\nrandom.shuffle(xs)\n") \
        == ["unseeded-random"]
    # `random` here is a local object, not the module
    assert _rules("random = make_rng()\nrandom.shuffle(xs)\n") == []


# ---------------------------------------------------------------------------
# mutable-default
# ---------------------------------------------------------------------------

def test_mutable_defaults_flagged():
    assert _rules("def f(xs=[]):\n    pass\n") == ["mutable-default"]
    assert _rules("def f(m={}, *, s=set()):\n    pass\n") \
        == ["mutable-default"] * 2


def test_none_default_ok():
    assert _rules("def f(xs=None, n=3, s='a', t=()):\n    pass\n") == []


# ---------------------------------------------------------------------------
# pool-submit-closure
# ---------------------------------------------------------------------------

def test_lambda_to_submit_flagged():
    assert _rules("pool.submit(lambda: 1)\n") == ["pool-submit-closure"]


def test_nested_def_to_submit_flagged():
    src = ("def outer(pool):\n"
           "    def work():\n"
           "        return 1\n"
           "    return pool.submit(work)\n")
    assert _rules(src) == ["pool-submit-closure"]


def test_module_level_callable_to_submit_ok():
    src = ("def work():\n"
           "    return 1\n"
           "def outer(pool):\n"
           "    return pool.submit(work, 1)\n")
    assert _rules(src) == []


# ---------------------------------------------------------------------------
# untracked-counter
# ---------------------------------------------------------------------------

def test_registered_counter_keys_ok():
    src = ('class P:\n'
           '    count_keys = ("ACT", "RD", "WR")\n'
           '    def f(self, counts):\n'
           '        counts["ACT"] += 1\n'
           '        self.counts["REFpb"] += 2\n'
           '        return self.cmd_counts.get("drain_entries", 0)\n')
    assert _rules(src) == []


def test_unregistered_counter_key_flagged_everywhere_keys_appear():
    # subscript write, count_keys declaration (incl. tuple concat), and
    # .get() read are all mint points for a counter name
    assert _rules('counts["frobnications"] = 1\n') == ["untracked-counter"]
    assert _rules('count_keys = ("ACT",) + ("frobnications",)\n') \
        == ["untracked-counter"]
    assert _rules('x = cmd_counts.get("frobnications", 0)\n') \
        == ["untracked-counter"]
    # non-counter dicts with arbitrary string keys are not the rule's
    # business
    assert _rules('opts["frobnications"] = 1\n') == []


def test_counter_registry_covers_every_key_policies_mint():
    """The end the rule exists for: the union of all count_keys across
    the live policy registry is registered (so the probe folds them)."""
    from repro.core.sched import registered_policies
    from repro.obs.metrics import COUNTER_REGISTRY
    minted = set()
    for spec in registered_policies().values():
        minted.update(spec.make_policy().count_keys)
    assert minted <= set(COUNTER_REGISTRY), \
        minted - set(COUNTER_REGISTRY)


# ---------------------------------------------------------------------------
# path scoping + whole-tree cleanliness
# ---------------------------------------------------------------------------

def test_rule_scoping_by_path():
    assert "jax-drift" not in rules_for_path("src/repro/compat/tree.py")
    assert "jax-drift" in rules_for_path("src/repro/models/layers.py")
    assert "unseeded-random" in rules_for_path("src/repro/core/analytic.py")
    assert "unseeded-random" in rules_for_path("src/repro/serve/replay.py")
    assert "unseeded-random" not in rules_for_path("tests/test_lints.py")
    assert "untracked-counter" in rules_for_path(
        "src/repro/core/sched/policies.py")
    assert "untracked-counter" not in rules_for_path(
        "src/repro/core/system_sim.py")


def test_syntax_error_reported_not_raised():
    out = lint_source("def broken(:\n")
    assert [f.rule for f in out] == ["syntax-error"]


def test_repo_tree_is_lint_clean():
    """The gate CI enforces: the whole checked tree has zero findings."""
    findings = lint_paths(
        REPO / p for p in ("src", "benchmarks", "scripts", "tests"))
    assert findings == [], "\n".join(str(f) for f in findings)


def test_all_rules_exercised_by_this_file():
    assert set(ALL_RULES) == {"jax-drift", "version-compare",
                              "unseeded-random", "mutable-default",
                              "pool-submit-closure", "untracked-counter"}


# ---------------------------------------------------------------------------
# docs lints (doc-code-block / doc-path)
# ---------------------------------------------------------------------------

def _doc_rules(text: str) -> list[str]:
    from repro.analysis.lints import lint_doc_source
    return [f.rule for f in lint_doc_source(text, "docs/x.md",
                                            repo_root=REPO)]


def test_doc_python_fence_must_parse():
    bad = "# t\n\n```python\ndef broken(:\n```\n"
    assert _doc_rules(bad) == ["doc-code-block"]
    good = "# t\n\n```python\nx = 1\n```\n"
    assert _doc_rules(good) == []


def test_doc_fence_line_numbers_point_into_block():
    from repro.analysis.lints import lint_doc_source
    text = "line1\n\n```python\nok = 1\ndef broken(:\n```\n"
    (f,) = lint_doc_source(text, "docs/x.md", repo_root=REPO)
    assert f.rule == "doc-code-block" and f.line == 5


def test_doc_named_paths_must_exist():
    assert _doc_rules("see src/repro/core/system_sim.py\n") == []
    assert _doc_rules("see src/repro/not_a_module.py\n") == ["doc-path"]
    # paths inside bash fences are checked too (verify commands!)
    assert _doc_rules("```bash\npython scripts/nonexistent.py\n```\n") \
        == ["doc-path"]
    # non-python fences are not parsed as python
    assert _doc_rules("```bash\ndef broken(:\n```\n") == []


def test_repo_docs_are_lint_clean():
    from repro.analysis.lints import lint_docs
    findings = lint_docs((REPO / p for p in ("README.md", "docs",
                                             "benchmarks")),
                         repo_root=REPO)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_doc_rules_are_separate_from_ast_rules():
    from repro.analysis.lints import DOC_RULES
    assert set(DOC_RULES) == {"doc-code-block", "doc-path"}
    assert not set(DOC_RULES) & set(ALL_RULES)
