"""Serving layer: row-paged KV cache invariants + continuous batching."""
import numpy as np
import pytest
from _proptest import given, settings, strategies as st

from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.kv_cache import ROW_BYTES, RowPagedKVCache, tokens_per_row


def _cache(**kw):
    base = dict(n_pages=16, page_tokens=tokens_per_row(64, 2),
                n_kv_heads=2, head_dim=64, max_seqs=4,
                max_pages_per_seq=8)
    base.update(kw)
    return RowPagedKVCache(**base)


def test_page_is_whole_rows():
    c = _cache()
    assert c.page_bytes % ROW_BYTES == 0
    assert c.rows_per_page() >= 1


def test_tokens_per_row_exact():
    assert tokens_per_row(64, 2, 2) == 4096 // (64 * 2 * 2)
    with pytest.raises(ValueError):
        tokens_per_row(96, 5, 2)        # no integral packing in one row


def test_alloc_append_free_cycle():
    c = _cache()
    c.alloc_seq(0, 10)
    used0 = c.utilization()
    pg, slot = c.append_token(0)
    assert 0 <= pg < c.n_pages
    c.free_seq(0)
    assert c.utilization() == 0.0
    assert used0 > 0


def test_append_crosses_page_boundary():
    c = _cache()
    tp = c.page_tokens
    c.alloc_seq(0, tp)                   # exactly one full page
    pg2, slot2 = c.append_token(0)       # must grab a fresh page
    assert slot2 == 0
    assert c.page_table[0, 1] == pg2


def test_pool_exhaustion_raises():
    c = _cache(n_pages=2, max_pages_per_seq=8)
    with pytest.raises(MemoryError):
        c.alloc_seq(0, c.page_tokens * 3)


def test_gather_matches_writes():
    import jax.numpy as jnp
    c = _cache()
    c.alloc_seq(1, 3)
    for t in range(3):
        pg, slot = divmod(t, c.page_tokens)
        page_id = int(c.page_table[1, pg])
        c.write(page_id, slot,
                jnp.full((2, 64), float(t)), jnp.full((2, 64), -float(t)))
    k, v = c.gather_seq(1)
    assert k.shape == (3, 2, 64)
    np.testing.assert_allclose(np.asarray(k)[:, 0, 0], [0.0, 1.0, 2.0])
    np.testing.assert_allclose(np.asarray(v)[:, 0, 0], [0.0, -1.0, -2.0])


def test_kv_cache_emits_unified_records():
    """The paged KV cache speaks the same ExtentRecord currency as the
    layer-op traces: whole-page row-aligned reads and in-page writes,
    covering BOTH the K and the V pool."""
    c = _cache()
    c.alloc_seq(2, c.page_tokens + 1)    # spans two pages
    reads = c.read_stream(2, base_addr=1 << 20, arrival_ns=5.0)
    assert len(reads) == 4               # 2 pages x {K, V}
    assert reads.read_bytes == 4 * c.page_bytes
    addrs = {r.addr for r in reads}
    assert len(addrs) == 4               # K and V pages never alias
    for r in reads:
        assert r.kind == "read" and r.arrival_ns == 5.0 and r.stream_id == 2
        assert (r.addr - (1 << 20)) % ROW_BYTES == 0
        assert r.nbytes % ROW_BYTES == 0
    before = int(c.seq_lens[2])
    writes = c.append_stream(2)
    assert int(c.seq_lens[2]) == before + 1   # token accounted exactly once
    per_tok = c.page_bytes // c.page_tokens
    assert len(writes) == 2              # K write + V write
    assert all(w.kind == "write" and w.stream_id == 2
               and w.nbytes == per_tok for w in writes)
    # Each write lands inside the token's page of its own pool.
    page_id, slot = divmod(int(c.seq_lens[2]) - 1, c.page_tokens)
    pool_page = int(c.page_table[2, page_id])
    assert [w.addr for w in writes] == [
        c.page_addr(pool_page, pool="k") + slot * per_tok,
        c.page_addr(pool_page, pool="v") + slot * per_tok]


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(min_value=0, max_value=999))
def test_kv_pool_never_double_allocates(seed):
    """Property: live pages are disjoint across sequences at all times."""
    rng = np.random.default_rng(seed)
    c = _cache(n_pages=12, max_seqs=3, max_pages_per_seq=4)
    lens = [0, 0, 0]
    for _ in range(40):
        sid = int(rng.integers(0, 3))
        if lens[sid] == 0 and rng.random() < 0.5:
            n = int(rng.integers(1, c.page_tokens * 2))
            try:
                c.alloc_seq(sid, n)
                lens[sid] = n
            except MemoryError:
                pass
        elif lens[sid] and rng.random() < 0.3:
            c.free_seq(sid)
            lens[sid] = 0
        elif lens[sid]:
            try:
                c.append_token(sid)
                lens[sid] += 1
            except MemoryError:
                pass
        live = [p for row in c.page_table for p in row if p >= 0]
        assert len(live) == len(set(live))
        assert len(live) + len(c._free) == c.n_pages


# --- continuous batching ------------------------------------------------------

def test_batcher_fifo_and_retire():
    b = ContinuousBatcher(2)
    for rid in range(4):
        b.submit(Request(rid, np.array([1, 2]), max_new_tokens=2))
    adm = b.schedule()
    assert [r.rid for _, r in adm] == [0, 1]
    b.record_tokens(np.array([10, 11]))
    done = b.record_tokens(np.array([12, 13]))
    assert sorted(r.rid for r in done) == [0, 1]
    adm2 = b.schedule()
    assert [r.rid for _, r in adm2] == [2, 3]


def test_batcher_iteration_level_join():
    """A request finishing frees its slot for the next queued request at a
    token boundary (no full-batch drain)."""
    b = ContinuousBatcher(2)
    b.submit(Request(0, np.array([1]), max_new_tokens=1))
    b.submit(Request(1, np.array([1]), max_new_tokens=3))
    b.submit(Request(2, np.array([1]), max_new_tokens=1))
    b.schedule()
    b.record_tokens(np.array([5, 6]))        # r0 done
    adm = b.schedule()
    assert [r.rid for _, r in adm] == [2]
    assert b.active[0].rid == 2 and b.active[1].rid == 1


def test_occupancy_zero_before_first_step():
    """No division by zero (and a defined 0.0) before any decode step."""
    b = ContinuousBatcher(4)
    assert b.occupancy == 0.0
    b.submit(Request(0, np.array([1]), 1))
    assert b.occupancy == 0.0          # still no step recorded


def test_request_timeline_step_indices():
    """submit/admit/first-token/completion step indices as maintained by
    the batcher (the TTFT/TPOT accounting the replay engine folds
    makespans onto)."""
    b = ContinuousBatcher(1)
    r0 = Request(0, np.array([1]), max_new_tokens=2)
    r1 = Request(1, np.array([1]), max_new_tokens=1)
    b.submit(r0)
    b.submit(r1)
    assert r0.timeline.submitted_step == 0 and r1.timeline.submitted_step == 0
    b.schedule()                         # r0 takes the only slot
    assert r0.timeline.admitted_step == 0
    assert r1.timeline.admitted_step == -1
    b.record_tokens(np.array([7]))       # step 0: r0 first token
    assert r0.timeline.first_token_step == 0
    assert r0.timeline.completed_step == -1
    b.schedule()
    b.record_tokens(np.array([8]))       # step 1: r0 completes
    assert r0.timeline.completed_step == 1
    assert r0.timeline.decode_steps == 2 == len(r0.out_tokens)
    b.schedule()                         # r1 admitted at step index 2
    assert r1.timeline.admitted_step == 2
    b.record_tokens(np.array([9]))
    assert r1.timeline.first_token_step == 2
    assert r1.timeline.completed_step == 2
    assert r1.timeline.decode_steps == 1


def test_admission_check_blocks():
    b = ContinuousBatcher(2, admit=lambda req: req.rid != 1)
    b.submit(Request(0, np.array([1]), 1))
    b.submit(Request(1, np.array([1]), 1))
    adm = b.schedule()
    # FIFO order preserved: r0 admitted; r1 blocks the queue head
    assert [r.rid for _, r in adm] == [0]
    assert b.queue[0].rid == 1
