"""Scheduler-core package: policy pluggability, introspection, tCCDR,
closed-page variant, and the legacy engine facade."""
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import sched
from repro.core.mc import (complexity_of_policy, conventional_mc_complexity,
                           rome_mc_complexity)


# ---------------------------------------------------------------------------
# Facade & factory
# ---------------------------------------------------------------------------

def test_engine_facade_reexports_sched_objects():
    """`repro.core.engine` is a compatibility facade: the legacy names must
    be the *same objects* as the sched package's, so isinstance checks and
    behaviour can never diverge between the two import paths."""
    for name in ("HBM4ChannelSim", "RoMeChannelSim", "Txn", "SimResult",
                 "sequential_read_txns_hbm4", "sequential_read_txns_rome",
                 "interleaved_stream_txns_hbm4", "_PendingQueue"):
        assert getattr(eng, name) is getattr(sched, name)


def test_make_channel_sim_factory():
    assert isinstance(sched.make_channel_sim("hbm4"), sched.HBM4ChannelSim)
    assert isinstance(sched.make_channel_sim("rome"), sched.RoMeChannelSim)
    closed = sched.make_channel_sim("hbm4_closed")
    assert isinstance(closed, sched.HBM4ChannelSim)
    assert isinstance(closed.policy, sched.HBM4ClosedPagePolicy)
    with pytest.raises(ValueError):
        sched.make_channel_sim("ddr5")


def test_sims_share_one_event_loop():
    """The refactor's point: both controllers run the same core loop."""
    assert isinstance(sched.HBM4ChannelSim(), sched.ChannelSimCore)
    assert isinstance(sched.RoMeChannelSim(), sched.ChannelSimCore)
    assert type(sched.HBM4ChannelSim().run) is type(sched.RoMeChannelSim().run)


# ---------------------------------------------------------------------------
# State-footprint introspection (Table IV)
# ---------------------------------------------------------------------------

def test_policy_footprint_matches_mc_census():
    """The policies' introspected state must agree with the architectural
    census in repro.core.mc (paper Table IV)."""
    h = complexity_of_policy(sched.FRFCFSOpenPagePolicy(), 64)
    census_h = conventional_mc_complexity()
    assert (h.n_timing_params, h.n_bank_fsms, h.n_bank_states) == \
        (census_h.n_timing_params, census_h.n_bank_fsms,
         census_h.n_bank_states) == (15, 64, 7)

    r = complexity_of_policy(sched.RoMeRowPolicy(), 2)
    census_r = rome_mc_complexity()
    assert (r.n_timing_params, r.n_bank_fsms, r.n_bank_states) == \
        (census_r.n_timing_params, census_r.n_bank_fsms,
         census_r.n_bank_states) == (10, 5, 4)


def test_closed_page_footprint():
    fp = sched.HBM4ClosedPagePolicy().state_footprint()
    assert fp["name"] == "frfcfs_closed"
    assert "row-buffer locality" not in fp["scheduling"]


# ---------------------------------------------------------------------------
# tCCDR: same-PC, cross-SID burst spacing (regression)
# ---------------------------------------------------------------------------

def _two_bg_trace(n: int, alternate_sid: bool):
    """Row hits alternating between two bank groups of one PC; SIDs either
    all 0 or alternating 0/1. Without tCCDR both traces pace at
    tCCDS/bus (1 ns); with it the cross-SID trace paces at tCCDR (2 ns)."""
    txns = []
    for i in range(n):
        txns.append(eng.Txn(0.0, bank=8 * (i % 2), row=0, col=i // 2,
                            sid=(i % 2) if alternate_sid else 0))
    return txns


def test_tccdr_enforced_across_sids():
    t = eng.HBM4ChannelSim().t
    assert t.tCCDR > t.tCCDS  # the constraint must be observable
    n = 64
    same = eng.HBM4ChannelSim(refresh=False).run(_two_bg_trace(n, False))
    cross = eng.HBM4ChannelSim(refresh=False).run(_two_bg_trace(n, True))
    # Single-SID paces at max(tCCDS, bus) = 1 ns per burst; alternating
    # SIDs must pace at tCCDR = 2 ns per burst.
    assert cross.total_ns > 1.6 * same.total_ns
    gaps = np.diff(np.sort(cross.finish_ns))
    assert gaps.min() >= t.tCCDR - 1e-9


def test_tccdr_single_sid_unaffected():
    """All-sid-0 traces (every pre-existing benchmark) see no tCCDR term:
    stream bandwidth is unchanged at >90 % of peak."""
    sim = eng.HBM4ChannelSim(max_ref_postpone=32)
    r = sim.run(eng.sequential_read_txns_hbm4(1 << 17))
    assert r.bandwidth_gbps / sim.g.bandwidth_gbps > 0.90


# ---------------------------------------------------------------------------
# Closed-page policy
# ---------------------------------------------------------------------------

def test_closed_page_precharges_every_access():
    sim = sched.HBM4ClosedPageChannelSim(refresh=False)
    txns = eng.sequential_read_txns_hbm4(1 << 14)
    r = sim.run(txns)
    # One ACT and one PRE per access — no row reuse at all.
    assert r.cmd_counts["PRE"] == len(txns)
    assert r.cmd_counts["ACT"] == len(txns)


def test_closed_page_loses_stream_bandwidth_to_open_page():
    txns = eng.sequential_read_txns_hbm4(1 << 16)
    open_r = eng.HBM4ChannelSim(refresh=False).run(list(txns))
    closed_r = sched.HBM4ClosedPageChannelSim(refresh=False).run(list(txns))
    assert closed_r.total_ns > 1.5 * open_r.total_ns


def test_closed_page_command_counts_are_structural():
    """Closed page has RoMe-like predictability (one ACT + one PRE per
    access, independent of queue depth, layout, or arrival interleaving —
    no scheduling-dependent re-activation inflation) but pays it per 32 B
    column instead of per 4 KB row. That contrast is the paper's point:
    granularity, not policy alone, is what makes always-precharge cheap."""
    n = (1 << 15) // 32
    for layout in ("bg_striped", "row_linear"):
        for qd in (2, 64):
            r = sched.HBM4ClosedPageChannelSim(
                queue_depth=qd, refresh=False).run(
                eng.sequential_read_txns_hbm4(1 << 15, layout=layout))
            assert r.cmd_counts["ACT"] == n and r.cmd_counts["PRE"] == n
    # The open-page baseline's ACT count on the same bytes is
    # scheduling-dependent and far below n (row reuse) on a clean stream.
    ro = eng.HBM4ChannelSim(refresh=False).run(
        eng.sequential_read_txns_hbm4(1 << 15, layout="row_linear"))
    assert ro.cmd_counts["ACT"] < n // 8


# ---------------------------------------------------------------------------
# Core loop invariants under a policy swap
# ---------------------------------------------------------------------------

def test_refresh_governor_paces_closed_page_too():
    """The governor lives in the core, so any policy gets the bounded
    postponement / idle-advance behaviour for free."""
    sim = sched.HBM4ClosedPageChannelSim()
    gap = 40 * sim.t.tREFIpb
    txns = [eng.Txn(arrival_ns=i * gap, bank=i % sim.n_banks, row=i)
            for i in range(4)]
    r = sim.run(txns)
    assert r.cmd_counts["ref_backlog_max"] <= sim.max_ref_postpone
    assert np.all(np.isfinite(r.finish_ns)) and np.all(r.finish_ns > 0)


def test_duplicate_txns_complete_once_under_all_policies():
    for sim in (sched.HBM4ChannelSim(refresh=False),
                sched.HBM4ClosedPageChannelSim(refresh=False),
                sched.RoMeChannelSim(refresh=False)):
        txns = [eng.Txn(arrival_ns=0.0, bank=0, row=0) for _ in range(3)]
        r = sim.run(txns)
        assert np.all(r.finish_ns > 0)
        assert len(np.unique(r.finish_ns)) == 3
