"""Timing tables (II/III/V), geometry, and system configs."""
import pytest

from repro.core import (ChannelGeometry, CubeGeometry, HBM4Timing,
                        RoMeTiming, hbm4_config, rome_config)


def test_channel_geometry_hbm4():
    g = ChannelGeometry()
    assert g.bandwidth_gbps == 64.0          # 64 pins x 8 Gbps
    assert g.banks_per_channel == 128
    assert g.cols_per_row == 32              # 1 KB row / 32 B col


def test_cube_bandwidth_table_v():
    assert CubeGeometry().bandwidth_tbps == pytest.approx(2.048)  # ~2 TB/s
    r = rome_config()
    assert r.cube_bw_gbps / hbm4_config().cube_bw_gbps == pytest.approx(
        36 / 32)                              # +12.5 %


def test_table_v_values():
    h = hbm4_config()
    assert (h.channels_per_cube, h.banks_per_channel, h.row_bytes,
            h.ag_mc_bytes) == (32, 128, 1024, 32)
    r = rome_config()
    assert (r.channels_per_cube, r.banks_per_channel, r.row_bytes,
            r.ag_mc_bytes) == (36, 32, 4096, 4096)
    assert r.vbas_per_channel == 16


def test_rome_timing_table_iii():
    t = RoMeTiming()
    assert (t.tR2RS, t.tR2RR) == (64.0, 68.0)
    assert (t.tR2WS, t.tR2WR) == (69.0, 73.0)
    assert (t.tW2RS, t.tW2RR) == (71.0, 75.0)
    assert (t.tW2WS, t.tW2WR) == (64.0, 68.0)
    assert (t.tRD_row, t.tWR_row) == (95.0, 115.0)
    assert t.n_managed() == 10
    assert HBM4Timing().n_managed() == 15


def test_rome_gap_matrix():
    t = RoMeTiming()
    # same VBA chains on the row-op latency
    assert t.gap_ns(False, False, True, True) == t.tRD_row
    assert t.gap_ns(True, True, True, True) == t.tWR_row
    # different SID adds 1-2 nCK over different VBA
    for pw, nw in ((False, False), (False, True), (True, False),
                   (True, True)):
        s = t.gap_ns(pw, nw, False, True)
        r = t.gap_ns(pw, nw, False, False)
        assert r - s == 4.0


def test_hbm4_timing_table_v():
    t = HBM4Timing()
    assert (t.tRC, t.tRP, t.tRAS, t.tCL) == (45.0, 16.0, 29.0, 16.0)
    assert (t.tCCDL, t.tCCDS, t.tRRDS) == (2.0, 1.0, 2.0)
