"""Timing-protocol sanitizer: injected violations fire exactly once,
clean traces stay clean, and SystemSim sanitizer mode raises.

The injected-violation tests hand-craft CmdRecord streams that are
legal under every rule except the one under test — each must produce
exactly ``{rule: 1}``, proving the checker neither misses the shaved
constraint nor double-counts it through an overlapping rule.
"""
from __future__ import annotations

import dataclasses

import pytest

from repro.analysis import (TimingProtocolError, check_sim_result,
                            checker_for_sim, conformance_report,
                            policy_conformance)
from repro.analysis.timing_checker import HBM4TraceChecker, RoMeTraceChecker
from repro.core.sched import CmdRecord, facade_trace_suite, make_channel_sim
from repro.core.system_sim import SystemSim, bulk_stream_extents
from repro.core.timing import HBM4Timing, RoMeTiming, hbm4_config, rome_config

T = HBM4Timing()
RT = RoMeTiming()


def _act(t, bank, row=1, pc=0):
    return CmdRecord(t, "ACT", bank, pc, 0, row, -1.0, -1.0)


def _rd(t, bank, row=1, pc=0, sid=0, data=None):
    ds, de = data if data is not None else (t + T.tCL, t + T.tCL + 1.0)
    return CmdRecord(t, "RD", bank, pc, sid, row, ds, de)


def _pre(t, bank, pc=0):
    return CmdRecord(t, "PRE", bank, pc, -1, -1, -1.0, -1.0)


# ---------------------------------------------------------------------------
# Injected violations, HBM4
# ---------------------------------------------------------------------------

def test_shaved_trp_fires_exactly_once():
    """ACT re-opening a bank 1 ns before tRP elapses: one tRP hit."""
    pre_t = T.tRAS + 4.0                     # > tRAS after ACT, > tRTP after RD
    trace = [
        _act(0.0, 0), _rd(T.tRCDRD, 0), _pre(pre_t, 0),
        _act(pre_t + T.tRP - 1.0, 0),
    ]
    rep = HBM4TraceChecker(refresh=False).check(trace)
    assert rep.counts == {"tRP": 1}, rep.summary()


def test_tfaw_fifth_act_in_window_fires_exactly_once():
    """5 ACTs to distinct banks in one PC inside tFAW: the 5th trips the
    rolling 4-ACT window once (pairwise tRRD spacing is respected)."""
    gap = T.tRRDS  # legal pairwise, 5 ACTs span 4*gap < tFAW
    assert 4 * gap < T.tFAW
    trace = [_act(i * gap, bank=i * 9) for i in range(5)]
    rep = HBM4TraceChecker(refresh=False).check(trace)
    assert rep.counts == {"tFAW": 1}, rep.summary()


def test_cross_sid_tccdr_gap_fires_exactly_once():
    """Back-to-back column bursts from different SIDs closer than tCCDR
    (but legal under tCCDS, and in different bank groups so tCCDL does
    not apply): one tCCDR hit."""
    g = HBM4TraceChecker(refresh=False)
    b0, b1 = 0, g.g.banks_per_group          # distinct bank groups, same pc
    t0 = T.tRCDRD + 2.0
    shaved = T.tCCDR - 1.0
    assert shaved >= T.tCCDS
    trace = [
        _act(0.0, b0), _act(T.tRRDS, b1),
        _rd(t0, b0, sid=0, data=(t0 + T.tCL, t0 + T.tCL + 0.5)),
        _rd(t0 + shaved, b1, sid=1,
            data=(t0 + shaved + T.tCL, t0 + shaved + T.tCL + 0.5)),
    ]
    rep = g.check(trace)
    assert rep.counts == {"tCCDR": 1}, rep.summary()


def test_overdue_refresh_fires_exactly_once():
    """A trace spanning many tREFIpb periods with zero REF commands:
    end-of-trace refresh debt past the postponement bound, flagged once."""
    checker = HBM4TraceChecker(refresh=True, max_ref_postpone=8)
    t_end = 13.0 * checker.ref_period        # debt 13 > bound 10
    trace = [_act(0.0, 0), _rd(T.tRCDRD, 0),
             _rd(t_end, 0, data=(t_end + T.tCL, t_end + T.tCL + 1.0))]
    rep = checker.check(trace)
    assert rep.counts == {"ref-postpone": 1}, rep.summary()


def test_dq_overlap_and_row_state_detected():
    """Two reads whose data windows overlap on one PC's bus, plus a read
    to a row that is not the open one."""
    t0 = T.tRCDRD + 1.0
    trace = [
        _act(0.0, 0, row=1),
        _rd(t0, 0, row=1, data=(t0 + T.tCL, t0 + T.tCL + 4.0)),
        _rd(t0 + T.tCCDL, 0, row=2,          # wrong row AND overlapping DQ
            data=(t0 + T.tCCDL + T.tCL, t0 + T.tCCDL + T.tCL + 4.0)),
    ]
    rep = HBM4TraceChecker(refresh=False).check(trace)
    assert rep.counts == {"row-state": 1, "dq-overlap": 1}, rep.summary()


# ---------------------------------------------------------------------------
# Injected violations, RoMe
# ---------------------------------------------------------------------------

def _row(t, vba, op="RD_row", sid=0):
    svc = RT.tWR_row if op == "WR_row" else RT.tRD_row
    return CmdRecord(t, op, vba, 0, sid, 0, t + svc - 10.0, t + svc)


def test_rome_cross_sid_gap_fires_exactly_once():
    """Two reads to different VBAs from different SIDs closer than
    tR2RR: one hit, named for the Table III parameter."""
    trace = [_row(0.0, 0, sid=0), _row(RT.tR2RR - 1.0, 1, sid=1)]
    rep = RoMeTraceChecker(refresh=False).check(trace)
    assert rep.counts == {"tR2RR": 1}, rep.summary()


def test_rome_same_vba_service_time_fires_exactly_once():
    """A second access to the same VBA before tRD_row elapses, with an
    intervener so the consecutive-pair rule alone would miss it. At the
    stock Table III point two legal pair gaps already exceed tRD_row, so
    the C/A gaps are scaled down to expose the VBA-busy rule on its own
    (defense in depth against a policy that pipelines the C/A path but
    forgets a VBA's service occupancy)."""
    t = dataclasses.replace(RT, tR2RS=10.0)
    trace = [_row(0.0, 0), _row(12.0, 1), _row(24.0, 0)]
    assert 24.0 < t.tRD_row
    rep = RoMeTraceChecker(t, refresh=False).check(trace)
    assert rep.counts == {"tRD_row": 1}, rep.summary()


def test_rome_ref_concurrency_cap_fires_exactly_once():
    """Four refresh windows forced into flight at once: the MC has
    max_concurrent_refreshing() = 3 refresh FSMs, so the 4th REF start
    is flagged (C/A spacing of 2*tRREFpb is kept, so nothing else is)."""
    checker = RoMeTraceChecker(refresh=False)
    assert checker.ref_cap == 3
    step = 2 * RT.tRREFpb
    trace = [CmdRecord(i * step, "REF", i, 0, -1, -1, -1.0, -1.0)
             for i in range(4)]
    rep = checker.check(trace)
    assert rep.counts == {"ref-concurrency": 1}, rep.summary()


# ---------------------------------------------------------------------------
# Clean traces stay clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("label,kind,kwargs,txns", [
    pytest.param(*t, id=t[0]) for t in facade_trace_suite()[:6]])
def test_facade_traces_replay_clean(label, kind, kwargs, txns):
    sim = make_channel_sim(kind, emit_trace=True, **kwargs)
    rep = check_sim_result(sim, sim.run(txns), label)
    assert rep.ok, rep.summary()
    assert rep.n_commands > 0


def test_policy_conformance_reduced_is_clean():
    res = policy_conformance("rome_qd2", reduced=True)
    assert res["clean"], res
    assert res["n_commands"] > 0


def test_conformance_report_shape():
    rep = conformance_report(policies=["hbm4_frfcfs"], reduced=True)
    assert rep["n_policies"] == 1 and rep["clean"], rep


# ---------------------------------------------------------------------------
# SystemSim sanitizer mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg_fn", [hbm4_config, rome_config])
def test_system_sim_check_timing_clean(cfg_fn):
    sim = SystemSim(cfg_fn(), n_channels=2, check_timing=True)
    res = sim.run_extents(bulk_stream_extents(1 << 18, 8))
    assert res.total_ns > 0
    for r in res.channel_results.values():
        assert r.trace is not None and len(r.trace) > 0


def test_system_sim_sanitizer_raises_on_tampered_trace():
    """A shaved PRE->ACT gap smuggled into a channel result must surface
    as TimingProtocolError with the offending rule in the report."""
    sim = SystemSim(hbm4_config(), n_channels=2, check_timing=True)
    res = sim.run_extents(bulk_stream_extents(1 << 16, 4))
    c, r = next(iter(res.channel_results.items()))
    pre_t = T.tRAS + 4.0
    r.trace.extend([
        _act(1e9, 0), _rd(1e9 + T.tRCDRD, 0), _pre(1e9 + pre_t, 0),
        _act(1e9 + pre_t + T.tRP - 1.0, 0),
    ])
    with pytest.raises(TimingProtocolError) as exc:
        sim._sanitize(res.channel_results)
    assert "tRP" in exc.value.report.counts


def test_check_sim_result_requires_trace():
    label, kind, kwargs, txns = facade_trace_suite()[0]
    sim = make_channel_sim(kind, **kwargs)      # emission off
    with pytest.raises(ValueError, match="emit_trace"):
        check_sim_result(sim, sim.run(txns))


def test_checker_for_sim_picks_family():
    assert isinstance(checker_for_sim(make_channel_sim("hbm4")),
                      HBM4TraceChecker)
    assert isinstance(checker_for_sim(make_channel_sim("rome")),
                      RoMeTraceChecker)
