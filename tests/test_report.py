"""Roofline report rendering + dryrun record schema."""
import json
import os

import pytest

from repro.launch.report import notes, one_liner, render

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


@pytest.fixture(scope="module")
def results():
    if not os.path.exists(RESULTS):
        pytest.skip("dry-run results not present")
    with open(RESULTS) as f:
        return json.load(f)


def test_all_cells_recorded(results):
    # 10 archs x 4 shapes x 2 meshes
    assert len(results) == 80
    assert all(v["status"] in ("OK", "SKIP") for v in results.values())


def test_ok_cells_have_roofline(results):
    for k, v in results.items():
        if v["status"] != "OK":
            continue
        rf = v["roofline"]
        assert rf["t_memory_ms"] > 0
        assert rf["bottleneck"] in ("compute", "memory", "collective")
        assert 0 < rf["roofline_fraction"] <= 1.0
        assert v["mem_per_chip_gb"] > 0


def test_skips_are_exactly_the_declared_long_context_cells(results):
    skips = {k for k, v in results.items() if v["status"] == "SKIP"}
    long_attn_archs = {"qwen2-7b", "minitron-8b", "qwen3-14b",
                       "llama-3.2-vision-90b", "whisper-small",
                       "granite-moe-3b-a800m", "phi3.5-moe-42b-a6.6b"}
    expect = {f"{a}|long_500k|{m}" for a in long_attn_archs
              for m in ("single", "multi")}
    assert skips == expect


def test_render_and_notes(results):
    table = render(results)
    assert table.count("\n") >= 80
    assert "| bound |" in table.splitlines()[0]
    n = notes(results)
    assert "memory-bound" in n or "compute-bound" in n


def test_multi_pod_cells_use_512_chips(results):
    for k, v in results.items():
        if v["status"] == "OK" and v["mesh"] == "multi":
            assert v["n_chips"] == 512
        if v["status"] == "OK" and v["mesh"] == "single":
            assert v["n_chips"] == 256
