"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode.kernel import flash_decode, pick_block_s
from repro.kernels.flash_decode.ref import flash_decode_ref
from repro.kernels.rowstream_matmul.kernel import pick_bk, rowstream_matmul
from repro.kernels.rowstream_matmul.ref import rowstream_matmul_ref
from repro.kernels.rwkv_scan.kernel import pick_chunk, rwkv_scan
from repro.kernels.rwkv_scan.ref import rwkv_scan_ref

KEY = jax.random.PRNGKey(7)


# --- rowstream matmul --------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (64, 512, 256),
                                   (256, 1024, 128), (8, 256, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rowstream_matmul(m, k, n, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (m, k), dtype)
    w = jax.random.normal(k2, (k, n), dtype)
    out = rowstream_matmul(x, w)
    ref = rowstream_matmul_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 8)


def test_pick_bk_row_aligned():
    for k, n, isz in ((4096, 1024, 2), (2048, 512, 2), (8192, 4096, 4)):
        bk = pick_bk(k, n, isz)
        assert bk % 128 == 0
        assert k % bk == 0
        assert (bk * n * isz) % 4096 == 0   # whole DRAM rows


# --- flash decode ------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,s,d", [(2, 8, 2, 128, 64),
                                         (1, 4, 4, 256, 64),
                                         (3, 16, 4, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(b, h, hkv, s, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    vc = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    pos = jnp.array(s // 2, jnp.int32)
    out = flash_decode(q, kc, vc, pos)
    ref = flash_decode_ref(q, kc, vc, pos)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_decode_masks_future():
    """Slots beyond pos are unwritten garbage and must not leak."""
    ks = jax.random.split(KEY, 3)
    b, h, hkv, s, d = 1, 4, 2, 64, 32
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, hkv, s, d))
    vc = jax.random.normal(ks[2], (b, hkv, s, d))
    pos = jnp.array(10, jnp.int32)
    out1 = flash_decode(q, kc, vc, pos)
    kc2 = kc.at[:, :, 11:].set(1e9)
    vc2 = vc.at[:, :, 11:].set(-1e9)
    out2 = flash_decode(q, kc2, vc2, pos)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6)


def test_pick_block_s_row_aligned():
    for s, d, isz in ((32768, 128, 2), (2048, 64, 2), (4096, 128, 4)):
        bs = pick_block_s(s, d, isz)
        assert s % bs == 0
        assert (bs * d * isz) % 4096 == 0


# --- rwkv scan ---------------------------------------------------------------

@pytest.mark.parametrize("b,s,H,hd,chunk", [(2, 64, 3, 16, 16),
                                            (1, 128, 2, 32, 32),
                                            (2, 48, 4, 16, 8)])
def test_rwkv_scan(b, s, H, hd, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (b, s, H, hd))
    k = jax.random.normal(ks[1], (b, s, H, hd))
    v = jax.random.normal(ks[2], (b, s, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, H, hd))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    o, S = rwkv_scan(r, k, v, w, u, chunk=chunk)
    o_ref, S_ref = rwkv_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref),
                               rtol=1e-3, atol=1e-3)


def test_rwkv_scan_extreme_decay_stable():
    """Near-zero decays (log w = -inf-ish) must not produce NaN/Inf — the
    log-space masking guarantees exponent differences <= 0."""
    ks = jax.random.split(KEY, 5)
    b, s, H, hd = 1, 32, 2, 16
    r = jax.random.normal(ks[0], (b, s, H, hd))
    k = jax.random.normal(ks[1], (b, s, H, hd))
    v = jax.random.normal(ks[2], (b, s, H, hd))
    w = jnp.where(jax.random.bernoulli(ks[3], 0.4, (b, s, H, hd)),
                  1e-35, 0.9)
    u = jnp.zeros((H, hd))
    o, S = rwkv_scan(r, k, v, w, u, chunk=8)
    o_ref, S_ref = rwkv_scan_ref(r, k, v, w, u)
    assert bool(jnp.isfinite(o).all()) and bool(jnp.isfinite(S).all())
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-3, atol=2e-3)


def test_pick_chunk_row_aligned():
    for s, hd in ((4096, 64), (1024, 128), (512, 64)):
        c = pick_chunk(s, hd, 4)
        assert s % c == 0
        assert (c * hd * 4) % 4096 == 0
