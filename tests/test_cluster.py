"""Fleet-scale ClusterSim: router conservation, KV page accounting across
replicas, rejection semantics, and seeded bit-reproducibility.

The conservation properties are the ones a fleet simulator can silently
break while every single-replica test stays green: a request routed
twice, a rejected request double-counted, or replica-level page
reservations drifting from the recorder commitments they summarize.
"""
import numpy as np
import pytest

from _proptest import given, settings, strategies as st
from repro.serve.cluster import (REJECTED, UNROUTED, ROUTERS, ClusterSim,
                                 Router, make_router)

FAST = dict(n_requests=20, rate_rps=2e5, scale=2 ** -12, sim_mode="hybrid",
            n_channels=4, length_scale=1 / 32)


def _run(router="round_robin", n_replicas=3, **kw):
    params = dict(FAST, n_replicas=n_replicas, router=router, kind="poisson",
                  seed=0)
    params.update(kw)
    cs = ClusterSim(**params)
    return cs, cs.run()


# ---------------------------------------------------------------------------
# Router conservation: every issued request is placed exactly once
# ---------------------------------------------------------------------------

@settings(max_examples=12)
@given(seed=st.integers(0, 1 << 16),
       router=st.sampled_from(sorted(ROUTERS)),
       kind=st.sampled_from(["poisson", "bursty", "closed"]))
def test_router_conservation(seed, router, kind):
    kw = {"n_users": 5, "think_ns": 1e4} if kind == "closed" else {}
    cs, r = _run(router=router, kind=kind, seed=seed, **kw)
    issued = r.arrival_ns >= 0
    # Open-loop kinds issue every request; closed loops may stop short
    # only if rejections burned the rid budget (none here: no SLO).
    assert r.issued == cs.arrivals.n_requests
    # Placed exactly once: every issued rid carries either one replica
    # index or the rejected sentinel — never UNROUTED, never both.
    placed = issued & (r.replica_of >= 0)
    rejected = issued & (r.replica_of == REJECTED)
    assert not (issued & (r.replica_of == UNROUTED)).any()
    assert (placed | rejected).sum() == r.issued
    # Per-replica placement counts sum back to the fleet total.
    counts = np.bincount(r.replica_of[placed],
                         minlength=len(cs.replicas))
    assert np.array_equal(counts, r.requests_per_replica)
    assert counts.sum() + rejected.sum() == r.issued
    # Every placed request ran to completion (no SLO rejection here, and
    # the loop only terminates drained); rejected ones never produced
    # tokens.
    assert (r.completed_ns[placed] >= 0).all()
    assert (r.n_out[rejected] == 0).all()
    assert (r.first_token_ns[rejected] < 0).all()


# ---------------------------------------------------------------------------
# KV page accounting: replica reservations == fleet-wide live demand
# ---------------------------------------------------------------------------

class _AuditingRouter(Router):
    """least_kv placement + a fleet-wide page-conservation audit at every
    routing decision (the instant replica state is consulted)."""

    def __init__(self):
        self.inner = make_router("least_kv")
        self.audits = 0

    def place(self, spec, replicas, now_ns):
        fleet_outstanding = 0
        for rep in replicas:
            rec = rep.rec
            # Replica-level reservation is internally consistent...
            assert rep.outstanding_pages == sum(rep._worst.values())
            # ...and decomposes exactly into recorder-committed pages
            # (admitted, live) plus the worst case of requests still
            # waiting in the routed queue or the batcher queue.
            committed = sum(rec._worst_pages.values())
            assert rec._committed_pages == committed
            waiting = sum(
                rec.cache.pages_for(s.prompt_len + s.max_new_tokens)
                for s in rep.queue._q[rep.queue._next:])
            waiting += sum(
                rec.cache.pages_for(q.prompt_len + q.max_new_tokens)
                for q in rec.batcher.queue)
            assert rep.outstanding_pages == committed + waiting, (
                rep.index, rep.outstanding_pages, committed, waiting)
            # Committed pages never overrun the replica's pool.
            assert committed <= rec.cache.n_pages
            fleet_outstanding += rep.outstanding_pages
        self.fleet_outstanding = fleet_outstanding
        self.audits += 1
        return self.inner.place(spec, replicas, now_ns)


def test_kv_page_accounting_sums_to_fleet_total():
    router = _AuditingRouter()
    cs, r = _run(router=router, kind="bursty", burst_size=6, seed=3)
    assert router.audits == r.issued
    # Drained fleet holds no reservations anywhere.
    for rep in cs.replicas:
        assert rep.outstanding_pages == 0
        assert rep.rec._committed_pages == 0
        assert not rep._worst
        assert not rep.rec._worst_pages


# ---------------------------------------------------------------------------
# Determinism
# ---------------------------------------------------------------------------

def test_seeded_sweep_bit_reproducible_same_workers():
    _, a = _run(router="least_kv", kind="bursty", burst_size=5, seed=11)
    _, b = _run(router="least_kv", kind="bursty", burst_size=5, seed=11)
    for f in ("arrival_ns", "admitted_ns", "first_token_ns", "completed_ns",
              "n_out", "replica_of"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert a.makespan_ns == b.makespan_ns
    assert a.steps_total == b.steps_total


def test_seeded_sweep_bit_reproducible_across_workers():
    """workers only parallelizes cycle-path channel sims, which are
    bit-identical to serial — so the worker count can never change a
    fleet result."""
    kw = dict(router="round_robin", kind="bursty", burst_size=5, seed=2,
              n_requests=8, scale=2 ** -15, n_channels=2,
              sim_mode="cycle")
    _, a = _run(workers=1, **kw)
    _, b = _run(workers=2, **kw)
    for f in ("arrival_ns", "admitted_ns", "first_token_ns", "completed_ns",
              "n_out", "replica_of"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert a.makespan_ns == b.makespan_ns


# ---------------------------------------------------------------------------
# Router semantics
# ---------------------------------------------------------------------------

def test_slo_rejection_semantics():
    """Overload + a tight TTFT deadline turns into admission rejections,
    not unbounded queueing — and the accounting stays conserved."""
    router = make_router("slo_aware", ttft_slo_ns=500.0)
    cs, r = _run(router=router, kind="bursty", burst_size=10,
                 rate_rps=5e5, n_requests=40, n_replicas=2, seed=0)
    assert r.rejected > 0
    assert r.completed + r.rejected == r.issued
    issued = r.arrival_ns >= 0
    assert (((r.replica_of == REJECTED) == (r.completed_ns < 0))
            [issued]).all()


def test_slo_rejection_closed_loop_terminates():
    """Closed-loop users whose requests are rejected still consume the
    rid budget (fast error + think time), so an over-tight SLO cannot
    deadlock the fleet loop."""
    router = make_router("slo_aware", ttft_slo_ns=0.0)
    cs, r = _run(router=router, kind="closed", n_users=4, think_ns=1e3,
                 n_requests=16, seed=5)
    assert r.issued == 16
    assert r.completed + r.rejected == 16


def test_session_affinity_is_sticky():
    router = make_router("session_affinity", n_sessions=8)
    _, r = _run(router=router, kind="poisson", n_requests=32, seed=9)
    placed = np.flatnonzero(r.replica_of >= 0)
    by_session = {}
    for rid in placed:
        by_session.setdefault(rid % 8, set()).add(int(r.replica_of[rid]))
    for session, reps in by_session.items():
        assert len(reps) == 1, (session, reps)


def test_round_robin_balances_counts():
    _, r = _run(router="round_robin", n_replicas=4, n_requests=32, seed=1)
    counts = r.requests_per_replica
    assert counts.max() - counts.min() <= 1, counts.tolist()


def test_more_replicas_shorter_makespan():
    kw = dict(kind="bursty", burst_size=6, rate_rps=4e5, n_requests=24,
              seed=4)
    _, one = _run(n_replicas=1, **kw)
    _, four = _run(n_replicas=4, **kw)
    assert one.completed == four.completed == 24
    assert four.makespan_ns < one.makespan_ns


# ---------------------------------------------------------------------------
# Pricer integration
# ---------------------------------------------------------------------------

def test_pricer_stats_stamped_in_result():
    _, r = _run(seed=6)
    st_ = r.pricer_stats
    assert st_["hits"] + st_["misses"] == r.steps_total
    assert 0.0 <= st_["hit_rate"] <= 1.0
    _, bare = _run(seed=6, attach_pricer=False)
    assert bare.pricer_stats == {}
    # The signature cache changes wall-clock, never results.
    assert bare.makespan_ns == r.makespan_ns
    assert bare.steps_total == r.steps_total


def test_unknown_router_rejected():
    with pytest.raises(ValueError, match="unknown router"):
        make_router("nope")
