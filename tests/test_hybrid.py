"""Hybrid fast-path SystemSim: calibrated queue-window model, pressure
classification, vectorized lockstep engine, and the unscaled replay path.

The two contracts under test (benchmarks/hybrid_xval.py cross-validates
the same claims at full size):

* analytically-priced steps sit within the declared band
  (``HYBRID_BAND``) of the cycle engine; cycle-routed steps are the
  cycle engine — exactly;
* the vectorized lockstep driver is bit-identical to the scalar event
  loop on every facade trace.
"""
import numpy as np
import pytest

from _proptest import given, settings, strategies as st
from repro.core.queue_model import (HYBRID_BAND, QueueWindowParams,
                                    queue_window_params, stream_features,
                                    stressor_streams)
from repro.core.sched import facade_trace_suite, make_channel_sim, run_channels
from repro.core.sched.registry import policy_names, policy_spec
from repro.core.system_sim import SystemSim, hybrid_fraction
from repro.core.timing import hbm4_config, rome_config
from repro.workloads import (bulk_stream, interleave, sparse_stream,
                             strided_stream)

N_CHANNELS = 2


def _cfg_of(spec):
    return hbm4_config() if spec.family == "hbm4" else rome_config()


def _random_mixed_stream(cfg, rng):
    """A randomized decode-step-shaped mix (bulk slice + row-scale
    strides + sparse sub-row gather + optional write tail), small enough
    that the cycle reference stays fast."""
    row = cfg.row_bytes
    parts = [
        bulk_stream(int(rng.integers(8, 48)) * row,
                    n_extents=int(rng.integers(1, 4))),
        strided_stream(int(rng.integers(4, 16)),
                       int(rng.integers(1, 3)) * row,
                       4 * row, base_addr=1 << 21).retagged(1),
    ]
    if rng.integers(2):
        parts.append(sparse_stream(int(rng.integers(8, 32)),
                                   max(64, row // 8), 1 << 22,
                                   seed=int(rng.integers(1 << 20)),
                                   stream_id=2))
    if rng.integers(2):
        parts.append(bulk_stream(int(rng.integers(1, 6)) * row,
                                 kind="write",
                                 base_addr=1 << 24).retagged(3))
    return interleave(parts)


# ---------------------------------------------------------------------------
# Vectorized engine: bit-identity
# ---------------------------------------------------------------------------

def test_vectorized_bit_identical_on_facade_suite():
    """Every facade trace: the lockstep driver must reproduce the scalar
    event loop exactly — same finish times, makespan, byte count, and
    command census. (Identity by construction: both drive the same
    suspended ChannelRunState machine.)"""
    for label, kind, kwargs, txns in facade_trace_suite():
        scalar = make_channel_sim(kind, **kwargs).run(txns)
        vec, = run_channels(kind, kwargs, [txns])
        assert np.array_equal(scalar.finish_ns, vec.finish_ns), label
        assert scalar.total_ns == vec.total_ns, label
        assert scalar.bytes_moved == vec.bytes_moved, label
        assert scalar.cmd_counts == vec.cmd_counts, label


def test_vectorized_trace_identical_on_facade_suite():
    """With emission on, scalar and lockstep runs must produce the SAME
    command stream — every ACT/RD/WR/PRE/REF with its bank, SID, row,
    timestamp and data window, not just aggregate counts."""
    for label, kind, kwargs, txns in facade_trace_suite():
        kwargs = dict(kwargs, emit_trace=True)
        scalar = make_channel_sim(kind, **kwargs).run(txns)
        vec, = run_channels(kind, kwargs, [txns])
        assert scalar.trace is not None and len(scalar.trace) > 0, label
        assert scalar.trace == vec.trace, label


def test_trace_emission_off_by_default():
    """emit_trace=False (the default) must leave SimResult.trace None —
    the hook is zero-cost when off and nothing downstream can rely on a
    trace it didn't ask for."""
    label, kind, kwargs, txns = facade_trace_suite()[0]
    assert make_channel_sim(kind, **kwargs).run(txns).trace is None


def test_vectorized_multi_channel_matches_per_channel_runs():
    """Several channels advancing together in one lockstep batch must
    equal independent scalar runs of each channel's queue."""
    suite = [t for t in facade_trace_suite() if t[1] == "hbm4"][:3]
    kwargs = suite[0][2]
    queues = [txns for _, _, kw, txns in suite if kw == kwargs]
    results = run_channels("hbm4", kwargs, queues, batch=7)
    for txns, vec in zip(queues, results):
        scalar = make_channel_sim("hbm4", **kwargs).run(txns)
        assert np.array_equal(scalar.finish_ns, vec.finish_ns)
        assert scalar.cmd_counts == vec.cmd_counts


# ---------------------------------------------------------------------------
# Hybrid band: every registered policy, randomized mixed streams
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", policy_names())
@given(seed=st.integers(0, 10 ** 6))
@settings(max_examples=3, deadline=None)
def test_hybrid_within_band_of_cycle(policy, seed):
    """Hybrid pricing vs the cycle engine on randomized mixed streams:
    analytically-classified runs must land within the declared band;
    cycle-routed runs must be *exactly* the cycle engine's answer."""
    spec = policy_spec(policy)
    cfg = _cfg_of(spec)
    rng = np.random.default_rng(seed)
    stream = _random_mixed_stream(cfg, rng)
    ref = spec.system_sim(n_channels=N_CHANNELS, mode="cycle").run(stream)
    res = spec.system_sim(n_channels=N_CHANNELS, mode="hybrid").run(stream)
    rel = abs(res.total_ns - ref.total_ns) / ref.total_ns
    if res.mode == "analytic":
        assert rel < HYBRID_BAND, (policy, seed, ref.total_ns, res.total_ns)
        # Byte accounting must match the cycle engine exactly in every
        # mode — both price whole stripe units.
        assert res.bytes_moved == ref.bytes_moved
    else:
        assert res.mode == "cycle"
        assert rel == 0.0, (policy, seed, rel)


def test_hybrid_band_on_stressor_suite_flagships():
    """The calibration stressors themselves, end to end through the
    hybrid classifier, for the two serve-replay flagship policies."""
    for policy in ("hbm4_frfcfs", "rome_qd2"):
        spec = policy_spec(policy)
        cfg = _cfg_of(spec)
        cyc = spec.system_sim(n_channels=N_CHANNELS, mode="cycle")
        hyb = spec.system_sim(n_channels=N_CHANNELS, mode="hybrid")
        n_analytic = 0
        for label, stream in stressor_streams(cfg):
            ref = cyc.run(stream)
            res = hyb.run(stream)
            rel = abs(res.total_ns - ref.total_ns) / ref.total_ns
            if res.mode == "analytic":
                n_analytic += 1
                assert rel < HYBRID_BAND, (policy, label, rel)
            else:
                assert rel == 0.0, (policy, label, rel)
        # The flagships must actually exercise the analytic path.
        assert n_analytic > 0, policy


# ---------------------------------------------------------------------------
# Classification & mode plumbing
# ---------------------------------------------------------------------------

def test_txn_guard_forces_analytic_pricing():
    """A stream whose decomposed transaction count exceeds
    ``max_cycle_txns`` must be priced analytically even when contended —
    the guard that makes unscaled traces runnable."""
    spec = policy_spec("hbm4_frfcfs")
    sim = spec.system_sim(n_channels=N_CHANNELS, mode="hybrid",
                          max_cycle_txns=10)
    res = sim.run(bulk_stream(1 << 16))
    assert res.mode == "analytic"


def test_explicit_threshold_overrides_calibrated_cut():
    """``pressure_threshold=0.0`` must route every nonzero-pressure run
    to the cycle engine regardless of the calibrated table."""
    spec = policy_spec("rome_qd2")
    sim = spec.system_sim(n_channels=N_CHANNELS, mode="hybrid",
                          pressure_threshold=0.0)
    res = sim.run(bulk_stream(1 << 20))
    assert res.mode == "cycle"
    assert res.queue_pressure > 0.0


def test_calibrated_threshold_is_loaded_from_table():
    """Every registered policy resolves a calibrated threshold in
    (0, DEFAULT]; the persisted table is the source."""
    from repro.core.queue_model import DEFAULT_PRESSURE_THRESHOLD
    for name in policy_names():
        p = queue_window_params(name)
        assert isinstance(p, QueueWindowParams)
        assert 0.0 < p.pressure_threshold <= DEFAULT_PRESSURE_THRESHOLD, name


def test_run_steps_mixed_modes_and_hybrid_fraction():
    """run_steps classifies per step independently: a bulk step prices
    analytic while a fine-thrash step drops to cycle, and
    ``hybrid_fraction`` reports the split."""
    spec = policy_spec("rome_qd2")
    cfg = rome_config()
    row = cfg.row_bytes
    sim = spec.system_sim(n_channels=N_CHANNELS, mode="hybrid")
    streams = [bulk_stream(64 * row),
               strided_stream(128, max(64, row // 16), row,
                              base_addr=1 << 22)]
    results = sim.run_steps(streams)
    modes = [r.mode for r in results]
    assert modes == ["analytic", "cycle"], modes
    assert hybrid_fraction(results) == 0.5


def test_analytic_features_match_cycle_byte_accounting():
    """The O(n_records) census prices exactly the bytes the cycle engine
    moves (whole stripe units, overfetch included)."""
    spec = policy_spec("rome_qd2")
    cfg = rome_config()
    sim = spec.system_sim(n_channels=N_CHANNELS)
    stream = interleave([
        bulk_stream(10 * cfg.row_bytes),
        sparse_stream(16, 256, 1 << 22, seed=5, stream_id=1)])
    feats = stream_features(stream, cfg, sim.amap)
    ref = sim.run(stream)
    assert int(feats["mc_channel_bytes"].sum()) == ref.bytes_moved


# ---------------------------------------------------------------------------
# Persistent pool + step-pricing cache
# ---------------------------------------------------------------------------

def test_persistent_pool_is_reused_and_parallel_bit_identical():
    """get_pool hands back one engine-lifetime pool (no per-call spawn
    churn), and parallel channel sims are bit-identical to serial —
    channels share no state, so the split cannot change results."""
    from repro.core.pool import get_pool, pool_workers

    pool = get_pool(2)
    assert get_pool(2) is pool
    assert get_pool(1) is pool          # smaller ask reuses the pool
    assert pool_workers() >= 2

    spec = policy_spec("rome_qd2")
    cfg = rome_config()
    rng = np.random.default_rng(0)
    sim = spec.system_sim(n_channels=N_CHANNELS, mode="cycle")
    stream = _random_mixed_stream(cfg, rng)
    serial = sim.run(stream, workers=1)
    parallel = sim.run(stream, workers=2)
    assert get_pool(2) is pool          # still the same pool afterwards
    assert parallel.total_ns == serial.total_ns
    assert parallel.bytes_moved == serial.bytes_moved
    assert np.array_equal(parallel.channel_finish_ns,
                          serial.channel_finish_ns)
    # Batched steps through the same pool, same contract.
    streams = [_random_mixed_stream(cfg, rng) for _ in range(3)]
    s1 = sim.run_steps(streams, workers=1)
    s2 = sim.run_steps(streams, workers=2)
    for a, b in zip(s1, s2):
        assert a.total_ns == b.total_ns
        assert a.bytes_moved == b.bytes_moved


def test_step_pricer_cache_hits_are_exact():
    """A signature hit returns features priced identically to a fresh
    computation: the signature (kind, relative arrival, stripe offset,
    channel, size) determines every census input, so caching is exact,
    not approximate."""
    from repro.core.queue_model import StepPricer, queue_window_params

    spec = policy_spec("rome_qd2")
    cfg = rome_config()
    sim = spec.system_sim(n_channels=N_CHANNELS, mode="hybrid")
    rng = np.random.default_rng(1)
    stream = _random_mixed_stream(cfg, rng)
    # A shifted copy has a different identity and absolute arrivals but
    # the same signature — the cache must hit and the hit must price
    # identically to computing from scratch.
    shifted = stream.shifted(12_345.0)
    pricer = StepPricer(cfg, sim.amap, queue_window_params("rome_qd2"),
                        recheck_every=1)
    assert pricer.signature(stream) == pricer.signature(shifted)
    a = pricer.features(stream)
    assert pricer.stats["misses"] == 1
    b = pricer.features(shifted)          # hit + forced recheck
    assert pricer.stats["hits"] == 1
    assert pricer.stats["rechecks"] == 1  # recheck passed (no raise)
    for key in ("base_ns", "txns_gating", "ext_gating", "total_txns"):
        assert a[key] == b[key], key
    fresh = stream_features(stream, cfg, sim.amap)
    assert a["base_ns"] == fresh["base_ns"]
    assert np.array_equal(a["mc_channel_bytes"], fresh["mc_channel_bytes"])


def test_attached_pricer_does_not_change_run_steps_results():
    spec = policy_spec("rome_qd2")
    cfg = rome_config()
    rng = np.random.default_rng(2)
    streams = [_random_mixed_stream(cfg, rng) for _ in range(4)]
    plain = spec.system_sim(n_channels=N_CHANNELS, mode="hybrid")
    cached = spec.system_sim(n_channels=N_CHANNELS, mode="hybrid")
    cached.attach_pricer(recheck_every=3)
    r1 = plain.run_steps(streams)
    r2 = cached.run_steps(streams)
    # Second pass over shifted copies of the same steps: every lookup
    # hits the signature cache, and results stay identical.
    r3 = cached.run_steps([s.shifted(999.0) for s in streams])
    for a, b, c in zip(r1, r2, r3):
        assert a.total_ns == b.total_ns == c.total_ns
        assert a.mode == b.mode == c.mode
    assert cached.pricer.stats["hits"] >= len(streams)
