"""Command generator (Figs 9 & 10) — structural + property tests."""
import math

import pytest
from _proptest import given, settings, strategies as st

from repro.core import CommandGenerator, HBM4Timing, RoMeTiming
from repro.core.command_generator import (command_issue_latency_ns,
                                          extra_channels, min_ca_pins,
                                          min_required_interval_ns)


@pytest.fixture(scope="module")
def cg():
    return CommandGenerator()


def test_schedule_structure(cg):
    for is_write in (False, True):
        sch = cg.expand(is_write)
        ops = [c.op for c in sch.commands]
        assert ops.count("ACT") == 2
        assert ops.count("PRE") == 2
        assert ops.count("WR" if is_write else "RD") == 64


def test_acts_staggered_trrds(cg):
    t = HBM4Timing()
    sch = cg.expand(False)
    acts = [c for c in sch.commands if c.op == "ACT"]
    assert acts[1].t_ns - acts[0].t_ns == pytest.approx(t.tRRDS)
    # the intentional (tRRDS - tCCDS) lead delay (Fig 9)
    assert acts[0].t_ns == pytest.approx(t.tRRDS - t.tCCDS)


def test_bursts_perfectly_interleaved(cg):
    t = HBM4Timing()
    sch = cg.expand(False)
    bursts = [c for c in sch.commands if c.op == "RD"]
    for b1, b2 in zip(bursts, bursts[1:]):
        assert b2.t_ns - b1.t_ns == pytest.approx(t.tCCDS)
        assert b2.bank != b1.bank


def test_trcd_respected(cg):
    t = HBM4Timing()
    for is_write in (False, True):
        sch = cg.expand(is_write)
        act_t = {c.bank: c.t_ns for c in sch.commands if c.op == "ACT"}
        trcd = t.tRCDWR if is_write else t.tRCDRD
        for c in sch.commands:
            if c.op in ("RD", "WR"):
                assert c.t_ns >= act_t[c.bank] + trcd - 1e-9


def test_tras_respected(cg):
    t = HBM4Timing()
    sch = cg.expand(False)
    act_t = {c.bank: c.t_ns for c in sch.commands if c.op == "ACT"}
    for c in sch.commands:
        if c.op == "PRE":
            assert c.t_ns >= act_t[c.bank] + t.tRAS - 1e-9


def test_derived_row_timings_match_table_v(cg):
    tv = RoMeTiming()
    # Derived same-VBA delays land within a few ns of Table V (JEDEC
    # pre-final; the paper adopts values from prior studies).
    assert cg.derived_tRD_row() == pytest.approx(tv.tRD_row, abs=6.0)
    assert cg.derived_tWR_row() == pytest.approx(tv.tWR_row, abs=6.0)
    assert cg.derived_tR2RS() == pytest.approx(tv.tR2RS, abs=1e-9)


def test_refresh_pairing(cg):
    t = HBM4Timing()
    refs = cg.expand_refresh()
    assert [r.op for r in refs] == ["REFpb", "REFpb"]
    assert refs[1].t_ns - refs[0].t_ns == pytest.approx(t.tRREFpb)
    assert cg.refresh_stall_ns() < cg.naive_refresh_stall_ns()


# --- C/A pins (Fig 10) ------------------------------------------------------

def test_five_pins_suffice():
    assert min_ca_pins() == 5
    lim = min_required_interval_ns()
    assert command_issue_latency_ns(5) < lim <= command_issue_latency_ns(4)


def test_extra_channels_budget():
    n, extra = extra_channels()
    assert (n, extra) == (4, 12)


@given(pins=st.integers(min_value=1, max_value=18))
def test_issue_latency_monotone(pins):
    """More pins never make command issue slower."""
    if pins < 18:
        assert command_issue_latency_ns(pins) >= \
            command_issue_latency_ns(pins + 1)


@given(bits=st.integers(min_value=1, max_value=128),
       pins=st.integers(min_value=1, max_value=32))
def test_issue_latency_exact(bits, pins):
    assert command_issue_latency_ns(pins, command_bits=bits) == \
        math.ceil(bits / pins) * 0.5
