"""scripts/bench_compare.py: tolerance bands, status gating, injected
regressions, and baseline round-tripping."""
import importlib.util
import json
import pathlib

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
_spec = importlib.util.spec_from_file_location(
    "bench_compare", ROOT / "scripts" / "bench_compare.py")
bc = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bc)


def _payload(status="pass", bench_status="PASS", value=100.0):
    return {
        "status": status,
        "failures": 0 if status == "pass" else 1,
        "benchmarks": {
            "demo_bench": {
                "status": bench_status,
                "wall_s": 1.23,
                "results": {"metric_a": value,
                            "nested": {"metric_b": 7, "label": "text",
                                       "flag": True},
                            "wall_s": 9.9},
            }
        },
    }


def _write(tmp_path, name, obj):
    p = tmp_path / name
    p.write_text(json.dumps(obj))
    return str(p)


def _baseline_dir(tmp_path, value=100.0, rel_tol=0.05, tolerances=None):
    d = tmp_path / "baselines"
    d.mkdir(exist_ok=True)
    (d / "demo_bench.json").write_text(json.dumps({
        "benchmark": "demo_bench",
        "rel_tol": rel_tol,
        "tolerances": tolerances or {},
        "metrics": {"metric_a": value, "nested.metric_b": 7.0},
    }))
    return str(d)


# ---------------------------------------------------------------------------
# Metric flattening
# ---------------------------------------------------------------------------

def test_flatten_skips_wall_time_strings_and_bools():
    flat = bc.flatten_metrics(_payload()["benchmarks"]["demo_bench"]
                              ["results"])
    assert flat == {"metric_a": 100.0, "nested.metric_b": 7.0}


def test_flatten_walks_lists():
    flat = bc.flatten_metrics({"records": [{"x": 1}, {"x": 2}]})
    assert flat == {"records.0.x": 1.0, "records.1.x": 2.0}


# ---------------------------------------------------------------------------
# Gate verdicts
# ---------------------------------------------------------------------------

def test_gate_clean_within_tolerance(tmp_path, capsys):
    fresh = _write(tmp_path, "fresh.json", _payload(value=102.0))
    rc = bc.main([fresh, "--baseline-dir", _baseline_dir(tmp_path)])
    assert rc == 0
    assert "regression gate clean" in capsys.readouterr().out


def test_gate_fails_on_injected_regression(tmp_path, capsys):
    """The deliberate tolerance violation: +20% on a 5% band must fail
    and name the metric in the delta table."""
    fresh = _write(tmp_path, "fresh.json", _payload(value=120.0))
    rc = bc.main([fresh, "--baseline-dir", _baseline_dir(tmp_path)])
    assert rc == 1
    out = capsys.readouterr()
    assert "metric_a" in out.out
    assert "REGRESSION GATE FAILED" in out.err


def test_gate_respects_per_metric_tolerance_override(tmp_path):
    fresh = _write(tmp_path, "fresh.json", _payload(value=120.0))
    basedir = _baseline_dir(tmp_path, tolerances={"metric_a": 0.5})
    assert bc.main([fresh, "--baseline-dir", basedir]) == 0


def test_gate_fails_on_payload_status_fail(tmp_path):
    """A payload that says status!=pass fails the gate even when every
    baselined metric is within band — the masking bugfix."""
    fresh = _write(tmp_path, "fresh.json",
                   _payload(status="fail", value=100.0))
    assert bc.main([fresh, "--baseline-dir",
                    _baseline_dir(tmp_path)]) == 1


def test_gate_fails_on_benchmark_entry_failure(tmp_path):
    fresh = _write(tmp_path, "fresh.json",
                   _payload(bench_status="FAIL", value=100.0))
    assert bc.main([fresh, "--baseline-dir",
                    _baseline_dir(tmp_path)]) == 1


def test_gate_fails_on_missing_metric(tmp_path):
    payload = _payload()
    del payload["benchmarks"]["demo_bench"]["results"]["metric_a"]
    fresh = _write(tmp_path, "fresh.json", payload)
    assert bc.main([fresh, "--baseline-dir",
                    _baseline_dir(tmp_path)]) == 1


def test_gate_fails_when_nothing_was_compared(tmp_path, capsys):
    """A gate that compared zero metrics must fail, not pass vacuously —
    a benchmark rename or a ci.yml pattern typo would otherwise disable
    gating silently."""
    fresh = _write(tmp_path, "fresh.json", _payload())
    empty = tmp_path / "empty_baselines"
    empty.mkdir()
    assert bc.main([fresh, "--baseline-dir", str(empty)]) == 1
    out = capsys.readouterr()
    assert "no baseline" in out.out
    assert "no benchmark was compared" in out.err


def test_gate_fails_on_empty_benchmark_selection(tmp_path):
    fresh = _write(tmp_path, "fresh.json",
                   {"status": "pass", "failures": 0, "benchmarks": {}})
    assert bc.main([fresh, "--baseline-dir",
                    _baseline_dir(tmp_path)]) == 1


def test_gate_skips_unbaselined_when_others_compared(tmp_path, capsys):
    """Unbaselined benchmarks are informational as long as at least one
    benchmark was actually gated."""
    payload = _payload()
    payload["benchmarks"]["unbaselined_bench"] = {
        "status": "PASS", "wall_s": 0.1, "results": {"x": 1}}
    fresh = _write(tmp_path, "fresh.json", payload)
    assert bc.main([fresh, "--baseline-dir", _baseline_dir(tmp_path)]) == 0
    assert "no baseline for unbaselined_bench" in capsys.readouterr().out


def test_gate_rejects_unreadable_payload(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert bc.main([str(bad)]) == 2


# ---------------------------------------------------------------------------
# Summary + baseline round trip
# ---------------------------------------------------------------------------

def test_summary_file_gets_markdown_table(tmp_path):
    fresh = _write(tmp_path, "fresh.json", _payload(value=120.0))
    summary = tmp_path / "summary.md"
    rc = bc.main([fresh, "--baseline-dir", _baseline_dir(tmp_path),
                  "--summary", str(summary)])
    assert rc == 1
    text = summary.read_text()
    assert "Benchmark regression gate" in text
    assert "| demo_bench |" in text and "metric_a" in text


def test_write_baseline_round_trip(tmp_path):
    fresh = _write(tmp_path, "fresh.json", _payload(value=42.0))
    basedir = tmp_path / "gen_baselines"
    assert bc.main([fresh, "--baseline-dir", str(basedir),
                    "--write-baseline"]) == 0
    data = json.loads((basedir / "demo_bench.json").read_text())
    assert data["metrics"]["metric_a"] == 42.0
    assert "wall_s" not in data["metrics"]
    # The regenerated baseline must gate its own source payload clean.
    assert bc.main([fresh, "--baseline-dir", str(basedir)]) == 0


def test_write_baseline_preserves_tuned_tolerances(tmp_path):
    """Regenerating a baseline must keep hand-tuned per-metric tolerance
    overrides and the stored rel_tol, refreshing only the metrics."""
    basedir = tmp_path / "baselines"
    basedir.mkdir()
    (basedir / "demo_bench.json").write_text(json.dumps({
        "benchmark": "demo_bench", "rel_tol": 0.12,
        "tolerances": {"nested.*": 0.4},
        "metrics": {"metric_a": 1.0}}))
    fresh = _write(tmp_path, "fresh.json", _payload(value=55.0))
    assert bc.main([fresh, "--baseline-dir", str(basedir),
                    "--write-baseline"]) == 0
    data = json.loads((basedir / "demo_bench.json").read_text())
    assert data["metrics"]["metric_a"] == 55.0
    assert data["rel_tol"] == 0.12
    assert data["tolerances"] == {"nested.*": 0.4}


def test_write_baseline_refuses_failed_benchmarks(tmp_path, capsys):
    fresh = _write(tmp_path, "fresh.json", _payload(bench_status="FAIL"))
    basedir = tmp_path / "gen_baselines"
    assert bc.main([fresh, "--baseline-dir", str(basedir),
                    "--write-baseline"]) == 0
    assert not (basedir / "demo_bench.json").exists()
    assert "refusing" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# benchmarks.run: the explicit status field (masking bugfix)
# ---------------------------------------------------------------------------

class _PassingBench:
    @staticmethod
    def run():
        return {"value": 1}


class _BandFailure:
    @staticmethod
    def run():
        raise AssertionError("band violated")


class _DriverKiller:
    @staticmethod
    def run():
        raise KeyboardInterrupt  # escapes the per-benchmark handler


def _run_driver(monkeypatch, tmp_path, modules, argv_extra=()):
    import benchmarks.run as br
    monkeypatch.setattr(br, "ALL", modules)
    out = tmp_path / "bench.json"
    rc = br.main(["", "--json", str(out), *argv_extra])
    return rc, json.loads(out.read_text())


def test_run_json_status_pass(monkeypatch, tmp_path, capsys):
    rc, payload = _run_driver(monkeypatch, tmp_path,
                              [("ok", _PassingBench)])
    assert rc == 0
    assert payload["status"] == "pass" and payload["completed"]


def test_run_json_status_fail_on_band_failure(monkeypatch, tmp_path,
                                              capsys):
    """A band failure after the JSON dump used to be maskable by
    always() upload steps; now the payload itself says "fail" and
    bench_compare refuses it."""
    rc, payload = _run_driver(
        monkeypatch, tmp_path,
        [("ok", _PassingBench), ("bad", _BandFailure)])
    assert rc == 1
    assert payload["status"] == "fail" and payload["failures"] == 1
    fresh = tmp_path / "bench.json"
    assert bc.main([str(fresh), "--baseline-dir", str(tmp_path)]) == 1


def test_run_json_written_even_when_driver_dies(monkeypatch, tmp_path,
                                                capsys):
    """Even an exception that escapes the per-benchmark handler leaves
    a parseable payload whose status is "fail"."""
    import benchmarks.run as br
    monkeypatch.setattr(br, "ALL",
                        [("ok", _PassingBench), ("boom", _DriverKiller)])
    out = tmp_path / "bench.json"
    with pytest.raises(KeyboardInterrupt):
        br.main(["", "--json", str(out)])
    payload = json.loads(out.read_text())
    assert payload["status"] == "fail"
    assert payload["completed"] is False
    assert payload["benchmarks"]["ok"]["status"] == "PASS"
