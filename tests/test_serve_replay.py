"""repro.serve.replay: serving-trace recording + SystemSim replay.

Covers the serving->memory contract end to end: seeded arrival
processes, the byte/kind/stream-tag conservation property over
randomized serve runs, timeline folding, `SystemSim.run_steps`
equivalence, and the near-zero-load TPOT regression against the
analytic `perfmodel.tpot` path (the established 15 % engine_xval band).
"""
import numpy as np
import pytest
from _proptest import given, settings, strategies as st

from repro.configs.paper_workloads import ServingMix
from repro.serve.kv_cache import RowPagedKVCache
from repro.serve.replay import (ArrivalProcess, RequestSpec,
                                ServeTraceRecorder, build_replay,
                                make_kv_cache)


# --- arrival processes --------------------------------------------------------

def _proc(**kw):
    base = dict(kind="poisson", rate_rps=1e5, n_requests=8,
                mix="deepseek-v3", length_scale=1 / 32, seed=7)
    base.update(kw)
    return ArrivalProcess(**base)


def test_arrivals_deterministic_and_ordered():
    a, b = _proc(), _proc()
    sa = a.due(float("inf"))
    sb = b.due(float("inf"))
    assert sa == sb                      # same seed -> same sequence
    assert len(sa) == 8
    assert all(s.arrival_ns >= 0 for s in sa)
    arr = [s.arrival_ns for s in sa]
    assert arr == sorted(arr)
    assert [s.rid for s in sa] == list(range(8))
    assert all(s.prompt_len >= 1 and s.max_new_tokens >= 1 for s in sa)
    assert a.exhausted() and a.next_arrival_ns() is None


def test_arrivals_due_windowing():
    a = _proc()
    t1 = a.next_arrival_ns()
    first = a.due(t1)
    assert [s.rid for s in first] == [0]
    assert not a.exhausted()
    rest = a.due(float("inf"))
    assert [s.rid for s in rest] == list(range(1, 8))


def test_bursty_arrivals_batch():
    a = _proc(kind="bursty", burst_size=4)
    specs = a.due(float("inf"))
    assert len(specs) == 8
    times = [s.arrival_ns for s in specs]
    assert times[0] == times[1] == times[2] == times[3]
    assert times[4] == times[5] == times[6] == times[7]
    assert times[4] > times[0]


def test_closed_loop_arrivals():
    a = _proc(kind="closed", n_users=2, n_requests=5, think_ns=0.0)
    seed_specs = a.due(0.0)
    assert len(seed_specs) == 2          # one in-flight request per user
    assert not a.exhausted()
    a.on_complete(100.0)                 # user done -> next request queued
    nxt = a.due(100.0)
    assert len(nxt) == 1 and nxt[0].rid == 2
    a.on_complete(200.0)
    a.on_complete(300.0)
    assert len(a.due(1e9)) == 2          # rids 3, 4 — then the cap hits
    a.on_complete(400.0)
    assert a.exhausted()


def test_arrival_validation():
    with pytest.raises(ValueError):
        _proc(kind="uniform")
    with pytest.raises(ValueError):
        _proc(rate_rps=0.0)


# --- conservation property ----------------------------------------------------

def _drive_fixed_clock(recorder, dt_ns=100.0, max_steps=10_000):
    """Drive a recorder with a fixed per-step duration (no cycle sim) and
    return every recorded StepTrace."""
    traces, now = [], 0.0
    while not recorder.drained():
        recorder.submit_due(now)
        st = recorder.step(now)
        if st is None:
            nxt = recorder.arrivals.next_arrival_ns()
            if nxt is None:
                break
            now = max(now, nxt)
            continue
        traces.append(st)
        for rid in st.finished:
            recorder.arrivals.on_complete(now + dt_ns)
        now += dt_ns
        assert len(traces) < max_steps
    return traces


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(min_value=0, max_value=999))
def test_kv_conservation_over_random_serve_run(seed):
    """Byte/kind/stream-tag conservation: every admitted request's KV
    appends and reads appear exactly once across the recorded streams,
    with the byte counts the row-paged geometry dictates."""
    arrivals = ArrivalProcess("poisson", rate_rps=2e5, n_requests=6,
                              mix=ServingMix(prompt_median=24, prompt_cv=1.0,
                                             out_mean=6, prompt_max=96,
                                             out_max=24),
                              seed=seed)
    cache = make_kv_cache(n_slots=3, max_seq_tokens=120)
    rec = ServeTraceRecorder(arrivals, cache)
    traces = _drive_fixed_clock(rec)

    assert len(rec.batcher.completed) == 6          # everyone finished
    assert cache.utilization() == 0.0               # all pages returned
    pt, pb = cache.page_tokens, cache.page_bytes
    per_tok = pb // pt
    for rid, req in rec.requests.items():
        p, g = req.prompt_len, len(req.out_tokens)
        assert g == req.max_new_tokens
        recs = [r for tr in traces for r in tr.stream.of_stream(rid)]
        writes = [r for r in recs if r.is_write]
        reads = [r for r in recs if not r.is_write]
        # appends: one K + one V record per decoded token, exactly once
        assert len(writes) == 2 * g
        assert sum(r.nbytes for r in writes) == 2 * g * per_tok
        # reads: per decode step k the gather covers ceil((p+k)/pt) pages
        # in each of the K and V pools, whole pages only
        exp_read = sum(2 * (-(-(p + k) // pt)) * pb for k in range(g))
        assert sum(r.nbytes for r in reads) == exp_read
        assert all(r.nbytes == pb for r in reads)
        # the rid appears in exactly `g` step traces (its decode steps)
        steps_with = [tr for tr in traces if rid in tr.active]
        assert len(steps_with) == g
        for tr in steps_with:
            assert all(r.arrival_ns == tr.start_ns
                       for r in tr.stream.of_stream(rid))
    # weight/KV tagging never collides: negative ids are weights only
    for tr in traces:
        for r in tr.stream:
            if r.stream_id < 0:
                assert not r.is_write
            else:
                assert r.stream_id in rec.requests


def test_admission_respects_worst_case_pages():
    """A request is only admitted when prompt+max_new worst-case pages
    fit alongside every live request's reservation — no MemoryError can
    fire mid-decode."""
    arrivals = ArrivalProcess("bursty", rate_rps=1e6, n_requests=6,
                              burst_size=6,
                              mix=ServingMix(prompt_median=40, prompt_cv=0.2,
                                             out_mean=8, prompt_max=64,
                                             out_max=16),
                              seed=1)
    cache = make_kv_cache(n_slots=4, max_seq_tokens=80, headroom=0)
    rec = ServeTraceRecorder(arrivals, cache)
    max_live = 0
    now = 0.0
    while not rec.drained():
        rec.submit_due(now)
        st = rec.step(now)
        if st is None:
            nxt = rec.arrivals.next_arrival_ns()
            if nxt is None:
                break
            now = max(now, nxt)
            continue
        max_live = max(max_live, rec._committed_pages)
        assert rec._committed_pages <= cache.n_pages
        now += 50.0
    assert len(rec.batcher.completed) == 6
    assert max_live > 0


def test_same_iteration_admissions_cannot_overcommit():
    """Regression: two requests admitted in ONE schedule() call must not
    both pass admission against the same stale page count. Pool of 8
    pages, two simultaneous arrivals each reserving a worst case of 5 —
    they must run serially, and no MemoryError can fire mid-decode."""
    arrivals = ArrivalProcess("poisson", rate_rps=1.0, n_requests=2, seed=0)
    arrivals._pending = [RequestSpec(0, 0.0, 60, 16),
                         RequestSpec(1, 0.0, 60, 16)]
    cache = RowPagedKVCache(n_pages=8, page_tokens=16, n_kv_heads=2,
                            head_dim=64, max_seqs=2, max_pages_per_seq=5)
    assert cache.pages_for(60 + 16) == 5       # the reproducer's geometry
    rec = ServeTraceRecorder(arrivals, cache)
    traces = _drive_fixed_clock(rec)
    assert len(rec.batcher.completed) == 2
    assert rec._committed_pages == 0
    r0, r1 = rec.requests[0], rec.requests[1]
    # 5 + 5 > 8: the second request waits for the first to release
    assert r1.timeline.admitted_step > r0.timeline.completed_step
    assert all(len(tr.active) == 1 for tr in traces)


def test_oversized_request_rejected_eagerly():
    arrivals = ArrivalProcess("poisson", rate_rps=1e5, n_requests=1,
                              mix=ServingMix(prompt_median=4000,
                                             prompt_cv=0.0, out_mean=4,
                                             prompt_max=4000, out_max=8),
                              seed=0)
    cache = make_kv_cache(n_slots=2, max_seq_tokens=64)
    rec = ServeTraceRecorder(arrivals, cache)
    with pytest.raises(ValueError, match="pages"):
        rec.submit_due(float("inf"))


def test_per_seq_page_limit_rejected_eagerly():
    """A request whose worst case fits the pool but overflows one
    sequence's page-table row is rejected at submit, not mid-decode."""
    arrivals = ArrivalProcess("poisson", rate_rps=1e5, n_requests=1, seed=0)
    arrivals._pending = [RequestSpec(0, 0.0, 50, 30)]   # worst = 5 pages
    cache = RowPagedKVCache(n_pages=64, page_tokens=16, n_kv_heads=2,
                            head_dim=64, max_seqs=4, max_pages_per_seq=3)
    rec = ServeTraceRecorder(arrivals, cache)
    with pytest.raises(ValueError, match="max_pages_per_seq"):
        rec.submit_due(float("inf"))


# --- replay engine ------------------------------------------------------------

def test_replay_end_to_end_rome():
    """Full closed loop on the (cheap) RoMe family: timelines are
    consistent, occupancy/goodput are sane, streams fold into ns."""
    eng, acc = build_replay(policy="rome_qd2", rate_rps=2e5, n_requests=6,
                            seed=11, keep_traces=True)
    res = eng.run()
    assert res.completed == 6
    assert res.makespan_ns > 0 and res.goodput_rps > 0
    assert 0.0 < res.occupancy <= 1.0
    for r in res.requests:
        assert r.admitted_ns >= r.arrival_ns >= 0
        assert r.first_token_ns > r.admitted_ns
        assert r.completed_ns >= r.first_token_ns
        assert r.n_out == r.max_new_tokens
        assert r.ttft_ns > 0
        if r.n_out >= 2:
            assert r.tpot_ns > 0
    s = res.summary()
    assert s["n_steps"] == len(res.steps) == len(res.traces)
    assert s["tpot_p99_ns"] >= s["tpot_p50_ns"] > 0
    assert s["stream_bytes"] == sum(tr.stream.total_bytes
                                    for tr in res.traces)
    # RoMe moves whole 4 KB rows: the sub-row KV appends are rounded up,
    # so the simulated bytes strictly exceed the software-side demand
    # (the §VII overfetch, now visible in the serving metric).
    assert s["bytes_moved"] > s["stream_bytes"]
    # step starts strictly increase by each step's duration
    for a, b in zip(res.steps, res.steps[1:]):
        assert b.start_ns >= a.start_ns + a.dur_ns - 1e-6


def test_replay_higher_load_queues_longer():
    """More offered load on the same arrival sequence => same goodput
    work finishes with longer queueing tails (TTFT p99)."""
    lo, _ = build_replay(policy="rome_qd2", rate_rps=5e4, n_requests=8,
                         seed=5)
    hi, _ = build_replay(policy="rome_qd2", rate_rps=2e6, n_requests=8,
                         seed=5)
    r_lo, r_hi = lo.run(), hi.run()
    assert r_lo.completed == r_hi.completed == 8
    assert r_hi.goodput_rps > r_lo.goodput_rps     # compressed timeline
    p_lo = r_lo.percentiles(r_lo.ttfts_ns)["p99"]
    p_hi = r_hi.percentiles(r_hi.ttfts_ns)["p99"]
    assert p_hi > p_lo                             # queueing shows in TTFT


def test_run_steps_matches_serial_replay():
    """SystemSim.run_steps (batched, per-step reset) reproduces the
    engine's per-step makespans bit for bit, serial or parallel."""
    eng, acc = build_replay(policy="rome_qd2", rate_rps=1e5, n_requests=4,
                            seed=2, keep_traces=True)
    res = eng.run()
    streams = [tr.stream for tr in res.traces]
    starts = [tr.start_ns for tr in res.traces]
    batched = eng.system.run_steps(streams, starts_ns=starts)
    assert len(batched) == len(res.steps)
    for step, b in zip(res.steps, batched):
        assert b.total_ns == pytest.approx(step.dur_ns)
        assert b.bytes_moved == step.bytes_moved
    two = eng.system.run_steps(streams[:3], workers=2,
                               starts_ns=starts[:3])
    for b1, b2 in zip(batched[:3], two):
        assert b1.total_ns == b2.total_ns
        assert b1.bytes_moved == b2.bytes_moved
    with pytest.raises(ValueError):
        eng.system.run_steps(streams, starts_ns=starts[:1])


def test_low_load_tpot_matches_analytic_band():
    """Near-zero-load replay TPOT vs the analytic perfmodel.tpot path,
    inside the established 15 % engine_xval band. Uses the band-valid
    step scale (data-bound steps; see build_replay docstring)."""
    from repro.perfmodel.tpot import stream_mem_ns
    mix = ServingMix(prompt_median=512, prompt_cv=0.5, out_mean=64,
                     prompt_max=1024, out_max=96)
    for policy in ("hbm4_frfcfs", "rome_qd2"):
        eng, acc = build_replay(policy=policy, rate_rps=1e3, n_requests=1,
                                seed=3, keep_traces=True, scale=2 ** -12,
                                length_scale=1 / 16, mix=mix)
        res = eng.run()
        assert res.completed == 1
        assert max(s.n_active for s in res.steps) == 1
        meas = float(np.mean([s.dur_ns for s in res.steps]))
        model = float(np.mean([stream_mem_ns(tr.stream, acc)
                               for tr in res.traces]))
        rel = abs(meas - model) / model
        assert rel < 0.15, (policy, meas, model, rel)
        # and the request's folded TPOT is the same number at zero load
        tpot = res.requests[0].tpot_ns
        if tpot is not None:
            assert tpot == pytest.approx(meas, rel=0.25)

# --- chunked prefill ----------------------------------------------------------

def _prefill_replay(policy="rome_qd2", overlap=True, warm=False, **kw):
    base = dict(policy=policy, rate_rps=2e5, n_requests=6, seed=11,
                keep_traces=True, prefill_chunk_tokens=8,
                prefill_overlap=overlap, warm=warm)
    base.update(kw)
    return build_replay(**base)


def test_chunked_prefill_kv_byte_conservation():
    """With prefill simulated, every request's K/V footprint appears
    exactly once across the recorded streams: prompt appends (coalesced
    page runs) + one append per decoded token, and page-granular
    reads only."""
    eng, _ = _prefill_replay()
    res = eng.run()
    assert res.completed == 6
    cache = eng.recorder.cache
    pb, pt = cache.page_bytes, cache.page_tokens
    per_tok = pb // pt
    for r in res.requests:
        recs = [rec for tr in res.traces
                for rec in tr.stream.of_stream(r.rid)]
        writes = sum(rec.nbytes for rec in recs if rec.is_write)
        reads = [rec for rec in recs if not rec.is_write]
        # prompt + decoded tokens, K and V pools, exactly once
        assert writes == 2 * (r.prompt_len + r.n_out) * per_tok, r.rid
        assert all(rec.nbytes % pb == 0 for rec in reads), r.rid


def test_chunked_prefill_timeline_ordering():
    """prefill_done_ns is stamped for every request and orders between
    admission and first token."""
    for overlap in (False, True):
        eng, _ = _prefill_replay(overlap=overlap)
        res = eng.run()
        assert res.completed == 6
        for r in res.requests:
            assert r.prefill_done_ns >= r.admitted_ns >= r.arrival_ns
            assert r.first_token_ns >= r.prefill_done_ns, r.rid


def test_prefill_step_kinds_by_overlap_mode():
    """Overlap packs prefill into decode steps (mixed kind); stall mode
    claims dedicated prefill-only steps and never mixes."""
    eng, _ = _prefill_replay(overlap=False)
    res = eng.run()
    kinds = {s.kind for s in res.steps}
    assert "prefill" in kinds and "mixed" not in kinds
    s = res.summary()
    assert s["n_prefill_steps"] > 0 and s["n_mixed_steps"] == 0

    eng, _ = _prefill_replay(overlap=True)
    res = eng.run()
    assert res.summary()["n_mixed_steps"] > 0
    for tr, step in zip(res.traces, res.steps):
        if step.kind == "mixed":
            assert tr.prefilled and tr.active
        elif step.kind == "prefill":
            assert tr.prefilled and not tr.active


def test_legacy_default_has_no_prefill_steps():
    """prefill_chunk_tokens=None keeps the analytic-admission contract:
    no prefill extents, no prefill/mixed steps, sentinel timestamps."""
    eng, _ = build_replay(policy="rome_qd2", rate_rps=2e5, n_requests=4,
                          seed=3)
    res = eng.run()
    s = res.summary()
    assert s["n_prefill_steps"] == 0 and s["n_mixed_steps"] == 0
    assert all(st.kind == "decode" for st in res.steps)
    assert all(r.prefill_done_ns == -1.0 for r in res.requests)


def test_prefill_pack_respects_budget_and_fifo():
    """Batcher-level contract: packs never exceed the token budget, are
    FIFO by admission, and apply_prefill flips decode eligibility only
    once the whole prompt has landed."""
    from repro.serve.batching import ContinuousBatcher, Request
    b = ContinuousBatcher(n_slots=2, prefill_chunk_tokens=5)
    b.submit(Request(rid=0, prompt=np.zeros(8, np.int32),
                     max_new_tokens=2))
    b.submit(Request(rid=1, prompt=np.zeros(3, np.int32),
                     max_new_tokens=2))
    b.schedule()
    done_rids = []
    for _ in range(8):
        pack = b.prefill_pack()
        if not pack:
            break
        assert sum(n for _, _, n in pack) <= 5
        assert all(n > 0 for _, _, n in pack)
        rids = [req.rid for _, req, n in pack]
        assert rids == sorted(rids)                # FIFO by admission
        b.record_tokens(np.zeros(b.n_slots, np.int32), decode=False)
        done_rids += [r.rid for r in b.apply_prefill(pack)]
    assert set(done_rids) == {0, 1}
    assert all(r.prefill_done for r in b.active if r is not None)
    with pytest.raises(ValueError):
        ContinuousBatcher(n_slots=2, prefill_chunk_tokens=0)


def test_warm_replay_deterministic_and_checked():
    """warm=True engines run the whole trace as one WarmRunState session
    (sanitizer on) and are bit-deterministic across repeats."""
    a = _prefill_replay(warm=True)[0].run().summary()
    b = _prefill_replay(warm=True)[0].run().summary()
    assert a == b
    assert a["completed"] == 6
