"""Training substrate: AdamW, grad compression, microbatch equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _proptest import given, settings, strategies as st

from repro.train.grad_compress import (ErrorFeedback, compress_int8,
                                       compress_tree, decompress_int8,
                                       decompress_tree, ef_init)
from repro.train.optimizer import adamw_init, adamw_update
from repro.train.train_step import make_train_step, train_state_init

KEY = jax.random.PRNGKey(0)


def _quadratic_loss(params, batch):
    x = batch["x"]
    pred = x @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def _toy_problem(n=64, d=8):
    k1, k2, k3 = jax.random.split(KEY, 3)
    w_true = jax.random.normal(k1, (d, 1))
    x = jax.random.normal(k2, (n, d))
    y = x @ w_true + 0.01 * jax.random.normal(k3, (n, 1))
    params = {"w": jnp.zeros((d, 1)), "b": jnp.zeros((1,))}
    return params, {"x": x, "y": y}


def test_adamw_converges():
    params, batch = _toy_problem()
    state = adamw_init(params)
    loss0 = float(_quadratic_loss(params, batch))
    for _ in range(200):
        _, grads = jax.value_and_grad(_quadratic_loss)(params, batch)
        params, state = adamw_update(params, grads, state, lr=0.05,
                                     weight_decay=0.0)
    assert float(_quadratic_loss(params, batch)) < 0.05 * loss0


def test_adamw_moments_fp32_params_dtype_kept():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = adamw_init(params)
    assert state.mu["w"].dtype == jnp.float32
    grads = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    new, state = adamw_update(params, grads, state)
    assert new["w"].dtype == jnp.bfloat16


def test_grad_clip_applies():
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params)
    huge = {"w": jnp.full((4,), 1e9)}
    p1, _ = adamw_update(params, huge, state, lr=1e-3, grad_clip=1.0,
                         weight_decay=0.0)
    assert float(jnp.abs(p1["w"]).max()) < 1e-2


def test_microbatch_equivalence():
    """Accumulated step == single-batch step (same grads => same params)."""
    params, batch = _toy_problem(n=32)
    s1 = train_state_init(params)
    s2 = train_state_init(params)
    step1 = make_train_step(_quadratic_loss, microbatches=1, lr=0.01)
    step4 = make_train_step(_quadratic_loss, microbatches=4, lr=0.01)
    s1, m1 = jax.jit(step1)(s1, batch)
    s2, m2 = jax.jit(step4)(s2, batch)
    np.testing.assert_allclose(np.asarray(s1.params["w"]),
                               np.asarray(s2.params["w"]),
                               rtol=1e-5, atol=1e-6)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)


# --- int8 gradient compression with error feedback ---------------------------

def test_compress_roundtrip_error_bounded():
    g = jax.random.normal(KEY, (256,))
    q, s = compress_int8(g)
    back = decompress_int8(q, s)
    assert float(jnp.abs(back - g).max()) <= float(s) / 2 + 1e-9


def test_error_feedback_unbiased_over_time():
    """With EF, the *accumulated* applied gradient converges to the true
    accumulated gradient (residual stays bounded)."""
    g = {"w": jax.random.normal(KEY, (128,)) * 1e-3}
    ef = ef_init(g)
    applied = jnp.zeros((128,))
    for i in range(50):
        (q, s), ef = compress_tree(g, ef)
        applied = applied + decompress_tree(q, s)["w"]
    true = g["w"] * 50
    resid = float(jnp.abs(ef.buf["w"]).max())
    # total error equals the current residual (telescoping), so it stays
    # one quantization step, never growing with iterations
    np.testing.assert_allclose(np.asarray(applied + ef.buf["w"]),
                               np.asarray(true), rtol=1e-4, atol=1e-6)
    assert resid < float(jnp.abs(g["w"]).max())


@settings(deadline=None, max_examples=25)
@given(scale=st.floats(min_value=1e-6, max_value=1e4),
       n=st.integers(min_value=1, max_value=64))
def test_compress_property(scale, n):
    g = jax.random.normal(jax.random.PRNGKey(n), (n,)) * scale
    q, s = compress_int8(g)
    assert q.dtype == jnp.int8
    back = decompress_int8(q, s)
    assert float(jnp.abs(back - g).max()) <= float(s) * 0.5 + 1e-12
