"""Cycle-level engine invariants for both controllers."""
import numpy as np
import pytest
from _proptest import given, settings, strategies as st

from repro.core import engine as eng


def test_hbm4_bandwidth_below_peak():
    sim = eng.HBM4ChannelSim()
    r = sim.run(eng.sequential_read_txns_hbm4(1 << 17))
    assert 0 < r.bandwidth_gbps <= sim.g.bandwidth_gbps + 1e-9


def test_hbm4_stream_efficiency():
    """A well-tuned MC on a bulk stream sustains >90 % of peak."""
    sim = eng.HBM4ChannelSim(max_ref_postpone=32)
    r = sim.run(eng.sequential_read_txns_hbm4(1 << 18))
    assert r.bandwidth_gbps / sim.g.bandwidth_gbps > 0.90


def test_rome_stream_efficiency():
    sim = eng.RoMeChannelSim()
    r = sim.run(eng.sequential_read_txns_rome(1 << 20))
    assert r.bandwidth_gbps / sim.g.bandwidth_gbps > 0.95


def test_rome_beats_hbm4_per_channel_is_false_without_extra_channels():
    """Per channel the two are comparable (both near peak) — RoMe's system
    win comes from +4 channels, not per-channel magic (paper §VI-B)."""
    h = eng.HBM4ChannelSim(max_ref_postpone=32)
    rh = h.run(eng.sequential_read_txns_hbm4(1 << 18))
    r = eng.RoMeChannelSim()
    rr = r.run(eng.sequential_read_txns_rome(1 << 20))
    eff_h = rh.bandwidth_gbps / h.g.bandwidth_gbps
    eff_r = rr.bandwidth_gbps / r.g.bandwidth_gbps
    assert abs(eff_h - eff_r) < 0.10


def test_rome_queue_depth_two_saturates():
    r2 = eng.RoMeChannelSim(queue_depth=2, refresh=False)
    r8 = eng.RoMeChannelSim(queue_depth=8, refresh=False)
    t2 = r2.run(eng.sequential_read_txns_rome(1 << 19))
    t8 = r8.run(eng.sequential_read_txns_rome(1 << 19))
    assert t2.total_ns <= t8.total_ns * 1.02


def test_hbm4_shallow_queue_starves():
    deep = eng.HBM4ChannelSim(queue_depth=64, refresh=False)
    shallow = eng.HBM4ChannelSim(queue_depth=2, refresh=False)
    txns = eng.sequential_read_txns_hbm4(1 << 16, layout="row_linear")
    td = deep.run(list(txns))
    ts = shallow.run(list(txns))
    assert ts.total_ns > 1.3 * td.total_ns


def test_writes_slower_than_reads_rome_same_vba():
    """tWR_row (115) > tRD_row (95) binds back-to-back ops on ONE VBA;
    across interleaved VBAs both directions pace at tX2XS = 64."""
    rd = eng.RoMeChannelSim(refresh=False, n_vbas=1).run(
        eng.sequential_read_txns_rome(1 << 18, n_vbas=1))
    wr = eng.RoMeChannelSim(refresh=False, n_vbas=1).run(
        eng.sequential_read_txns_rome(1 << 18, n_vbas=1, is_write=True))
    assert wr.total_ns > rd.total_ns


def test_completion_times_finite_and_positive():
    sim = eng.RoMeChannelSim()
    r = sim.run(eng.sequential_read_txns_rome(1 << 16))
    assert np.all(np.isfinite(r.finish_ns)) and np.all(r.finish_ns > 0)


def test_act_counts():
    """RoMe: exactly 2 ACT per row command; HBM4 stream: ~1 ACT per KB."""
    rome = eng.RoMeChannelSim(refresh=False)
    rr = rome.run(eng.sequential_read_txns_rome(1 << 18))
    assert rr.cmd_counts["ACT"] == 2 * rr.cmd_counts["row_commands"]
    hbm = eng.HBM4ChannelSim(refresh=False)
    rh = hbm.run(eng.sequential_read_txns_hbm4(1 << 18))
    kb = (1 << 18) / 1024
    assert rh.cmd_counts["ACT"] == pytest.approx(kb, rel=0.02)


def test_interleaved_streams_inflate_acts():
    """Stream interleaving forces re-activations on the baseline — the
    mechanism behind RoMe's Fig 14 ACT-energy advantage. Measured curve:
    1.0 ACT/KB at 8 streams (clean), 4.1 at 32, 17+ at 64 (the per-stream
    queue window shrinks below a row's 32 columns and bank collisions
    force re-ACTs)."""
    solo = eng.HBM4ChannelSim(refresh=False).run(
        eng.sequential_read_txns_hbm4(1 << 16, layout="row_linear"))
    mixed = eng.HBM4ChannelSim(refresh=False).run(
        eng.interleaved_stream_txns_hbm4(32, 1 << 14))
    kb_solo = (1 << 16) / 1024
    kb_mixed = 32 * (1 << 14) / 1024
    assert mixed.cmd_counts["ACT"] / kb_mixed > \
        2.0 * solo.cmd_counts["ACT"] / kb_solo


def test_rome_sparse_arrivals_refresh_paced():
    """Regression (idle-advance): with sparse arrivals the sim must jump
    to min(next arrival, next refresh due) — refreshes due inside an idle
    gap are issued in the gap, so the postponement backlog stays within
    the JEDEC bound instead of piling up behind the next arrival."""
    sim = eng.RoMeChannelSim()
    period = 2 * sim.t.tREFIpb
    gap = 40 * period                       # 40 refreshes due per gap
    txns = [eng.Txn(arrival_ns=i * gap, bank=i % sim.n_vbas, row=i)
            for i in range(4)]
    r = sim.run(txns)
    assert r.cmd_counts["ref_backlog_max"] <= sim.max_ref_postpone
    # Refresh kept pace with wall-clock across the whole span (one
    # VBA-paired REFpb counts 2; slack = postponement cap + final partial).
    span = 3 * gap
    assert r.cmd_counts["REFpb"] >= 2 * (span // period - sim.max_ref_postpone)
    assert np.all(np.isfinite(r.finish_ns)) and np.all(np.diff(r.finish_ns) > 0)


def test_hbm4_sparse_arrivals_refresh_paced():
    """Same idle-advance property for the conventional controller."""
    sim = eng.HBM4ChannelSim()
    gap = 40 * sim.t.tREFIpb
    txns = [eng.Txn(arrival_ns=i * gap, bank=i % sim.n_banks, row=i)
            for i in range(4)]
    r = sim.run(txns)
    assert r.cmd_counts["ref_backlog_max"] <= sim.max_ref_postpone
    assert np.all(np.isfinite(r.finish_ns)) and np.all(r.finish_ns > 0)


def test_duplicate_txns_each_complete_once():
    """Field-identical transactions are distinct requests: dequeue is by
    identity, so each must complete exactly once, at distinct times."""
    for sim in (eng.RoMeChannelSim(refresh=False),
                eng.HBM4ChannelSim(refresh=False)):
        txns = [eng.Txn(arrival_ns=0.0, bank=0, row=0) for _ in range(3)]
        r = sim.run(txns)
        assert np.all(r.finish_ns > 0)
        assert len(np.unique(r.finish_ns)) == 3


@settings(deadline=None, max_examples=20)
@given(nbytes=st.sampled_from([1 << 14, 1 << 15, 1 << 16]),
       depth=st.integers(min_value=1, max_value=8))
def test_rome_properties(nbytes, depth):
    """Property: bandwidth <= peak; more queue never hurts makespan by
    more than jitter; byte accounting exact."""
    sim = eng.RoMeChannelSim(queue_depth=depth, refresh=False)
    txns = eng.sequential_read_txns_rome(nbytes)
    r = sim.run(txns)
    assert r.bandwidth_gbps <= sim.g.bandwidth_gbps + 1e-9
    assert r.bytes_moved == len(txns) * 4096
