"""Layer-op trace census: bytes/flops bookkeeping for the perf model."""
import pytest

from repro.configs.paper_workloads import PAPER_WORKLOADS
from repro.trace.layergraph import RowAllocator, decode_ops, prefill_ops


def test_row_allocator_alignment():
    a = RowAllocator()
    b1, n1 = a.alloc(100)
    b2, n2 = a.alloc(5000)
    assert b1 % 4096 == 0 and b2 % 4096 == 0
    assert b2 >= b1 + 4096            # rounded up to whole rows


@pytest.mark.parametrize("name", sorted(PAPER_WORKLOADS))
def test_decode_ops_structure(name):
    w = PAPER_WORKLOADS[name]
    ops = decode_ops(w, batch=64, seq_len=8192)
    kinds = {o.kind for o in ops}
    assert {"attn", "ffn", "head"} <= kinds
    assert len([o for o in ops if o.kind == "attn"]) == w.n_layers
    for o in ops:
        assert o.flops > 0
        assert o.read_bytes >= 0


def test_prefill_scales_flops_not_extents():
    w = PAPER_WORKLOADS["grok-1"]
    d = decode_ops(w, 8, 8192)
    p = prefill_ops(w, 8, 8192)
    # weights are read once either way; flops scale with tokens
    assert sum(o.flops for o in p) > 1000 * sum(o.flops for o in d)
    assert p[0].extents == d[0].extents


def test_moe_extents_sparser_than_dense():
    """Small batch activates few experts -> few (large) extents; large
    batch touches all experts (the Fig 13 LBR_FFN mechanism)."""
    w = PAPER_WORKLOADS["deepseek-v3"]
    small = decode_ops(w, 1, 8192)
    big = decode_ops(w, 256, 8192)
    s_moe = [o for o in small if o.kind == "ffn" and len(o.extents) > 1]
    b_moe = [o for o in big if o.kind == "ffn" and len(o.extents) > 1]
    assert s_moe and b_moe
    assert len(b_moe[0].extents) > len(s_moe[0].extents)
