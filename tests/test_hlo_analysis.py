"""HLO analyzer: synthetic-module parses + the pinned cost_analysis
deficiency that motivates it (while bodies counted once)."""
import jax
import jax.numpy as jnp
import pytest

from repro.compat.hlo import normalize_cost_analysis
from repro.launch.hlo_analysis import (HloModule, analyze_hlo, shape_bytes,
                                       xla_cost_analysis, _parse_instr_line)


def test_shape_bytes():
    assert shape_bytes("f32[2,3]") == 24
    assert shape_bytes("bf16[128]") == 256
    assert shape_bytes("(f32[2], s32[4])") == 8 + 16
    assert shape_bytes("pred[]") == 1
    assert shape_bytes("token[]") == 0


def test_parse_tuple_result_instruction():
    line = ("  %while.15 = (s32[], bf16[8,1,3584]{2,1,0}, "
            "f32[28,16]{1,0}) while(%tuple.20), condition=%c, body=%b")
    name, rtype, op = _parse_instr_line(line)
    assert name == "while.15" and op == "while"
    assert shape_bytes(rtype) == 4 + 8 * 3584 * 2 + 28 * 16 * 4


SYNTH = """
HloModule synth

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%z, %a)
  %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_from_condition():
    st = analyze_hlo(SYNTH)
    # 7 iterations x (2*8*8*8) flops
    assert st.flops == 7 * 2 * 8 * 8 * 8
    # 7 all-reduces of 256 B
    assert st.collective_bytes == 7 * 256
    assert st.coll_by_kind == {"all-reduce": 7 * 256}
    assert st.n_collectives == 7


def test_cost_analysis_counts_while_once():
    """Pin the deficiency: XLA's cost_analysis does NOT multiply while
    bodies by trip count — the reason hlo_analysis exists. If this ever
    starts failing, cost_analysis got fixed and the analyzer can defer."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(xs, xs).compile()
    xla_flops = xla_cost_analysis(c)["flops"]
    ours = analyze_hlo(c.as_text()).flops
    per_iter = 2 * 64 ** 3
    assert xla_flops < 2 * per_iter          # counted once
    assert ours == pytest.approx(10 * per_iter, rel=0.01)


def test_normalize_cost_analysis_shapes():
    """Both historical return shapes of Compiled.cost_analysis() normalize
    to the same flat dict."""
    assert normalize_cost_analysis({"flops": 2.0}) == {"flops": 2.0}
    assert normalize_cost_analysis([{"flops": 2.0}]) == {"flops": 2.0}
    assert normalize_cost_analysis([]) == {}
    assert normalize_cost_analysis(None) == {}


def test_real_module_collective_symbols():
    """Collective operand sizes resolve through the symbol table even when
    operands print as bare %names."""
    hlo = """
HloModule m

ENTRY %main (a: f32[16,32]) -> f32[16,32] {
  %a = f32[16,32]{1,0} parameter(0)
  %d = f32[16,32]{1,0} add(%a, %a)
  ROOT %ar = f32[16,32]{1,0} all-reduce(%d), replica_groups={}
}
"""
    st = analyze_hlo(hlo)
    assert st.collective_bytes == 16 * 32 * 4
