"""Sharding vocabulary + plan concretization (no devices needed)."""
import jax.numpy as jnp
import pytest

from repro.distributed.sharding import (filter_spec, pad_to_multiple,
                                        padded_heads, padded_vocab)


def test_filter_spec_drops_missing_axes():
    assert filter_spec((("pod", "data"), None, "model"),
                       ("data", "model")) == (("data",), None, "model")
    assert filter_spec(("pod",), ()) == (None,)
    assert filter_spec((None, "x"), ("x",)) == (None, "x")


def test_padding_policies():
    assert padded_heads(28, 16) == 32        # qwen2
    assert padded_heads(40, 16) == 48        # qwen3
    assert padded_heads(12, 16) == 16        # whisper
    assert padded_heads(32, 16) == 32
    assert padded_vocab(51865) == 51968      # whisper
    assert padded_vocab(152064) == 152064    # already aligned
    assert pad_to_multiple(1, 16) == 16


class _FakeMesh:
    def __init__(self, shape, names):
        import numpy as np
        self.devices = np.zeros(shape)
        self.axis_names = names


def test_concretize_divisibility():
    from repro.launch.plans import concretize_spec
    mesh = _FakeMesh((16, 16), ("data", "model"))
    # batch=1 cannot shard over anything
    assert concretize_spec((("pod", "data"),), (1,), mesh) == \
        __import__("jax").sharding.PartitionSpec(None)
    # 40 heads don't divide 16 -> dropped
    p = concretize_spec((None, "model"), (8, 40), mesh)
    assert tuple(p) == (None, None)
    # 128 batch over data=16 OK
    p = concretize_spec((("pod", "data"), None), (128, 4), mesh)
    assert tuple(p) == ("data", None)


def test_concretize_no_duplicate_axes():
    from repro.launch.plans import concretize_spec
    mesh = _FakeMesh((4, 4), ("data", "model"))
    p = concretize_spec(("data", ("data", "model")), (8, 8), mesh)
    flat = []
    for e in tuple(p):
        if e is None:
            continue
        flat += list(e) if isinstance(e, tuple) else [e]
    assert len(flat) == len(set(flat))


def test_train_memory_plan_shapes():
    from repro.configs.registry_configs import ALL_ARCHS
    from repro.configs.shapes import SHAPES
    from repro.launch.plans import train_memory_plan
    mesh = _FakeMesh((16, 16), ("data", "model"))
    mb, sp = train_memory_plan(ALL_ARCHS["llama-3.2-vision-90b"],
                               SHAPES["train_4k"], mesh)
    assert mb == 16
    mb2, _ = train_memory_plan(ALL_ARCHS["h2o-danube-1.8b"],
                               SHAPES["train_4k"], mesh)
    assert mb2 <= 4
    # microbatches always divide the local batch
    for arch, cfg in ALL_ARCHS.items():
        mb, _ = train_memory_plan(cfg, SHAPES["train_4k"], mesh)
        assert (SHAPES["train_4k"].global_batch // 16) % mb == 0
