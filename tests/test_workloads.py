"""The unified workload API: ExtentRecord/ExtentStream semantics, the
trace-driven builder contract (row-aligned writes, roofline arrivals),
decomposition conservation properties, and the TPOT stream consistency.
"""
import numpy as np
import pytest
from _proptest import given, settings, strategies as st

from repro.configs.paper_workloads import PAPER_WORKLOADS
from repro.core.system_sim import SystemSim
from repro.core.timing import hbm4_config, rome_config
from repro.perfmodel.accelerator import paper_accelerator, scaled_accelerator
from repro.perfmodel.tpot import step_time, stream_mem_ns
from repro.trace.layergraph import ROW, decode_ops
from repro.workloads import (ExtentRecord, ExtentStream, bulk_stream,
                             from_layer_ops, interleave, scale_layer_ops,
                             sparse_stream, strided_stream)


# ---------------------------------------------------------------------------
# Record / stream semantics
# ---------------------------------------------------------------------------

def test_record_validation():
    with pytest.raises(ValueError):
        ExtentRecord(0, 4096, "readwrite")
    with pytest.raises(ValueError):
        ExtentRecord(0, 0, "read")
    with pytest.raises(ValueError):
        ExtentRecord(-4, 64, "read")


def test_stream_slicing_and_aggregates():
    s = bulk_stream(1 << 16, n_extents=4) + bulk_stream(
        1 << 14, n_extents=2, kind="write", base_addr=1 << 20)
    assert len(s) == 6
    assert s.total_bytes == (1 << 16) + (1 << 14)
    assert s.read_bytes == 1 << 16 and s.write_bytes == 1 << 14
    head = s[:4]
    assert isinstance(head, ExtentStream) and head.write_bytes == 0
    assert s.of_kind("write").extents() == s.extents("write")
    assert s.limit_bytes(1 << 15).total_bytes == 1 << 15   # 2 of 4 reads


def test_stream_shift_retag_rebase():
    s = bulk_stream(8192, n_extents=2, base_addr=4096, arrival_ns=10.0)
    assert s.shifted(5.0)[0].arrival_ns == 15.0
    assert s.retagged(7).stream_ids == (7,)
    rb = s.rebased(0)
    assert rb[0].addr == 0 and rb.total_bytes == s.total_bytes


def test_interleave_is_arrival_ordered_and_stable():
    a = strided_stream(4, 4096, 8192, inter_arrival_ns=10.0).retagged(0)
    b = strided_stream(4, 4096, 8192, base_addr=1 << 20,
                       inter_arrival_ns=10.0).retagged(1)
    mix = interleave([a, b])
    arrivals = [r.arrival_ns for r in mix]
    assert arrivals == sorted(arrivals)
    # Equal arrivals keep input-stream order (a before b).
    assert [r.stream_id for r in mix[:2]] == [0, 1]
    # Per-tenant issue order survives the merge.
    for sid, src in ((0, a), (1, b)):
        sub = [r.addr for r in mix if r.stream_id == sid]
        assert sub == [r.addr for r in src]


def test_coalesced_merges_rows():
    # Two tokens in one 4 KB row, one in another: 2 merged row reads.
    s = ExtentStream([ExtentRecord(100, 512), ExtentRecord(700, 512),
                      ExtentRecord(9000, 512)])
    c = s.coalesced(granularity=4096)
    assert [(r.addr, r.nbytes) for r in c] == [(0, 4096), (8192, 4096)]
    # Kinds never merge with each other.
    m = ExtentStream([ExtentRecord(0, 512, "read"),
                      ExtentRecord(512, 512, "write")])
    assert len(m.coalesced(granularity=4096)) == 2


def test_sparse_stream_is_disjoint_and_sorted():
    s = sparse_stream(256, 512, 1 << 20, seed=3)
    addrs = [r.addr for r in s]
    assert addrs == sorted(addrs) and len(set(addrs)) == len(addrs)
    assert all(r.nbytes == 512 for r in s)


# ---------------------------------------------------------------------------
# Builder contract: from_layer_ops
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wname", ["deepseek-v3", "llama-3-405b"])
def test_from_layer_ops_write_extents_row_aligned_disjoint(wname):
    w = PAPER_WORKLOADS[wname]
    ops = decode_ops(w, batch=16, seq_len=2048)[:8]
    acc = paper_accelerator("rome")
    stream = from_layer_ops(ops, acc)
    writes = stream.of_kind("write")
    assert len(writes) > 0
    assert all(r.addr % ROW == 0 for r in writes)
    # Writes never overlap any read extent of the trace.
    reads = sorted(stream.extents("read"))
    starts = [a for a, _ in reads]
    for r in writes:
        i = np.searchsorted(starts, r.addr, side="right") - 1
        if i >= 0:
            a, n = reads[i]
            assert r.addr >= a + n, (r, reads[i])
        if i + 1 < len(reads):
            assert r.end <= reads[i + 1][0], (r, reads[i + 1])


def test_from_layer_ops_arrivals_follow_roofline():
    w = PAPER_WORKLOADS["llama-3-405b"]
    ops = decode_ops(w, batch=16, seq_len=2048)[:4]
    acc = paper_accelerator("hbm4")
    stream = from_layer_ops(ops, acc)
    # One arrival per op, strictly increasing, records grouped by op.
    per_op = {sid: stream.of_stream(sid) for sid in stream.stream_ids}
    assert set(per_op) == set(range(len(ops)))
    arrivals = []
    for sid, sub in per_op.items():
        ts = {r.arrival_ns for r in sub}
        assert len(ts) == 1          # reads+writes of an op arrive together
        arrivals.append(ts.pop())
        assert sub.read_bytes == ops[sid].read_bytes
        assert sub.write_bytes == ops[sid].write_bytes
    assert arrivals == sorted(arrivals) and arrivals[0] == 0.0
    assert arrivals[-1] > 0.0


def test_stream_mem_ns_matches_step_time():
    """tpot's stream path and op path are the same model by construction."""
    w = PAPER_WORKLOADS["deepseek-v3"]
    ops = decode_ops(w, batch=16, seq_len=2048)[:8]
    for mem in ("hbm4", "rome"):
        acc = paper_accelerator(mem)
        st_ = step_time(ops, acc)
        sm = stream_mem_ns(from_layer_ops(ops, acc), acc)
        assert sm == pytest.approx(st_.mem_ns, rel=1e-9)


def test_scale_layer_ops_preserves_structure():
    w = PAPER_WORKLOADS["deepseek-v3"]
    ops = decode_ops(w, batch=16, seq_len=2048)[:8]
    sops = scale_layer_ops(ops, 2 ** -11)
    assert len(sops) == len(ops)
    for o, s in zip(ops, sops):
        assert len(s.extents) == len(o.extents)
        assert len(s.write_extents) == len(o.write_extents)
        assert all(a % ROW == 0 and n >= ROW for a, n in
                   s.extents + s.write_extents)
        assert s.flops == pytest.approx(o.flops * 2 ** -11)


# ---------------------------------------------------------------------------
# Decomposition conservation (property): interleaved multi-tenant streams
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n_tenants=st.integers(min_value=1, max_value=4),
       n_recs=st.integers(min_value=1, max_value=6),
       rec_units=st.integers(min_value=1, max_value=5),
       n_writers=st.integers(min_value=0, max_value=2),
       cfg_rome=st.booleans())
def test_decompose_conserves_bytes_and_arrival_order(
        n_tenants, n_recs, rec_units, n_writers, cfg_rome):
    cfg = rome_config() if cfg_rome else hbm4_config()
    g = cfg.ag_mc_bytes
    tenants = []
    for t in range(n_tenants):
        kind = "write" if t < min(n_writers, n_tenants) else "read"
        tenants.append(ExtentStream(
            ExtentRecord((t * 97 + k * n_tenants) * g, rec_units * g, kind,
                         k * 5.0 + t, t)
            for k in range(n_recs)))
    mix = interleave(tenants)
    sim = SystemSim(cfg, n_channels=3)
    per_channel = sim.decompose(mix)
    # Byte conservation: every touched stripe unit lands on exactly one
    # channel, at MC granularity.
    n_txns = sum(len(v) for v in per_channel.values())
    assert n_txns * g == mix.total_bytes        # extents are unit-aligned
    # Kind conservation, per record byte count.
    n_writes = sum(1 for v in per_channel.values() for tx in v if tx.is_write)
    assert n_writes * g == mix.write_bytes
    # Per-channel queues inherit the stream's arrival order.
    for txns in per_channel.values():
        arr = [tx.arrival_ns for tx in txns]
        assert arr == sorted(arr)
    # Stream tags survive decomposition.
    tags = {tx.stream for v in per_channel.values() for tx in v}
    assert tags == set(mix.stream_ids)


def test_decompose_overfetch_rule():
    """A 1-byte record still moves a whole stripe unit."""
    cfg = rome_config()
    sim = SystemSim(cfg, n_channels=2)
    per_channel = sim.decompose(ExtentStream([ExtentRecord(10, 1)]))
    assert sum(len(v) for v in per_channel.values()) == 1
