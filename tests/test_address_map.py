"""Address-map stripe math and LBR properties."""
import numpy as np
from _proptest import given, settings, strategies as st

from repro.core import (hbm4_config, load_balance_ratio, make_address_map,
                        rome_config)
from repro.core.address_map import channel_bytes


def test_channel_bytes_exact_small():
    amap = make_address_map(rome_config(), n_cubes=1)   # 36 channels, 4 KB
    cb = channel_bytes(amap, [(0, 4096 * 36)])
    assert np.all(cb == 4096)


def test_partial_stripe_accounting():
    amap = make_address_map(rome_config(), n_cubes=1)
    cb = channel_bytes(amap, [(100, 5000)])
    assert cb.sum() == 5000


def test_lbr_perfectly_balanced():
    amap = make_address_map(rome_config(), n_cubes=8)
    n = amap.n_channels
    assert load_balance_ratio(amap, [(0, 4096 * n * 7)]) == 1.0


def test_lbr_single_row_worst_case():
    amap = make_address_map(rome_config(), n_cubes=8)
    lbr = load_balance_ratio(amap, [(0, 4096)])
    assert lbr == 1.0 / amap.n_channels


def test_hbm4_fine_stripes_balance():
    """32 B stripes keep HBM4 LBR ~1 even for modest extents (the paper's
    baseline normalization)."""
    amap = make_address_map(hbm4_config(), n_cubes=8)
    assert load_balance_ratio(amap, [(0, 1 << 20)]) > 0.99


@settings(deadline=None, max_examples=50)
@given(start=st.integers(min_value=0, max_value=1 << 24),
       nbytes=st.integers(min_value=1, max_value=1 << 22))
def test_channel_bytes_conserved(start, nbytes):
    """Property: stripe accounting conserves total bytes exactly."""
    amap = make_address_map(rome_config(), n_cubes=2)
    cb = channel_bytes(amap, [(start, nbytes)])
    assert cb.sum() == nbytes
    assert np.all(cb >= 0)


@settings(deadline=None, max_examples=30)
@given(extents=st.lists(
    st.tuples(st.integers(min_value=0, max_value=1 << 22),
              st.integers(min_value=1, max_value=1 << 20)),
    min_size=1, max_size=8))
def test_lbr_bounds(extents):
    """Property: 0 < LBR <= 1."""
    amap = make_address_map(rome_config(), n_cubes=1)
    lbr = load_balance_ratio(amap, extents)
    assert 0.0 < lbr <= 1.0 + 1e-12
