"""Address-map stripe math and LBR properties."""
import numpy as np
from _proptest import given, settings, strategies as st

from repro.core import (hbm4_config, load_balance_ratio, make_address_map,
                        rome_config)
from repro.core.address_map import channel_bytes


def test_channel_bytes_exact_small():
    amap = make_address_map(rome_config(), n_cubes=1)   # 36 channels, 4 KB
    cb = channel_bytes(amap, [(0, 4096 * 36)])
    assert np.all(cb == 4096)


def test_partial_stripe_accounting():
    amap = make_address_map(rome_config(), n_cubes=1)
    cb = channel_bytes(amap, [(100, 5000)])
    assert cb.sum() == 5000


def test_lbr_perfectly_balanced():
    amap = make_address_map(rome_config(), n_cubes=8)
    n = amap.n_channels
    assert load_balance_ratio(amap, [(0, 4096 * n * 7)]) == 1.0


def test_lbr_single_row_worst_case():
    amap = make_address_map(rome_config(), n_cubes=8)
    lbr = load_balance_ratio(amap, [(0, 4096)])
    assert lbr == 1.0 / amap.n_channels


def test_hbm4_fine_stripes_balance():
    """32 B stripes keep HBM4 LBR ~1 even for modest extents (the paper's
    baseline normalization)."""
    amap = make_address_map(hbm4_config(), n_cubes=8)
    assert load_balance_ratio(amap, [(0, 1 << 20)]) > 0.99


@settings(deadline=None, max_examples=50)
@given(start=st.integers(min_value=0, max_value=1 << 24),
       nbytes=st.integers(min_value=1, max_value=1 << 22))
def test_channel_bytes_conserved(start, nbytes):
    """Property: stripe accounting conserves total bytes exactly."""
    amap = make_address_map(rome_config(), n_cubes=2)
    cb = channel_bytes(amap, [(start, nbytes)])
    assert cb.sum() == nbytes
    assert np.all(cb >= 0)


@settings(deadline=None, max_examples=30)
@given(extents=st.lists(
    st.tuples(st.integers(min_value=0, max_value=1 << 22),
              st.integers(min_value=1, max_value=1 << 20)),
    min_size=1, max_size=8))
def test_lbr_bounds(extents):
    """Property: 0 < LBR <= 1."""
    amap = make_address_map(rome_config(), n_cubes=1)
    lbr = load_balance_ratio(amap, extents)
    assert 0.0 < lbr <= 1.0 + 1e-12


@settings(deadline=None, max_examples=25)
@given(extents=st.lists(
    st.tuples(st.integers(min_value=0, max_value=1 << 22),
              st.integers(min_value=0, max_value=1 << 18)),
    min_size=1, max_size=12),
       n_channels=st.sampled_from([1, 2, 5, 8, 9]),
       family=st.sampled_from(["hbm4", "rome"]))
def test_census_matches_per_extent_loop_reference(extents, n_channels,
                                                  family):
    """Property: the difference-array census (one cumsum over cyclic
    windows) agrees exactly with a naive per-extent, per-unit Python
    loop — bytes, touched stripe units, and record touch counts alike —
    on both stripe granularities and on channel counts that do and do
    not divide the address space evenly."""
    from repro.core.address_map import (AddressMap, extent_arrays,
                                        extent_census)

    cfg = hbm4_config() if family == "hbm4" else rome_config()
    amap = AddressMap(n_channels=n_channels, stripe_bytes=cfg.ag_mc_bytes,
                      banks_per_channel=4, row_bytes=cfg.row_bytes)
    g, nch = amap.stripe_bytes, amap.n_channels

    ref_bytes = np.zeros(nch, np.int64)
    ref_units = np.zeros(nch, np.int64)
    ref_touch = np.zeros(nch, np.int64)
    for start, nbytes in extents:
        if nbytes <= 0:
            continue
        touched = set()
        first, last = start // g, (start + nbytes - 1) // g
        for unit in range(first, last + 1):
            ch = unit % nch
            lo, hi = max(start, unit * g), min(start + nbytes,
                                               (unit + 1) * g)
            ref_bytes[ch] += hi - lo
            ref_units[ch] += 1
            touched.add(ch)
        for ch in touched:
            ref_touch[ch] += 1

    starts, sizes = extent_arrays([(s, n) for s, n in extents])
    out = extent_census(amap, starts, sizes)
    assert np.array_equal(out["bytes"][0], ref_bytes)
    assert np.array_equal(out["units"][0], ref_units)
    assert np.array_equal(out["touches"][0], ref_touch)
    # Segmented form: one census over per-extent segments row-sums back
    # to the pooled census.
    seg = np.arange(len(starts)) % 3
    seg_out = extent_census(amap, starts, sizes, seg=seg, n_segs=3)
    for key in ("bytes", "units", "touches"):
        assert np.array_equal(seg_out[key].sum(axis=0), out[key][0]), key
