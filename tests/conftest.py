# NOTE (brief): XLA_FLAGS / device-count overrides are NOT set here —
# smoke tests and benches must see the real single CPU device. Tests that
# need a multi-device mesh spawn a subprocess with the flag set.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# Repo root too, so tests can import the benchmarks driver package.
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))
