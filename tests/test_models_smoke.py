"""Per-arch smoke tests (brief requirement): reduced same-family config,
one forward + one train-grad + one decode step on CPU; output shapes and
no NaNs asserted. The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry_configs import ALL_ARCHS
from repro.models.registry import get_adapter

KEY = jax.random.PRNGKey(0)
ARCHS = sorted(ALL_ARCHS)


def _batch(adapter, cfg, b=2, s=8):
    batch = {"tokens": jnp.ones((b, s), jnp.int32) * 3,
             "labels": jnp.ones((b, s), jnp.int32) * 5}
    if "vision_embeds" in adapter.extra_inputs:
        batch["vision_embeds"] = jnp.ones(
            (b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16) * 0.02
    if "frames" in adapter.extra_inputs:
        batch["frames"] = jnp.ones(
            (b, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = reduced(ALL_ARCHS[arch])
    ad = get_adapter(cfg)
    params = ad.init(KEY)
    batch = _batch(ad, cfg)
    logits = ad.forward(params, batch)
    assert logits.shape[:2] == (2, 8)
    assert logits.shape[2] >= cfg.vocab
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_and_grad_finite(arch):
    cfg = reduced(ALL_ARCHS[arch])
    ad = get_adapter(cfg)
    params = ad.init(KEY)
    batch = _batch(ad, cfg)
    loss, grads = jax.value_and_grad(lambda p: ad.loss(p, batch))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(
        np.all(np.isfinite(np.asarray(g, np.float32))) for g in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = reduced(ALL_ARCHS[arch])
    ad = get_adapter(cfg)
    params = ad.init(KEY)
    state = ad.init_decode_state(2, 16)
    batch = {"tokens": jnp.ones((2, 1), jnp.int32)}
    logits, state2 = ad.decode(params, batch, state, jnp.array(3, jnp.int32))
    assert logits.shape[:2] == (2, 1)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    # state structure preserved
    assert jax.tree.structure(state) == jax.tree.structure(state2)


@pytest.mark.parametrize("arch", ["qwen2-7b", "rwkv6-3b", "zamba2-1.2b"])
def test_decode_matches_forward_suffix(arch):
    """Feeding tokens one-by-one through decode must reproduce the
    full-sequence forward logits (cache/state correctness)."""
    cfg = reduced(ALL_ARCHS[arch])
    ad = get_adapter(cfg)
    params = ad.init(KEY)
    toks = jax.random.randint(KEY, (1, 6), 0, cfg.vocab)
    full = ad.forward(params, {"tokens": toks})
    state = ad.init_decode_state(1, 16)
    outs = []
    for t in range(6):
        lg, state = ad.decode(params, {"tokens": toks[:, t:t + 1]}, state,
                              jnp.array(t, jnp.int32))
        outs.append(np.asarray(lg[:, 0], np.float32))
    dec = np.stack(outs, 1)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), dec, rtol=0.15, atol=0.15)


def test_param_counts_sane():
    """n_params() stays within 35 % of the actual initialized count for
    every family (used for MODEL_FLOPS; exactness not required)."""
    for arch in ARCHS:
        cfg = reduced(ALL_ARCHS[arch])
        ad = get_adapter(cfg)
        params = ad.init(KEY)
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = cfg.n_params()
        assert 0.65 < predicted / actual < 1.45, \
            (arch, predicted, actual)
