"""Analytic-vs-engine cross-validation at the extent level.

`repro.core.analytic.transfer_time_ns` is the closed-form service-time
model the TPOT reproduction rides on; `repro.core.system_sim.SystemSim`
is the cycle-level ground truth for the same (addr, nbytes) extents. On
bulk-stream regimes — where the analytic model claims validity — the two
must agree within 10 % for both memory systems, reads and writes. The
stream-level sections pin the `run_extents` wrapper bit-for-bit to the
primary `run(stream)` path, serial runs to `workers>1` runs, and the
TPOT memory time to the measured makespan of a trace-driven decode
stream.
"""
import numpy as np
import pytest

from repro.configs.paper_workloads import PAPER_WORKLOADS
from repro.core import analytic
from repro.core.address_map import AddressMap, channel_bytes, make_address_map
from repro.core.system_sim import SystemSim, bulk_stream_extents
from repro.core.timing import hbm4_config, rome_config
from repro.perfmodel.tpot import stream_mem_ns, xval_decode_stream
from repro.workloads import ExtentRecord, ExtentStream, bulk_stream

# (n_channels, extents) bulk-stream regimes: one contiguous stream and one
# multi-extent stream over more channels.
REGIMES = [
    (2, bulk_stream_extents(1 << 18)),
    (4, bulk_stream_extents(1 << 19, n_extents=2)),
]


def _xval(cfg, n_channels, extents, is_write):
    sim = SystemSim(cfg, n_channels=n_channels)
    r = sim.run_extents(extents, is_write=is_write)
    ana = analytic.transfer_time_ns(extents, cfg, sim.amap,
                                    is_write=is_write)
    rel = abs(r.total_ns - ana) / r.total_ns
    return r, ana, rel


@pytest.mark.parametrize("regime", range(len(REGIMES)))
@pytest.mark.parametrize("cfg_name", ["hbm4", "rome"])
def test_systemsim_matches_analytic_reads(cfg_name, regime):
    cfg = hbm4_config() if cfg_name == "hbm4" else rome_config()
    n_channels, extents = REGIMES[regime]
    r, ana, rel = _xval(cfg, n_channels, extents, is_write=False)
    assert rel < 0.10, (cfg_name, regime, r.total_ns, ana)


@pytest.mark.parametrize("cfg_name", ["hbm4", "rome"])
def test_systemsim_matches_analytic_writes(cfg_name):
    cfg = hbm4_config() if cfg_name == "hbm4" else rome_config()
    n_channels, extents = REGIMES[0]
    r, ana, rel = _xval(cfg, n_channels, extents, is_write=True)
    assert rel < 0.10, (cfg_name, r.total_ns, ana)


def test_systemsim_byte_accounting_and_channel_split():
    """Decomposition must hand every stripe unit to exactly one channel
    and agree with the vectorized channel_bytes accounting."""
    cfg = rome_config()
    sim = SystemSim(cfg, n_channels=4)
    extents = [(0, 1 << 16), (1 << 20, 3 * 4096)]
    r = sim.run_extents(extents)
    per_ch = channel_bytes(sim.amap, extents)
    # channel_bytes trims partial stripes; the sim moves whole rows.
    stripes = np.ceil(per_ch / sim.amap.stripe_bytes)
    assert np.array_equal(r.channel_bytes,
                          (stripes * sim.amap.stripe_bytes).astype(np.int64))
    assert r.bytes_moved == int(r.channel_bytes.sum())


def test_systemsim_imbalance_gates_completion():
    """An extent set that loads one channel more must finish later than a
    balanced set of the same total bytes — the LBR effect the analytic
    model encodes as max(channel_bytes)."""
    cfg = rome_config()
    sim = SystemSim(cfg, n_channels=2)
    balanced = sim.run_extents(bulk_stream_extents(1 << 18))
    # Same bytes, but every extent starts on the stripe of channel 0.
    g = cfg.ag_mc_bytes
    skewed_extents = [(2 * i * 2 * g, g) for i in range((1 << 18) // g)]
    skewed = sim.run_extents(skewed_extents)
    assert skewed.load_balance_ratio < 0.6 < balanced.load_balance_ratio
    assert skewed.total_ns > 1.5 * balanced.total_ns


def test_systemsim_honors_custom_geometry():
    """Regression: decomposition and the per-channel sims must share the
    cfg's ChannelGeometry — a non-default bank-group count used to
    produce bank ids outside the default-geometry sims' bank tables."""
    import dataclasses
    from repro.core.timing import ChannelGeometry, CubeGeometry
    geo = CubeGeometry(channels=32, channel=ChannelGeometry(bank_groups=16,
                                                            banks_per_group=4))
    cfg = dataclasses.replace(hbm4_config(), geometry=geo)
    sim = SystemSim(cfg, n_channels=2)
    r = sim.run_extents(bulk_stream_extents(1 << 14))
    assert r.total_ns > 0
    assert r.bytes_moved == 1 << 14


def test_systemsim_idle_channels_are_free():
    cfg = rome_config()
    sim = SystemSim(cfg, n_channels=8)
    r = sim.run_extents([(0, 4096)])          # one row -> one channel
    assert (r.channel_bytes > 0).sum() == 1
    assert r.total_ns > 0 and len(r.channel_results) == 1


# ---------------------------------------------------------------------------
# Stream API: run_extents wrapper identity, serial vs parallel workers
# ---------------------------------------------------------------------------

def _results_identical(a, b) -> bool:
    if (a.total_ns != b.total_ns
            or a.bytes_moved != b.bytes_moved
            or not np.array_equal(a.channel_bytes, b.channel_bytes)
            or not np.array_equal(a.channel_finish_ns, b.channel_finish_ns)
            or set(a.channel_results) != set(b.channel_results)):
        return False
    return all(np.array_equal(a.channel_results[c].finish_ns,
                              b.channel_results[c].finish_ns)
               and a.channel_results[c].cmd_counts
               == b.channel_results[c].cmd_counts
               for c in a.channel_results)


@pytest.mark.parametrize("cfg_name", ["hbm4", "rome"])
def test_run_extents_is_thin_wrapper_over_stream(cfg_name):
    """run_extents must be the one-kind-stream special case of run(),
    bit for bit, on the bulk regimes above."""
    cfg = hbm4_config() if cfg_name == "hbm4" else rome_config()
    sim = SystemSim(cfg, n_channels=2)
    extents = bulk_stream_extents(1 << 16, n_extents=2)
    for is_write in (False, True):
        kind = "write" if is_write else "read"
        via_wrapper = sim.run_extents(extents, is_write=is_write,
                                      arrival_ns=3.0)
        via_stream = sim.run(ExtentStream(
            ExtentRecord(a, n, kind, 3.0) for a, n in extents))
        assert _results_identical(via_wrapper, via_stream)


@pytest.mark.parametrize("cfg_name", ["hbm4", "rome"])
def test_parallel_workers_identical_to_serial(cfg_name):
    """Channels share no modeled resource: a process-pool run must
    reproduce the serial SystemResult exactly."""
    cfg = hbm4_config() if cfg_name == "hbm4" else rome_config()
    sim = SystemSim(cfg, n_channels=4)
    stream = bulk_stream(1 << 16, n_extents=4) + bulk_stream(
        1 << 14, kind="write", base_addr=1 << 22)
    serial = sim.run(stream, workers=1)
    parallel = sim.run(stream, workers=4)
    assert _results_identical(serial, parallel)
    assert len(serial.channel_results) == 4


# ---------------------------------------------------------------------------
# Trace-driven: TPOT memory time vs measured multi-channel makespan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mem,scale", [("hbm4", 2 ** -13),
                                       ("rome", 2 ** -11)])
def test_tpot_stream_matches_makespan(mem, scale):
    """SystemSim makespan of the from_layer_ops decode stream agrees with
    perfmodel.tpot's memory time within 15 % (byte-scaled slice of the
    DeepSeek decode trace on a 2-channel system — the shared
    xval_decode_stream regime, with HBM4 scaled further down to keep the
    tier-1 run fast; the full 2-workload sweep lives in
    benchmarks/engine_xval.py)."""
    w = PAPER_WORKLOADS["deepseek-v3"]
    stream, acc = xval_decode_stream(w, mem, scale=scale)
    assert stream.write_bytes > 0          # mixed-kind, not read-only
    res = SystemSim(acc.mem_cfg, n_channels=acc.n_channels).run(stream)
    model_ns = stream_mem_ns(stream, acc)
    assert abs(res.total_ns - model_ns) / model_ns < 0.15


# ---------------------------------------------------------------------------
# act_inflation (satellite: the parameter must actually do something)
# ---------------------------------------------------------------------------

def test_act_inflation_noop_at_unity_and_on_rome():
    amap_h = make_address_map(hbm4_config(), n_cubes=1)
    amap_r = make_address_map(rome_config(), n_cubes=1)
    ext = bulk_stream_extents(1 << 20)
    base = analytic.transfer_time_ns(ext, hbm4_config(), amap_h)
    assert analytic.transfer_time_ns(ext, hbm4_config(), amap_h,
                                     act_inflation=1.0) == base
    # RoMe's ACT count is structural: inflation must never apply.
    base_r = analytic.transfer_time_ns(ext, rome_config(), amap_r)
    assert analytic.transfer_time_ns(ext, rome_config(), amap_r,
                                     act_inflation=20.0) == base_r


def test_act_inflation_binds_hbm4_at_high_stream_counts():
    """High measured inflation (cf. energy_model.act_inflation at 32-64
    streams: 4-17x ACT/KB) must surface as an ACT-bound transfer time."""
    cfg = hbm4_config()
    amap = make_address_map(cfg, n_cubes=1)
    ext = bulk_stream_extents(1 << 20)
    base = analytic.transfer_time_ns(ext, cfg, amap)
    mild = analytic.transfer_time_ns(ext, cfg, amap, act_inflation=2.0)
    heavy = analytic.transfer_time_ns(ext, cfg, amap, act_inflation=24.0)
    assert mild == base                      # column bus still the roof
    assert heavy > 1.5 * base                # ACT path now gates
    # Monotone in inflation once binding.
    heavier = analytic.transfer_time_ns(ext, cfg, amap, act_inflation=32.0)
    assert heavier > heavy
