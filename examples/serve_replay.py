"""Serving-trace replay demo: the HBM4-vs-RoMe p99 TPOT delta under load.

    PYTHONPATH=src python examples/serve_replay.py

One command, one number: a seeded Poisson request stream runs through
the real continuous batcher + row-paged KV cache; every decode step's
multi-tenant extent stream is simulated cycle-level on both memory
systems at the paper's equal-CA-pin widths (HBM4 x 8 channels vs RoMe
x 9 — the 32:36 full-cube ratio scaled down), and the measured
makespans fold back into request timelines. Prints per-policy TTFT/TPOT
percentiles, goodput, and the headline p99 TPOT delta at a fixed
offered load. The full load sweep with reproduction bands lives in
benchmarks/serve_trace.py.
"""
import sys

sys.path.insert(0, "src")

from repro.configs.paper_workloads import REPLAY_SWEEP_MIX
from repro.serve.replay import build_replay

OFFERED_RPS = 6e5                  # fixed offered load (near saturation)
MIX = REPLAY_SWEEP_MIX             # shared with benchmarks/serve_trace.py
CELLS = {"hbm4_frfcfs": 8, "rome_qd2": 9}   # equal-pin channel widths


def main() -> int:
    p99 = {}
    for policy, nch in CELLS.items():
        eng, acc = build_replay(
            policy=policy, rate_rps=OFFERED_RPS, n_requests=8,
            kind="poisson", seed=0, mix=MIX, length_scale=1 / 16,
            scale=2 ** -12, n_channels=nch)
        res = eng.run()
        s = res.summary()
        p99[policy] = s["tpot_p99_ns"]
        print(f"[{policy} x {nch}ch] {s['completed']} requests, "
              f"{s['n_steps']} decode steps, occupancy {s['occupancy']:.2f}")
        print(f"  TTFT p50/p99: {s['ttft_p50_ns']:8.1f} / "
              f"{s['ttft_p99_ns']:8.1f} ns")
        print(f"  TPOT p50/p99: {s['tpot_p50_ns']:8.1f} / "
              f"{s['tpot_p99_ns']:8.1f} ns")
        print(f"  goodput: {s['goodput_rps']:,.0f} req/s "
              f"(offered {OFFERED_RPS:,.0f})")
    delta = p99["hbm4_frfcfs"] / p99["rome_qd2"] - 1
    verdict = "wins" if delta > 0 else "loses" if delta < 0 else "ties"
    print(f"\np99 TPOT, equal CA-pin budget at {OFFERED_RPS:,.0f} req/s: "
          f"HBM4 {p99['hbm4_frfcfs']:.1f} ns vs RoMe "
          f"{p99['rome_qd2']:.1f} ns -> RoMe {verdict} by {delta:+.1%}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
