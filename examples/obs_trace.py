"""Observability demo: one command, one Perfetto trace pair.

    PYTHONPATH=src python examples/obs_trace.py [OUT_DIR]

Runs the seeded equal-pin serve replay twice — hbm4_frfcfs x 8 channels
vs rome_qd2 x 9 (the paper's 32:36 CA-pin budget at quarter scale) —
with the full observability stack attached: a windowed
:class:`repro.obs.MetricsProbe` sampling per-channel bus utilization /
queue depth / command mix, and an :class:`repro.obs.ObsCollector`
building each request's span tree (queued -> admitted -> prefill ->
decode -> done). Exports one Chrome-trace JSON + metrics JSONL per
policy into OUT_DIR (default ``obs_out/``).

Open a trace at https://ui.perfetto.dev ("Open trace file") or in
chrome://tracing: replicas appear as processes (steps track + one
thread per request), memory channels as counter tracks. Then compare
the pair without any UI:

    python scripts/obs_report.py obs_out/hbm4_frfcfs.trace.json \\
                                 obs_out/rome_qd2.trace.json

which reproduces the HBM4-vs-RoMe row-hit-rate gap from the counter
tracks alone (docs/observability.md walks through the output).
"""
import sys

sys.path.insert(0, "src")

from repro.obs.demo import export_equal_pin_pair


def main() -> int:
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "obs_out"
    pair = export_equal_pin_pair(out_dir)
    for policy, info in pair.items():
        s = info["summary"]
        print(f"[{policy}] -> {info['trace']}")
        print(f"  {s['completed']} requests, {s['n_steps']} steps, "
              f"{s['bytes_moved']} B moved "
              f"(trace counters: {s['trace_bytes']} B)")
        print(f"  row-hit rate: probe {s['row_hit_rate']:.4f} / "
              f"trace {s['trace_row_hit_rate']:.4f}")
    gap = (pair["hbm4_frfcfs"]["summary"]["trace_row_hit_rate"]
           - pair["rome_qd2"]["summary"]["trace_row_hit_rate"])
    print(f"\nrow-hit-rate gap (HBM4 - RoMe), from the traces alone: "
          f"{gap:.4f}")
    print(f"open either file at https://ui.perfetto.dev, or run:\n"
          f"  python scripts/obs_report.py {pair['hbm4_frfcfs']['trace']} "
          f"{pair['rome_qd2']['trace']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
