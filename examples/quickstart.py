"""Quickstart: the RoMe memory system in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's core artifacts: the RD_row command expansion (Fig 9),
the 5-pin C/A result (Fig 10), MC complexity (Table IV), cycle-level
bandwidth for both controllers, and one TPOT comparison point (Fig 12).
"""
import sys

sys.path.insert(0, "src")

from repro.core import (CommandGenerator, conventional_mc_complexity,
                        engine as eng, min_ca_pins, rome_mc_complexity)
from repro.configs.paper_workloads import PAPER_WORKLOADS
from repro.perfmodel.accelerator import paper_accelerator
from repro.perfmodel.tpot import tpot_ns


def main():
    print("=== RD_row expansion (Fig 9) ===")
    cg = CommandGenerator()
    sch = cg.expand(is_write=False)
    print("first 6 commands:", sch.commands[:6])
    print(f"derived tRD_row = {cg.derived_tRD_row():.0f} ns "
          f"(Table V: 95); tR2RS = {cg.derived_tR2RS():.0f} ns "
          f"(Table V: 64)")

    print("\n=== C/A pins (Fig 10) ===")
    print(f"minimum pins sustaining 2*tRRDS: {min_ca_pins()} "
          f"(72% fewer than HBM4's 18) -> +4 channels = +12.5% bandwidth")

    print("\n=== MC complexity (Table IV) ===")
    h, r = conventional_mc_complexity(), rome_mc_complexity()
    print(f"timing params {h.n_timing_params} -> {r.n_timing_params}; "
          f"bank FSMs {h.n_bank_fsms} -> {r.n_bank_fsms}; "
          f"states {h.n_bank_states} -> {r.n_bank_states}; "
          f"queue {h.request_queue_depth} -> {r.request_queue_depth}")

    print("\n=== cycle-level channel bandwidth ===")
    hs = eng.HBM4ChannelSim(max_ref_postpone=32)
    rh = hs.run(eng.sequential_read_txns_hbm4(1 << 18))
    rs = eng.RoMeChannelSim()
    rr = rs.run(eng.sequential_read_txns_rome(1 << 20))
    print(f"HBM4 channel: {rh.bandwidth_gbps:.1f} GB/s "
          f"({rh.bandwidth_gbps/hs.g.bandwidth_gbps:.1%} of peak, "
          f"queue depth 64)")
    print(f"RoMe channel: {rr.bandwidth_gbps:.1f} GB/s "
          f"({rr.bandwidth_gbps/rs.g.bandwidth_gbps:.1%} of peak, "
          f"queue depth 2)")

    print("\n=== TPOT (Fig 12, batch 256, seq 8K) ===")
    for name, w in PAPER_WORKLOADS.items():
        th = tpot_ns(w, paper_accelerator("hbm4"), 256).total_ns
        tr = tpot_ns(w, paper_accelerator("rome"), 256).total_ns
        print(f"{name:14s}: {th/1e6:6.2f} ms -> {tr/1e6:6.2f} ms "
              f"({1-tr/th:+.1%})")


if __name__ == "__main__":
    main()
