"""End-to-end driver (brief deliverable b): train a ~100M-parameter dense
LM for a few hundred steps on the synthetic pipeline, with checkpointing.

    PYTHONPATH=src python examples/train_e2e.py --steps 300

Uses the same production train loop as launch/train.py (microbatched grad
accumulation, remat, atomic checkpoints, restart-safe data); the ~100M
config is the qwen2 family at reduced width so a CPU finishes in minutes.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.registry_configs import ALL_ARCHS
from repro.data.pipeline import make_pipeline
from repro.distributed import checkpoint as ckpt
from repro.launch.mesh import make_mesh
from repro.models.registry import get_adapter
from repro.train.train_step import make_train_step, train_state_init


def hundred_m_config():
    """qwen2-family config at ~100M params (tied embeddings)."""
    return dataclasses.replace(
        ALL_ARCHS["qwen2-7b"], name="qwen2-100m",
        n_layers=10, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab=32000, tie_embeddings=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/rome_e2e_ckpt")
    args = ap.parse_args(argv)

    cfg = hundred_m_config()
    ad = get_adapter(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    pipe = make_pipeline(cfg.vocab, args.seq_len, args.global_batch, seed=7)

    with set_mesh(mesh):
        params = ad.init(jax.random.PRNGKey(7), tp=1)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        print(f"[e2e] model: {n_params/1e6:.1f}M params")
        state = train_state_init(params)
        start = 0
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state = ckpt.restore(args.ckpt_dir, latest, state)
            start = latest + 1
            print(f"[e2e] resumed from step {latest}")
        step = jax.jit(make_train_step(
            lambda p, b: ad.loss(p, b, remat=True),
            microbatches=args.microbatches, lr=3e-4), donate_argnums=(0,))

        losses = []
        t0 = time.time()
        for i in range(start, start + args.steps):
            batch = jax.tree.map(jnp.asarray, pipe.batch_at(i))
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
            if i % 20 == 0:
                rate = args.global_batch * args.seq_len * (i - start + 1) \
                    / (time.time() - t0)
                print(f"[e2e] step {i:4d} loss {losses[-1]:.4f} "
                      f"({rate:.0f} tok/s)", flush=True)
            if (i + 1) % 100 == 0:
                ckpt.save(args.ckpt_dir, i, state)
                print(f"[e2e] checkpoint @ {i}")

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"[e2e] loss {first:.3f} -> {last:.3f} over {len(losses)} steps "
          f"({'OK' if last < first else 'NO IMPROVEMENT'})")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
