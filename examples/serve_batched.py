"""Batched serving example: continuous batching + row-paged KV accounting.

    PYTHONPATH=src python examples/serve_batched.py

Serves a reduced qwen3 (qk-norm GQA) with Orca-style iteration-level
scheduling; prints per-request completions, slot occupancy, and the
KV-cache page/DRAM-row accounting that makes every cache read a whole-row
stream (the RoMe software contract).
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main
from repro.serve.kv_cache import RowPagedKVCache, tokens_per_row

if __name__ == "__main__":
    # Page math demo: one decode layer's K for a 4-kv-head, hd=128 arch
    tpr = tokens_per_row(head_dim=128, n_kv_heads=4, itemsize=2)
    print(f"[kv] tokens per 4 KB DRAM row (kv=4, hd=128, bf16): {tpr}")
    pool = RowPagedKVCache(n_pages=64, page_tokens=tpr, n_kv_heads=4,
                           head_dim=128, max_seqs=8, max_pages_per_seq=16)
    print(f"[kv] page = {pool.page_bytes} B = {pool.rows_per_page()} "
          f"DRAM row(s)")
    raise SystemExit(main(["--arch", "qwen3-14b", "--reduced",
                           "--requests", "10", "--slots", "4",
                           "--max-new", "16"]))
