"""Fleet-scale serving demo: N replicas behind a router, HBM4 vs RoMe.

    PYTHONPATH=src python examples/cluster_sweep.py

One command, one table: a seeded bursty request stream is routed across
a small fleet of replica cubes (each a continuous batcher + row-paged
KV pool + the shared weight slice), every replica's decode steps are
priced in batched hybrid-mode SystemSim calls, and the folded timelines
print fleet goodput and tail latencies per memory system and router.
The full sweep with reproduction bands and the million-request scale
cell lives in benchmarks/cluster_sweep.py.
"""
import sys

sys.path.insert(0, "src")

from repro.serve.cluster import ClusterSim

CELLS = {"hbm4_frfcfs": 8, "rome_qd2": 9}   # equal-pin channel widths
ROUTERS = ("round_robin", "least_kv")
N_REPLICAS = 4
N_REQUESTS = 400
OFFERED_RPS = 4e5


def main() -> int:
    goodput = {}
    for policy, nch in CELLS.items():
        for router in ROUTERS:
            cs = ClusterSim(policy=policy, n_channels=nch, router=router,
                            n_replicas=N_REPLICAS, n_requests=N_REQUESTS,
                            rate_rps=OFFERED_RPS, kind="bursty",
                            burst_size=8, seed=0, scale=1.0,
                            sim_mode="hybrid", length_scale=1 / 64,
                            n_slots=8)
            r = cs.run()
            s = r.summary()
            goodput[(policy, router)] = s["goodput_rps"]
            print(f"[{policy} x {nch}ch | {router:>12}] "
                  f"{s['completed']}/{s['n_requests']} done in "
                  f"{s['n_steps']} steps, goodput {s['goodput_rps']:,.0f} "
                  f"rps, TTFT p99 {s['ttft_p99_ns']:,.0f} ns, "
                  f"TPOT p99 {s['tpot_p99_ns']:,.0f} ns "
                  f"(load share max {s['max_replica_share']:.2f}, "
                  f"pricer hits {s.get('pricer_hit_rate', 0):.0%})")
    for router in ROUTERS:
        h = goodput[("hbm4_frfcfs", router)]
        m = goodput[("rome_qd2", router)]
        print(f"fleet goodput RoMe/HBM4 under {router}: {m / h:.3f}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
