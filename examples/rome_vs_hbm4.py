"""Full RoMe-vs-HBM4 simulation walkthrough (the paper's evaluation, end
to end):

    PYTHONPATH=src python examples/rome_vs_hbm4.py

1. calibrates both controllers with the cycle-level engine (one shared
   scheduler core, per-controller policies — repro.core.sched),
2. cross-checks the extent-level analytic model against the multi-channel
   SystemSim ground truth,
3. builds the *timed* decode ExtentStream (repro.workloads) for a paper
   LLM and validates the TPOT memory time against the cycle-accurate
   multi-channel makespan of that same stream,
4. builds per-device layer-op traces for the three paper LLMs,
5. reports TPOT (Fig 12), LBR (Fig 13), and energy (Fig 14) side by side.
"""
import sys

sys.path.insert(0, "src")

from repro.configs.paper_workloads import PAPER_WORKLOADS
from repro.core.analytic import calibrate_hbm4, calibrate_rome, transfer_time_ns
from repro.core.system_sim import SystemSim, bulk_stream_extents
from repro.core.timing import hbm4_config, rome_config
from repro.perfmodel.accelerator import paper_accelerator
from repro.perfmodel.energy_model import decode_energy
from repro.perfmodel.lbr import lbr_by_kind
from repro.perfmodel.tpot import stream_mem_ns, tpot_ns, xval_decode_stream


def main():
    print("=== channel calibration (cycle-level engine) ===")
    h, r = calibrate_hbm4(), calibrate_rome()
    print(f"HBM4: read eff {h.read_eff:.3f}, ACT/KB {h.act_per_kb:.2f}")
    print(f"RoMe: read eff {r.read_eff:.3f}, ACT/KB {r.act_per_kb:.2f} "
          f"(structural minimum: 0.5)")

    print("\n=== extent-level ground truth (multi-channel SystemSim) ===")
    extents = bulk_stream_extents(1 << 18)
    for name, cfg in (("HBM4", hbm4_config()), ("RoMe", rome_config())):
        sim = SystemSim(cfg, n_channels=2)
        res = sim.run_extents(extents)
        ana = transfer_time_ns(extents, cfg, sim.amap)
        print(f"{name}: 256 KB over 2 channels — SystemSim "
              f"{res.total_ns:.0f} ns ({res.bandwidth_gbps:.1f} GB/s, "
              f"LBR {res.load_balance_ratio:.3f}) vs analytic "
              f"{ana:.0f} ns ({abs(res.total_ns - ana) / res.total_ns:.1%} off)")

    print("\n=== trace-driven stream (decode TPOT vs measured makespan) ===")
    w = PAPER_WORKLOADS["deepseek-v3"]
    for mem in ("HBM4", "RoMe"):
        # Timed, typed ExtentStream of the scaled decode slice (the same
        # regime benchmarks/engine_xval.py asserts its 15 % band on).
        stream, acc = xval_decode_stream(w, mem.lower())
        res = SystemSim(acc.mem_cfg,
                        n_channels=acc.n_channels).run(stream, workers=2)
        model = stream_mem_ns(stream, acc)
        print(f"{mem}: {len(stream)} records, {stream.total_bytes >> 10} KB "
              f"(reads+writes) — makespan {res.total_ns:.0f} ns vs TPOT "
              f"memory time {model:.0f} ns "
              f"({abs(res.total_ns - model) / model:.1%} off)")

    acc_h, acc_r = paper_accelerator("hbm4"), paper_accelerator("rome")
    for name, w in PAPER_WORKLOADS.items():
        print(f"\n=== {name} (batch 256, seq 8K, 8 accelerators) ===")
        th = tpot_ns(w, acc_h, 256)
        tr = tpot_ns(w, acc_r, 256)
        print(f"TPOT: {th.total_ns/1e6:.2f} ms -> {tr.total_ns/1e6:.2f} ms"
              f"  ({1 - tr.total_ns/th.total_ns:+.1%}; paper ~-10%)")
        lbr = lbr_by_kind(w, 256)
        print(f"LBR (vs HBM4): attn {lbr['attn']:.3f}  ffn {lbr['ffn']:.3f}")
        e = decode_energy(w, 256)
        print(f"energy: total x{e['total_ratio']:.3f}, "
              f"ACT x{e['act_ratio']:.3f} "
              f"(paper ACT: 0.555/0.860/0.844), "
              f"overfetch {e['overfetch_frac']:.2%}")


if __name__ == "__main__":
    main()
