#!/usr/bin/env python
"""Benchmark regression gate: diff fresh ``benchmarks.run --json``
payloads against committed baselines with per-metric tolerance bands.

Usage::

    python scripts/bench_compare.py bench_engine_xval.json [more.json ...] \
        [--baseline-dir benchmarks/baselines] [--default-rel-tol 0.05] \
        [--summary $GITHUB_STEP_SUMMARY] [--write-baseline]

For every benchmark present in a fresh payload that has a committed
baseline (``<baseline-dir>/<benchmark>.json``), every numeric metric in
the baseline is compared against the fresh value: a metric is a
regression when ``|fresh - base| > tol * max(|base|, 1e-12)`` with
``tol`` resolved from the baseline's ``tolerances`` glob map (first
match wins) or its ``rel_tol`` default. Wall-time / worker-count leaves
are never gated (machine-dependent); everything else the simulators
emit is deterministic, so the default band is tight.

The gate also *refuses* any payload whose top-level ``"status"`` is not
``"pass"`` — benchmarks.run writes that field via try/finally, so a
band failure (or a crash after a partial JSON dump) can never hide
behind an ``always()`` artifact-upload step in CI.

A markdown delta table is printed and, with ``--summary PATH``,
appended to that file (point it at ``$GITHUB_STEP_SUMMARY``).
Exit status: 0 clean, 1 regression / bad status, 2 usage error.

``--write-baseline`` (re)generates the baseline files from the fresh
payloads instead of comparing — run it locally after an intentional
behaviour change and commit the result.
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import os
import sys

SKIP_LEAVES = {"wall_s", "total_wall_s", "workers"}
DEFAULT_BASELINE_DIR = os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks", "baselines")


def flatten_metrics(obj, prefix: str = "") -> dict[str, float]:
    """Dotted-path -> value map of the numeric leaves of a results dict,
    skipping machine-dependent leaves (wall time, worker counts)."""
    out: dict[str, float] = {}
    if isinstance(obj, dict):
        items = obj.items()
    elif isinstance(obj, (list, tuple)):
        items = ((str(i), v) for i, v in enumerate(obj))
    else:
        items = ()
    for k, v in items:
        key = str(k)
        if key in SKIP_LEAVES:
            continue
        path = f"{prefix}.{key}" if prefix else key
        if isinstance(v, bool):
            continue
        if isinstance(v, (int, float)):
            out[path] = float(v)
        elif isinstance(v, (dict, list, tuple)):
            out.update(flatten_metrics(v, path))
    return out


def tolerance_for(path: str, baseline: dict, default_rel_tol: float) -> float:
    for pattern, tol in baseline.get("tolerances", {}).items():
        if fnmatch.fnmatch(path, pattern):
            return float(tol)
    return float(baseline.get("rel_tol", default_rel_tol))


def compare_benchmark(name: str, fresh_entry: dict, baseline: dict,
                      default_rel_tol: float) -> list[dict]:
    """Rows for one benchmark: every baseline metric vs the fresh run."""
    rows = []
    fresh = flatten_metrics(fresh_entry.get("results", {}))
    for path, base in sorted(baseline.get("metrics", {}).items()):
        tol = tolerance_for(path, baseline, default_rel_tol)
        row = {"benchmark": name, "metric": path, "baseline": base,
               "tol": tol}
        if path not in fresh:
            row.update(fresh=None, delta_frac=None, ok=False,
                       note="metric missing from fresh run")
        else:
            new = fresh[path]
            delta = abs(new - base) / max(abs(base), 1e-12)
            row.update(fresh=new, delta_frac=delta, ok=delta <= tol,
                       note="")
        rows.append(row)
    return rows


def load_payload(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _fmt(v) -> str:
    if v is None:
        return "—"
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.6g}"


def markdown_table(rows: list[dict], only_failures: bool = False) -> str:
    lines = ["| benchmark | metric | baseline | fresh | Δ | tol | verdict |",
             "|---|---|---|---|---|---|---|"]
    for r in rows:
        if only_failures and r["ok"]:
            continue
        delta = ("—" if r["delta_frac"] is None
                 else f"{r['delta_frac']:+.2%}".replace("+", ""))
        verdict = "✅" if r["ok"] else f"❌ {r['note'] or 'out of band'}"
        lines.append(f"| {r['benchmark']} | `{r['metric']}` | "
                     f"{_fmt(r['baseline'])} | {_fmt(r['fresh'])} | "
                     f"{delta} | {r['tol']:.0%} | {verdict} |")
    return "\n".join(lines)


def write_baselines(payloads: dict[str, dict], baseline_dir: str,
                    default_rel_tol: float) -> list[str]:
    os.makedirs(baseline_dir, exist_ok=True)
    written = []
    for _, payload in payloads.items():
        for bench, entry in payload.get("benchmarks", {}).items():
            if entry.get("status") != "PASS":
                print(f"refusing to baseline {bench}: status "
                      f"{entry.get('status')!r}", file=sys.stderr)
                continue
            path = os.path.join(baseline_dir, f"{bench}.json")
            # Regeneration refreshes the metric values but must keep any
            # hand-tuned tolerance overrides from the existing baseline.
            rel_tol, tolerances = default_rel_tol, {}
            if os.path.exists(path):
                with open(path) as f:
                    prev = json.load(f)
                rel_tol = prev.get("rel_tol", rel_tol)
                tolerances = prev.get("tolerances", tolerances)
            out = {"benchmark": bench,
                   "rel_tol": rel_tol,
                   "tolerances": tolerances,
                   "metrics": flatten_metrics(entry.get("results", {}))}
            with open(path, "w") as f:
                json.dump(out, f, indent=1, sort_keys=True)
                f.write("\n")
            written.append(path)
    return written


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("fresh", nargs="+",
                   help="bench_*.json payloads from benchmarks.run --json")
    p.add_argument("--baseline-dir", default=DEFAULT_BASELINE_DIR)
    p.add_argument("--default-rel-tol", type=float, default=0.05)
    p.add_argument("--summary", default=None,
                   help="append the markdown delta table to this file "
                        "(e.g. $GITHUB_STEP_SUMMARY)")
    p.add_argument("--write-baseline", action="store_true",
                   help="(re)generate baselines from the fresh payloads "
                        "instead of comparing")
    args = p.parse_args(argv)

    try:
        payloads = {path: load_payload(path) for path in args.fresh}
    except (OSError, json.JSONDecodeError) as e:
        print(f"cannot load fresh payload: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        for path in write_baselines(payloads, args.baseline_dir,
                                    args.default_rel_tol):
            print(f"wrote {path}")
        return 0

    failures = []
    rows: list[dict] = []
    compared = 0
    for path, payload in payloads.items():
        # Explicit status gate: a payload that says anything but "pass"
        # is a failure regardless of metric deltas (see benchmarks.run).
        status = payload.get("status")
        if status != "pass":
            failures.append(f"{path}: payload status is {status!r} "
                            f"(expected 'pass')")
        if not payload.get("benchmarks"):
            failures.append(f"{path}: payload contains no benchmarks "
                            f"(empty selection / pattern typo?)")
        for bench, entry in payload.get("benchmarks", {}).items():
            if entry.get("status") != "PASS":
                failures.append(f"{path}: benchmark {bench} status "
                                f"{entry.get('status')!r}")
            bfile = os.path.join(args.baseline_dir, f"{bench}.json")
            if not os.path.exists(bfile):
                print(f"note: no baseline for {bench} ({bfile}), skipping")
                continue
            with open(bfile) as f:
                baseline = json.load(f)
            bench_rows = compare_benchmark(bench, entry, baseline,
                                           args.default_rel_tol)
            rows.extend(bench_rows)
            compared += 1
    if compared == 0:
        # A gate that compared nothing must not pass: a renamed
        # benchmark or a ci.yml pattern typo would otherwise disable
        # gating silently and forever.
        failures.append("no benchmark was compared against a baseline "
                        "(rename/typo? regenerate with --write-baseline)")
    failures.extend(f"{r['benchmark']}.{r['metric']}: "
                    f"baseline {_fmt(r['baseline'])}, fresh "
                    f"{_fmt(r['fresh'])} ({r['note'] or 'out of band'})"
                    for r in rows if not r["ok"])

    n_bad = sum(not r["ok"] for r in rows)
    header = (f"## Benchmark regression gate\n\n"
              f"{compared} benchmark(s) compared, {len(rows)} metric(s), "
              f"{n_bad} out of band, "
              f"{len(failures)} failure(s) total.\n\n")
    per_bench: dict[str, list] = {}
    for r in rows:
        per_bench.setdefault(r["benchmark"], []).append(r)
    summary_lines = [
        f"- `{b}`: {sum(r['ok'] for r in rs)}/{len(rs)} metrics in band"
        for b, rs in sorted(per_bench.items())]
    body = header + "\n".join(summary_lines)
    if n_bad:
        body += "\n\n" + markdown_table(rows, only_failures=True)
    elif rows and len(rows) <= 60:
        body += "\n\n" + markdown_table(rows)
    if not rows:
        body += "_no baselined metrics matched_"
    print(body)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(body + "\n")

    if failures:
        print("\nREGRESSION GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("\nregression gate clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
