#!/usr/bin/env python
"""Run the repo-invariant AST lints (repro.analysis.lints).

Usage:
    PYTHONPATH=src python scripts/lint.py [paths...]

Defaults to the whole checked tree (src, benchmarks, scripts, tests)
plus the markdown docs (README.md, docs/, benchmarks/README.md), which
get the doc rules: fenced ```python blocks must ast.parse, and every
repo path a doc names must exist. Exits 1 if any finding fires; prints
``path:line: [rule] message`` lines.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis.lints import lint_docs, lint_paths  # noqa: E402

DEFAULT_PATHS = ("src", "benchmarks", "scripts", "tests")
DEFAULT_DOC_PATHS = ("README.md", "docs", "benchmarks")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", help="files or directories "
                    "(default: %s)" % " ".join(DEFAULT_PATHS))
    args = ap.parse_args(argv)
    explicit = [Path(p) for p in args.paths]
    paths = explicit or [REPO / p for p in DEFAULT_PATHS]
    doc_paths = explicit or [REPO / p for p in DEFAULT_DOC_PATHS]
    findings = lint_paths(p for p in paths if p.exists())
    findings += lint_docs((p for p in doc_paths if p.exists()),
                          repo_root=REPO)
    for f in findings:
        try:
            shown = f._replace(path=str(Path(f.path).relative_to(REPO)))
        except ValueError:
            shown = f
        print(shown)
    if findings:
        print(f"\nlint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("lint: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
