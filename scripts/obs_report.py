#!/usr/bin/env python
"""Render per-run summaries from exported Chrome-trace JSON.

Everything here is computed **from the trace alone** — no simulator
state is consulted — which is the point: the exported counters and
spans must carry enough to re-derive the headline diagnostics
(docs/observability.md):

* per-channel utilization timelines (text sparkline per channel),
* the row-hit rate, recomputed from the cumulative ``row_hits`` /
  ``col_cmds`` counter tracks — across two traces this reproduces the
  HBM4-vs-RoMe locality gap,
* tail-step attribution: the p99-duration step, the requests it was
  serving, and the channel that moved the most bytes during it.

Usage::

    python scripts/obs_report.py TRACE.json [TRACE2.json ...]
    python scripts/obs_report.py --run OUT_DIR   # build the seeded
        # equal-pin hbm4_frfcfs-vs-rome_qd2 pair first, then report it

With two or more traces the report ends with a cross-run comparison
table (row-hit rate, bytes, makespan).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.export import (counter_final, counter_series,  # noqa: E402
                              load_chrome_trace, slices,
                              trace_row_hit_rate, trace_total_bytes)

SPARK = " .:-=+*#%@"


def _sparkline(values, width: int = 48) -> str:
    if not values:
        return ""
    # Downsample to `width` buckets by mean.
    n = len(values)
    buckets = []
    for b in range(min(width, n)):
        lo = b * n // min(width, n)
        hi = max(lo + 1, (b + 1) * n // min(width, n))
        buckets.append(sum(values[lo:hi]) / (hi - lo))
    return "".join(SPARK[min(len(SPARK) - 1,
                             int(v * (len(SPARK) - 1) + 0.5))]
                   for v in buckets)


def _percentile(sorted_vals, q: float):
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * (len(sorted_vals) - 1)))
    return sorted_vals[idx]


def _channel_bytes_in(series: dict, c: int, t0_us: float,
                      t1_us: float) -> float:
    """Bytes channel ``c`` moved inside [t0, t1], off its cumulative
    byte counter (piecewise-constant readback: delta of the bounding
    samples)."""
    pts = series.get(f"ch{c} bytes", [])
    before = 0
    last_in = None
    for ts, v in pts:
        if ts <= t0_us:
            before = v
        if ts <= t1_us:
            last_in = v
    return (last_in - before) if last_in is not None else 0


def report_one(path: str) -> dict:
    trace = load_chrome_trace(path)
    series = counter_series(trace)
    label = trace.get("otherData", {}).get("label", "") or path
    sl = slices(trace)
    steps = sorted((e for e in sl if e.get("cat") == "step"),
                   key=lambda e: e["ts"])
    reqs = [e for e in sl if e.get("cat") == "request"]
    makespan_us = max((e["ts"] + e["dur"] for e in sl), default=0.0)
    hit = trace_row_hit_rate(trace)
    total_bytes = trace_total_bytes(trace)

    print(f"== {label} ==")
    print(f"  trace: {path}")
    print(f"  makespan: {makespan_us:.1f} us   requests: {len(reqs)}   "
          f"steps: {len(steps)}")
    print(f"  bytes (channel counter integral): {total_bytes}")
    print(f"  row-hit rate (from counters alone): {hit:.4f}")

    channels = sorted({int(n[2:].split()[0]) for n in series
                       if n.startswith("ch") and n.endswith(" util")})
    for c in channels:
        utils = [v for _, v in series[f"ch{c} util"]]
        mean_u = sum(utils) / len(utils) if utils else 0.0
        print(f"  ch{c} util [{_sparkline(utils)}] mean {mean_u:.2f}")

    p99 = None
    if steps:
        durs = sorted(e["dur"] for e in steps)
        cut = _percentile(durs, 0.99)
        p99 = max((e for e in steps if e["dur"] >= cut),
                  key=lambda e: e["dur"])
        args = p99.get("args", {})
        owners = args.get("active", [])
        t0, t1 = p99["ts"], p99["ts"] + p99["dur"]
        by_ch = {c: _channel_bytes_in(series, c, t0, t1)
                 for c in channels}
        top = max(by_ch, key=by_ch.get) if by_ch else None
        print(f"  p99 step: {args.get('kind', '?')} "
              f"{p99['name']} dur {p99['dur']:.2f} us "
              f"({args.get('n_active', 0)} active, "
              f"{args.get('n_prefill', 0)} prefill chunks)")
        print(f"    owning requests: {owners}")
        if top is not None:
            print(f"    busiest channel: ch{top} "
                  f"({int(by_ch[top])} B in the step window)")
    print()
    return {"label": label, "row_hit_rate": hit, "bytes": total_bytes,
            "makespan_us": makespan_us, "n_requests": len(reqs),
            "n_steps": len(steps)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("traces", nargs="*", help="Chrome-trace JSON files")
    ap.add_argument("--run", metavar="OUT_DIR",
                    help="first build the seeded equal-pin "
                         "hbm4_frfcfs-vs-rome_qd2 pair into OUT_DIR "
                         "(examples/obs_trace.py does the same), then "
                         "report it")
    ap.add_argument("--json", action="store_true",
                    help="also print the summary dict as JSON")
    args = ap.parse_args(argv)
    paths = list(args.traces)
    if args.run:
        from repro.obs.demo import export_equal_pin_pair
        pair = export_equal_pin_pair(args.run)
        paths += [v["trace"] for v in pair.values()]
    if not paths:
        ap.error("no traces given (pass files or --run OUT_DIR)")
    reports = [report_one(p) for p in paths]
    if len(reports) >= 2:
        print("== cross-run comparison ==")
        w = max(len(r["label"]) for r in reports)
        print(f"  {'run'.ljust(w)}  row_hit  bytes        makespan_us")
        for r in reports:
            print(f"  {r['label'].ljust(w)}  {r['row_hit_rate']:.4f}   "
                  f"{str(r['bytes']).ljust(11)}  "
                  f"{r['makespan_us']:.1f}")
        hits = {r["label"]: r["row_hit_rate"] for r in reports}
        hi, lo = max(hits.values()), min(hits.values())
        print(f"  row-hit-rate gap (max - min): {hi - lo:.4f}")
    if args.json:
        print(json.dumps(reports, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
