#!/usr/bin/env bash
# Tier-1 verification gate (ROADMAP.md): repo lints, then the test suite.
# Usage: scripts/check.sh [pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/lint.py
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
