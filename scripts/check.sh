#!/usr/bin/env bash
# Tier-1 verification gate (ROADMAP.md). Usage: scripts/check.sh [pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
