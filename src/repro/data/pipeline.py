"""Deterministic synthetic token pipeline.

Generates reproducible token streams (a fixed-seed Zipfian-ish mixture so
losses are learnable, not uniform noise), sharded by host: each host
materializes only its slice of the global batch — the pattern a real
multi-host input pipeline (e.g. grain/tf.data) uses at scale. Restart-safe:
the stream is a pure function of (seed, step), so resuming from a
checkpoint at step k regenerates exactly the batches k, k+1, ...
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def batch_at(self, step: int) -> dict:
        """{'tokens': (host_batch, seq), 'labels': (host_batch, seq)} for
        this host at `step` — pure function of (seed, step, host_id)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        b, s = self.host_batch, self.seq_len
        # Zipf-like marginal over a smallish head + uniform tail, plus a
        # copy structure (next token repeats prev with p=0.3) so a model
        # can actually reduce loss.
        head = min(self.vocab, 1024)
        p = 1.0 / np.arange(1, head + 1)
        p /= p.sum()
        base = rng.choice(head, size=(b, s), p=p).astype(np.int32)
        shift = np.roll(base, 1, axis=1)
        copy_mask = rng.random((b, s)) < 0.3
        tokens = np.where(copy_mask, shift, base).astype(np.int32)
        labels = np.roll(tokens, -1, axis=1).astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_pipeline(vocab: int, seq_len: int, global_batch: int, seed: int = 0,
                  n_hosts: int = 1, host_id: int = 0) -> SyntheticTokens:
    return SyntheticTokens(vocab, seq_len, global_batch, seed, n_hosts,
                           host_id)
