"""Windowed channel telemetry: fold sampled engine state into series.

:class:`MetricsProbe` is the consumer side of the sampling seam in
:class:`repro.core.sched.ChannelRunState`: attach one to a
:class:`~repro.core.system_sim.SystemSim` (``sim.attach_probe(probe)``)
and every cycle-path channel run samples its state — ``(t_ns,
queue_depth, ref_backlog, draining, counts_snapshot)`` — once per
``window_ns`` crossing. The probe diffs successive snapshots into
per-window **deltas** (:class:`ChannelWindow`): command mix, bytes
moved, data-bus utilization, row-hit rate, plus the sampled queue
depth / refresh backlog / write-drain residency scalars at the window
close. Sampling never alters simulated results (asserted bit-identical
in tests/test_obs.py) and costs one always-false float compare per
event-loop iteration when detached.

Byte accounting is exact by construction: RD/WR are pure data-burst
counters in both controller families (RoMe's refresh path emits row
commands but never RD/WR), so a channel's bytes are apportioned over
windows proportionally to the cumulative Δ(RD+WR) with telescoping
integer rounding — per-channel window bytes sum to the channel's
``bytes_moved`` exactly, and the probe's total reconciles with
:attr:`SystemResult.bytes_moved` (the exporter round-trip test pins
this). Analytically priced runs
issue no commands; the probe records their step-level aggregates only
(:class:`StepSample`), so hybrid runs keep a complete step timeline
with channel telemetry wherever the cycle engine ran.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .metrics import is_highwater


@dataclass(frozen=True)
class StepSample:
    """Step-level aggregate of one observed run/step."""

    start_ns: float        # step start on the observation clock
    total_ns: float        # step makespan (memory time)
    bytes_moved: int
    mode: str              # "cycle" | "analytic" — the pricing path taken
    queue_pressure: float

    @property
    def end_ns(self) -> float:
        return self.start_ns + self.total_ns


@dataclass(frozen=True)
class ChannelWindow:
    """One telemetry window on one channel — deltas between two
    successive engine-state samples, placed on the observation clock."""

    channel: int
    t0_ns: float
    t1_ns: float
    cmds: dict             # per-window counter deltas (ACT/RD/WR/...)
    bytes_moved: int       # exact: windows sum to the channel's total
    busy_ns: float         # data-bus busy time implied by bytes_moved
    queue_depth: int       # outstanding txns at window close
    ref_backlog: int       # refresh debt at window close
    draining: bool         # write-drain FSM residency at window close

    @property
    def dur_ns(self) -> float:
        return self.t1_ns - self.t0_ns

    @property
    def utilization(self) -> float:
        """Data-bus busy fraction in the window, clamped to [0, 1]."""
        d = self.dur_ns
        if d <= 0.0:
            return 1.0 if self.busy_ns > 0 else 0.0
        return min(1.0, self.busy_ns / d)

    @property
    def col_cmds(self) -> int:
        """Data accesses in the window (HBM4: RD+WR column bursts; RoMe:
        *data* row commands — refresh also emits row commands, one per
        two REFpb, so its share is subtracted)."""
        if "row_commands" in self.cmds:
            return max(0, self.cmds.get("row_commands", 0)
                       - self.cmds.get("REFpb", 0) // 2)
        return self.cmds.get("RD", 0) + self.cmds.get("WR", 0)

    @property
    def row_hits(self) -> int:
        """Accesses served from an open row (0 by construction for
        row-granular controllers — every access precharges)."""
        if "row_commands" in self.cmds:
            return 0
        return max(0, self.col_cmds - self.cmds.get("ACT", 0))

    @property
    def row_hit_rate(self) -> float:
        c = self.col_cmds
        return self.row_hits / c if c > 0 else 0.0


@dataclass
class MetricsProbe:
    """Collects windowed channel telemetry and step samples.

    ``window_ns`` is the sampling window threaded into every channel
    sim while the probe is attached. ``channel_bw_gbps`` (B/ns) is the
    utilization denominator; :meth:`SystemSim.attach_probe` fills it
    from the config when unset. One probe may observe many runs (a whole
    replay); :meth:`reset` clears it for reuse.
    """

    window_ns: float = 1000.0
    channel_bw_gbps: float | None = None
    windows: list = field(default_factory=list)   # ChannelWindow, fold order
    steps: list = field(default_factory=list)     # StepSample, observe order

    def __post_init__(self):
        if self.window_ns <= 0:
            raise ValueError(
                f"window_ns must be > 0, got {self.window_ns}")

    # -- folding -----------------------------------------------------------

    def observe_run(self, res, t0: float = 0.0,
                    start_ns: float | None = None) -> None:
        """Fold one :class:`SystemResult` into the probe. ``t0`` shifts
        the run's channel-telemetry clocks onto the observation clock
        (reset-mode steps are simulated rebased to 0 — pass the step
        start; warm sessions already run absolute, pass 0). ``start_ns``
        is the step's start for the step timeline (defaults to ``t0``)."""
        start = float(t0 if start_ns is None else start_ns)
        self.steps.append(StepSample(
            start_ns=start, total_ns=float(res.total_ns),
            bytes_moved=int(res.bytes_moved), mode=res.mode,
            queue_pressure=float(res.queue_pressure)))
        for c, r in sorted(res.channel_results.items()):
            self._fold_channel(c, r, float(t0))

    def _fold_channel(self, c: int, r, t0: float) -> None:
        samples = r.samples
        n_txns = len(r.finish_ns)
        total_b = int(r.bytes_moved)
        bw = self.channel_bw_gbps
        if not samples:
            # Sampling was off (or the slice is empty): one synthetic
            # window covering the run keeps aggregates exact.
            if n_txns:
                self.windows.append(ChannelWindow(
                    c, t0, t0 + float(r.total_ns), dict(r.cmd_counts),
                    total_b, total_b / bw if bw else 0.0, 0, 0, False))
            return
        # The slice leads with its baseline snapshot (cumulative counts
        # at feed time); r.cmd_counts holds this feed's true-counter
        # deltas, so base + delta is the exact final snapshot — the tail
        # window runs from the last crossing to the drain.
        base_t, _, _, _, base_snap = samples[0]
        final_snap = dict(base_snap)
        for k, v in r.cmd_counts.items():
            if is_highwater(k):
                final_snap[k] = v
            else:
                final_snap[k] = base_snap.get(k, 0) + v
        last = samples[-1]
        seq = list(samples)
        t_end = max(float(r.total_ns), last[0])
        seq.append((t_end, 0, last[2], False, final_snap))
        # RD/WR are pure data-burst counters in every policy (refresh
        # never bumps them), so bytes apportion over windows by the
        # cumulative data-burst fraction — integer rounding telescopes,
        # the last window lands exactly on total_b.
        data_total = (r.cmd_counts.get("RD", 0)
                      + r.cmd_counts.get("WR", 0))
        cum_data = cum_b = 0
        prev_t, _, _, _, prev_snap = seq[0]
        for t, q, backlog, draining, snap in seq[1:]:
            cmds = {k: v - prev_snap.get(k, 0) for k, v in snap.items()
                    if not is_highwater(k)}
            data = cmds.get("RD", 0) + cmds.get("WR", 0)
            if t <= prev_t and not any(cmds.values()):
                continue          # coincident marker, nothing happened
            cum_data += data
            b = 0
            if data_total:
                new_cum_b = total_b * cum_data // data_total
                b, cum_b = new_cum_b - cum_b, new_cum_b
            self.windows.append(ChannelWindow(
                c, t0 + prev_t, t0 + t, cmds, b,
                b / bw if bw else 0.0, int(q), int(backlog),
                bool(draining)))
            prev_t, prev_snap = t, snap

    # -- views -------------------------------------------------------------

    def channel_series(self, channel: int) -> list:
        """This channel's windows, time-ordered."""
        return sorted((w for w in self.windows if w.channel == channel),
                      key=lambda w: w.t0_ns)

    def channels(self) -> list:
        return sorted({w.channel for w in self.windows})

    def totals(self) -> dict:
        """Aggregate over every observed window + step: summed counter
        deltas, exact bytes, row-hit census, step bytes/time."""
        cmds: dict = {}
        bytes_w = 0
        hits = cols = 0
        for w in self.windows:
            for k, v in w.cmds.items():
                cmds[k] = cmds.get(k, 0) + v
            bytes_w += w.bytes_moved
            hits += w.row_hits
            cols += w.col_cmds
        return {
            "cmds": cmds,
            "window_bytes": bytes_w,
            "row_hits": hits,
            "col_cmds": cols,
            "step_bytes": sum(s.bytes_moved for s in self.steps),
            "step_mem_ns": sum(s.total_ns for s in self.steps),
            "n_steps": len(self.steps),
            "n_windows": len(self.windows),
        }

    def row_hit_rate(self) -> float:
        """Aggregate row-hit rate over every observed window."""
        t = self.totals()
        return t["row_hits"] / t["col_cmds"] if t["col_cmds"] else 0.0

    def reset(self) -> None:
        self.windows.clear()
        self.steps.clear()


__all__ = ["MetricsProbe", "ChannelWindow", "StepSample"]
