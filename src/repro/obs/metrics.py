"""The observability metric registry.

Every command-count key a scheduler policy may emit into the shared
``counts`` dict (:class:`repro.core.sched.SchedulerPolicy.count_keys`
plus the engine-owned keys) is declared here, with its semantics and
whether it is a monotone **counter** (window deltas are meaningful) or a
session **high-water mark** (only the cumulative value is; diffing it
across telemetry windows would be nonsense). The probe consults this
table when folding sampled snapshots into per-window deltas, and
``scripts/lint.py`` (rule ``untracked-counter``) fails the build if a
policy grows a counts key that is not declared here — a silently
untracked counter would vanish from every trace and report.

Adding a counter: add the policy emission *and* a :class:`MetricSpec`
row in the same change; the lint rule enforces exactly that.
"""
from __future__ import annotations

from dataclasses import dataclass

#: Metric kinds. ``counter`` — monotone within a session; per-window
#: deltas are the time-resolved series. ``highwater`` — a running max;
#: never diffed, always reported cumulatively.
COUNTER = "counter"
HIGHWATER = "highwater"


@dataclass(frozen=True)
class MetricSpec:
    """One registered counts key."""

    name: str
    kind: str          # COUNTER or HIGHWATER
    description: str


#: name -> MetricSpec for every counts key any registered policy emits.
COUNTER_REGISTRY: dict[str, MetricSpec] = {
    m.name: m for m in (
        MetricSpec("ACT", COUNTER,
                   "row activations (HBM4: one per row miss; RoMe: two "
                   "per row command, one per pseudo-channel half)"),
        MetricSpec("RD", COUNTER,
                   "column read bursts issued on the data bus"),
        MetricSpec("WR", COUNTER,
                   "column write bursts issued on the data bus"),
        MetricSpec("PRE", COUNTER,
                   "precharges (explicit or auto, incl. refresh-forced)"),
        MetricSpec("REFpb", COUNTER,
                   "per-bank refreshes issued by the bounded-postponement "
                   "governor (RoMe pays two per rotation unit)"),
        MetricSpec("ca_commands", COUNTER,
                   "command/address bus slots consumed (the C/A pressure "
                   "census behind Fig. 5)"),
        MetricSpec("row_commands", COUNTER,
                   "RoMe row-granular RD_row/WR_row commands — one per "
                   "4 KB row access; its presence marks a row-granular "
                   "(always-precharge) controller"),
        MetricSpec("drain_entries", COUNTER,
                   "write-drain FSM entries (hbm4_writedrain: hi-watermark "
                   "crossings that flip the channel into drain mode)"),
        MetricSpec("sid_switches", COUNTER,
                   "cross-SID burst-group switches (hbm4_sidgroup: each "
                   "pays the tCCDR/tX2XR gap the grouping amortizes)"),
        MetricSpec("ref_backlog_max", HIGHWATER,
                   "worst refresh backlog the session has ever seen — a "
                   "session-cumulative high-water mark, never reset at "
                   "feed boundaries (see ChannelRunState.result)"),
    )
}

#: Derived per-window channel telemetry fields the probe computes from
#: the sampled state (not counts keys; listed for docs and exporters).
WINDOW_FIELDS = (
    "utilization",     # data-bus busy fraction within the window
    "bytes_moved",     # bytes transferred in the window (exact: sums to
                       # SystemResult.bytes_moved over a run)
    "queue_depth",     # outstanding transactions at window close
    "ref_backlog",     # refresh debt at window close
    "draining",        # write-drain FSM residency at window close
    "row_hit_rate",    # per-window (col cmds - ACT) / col cmds
)


def counter_names() -> tuple:
    """All registered counts keys (lint + exporter surface)."""
    return tuple(COUNTER_REGISTRY)


def is_highwater(name: str) -> bool:
    """True if ``name`` is a high-water mark (cumulative-only; the probe
    must not diff it across windows)."""
    spec = COUNTER_REGISTRY.get(name)
    return spec is not None and spec.kind == HIGHWATER


__all__ = ["MetricSpec", "COUNTER_REGISTRY", "WINDOW_FIELDS", "COUNTER",
           "HIGHWATER", "counter_names", "is_highwater"]
