"""Chrome/Perfetto ``trace_event`` JSON + flat metrics JSONL exporters.

One call — :func:`write_chrome_trace` — turns an
:class:`~.spans.ObsCollector` (span trees + step events) and/or a
:class:`~.probe.MetricsProbe` (windowed channel telemetry) into a JSON
file the Perfetto UI (https://ui.perfetto.dev) or ``chrome://tracing``
opens directly:

* one **process track per replica** (``replica <i>``) holding a
  ``steps`` thread (step slices) and one thread per request (the span
  tree nested by containment);
* one **memory-channels process** whose counter tracks carry the
  per-window series: ``ch<c> util`` (bus utilization), ``ch<c> bytes``
  (cumulative — its final value is the channel's exact byte total, so
  the counters reconcile with ``SystemResult.bytes_moved``),
  ``ch<c> queue`` / ``ch<c> backlog`` / ``ch<c> drain`` (sampled
  state), and ``ch<c> row_hits`` / ``ch<c> col_cmds`` (cumulative —
  their finals give the row-hit rate, which is how
  ``scripts/obs_report.py`` reproduces the HBM4-vs-RoMe locality gap
  from a trace alone).

Timestamps are microseconds (Chrome's unit), fractional — the engine's
ns clocks divide by 1e3 without rounding. :func:`load_chrome_trace` /
:func:`counter_series` / :func:`slices` are the read-back surface the
round-trip tests and the report CLI share.
"""
from __future__ import annotations

import json

#: pid layout: replicas are small ints offset by REPLICA_PID_BASE; the
#: channel-telemetry counter tracks live in one well-known process.
REPLICA_PID_BASE = 10
CHANNELS_PID = 9000
#: tid layout inside a replica process: steps on tid 0, request rid r on
#: tid REQUEST_TID_BASE + r.
REQUEST_TID_BASE = 1000
STEPS_TID = 0

_US = 1e-3     # ns -> µs


def _span_events(span, pid: int, tid: int, out: list) -> None:
    out.append({"name": span.name, "cat": span.cat, "ph": "X",
                "ts": span.start_ns * _US, "dur": span.dur_ns * _US,
                "pid": pid, "tid": tid, "args": dict(span.args)})
    for child in span.children:
        _span_events(child, pid, tid, out)


def chrome_trace_events(collector=None, probe=None) -> list:
    """The flat ``traceEvents`` list (dicts) for one run."""
    probe = probe if probe is not None else getattr(collector, "probe",
                                                    None)
    ev: list = []
    replicas = set()
    if collector is not None:
        for span in collector.step_spans():
            replicas.add(span.replica)
            _span_events(span, REPLICA_PID_BASE + span.replica,
                         STEPS_TID, ev)
        for root in collector.request_spans():
            replicas.add(root.replica)
            rid = root.args.get("rid", 0)
            pid = REPLICA_PID_BASE + root.replica
            tid = REQUEST_TID_BASE + rid
            _span_events(root, pid, tid, ev)
            ev.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid,
                       "args": {"name": f"req {rid}"}})
    for r in sorted(replicas):
        pid = REPLICA_PID_BASE + r
        ev.append({"name": "process_name", "ph": "M", "pid": pid,
                   "args": {"name": f"replica {r}"}})
        ev.append({"name": "thread_name", "ph": "M", "pid": pid,
                   "tid": STEPS_TID, "args": {"name": "steps"}})
    if probe is not None and probe.windows:
        ev.append({"name": "process_name", "ph": "M", "pid": CHANNELS_PID,
                   "args": {"name": "memory channels"}})
        for c in probe.channels():
            cum_bytes = 0
            cum_hits = 0
            cum_cols = 0
            for w in probe.channel_series(c):
                ts = w.t1_ns * _US
                cum_bytes += w.bytes_moved
                cum_hits += w.row_hits
                cum_cols += w.col_cmds
                for name, val in (
                        ("util", round(w.utilization, 6)),
                        ("bytes", cum_bytes),
                        ("queue", w.queue_depth),
                        ("backlog", w.ref_backlog),
                        ("drain", int(w.draining)),
                        ("row_hits", cum_hits),
                        ("col_cmds", cum_cols)):
                    ev.append({"name": f"ch{c} {name}", "ph": "C",
                               "pid": CHANNELS_PID, "ts": ts,
                               "args": {"value": val}})
    return ev


def write_chrome_trace(path, collector=None, probe=None,
                       label: str | None = None) -> dict:
    """Write one Chrome-trace JSON file; returns the written document."""
    doc = {
        "traceEvents": chrome_trace_events(collector, probe),
        "displayTimeUnit": "ms",
        "otherData": {"label": label or "",
                      "format": "repro.obs chrome-trace v1"},
    }
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def write_metrics_jsonl(path, probe=None, collector=None) -> int:
    """Flat metrics JSONL: one ``window`` record per channel telemetry
    window, one ``step`` per observed step, one ``request`` per folded
    request. Returns the number of lines written."""
    n = 0
    with open(path, "w") as f:
        if probe is not None:
            for w in probe.windows:
                f.write(json.dumps({
                    "type": "window", "channel": w.channel,
                    "t0_ns": w.t0_ns, "t1_ns": w.t1_ns,
                    "bytes": w.bytes_moved,
                    "util": round(w.utilization, 6),
                    "queue": w.queue_depth, "backlog": w.ref_backlog,
                    "drain": int(w.draining),
                    "row_hit_rate": round(w.row_hit_rate, 6),
                    "cmds": w.cmds}) + "\n")
                n += 1
            for s in probe.steps:
                f.write(json.dumps({
                    "type": "step", "start_ns": s.start_ns,
                    "total_ns": s.total_ns, "bytes": s.bytes_moved,
                    "mode": s.mode,
                    "pressure": round(s.queue_pressure, 6)}) + "\n")
                n += 1
        if collector is not None:
            mem = collector.mem_attribution()
            for rid in sorted(collector.requests):
                m = collector.requests[rid]
                f.write(json.dumps({
                    "type": "request", "rid": rid, "replica": m.replica,
                    "arrival_ns": m.arrival_ns,
                    "admitted_ns": m.admitted_ns,
                    "prefill_done_ns": m.prefill_done_ns,
                    "first_token_ns": m.first_token_ns,
                    "completed_ns": m.completed_ns,
                    "mem_ns": round(mem.get(rid, 0.0), 3)}) + "\n")
                n += 1
    return n


# -- read-back surface (tests + scripts/obs_report.py) ---------------------

def load_chrome_trace(path) -> dict:
    with open(path) as f:
        return json.load(f)


def slices(trace: dict) -> list:
    """All ``X`` events, as stored (ts/dur in µs)."""
    return [e for e in trace["traceEvents"] if e.get("ph") == "X"]


def counter_series(trace: dict) -> dict:
    """name -> [(ts_us, value)] for every counter track, trace order."""
    out: dict = {}
    for e in trace["traceEvents"]:
        if e.get("ph") == "C":
            out.setdefault(e["name"], []).append(
                (e["ts"], e["args"]["value"]))
    return out


def counter_final(series: dict, suffix: str) -> dict:
    """channel -> last value of every ``ch<c> <suffix>`` track."""
    out: dict = {}
    want = f" {suffix}"
    for name, pts in series.items():
        if name.startswith("ch") and name.endswith(want):
            c = int(name[2:-len(want)])
            out[c] = pts[-1][1]
    return out


def trace_row_hit_rate(trace: dict) -> float:
    """Aggregate row-hit rate recomputed purely from the counter
    tracks — the ``obs_report`` path that reproduces the HBM4-vs-RoMe
    locality gap without touching any simulator state."""
    series = counter_series(trace)
    hits = sum(counter_final(series, "row_hits").values())
    cols = sum(counter_final(series, "col_cmds").values())
    return hits / cols if cols else 0.0


def trace_total_bytes(trace: dict) -> int:
    """Summed final values of the cumulative per-channel byte counters
    (reconciles with ``SystemResult.bytes_moved`` for cycle runs)."""
    return int(sum(counter_final(counter_series(trace),
                                 "bytes").values()))


__all__ = ["chrome_trace_events", "write_chrome_trace",
           "write_metrics_jsonl", "load_chrome_trace", "slices",
           "counter_series", "counter_final", "trace_row_hit_rate",
           "trace_total_bytes", "REPLICA_PID_BASE", "CHANNELS_PID",
           "REQUEST_TID_BASE", "STEPS_TID"]
