"""repro.obs — time-resolved telemetry, request spans, trace export.

The observability subsystem turns end-of-run scalars into timelines
(docs/observability.md):

``metrics``
    The counter registry: every counts key a scheduler policy may emit,
    with counter-vs-high-water semantics. ``scripts/lint.py`` enforces
    that no policy grows an undeclared key.
``probe``
    :class:`MetricsProbe` — windowed channel telemetry folded from the
    engine's state samples (bus utilization, queue depth, row-hit rate,
    command mix, refresh backlog, write-drain residency). Zero-cost when
    detached; bit-identical results either way.
``spans``
    :class:`ObsCollector` — request/step span trees from serve replays
    and fleet runs (queued → admitted → prefill chunks → decode → done)
    with per-span memory-time attribution.
``export``
    Chrome/Perfetto ``trace_event`` JSON + flat metrics JSONL, plus the
    read-back helpers ``scripts/obs_report.py`` and the round-trip
    tests share.
``demo``
    The one-command equal-pin HBM4-vs-RoMe trace pair
    (examples/obs_trace.py).

Attach points: ``SystemSim.attach_probe(probe)`` for raw extent runs,
``build_replay(..., collector=ObsCollector(probe=...))`` for serve
replays, ``ClusterSim(..., collector=...)`` for fleet runs.
"""
from .export import (chrome_trace_events, counter_final, counter_series,
                     load_chrome_trace, slices, trace_row_hit_rate,
                     trace_total_bytes, write_chrome_trace,
                     write_metrics_jsonl)
from .metrics import (COUNTER_REGISTRY, WINDOW_FIELDS, MetricSpec,
                      counter_names, is_highwater)
from .probe import ChannelWindow, MetricsProbe, StepSample
from .spans import ObsCollector, Span, StepEvent

__all__ = [
    "MetricsProbe", "ChannelWindow", "StepSample",
    "ObsCollector", "Span", "StepEvent",
    "COUNTER_REGISTRY", "MetricSpec", "WINDOW_FIELDS", "counter_names",
    "is_highwater",
    "chrome_trace_events", "write_chrome_trace", "write_metrics_jsonl",
    "load_chrome_trace", "slices", "counter_series", "counter_final",
    "trace_row_hit_rate", "trace_total_bytes",
]
