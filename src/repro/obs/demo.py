"""One-command observability demo: the equal-pin HBM4-vs-RoMe trace pair.

:func:`export_equal_pin_pair` runs the same seeded serve replay twice —
``hbm4_frfcfs`` on 8 channels vs ``rome_qd2`` on 9 (the paper's 32:36
equal-CA-pin cube at quarter scale, matching
``benchmarks/serve_trace.py``) — with a windowed
:class:`~.probe.MetricsProbe` and an :class:`~.spans.ObsCollector`
attached, and writes one Chrome-trace JSON (plus a metrics JSONL) per
policy. ``examples/obs_trace.py`` is the CLI wrapper;
``scripts/obs_report.py --run`` uses the same builder so the report can
regenerate its own input. Everything here is pure-cycle pricing
(``sim_mode="cycle"``) so the exported counter tracks carry full channel
telemetry and their byte integrals reconcile exactly with the replay's
``bytes_moved``.
"""
from __future__ import annotations

import os

#: Equal-CA-pin channel widths (serve_trace.py's quarter-scale cube).
EQUAL_PIN_CHANNELS = {"hbm4_frfcfs": 8, "rome_qd2": 9}


def export_equal_pin_pair(out_dir: str,
                          n_requests: int = 5,
                          seed: int = 0,
                          rate_rps: float = 2e5,
                          window_ns: float = 200.0,
                          scale: float = 2 ** -13,
                          length_scale: float = 1 / 16,
                          jsonl: bool = True) -> dict:
    """Run the seeded equal-pin replay pair under full observation and
    export one Perfetto-openable trace per policy into ``out_dir``.

    Returns ``{policy: {"trace": path, "jsonl": path | None, "summary":
    replay summary + obs aggregates}}`` — the summary carries both the
    simulator-side truth (``bytes_moved``, ``row_hit_rate`` off the
    probe) and the trace-side readback
    (:func:`~.export.trace_row_hit_rate`), which the round-trip tests
    pin equal."""
    from ..configs.paper_workloads import REPLAY_SWEEP_MIX
    from ..serve.replay import build_replay
    from .export import (trace_row_hit_rate, trace_total_bytes,
                         write_chrome_trace, write_metrics_jsonl)
    from .probe import MetricsProbe
    from .spans import ObsCollector

    os.makedirs(out_dir, exist_ok=True)
    out: dict = {}
    for policy, nch in EQUAL_PIN_CHANNELS.items():
        collector = ObsCollector(probe=MetricsProbe(window_ns=window_ns))
        eng, _ = build_replay(
            policy=policy, rate_rps=rate_rps, n_requests=n_requests,
            seed=seed, mix=REPLAY_SWEEP_MIX, length_scale=length_scale,
            scale=scale, n_channels=nch, sim_mode="cycle",
            collector=collector)
        res = eng.run()
        trace_path = os.path.join(out_dir, f"{policy}.trace.json")
        write_chrome_trace(trace_path, collector, label=policy)
        jsonl_path = None
        if jsonl:
            jsonl_path = os.path.join(out_dir, f"{policy}.metrics.jsonl")
            write_metrics_jsonl(jsonl_path, probe=collector.probe,
                                collector=collector)
        from .export import load_chrome_trace
        doc = load_chrome_trace(trace_path)
        out[policy] = {
            "trace": trace_path,
            "jsonl": jsonl_path,
            "summary": {
                **res.summary(),
                "row_hit_rate": round(collector.probe.row_hit_rate(), 4),
                "trace_row_hit_rate": round(trace_row_hit_rate(doc), 4),
                "trace_bytes": trace_total_bytes(doc),
            },
        }
    return out


__all__ = ["export_equal_pin_pair", "EQUAL_PIN_CHANNELS"]
