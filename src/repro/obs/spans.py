"""Request/step span trees for serve replays and fleet runs.

:class:`ObsCollector` sits on the replay loop's step boundary
(:class:`repro.serve.replay.ReplayEngine` and
:class:`repro.serve.cluster.ClusterSim` both accept ``collector=``): at
every executed step it records a :class:`StepEvent` — who was admitted /
prefilled / decoded / finished, the step's wall duration and its memory
time from the :class:`~repro.core.system_sim.SystemResult` — and at the
end folds the engine's request reports into per-request lifecycle marks.
From those two streams it builds the span trees the Chrome-trace
exporter renders::

    request <rid>                  [arrival ........... completed]
      ├─ queued                    [arrival .. admitted]
      ├─ prefill                   [admitted .. prefill_done]
      │    └─ chunk <n>tok ...     (one per chunked-prefill step)
      └─ decode                    [prefill_done .. completed]

Memory-time attribution: a step's ``SystemResult.total_ns`` is split
evenly across the requests it served (active decoders + prefill
chunks); each request span carries its accumulated share in ``args``
(``mem_ns``), so the p99-step attribution in ``scripts/obs_report.py``
can name the requests that own the tail. Fleet runs fold per-replica
(``replica=i``); every replica gets its own process track in the
exported trace.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class StepEvent:
    """One executed serving step, as seen at the replay loop."""

    replica: int
    index: int
    start_ns: float
    dur_ns: float          # step wall duration (memory + overhead)
    mem_ns: float          # SystemResult.total_ns — the memory share
    bytes_moved: int
    mode: str              # pricing path the step took
    kind: str              # "decode" | "prefill" | "mixed" | ...
    active: tuple          # rids decoding this step
    prefilled: tuple       # (rid, n_tokens) prefill chunks this step
    admitted: tuple        # rids admitted at this step's start
    finished: tuple        # rids completed at this step's end

    @property
    def end_ns(self) -> float:
        return self.start_ns + self.dur_ns

    @property
    def participants(self) -> tuple:
        """Requests this step served (mirrors ``StepTrace.rids``)."""
        seen = dict.fromkeys(self.active)
        for rid, _ in self.prefilled:
            seen.setdefault(rid)
        return tuple(seen)


@dataclass
class Span:
    """One slice in a span tree (exported as a Chrome-trace ``X``)."""

    name: str
    cat: str
    start_ns: float
    end_ns: float
    replica: int = 0
    args: dict = field(default_factory=dict)
    children: list = field(default_factory=list)

    @property
    def dur_ns(self) -> float:
        return max(0.0, self.end_ns - self.start_ns)

    def add_child(self, child: "Span") -> "Span":
        """Attach ``child`` clamped inside this span — exported trees
        are nested by construction (pinned by tests/test_obs.py)."""
        child.start_ns = min(max(child.start_ns, self.start_ns),
                             self.end_ns)
        child.end_ns = min(max(child.end_ns, child.start_ns), self.end_ns)
        self.children.append(child)
        return child


@dataclass
class _ReqMarks:
    """Lifecycle marks for one request (filled from a RequestReport or
    the cluster result arrays; -1 = never happened)."""

    rid: int
    replica: int = 0
    arrival_ns: float = 0.0
    admitted_ns: float = -1.0
    prefill_done_ns: float = -1.0
    first_token_ns: float = -1.0
    completed_ns: float = -1.0
    prompt_len: int = 0
    n_out: int = 0


class ObsCollector:
    """Accumulates step events + request marks; builds span trees."""

    def __init__(self, probe=None):
        #: optional :class:`MetricsProbe` carried alongside so one
        #: object hands the exporter both spans and channel telemetry.
        self.probe = probe
        self.steps: list = []            # StepEvent, execution order
        self.requests: dict = {}         # rid -> _ReqMarks

    # -- feeding -----------------------------------------------------------

    def on_step(self, st, res, start_ns: float, dur_ns: float,
                replica: int = 0) -> None:
        """Record one executed step. ``st`` is the recorder's
        :class:`~repro.serve.replay.StepTrace`, ``res`` the step's
        :class:`SystemResult`."""
        self.steps.append(StepEvent(
            replica=replica, index=int(st.index),
            start_ns=float(start_ns), dur_ns=float(dur_ns),
            mem_ns=float(res.total_ns), bytes_moved=int(res.bytes_moved),
            mode=res.mode, kind=st.kind,
            active=tuple(st.active), prefilled=tuple(st.prefilled),
            admitted=tuple(st.admitted), finished=tuple(st.finished)))

    def add_request(self, rid: int, replica: int = 0, *,
                    arrival_ns: float = 0.0, admitted_ns: float = -1.0,
                    prefill_done_ns: float = -1.0,
                    first_token_ns: float = -1.0,
                    completed_ns: float = -1.0, prompt_len: int = 0,
                    n_out: int = 0) -> None:
        self.requests[int(rid)] = _ReqMarks(
            int(rid), int(replica), float(arrival_ns), float(admitted_ns),
            float(prefill_done_ns), float(first_token_ns),
            float(completed_ns), int(prompt_len), int(n_out))

    def fold_reports(self, reports, replica: int = 0) -> None:
        """Fold replay-engine :class:`RequestReport` records (anything
        with the same attribute surface works)."""
        for rep in reports:
            self.add_request(
                rep.rid, replica, arrival_ns=rep.arrival_ns,
                admitted_ns=rep.admitted_ns,
                prefill_done_ns=getattr(rep, "prefill_done_ns", -1.0),
                first_token_ns=rep.first_token_ns,
                completed_ns=rep.completed_ns,
                prompt_len=getattr(rep, "prompt_len", 0),
                n_out=getattr(rep, "n_out", 0))

    # -- attribution -------------------------------------------------------

    def mem_attribution(self) -> dict:
        """rid -> accumulated memory-time share (ns): each step's
        ``mem_ns`` split evenly over the requests it served."""
        out: dict = {}
        for ev in self.steps:
            parts = ev.participants
            if not parts:
                continue
            share = ev.mem_ns / len(parts)
            for rid in parts:
                out[rid] = out.get(rid, 0.0) + share
        return out

    def _steps_of(self) -> tuple:
        """(rid -> prefill-chunk steps, rid -> decode-step count)."""
        chunks: dict = {}
        decode_n: dict = {}
        for ev in self.steps:
            for rid, ntok in ev.prefilled:
                chunks.setdefault(rid, []).append((ev, ntok))
            for rid in ev.active:
                decode_n[rid] = decode_n.get(rid, 0) + 1
        return chunks, decode_n

    # -- span trees --------------------------------------------------------

    def request_spans(self) -> list:
        """One root :class:`Span` tree per known request, rid order."""
        chunks, decode_n = self._steps_of()
        mem = self.mem_attribution()
        out = []
        for rid in sorted(self.requests):
            m = self.requests[rid]
            end = m.completed_ns
            if end < 0:             # incomplete: extend to last evidence
                end = max([m.arrival_ns, m.admitted_ns, m.prefill_done_ns,
                           m.first_token_ns]
                          + [ev.end_ns for ev, _ in chunks.get(rid, [])]
                          + [ev.end_ns for ev in self.steps
                             if rid in ev.active])
            root = Span(f"req {rid}", "request", m.arrival_ns, end,
                        replica=m.replica,
                        args={"rid": rid, "prompt_len": m.prompt_len,
                              "n_out": m.n_out,
                              "mem_ns": round(mem.get(rid, 0.0), 3),
                              "complete": m.completed_ns >= 0})
            if m.admitted_ns >= 0:
                root.add_child(Span("queued", "queue", m.arrival_ns,
                                    m.admitted_ns, replica=m.replica))
                pf_end = (m.prefill_done_ns if m.prefill_done_ns >= 0
                          else m.admitted_ns)
                if pf_end > m.admitted_ns or chunks.get(rid):
                    pf = root.add_child(Span(
                        "prefill", "prefill", m.admitted_ns, pf_end,
                        replica=m.replica,
                        args={"n_chunks": len(chunks.get(rid, []))}))
                    for ev, ntok in chunks.get(rid, []):
                        pf.add_child(Span(
                            f"chunk {ntok}tok", "prefill", ev.start_ns,
                            ev.end_ns, replica=m.replica,
                            args={"step": ev.index}))
                dec_start = pf_end
                if end > dec_start:
                    root.add_child(Span(
                        "decode", "decode", dec_start, end,
                        replica=m.replica,
                        args={"n_steps": decode_n.get(rid, 0)}))
            out.append(root)
        return out

    def step_spans(self) -> list:
        """Flat step slices, one per executed step (per-replica track)."""
        return [Span(f"step {ev.index}", "step", ev.start_ns, ev.end_ns,
                     replica=ev.replica,
                     args={"mode": ev.mode, "kind": ev.kind,
                           "mem_ns": round(ev.mem_ns, 3),
                           "bytes": ev.bytes_moved,
                           "n_active": len(ev.active),
                           "n_prefill": len(ev.prefilled),
                           "active": list(ev.active)[:16]})
                for ev in self.steps]

    def p99_step(self) -> "StepEvent | None":
        """The step at (or just above) the p99 wall duration — the tail
        the report attributes to requests and channels."""
        if not self.steps:
            return None
        durs = sorted(ev.dur_ns for ev in self.steps)
        cut = durs[min(len(durs) - 1, int(0.99 * (len(durs) - 1)))]
        return max((ev for ev in self.steps if ev.dur_ns >= cut),
                   key=lambda ev: ev.dur_ns)


__all__ = ["ObsCollector", "Span", "StepEvent"]
