"""Int8 gradient compression with error feedback (distributed-optimization
trick for bandwidth-constrained cross-pod all-reduce).

Per-tensor symmetric quantization: q = round(g / s) with s = max|g| / 127.
The quantization residual is carried in an error-feedback buffer and added
back before the next compression, so the scheme is unbiased over time
(Seide et al. / EF-SGD). Intended use: compress before the cross-pod
('pod' axis) reduce where links are slowest; the within-pod reduce stays
fp32. All ops are jit-compatible pytree maps.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..compat import tree_map


class ErrorFeedback(NamedTuple):
    buf: dict      # residual pytree (fp32), like grads


def ef_init(grads_like) -> ErrorFeedback:
    return ErrorFeedback(
        tree_map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(int8 payload, fp32 scale). Scale is per-tensor."""
    g32 = g.astype(jnp.float32)
    s = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / s), -127, 127).astype(jnp.int8)
    return q, s


def decompress_int8(q: jax.Array, s: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * s).astype(dtype)


def compress_tree(grads, ef: ErrorFeedback):
    """Quantize grads+residual; returns ((q, s) pytrees, new ErrorFeedback)."""
    g_leaves, treedef = jax.tree.flatten(grads)
    e_leaves = treedef.flatten_up_to(ef.buf)
    q_leaves, s_leaves, r_leaves = [], [], []
    for g, e in zip(g_leaves, e_leaves):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_int8(corrected)
        q_leaves.append(q)
        s_leaves.append(s)
        r_leaves.append(corrected - decompress_int8(q, s))
    return (treedef.unflatten(q_leaves), treedef.unflatten(s_leaves)), \
        ErrorFeedback(treedef.unflatten(r_leaves))


def decompress_tree(qs, scales, dtype=jnp.float32):
    return tree_map(lambda q, s: decompress_int8(q, s, dtype), qs, scales)
