"""AdamW in pure JAX over arbitrary parameter pytrees.

Moments are stored in fp32 regardless of parameter dtype (mixed-precision
convention); the update is computed in fp32 and cast back. Moment tensors
inherit the parameter sharding, so under FSDP the optimizer state is sharded
exactly like the weights (ZeRO-style).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..compat import tree_map


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    mu: dict                 # first moment (fp32, pytree like params)
    nu: dict                 # second moment (fp32)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=tree_map(zeros, params),
        nu=tree_map(zeros, params),
    )


def adamw_update(params, grads, state: AdamWState, *,
                 lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 grad_clip: float = 1.0):
    """One AdamW step with global-norm clipping. Returns (params, state)."""
    # Global-norm clip in fp32.
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))

    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = tree_map(upd, params, grads, state.mu, state.nu)
    new_params = tree_map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = tree_map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = tree_map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu)
