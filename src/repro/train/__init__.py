from .optimizer import AdamWState, adamw_init, adamw_update
from .train_step import TrainState, make_train_step, train_state_init
from .grad_compress import compress_int8, decompress_int8, ErrorFeedback

__all__ = [
    "AdamWState", "adamw_init", "adamw_update",
    "TrainState", "make_train_step", "train_state_init",
    "compress_int8", "decompress_int8", "ErrorFeedback",
]
