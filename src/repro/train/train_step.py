"""Train-step factory: loss + grad + AdamW, with microbatched gradient
accumulation (the collective-overlap trick: XLA overlaps each microbatch's
reduce with the next microbatch's compute) and optional remat.

The returned step is a pure function suitable for jax.jit with explicit
in/out shardings — the launch layer owns mesh and sharding decisions.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..compat import tree_map
from ..distributed.sharding import constrain_like, shard_hint
from .optimizer import AdamWState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def train_state_init(params) -> TrainState:
    return TrainState(params=params, opt=adamw_init(params))


def make_train_step(loss_fn: Callable, *, microbatches: int = 1,
                    lr: float = 3e-4, weight_decay: float = 0.1,
                    grad_clip: float = 1.0,
                    param_specs: Any = None) -> Callable:
    """loss_fn(params, batch) -> scalar loss. Returns
    step(state, batch) -> (state, metrics).

    With microbatches > 1 the global batch is split along axis 0 and
    accumulated via lax.scan (constant memory in the number of microbatches;
    XLA overlaps the per-microbatch gradient reduce with the next
    microbatch's compute where the schedule allows).

    `param_specs` (named-axis tuples mirroring the params) pins gradients
    and their accumulator to the parameter sharding: XLA then emits
    per-microbatch reduce-scatters instead of full all-reduces — half the
    wire bytes — and the AdamW update runs entirely on local shards
    (measured on llama-90b train_4k; EXPERIMENTS.md §Perf).
    """
    grad_fn = jax.value_and_grad(loss_fn)
    pin = (lambda g: constrain_like(g, param_specs)) if param_specs \
        else (lambda g: g)

    def single(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = grad_fn(state.params, batch)
        params, opt = adamw_update(state.params, pin(grads), state.opt,
                                   lr=lr, weight_decay=weight_decay,
                                   grad_clip=grad_clip)
        return TrainState(params, opt), {"loss": loss}

    if microbatches <= 1:
        return single

    def accumulated(state: TrainState, batch) -> tuple[TrainState, dict]:
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            y = x.reshape((microbatches, b // microbatches) + x.shape[1:])
            # Keep the *inner* batch dim data-sharded; the microbatch dim is
            # the scan axis and must not be sharded.
            return shard_hint(y, None, ("pod", "data"),
                              *([None] * (y.ndim - 2)))

        mb = tree_map(split, batch)
        zero = pin(tree_map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                state.params))

        def body(carry, microbatch):
            acc, loss_acc = carry
            loss, grads = grad_fn(state.params, microbatch)
            acc = pin(tree_map(lambda a, g: a + g.astype(jnp.float32),
                                   acc, pin(grads)))
            return (acc, loss_acc + loss), None

        (gacc, loss_sum), _ = jax.lax.scan(body, (zero, 0.0), mb)
        grads = tree_map(lambda g: g / microbatches, gacc)
        params, opt = adamw_update(state.params, grads, state.opt, lr=lr,
                                   weight_decay=weight_decay,
                                   grad_clip=grad_clip)
        return TrainState(params, opt), {"loss": loss_sum / microbatches}

    return accumulated
