"""Per-layer operation census: FLOPs, bytes, and memory extents.

Builds the per-device layer-op list for a decode or prefill step, honouring
the paper's parallelism mapping (§VI-A): TP for attention (1/8/8 for
DeepSeek/Grok/Llama), expert parallelism for MoE, full DP for MLA
attention. Each op carries its memory *extents* — (base_addr, nbytes)
ranges in a row-aligned virtual address space — which drive the LBR model
(Fig 13) and the RoMe/HBM4 service-time comparison (Fig 12).

The allocator aligns every tensor to the 4 KB DRAM row — the software-side
contract of a RoMe system (and what repro.serve's paged KV cache enforces
at runtime).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from ..configs.paper_workloads import PaperWorkload

ROW = 4096
BF16 = 2


@dataclass
class LayerOp:
    name: str
    kind: str                      # "attn" | "ffn" | "embed" | "head"
    flops: float                   # per device
    extents: list = field(default_factory=list)   # [(addr, nbytes)] reads
    write_bytes: int = 0           # streamed writes (KV append, activations)
    write_extents: list = field(default_factory=list)  # [(addr, nbytes)]

    @property
    def read_bytes(self) -> int:
        return sum(n for _, n in self.extents)


class RowAllocator:
    """Row-aligned bump allocator for the virtual address space."""

    def __init__(self) -> None:
        self.cursor = 0

    def alloc(self, nbytes: int) -> tuple[int, int]:
        base = self.cursor
        self.cursor += math.ceil(nbytes / ROW) * ROW
        return (base, nbytes)


def _expected_active_experts(n_experts: int, top_k: int, tokens: int,
                             experts_per_device: int) -> float:
    """Expected number of distinct experts activated on one device when
    `tokens` tokens each pick top_k of n_experts uniformly."""
    if tokens <= 0:
        return 0.0
    p_unused = (1.0 - top_k / n_experts) ** tokens
    return experts_per_device * (1.0 - p_unused)


# ---------------------------------------------------------------------------
# Paper workloads (decode step; per device)
# ---------------------------------------------------------------------------

def decode_ops(w: PaperWorkload, batch: int, seq_len: int,
               n_devices: int = 8) -> list[LayerOp]:
    """One decode step on one device. `batch` = global batch size."""
    alloc = RowAllocator()
    ops: list[LayerOp] = []
    d, hd = w.d_model, w.head_dim

    # --- attention weights (per device) ------------------------------------
    tp = w.attn_tp
    b_local = batch // (n_devices // tp) if tp < n_devices else batch
    if w.mla_kv_lora:               # MLA (DeepSeek): DP attention
        wq_d = d * w.mla_q_lora
        wq_u = w.mla_q_lora * w.n_heads * (hd + w.mla_rope_dim)
        wkv_d = d * (w.mla_kv_lora + w.mla_rope_dim)
        wkv_u = w.mla_kv_lora * w.n_heads * (2 * hd)
        wo = w.n_heads * hd * d
        attn_w = (wq_d + wq_u + wkv_d + wkv_u + wo) * w.bytes_per_param
        kv_per_tok = w.kv_bytes_per_token_per_layer
        kv_read = b_local * seq_len * kv_per_tok
        attn_flops = 2.0 * b_local * (attn_w / w.bytes_per_param) \
            + 2.0 * b_local * seq_len * (w.mla_kv_lora + w.mla_rope_dim) \
            * (1 + w.n_heads)
    else:                           # GQA with TP
        wq = d * (w.n_heads * hd) // tp
        wkv = 2 * d * (w.n_kv_heads * hd) // tp
        wo = (w.n_heads * hd) * d // tp
        attn_w = (wq + wkv + wo) * w.bytes_per_param
        kv_per_tok = w.kv_bytes_per_token_per_layer // tp
        kv_read = b_local * seq_len * kv_per_tok
        attn_flops = 2.0 * b_local * (attn_w / w.bytes_per_param) \
            + 4.0 * b_local * seq_len * (w.n_heads // tp) * hd

    # --- FFN weights --------------------------------------------------------
    if w.is_moe:
        e_dev = w.n_experts // w.moe_ep
        expert_bytes = 3 * d * w.d_ff * w.bytes_per_param
        active = _expected_active_experts(w.n_experts, w.top_k, batch, e_dev)
        shared_bytes = w.n_shared_experts * expert_bytes
        ffn_tokens = batch * w.top_k / n_devices  # routed tokens per device
        ffn_flops = 2.0 * 3 * d * w.d_ff * ffn_tokens \
            + 2.0 * 3 * d * w.d_ff * (batch / n_devices) * w.n_shared_experts
    else:
        ffn_w = 3 * d * w.d_ff // n_devices * w.bytes_per_param
        ffn_flops = 2.0 * batch * (3 * d * w.d_ff) / n_devices

    act_bytes = b_local * d * w.bytes_per_param

    def walloc(*sizes: int) -> list:
        """Row-aligned write extents (KV append / activation stores) from
        the same allocator as the reads, so the two never overlap."""
        return [alloc.alloc(s) for s in sizes if s > 0]

    for layer in range(w.n_layers):
        # attention
        extents = [alloc.alloc(attn_w)]
        for s in range(min(b_local, 64)):   # cap extent count; scale below
            extents.append(alloc.alloc(kv_read // max(1, min(b_local, 64))))
        wx = walloc(b_local * kv_per_tok, act_bytes, act_bytes)
        ops.append(LayerOp(
            name=f"L{layer}.attn", kind="attn",
            flops=attn_flops,
            extents=extents,
            write_bytes=sum(n for _, n in wx),
            write_extents=wx,
        ))
        # ffn
        if w.is_moe and layer >= w.n_dense_layers:
            ex: list = []
            n_active = max(1, round(active))
            for e in range(n_active):
                ex.append(alloc.alloc(expert_bytes))
            if shared_bytes:
                ex.append(alloc.alloc(shared_bytes))
            wx = walloc(act_bytes, act_bytes)
            ops.append(LayerOp(
                name=f"L{layer}.moe", kind="ffn",
                flops=ffn_flops, extents=ex,
                write_bytes=sum(n for _, n in wx), write_extents=wx))
        elif w.is_moe:                                # leading dense layers
            nb = 3 * d * w.dense_d_ff // n_devices * w.bytes_per_param
            wx = walloc(act_bytes, act_bytes)
            ops.append(LayerOp(
                name=f"L{layer}.ffn", kind="ffn",
                flops=2.0 * batch * 3 * d * w.dense_d_ff / n_devices,
                extents=[alloc.alloc(nb)],
                write_bytes=sum(n for _, n in wx), write_extents=wx))
        else:
            wx = walloc(act_bytes, act_bytes)
            ops.append(LayerOp(
                name=f"L{layer}.ffn", kind="ffn",
                flops=ffn_flops,
                extents=[alloc.alloc(ffn_w)],
                write_bytes=sum(n for _, n in wx), write_extents=wx))

    # LM head (TP over all devices)
    head_b = d * w.vocab // n_devices * w.bytes_per_param
    wx = walloc(batch * w.vocab // n_devices * 4)
    ops.append(LayerOp(name="lm_head", kind="head",
                       flops=2.0 * batch * d * w.vocab / n_devices,
                       extents=[alloc.alloc(head_b)],
                       write_bytes=sum(n for _, n in wx),
                       write_extents=wx))
    return ops


def prefill_ops(w: PaperWorkload, batch: int, seq_len: int,
                n_devices: int = 8) -> list[LayerOp]:
    """Prefill processes batch*seq tokens; same weight extents, token count
    multiplied — the workload turns compute-bound (paper: <0.1 % memory
    sensitivity)."""
    tokens = batch * seq_len
    ops = decode_ops(w, batch, seq_len, n_devices)
    scaled = []
    for op in ops:
        f = op.flops * seq_len
        # Writes scale with the token count; the per-token addresses of the
        # decode trace no longer apply, so prefill ops carry byte counts
        # only (the perf model falls back to its address-less write path).
        wb = op.write_bytes * seq_len
        scaled.append(LayerOp(op.name, op.kind, f, op.extents, wb))
    return scaled
