from .layergraph import LayerOp, RowAllocator, decode_ops, prefill_ops

__all__ = ["LayerOp", "RowAllocator", "decode_ops", "prefill_ops"]
