"""llama-3.2-vision-90b [vlm] — cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]. Frontend = stub patch
embeddings; cross layer every 5th layer (100L = 20 x [4 self + 1 cross])."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    cross_attn_every=5, n_vision_tokens=1601, rope_theta=5e5,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
