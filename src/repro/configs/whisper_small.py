"""whisper-small [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, head_dim=64,
    encoder_layers=12, n_audio_frames=1500, max_target_positions=448,
    tie_embeddings=True, norm_eps=1e-5,
    source="arXiv:2212.04356; unverified",
)
