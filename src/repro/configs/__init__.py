from .base import ArchConfig, MoEConfig, SSMConfig, reduced
from .shapes import SHAPES, InputShape
from .registry_configs import ALL_ARCHS, get_config

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "reduced", "SHAPES",
           "InputShape", "ALL_ARCHS", "get_config"]
