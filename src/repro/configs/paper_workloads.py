"""The paper's three evaluation LLMs (§VI-A) for the TPOT / LBR / energy
reproduction: DeepSeek-V3 (MLA + MoE), Grok-1 (GQA + MoE), Llama-3-405B
(GQA + dense FFN). Weights in BF16; parallelism per §VI-A: prefill TP=8;
decode attention TP = 1 / 8 / 8 (MLA's compressed KV favors data
parallelism); MoE uses expert parallelism across the 8 accelerators.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class PaperWorkload:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int          # GQA kv heads (MLA: latent dim handled below)
    head_dim: int
    d_ff: int                # dense FFN or per-expert intermediate
    vocab: int
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    n_dense_layers: int = 0  # leading dense-FFN layers (DeepSeek: 3)
    dense_d_ff: int = 0
    # MLA
    mla_kv_lora: int = 0     # compressed KV dim (c_kv); 0 => plain GQA
    mla_q_lora: int = 0
    mla_rope_dim: int = 0
    # parallelism (§VI-A, decode)
    attn_tp: int = 8
    moe_ep: int = 8
    bytes_per_param: int = 2

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def kv_bytes_per_token_per_layer(self) -> int:
        """KV-cache bytes appended per token per layer (BF16)."""
        if self.mla_kv_lora:
            return (self.mla_kv_lora + self.mla_rope_dim) * self.bytes_per_param
        return 2 * self.n_kv_heads * self.head_dim * self.bytes_per_param


DEEPSEEK_V3 = PaperWorkload(
    name="deepseek-v3",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=2048, vocab=129280,
    n_experts=256, top_k=8, n_shared_experts=1,
    n_dense_layers=3, dense_d_ff=18432,
    mla_kv_lora=512, mla_q_lora=1536, mla_rope_dim=64,
    attn_tp=1,            # MLA favors DP for attention (§VI-A)
    moe_ep=8,
)

GROK_1 = PaperWorkload(
    name="grok-1",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab=131072,
    n_experts=8, top_k=2,
    attn_tp=8, moe_ep=8,
)

LLAMA_3_405B = PaperWorkload(
    name="llama-3-405b",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
    d_ff=53248, vocab=128256,
    attn_tp=8, moe_ep=1,
)

PAPER_WORKLOADS = {w.name: w for w in (DEEPSEEK_V3, GROK_1, LLAMA_3_405B)}


# ---------------------------------------------------------------------------
# Serving-trace length mixes (repro.serve.replay)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServingMix:
    """Prompt/output token-length distribution for a serving trace.

    Prompt lengths are lognormal (median ``prompt_median``, coefficient of
    variation ``prompt_cv``); output lengths are geometric with mean
    ``out_mean``. Both are clamped to ``[1, *_max]``. The replay subsystem
    samples these through a seeded RNG
    (:class:`repro.serve.replay.ArrivalProcess`) and may scale them down
    uniformly (``length_scale``) to keep cycle-level simulation tractable
    — the *shape* of the mix, not its absolute token count, is what
    stresses the memory system.
    """

    prompt_median: int
    prompt_cv: float
    out_mean: int
    prompt_max: int = 8192
    out_max: int = 2048


# Chat-style mixes per evaluation model: MoE chat traffic (DeepSeek,
# Grok) skews to short-median / heavy-tail prompts; the dense Llama row
# mirrors the paper's long-context 8K-seq evaluation point.
SERVING_MIXES = {
    "deepseek-v3": ServingMix(prompt_median=512, prompt_cv=1.0, out_mean=256),
    "grok-1": ServingMix(prompt_median=512, prompt_cv=1.0, out_mean=256),
    "llama-3-405b": ServingMix(prompt_median=2048, prompt_cv=0.5,
                               out_mean=256),
}

#: The serve-replay sweep mix (benchmarks/serve_trace.py and
#: examples/serve_replay.py must agree on it, or the example's headline
#: stops reproducing the gated conditions): the chat mix with outputs
#: shortened so a cycle-level full load sweep stays tractable at 1/16
#: length scale.
REPLAY_SWEEP_MIX = ServingMix(prompt_median=512, prompt_cv=1.0, out_mean=128,
                              prompt_max=4096, out_max=512)
