"""Architecture configuration schema.

One :class:`ArchConfig` instance per assigned architecture (see sibling
modules). The same schema drives model construction, parameter init,
sharding specs, trace generation for the RoMe perf model, and the dry-run
input specs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_d_ff: int
    capacity_factor: float = 1.25
    # granite/phi both use a dense FFN nowhere; every block is MoE.


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64           # N (per-head state size)
    conv_width: int = 4
    expand: int = 2               # inner dim = expand * d_model
    head_dim: int = 64            # mamba2 head size


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | ssm | hybrid | vlm | audio | moe
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False                  # qwen2-style QKV bias
    qk_norm: bool = False                   # qwen3-style per-head RMSNorm
    sliding_window: Optional[int] = None    # SWA (h2o-danube: 4096)
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every k SSM blocks
    shared_attn_every: Optional[int] = None
    # vlm (mllama): one cross-attention block every k self-attention blocks
    cross_attn_every: Optional[int] = None
    n_vision_tokens: int = 1601             # stub patch-embedding count
    # audio (whisper): encoder-decoder
    encoder_layers: int = 0
    n_audio_frames: int = 1500              # stub frame-embedding count
    max_target_positions: int = 448
    # training
    dtype: str = "bfloat16"
    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic token mixing: SSM state, hybrid (windowed shared
        attention), or sliding-window attention."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    def n_params(self) -> int:
        """Total parameter count (embedding included once; exact for the
        families we build — used for MODEL_FLOPS and roofline)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm"):
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
            if self.moe:
                router = d * self.moe.n_experts
                ffn = self.moe.n_experts * 3 * d * self.moe.expert_d_ff + router
            else:
                ffn = 3 * d * self.d_ff
            per_layer = attn + ffn + 2 * d
        elif self.family == "ssm":            # rwkv6
            per_layer = self._rwkv6_layer_params()
        elif self.family == "hybrid":         # zamba2
            per_layer = self._mamba2_layer_params()
        elif self.family == "audio":
            attn = d * (self.n_heads * hd) * 2 + 2 * d * (self.n_kv_heads * hd) * 2
            ffn = 2 * d * self.d_ff
            per_layer = attn + ffn + 3 * d
        total = emb + L * per_layer
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = L // self.cross_attn_every
            cross = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d + 2 * d
            total += n_cross * cross
        if self.family == "hybrid" and self.shared_attn_every:
            hd_full = d  # shared attn uses full d_model heads
            total += 4 * d * hd_full + 2 * d   # one shared block
        if self.family == "audio":
            enc_attn = self.d_model * self.d_model * 4
            enc_ffn = 2 * d * self.d_ff
            total += self.encoder_layers * (enc_attn + enc_ffn + 2 * d)
        return int(total)

    def _rwkv6_layer_params(self) -> int:
        d = self.d_model
        # time-mix: r,k,v,g,o projections + decay/bonus vectors + small loras
        tm = 5 * d * d + 4 * d + 2 * (d * 64 + 64 * d)
        cm = 2 * d * int(self.d_ff) + d * d   # channel mix (k, v, r)
        return tm + cm + 2 * d

    def _mamba2_layer_params(self) -> int:
        d = self.d_model
        s = self.ssm or SSMConfig()
        inner = s.expand * d
        in_proj = d * (2 * inner + 2 * s.state_dim + inner // s.head_dim)
        out_proj = inner * d
        conv = (inner + 2 * s.state_dim) * s.conv_width
        return in_proj + out_proj + conv + 2 * d

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.n_params()
        d, L, m = self.d_model, self.n_layers, self.moe
        full_ffn = m.n_experts * 3 * d * m.expert_d_ff
        active_ffn = m.top_k * 3 * d * m.expert_d_ff
        return int(self.n_params() - L * (full_ffn - active_ffn))


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=max(2, (cfg.shared_attn_every or cfg.cross_attn_every or 1) + 1),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_ff=128,
        vocab=256,
        head_dim=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        n_vision_tokens=16 if cfg.family == "vlm" else cfg.n_vision_tokens,
        n_audio_frames=16 if cfg.family == "audio" else cfg.n_audio_frames,
    )
    if cfg.moe:
        base["moe"] = MoEConfig(n_experts=min(cfg.moe.n_experts, 8),
                                top_k=min(cfg.moe.top_k, 2),
                                expert_d_ff=64)
    if cfg.ssm:
        base["ssm"] = SSMConfig(state_dim=16, head_dim=16, expand=2)
    if cfg.sliding_window:
        base["sliding_window"] = 32
    if cfg.shared_attn_every:
        base["shared_attn_every"] = 2
        base["n_layers"] = 5
    if cfg.cross_attn_every:
        base["cross_attn_every"] = 2
        base["n_layers"] = 4
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
