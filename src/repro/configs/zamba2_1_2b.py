"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]. ssm_state=64. Runs long_500k (shared attention
switches to a sliding window there; DESIGN.md notes the adaptation)."""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64,
    ssm=SSMConfig(state_dim=64, conv_width=4, expand=2, head_dim=64),
    shared_attn_every=6,
    source="arXiv:2411.15242; hf",
)
