"""Arch-id -> ArchConfig registry (the 10 assigned architectures)."""
from . import (granite_moe_3b, h2o_danube_1_8b, llama32_vision_90b,
               minitron_8b, phi35_moe_42b, qwen2_7b, qwen3_14b, rwkv6_3b,
               whisper_small, zamba2_1_2b)

ALL_ARCHS = {
    "qwen2-7b": qwen2_7b.CONFIG,
    "minitron-8b": minitron_8b.CONFIG,
    "h2o-danube-1.8b": h2o_danube_1_8b.CONFIG,
    "qwen3-14b": qwen3_14b.CONFIG,
    "rwkv6-3b": rwkv6_3b.CONFIG,
    "zamba2-1.2b": zamba2_1_2b.CONFIG,
    "llama-3.2-vision-90b": llama32_vision_90b.CONFIG,
    "whisper-small": whisper_small.CONFIG,
    "granite-moe-3b-a800m": granite_moe_3b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b.CONFIG,
}


def get_config(arch_id: str):
    if arch_id not in ALL_ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ALL_ARCHS)}")
    return ALL_ARCHS[arch_id]
