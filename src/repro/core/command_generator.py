"""RoMe command generator (paper §IV-C, §IV-D; Figs 9 & 10).

The command generator sits on the HBM logic die. It accepts the three
row-level commands (RD_row, WR_row, REF) and expands each into a *fixed,
statically timed* sequence of conventional DRAM commands — one ACT per bank
of the VBA (staggered by tRRDS), a perfectly interleaved train of RD/WR
bursts at tCCDS spacing, and a PRE per bank. Unlike a conventional MC it
never consults dynamic bank state: the schedule is a pure function of the
timing parameters.

Also models the C/A-pin serialization cost (Fig 10): with fewer pins a
command takes more beats to transfer; RoMe needs command issue to stay under
the 2*tRRDS minimum row-command interval, which 5 pins satisfy (72% pin
reduction from HBM4's 18).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Literal

from .timing import ChannelGeometry, HBM4Timing

Op = Literal["ACT", "RD", "WR", "PRE", "REFpb"]


@dataclass(frozen=True)
class DramCommand:
    t_ns: float          # issue time relative to row-command acceptance
    op: Op
    bank: int            # bank index within the VBA (0 or 1)

    def __repr__(self) -> str:  # compact, for schedule dumps
        return f"{self.op}@{self.t_ns:g}ns(b{self.bank})"


@dataclass(frozen=True)
class RowCommandSchedule:
    """Expanded schedule for one RD_row / WR_row."""

    commands: List[DramCommand]
    first_data_ns: float      # first data beat on the DQ bus
    last_data_ns: float       # last data beat leaves the DQ bus
    bank_ready_ns: float      # both banks precharged & re-activatable
    is_write: bool

    @property
    def data_bus_ns(self) -> float:
        return self.last_data_ns - self.first_data_ns


class CommandGenerator:
    """Static expander for row-granularity commands (Fig 9).

    A VBA = two banks in *different* bank groups (Fig 7(d)) with both pseudo
    channels operated in lockstep (Fig 8(b)), so each RD burst moves
    col_bytes * 2 PCs = 64 B of the effective 4 KB row; 32 bursts per bank,
    64 total, at tCCDS spacing alternating between the two banks.
    """

    def __init__(self, timing: HBM4Timing | None = None,
                 geometry: ChannelGeometry | None = None):
        self.t = timing or HBM4Timing()
        self.g = geometry or ChannelGeometry()

    # -- schedule construction -------------------------------------------------

    def _acts(self) -> tuple[float, float]:
        """ACT issue times for bank0/bank1.

        Fig 9: an intentional delay of (tRRDS - tCCDS) is inserted before the
        ACT to the first bank so the RD/WR trains to the two banks mesh at
        tCCDS spacing while respecting tRRDS between the ACTs.
        """
        act0 = self.t.tRRDS - self.t.tCCDS
        act1 = act0 + self.t.tRRDS
        return act0, act1

    def bursts_per_bank(self) -> int:
        # 1 KB row per bank per PC; both PCs move in lockstep, so the burst
        # count per bank equals cols_per_row of a single PC's row.
        return self.g.cols_per_row

    def expand(self, is_write: bool) -> RowCommandSchedule:
        t = self.t
        act0, act1 = self._acts()
        trcd = t.tRCDWR if is_write else t.tRCDRD
        # First burst to bank0 such that bank1's first burst (tCCDS later)
        # also respects its own tRCD.
        s = max(act0 + trcd, act1 + trcd - t.tCCDS)
        n = self.bursts_per_bank()
        cmds: List[DramCommand] = [
            DramCommand(act0, "ACT", 0),
            DramCommand(act1, "ACT", 1),
        ]
        op: Op = "WR" if is_write else "RD"
        last = {0: 0.0, 1: 0.0}
        for k in range(n):
            t0 = s + 2 * k * t.tCCDS
            t1 = t0 + t.tCCDS
            cmds.append(DramCommand(t0, op, 0))
            cmds.append(DramCommand(t1, op, 1))
            last[0], last[1] = t0, t1
        # Data window: each burst occupies tCCDS on the bus after CL/CWL.
        cl = t.tCWL if is_write else t.tCL
        first_data = s + cl
        last_data = last[1] + cl + t.tCCDS
        # Precharge: after tRTP (read) or write-recovery tWR past last data.
        pres = {}
        for b in (0, 1):
            if is_write:
                pres[b] = last[b] + cl + t.tCCDS + t.tWR
            else:
                pres[b] = last[b] + t.tRTP
            # tRAS lower bound: PRE no earlier than ACT + tRAS.
            pres[b] = max(pres[b], (act0 if b == 0 else act1) + t.tRAS)
            cmds.append(DramCommand(pres[b], "PRE", b))
        bank_ready = max(pres.values()) + t.tRP
        cmds.sort(key=lambda c: (c.t_ns, c.op, c.bank))
        return RowCommandSchedule(cmds, first_data, last_data, bank_ready,
                                  is_write)

    # -- derived row-level timings --------------------------------------------

    def derived_tRD_row(self) -> float:
        """Earliest the *next* RD_row to the same VBA may start (command
        acceptance to command acceptance)."""
        sch = self.expand(is_write=False)
        act0_next_offset = self.t.tRRDS - self.t.tCCDS
        return sch.bank_ready_ns - act0_next_offset

    def derived_tWR_row(self) -> float:
        sch = self.expand(is_write=True)
        act0_next_offset = self.t.tRRDS - self.t.tCCDS
        return sch.bank_ready_ns - act0_next_offset

    def derived_tR2RS(self) -> float:
        """Earliest a RD_row to a *different* VBA can start such that its
        data train lands immediately after ours: the DQ bus is the only
        shared resource, so the spacing equals the data-bus occupancy of one
        row = 64 bursts * tCCDS."""
        return 2 * self.bursts_per_bank() * self.t.tCCDS

    # -- refresh (paper §V-B) --------------------------------------------------

    def expand_refresh(self) -> List[DramCommand]:
        """VBA-paired per-bank refresh: two REFpb commands tRREFpb apart.

        The MC issues one VBA-refresh every 2*tREFIpb; the generator fans it
        out to both banks. VBA stall = tRFCpb + tRREFpb (vs 2*tRFCpb if the
        MC issued them serially)."""
        return [DramCommand(0.0, "REFpb", 0),
                DramCommand(self.t.tRREFpb, "REFpb", 1)]

    def refresh_stall_ns(self) -> float:
        return self.t.tRFCpb + self.t.tRREFpb

    def naive_refresh_stall_ns(self) -> float:
        return 2 * self.t.tRFCpb


# ---------------------------------------------------------------------------
# C/A pin serialization model (Fig 10, §IV-D)
# ---------------------------------------------------------------------------

# Row-command payload in bits. Modeling choice calibrated so the Fig 10
# crossover lands at 5 pins (the paper's minimum): 4 opcode + 2 SID +
# 3 VBA + 18 row + 7 misc/parity.
ROW_COMMAND_BITS = 34
CA_BEAT_NS = 0.5            # C/A pins clocked at 2 Gb/s (DDR at 1 GHz)
HBM4_CA_PINS = 18           # 10 row + 8 column C/A pins per channel
ROME_CA_PINS = 5


def command_issue_latency_ns(n_pins: int,
                             command_bits: int = ROW_COMMAND_BITS,
                             beat_ns: float = CA_BEAT_NS) -> float:
    """Time to serialize one row-level command over `n_pins` C/A pins."""
    if n_pins <= 0:
        raise ValueError("need at least one C/A pin")
    beats = math.ceil(command_bits / n_pins)
    return beats * beat_ns


def min_required_interval_ns(timing: HBM4Timing | None = None) -> float:
    """Tightest command-issue interval RoMe must sustain (§IV-D): a REF
    immediately after a RD_row/WR_row requires 2*tRRDS."""
    t = timing or HBM4Timing()
    return 2 * t.tRRDS


def min_ca_pins(timing: HBM4Timing | None = None) -> int:
    """Smallest pin count whose issue latency beats 2*tRRDS."""
    lim = min_required_interval_ns(timing)
    for pins in range(1, HBM4_CA_PINS + 1):
        if command_issue_latency_ns(pins) < lim:
            return pins
    return HBM4_CA_PINS


def freed_pins_per_channel() -> int:
    return HBM4_CA_PINS - ROME_CA_PINS           # 13


def extra_channels(legacy_channels: int = 32,
                   pins_per_channel: int = 120) -> tuple[int, int]:
    """(§IV-E) Channels constructible from the freed pin budget and the
    extra pins needed. HBM4 channel = 120 pins; RoMe channel = 107."""
    rome_channel_pins = pins_per_channel - freed_pins_per_channel()  # 107
    budget = freed_pins_per_channel() * legacy_channels              # 416
    n = budget // rome_channel_pins                                  # 3
    # The paper adds one channel per DRAM die (8->9 per die => 32->36/cube),
    # i.e. 4 channels, spending slightly beyond the freed budget:
    n = 4
    extra_pins = n * rome_channel_pins - budget                      # 12
    return n, extra_pins
