"""DRAM energy model (paper §VI-C, Fig 14).

Per-event energies follow the HBM energy breakdown of [2] (Folded Banks) /
[51] (Fine-Grained DRAM): data movement (core access + TSV/interposer I/O)
dominates; row activation and command transport are the terms RoMe changes.

RoMe's savings (paper Fig 14): total −1.9 / −0.7 / −0.7 % for
DeepSeek-V3 / Grok-1 / Llama-3, driven by (i) minimal ACT count — one
ACT pair per 4 KB row regardless of access pattern, vs conventional
open-page re-activations under stream interleaving — and (ii) one row-level
command on the interposer instead of 32 column commands per PC.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class EnergyParams:
    # Row path
    e_act_pj: float = 450.0          # one ACT+PRE cycle of a 1 KB bank row
    # Column/data path (per bit)
    e_core_pj_bit: float = 1.10      # bank core access + BK/BG bus
    e_io_pj_bit: float = 0.55        # TSV + interposer I/O
    # Command transport (per command over the interposer C/A pins)
    e_ca_cmd_pj: float = 12.0
    # Command generator (logic die, 7 nm) per expanded DRAM command
    e_cmdgen_pj: float = 1.5
    # Refresh
    e_refpb_pj: float = 2200.0       # one per-bank refresh burst
    # Static/background power per channel (pJ per ns)
    p_background_pj_ns: float = 45.0


@dataclass(frozen=True)
class EnergyBreakdown:
    act_pj: float
    data_core_pj: float
    data_io_pj: float
    ca_pj: float
    cmdgen_pj: float
    refresh_pj: float
    background_pj: float

    @property
    def total_pj(self) -> float:
        return (self.act_pj + self.data_core_pj + self.data_io_pj +
                self.ca_pj + self.cmdgen_pj + self.refresh_pj +
                self.background_pj)

    def as_dict(self) -> dict:
        return {
            "act": self.act_pj, "data_core": self.data_core_pj,
            "data_io": self.data_io_pj, "ca": self.ca_pj,
            "cmdgen": self.cmdgen_pj, "refresh": self.refresh_pj,
            "background": self.background_pj, "total": self.total_pj,
        }


def hbm4_energy(bytes_moved: int, n_acts: int, n_col_cmds: int,
                n_refpb: int, elapsed_ns: float, n_channels: int,
                p: EnergyParams = EnergyParams()) -> EnergyBreakdown:
    """Energy for a conventional HBM4 transfer.

    `n_acts` is the *actual* activation count (open-page conflicts between
    interleaved streams inflate it above the bytes/1KB minimum);
    `n_col_cmds` = number of RD/WR commands crossing the interposer.
    """
    bits = bytes_moved * 8
    return EnergyBreakdown(
        act_pj=n_acts * p.e_act_pj,
        data_core_pj=bits * p.e_core_pj_bit,
        data_io_pj=bits * p.e_io_pj_bit,
        ca_pj=n_col_cmds * p.e_ca_cmd_pj,
        cmdgen_pj=0.0,
        refresh_pj=n_refpb * p.e_refpb_pj,
        background_pj=elapsed_ns * n_channels * p.p_background_pj_ns,
    )


def rome_energy(bytes_moved: int, n_row_cmds: int, n_refpb: int,
                elapsed_ns: float, n_channels: int,
                overfetch_frac: float = 0.0,
                p: EnergyParams = EnergyParams()) -> EnergyBreakdown:
    """Energy for a RoMe transfer.

    One row command on the interposer expands (on the logic die) into
    2 ACT + 64 RD/WR + 2 PRE; ACT count is the minimum possible: one bank
    pair per 4 KB. `overfetch_frac` accounts for rows read beyond the bytes
    actually requested (§VII — negligible for LLM streams, significant for
    fine-grained sparse access)."""
    eff_bytes = int(bytes_moved * (1.0 + overfetch_frac))
    bits = eff_bytes * 8
    # Two ACT commands per RD_row/WR_row, each opening the row in both
    # lockstep PCs => 4 physical 1 KB bank-array activations per 4 KB row —
    # exactly the conventional minimum. The baseline's ACT count is inflated
    # above this by stream-interleaving row conflicts; RoMe's is structural.
    n_acts = 4 * n_row_cmds
    n_expanded = 68 * n_row_cmds     # 2 ACT + 64 bursts + 2 PRE
    return EnergyBreakdown(
        act_pj=n_acts * p.e_act_pj,
        data_core_pj=bits * p.e_core_pj_bit,
        data_io_pj=bits * p.e_io_pj_bit,
        ca_pj=n_row_cmds * p.e_ca_cmd_pj,            # 1 cmd vs 32/PC
        cmdgen_pj=n_expanded * p.e_cmdgen_pj,
        refresh_pj=n_refpb * p.e_refpb_pj,
        background_pj=elapsed_ns * n_channels * p.p_background_pj_ns,
    )
