"""Virtual Bank (VBA) design space (paper §IV-B, Figs 7 & 8).

Six configurations = {Fig 7(b), 7(c), 7(d)} x {Fig 8(a), 8(b)}. All deliver
full channel bandwidth from a single VBA; they differ in DRAM-internal
datapath changes (area) and in effective geometry (row size, #VBAs). The
paper measures <= 3.6 % performance spread across the six and adopts
7(d) + 8(b) — the only point requiring **no** internal DRAM modification.
"""
from __future__ import annotations

from dataclasses import dataclass
from enum import Enum


class BankMode(Enum):
    WIDER_BANK = "7b"          # single bank, doubled AG_bank (datapath x2)
    TANDEM_SAME_BG = "7c"      # two banks in the same bank group in tandem
    INTERLEAVED_DIFF_BG = "7d" # two banks in different BGs, time-multiplexed


class PCMode(Enum):
    SINGLE_PC_DOUBLE = "8a"    # one PC fetches double => BG-BUS x2 + muxes
    LOCKSTEP_PCS = "8b"        # both PCs operate simultaneously (legacy mode)


@dataclass(frozen=True)
class VBAConfig:
    bank_mode: BankMode
    pc_mode: PCMode

    # -- geometry ------------------------------------------------------------

    @property
    def effective_row_bytes(self) -> int:
        """Effective row per VBA access (base bank row = 1 KB)."""
        row = 1024
        if self.bank_mode is BankMode.WIDER_BANK:
            row *= 2               # doubled AG_bank
        else:
            row *= 2               # two banks in tandem / interleaved
        if self.pc_mode is PCMode.LOCKSTEP_PCS:
            row *= 2               # both PCs move their half simultaneously
        else:
            row *= 1               # single PC fetches double per column
        return row

    @property
    def vbas_per_channel(self) -> int:
        banks = 128                # HBM4 banks per channel
        per_vba = 1 if self.bank_mode is BankMode.WIDER_BANK else 2
        if self.pc_mode is PCMode.LOCKSTEP_PCS:
            per_vba *= 2           # a VBA spans both PCs' banks
            return banks // per_vba
        # 8(a): PCs merged from the MC view but banks counted per channel.
        return banks // per_vba

    # -- datapath multipliers (area; §IV-B & [51]) ----------------------------

    @property
    def bank_dataline_x(self) -> int:
        return 2 if self.bank_mode is BankMode.WIDER_BANK else 1

    @property
    def bkbus_x(self) -> int:
        return 2 if self.bank_mode is BankMode.WIDER_BANK else 1

    @property
    def io_ctrl_buffer_x(self) -> int:
        if self.bank_mode in (BankMode.WIDER_BANK, BankMode.TANDEM_SAME_BG):
            return 2
        return 1

    @property
    def bgbus_x(self) -> int:
        return 2 if self.pc_mode is PCMode.SINGLE_PC_DOUBLE else 1

    @property
    def needs_gbus_mux(self) -> bool:
        return self.pc_mode is PCMode.SINGLE_PC_DOUBLE

    @property
    def dram_internal_change(self) -> bool:
        """Does this point require modifying the DRAM die datapath?"""
        return (self.bank_dataline_x > 1 or self.bkbus_x > 1 or
                self.io_ctrl_buffer_x > 1 or self.bgbus_x > 1 or
                self.needs_gbus_mux)

    @property
    def area_overhead_frac(self) -> float:
        """Rough DRAM-die area overhead. [51] reports up to 77 % for a fully
        doubled (4x dataline) design; we scale linearly in the number of
        doubled structures (dataline, BK-BUS, IO buffer, BG-BUS), with the
        bank-internal dataline dominating."""
        weights = {
            "dataline": 0.45, "bkbus": 0.12, "iobuf": 0.10, "bgbus": 0.10,
        }
        f = 0.0
        if self.bank_dataline_x > 1:
            f += weights["dataline"]
        if self.bkbus_x > 1:
            f += weights["bkbus"]
        if self.io_ctrl_buffer_x > 1:
            f += weights["iobuf"]
        if self.bgbus_x > 1:
            f += weights["bgbus"]
        return f

    @property
    def name(self) -> str:
        return f"{self.bank_mode.value}+{self.pc_mode.value}"


ALL_VBA_CONFIGS = [VBAConfig(b, p) for b in BankMode for p in PCMode]

# The paper's adopted design: Fig 7(d) + Fig 8(b).
ADOPTED = VBAConfig(BankMode.INTERLEAVED_DIFF_BG, PCMode.LOCKSTEP_PCS)
