"""Calibrated queue-window model: the analytic layer of the hybrid path.

``analytic.transfer_time_ns`` is a bulk-stream roofline: bytes on the
gating channel over calibrated sustained bandwidth. Two measured regimes
sit *above* that roofline (benchmarks/engine_xval.py):

* **small steps** — serve-trace decode steps under ~100 KB land ~2x over
  the roofline because the per-step pipeline fill (queue ramp, first-ACT
  latency, refresh alignment) is a fixed cost the roofline amortizes
  away only for large transfers, and
* **fine row-thrash** — interleaved sub-row records shrink the per-row
  queue window below a row's worth of columns, so rows are served in
  several visits and re-ACTs inflate the row-command path >4x past the
  calibrated ACT rate.

This module closes those gaps with a 4-parameter per-policy correction
fitted against the cycle engine::

    predicted_ns = max(roofline_ns, arrival_span_ns)
                 + step_overhead_ns                       # pipeline fill
                 + serial_ns_per_txn * txns_gating        # queue-window
                 + thrash_ns_per_txn * fine_txns_gating   # ACT-issue
                 + ext_ns_per_rec * ext_gating            # row-open/rec

where ``txns_gating`` is the exact transaction count SystemSim's
decomposition would put on the most-loaded channel (computed in
O(n_records) by :func:`repro.core.address_map.channel_unit_counts`,
without materializing transactions), ``fine_txns_gating`` restricts
that census to records smaller than an effective row — the sub-row
interleaving that causes row re-visits on a conventional MC — and
``ext_gating`` counts the *records* touching the gating channel
(:func:`~repro.core.address_map.record_touch_counts`): each record pays
a fixed row-open/ACT path once per channel it opens, the cost that
dominates row-scale strided tenant interleaving. All four
parameters are fitted non-negative per registered
:class:`~repro.core.sched.PolicySpec` by
:func:`calibrate_queue_window` across the established stressors
(bulk anchors, small steps, ``tenant_mix``-style op-granularity
interleaving, fine row-thrash, read-trickle); the tables persist next to
the policy registry in ``sched/queue_window.json``.

The model's second job is *classification*: :func:`queue_pressure`
reports the correction relative to the roofline floor, and the hybrid
``SystemSim`` prices a step analytically only when that pressure is
below the policy's *calibrated* threshold (fitted alongside the
coefficients, capped at :data:`DEFAULT_PRESSURE_THRESHOLD`) —
contended windows drop into the cycle engine. Both
the residual band and the classification are cross-validated in
``benchmarks/hybrid_xval.py`` and ``tests/test_hybrid.py``.
"""
from __future__ import annotations

import functools
import hashlib
import json
from collections import OrderedDict
from dataclasses import asdict, dataclass
from pathlib import Path

import numpy as np

from .address_map import AddressMap, extent_census
from .analytic import ChannelEfficiency, calibrate
from .timing import MemSystemConfig, hbm4_config, rome_config

#: Pressure above which a step is "contended" and the hybrid path drops
#: into the cycle engine (fraction of the roofline floor). The *cap*:
#: calibration may lower a policy's own threshold below this when its
#: fit can't hold the band that far (see :func:`calibrate_queue_window`).
DEFAULT_PRESSURE_THRESHOLD = 0.15

#: Declared accuracy of analytic pricing inside the threshold — the same
#: 15 % band as the established engine_xval cross-validation.
HYBRID_BAND = 0.15

#: Calibration safety margin: a stressor counts as analytic-safe only if
#: its fit residual clears the band with this much headroom, so holdout
#: streams near the fitted ones stay inside the band too.
_SAFETY = 0.8

#: Where the per-policy calibration tables persist (next to the policy
#: registry, as one JSON document keyed by policy name).
TABLE_PATH = Path(__file__).resolve().parent / "sched" / "queue_window.json"


@dataclass(frozen=True)
class QueueWindowParams:
    """Fitted queue-window correction for one scheduling point."""

    policy: str
    step_overhead_ns: float     # fixed per-step pipeline-fill cost
    serial_ns_per_txn: float    # queue-window serialization per gating txn
    thrash_ns_per_txn: float    # ACT-issue serialization per fine gating txn
    ext_ns_per_rec: float       # row-open/ACT path per record per channel
    resid_rel_max: float        # worst |pred-meas|/meas on the calib suite
    calib_channels: int         # system width the fit was measured at
    n_samples: int
    #: Calibrated classification cut for THIS policy: the largest
    #: pressure (capped at :data:`DEFAULT_PRESSURE_THRESHOLD`) at which
    #: every calibration stressor still fits inside :data:`HYBRID_BAND`
    #: with margin. A policy the roofline fundamentally mispredicts
    #: (e.g. closed-page at the tRC random-row rate) calibrates to ~0 —
    #: its hybrid degenerates to pure cycle, which is safe.
    pressure_threshold: float = DEFAULT_PRESSURE_THRESHOLD

    def predict_extra_ns(self, txns_gating: float, fine_txns_gating: float,
                         ext_gating: float = 0.0) -> float:
        return (self.step_overhead_ns
                + self.serial_ns_per_txn * txns_gating
                + self.thrash_ns_per_txn * fine_txns_gating
                + self.ext_ns_per_rec * ext_gating)


# ---------------------------------------------------------------------------
# Features (vectorized, batched, memoized per stream instance)
# ---------------------------------------------------------------------------

def _roofline_kind_ns(cfg: MemSystemConfig, eff_val: float,
                      max_bytes: np.ndarray) -> np.ndarray:
    """Vectorized replica of ``analytic.transfer_time_ns`` at
    ``act_inflation=1.0`` (the regime ``stream_time_ns`` uses): the
    gating channel's exact bytes over calibrated sustained bandwidth,
    with RoMe's whole-row rounding. Same IEEE operation sequence as the
    scalar path, so batched and per-stream pricing agree bit-for-bit."""
    bw = cfg.channel_bw_gbps * eff_val
    if cfg.ag_mc_bytes >= cfg.row_bytes:
        t = np.ceil(max_bytes / cfg.row_bytes) * cfg.row_bytes / bw
    else:
        t = max_bytes / bw
    return np.where(max_bytes == 0.0, 0.0, t)


def _features_batch(streams, cfg: MemSystemConfig, amap: AddressMap,
                    eff: ChannelEfficiency) -> "list[dict]":
    """Compute the feature dicts of many streams in one vectorized pass:
    every record of every stream goes through a single segmented
    :func:`~repro.core.address_map.extent_census` call (segments =
    (stream, kind) pairs), and the rooflines/gating maxima fall out
    array-at-a-time. No per-record Python."""
    n = len(streams)
    nch = amap.n_channels
    cols = [s.arrays() for s in streams]
    lens = np.array([c[0].size for c in cols], dtype=np.int64)
    total = int(lens.sum())
    if total:
        addr = np.concatenate([c[0] for c in cols])
        size = np.concatenate([c[1] for c in cols])
        is_w = np.concatenate([c[2] for c in cols])
        seg = np.repeat(np.arange(n), lens)
    else:
        addr = size = seg = np.zeros(0, np.int64)
        is_w = np.zeros(0, bool)
    census = extent_census(amap, addr, size, seg=2 * seg + is_w,
                           n_segs=2 * n)
    bytes_k = census["bytes"].reshape(n, 2, nch)
    units = census["units"].reshape(n, 2, nch).sum(axis=1)
    ext = census["touches"].reshape(n, 2, nch).sum(axis=1)
    fine_sel = size < cfg.row_bytes
    fine = extent_census(amap, addr[fine_sel], size[fine_sel],
                         seg=seg[fine_sel], n_segs=n)["units"]
    base = (_roofline_kind_ns(cfg, eff.read_eff,
                              bytes_k[:, 0, :].max(axis=1).astype(float))
            + _roofline_kind_ns(cfg, eff.write_eff,
                                bytes_k[:, 1, :].max(axis=1).astype(float)))
    out = []
    for i in range(n):
        arrival = cols[i][3]
        span = (float(arrival.max() - arrival.min())
                if arrival.size >= 2 else 0.0)
        out.append({
            "base_ns": float(base[i]),
            "span_ns": span,
            "txns_gating": float(units[i].max(initial=0)),
            "fine_txns_gating": float(fine[i].max(initial=0)),
            "ext_gating": float(ext[i].max(initial=0)),
            "total_txns": int(units[i].sum()),
            "mc_channel_bytes": units[i] * amap.stripe_bytes,
        })
    return out


def stream_features_many(streams, cfg: MemSystemConfig, amap: AddressMap,
                         eff: ChannelEfficiency | None = None
                         ) -> "list[dict]":
    """Feature dicts for a whole batch of streams in one vectorized
    call — the batched pricing entry point the fleet-scale paths use.

    Results are memoized per :class:`~repro.workloads.ExtentStream`
    *instance* (streams are immutable, so a stream re-classified every
    hybrid run — e.g. the same recorded step priced under several
    thresholds — never re-runs its census), keyed by the
    (cfg, amap, eff) tuple the features depend on.
    """
    eff = eff or calibrate(cfg)
    key = ("qwf", cfg, amap, eff)
    out: list = [None] * len(streams)
    missing = []
    for i, s in enumerate(streams):
        memo = getattr(s, "memo", None)
        if memo is not None:
            f = memo.get(key)
            if f is not None:
                out[i] = f
                continue
        missing.append(i)
    if missing:
        fresh = _features_batch([streams[i] for i in missing],
                                cfg, amap, eff)
        for i, f in zip(missing, fresh):
            out[i] = f
            memo = getattr(streams[i], "memo", None)
            if memo is not None:
                memo[key] = f
    return out


def stream_features(stream, cfg: MemSystemConfig, amap: AddressMap,
                    eff: ChannelEfficiency | None = None) -> dict:
    """O(n_records) census of a timed stream — everything the model and
    the hybrid classifier need, with no transaction materialization.

    ``base_ns`` is the calibrated roofline (``stream_time_ns``);
    ``span_ns`` the arrival span (a trickle stream is paced by arrivals,
    not service); ``txns_gating``/``fine_txns_gating`` the most-loaded
    channel's decomposed transaction counts (all records / sub-row
    records); ``total_txns`` the system-wide count (the cycle-cost guard
    the hybrid path uses); ``mc_channel_bytes`` the per-channel bytes at
    MC granularity — identical to what the cycle engine would report,
    since both move whole stripe units.

    One-stream view of :func:`stream_features_many` (same vectorized
    census, same per-instance memo).
    """
    return stream_features_many([stream], cfg, amap, eff=eff)[0]


def predict_step_ns(stream, cfg: MemSystemConfig, amap: AddressMap,
                    params: QueueWindowParams,
                    eff: ChannelEfficiency | None = None,
                    feats: dict | None = None) -> float:
    """Queue-window-corrected service time of one step stream."""
    f = feats or stream_features(stream, cfg, amap, eff=eff)
    floor = max(f["base_ns"], f["span_ns"])
    return floor + params.predict_extra_ns(f["txns_gating"],
                                           f["fine_txns_gating"],
                                           f["ext_gating"])


def queue_pressure(stream, cfg: MemSystemConfig, amap: AddressMap,
                   params: QueueWindowParams,
                   eff: ChannelEfficiency | None = None,
                   feats: dict | None = None) -> float:
    """Modeled contention: the fitted correction relative to the
    roofline floor. ~0 == the roofline alone explains the step
    (uncontended, analytic pricing is trustworthy); above
    :data:`DEFAULT_PRESSURE_THRESHOLD` the queue-window terms dominate
    and the hybrid path defers to the cycle engine."""
    f = feats or stream_features(stream, cfg, amap, eff=eff)
    floor = max(f["base_ns"], f["span_ns"])
    if floor <= 0.0:
        return 0.0
    return params.predict_extra_ns(f["txns_gating"],
                                   f["fine_txns_gating"],
                                   f["ext_gating"]) / floor


# ---------------------------------------------------------------------------
# Step-pricing memo cache
# ---------------------------------------------------------------------------

class StepPricer:
    """Bounded LRU memo over step-stream pricing features.

    Continuous-batching decode steps are highly repetitive: the same
    batch size and per-sequence page counts produce streams with the
    same *shape* at different clock offsets and page addresses. The
    cache key is a signature digest over each record's pricing-relevant
    shape: ``(kind, arrival - arrival[0], addr mod stripe, first-unit
    channel, nbytes)``. Those five values determine every feature the
    queue-window model consumes — the per-kind per-channel transaction,
    byte, and record-touch counts (the cyclic-window census depends only
    on the sub-stripe offset, starting channel, and length of each
    record), the roofline, and the arrival span — so a signature hit is
    *exact*, not approximate. Shift-invariance (arrivals keyed relative
    to the first record) is what makes the same recorded step hit at
    every clock position.

    A correctness guard re-prices every ``recheck_every``-th hit from
    scratch (bypassing both this cache and the per-stream memo) and
    asserts the cached prediction within ``tolerance`` — the sampled
    re-pricing the fleet benchmarks stamp into their records.

    Entries are evicted LRU past ``maxsize``; ``stats`` reports
    hit/miss/recheck counters and the hit rate.
    """

    def __init__(self, cfg: MemSystemConfig, amap: AddressMap,
                 params: QueueWindowParams,
                 eff: ChannelEfficiency | None = None,
                 maxsize: int = 65536, recheck_every: int = 64,
                 tolerance: float = HYBRID_BAND):
        self.cfg = cfg
        self.amap = amap
        self.params = params
        self.eff = eff or calibrate(cfg)
        self.maxsize = maxsize
        self.recheck_every = recheck_every
        self.tolerance = tolerance
        self._cache: "OrderedDict[bytes, dict]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.rechecks = 0

    def signature(self, stream) -> bytes:
        """Pricing signature digest of one stream (memoized per
        instance). See the class docstring for why it is exact."""
        memo = getattr(stream, "memo", None)
        skey = ("qwsig", self.cfg, self.amap)
        if memo is not None:
            sig = memo.get(skey)
            if sig is not None:
                return sig
        addr, nbytes, is_write, arrival = stream.arrays()
        g = self.amap.stripe_bytes
        nch = self.amap.n_channels
        h = hashlib.blake2b(digest_size=16)
        h.update(np.array([addr.size, g, nch], np.int64).tobytes())
        h.update((addr % g).tobytes())
        h.update(((addr // g) % nch).tobytes())
        h.update(nbytes.tobytes())
        h.update(is_write.tobytes())
        rel = arrival - arrival[0] if arrival.size else arrival
        h.update(rel.tobytes())
        sig = h.digest()
        if memo is not None:
            memo[skey] = sig
        return sig

    def predict_ns(self, feats: dict) -> float:
        floor = max(feats["base_ns"], feats["span_ns"])
        return floor + self.params.predict_extra_ns(
            feats["txns_gating"], feats["fine_txns_gating"],
            feats["ext_gating"])

    def _recheck(self, stream, cached: dict) -> None:
        """Sampled hit verification: recompute from scratch (no caches)
        and assert the cached prediction inside the declared band."""
        self.rechecks += 1
        fresh = _features_batch([stream], self.cfg, self.amap, self.eff)[0]
        p_new, p_old = self.predict_ns(fresh), self.predict_ns(cached)
        denom = max(abs(p_new), 1e-9)
        if abs(p_new - p_old) / denom > self.tolerance:
            raise AssertionError(
                f"StepPricer cache hit re-priced outside the "
                f"{self.tolerance:.0%} band: cached {p_old} ns vs fresh "
                f"{p_new} ns — signature collision or census regression")

    def features_many(self, streams) -> "list[dict]":
        """Features for each stream, through the signature cache; misses
        are priced in one vectorized batch."""
        out: list = [None] * len(streams)
        missing: list = []
        for i, s in enumerate(streams):
            sig = self.signature(s)
            f = self._cache.get(sig)
            if f is not None:
                self._cache.move_to_end(sig)
                self.hits += 1
                if self.recheck_every and self.hits % self.recheck_every == 0:
                    self._recheck(s, f)
                out[i] = f
            else:
                self.misses += 1
                missing.append((i, sig))
        if missing:
            fresh = _features_batch([streams[i] for i, _ in missing],
                                    self.cfg, self.amap, self.eff)
            for (i, sig), f in zip(missing, fresh):
                out[i] = f
                self._cache[sig] = f
                while len(self._cache) > self.maxsize:
                    self._cache.popitem(last=False)
        return out

    def features(self, stream) -> dict:
        return self.features_many([stream])[0]

    @property
    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "rechecks": self.rechecks,
            "entries": len(self._cache),
            "hit_rate": round(self.hits / total, 4) if total else 0.0,
        }


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

def _stressor_streams(cfg: MemSystemConfig) -> list[tuple[str, object]]:
    """The fitting suite: every established regime the correction must
    explain, sized so the cycle engine stays seconds-fast at the
    calibration width. Row granularity differs 128x between families, so
    byte sizes scale with ``row_bytes`` where the *pattern* (not the
    byte count) is the point."""
    from ..workloads.builders import (bulk_stream, interleave, sparse_stream,
                                      strided_stream)
    row = cfg.row_bytes
    streams: list[tuple[str, object]] = [
        # Roofline anchors: the regimes analytic calibration already fits.
        ("bulk_256k", bulk_stream(1 << 18)),
        ("bulk_1m", bulk_stream(1 << 20)),
        ("bulk_write_512k", bulk_stream(1 << 19, kind="write")),
        # Small steps: the <100 KB serve-step regime (~2x the roofline).
        ("small_8k", bulk_stream(1 << 13)),
        ("small_32k", bulk_stream(1 << 15)),
        ("small_96k", bulk_stream(3 << 15)),
        ("small_mixed", interleave([
            bulk_stream(1 << 15, n_extents=4),
            bulk_stream(1 << 14, kind="write",
                        base_addr=1 << 20).retagged(1)])),
        # tenant_mix-style op-granularity interleaving: several tenants'
        # row-scale records arriving together (queue-window serialization).
        ("tenant_mix", interleave([
            strided_stream(16, 2 * row, 4 * row,
                           base_addr=t << 21).retagged(t)
            for t in range(4)])),
        # Small decode-step shape: a small bulk slice + row-scale tenant
        # strides + write tail — the floor is small enough that per-record
        # row-open costs show, unlike the bulk-dominated mixes above.
        ("small_tenant_mix", interleave([
            bulk_stream(40 * row, n_extents=2),
            strided_stream(12, 2 * row, 4 * row,
                           base_addr=1 << 21).retagged(1),
            bulk_stream(4 * row, kind="write",
                        base_addr=1 << 24).retagged(2)])),
        # Fine row-thrash: sub-row records strided a row apart — every
        # record its own row, the >4x ACT-inflation regime.
        ("fine_thrash", strided_stream(256, max(64, row // 16), row,
                                       base_addr=1 << 22)),
        ("fine_gather", sparse_stream(128, max(64, row // 16), 1 << 22,
                                      seed=3, stream_id=2)),
        # Read trickle: arrival-paced, service nearly idle — the regime
        # where span (not the roofline) is the floor.
        ("read_trickle", strided_stream(64, row, 2 * row,
                                        base_addr=1 << 23,
                                        inter_arrival_ns=400.0)),
        # Replay-like small step: a handful of row-scale reads from
        # several streams at t=0 plus a small write tail.
        ("replay_step", interleave(
            [bulk_stream(4 * row, n_extents=4,
                         base_addr=s << 20).retagged(s) for s in range(4)]
            + [bulk_stream(row, kind="write",
                           base_addr=1 << 24).retagged(9)])),
    ]
    return streams


def stressor_streams(cfg: MemSystemConfig) -> "list[tuple[str, object]]":
    """Public view of the calibration stressor suite — the labeled
    ``(name, stream)`` regimes the fit must explain. Exposed so
    benchmarks/hybrid_xval.py and the property tests validate the hybrid
    band on *exactly* the streams the parameters were fitted on (plus
    their own holdouts)."""
    return _stressor_streams(cfg)


def _fit_nonneg(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Least squares with non-negative coefficients: solve, clamp the
    most-negative coefficient to zero, refit the rest (active-set NNLS;
    exact for this small system)."""
    cols = list(range(X.shape[1]))
    coef = np.zeros(X.shape[1])
    while cols:
        c, *_ = np.linalg.lstsq(X[:, cols], y, rcond=None)
        if (c >= 0).all():
            coef[cols] = c
            break
        cols.pop(int(np.argmin(c)))
    return coef


def calibrate_queue_window(spec, n_channels: int = 2) -> QueueWindowParams:
    """Fit the 4-parameter correction for one registered scheduling
    point against its cycle engine across the stressor suite.

    The fit is measured at a small system width (``n_channels=2`` keeps
    the full catalogue's calibration in the tens of seconds): the
    parameters are *per-gating-channel-transaction* costs, so they
    transfer across widths — the features re-derive the gating channel's
    census from the actual address map at prediction time. Residuals are
    recorded in ``resid_rel_max`` so consumers can see the band the fit
    actually achieved (cross-validated at full width in
    benchmarks/hybrid_xval.py).
    """
    cfg = hbm4_config() if spec.family == "hbm4" else rome_config()
    sim = spec.system_sim(n_channels=n_channels)
    eff = calibrate(cfg)
    rows, meas = [], []
    for _, stream in _stressor_streams(cfg):
        f = stream_features(stream, cfg, sim.amap, eff=eff)
        floor = max(f["base_ns"], f["span_ns"])
        measured = sim.run(stream).total_ns
        rows.append((1.0, f["txns_gating"], f["fine_txns_gating"],
                     f["ext_gating"], floor))
        meas.append(measured)
    X = np.array([r[:4] for r in rows])
    floors = np.array([r[4] for r in rows])
    y = np.maximum(np.array(meas) - floors, 0.0)
    coef = _fit_nonneg(X, y)
    pred = floors + X @ coef
    relerr = np.abs(pred - np.array(meas)) / np.array(meas)
    resid = float(np.max(relerr))
    # Calibrated classification cut: the fitted pressure of every
    # stressor whose residual does NOT clear the band with margin pushes
    # the threshold just below it — those regimes must route to the
    # cycle engine at prediction time.
    press = np.where(floors > 0.0, (X @ coef) / floors, 0.0)
    bad = press[relerr >= _SAFETY * HYBRID_BAND]
    threshold = DEFAULT_PRESSURE_THRESHOLD
    if bad.size:
        threshold = min(threshold, 0.95 * float(bad.min()))
    return QueueWindowParams(
        policy=spec.name,
        step_overhead_ns=float(coef[0]),
        serial_ns_per_txn=float(coef[1]),
        thrash_ns_per_txn=float(coef[2]),
        ext_ns_per_rec=float(coef[3]),
        resid_rel_max=resid,
        calib_channels=n_channels,
        n_samples=len(meas),
        pressure_threshold=round(max(threshold, 0.0), 4),
    )


# ---------------------------------------------------------------------------
# Persistence
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _load_table() -> dict:
    if not TABLE_PATH.exists():
        return {}
    with open(TABLE_PATH) as f:
        return json.load(f)


def save_queue_window_table(params: "list[QueueWindowParams]") -> None:
    """Persist fitted tables (sorted by policy name, stable diffs)."""
    doc = {p.policy: {k: v for k, v in asdict(p).items() if k != "policy"}
           for p in sorted(params, key=lambda p: p.policy)}
    with open(TABLE_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    _load_table.cache_clear()
    queue_window_params.cache_clear()


@functools.lru_cache(maxsize=None)
def queue_window_params(policy_name: str) -> QueueWindowParams:
    """Fitted parameters for a registered policy: from the persisted
    table when present (the committed, reviewed fit), else calibrated on
    the fly and cached for the process (ad-hoc / newly registered
    specs)."""
    entry = _load_table().get(policy_name)
    if entry is not None:
        return QueueWindowParams(policy=policy_name, **entry)
    from .sched.registry import policy_spec
    return calibrate_queue_window(policy_spec(policy_name))


def calibrate_all(n_channels: int = 2, write: bool = True
                  ) -> "list[QueueWindowParams]":
    """Fit every registered policy and (by default) rewrite the
    persisted table — the regeneration entry point
    (``python -m repro.core.queue_model``)."""
    from .sched.registry import registered_policies
    params = [calibrate_queue_window(spec, n_channels=n_channels)
              for spec in registered_policies().values()]
    if write:
        save_queue_window_table(params)
    return params


if __name__ == "__main__":
    for p in calibrate_all():
        print(f"{p.policy:24s} c0={p.step_overhead_ns:9.1f} "
              f"c1={p.serial_ns_per_txn:8.3f} c2={p.thrash_ns_per_txn:8.3f} "
              f"c3={p.ext_ns_per_rec:8.3f} "
              f"resid_rel_max={p.resid_rel_max:.3f} "
              f"threshold={p.pressure_threshold:.4f}")


__all__ = [
    "QueueWindowParams", "StepPricer", "stream_features",
    "stream_features_many", "predict_step_ns",
    "queue_pressure", "stressor_streams",
    "calibrate_queue_window", "calibrate_all",
    "queue_window_params", "save_queue_window_table",
    "DEFAULT_PRESSURE_THRESHOLD", "TABLE_PATH",
]
