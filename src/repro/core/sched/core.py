"""Shared channel-simulation core: one event loop, N policies.

:class:`ChannelSimCore` owns everything both memory controllers have in
common — the event clock, the arrival-ordered :class:`_PendingQueue`, the
demand-aware bounded-postponement refresh governor, the idle-advance rule
(jump to min(next arrival, next refresh due)), and per-transaction finish
accounting. Everything controller-specific — which command to issue next,
what per-bank/per-VBA state exists, how a refresh stalls the array — lives
behind the :class:`~repro.core.sched.policies.SchedulerPolicy` interface.

The split makes the paper's Table IV complexity contrast *structural* in
the code: the conventional FR-FCFS policy carries 64 seven-state bank FSMs
and ~15 timing clocks; the RoMe policy carries 5 four-state FSMs and the
ten Table III row-to-row gaps. The loop they plug into is identical.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from itertools import islice
from typing import NamedTuple

import numpy as np


class CmdRecord(NamedTuple):
    """One emitted memory command, for the trace sanitizer.

    HBM4 policies emit DRAM-level ops (``ACT``/``RD``/``WR``/``PRE``/
    ``REF``); the RoMe policy emits row-level ops (``RD_row``/``WR_row``/
    ``REF``) — Table III *is* its protocol, so conformance is checked at
    the granularity the MC actually schedules. Fields that don't apply to
    an op (row for PRE/REF, data window for non-column commands, sid for
    refresh) are ``-1``. A NamedTuple keeps records cheap, picklable
    (they ride back through ``core.pool`` inside :class:`SimResult`) and
    comparable (the vectorized driver asserts full trace identity).
    """

    t_ns: float            # command issue time on the C/A bus
    op: str                # ACT | RD | WR | PRE | REF | RD_row | WR_row
    bank: int              # flat bank id (HBM4) / VBA id (RoMe)
    pc: int                # pseudo channel (RoMe lockstep: always 0)
    sid: int               # stack id, -1 when not request-driven
    row: int               # row (ACT/RD/WR) or -1
    data_start_ns: float   # first data beat on the DQ bus, -1.0 if none
    data_end_ns: float     # last data beat leaves the bus, -1.0 if none


@dataclass
class Txn:
    """One memory transaction at MC access granularity."""

    arrival_ns: float
    bank: int           # flat bank id within the channel (HBM4) / VBA id (RoMe)
    row: int
    col: int = 0        # column index within the row (HBM4 only)
    is_write: bool = False
    sid: int = 0        # stack id (rank)
    stream: int = 0     # software stream tag (for stats only)


def counts_row_hit_rate(cmd_counts: dict) -> float:
    """Row-buffer hit rate derived from a command-count dict.

    ``RD``/``WR`` are the column commands; every ``ACT`` opens a row for
    an access that missed the row buffer, so ``hits = (RD + WR) - ACT``
    and the rate is ``hits / (RD + WR)``. Row-granular controllers
    (counts carrying ``row_commands``) precharge after every row access
    — there is no row buffer to hit, so their rate is 0.0 *by
    construction*; the HBM4-vs-RoMe row-hit gap a telemetry report shows
    is therefore exactly the locality an RH+-style policy could exploit,
    not a bug. Returns 0.0 when no column command was issued."""
    if "row_commands" in cmd_counts:
        return 0.0
    col = cmd_counts.get("RD", 0) + cmd_counts.get("WR", 0)
    if col <= 0:
        return 0.0
    return max(0.0, (col - cmd_counts.get("ACT", 0)) / col)


@dataclass
class SimResult:
    finish_ns: np.ndarray          # completion time per txn (input order)
    total_ns: float                # makespan
    bytes_moved: int
    cmd_counts: dict = field(default_factory=dict)  # ACT/RD/WR/PRE/REF/row cmds
    trace: list | None = None      # CmdRecords when run with emit_trace=True
    #: Telemetry samples when run with ``sample_window_ns`` set: tuples
    #: ``(t_ns, queue_depth, ref_backlog, draining, counts_snapshot)``
    #: appended at window-boundary crossings (see
    #: :class:`repro.obs.MetricsProbe`); None when sampling is off.
    samples: list | None = None

    @property
    def bandwidth_gbps(self) -> float:
        if self.total_ns <= 0:
            return 0.0
        return self.bytes_moved / self.total_ns  # B/ns == GB/s

    @property
    def row_hit_rate(self) -> float:
        """Row-buffer hit rate of this run (:func:`counts_row_hit_rate`
        over :attr:`cmd_counts`): ``(RD+WR hits) / column commands``,
        0.0 for row-granular (always-precharge) controllers."""
        return counts_row_hit_rate(self.cmd_counts)


class _PendingQueue:
    """Arrival-ordered outstanding transactions with O(1) dequeue.

    ``list.remove`` made every dequeue O(n) worst-case in the number of
    outstanding transactions — and, because it matches by dataclass
    equality, it removed the *wrong object* when two field-identical
    transactions were in flight (one got serviced twice, the other
    never). Removal here is by identity: tombstone the slot via an
    id->slot map, with a head cursor that skips tombstones. The scheduler
    only removes transactions inside the first ``queue_depth`` live
    entries, so at most ``queue_depth`` interior tombstones exist at any
    time and every window scan is O(queue_depth); with no interior
    tombstones (the common head-of-queue dequeue) the window is a plain
    list slice."""

    __slots__ = ("_slots", "_pos", "_head", "_n", "_tomb")

    def __init__(self, txns: list):
        self._slots = list(txns)
        self._pos = {id(tx): i for i, tx in enumerate(self._slots)}
        if len(self._pos) != len(self._slots):
            raise ValueError(
                "trace contains the same Txn object more than once; pass "
                "distinct Txn instances (field-identical copies are fine)")
        self._head = 0
        self._n = len(self._slots)
        self._tomb = 0                 # tombstones at index >= _head

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0

    def _skip_tombstones(self) -> None:
        slots, h = self._slots, self._head
        while h < len(slots) and slots[h] is None:
            h += 1
            self._tomb -= 1
        self._head = h

    def head(self) -> Txn:
        """Oldest outstanding transaction."""
        self._skip_tombstones()
        return self._slots[self._head]

    def first(self, depth: int) -> list:
        """The scheduler window: up to `depth` oldest live transactions."""
        self._skip_tombstones()
        slots, h, tomb = self._slots, self._head, self._tomb
        if tomb == 0:
            return slots[h:h + depth]
        # Every tombstone index t satisfies t < h + depth + tomb (removals
        # only happen inside the window), so this slice is guaranteed to
        # contain the full window; filter/islice keep the scan in C.
        return list(islice(filter(None, slots[h:h + depth + tomb]), depth))

    def remove(self, tx: Txn) -> None:
        self._slots[self._pos.pop(id(tx))] = None
        self._n -= 1
        self._tomb += 1


class ChannelRunState:
    """One channel's in-flight simulation: the event loop, suspended.

    Everything :meth:`ChannelSimCore.run` used to keep in local variables
    lives here, so a run can be advanced incrementally —
    :meth:`advance` executes up to ``max_iters`` loop iterations and
    returns whether the channel finished. This is the batched state-step
    the vectorized multi-channel driver (:mod:`.vectorized`) interleaves
    across all channels of a cube; because the scalar path
    (:meth:`ChannelSimCore.run`) drives the *same* state machine to
    completion in one call, and channels share no state, any interleaving
    of ``advance`` calls is bit-identical to the scalar result.
    """

    __slots__ = ("core", "policy", "pending", "finish", "counts",
                 "idx_in_finish", "period", "next_ref_t", "next_ref_unit",
                 "ref_backlog", "now", "n_txns", "trace", "_counts_base",
                 "_trace_base", "samples", "next_sample_t", "_samples_base")

    def __init__(self, core: "ChannelSimCore", txns: list[Txn]):
        pol = core.policy
        order = sorted(range(len(txns)), key=lambda i: txns[i].arrival_ns)
        ordered = [txns[i] for i in order]
        self.core = core
        self.policy = pol
        self.idx_in_finish = {id(tx): order[k]
                              for k, tx in enumerate(ordered)}
        self.pending = _PendingQueue(ordered)
        self.finish = np.zeros(len(txns))
        self.counts = {k: 0 for k in pol.count_keys}
        self.counts["ref_backlog_max"] = 0
        # The trace list is handed to the policy *before* begin() so a
        # policy may cache it in per-run state; None keeps every emission
        # site a single attribute test (zero-cost when off).
        self.trace = [] if core.emit_trace else None
        pol.trace = self.trace
        pol.begin(self.counts)
        # Telemetry sampling (repro.obs.MetricsProbe): with a sample
        # window set, the event loop appends one state sample per
        # window-boundary crossing. When off, next_sample_t = +inf makes
        # the hot-loop guard a single always-false float compare — the
        # same zero-cost-when-off contract as the trace sink above. The
        # leading sample is the baseline snapshot deltas diff against.
        w = core.sample_window_ns
        self.samples = [] if w else None
        self.next_sample_t = float(w) if w else float("inf")
        if self.samples is not None:
            self.samples.append((0.0, len(txns), 0, False,
                                 dict(self.counts)))
        self.period = pol.ref_period
        self.next_ref_t = self.period
        self.next_ref_unit = 0
        self.ref_backlog = 0
        self.now = 0.0
        self.n_txns = len(txns)
        self._counts_base = None       # set by feed(): warm per-batch deltas
        self._trace_base = 0           # trace length at the last feed()
        self._samples_base = 0         # sample count at the last feed()

    @property
    def finished(self) -> bool:
        return not self.pending

    def feed(self, txns: list[Txn]) -> None:
        """Load the next transaction batch into a *drained* state without
        resetting any warm channel state.

        This is the suspend/resume seam warm cross-step replay
        (:meth:`SystemSim.run_steps` with ``warm=True``) is built on: the
        policy FSMs (open rows, per-PC timing clocks), the refresh
        governor (absolute due cadence, rotation unit, backlog) and the
        event clock all carry over — only the queue, the finish array and
        the per-batch command-count baseline are renewed. Arrivals are on
        the same absolute clock as every previous batch; arrivals in a
        gap after the last drain are reached through the normal
        idle-advance, which issues the refreshes due *inside* the gap at
        their own anchors. Feeding an undrained state is an error — the
        single event loop cannot interleave two batches' accounting.
        """
        if self.pending:
            raise RuntimeError(
                f"feed() on an undrained channel: {len(self.pending)} of "
                f"{self.n_txns} transactions outstanding")
        order = sorted(range(len(txns)), key=lambda i: txns[i].arrival_ns)
        ordered = [txns[i] for i in order]
        self.idx_in_finish = {id(tx): order[k]
                              for k, tx in enumerate(ordered)}
        self.pending = _PendingQueue(ordered)
        self.finish = np.zeros(len(txns))
        self.n_txns = len(txns)
        self._counts_base = dict(self.counts)
        if self.trace is not None:
            self._trace_base = len(self.trace)
        if self.samples is not None:
            # Per-feed baseline marker: the first sample of a feed slice
            # carries the cumulative snapshot window deltas start from.
            self._samples_base = len(self.samples)
            self.samples.append((self.now, len(self.pending),
                                 self.ref_backlog,
                                 bool(getattr(self.policy, "draining",
                                              False)),
                                 dict(self.counts)))

    def advance(self, max_iters: int = 1) -> bool:
        """Execute up to ``max_iters`` event-loop iterations; returns True
        once the channel has drained. Hot path: every per-iteration
        attribute is hoisted into locals so a batched advance amortizes
        the Python dispatch cost across the whole batch."""
        core = self.core
        pol = self.policy
        pending = self.pending
        finish = self.finish
        counts = self.counts
        idx_in_finish = self.idx_in_finish
        refresh = core.refresh
        max_post = core.max_ref_postpone
        depth = core.queue_depth
        period = self.period
        next_ref_t = self.next_ref_t
        next_ref_unit = self.next_ref_unit
        ref_backlog = self.ref_backlog
        now = self.now
        issue = pol.issue
        issue_refresh = pol.issue_refresh
        n_ref_units = pol.n_ref_units
        samples = self.samples
        next_sample_t = self.next_sample_t
        sample_w = core.sample_window_ns

        for _ in range(max_iters):
            if not pending:
                break
            # Telemetry sampling: one state snapshot per window-boundary
            # crossing. next_sample_t is +inf when sampling is off, so
            # the disabled cost is this single float compare; sampling
            # itself only *observes* (appends), never changes loop state
            # — results stay bit-identical either way.
            if now >= next_sample_t:
                samples.append((now, len(pending), ref_backlog,
                                bool(getattr(pol, "draining", False)),
                                dict(counts)))
                next_sample_t += sample_w
                if next_sample_t <= now:     # idle jump skipped windows
                    next_sample_t = (now // sample_w + 1.0) * sample_w
            qwin = pending.first(depth)

            # -- refresh governor: rotating per-unit refresh with
            # demand-aware bounded postponement, each issue anchored at its
            # own due time so refreshes of different units may overlap. --
            while refresh and next_ref_t <= now:
                ref_backlog += 1
                next_ref_t += period
            if ref_backlog > counts["ref_backlog_max"]:
                counts["ref_backlog_max"] = ref_backlog
            while ref_backlog > 0:
                demanded = any(tx.bank == next_ref_unit for tx in qwin)
                if demanded and ref_backlog < max_post:
                    break
                due = next_ref_t - ref_backlog * period
                issue_refresh(next_ref_unit, due)
                next_ref_unit = (next_ref_unit + 1) % n_ref_units
                ref_backlog -= 1

            window = [tx for tx in qwin if tx.arrival_ns <= now]
            if not window:
                # Idle: jump to the next event — arrival OR refresh due —
                # so refreshes due during a sparse-arrival gap are issued
                # in the gap (bounded postponement) instead of piling up
                # behind the next arrival.
                cand = pending.head().arrival_ns
                if refresh:
                    cand = min(cand, next_ref_t)
                now = max(now + 1e-9, cand)
                continue

            now, issued, completions = issue(window, now)
            for tx, fin in completions:
                finish[idx_in_finish[id(tx)]] = fin
                pending.remove(tx)

            if not issued:
                # Nothing issueable: jump to the next event (refresh or
                # arrival) to guarantee progress.
                nxt = [tx.arrival_ns for tx in qwin if tx.arrival_ns > now]
                cand = min(nxt) if nxt else now + period
                if refresh:
                    cand = min(cand, next_ref_t)
                now = max(now + 1e-9, cand)

        self.next_ref_t = next_ref_t
        self.next_ref_unit = next_ref_unit
        self.ref_backlog = ref_backlog
        self.now = now
        self.next_sample_t = next_sample_t
        return not pending

    def result(self) -> SimResult:
        """The drained batch's :class:`SimResult`. After a :meth:`feed`
        the command counts are the *delta* since that feed and the trace
        and telemetry samples are the per-feed slices. The one exception
        is ``ref_backlog_max``: it is a session-cumulative **high-water
        mark**, not a counter — it is *never* reset at a feed boundary,
        and attaching telemetry sampling (``sample_window_ns``) does not
        change that: the per-window backlog series comes from the
        sampled ``ref_backlog`` scalar, while the counts key keeps
        reporting the worst backlog the whole warm session has ever
        seen. A later feed's result can therefore report a
        ``ref_backlog_max`` reached during an *earlier* feed — that is
        the intended semantics (pinned by tests/test_obs.py), so warm
        step results stay comparable with fresh per-step runs on every
        true counter while the refresh high-water stays an invariant of
        the session. Finish times are always on the state's absolute
        clock."""
        if self.pending:
            raise RuntimeError(
                f"channel not drained: {len(self.pending)} of "
                f"{self.n_txns} transactions outstanding")
        bytes_moved = self.n_txns * self.policy.bytes_per_txn
        counts, trace, samples = self.counts, self.trace, self.samples
        if self._counts_base is not None:
            base = self._counts_base
            counts = {k: (v if k == "ref_backlog_max"
                          else v - base.get(k, 0))
                      for k, v in counts.items()}
            if trace is not None:
                trace = trace[self._trace_base:]
            if samples is not None:
                samples = samples[self._samples_base:]
        else:
            # Snapshot: a later feed() keeps mutating the live dict/list,
            # and the first batch's result must not grow with the session.
            counts = dict(counts)
            if trace is not None:
                trace = trace[:]
            if samples is not None:
                samples = samples[:]
        return SimResult(self.finish,
                         float(self.finish.max(initial=0.0)),
                         bytes_moved, counts, trace=trace, samples=samples)


class ChannelSimCore:
    """Policy-driven event loop for one memory channel.

    The loop body is the invariant part of both controllers:

    1. take the scheduler window (`queue_depth` oldest pending txns),
    2. accrue refresh debt (one unit per elapsed ``policy.ref_period``),
    3. drain the debt — a refresh due for a unit with queued demand is
       postponed (JEDEC bounded postponement) until the backlog hits
       ``max_ref_postpone``, each issue anchored at its own due time,
    4. let the policy issue command work for the arrived window,
    5. if nothing arrived / nothing issued, jump the clock to the next
       event (arrival or refresh due) so progress is guaranteed and
       refreshes fire *inside* idle gaps instead of piling up behind the
       next arrival.

    Policies mutate their own FSM state and the shared ``counts`` dict;
    the loop state (clock, queue, refresh debt, finish array) lives in a
    :class:`ChannelRunState` — :meth:`run` drives one state to
    completion, :meth:`start_run` hands the state out for incremental
    (batched / vectorized multi-channel) advancing.
    """

    def __init__(self, policy, queue_depth: int, refresh: bool = True,
                 max_ref_postpone: int = 8, emit_trace: bool = False,
                 sample_window_ns: float | None = None):
        self.policy = policy
        self.queue_depth = queue_depth
        self.refresh = refresh
        self.max_ref_postpone = max_ref_postpone
        self.emit_trace = emit_trace
        if sample_window_ns is not None and sample_window_ns <= 0:
            raise ValueError(
                f"sample_window_ns must be positive, got {sample_window_ns}")
        #: telemetry sampling cadence (ns); None disables sampling and
        #: keeps the event loop bit-identical to the pre-telemetry core.
        self.sample_window_ns = sample_window_ns

    def start_run(self, txns: list[Txn]) -> ChannelRunState:
        """Begin a run without driving it: the returned state advances
        under caller control (see :mod:`repro.core.sched.vectorized`)."""
        return ChannelRunState(self, txns)

    def run(self, txns: list[Txn]) -> SimResult:
        state = ChannelRunState(self, txns)
        while not state.advance(4096):
            pass
        return state.result()
