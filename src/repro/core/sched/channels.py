"""Thin policy+timing bindings: one concrete sim class per controller.

Each class is just ``ChannelSimCore`` + a policy + the public attributes
callers key off (``t``, ``g``, geometry-derived counts). All scheduling
behaviour lives in :mod:`repro.core.sched.policies`.
"""
from __future__ import annotations

from ..timing import ChannelGeometry, HBM4Timing, RoMeTiming
from .core import ChannelSimCore
from .policies import (FRFCFSOpenPagePolicy, FRFCFSWriteDrainPolicy,
                       HBM4ClosedPagePolicy, HBM4SIDGroupPolicy,
                       RoMeRowPolicy, SchedulerPolicy)


class HBM4ChannelSim(ChannelSimCore):
    """Conventional HBM4 channel (2 pseudo channels simulated jointly).

    ``page_policy`` selects the scheduler: ``"open"`` (FR-FCFS open-page,
    the paper's baseline) or ``"closed"`` (auto-precharge after every
    access — the shallow-queue-friendly comparison point).
    """

    def __init__(self, timing: HBM4Timing | None = None,
                 geometry: ChannelGeometry | None = None,
                 queue_depth: int = 64,
                 refresh: bool = True,
                 max_ref_postpone: int = 8,
                 page_policy: str = "open",
                 policy: SchedulerPolicy | None = None,
                 emit_trace: bool = False,
                 sample_window_ns: float | None = None):
        t = timing or HBM4Timing()
        g = geometry or ChannelGeometry()
        if policy is None:
            if page_policy == "open":
                policy = FRFCFSOpenPagePolicy(t, g)
            elif page_policy == "closed":
                policy = HBM4ClosedPagePolicy(t, g)
            else:
                raise ValueError(f"unknown page_policy {page_policy!r}")
        super().__init__(policy, queue_depth, refresh, max_ref_postpone,
                         emit_trace=emit_trace,
                         sample_window_ns=sample_window_ns)
        self.t = t
        self.g = g
        self.page_policy = page_policy
        self.banks_per_pc = g.banks_per_pc
        self.n_banks = g.banks_per_channel
        self.burst_ns = g.burst_ns  # 32 B over one PC's pins


class HBM4ClosedPageChannelSim(HBM4ChannelSim):
    """Closed-page HBM4 channel (``HBM4ChannelSim(page_policy="closed")``)."""

    def __init__(self, timing: HBM4Timing | None = None,
                 geometry: ChannelGeometry | None = None,
                 queue_depth: int = 64,
                 refresh: bool = True,
                 max_ref_postpone: int = 8,
                 emit_trace: bool = False,
                 sample_window_ns: float | None = None):
        super().__init__(timing, geometry, queue_depth, refresh,
                         max_ref_postpone, page_policy="closed",
                         emit_trace=emit_trace,
                         sample_window_ns=sample_window_ns)


class HBM4WriteDrainChannelSim(HBM4ChannelSim):
    """HBM4 channel under :class:`FRFCFSWriteDrainPolicy` (watermark
    write batching over the open-page FR-FCFS baseline)."""

    def __init__(self, timing: HBM4Timing | None = None,
                 geometry: ChannelGeometry | None = None,
                 queue_depth: int = 64,
                 refresh: bool = True,
                 max_ref_postpone: int = 8,
                 high_watermark: int = 8,
                 low_watermark: int = 2,
                 drain_budget: int = 16,
                 write_age_ns: float = 400.0,
                 emit_trace: bool = False,
                 sample_window_ns: float | None = None):
        t = timing or HBM4Timing()
        g = geometry or ChannelGeometry()
        super().__init__(t, g, queue_depth, refresh, max_ref_postpone,
                         emit_trace=emit_trace,
                         sample_window_ns=sample_window_ns,
                         policy=FRFCFSWriteDrainPolicy(
                             t, g, high_watermark=high_watermark,
                             low_watermark=low_watermark,
                             drain_budget=drain_budget,
                             write_age_ns=write_age_ns))


class HBM4SIDGroupChannelSim(HBM4ChannelSim):
    """HBM4 channel under :class:`HBM4SIDGroupPolicy` (tCCDR-aware
    cross-SID burst grouping over the open-page FR-FCFS baseline)."""

    def __init__(self, timing: HBM4Timing | None = None,
                 geometry: ChannelGeometry | None = None,
                 queue_depth: int = 64,
                 refresh: bool = True,
                 max_ref_postpone: int = 8,
                 emit_trace: bool = False,
                 sample_window_ns: float | None = None):
        t = timing or HBM4Timing()
        g = geometry or ChannelGeometry()
        super().__init__(t, g, queue_depth, refresh, max_ref_postpone,
                         emit_trace=emit_trace,
                         sample_window_ns=sample_window_ns,
                         policy=HBM4SIDGroupPolicy(t, g))


class RoMeChannelSim(ChannelSimCore):
    """RoMe MC + command generator for one channel (§V-A).

    Queue of depth `queue_depth` (default 2 — the paper's saturation
    point); scheduling is :class:`RoMeRowPolicy` (oldest-first with VBA
    interleaving, Table III gaps, VBA-paired refresh).
    ``refresh_priority="eager"`` issues every refresh at its due time
    (``max_ref_postpone`` forced to 1) — the design-space point that
    trades stream bandwidth for zero refresh debt.
    """

    def __init__(self, timing: RoMeTiming | None = None,
                 geometry: ChannelGeometry | None = None,
                 n_vbas: int = 16,
                 queue_depth: int = 2,
                 refresh: bool = True,
                 max_ref_postpone: int = 8,
                 variant: str | None = None,
                 refresh_priority: str = "demand",
                 emit_trace: bool = False,
                 sample_window_ns: float | None = None):
        t = timing or RoMeTiming()
        g = geometry or ChannelGeometry()
        policy = RoMeRowPolicy(t, g, n_vbas=n_vbas, variant=variant,
                               refresh_priority=refresh_priority)
        if refresh_priority == "eager":
            max_ref_postpone = 1
        super().__init__(policy, queue_depth, refresh, max_ref_postpone,
                         emit_trace=emit_trace,
                         sample_window_ns=sample_window_ns)
        self.t = t
        self.g = g
        self.n_vbas = n_vbas
        self.row_bytes = policy.row_bytes  # 4 KB


#: kind -> channel sim class, the factory table ``SystemSim`` and the
#: policy registry key off.
CHANNEL_SIM_KINDS = {
    "hbm4": HBM4ChannelSim,
    "hbm4_closed": HBM4ClosedPageChannelSim,
    "hbm4_writedrain": HBM4WriteDrainChannelSim,
    "hbm4_sidgroup": HBM4SIDGroupChannelSim,
    "rome": RoMeChannelSim,
}


def make_channel_sim(kind: str, **kwargs) -> ChannelSimCore:
    """Factory over :data:`CHANNEL_SIM_KINDS` (``"hbm4"``,
    ``"hbm4_closed"``, ``"hbm4_writedrain"``, ``"hbm4_sidgroup"``,
    ``"rome"``)."""
    try:
        cls = CHANNEL_SIM_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown channel sim kind {kind!r}") from None
    return cls(**kwargs)
