"""Thin policy+timing bindings: one concrete sim class per controller.

Each class is just ``ChannelSimCore`` + a policy + the public attributes
callers key off (``t``, ``g``, geometry-derived counts). All scheduling
behaviour lives in :mod:`repro.core.sched.policies`.
"""
from __future__ import annotations

from ..timing import ChannelGeometry, HBM4Timing, RoMeTiming
from .core import ChannelSimCore
from .policies import (FRFCFSOpenPagePolicy, HBM4ClosedPagePolicy,
                       RoMeRowPolicy, SchedulerPolicy)


class HBM4ChannelSim(ChannelSimCore):
    """Conventional HBM4 channel (2 pseudo channels simulated jointly).

    ``page_policy`` selects the scheduler: ``"open"`` (FR-FCFS open-page,
    the paper's baseline) or ``"closed"`` (auto-precharge after every
    access — the shallow-queue-friendly comparison point).
    """

    def __init__(self, timing: HBM4Timing | None = None,
                 geometry: ChannelGeometry | None = None,
                 queue_depth: int = 64,
                 refresh: bool = True,
                 max_ref_postpone: int = 8,
                 page_policy: str = "open"):
        t = timing or HBM4Timing()
        g = geometry or ChannelGeometry()
        if page_policy == "open":
            policy: SchedulerPolicy = FRFCFSOpenPagePolicy(t, g)
        elif page_policy == "closed":
            policy = HBM4ClosedPagePolicy(t, g)
        else:
            raise ValueError(f"unknown page_policy {page_policy!r}")
        super().__init__(policy, queue_depth, refresh, max_ref_postpone)
        self.t = t
        self.g = g
        self.page_policy = page_policy
        self.banks_per_pc = g.banks_per_pc
        self.n_banks = g.banks_per_channel
        self.burst_ns = g.burst_ns  # 32 B over one PC's pins


class HBM4ClosedPageChannelSim(HBM4ChannelSim):
    """Closed-page HBM4 channel (``HBM4ChannelSim(page_policy="closed")``)."""

    def __init__(self, timing: HBM4Timing | None = None,
                 geometry: ChannelGeometry | None = None,
                 queue_depth: int = 64,
                 refresh: bool = True,
                 max_ref_postpone: int = 8):
        super().__init__(timing, geometry, queue_depth, refresh,
                         max_ref_postpone, page_policy="closed")


class RoMeChannelSim(ChannelSimCore):
    """RoMe MC + command generator for one channel (§V-A).

    Queue of depth `queue_depth` (default 2 — the paper's saturation
    point); scheduling is :class:`RoMeRowPolicy` (oldest-first with VBA
    interleaving, Table III gaps, VBA-paired refresh).
    """

    def __init__(self, timing: RoMeTiming | None = None,
                 geometry: ChannelGeometry | None = None,
                 n_vbas: int = 16,
                 queue_depth: int = 2,
                 refresh: bool = True,
                 max_ref_postpone: int = 8):
        t = timing or RoMeTiming()
        g = geometry or ChannelGeometry()
        policy = RoMeRowPolicy(t, g, n_vbas=n_vbas)
        super().__init__(policy, queue_depth, refresh, max_ref_postpone)
        self.t = t
        self.g = g
        self.n_vbas = n_vbas
        self.row_bytes = policy.row_bytes  # 4 KB


def make_channel_sim(kind: str, **kwargs) -> ChannelSimCore:
    """Factory: ``"hbm4"`` | ``"hbm4_closed"`` | ``"rome"``."""
    if kind == "hbm4":
        return HBM4ChannelSim(**kwargs)
    if kind == "hbm4_closed":
        return HBM4ClosedPageChannelSim(**kwargs)
    if kind == "rome":
        return RoMeChannelSim(**kwargs)
    raise ValueError(f"unknown channel sim kind {kind!r}")
