"""Lockstep multi-channel advance for the cycle engine.

A cube's channels are independent once :meth:`SystemSim.decompose` has
split the stream into per-channel transaction lists — the scalar path
simply runs one Python event loop per channel to completion. That leaves
two costs on the table for wide cubes (32–36 channels):

1. per-run dispatch overhead — ``N`` separate ``run()`` calls, each
   paying attribute-lookup and frame setup per event-loop iteration, and
2. no opportunity to stop early as channels drain at different times.

:func:`run_channels` instead starts a :class:`~.core.ChannelRunState`
per channel and advances **all unfinished channels together** in batched
state-steps: each sweep gives every live channel a ``batch``-iteration
slice of its event loop, with a numpy boolean mask tracking which
channels are still live so drained channels drop out of the sweep
immediately. Because channels share no state and each state-step runs
the *same* loop body as :meth:`~.core.ChannelSimCore.run`, the result is
bit-identical to the scalar path by construction — and asserted so on
the facade trace suite (:func:`facade_trace_suite`,
``benchmarks/hybrid_xval.py``, ``tests/test_hybrid.py``).

Telemetry sampling (``sample_window_ns`` on the underlying cores — the
:class:`repro.obs.MetricsProbe` seam) rides *inside* ``advance``: each
state appends its own window samples as its slice of the loop runs, so
the lockstep driver needs no coordination, sweep order cannot affect
the sampled series, and the bit-identity guarantee extends unchanged
to sampled runs (``benchmarks/obs_overhead.py`` gates both directions:
off-mode identity and ≤5 % on-mode overhead).
"""
from __future__ import annotations

import numpy as np

from .channels import make_channel_sim
from .core import SimResult, Txn


def advance_states(states, batch: int = 2048) -> None:
    """Drain a set of live :class:`~.core.ChannelRunState`\\ s in lockstep
    ``batch``-iteration slices (the same sweep loop as
    :func:`run_channels`, over caller-owned states). This is the warm
    cross-step driver: :class:`~repro.core.system_sim.WarmRunState` feeds
    each step's transactions into persistent per-channel states and calls
    this to drain them — channels share no state, so any interleaving of
    ``advance`` calls is bit-identical to per-channel loops."""
    live = np.array([not s.finished for s in states], dtype=bool)
    while live.any():
        for i in np.flatnonzero(live):
            if states[i].advance(batch):
                live[i] = False


def run_channels(kind: str, kwargs: dict, txns_per_channel: list[list[Txn]],
                 batch: int = 2048) -> list[SimResult]:
    """Simulate every channel of a cube in lockstep batches.

    ``kind``/``kwargs`` name a :data:`~.channels.CHANNEL_SIM_KINDS` entry
    (one fresh simulator — hence one fresh policy FSM — is built per
    channel; policies are stateful and must never be shared). Returns one
    :class:`SimResult` per channel, in input order, bit-identical to
    ``[make_channel_sim(kind, **kwargs).run(t) for t in txns_per_channel]``.
    """
    n = len(txns_per_channel)
    states = [make_channel_sim(kind, **kwargs).start_run(txns)
              for txns in txns_per_channel]
    advance_states(states, batch)
    return [states[i].result() for i in range(n)]


__all__ = ["run_channels", "advance_states"]
