"""Synthetic single-channel transaction traces for the µbenchmarks.

Channel-local streams at MC access granularity: bandwidth-maximizing and
page-interleaved sequential layouts for HBM4, VBA-striped row streams for
RoMe, and the interleaved multi-stream (ACT-inflation) workload. For
multi-channel extent-level traffic use :class:`repro.core.system_sim.SystemSim`,
which decomposes (addr, nbytes) extents through the address map into these
same per-channel patterns.
"""
from __future__ import annotations

import numpy as np

from ..timing import ChannelGeometry
from .core import Txn


def sequential_read_txns_hbm4(nbytes: int, geometry: ChannelGeometry | None = None,
                              arrival_ns: float = 0.0,
                              is_write: bool = False,
                              layout: str = "bg_striped") -> list[Txn]:
    """Channel-local sequential stream decomposed into 32 B column txns.

    ``layout`` selects the address map within the channel:

    * ``"bg_striped"`` — consecutive 32 B units alternate pseudo channels,
      then rotate bank groups (so bursts mesh at tCCDS, not tCCDL), then fill
      columns of a row; banks within a bank group ping-pong across row
      boundaries to hide tRC. This is the bandwidth-maximizing sweep winner
      (§VI-A) and needs only modest queue lookahead.
    * ``"row_linear"`` — consecutive units fill one bank's row before moving
      to the next bank group's row (page-interleaved map, classic open-page
      streaming). A single row drains at tCCDL (half rate); saturation
      *requires* the scheduler to interleave bursts from ≥2 open rows in
      different bank groups, i.e. a queue that spans multiple rows — this is
      the regime behind the paper's "HBM4 requires ≥45 entries" claim.
    """
    g = geometry or ChannelGeometry()
    txns: list[Txn] = []
    n_units = nbytes // g.col_bytes
    for u in range(n_units):
        bank, row, col = hbm4_unit_location(u, g, layout)
        txns.append(Txn(arrival_ns, bank=bank, row=row, col=col,
                        is_write=is_write))
    return txns


def hbm4_unit_location(u: int, g: ChannelGeometry,
                       layout: str = "bg_striped") -> tuple[int, int, int]:
    """(bank, row, col) of channel-local 32 B unit `u` under `layout`."""
    nbg = g.bank_groups
    cols = g.cols_per_row
    pc = u % g.pseudo_channels
    j = u // g.pseudo_channels          # unit index within the PC
    if layout == "bg_striped":
        bg = j % nbg
        k = j // nbg                    # burst index within this BG's stream
        col = k % cols
        rseq = k // cols                # row sequence number within BG
    elif layout == "row_linear":
        col = j % cols
        rrun = j // cols                # consecutive rows
        bg = rrun % nbg
        rseq = rrun // nbg
    else:
        raise ValueError(f"unknown layout {layout!r}")
    bank_in_bg = rseq % g.banks_per_group
    row = rseq // g.banks_per_group
    bank = pc * g.banks_per_pc + bg * g.banks_per_group + bank_in_bg
    return bank, row, col


def rome_unit_location(u: int, n_vbas: int) -> tuple[int, int, int]:
    """(vba, row, col) of channel-local row-unit `u` (VBA-striped)."""
    return u % n_vbas, u // n_vbas, 0


def sequential_read_txns_rome(nbytes: int, n_vbas: int = 16,
                              arrival_ns: float = 0.0,
                              is_write: bool = False,
                              row_bytes: int = 4096) -> list[Txn]:
    """Channel-local sequential stream as 4 KB row transactions striped
    across VBAs."""
    n_rows = (nbytes + row_bytes - 1) // row_bytes
    txns = []
    for r in range(n_rows):
        bank, row, _ = rome_unit_location(r, n_vbas)
        txns.append(Txn(arrival_ns, bank=bank, row=row, is_write=is_write))
    return txns


def interleaved_stream_txns_hbm4(n_streams: int, nbytes_each: int,
                                 geometry: ChannelGeometry | None = None,
                                 seed: int = 0) -> list[Txn]:
    """N concurrent sequential streams interleaved round-robin at 32 B
    granularity (as concurrent GEMM operands / expert streams arrive at the
    MC). Each stream is row_linear with its own bank/row phase. This is the
    ACT-inflation workload: with many streams the per-stream queue window
    shrinks below a row's 32 columns, so rows are served in several visits
    and intervening same-bank activity forces re-activations — the effect
    RoMe eliminates structurally (one RD_row = whole row, §VI-C / Fig 14).
    """
    g = geometry or ChannelGeometry()
    rng = np.random.default_rng(seed)
    streams = []
    for s in range(n_streams):
        txns = sequential_read_txns_hbm4(nbytes_each, g, layout="row_linear")
        # random bank-group/bank/row phase per stream
        bank_off = int(rng.integers(0, g.banks_per_channel))
        row_off = int(rng.integers(0, 1 << 12))
        for t in txns:
            t.bank = (t.bank + bank_off) % g.banks_per_channel
            t.row = t.row + row_off
            t.stream = s
        streams.append(txns)
    out: list[Txn] = []
    for i in range(max(len(s) for s in streams)):
        for s in streams:
            if i < len(s):
                out.append(s[i])
    return out


def _staggered(txns: list[Txn], inter_ns: float) -> list[Txn]:
    for i, t in enumerate(txns):
        t.arrival_ns = i * inter_ns
    return txns


def facade_trace_suite() -> list[tuple[str, str, dict, list[Txn]]]:
    """The 20-trace facade suite: ``(label, kind, kwargs, txns)`` tuples
    covering every channel-sim kind across layouts, read/write direction,
    queue depths, refresh on/off, and dense vs sparse arrivals.

    This is the bit-identity contract between the scalar per-channel loop
    (:meth:`~.core.ChannelSimCore.run`) and the vectorized lockstep
    advance (:func:`~.vectorized.run_channels`): every trace must produce
    byte-for-byte equal finish times and command counts under both.
    Runs do not mutate ``Txn`` fields, so the same trace list can be fed
    to both engines; each call builds the suite fresh regardless.
    """
    burst = 1 << 15
    suite: list[tuple[str, str, dict, list[Txn]]] = [
        ("hbm4_bg_read", "hbm4", {},
         sequential_read_txns_hbm4(burst)),
        ("hbm4_bg_write", "hbm4", {},
         sequential_read_txns_hbm4(burst, is_write=True)),
        ("hbm4_row_linear", "hbm4", {},
         sequential_read_txns_hbm4(burst, layout="row_linear")),
        ("hbm4_shallow", "hbm4", {"queue_depth": 2},
         sequential_read_txns_hbm4(burst, layout="row_linear")),
        ("hbm4_norefresh", "hbm4", {"refresh": False},
         sequential_read_txns_hbm4(burst)),
        ("hbm4_postpone32", "hbm4", {"max_ref_postpone": 32},
         sequential_read_txns_hbm4(1 << 16)),
        ("hbm4_interleave8", "hbm4", {},
         interleaved_stream_txns_hbm4(8, 1 << 12)),
        ("hbm4_interleave32", "hbm4", {},
         interleaved_stream_txns_hbm4(32, 1 << 11, seed=1)),
        ("hbm4_sparse", "hbm4", {},
         _staggered(sequential_read_txns_hbm4(1 << 13), 200.0)),
        ("hbm4_closed_read", "hbm4_closed", {},
         sequential_read_txns_hbm4(burst)),
        ("hbm4_closed_sparse", "hbm4_closed", {},
         _staggered(sequential_read_txns_hbm4(1 << 13), 150.0)),
        ("hbm4_writedrain_mix", "hbm4_writedrain", {},
         [t for pair in zip(
             sequential_read_txns_hbm4(burst // 2),
             sequential_read_txns_hbm4(burst // 2, is_write=True))
          for t in pair]),
        ("hbm4_writedrain_sparse", "hbm4_writedrain", {},
         _staggered(sequential_read_txns_hbm4(1 << 13, is_write=True),
                    100.0)),
        ("hbm4_sidgroup_read", "hbm4_sidgroup", {},
         sequential_read_txns_hbm4(burst)),
        ("rome_read", "rome", {},
         sequential_read_txns_rome(1 << 20)),
        ("rome_write", "rome", {},
         sequential_read_txns_rome(1 << 19, is_write=True)),
        ("rome_qd8", "rome", {"queue_depth": 8},
         sequential_read_txns_rome(1 << 19)),
        ("rome_one_vba", "rome", {"n_vbas": 1},
         sequential_read_txns_rome(1 << 18, n_vbas=1)),
        ("rome_eager", "rome", {"refresh_priority": "eager"},
         sequential_read_txns_rome(1 << 19)),
        ("rome_sparse", "rome", {},
         _staggered(sequential_read_txns_rome(1 << 18), 500.0)),
    ]
    assert len(suite) == 20
    return suite
