"""Scheduler-policy registry: the design-space catalogue.

One :class:`PolicySpec` per scheduling point — a named, picklable
binding of a channel-sim kind (:data:`~.channels.CHANNEL_SIM_KINDS`) to
its constructor arguments, plus the memory-system *family* that decides
how :class:`repro.core.system_sim.SystemSim` decomposes extents for it
(``"hbm4"`` = 32 B column transactions, ``"rome"`` = 4 KB row
transactions). The registry is what makes the policy sweep
(benchmarks/policy_sweep.py) and the conservation property test iterate
"every scheduling point we claim to support" instead of a hand-kept
list, and every spec's policy feeds the Table IV census through
``SchedulerPolicy.state_footprint()`` /
:func:`repro.core.mc.complexity_of_policy`.

Default catalogue (9 points):

========================  ======  =============================================
name                      family  scheduling point
========================  ======  =============================================
``hbm4_frfcfs``           hbm4    FR-FCFS open-page, qd 64 (paper baseline)
``hbm4_closed``           hbm4    auto-precharge closed page, qd 64
``hbm4_writedrain``       hbm4    FR-FCFS + hi/lo-watermark write draining
``hbm4_sidgroup``         hbm4    FR-FCFS + tCCDR-aware cross-SID grouping
``rome_qd2``              rome    RoMe oldest-first, qd 2 (paper point)
``rome_qd3``              rome    RoMe, qd 3
``rome_qd4``              rome    RoMe, qd 4 (area-study provisioning)
``rome_qd8``              rome    RoMe, qd 8 (diminishing-returns probe)
``rome_eager_refresh``    rome    RoMe qd 2, refresh never postponed
========================  ======  =============================================
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .channels import make_channel_sim
from .core import ChannelSimCore
from .policies import SchedulerPolicy

FAMILIES = ("hbm4", "rome")


@dataclass(frozen=True)
class PolicySpec:
    """One registered scheduling point of the design space."""

    name: str
    family: str                  # "hbm4" | "rome" (extent decomposition)
    sim_kind: str                # make_channel_sim kind
    sim_kwargs: dict = field(default_factory=dict)
    description: str = ""
    table_iv: str = ""           # the Table IV row/contrast this point informs

    def __post_init__(self) -> None:
        if self.family not in FAMILIES:
            raise ValueError(
                f"family must be one of {FAMILIES}, got {self.family!r}")

    @property
    def queue_depth(self) -> int:
        return self.sim_kwargs.get("queue_depth",
                                   64 if self.family == "hbm4" else 2)

    def make_sim(self, **overrides) -> ChannelSimCore:
        """Single-channel sim for this point (overrides win over the
        registered kwargs — e.g. ``refresh=False`` for µbenchmarks)."""
        return make_channel_sim(self.sim_kind, **(self.sim_kwargs | overrides))

    def make_policy(self) -> SchedulerPolicy:
        """A fresh policy instance (for ``state_footprint()`` census)."""
        return self.make_sim().policy

    def system_sim(self, n_channels: int | None = None, **sys_kwargs):
        """A :class:`~repro.core.system_sim.SystemSim` running this
        policy on the family's memory-system config."""
        # Lazy import: system_sim imports this package.
        from ..system_sim import SystemSim
        from ..timing import hbm4_config, rome_config
        cfg = hbm4_config() if self.family == "hbm4" else rome_config()
        # Thread the spec name so analytic/hybrid modes resolve this
        # point's persisted queue-window calibration, not a family guess.
        sys_kwargs.setdefault("policy_name", self.name)
        return SystemSim(cfg, n_channels=n_channels,
                         channel_kind=self.sim_kind,
                         channel_kwargs=dict(self.sim_kwargs), **sys_kwargs)


_REGISTRY: dict[str, PolicySpec] = {}


def register_policy(spec: PolicySpec, replace: bool = False) -> PolicySpec:
    if not replace and spec.name in _REGISTRY:
        raise ValueError(f"policy {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def policy_spec(name: str) -> PolicySpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; registered: {policy_names()}") from None


def policy_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def registered_policies() -> dict[str, PolicySpec]:
    """Snapshot of the registry (mutating it does not affect the registry)."""
    return dict(_REGISTRY)


def _register_defaults() -> None:
    register_policy(PolicySpec(
        "hbm4_frfcfs", "hbm4", "hbm4", {"queue_depth": 64},
        description="FR-FCFS open-page over a 64-entry CAM queue "
                    "(the paper's conventional-HBM4 baseline)",
        table_iv="conventional row: 15 timing params, 64x 7-state FSMs"))
    register_policy(PolicySpec(
        "hbm4_closed", "hbm4", "hbm4_closed", {"queue_depth": 64},
        description="auto-precharge closed page: sheds row-locality state, "
                    "caps at the tRC random-row rate",
        table_iv="conventional row minus row-buffer locality"))
    register_policy(PolicySpec(
        "hbm4_writedrain", "hbm4", "hbm4_writedrain",
        {"queue_depth": 64, "high_watermark": 8, "low_watermark": 2,
         "drain_budget": 16, "write_age_ns": 400.0},
        description="FR-FCFS + hi/lo-watermark write draining (batched "
                    "turnarounds, bounded read starvation)",
        table_iv="conventional row + drain FSM/comparators (aux_state)"))
    register_policy(PolicySpec(
        "hbm4_sidgroup", "hbm4", "hbm4_sidgroup", {"queue_depth": 64},
        description="FR-FCFS + tCCDR-aware cross-SID burst grouping "
                    "(rank grouping)",
        table_iv="conventional row + per-PC SID register (aux_state)"))
    register_policy(PolicySpec(
        "rome_qd2", "rome", "rome", {"queue_depth": 2},
        description="RoMe oldest-first + VBA interleave, queue depth 2 "
                    "(the paper's saturation point)",
        table_iv="RoMe row: 10 timing params, 5x 4-state FSMs"))
    for qd in (3, 4, 8):
        register_policy(PolicySpec(
            f"rome_qd{qd}", "rome", "rome",
            {"queue_depth": qd, "variant": f"qd{qd}"},
            description=f"RoMe oldest-first, queue depth {qd}",
            table_iv="RoMe row (census invariant in queue depth)"))
    register_policy(PolicySpec(
        "rome_eager_refresh", "rome", "rome",
        {"queue_depth": 2, "variant": "eager_ref",
         "refresh_priority": "eager"},
        description="RoMe qd 2 with refresh never postponed "
                    "(zero refresh debt, pays stream stalls)",
        table_iv="RoMe row (governor knob only; census invariant)"))


_register_defaults()


__all__ = ["PolicySpec", "register_policy", "policy_spec", "policy_names",
           "registered_policies", "FAMILIES"]
