"""Pluggable scheduler core for the cycle-level channel simulators.

One engine, N policies, N channels — the package layout mirrors the
paper's argument that RoMe's win is *structural*:

``core``
    :class:`ChannelSimCore` — the shared event loop (clock, pending
    queue, demand-aware bounded-postponement refresh governor,
    idle-advance, finish accounting) plus the transaction/result types.
``policies``
    :class:`SchedulerPolicy` implementations: FR-FCFS open-page (the
    HBM4 baseline), a closed-page HBM4 variant, and RoMe's
    oldest-first-with-VBA-interleave. A policy's hardware census is
    introspectable via ``state_footprint()`` (Table IV).
``channels``
    Thin policy+timing bindings (``HBM4ChannelSim``, ``RoMeChannelSim``,
    ``HBM4ClosedPageChannelSim``) and the ``make_channel_sim`` factory.
``traces``
    Synthetic single-channel µbenchmark traces.

Policy contract (full signatures in :mod:`.policies`)::

    class SchedulerPolicy:
        count_keys: tuple[str, ...]    # stat keys the policy maintains
        ref_period: float              # refresh cadence for the governor
        n_ref_units: int               # refresh rotation length
        bytes_per_txn: int             # MC access granularity

        def begin(counts): ...         # reset per-run FSM state
        def issue_refresh(unit, due): ...
        def issue(window, now) -> (now, issued, [(txn, finish_ns), ...])
        def state_footprint() -> dict  # Table IV census

The legacy import surface lives on in :mod:`repro.core.engine`, which is
now a compatibility facade over this package.
"""
from .channels import (HBM4ChannelSim, HBM4ClosedPageChannelSim,
                       RoMeChannelSim, make_channel_sim)
from .core import ChannelSimCore, SimResult, Txn, _PendingQueue
from .policies import (FRFCFSOpenPagePolicy, HBM4ClosedPagePolicy,
                       RoMeRowPolicy, SchedulerPolicy)
from .traces import (hbm4_unit_location, interleaved_stream_txns_hbm4,
                     rome_unit_location, sequential_read_txns_hbm4,
                     sequential_read_txns_rome)

__all__ = [
    "ChannelSimCore", "SimResult", "Txn",
    "SchedulerPolicy", "FRFCFSOpenPagePolicy", "HBM4ClosedPagePolicy",
    "RoMeRowPolicy",
    "HBM4ChannelSim", "HBM4ClosedPageChannelSim", "RoMeChannelSim",
    "make_channel_sim",
    "hbm4_unit_location", "rome_unit_location",
    "interleaved_stream_txns_hbm4",
    "sequential_read_txns_hbm4", "sequential_read_txns_rome",
]
