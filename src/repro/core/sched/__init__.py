"""Pluggable scheduler core for the cycle-level channel simulators.

One engine, N policies, N channels — the package layout mirrors the
paper's argument that RoMe's win is *structural*:

``core``
    :class:`ChannelSimCore` — the shared event loop (clock, pending
    queue, demand-aware bounded-postponement refresh governor,
    idle-advance, finish accounting) plus the transaction/result types.
``policies``
    :class:`SchedulerPolicy` implementations: FR-FCFS open-page (the
    HBM4 baseline), a closed-page HBM4 variant, FR-FCFS with hi/lo
    watermark write draining, FR-FCFS with tCCDR-aware cross-SID burst
    grouping, and RoMe's oldest-first-with-VBA-interleave (with
    queue-depth / refresh-priority variants). A policy's hardware census
    is introspectable via ``state_footprint()`` (Table IV).
``channels``
    Thin policy+timing bindings (``HBM4ChannelSim``, ``RoMeChannelSim``,
    ``HBM4ClosedPageChannelSim``, ``HBM4WriteDrainChannelSim``,
    ``HBM4SIDGroupChannelSim``) and the ``make_channel_sim`` factory
    over :data:`CHANNEL_SIM_KINDS`.
``registry``
    The design-space catalogue: named :class:`PolicySpec` entries binding
    a channel-sim kind + kwargs to a memory-system family, iterated by
    benchmarks/policy_sweep.py and the conservation property tests.
``traces``
    Synthetic single-channel µbenchmark traces.

Policy contract (full signatures in :mod:`.policies`)::

    class SchedulerPolicy:
        count_keys: tuple[str, ...]    # stat keys the policy maintains
        ref_period: float              # refresh cadence for the governor
        n_ref_units: int               # refresh rotation length
        bytes_per_txn: int             # MC access granularity

        def begin(counts): ...         # reset per-run FSM state
        def issue_refresh(unit, due): ...
        def issue(window, now) -> (now, issued, [(txn, finish_ns), ...])
        def state_footprint() -> dict  # Table IV census

The legacy import surface lives on in :mod:`repro.core.engine`, which is
now a compatibility facade over this package.
"""
from .channels import (CHANNEL_SIM_KINDS, HBM4ChannelSim,
                       HBM4ClosedPageChannelSim, HBM4SIDGroupChannelSim,
                       HBM4WriteDrainChannelSim, RoMeChannelSim,
                       make_channel_sim)
from .core import (ChannelRunState, ChannelSimCore, CmdRecord, SimResult,
                   Txn, _PendingQueue, counts_row_hit_rate)
from .policies import (FRFCFSOpenPagePolicy, FRFCFSWriteDrainPolicy,
                       HBM4ClosedPagePolicy, HBM4SIDGroupPolicy,
                       RoMeRowPolicy, SchedulerPolicy)
from .registry import (FAMILIES, PolicySpec, policy_names, policy_spec,
                       register_policy, registered_policies)
from .traces import (facade_trace_suite, hbm4_unit_location,
                     interleaved_stream_txns_hbm4, rome_unit_location,
                     sequential_read_txns_hbm4, sequential_read_txns_rome)
from .vectorized import advance_states, run_channels

__all__ = [
    "ChannelSimCore", "ChannelRunState", "CmdRecord", "SimResult", "Txn",
    "counts_row_hit_rate",
    "run_channels", "advance_states", "facade_trace_suite",
    "SchedulerPolicy", "FRFCFSOpenPagePolicy", "FRFCFSWriteDrainPolicy",
    "HBM4ClosedPagePolicy", "HBM4SIDGroupPolicy", "RoMeRowPolicy",
    "HBM4ChannelSim", "HBM4ClosedPageChannelSim",
    "HBM4WriteDrainChannelSim", "HBM4SIDGroupChannelSim", "RoMeChannelSim",
    "CHANNEL_SIM_KINDS", "make_channel_sim",
    "PolicySpec", "register_policy", "policy_spec", "policy_names",
    "registered_policies", "FAMILIES",
    "hbm4_unit_location", "rome_unit_location",
    "interleaved_stream_txns_hbm4",
    "sequential_read_txns_hbm4", "sequential_read_txns_rome",
]
