"""Scheduler policies: the pluggable half of the channel simulator.

Each policy packages one controller architecture's *decision logic and
state* — bank/VBA FSMs, per-resource clocks, command selection — behind
the interface :class:`ChannelSimCore` drives:

``count_keys``
    Command-count stat keys the policy maintains (the core adds
    ``ref_backlog_max``).
``ref_period`` / ``n_ref_units``
    Refresh cadence and rotation length for the core's governor.
``begin(counts)``
    (Re)initialize all per-run state; stash the shared counts dict.
``issue_refresh(unit, due)``
    Perform one rotating refresh for `unit`, anchored at `due`.
``issue(window, now) -> (now, issued, completions)``
    One scheduling step over the arrived window. `completions` is a list
    of ``(txn, finish_ns)``; `issued` False tells the core to advance the
    clock to the next event.
``bytes_per_txn``
    Data moved per transaction (MC access granularity).
``state_footprint()``
    The Table IV census of what the policy must physically track — FSM
    instances, states per FSM, managed timing parameters, page policy —
    so MC-complexity claims are introspected from the code that *is* the
    scheduler rather than asserted in prose.
"""
from __future__ import annotations

from ..command_generator import CommandGenerator
from ..timing import (ChannelGeometry, HBM4_BANK_STATES, HBM4Timing,
                      ROME_BANK_STATES, RoMeTiming)
from .core import CmdRecord, Txn


class SchedulerPolicy:
    """Interface; see the module docstring for the contract."""

    count_keys: tuple = ()
    ref_period: float = 0.0
    n_ref_units: int = 1
    bytes_per_txn: int = 0

    #: Command-trace sink, set by :class:`ChannelRunState` before
    #: ``begin()``: a list of :class:`CmdRecord` when the run was started
    #: with ``emit_trace=True``, else None. Every emission site guards on
    #: it so the hot path pays one attribute test when tracing is off.
    #: The trace exists so `repro.analysis.timing_checker` can verify the
    #: command stream against the JEDEC / Table III rule tables without
    #: trusting any of the readiness math below.
    trace: list | None = None

    def begin(self, counts: dict) -> None:
        raise NotImplementedError

    def issue_refresh(self, unit: int, due: float) -> None:
        raise NotImplementedError

    def issue(self, window: list[Txn], now: float):
        raise NotImplementedError

    def state_footprint(self) -> dict:
        raise NotImplementedError


# ===========================================================================
# Conventional HBM4: FR-FCFS
# ===========================================================================

class _BankState:
    __slots__ = ("open_row", "t_act", "t_last_rd", "t_last_wr_data",
                 "t_rp_done", "t_ref_done")

    def __init__(self) -> None:
        self.open_row: int | None = None
        self.t_act = -1e18
        self.t_last_rd = -1e18
        self.t_last_wr_data = -1e18
        self.t_rp_done = 0.0
        self.t_ref_done = 0.0


class FRFCFSOpenPagePolicy(SchedulerPolicy):
    """FR-FCFS over a bounded CAM queue, open-page, 7-state bank FSMs.

    One HBM4 channel = 2 pseudo channels simulated jointly. Each PC owns
    half the DQ pins and its own banks; the two PCs share C/A but we
    assume C/A is never the bottleneck for the baseline (it has 18 pins).
    Bank ids 0..127: pc = bank // 64, bank group = (bank % 64) // 4.
    """

    count_keys = ("ACT", "RD", "WR", "PRE", "REFpb", "ca_commands")
    page_policy = "open"

    #: Open-page keeps a row open while queued hits still target it; the
    #: closed-page subclass flips this (always precharge after access).
    keep_open_for_hits = True

    def __init__(self, timing: HBM4Timing | None = None,
                 geometry: ChannelGeometry | None = None):
        self.t = timing or HBM4Timing()
        self.g = geometry or ChannelGeometry()
        self.banks_per_pc = self.g.banks_per_pc
        self.n_banks = self.g.banks_per_channel
        self.burst_ns = self.g.burst_ns  # 32 B over one PC's pins
        self.ref_period = self.t.tREFIpb
        self.n_ref_units = self.n_banks
        self.bytes_per_txn = self.g.col_bytes

    # -- helpers -----------------------------------------------------------

    def _bg(self, bank: int) -> int:
        return (bank % self.banks_per_pc) // self.g.banks_per_group

    def _pc(self, bank: int) -> int:
        return bank // self.banks_per_pc

    # -- per-run state -----------------------------------------------------

    def begin(self, counts: dict) -> None:
        self.counts = counts
        self.banks = [_BankState() for _ in range(self.n_banks)]
        # Per-PC shared resources.
        self.pc_bus_free = [0.0, 0.0]              # DQ bus next-free
        self.pc_last_burst = [-1e18, -1e18]        # last RD/WR cmd time (tCCDS)
        self.pc_last_burst_bg = [dict(), dict()]   # bg -> last cmd time (tCCDL)
        self.pc_last_burst_sid = [dict(), dict()]  # sid -> last cmd time (tCCDR)
        self.pc_last_was_write = [False, False]
        self.pc_last_rd_cmd = [-1e18, -1e18]
        self.pc_last_wr_data_end = [-1e18, -1e18]
        self.pc_last_wr_data_end_bg = [dict(), dict()]  # bg -> data end (tWTRL)
        self.ch_last_ref = -1e18                   # REFpb spacing (tRREFpb)
        self.pc_act_times = [[], []]               # for tFAW (per PC)
        self.pc_last_act = [-1e18, -1e18]          # tRRDS
        self.pc_last_act_bg = [dict(), dict()]     # tRRDL

    # -- readiness clocks --------------------------------------------------

    def act_ready(self, bank_id: int, b: _BankState, at: float) -> float:
        t = self.t
        pc = self._pc(bank_id)
        bg = self._bg(bank_id)
        r = max(at, b.t_rp_done, b.t_ref_done,
                self.pc_last_act[pc] + t.tRRDS,
                self.pc_last_act_bg[pc].get(bg, -1e18) + t.tRRDL)
        acts = self.pc_act_times[pc]
        if len(acts) >= 4:
            r = max(r, acts[-4] + t.tFAW)
        return r

    def col_ready(self, bank_id: int, b: _BankState, is_write: bool,
                  sid: int, at: float) -> float:
        t = self.t
        pc = self._pc(bank_id)
        bg = self._bg(bank_id)
        trcd = t.tRCDWR if is_write else t.tRCDRD
        r = max(at, b.t_act + trcd, b.t_ref_done,
                self.pc_last_burst[pc] + t.tCCDS,
                self.pc_last_burst_bg[pc].get(bg, -1e18) + t.tCCDL)
        # tCCDR: RD/WR to RD/WR spacing across SIDs (ranks) sharing the PC.
        for other_sid, t_cmd in self.pc_last_burst_sid[pc].items():
            if other_sid != sid:
                r = max(r, t_cmd + t.tCCDR)
        if is_write and not self.pc_last_was_write[pc]:
            r = max(r, self.pc_last_rd_cmd[pc] + t.tRTW)
        if not is_write:
            if self.pc_last_was_write[pc]:
                r = max(r, self.pc_last_wr_data_end[pc] + t.tWTRS)
            # tWTRL binds same-bank-group reads against the *last write
            # to that group* even when interleaved reads already flipped
            # the turnaround direction — the per-PC gate above would
            # skip it (found by the trace sanitizer).
            wbg = self.pc_last_wr_data_end_bg[pc].get(bg)
            if wbg is not None:
                r = max(r, wbg + t.tWTRL)
        return r

    def pre_ready(self, b: _BankState, at: float) -> float:
        t = self.t
        return max(at, b.t_act + t.tRAS, b.t_last_rd + t.tRTP,
                   b.t_last_wr_data + t.tWR)

    # -- refresh -----------------------------------------------------------

    def issue_refresh(self, unit: int, due: float) -> None:
        t = self.t
        b = self.banks[unit]
        tr = self.trace
        # tRREFpb: REFpb commands to *different* banks still share the
        # C/A path — successive refresh starts keep their spacing even
        # when backdated due anchors and bank-busy pushes collide
        # (found by the trace sanitizer).
        start = max(due, b.t_rp_done, b.t_ref_done,
                    self.ch_last_ref + t.tRREFpb)
        if b.open_row is not None:
            pr = self.pre_ready(b, start)
            b.t_rp_done = pr + t.tRP
            b.open_row = None
            self.counts["PRE"] += 1
            if tr is not None:
                tr.append(CmdRecord(pr, "PRE", unit, self._pc(unit), -1, -1,
                                    -1.0, -1.0))
            start = b.t_rp_done
        b.t_ref_done = start + t.tRFCpb
        self.ch_last_ref = start
        self.counts["REFpb"] += 1
        if tr is not None:
            tr.append(CmdRecord(start, "REF", unit, self._pc(unit), -1, -1,
                                -1.0, -1.0))

    # -- one scheduling step -----------------------------------------------

    def issue(self, window: list[Txn], now: float):
        t = self.t
        counts = self.counts
        banks = self.banks
        tr = self.trace
        issued = False
        completions: list = []

        # Row-bus work (runs concurrently with the column bus): progress
        # the oldest row-miss whose bank's open row is no longer needed by
        # any queued hit. This is what deep queues buy the conventional
        # MC — lookahead to overlap ACT/PRE of upcoming rows with the
        # bursts of the current ones.
        prepared: set[int] = set()
        for tx in window:
            b = banks[tx.bank]
            if b.open_row == tx.row or tx.bank in prepared:
                continue
            if b.open_row is not None:
                # Keep a row open while queued hits still target it
                # (open-page only).
                if self.keep_open_for_hits and \
                        any(h.bank == tx.bank and h.row == b.open_row
                            for h in window):
                    prepared.add(tx.bank)
                    continue
                pr = self.pre_ready(b, max(tx.arrival_ns, b.t_ref_done))
                b.t_rp_done = pr + t.tRP
                b.open_row = None
                counts["PRE"] += 1
                counts["ca_commands"] += 1
                if tr is not None:
                    tr.append(CmdRecord(pr, "PRE", tx.bank,
                                        self._pc(tx.bank), tx.sid, -1,
                                        -1.0, -1.0))
                now = max(now, pr)
            else:
                ar = self.act_ready(tx.bank, b,
                                    max(tx.arrival_ns, b.t_ref_done))
                pc = self._pc(tx.bank)
                bg = self._bg(tx.bank)
                b.t_act = ar
                b.open_row = tx.row
                self.pc_last_act[pc] = ar
                self.pc_last_act_bg[pc][bg] = ar
                self.pc_act_times[pc].append(ar)
                if len(self.pc_act_times[pc]) > 8:
                    self.pc_act_times[pc] = self.pc_act_times[pc][-8:]
                counts["ACT"] += 1
                counts["ca_commands"] += 1
                if tr is not None:
                    tr.append(CmdRecord(ar, "ACT", tx.bank, pc, tx.sid,
                                        tx.row, -1.0, -1.0))
                now = max(now, ar)
            prepared.add(tx.bank)
            issued = True

        # Column-bus work: earliest-ready row hit (FR), oldest on ties.
        # Issue times are governed by per-resource clocks (bank readiness,
        # per-PC burst spacing, DQ bus) — the column C/A path sustains one
        # command per PC per tCCDS, so a pick may legally land before
        # `now` (commands ride independent buses).
        best, best_t = self._pick_column(window, now)
        if best is not None:
            tx, r = best, best_t
            b = banks[tx.bank]
            pc = self._pc(tx.bank)
            bg = self._bg(tx.bank)
            lat = t.tCWL if tx.is_write else t.tCL
            data_start = max(r + lat, self.pc_bus_free[pc])
            # If the bus is the constraint, push the command time too.
            cmd_t = data_start - lat
            data_end = data_start + self.burst_ns
            self.pc_bus_free[pc] = data_end
            self.pc_last_burst[pc] = cmd_t
            self.pc_last_burst_bg[pc][bg] = cmd_t
            self.pc_last_burst_sid[pc][tx.sid] = cmd_t
            self.pc_last_was_write[pc] = tx.is_write
            counts["ca_commands"] += 1
            if tx.is_write:
                b.t_last_wr_data = data_end
                self.pc_last_wr_data_end[pc] = data_end
                self.pc_last_wr_data_end_bg[pc][bg] = data_end
                counts["WR"] += 1
            else:
                b.t_last_rd = cmd_t
                self.pc_last_rd_cmd[pc] = cmd_t
                counts["RD"] += 1
            if tr is not None:
                tr.append(CmdRecord(cmd_t, "WR" if tx.is_write else "RD",
                                    tx.bank, pc, tx.sid, tx.row,
                                    data_start, data_end))
            self._after_column(tx, b, cmd_t)
            completions.append((tx, data_end))
            now = max(now, cmd_t)
            issued = True

        return now, issued, completions

    # -- subclass hooks ----------------------------------------------------

    def _column_groups(self, window: list[Txn],
                       now: float) -> list[list[Txn]]:
        """Candidate groups for the column bus, in preference order: the
        pick comes from the first group with an issuable row hit.
        Write-drain narrows the head group to one kind at a time but
        keeps the other kind as a fallback — a group with no issuable
        transaction must never stall the bus while a lower-preference
        one could issue (liveness: row-prep keeps rows open for *queued*
        hits regardless of kind, so a kind-filtered head group can be
        blocked behind the very rows the fallback group holds open)."""
        return [window]

    def _pick_column(self, window: list[Txn], now: float):
        """Earliest-ready activated row hit from the first non-empty
        candidate group; oldest (window order) on ties. Returns
        ``(txn, ready_ns)`` or ``(None, None)``."""
        for group in self._column_groups(window, now):
            best = None
            best_t = None
            for tx in group:
                b = self.banks[tx.bank]
                if b.open_row == tx.row and b.t_act <= 1e17:
                    r = self.col_ready(tx.bank, b, tx.is_write, tx.sid,
                                       tx.arrival_ns)
                    if best_t is None or r < best_t - 1e-12:
                        best, best_t = tx, r
            if best is not None:
                return best, best_t
        return None, None

    def _after_column(self, tx: Txn, b: _BankState, cmd_t: float) -> None:
        """Open-page: the row stays open after a column access."""

    # -- introspection -----------------------------------------------------

    def state_footprint(self) -> dict:
        scheduling = ("bank group interleaving", "PC interleaving")
        if self.keep_open_for_hits:
            scheduling = ("row-buffer locality",) + scheduling
        return {
            "name": "frfcfs_open" if self.keep_open_for_hits else
                    "frfcfs_closed",
            "timing_params": self.t.n_managed(),
            "fsm_instances": self.banks_per_pc,   # one per bank per PC
            "states_per_fsm": len(HBM4_BANK_STATES),
            "page_policy": self.page_policy,
            "scheduling": scheduling,
        }


class HBM4ClosedPagePolicy(FRFCFSOpenPagePolicy):
    """Closed-page HBM4 variant: auto-precharge after every column access.

    A comparison point between open-page FR-FCFS and RoMe: the scheduler
    sheds the row-buffer-locality bookkeeping (every access pays
    ACT + RD/WR + PRE), so it degrades far less with shallow queues but
    caps stream bandwidth at the tRC-limited random-row rate. The
    difference from the open-page policy is exactly two hooks — the
    keep-open-for-hits check and the post-access precharge — everything
    else (bank FSMs, per-PC clocks, refresh) is shared.
    """

    page_policy = "closed (auto-precharge after access)"
    keep_open_for_hits = False

    def _after_column(self, tx: Txn, b: _BankState, cmd_t: float) -> None:
        pr = self.pre_ready(b, cmd_t)
        b.t_rp_done = pr + self.t.tRP
        b.open_row = None
        self.counts["PRE"] += 1
        self.counts["ca_commands"] += 1
        if self.trace is not None:
            self.trace.append(CmdRecord(pr, "PRE", tx.bank,
                                        self._pc(tx.bank), tx.sid, -1,
                                        -1.0, -1.0))


class FRFCFSWriteDrainPolicy(FRFCFSOpenPagePolicy):
    """FR-FCFS with watermark-based write draining (posted writes).

    Conventional HBM controllers treat writes as *posted* traffic: they
    sit in a write buffer and are released in batches, so the tRTW/tWTRS
    bus turnarounds are paid once per burst instead of once per write.
    The state machine here:

    * *Drain entry*: queued-write occupancy >= ``high_watermark`` (and,
      under sustained mixed load, only after at least ``high_watermark``
      reads were serviced since the last drain — symmetric batching, so
      a 50/50 backlog alternates read and write bursts instead of
      re-triggering drains back to back).
    * *Drain exit* (hysteresis with a hard cap): occupancy fell to
      ``low_watermark``, or ``drain_budget`` writes were drained this
      batch. The cap is the read-starvation bound the tests pin: reads
      are blocked by at most ``drain_budget`` writes per drain.
    * *Outside drain*: reads own the column bus. A write becomes
      individually eligible only once aged past ``write_age_ns`` (and
      only while occupancy is below the watermark) — which is what
      stops the plain-FR-FCFS pathology of slotting a lone write into
      every read-stream gap and paying both turnaround penalties for a
      single burst. Writes remain the *fallback* group throughout:
      row-prep keeps rows open for queued hits of either kind, so a
      kind-filtered head group must never stall a bus the fallback
      could use (liveness).

    Table IV cost over plain FR-FCFS: a 2-state drain FSM, two occupancy
    comparators, drained/serviced batch counters, and a write-age
    timestamp compare — reported via ``state_footprint()`` so the
    complexity census stays honest.
    """

    count_keys = FRFCFSOpenPagePolicy.count_keys + ("drain_entries",)

    def __init__(self, timing: HBM4Timing | None = None,
                 geometry: ChannelGeometry | None = None,
                 high_watermark: int = 8, low_watermark: int = 2,
                 drain_budget: int = 16, write_age_ns: float = 400.0):
        super().__init__(timing, geometry)
        if not 0 < low_watermark <= high_watermark:
            raise ValueError(
                f"need 0 < low_watermark <= high_watermark, got "
                f"{low_watermark}/{high_watermark}")
        if drain_budget < 1:
            raise ValueError(f"drain_budget must be >= 1, got {drain_budget}")
        self.high_watermark = high_watermark
        self.low_watermark = low_watermark
        self.drain_budget = drain_budget
        self.write_age_ns = write_age_ns

    def begin(self, counts: dict) -> None:
        super().begin(counts)
        self.draining = False
        self._drained = 0            # writes issued in the current batch
        self._reads_since = self.high_watermark   # allow an initial drain

    def _column_groups(self, window: list[Txn],
                       now: float) -> list[list[Txn]]:
        writes = [tx for tx in window if tx.is_write]
        reads = [tx for tx in window if not tx.is_write]
        if self.draining and (self._drained >= self.drain_budget
                              or len(writes) <= self.low_watermark):
            self.draining = False
            self._reads_since = 0
        if (not self.draining and len(writes) >= self.high_watermark
                and (not reads
                     or self._reads_since >= self.high_watermark)):
            self.draining = True
            self._drained = 0
            self.counts["drain_entries"] += 1
        if self.draining:
            return [writes, reads]
        if not reads:
            # Pure posted traffic: only aged writes issue — young ones
            # wait for a batch (or for the core's idle-advance to age
            # them). No reads queued means nothing can deadlock behind
            # the held writes.
            return [[tx for tx in writes
                     if now - tx.arrival_ns >= self.write_age_ns]]
        head = reads
        if len(writes) < self.high_watermark:
            # Overdue trickle writes ride along with the reads; at or
            # above the watermark they wait for the (imminent) batch
            # drain instead of fragmenting it.
            head = reads + [tx for tx in writes
                            if now - tx.arrival_ns >= self.write_age_ns]
        return [head, writes]

    def _after_column(self, tx: Txn, b: _BankState, cmd_t: float) -> None:
        if tx.is_write:
            if self.draining:
                self._drained += 1
        else:
            self._reads_since += 1

    def state_footprint(self) -> dict:
        fp = super().state_footprint()
        fp["name"] = "frfcfs_writedrain"
        fp["scheduling"] = fp["scheduling"] + (
            "write draining (hi/lo watermark)",)
        fp["aux_state"] = ("drain-mode FSM (2 states)",
                           "write-occupancy hi/lo comparators",
                           "drained / reads-serviced batch counters",
                           "write-age timestamp compare")
        return fp


class HBM4SIDGroupPolicy(FRFCFSOpenPagePolicy):
    """FR-FCFS with tCCDR-aware cross-SID burst grouping.

    Column bursts addressed to different SIDs (stack levels) of the same
    pseudo channel must be spaced by tCCDR > tCCDS. This policy keeps a
    last-issued-SID register per PC and prefers a same-SID candidate
    whenever it is ready within the ``tCCDR - tCCDS`` window a switch
    would forfeit, coalescing bursts into same-SID runs (the
    rank-grouping trick of conventional multi-rank controllers).

    Measured honestly (benchmarks/policy_sweep.py): with the Table V
    timings, FR-FCFS's readiness-driven pick already encodes the tCCDR
    penalty, so explicit grouping is bandwidth-*neutral* (bounded by the
    margin rule) — what it buys is fewer SID switch *events*
    (``sid_switches`` stat; rank-switch IO/ODT stress) and a guaranteed
    bound rather than a greedy accident. That neutrality is itself a
    design-space result the sweep reports: conventional-MC scheduling
    tricks buy margins, not multiples — RoMe's granularity change is
    what moves the needle (Table IV / Fig 9).

    Table IV cost over plain FR-FCFS: one SID register per PC plus a
    readiness comparator — see ``state_footprint()``.
    """

    count_keys = FRFCFSOpenPagePolicy.count_keys + ("sid_switches",)

    def begin(self, counts: dict) -> None:
        super().begin(counts)
        self.pc_cur_sid = [-1] * self.g.pseudo_channels

    def _pick_column(self, window: list[Txn], now: float):
        best, best_t = super()._pick_column(window, now)
        if best is None:
            return best, best_t
        pc = self._pc(best.bank)
        cur = self.pc_cur_sid[pc]
        if cur < 0 or best.sid == cur:
            return best, best_t
        # Switching SIDs forfeits tCCDR - tCCDS of the next same-SID
        # burst; take a same-SID candidate if one is ready inside that
        # window.
        margin = self.t.tCCDR - self.t.tCCDS
        same, same_t = None, None
        for tx in window:
            if tx.sid != cur or self._pc(tx.bank) != pc:
                continue
            b = self.banks[tx.bank]
            if b.open_row == tx.row and b.t_act <= 1e17:
                r = self.col_ready(tx.bank, b, tx.is_write, tx.sid,
                                   tx.arrival_ns)
                if same_t is None or r < same_t - 1e-12:
                    same, same_t = tx, r
        if same is not None and same_t <= best_t + margin + 1e-12:
            return same, same_t
        return best, best_t

    def _after_column(self, tx: Txn, b: _BankState, cmd_t: float) -> None:
        pc = self._pc(tx.bank)
        if 0 <= self.pc_cur_sid[pc] != tx.sid:
            self.counts["sid_switches"] += 1
        self.pc_cur_sid[pc] = tx.sid

    def state_footprint(self) -> dict:
        fp = super().state_footprint()
        fp["name"] = "frfcfs_sidgroup"
        fp["scheduling"] = fp["scheduling"] + (
            "cross-SID burst grouping (tCCDR-aware)",)
        fp["aux_state"] = ("last-SID register per PC",
                           "same-SID readiness comparator")
        return fp


# ===========================================================================
# RoMe
# ===========================================================================

class RoMeRowPolicy(SchedulerPolicy):
    """RoMe MC: oldest-first with VBA interleaving (§V-A).

    Three commands (RD_row, WR_row, REF), 4-state VBA FSM. All intra-row
    sequencing is delegated to the command generator (statically timed),
    so the policy only enforces the ten Table III row-to-row gaps; per-VBA
    busy-until and refresh-until complete the FSM
    (Idle / Reading / Writing / Refreshing).
    """

    count_keys = ("ACT", "RD", "WR", "PRE", "REFpb", "row_commands",
                  "ca_commands")
    page_policy = "none (always precharge after row access)"

    #: Refresh priorities a variant may select. "demand" is the paper MC
    #: (refresh postponed under queued demand, bounded by the core's
    #: ``max_ref_postpone``); "eager" never postpones — the channel
    #: binding maps it to ``max_ref_postpone=1``.
    REFRESH_PRIORITIES = ("demand", "eager")

    def __init__(self, timing: RoMeTiming | None = None,
                 geometry: ChannelGeometry | None = None,
                 n_vbas: int = 16,
                 variant: str | None = None,
                 refresh_priority: str = "demand"):
        if refresh_priority not in self.REFRESH_PRIORITIES:
            raise ValueError(
                f"refresh_priority must be one of {self.REFRESH_PRIORITIES}, "
                f"got {refresh_priority!r}")
        self.t = timing or RoMeTiming()
        self.g = geometry or ChannelGeometry()
        self.variant = variant
        self.refresh_priority = refresh_priority
        self.n_vbas = n_vbas
        self.row_bytes = self.g.row_bytes * 2 * self.g.pseudo_channels  # 4 KB
        self._cg = CommandGenerator()
        self._sched_rd = self._cg.expand(is_write=False)
        self._sched_wr = self._cg.expand(is_write=True)
        self._bursts = 2 * self._cg.bursts_per_bank()
        # VBA-paired refresh every 2*tREFIpb, rotating (§V-B).
        self.ref_period = 2 * self.t.tREFIpb
        self.n_ref_units = n_vbas
        self.bytes_per_txn = self.row_bytes
        self._ref_cap = self.t.max_concurrent_refreshing()

    def begin(self, counts: dict) -> None:
        self.counts = counts
        self.vba_busy_until = [0.0] * self.n_vbas  # Reading/Writing/Refreshing
        self.last_cmd_t = -1e18
        self.last_cmd_write = False
        self.last_cmd_vba = -1
        self.last_cmd_sid = -1
        self.ch_last_ref = -1e18       # cross-VBA REFpb release spacing
        self._ref_ends = []            # active refresh windows (FSM cap)

    def start_time(self, tx: Txn, at: float) -> float:
        t = self.t
        r = max(at, tx.arrival_ns, self.vba_busy_until[tx.bank])
        if self.last_cmd_t > -1e17:
            gap = t.gap_ns(self.last_cmd_write, tx.is_write,
                           same_vba=(tx.bank == self.last_cmd_vba),
                           same_sid=(tx.sid == self.last_cmd_sid))
            r = max(r, self.last_cmd_t + gap)
        return r

    def issue_refresh(self, unit: int, due: float) -> None:
        # VBA-paired refresh, anchored at due time (may overlap across
        # VBAs — the paper's "up to three refreshing simultaneously").
        # Each VBA-refresh is two REFpb commands tRREFpb apart, so
        # successive VBA-refresh *starts* keep 2*tRREFpb on the C/A
        # path, and at most max_concurrent_refreshing() windows overlap
        # (the MC provisions exactly that many refresh FSMs) — both
        # found by the trace sanitizer.
        t = self.t
        start = max(due, self.vba_busy_until[unit],
                    self.ch_last_ref + 2 * t.tRREFpb)
        window = t.tRFCpb + t.tRREFpb
        cap = self._ref_cap
        in_flight = sorted(e for e in self._ref_ends if e > start)
        if len(in_flight) >= cap:
            # Wait until enough windows end that ours is the cap-th.
            start = in_flight[len(in_flight) - cap]
        self.vba_busy_until[unit] = start + window
        self.ch_last_ref = start
        self._ref_ends.append(start + window)
        if len(self._ref_ends) > 8:
            del self._ref_ends[0]
        self.counts["REFpb"] += 2
        self.counts["row_commands"] += 1
        self.counts["ca_commands"] += 1
        if self.trace is not None:
            self.trace.append(CmdRecord(start, "REF", unit, 0, -1, -1,
                                        -1.0, -1.0))

    def issue(self, window: list[Txn], now: float):
        t = self.t
        counts = self.counts
        # Oldest-first with VBA interleaving: prefer a request whose VBA
        # differs from the last-issued one if it is ready no later.
        cands = [(self.start_time(tx, now), i, tx)
                 for i, tx in enumerate(window)]
        cands.sort(key=lambda c: (c[0], c[1]))
        best_t, _, best = cands[0]
        for ct, _, tx in cands:
            if tx.bank != self.last_cmd_vba and ct <= best_t + 1e-9:
                best_t, best = ct, tx
                break

        sched = self._sched_wr if best.is_write else self._sched_rd
        svc = t.tWR_row if best.is_write else t.tRD_row
        self.vba_busy_until[best.bank] = best_t + svc
        self.last_cmd_t = best_t
        self.last_cmd_write = best.is_write
        self.last_cmd_vba = best.bank
        self.last_cmd_sid = best.sid
        counts["ACT"] += 2
        counts["PRE"] += 2
        counts["WR" if best.is_write else "RD"] += self._bursts
        counts["row_commands"] += 1
        counts["ca_commands"] += 1
        if self.trace is not None:
            self.trace.append(CmdRecord(
                best_t, "WR_row" if best.is_write else "RD_row",
                best.bank, 0, best.sid, best.row,
                best_t + sched.first_data_ns, best_t + sched.last_data_ns))
        completions = [(best, best_t + sched.last_data_ns)]
        now = max(now, best_t)
        return now, True, completions

    # -- introspection -----------------------------------------------------

    def state_footprint(self) -> dict:
        name = "rome_oldest_first"
        if self.variant:
            name += f"_{self.variant}"
        fp = {
            "name": name,
            "timing_params": self.t.n_managed(),
            # 2 VBAs operating + up to 3 refreshing simultaneously.
            "fsm_instances": 2 + self.t.max_concurrent_refreshing(),
            "states_per_fsm": len(ROME_BANK_STATES),
            "page_policy": self.page_policy,
            "scheduling": ("VBA interleaving",),
        }
        if self.refresh_priority != "demand":
            # The census is invariant across variants — the MC sheds no
            # FSM state by refreshing eagerly; only the governor knob
            # differs, and the footprint says so.
            fp["scheduling"] = fp["scheduling"] + (
                f"refresh priority: {self.refresh_priority}",)
        return fp
