"""Scheduler policies: the pluggable half of the channel simulator.

Each policy packages one controller architecture's *decision logic and
state* — bank/VBA FSMs, per-resource clocks, command selection — behind
the interface :class:`ChannelSimCore` drives:

``count_keys``
    Command-count stat keys the policy maintains (the core adds
    ``ref_backlog_max``).
``ref_period`` / ``n_ref_units``
    Refresh cadence and rotation length for the core's governor.
``begin(counts)``
    (Re)initialize all per-run state; stash the shared counts dict.
``issue_refresh(unit, due)``
    Perform one rotating refresh for `unit`, anchored at `due`.
``issue(window, now) -> (now, issued, completions)``
    One scheduling step over the arrived window. `completions` is a list
    of ``(txn, finish_ns)``; `issued` False tells the core to advance the
    clock to the next event.
``bytes_per_txn``
    Data moved per transaction (MC access granularity).
``state_footprint()``
    The Table IV census of what the policy must physically track — FSM
    instances, states per FSM, managed timing parameters, page policy —
    so MC-complexity claims are introspected from the code that *is* the
    scheduler rather than asserted in prose.
"""
from __future__ import annotations

from ..command_generator import CommandGenerator
from ..timing import (ChannelGeometry, HBM4_BANK_STATES, HBM4Timing,
                      ROME_BANK_STATES, RoMeTiming)
from .core import Txn


class SchedulerPolicy:
    """Interface; see the module docstring for the contract."""

    count_keys: tuple = ()
    ref_period: float = 0.0
    n_ref_units: int = 1
    bytes_per_txn: int = 0

    def begin(self, counts: dict) -> None:
        raise NotImplementedError

    def issue_refresh(self, unit: int, due: float) -> None:
        raise NotImplementedError

    def issue(self, window: list[Txn], now: float):
        raise NotImplementedError

    def state_footprint(self) -> dict:
        raise NotImplementedError


# ===========================================================================
# Conventional HBM4: FR-FCFS
# ===========================================================================

class _BankState:
    __slots__ = ("open_row", "t_act", "t_last_rd", "t_last_wr_data",
                 "t_rp_done", "t_ref_done")

    def __init__(self) -> None:
        self.open_row: int | None = None
        self.t_act = -1e18
        self.t_last_rd = -1e18
        self.t_last_wr_data = -1e18
        self.t_rp_done = 0.0
        self.t_ref_done = 0.0


class FRFCFSOpenPagePolicy(SchedulerPolicy):
    """FR-FCFS over a bounded CAM queue, open-page, 7-state bank FSMs.

    One HBM4 channel = 2 pseudo channels simulated jointly. Each PC owns
    half the DQ pins and its own banks; the two PCs share C/A but we
    assume C/A is never the bottleneck for the baseline (it has 18 pins).
    Bank ids 0..127: pc = bank // 64, bank group = (bank % 64) // 4.
    """

    count_keys = ("ACT", "RD", "WR", "PRE", "REFpb", "ca_commands")
    page_policy = "open"

    #: Open-page keeps a row open while queued hits still target it; the
    #: closed-page subclass flips this (always precharge after access).
    keep_open_for_hits = True

    def __init__(self, timing: HBM4Timing | None = None,
                 geometry: ChannelGeometry | None = None):
        self.t = timing or HBM4Timing()
        self.g = geometry or ChannelGeometry()
        self.banks_per_pc = self.g.banks_per_pc
        self.n_banks = self.g.banks_per_channel
        self.burst_ns = self.g.burst_ns  # 32 B over one PC's pins
        self.ref_period = self.t.tREFIpb
        self.n_ref_units = self.n_banks
        self.bytes_per_txn = self.g.col_bytes

    # -- helpers -----------------------------------------------------------

    def _bg(self, bank: int) -> int:
        return (bank % self.banks_per_pc) // self.g.banks_per_group

    def _pc(self, bank: int) -> int:
        return bank // self.banks_per_pc

    # -- per-run state -----------------------------------------------------

    def begin(self, counts: dict) -> None:
        self.counts = counts
        self.banks = [_BankState() for _ in range(self.n_banks)]
        # Per-PC shared resources.
        self.pc_bus_free = [0.0, 0.0]              # DQ bus next-free
        self.pc_last_burst = [-1e18, -1e18]        # last RD/WR cmd time (tCCDS)
        self.pc_last_burst_bg = [dict(), dict()]   # bg -> last cmd time (tCCDL)
        self.pc_last_burst_sid = [dict(), dict()]  # sid -> last cmd time (tCCDR)
        self.pc_last_was_write = [False, False]
        self.pc_last_rd_cmd = [-1e18, -1e18]
        self.pc_last_wr_data_end = [-1e18, -1e18]
        self.pc_act_times = [[], []]               # for tFAW (per PC)
        self.pc_last_act = [-1e18, -1e18]          # tRRDS
        self.pc_last_act_bg = [dict(), dict()]     # tRRDL

    # -- readiness clocks --------------------------------------------------

    def act_ready(self, bank_id: int, b: _BankState, at: float) -> float:
        t = self.t
        pc = self._pc(bank_id)
        bg = self._bg(bank_id)
        r = max(at, b.t_rp_done, b.t_ref_done,
                self.pc_last_act[pc] + t.tRRDS,
                self.pc_last_act_bg[pc].get(bg, -1e18) + t.tRRDL)
        acts = self.pc_act_times[pc]
        if len(acts) >= 4:
            r = max(r, acts[-4] + t.tFAW)
        return r

    def col_ready(self, bank_id: int, b: _BankState, is_write: bool,
                  sid: int, at: float) -> float:
        t = self.t
        pc = self._pc(bank_id)
        bg = self._bg(bank_id)
        trcd = t.tRCDWR if is_write else t.tRCDRD
        r = max(at, b.t_act + trcd, b.t_ref_done,
                self.pc_last_burst[pc] + t.tCCDS,
                self.pc_last_burst_bg[pc].get(bg, -1e18) + t.tCCDL)
        # tCCDR: RD/WR to RD/WR spacing across SIDs (ranks) sharing the PC.
        for other_sid, t_cmd in self.pc_last_burst_sid[pc].items():
            if other_sid != sid:
                r = max(r, t_cmd + t.tCCDR)
        if is_write and not self.pc_last_was_write[pc]:
            r = max(r, self.pc_last_rd_cmd[pc] + t.tRTW)
        if not is_write and self.pc_last_was_write[pc]:
            r = max(r, self.pc_last_wr_data_end[pc] + t.tWTRS)
        return r

    def pre_ready(self, b: _BankState, at: float) -> float:
        t = self.t
        return max(at, b.t_act + t.tRAS, b.t_last_rd + t.tRTP,
                   b.t_last_wr_data + t.tWR)

    # -- refresh -----------------------------------------------------------

    def issue_refresh(self, unit: int, due: float) -> None:
        t = self.t
        b = self.banks[unit]
        start = max(due, b.t_rp_done, b.t_ref_done)
        if b.open_row is not None:
            pr = self.pre_ready(b, start)
            b.t_rp_done = pr + t.tRP
            b.open_row = None
            self.counts["PRE"] += 1
            start = b.t_rp_done
        b.t_ref_done = start + t.tRFCpb
        self.counts["REFpb"] += 1

    # -- one scheduling step -----------------------------------------------

    def issue(self, window: list[Txn], now: float):
        t = self.t
        counts = self.counts
        banks = self.banks
        issued = False
        completions: list = []

        # Row-bus work (runs concurrently with the column bus): progress
        # the oldest row-miss whose bank's open row is no longer needed by
        # any queued hit. This is what deep queues buy the conventional
        # MC — lookahead to overlap ACT/PRE of upcoming rows with the
        # bursts of the current ones.
        prepared: set[int] = set()
        for tx in window:
            b = banks[tx.bank]
            if b.open_row == tx.row or tx.bank in prepared:
                continue
            if b.open_row is not None:
                # Keep a row open while queued hits still target it
                # (open-page only).
                if self.keep_open_for_hits and \
                        any(h.bank == tx.bank and h.row == b.open_row
                            for h in window):
                    prepared.add(tx.bank)
                    continue
                pr = self.pre_ready(b, max(tx.arrival_ns, b.t_ref_done))
                b.t_rp_done = pr + t.tRP
                b.open_row = None
                counts["PRE"] += 1
                counts["ca_commands"] += 1
                now = max(now, pr)
            else:
                ar = self.act_ready(tx.bank, b,
                                    max(tx.arrival_ns, b.t_ref_done))
                pc = self._pc(tx.bank)
                bg = self._bg(tx.bank)
                b.t_act = ar
                b.open_row = tx.row
                self.pc_last_act[pc] = ar
                self.pc_last_act_bg[pc][bg] = ar
                self.pc_act_times[pc].append(ar)
                if len(self.pc_act_times[pc]) > 8:
                    self.pc_act_times[pc] = self.pc_act_times[pc][-8:]
                counts["ACT"] += 1
                counts["ca_commands"] += 1
                now = max(now, ar)
            prepared.add(tx.bank)
            issued = True

        # Column-bus work: earliest-ready row hit (FR), oldest on ties.
        # Issue times are governed by per-resource clocks (bank readiness,
        # per-PC burst spacing, DQ bus) — the column C/A path sustains one
        # command per PC per tCCDS, so a pick may legally land before
        # `now` (commands ride independent buses).
        best = None
        best_t = None
        for tx in window:
            b = banks[tx.bank]
            if b.open_row == tx.row and b.t_act <= 1e17:
                r = self.col_ready(tx.bank, b, tx.is_write, tx.sid,
                                   tx.arrival_ns)
                if best_t is None or r < best_t - 1e-12:
                    best, best_t = tx, r
        if best is not None:
            tx, r = best, best_t
            b = banks[tx.bank]
            pc = self._pc(tx.bank)
            bg = self._bg(tx.bank)
            lat = t.tCWL if tx.is_write else t.tCL
            data_start = max(r + lat, self.pc_bus_free[pc])
            # If the bus is the constraint, push the command time too.
            cmd_t = data_start - lat
            data_end = data_start + self.burst_ns
            self.pc_bus_free[pc] = data_end
            self.pc_last_burst[pc] = cmd_t
            self.pc_last_burst_bg[pc][bg] = cmd_t
            self.pc_last_burst_sid[pc][tx.sid] = cmd_t
            self.pc_last_was_write[pc] = tx.is_write
            counts["ca_commands"] += 1
            if tx.is_write:
                b.t_last_wr_data = data_end
                self.pc_last_wr_data_end[pc] = data_end
                counts["WR"] += 1
            else:
                b.t_last_rd = cmd_t
                self.pc_last_rd_cmd[pc] = cmd_t
                counts["RD"] += 1
            self._after_column(b, cmd_t)
            completions.append((tx, data_end))
            now = max(now, cmd_t)
            issued = True

        return now, issued, completions

    def _after_column(self, b: _BankState, cmd_t: float) -> None:
        """Open-page: the row stays open after a column access."""

    # -- introspection -----------------------------------------------------

    def state_footprint(self) -> dict:
        scheduling = ("bank group interleaving", "PC interleaving")
        if self.keep_open_for_hits:
            scheduling = ("row-buffer locality",) + scheduling
        return {
            "name": "frfcfs_open" if self.keep_open_for_hits else
                    "frfcfs_closed",
            "timing_params": self.t.n_managed(),
            "fsm_instances": self.banks_per_pc,   # one per bank per PC
            "states_per_fsm": len(HBM4_BANK_STATES),
            "page_policy": self.page_policy,
            "scheduling": scheduling,
        }


class HBM4ClosedPagePolicy(FRFCFSOpenPagePolicy):
    """Closed-page HBM4 variant: auto-precharge after every column access.

    A comparison point between open-page FR-FCFS and RoMe: the scheduler
    sheds the row-buffer-locality bookkeeping (every access pays
    ACT + RD/WR + PRE), so it degrades far less with shallow queues but
    caps stream bandwidth at the tRC-limited random-row rate. The
    difference from the open-page policy is exactly two hooks — the
    keep-open-for-hits check and the post-access precharge — everything
    else (bank FSMs, per-PC clocks, refresh) is shared.
    """

    page_policy = "closed (auto-precharge after access)"
    keep_open_for_hits = False

    def _after_column(self, b: _BankState, cmd_t: float) -> None:
        pr = self.pre_ready(b, cmd_t)
        b.t_rp_done = pr + self.t.tRP
        b.open_row = None
        self.counts["PRE"] += 1
        self.counts["ca_commands"] += 1


# ===========================================================================
# RoMe
# ===========================================================================

class RoMeRowPolicy(SchedulerPolicy):
    """RoMe MC: oldest-first with VBA interleaving (§V-A).

    Three commands (RD_row, WR_row, REF), 4-state VBA FSM. All intra-row
    sequencing is delegated to the command generator (statically timed),
    so the policy only enforces the ten Table III row-to-row gaps; per-VBA
    busy-until and refresh-until complete the FSM
    (Idle / Reading / Writing / Refreshing).
    """

    count_keys = ("ACT", "RD", "WR", "PRE", "REFpb", "row_commands",
                  "ca_commands")
    page_policy = "none (always precharge after row access)"

    def __init__(self, timing: RoMeTiming | None = None,
                 geometry: ChannelGeometry | None = None,
                 n_vbas: int = 16):
        self.t = timing or RoMeTiming()
        self.g = geometry or ChannelGeometry()
        self.n_vbas = n_vbas
        self.row_bytes = self.g.row_bytes * 2 * self.g.pseudo_channels  # 4 KB
        self._cg = CommandGenerator()
        self._sched_rd = self._cg.expand(is_write=False)
        self._sched_wr = self._cg.expand(is_write=True)
        self._bursts = 2 * self._cg.bursts_per_bank()
        # VBA-paired refresh every 2*tREFIpb, rotating (§V-B).
        self.ref_period = 2 * self.t.tREFIpb
        self.n_ref_units = n_vbas
        self.bytes_per_txn = self.row_bytes

    def begin(self, counts: dict) -> None:
        self.counts = counts
        self.vba_busy_until = [0.0] * self.n_vbas  # Reading/Writing/Refreshing
        self.last_cmd_t = -1e18
        self.last_cmd_write = False
        self.last_cmd_vba = -1
        self.last_cmd_sid = -1

    def start_time(self, tx: Txn, at: float) -> float:
        t = self.t
        r = max(at, tx.arrival_ns, self.vba_busy_until[tx.bank])
        if self.last_cmd_t > -1e17:
            gap = t.gap_ns(self.last_cmd_write, tx.is_write,
                           same_vba=(tx.bank == self.last_cmd_vba),
                           same_sid=(tx.sid == self.last_cmd_sid))
            r = max(r, self.last_cmd_t + gap)
        return r

    def issue_refresh(self, unit: int, due: float) -> None:
        # VBA-paired refresh, anchored at due time (may overlap across
        # VBAs — the paper's "up to three refreshing simultaneously").
        t = self.t
        start = max(due, self.vba_busy_until[unit])
        self.vba_busy_until[unit] = start + t.tRFCpb + t.tRREFpb
        self.counts["REFpb"] += 2
        self.counts["row_commands"] += 1
        self.counts["ca_commands"] += 1

    def issue(self, window: list[Txn], now: float):
        t = self.t
        counts = self.counts
        # Oldest-first with VBA interleaving: prefer a request whose VBA
        # differs from the last-issued one if it is ready no later.
        cands = [(self.start_time(tx, now), i, tx)
                 for i, tx in enumerate(window)]
        cands.sort(key=lambda c: (c[0], c[1]))
        best_t, _, best = cands[0]
        for ct, _, tx in cands:
            if tx.bank != self.last_cmd_vba and ct <= best_t + 1e-9:
                best_t, best = ct, tx
                break

        sched = self._sched_wr if best.is_write else self._sched_rd
        svc = t.tWR_row if best.is_write else t.tRD_row
        self.vba_busy_until[best.bank] = best_t + svc
        self.last_cmd_t = best_t
        self.last_cmd_write = best.is_write
        self.last_cmd_vba = best.bank
        self.last_cmd_sid = best.sid
        counts["ACT"] += 2
        counts["PRE"] += 2
        counts["WR" if best.is_write else "RD"] += self._bursts
        counts["row_commands"] += 1
        counts["ca_commands"] += 1
        completions = [(best, best_t + sched.last_data_ns)]
        now = max(now, best_t)
        return now, True, completions

    # -- introspection -----------------------------------------------------

    def state_footprint(self) -> dict:
        return {
            "name": "rome_oldest_first",
            "timing_params": self.t.n_managed(),
            # 2 VBAs operating + up to 3 refreshing simultaneously.
            "fsm_instances": 2 + self.t.max_concurrent_refreshing(),
            "states_per_fsm": len(ROME_BANK_STATES),
            "page_policy": self.page_policy,
            "scheduling": ("VBA interleaving",),
        }
