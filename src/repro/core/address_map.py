"""Address mapping: software addresses -> (channel, bank/VBA, row, col).

The paper sweeps address mappings for both baseline and RoMe and picks the
bandwidth-maximizing one (§VI-A). For bulk-sequential LLM traffic that is a
channel-interleaved stripe: consecutive AG_MC-sized units rotate across
channels, then across banks/VBAs (RoMe) or bank groups/banks (HBM4), then
rows. This module provides the stripe math plus the channel load-balance
ratio (LBR, Fig 13) used throughout the perf model.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .timing import MemSystemConfig


@dataclass(frozen=True)
class AddressMap:
    """Stripe-interleaved address map over a multi-cube memory system."""

    n_channels: int            # total channels (cubes * channels_per_cube)
    stripe_bytes: int          # interleave granularity == AG_MC
    banks_per_channel: int     # banks (HBM4) or VBAs (RoMe)
    row_bytes: int             # bytes per effective row

    def channel_of(self, addr: np.ndarray | int):
        return (np.asarray(addr) // self.stripe_bytes) % self.n_channels

    def unit_of(self, addr: np.ndarray | int):
        """Index of the stripe unit within its channel."""
        return (np.asarray(addr) // self.stripe_bytes) // self.n_channels

    def bank_of(self, addr: np.ndarray | int):
        return self.unit_of(addr) % self.banks_per_channel

    def row_of(self, addr: np.ndarray | int):
        units_per_row = max(1, self.row_bytes // self.stripe_bytes)
        return (self.unit_of(addr) // self.banks_per_channel) // units_per_row


def make_address_map(cfg: MemSystemConfig, n_cubes: int = 8) -> AddressMap:
    if cfg.ag_mc_bytes >= cfg.row_bytes:
        banks = cfg.vbas_per_channel            # RoMe: interleave over VBAs
    else:
        banks = cfg.banks_per_channel
    return AddressMap(
        n_channels=cfg.channels_per_cube * n_cubes,
        stripe_bytes=cfg.ag_mc_bytes,
        banks_per_channel=banks,
        row_bytes=cfg.row_bytes,
    )


# ---------------------------------------------------------------------------
# Channel load balance (Fig 13) & the vectorized extent census
# ---------------------------------------------------------------------------
#
# All three censuses below (exact bytes, stripe-unit/transaction counts,
# record touches) share the same cyclic-window stripe math: an extent
# covers `full` complete rotations of the channel ring plus one window
# of `rem` consecutive channels starting at its first unit's channel.
# The batched kernel (`extent_census`) computes every census for a whole
# batch of extents — optionally segmented into per-stream rows — in a
# fixed number of numpy passes: full rotations reduce to per-segment
# sums, and the remainder windows become difference-array updates
# (+w at window start, -w at window end, wrapped tails folded to
# channel 0) resolved by one cumulative sum per segment. That is what
# lets the queue-window model price a fleet of decode steps
# array-at-a-time instead of looping Python over every record.


def extent_arrays(extents) -> tuple[np.ndarray, np.ndarray]:
    """(starts, sizes) int64 arrays from ``[(addr, nbytes)]`` (or any
    (n, 2)-shaped array-like); non-positive sizes dropped, matching the
    scalar loops' skip."""
    a = np.asarray(extents, dtype=np.int64)
    if a.size == 0:
        z = np.zeros(0, np.int64)
        return z, z.copy()
    starts, sizes = a[:, 0], a[:, 1]
    keep = sizes > 0
    if not bool(keep.all()):
        starts, sizes = starts[keep], sizes[keep]
    return starts, sizes


def _windowed_add(acc: np.ndarray, seg: np.ndarray | None, ch0: np.ndarray,
                  length: np.ndarray, weight) -> None:
    """Add ``weight`` to the cyclic channel window ``[ch0, ch0+length)``
    (mod n_channels) of each extent, accumulated into ``acc`` of shape
    (n_segs, n_channels) via difference arrays + one cumsum. ``length``
    must be in [0, n_channels]; ``weight`` is a scalar or per-extent
    array. ``seg`` selects each extent's row (None == row 0)."""
    n_segs, nch = acc.shape
    if ch0.size == 0:
        return
    w = np.broadcast_to(np.asarray(weight, dtype=acc.dtype), ch0.shape)
    row = np.zeros(ch0.shape, np.int64) if seg is None else seg
    # One spare slot per row absorbs -w at window ends that land exactly
    # on nch (never read back by the per-row cumsum).
    d = np.zeros(n_segs * (nch + 1), dtype=acc.dtype)
    base = row * (nch + 1)
    end = ch0 + length
    np.add.at(d, base + ch0, w)
    np.add.at(d, base + np.minimum(end, nch), -w)
    wrap = end - nch
    wrapped = wrap > 0
    if bool(wrapped.any()):
        np.add.at(d, base[wrapped], w[wrapped])          # [0, end-nch)
        np.add.at(d, base[wrapped] + wrap[wrapped], -w[wrapped])
    acc += np.cumsum(d.reshape(n_segs, nch + 1), axis=1)[:, :nch]


def extent_census(amap: AddressMap, starts: np.ndarray, sizes: np.ndarray,
                  seg: np.ndarray | None = None, n_segs: int = 1
                  ) -> dict[str, np.ndarray]:
    """Every per-channel census of a batch of extents in one vectorized
    pass. Returns ``{"bytes", "units", "touches"}``, each an
    ``(n_segs, n_channels)`` int64 array:

    * ``bytes`` — exact per-channel byte counts (partial first/last
      stripes trimmed), the :func:`channel_bytes` census;
    * ``units`` — stripe-unit (MC transaction) counts, duplicates kept,
      the :func:`channel_unit_counts` census;
    * ``touches`` — extents touching each channel at least once, the
      :func:`record_touch_counts` census.

    ``seg`` (per-extent segment/stream index into ``n_segs`` rows) is
    the batching axis: the queue-window model passes one segment per
    decode step and prices a whole fleet round in a single call.
    """
    g = amap.stripe_bytes
    nch = amap.n_channels
    out = {k: np.zeros((n_segs, nch), np.int64)
           for k in ("bytes", "units", "touches")}
    if starts.size == 0:
        return out
    first_unit = starts // g
    last_unit = (starts + sizes - 1) // g
    n_units = last_unit - first_unit + 1
    full, rem = np.divmod(n_units, nch)
    ch0 = first_unit % nch
    # Full rotations load every channel of the segment equally.
    if seg is None:
        full_sum = np.array([full.sum()])
    else:
        full_sum = np.bincount(seg, weights=full, minlength=n_segs
                               ).astype(np.int64)
    out["units"] += full_sum[:, None]
    out["bytes"] += full_sum[:, None] * g
    sel = rem > 0
    sseg = None if seg is None else seg[sel]
    _windowed_add(out["units"], sseg, ch0[sel], rem[sel], 1)
    _windowed_add(out["bytes"], sseg, ch0[sel], rem[sel], g)
    # Trim the partial first/last stripes to exact byte counts.
    head_excess = starts - first_unit * g
    tail_excess = (last_unit + 1) * g - (starts + sizes)
    row = np.zeros(starts.shape, np.int64) if seg is None else seg
    flat = out["bytes"].reshape(-1)
    np.subtract.at(flat, row * nch + ch0, head_excess)
    np.subtract.at(flat, row * nch + last_unit % nch, tail_excess)
    # Touches: extents spanning a whole rotation touch every channel
    # once; shorter ones touch their n_units-wide window.
    big = n_units >= nch
    if seg is None:
        big_sum = np.array([np.count_nonzero(big)])
    else:
        big_sum = np.bincount(seg[big], minlength=n_segs)
    out["touches"] += big_sum[:, None]
    small = ~big
    sseg = None if seg is None else seg[small]
    _windowed_add(out["touches"], sseg, ch0[small], n_units[small], 1)
    return out


def channel_bytes(amap: AddressMap, extents) -> np.ndarray:
    """Per-channel byte counts for a set of (start_addr, nbytes) extents.

    Exact stripe accounting (vectorized): each extent contributes
    floor/ceil stripes to a cyclic window of channels, with the partial
    first/last stripes trimmed to exact byte counts.
    """
    starts, sizes = extent_arrays(extents)
    return extent_census(amap, starts, sizes)["bytes"][0]


def channel_unit_counts(amap: AddressMap, extents) -> np.ndarray:
    """Per-channel *stripe-unit* counts for a set of (addr, nbytes)
    extents — the exact number of MC transactions
    :meth:`repro.core.system_sim.SystemSim.decompose` would create per
    channel (one txn per touched unit, duplicates counted per extent),
    without materializing any of them. Same cyclic-window stripe math as
    :func:`channel_bytes`, but counting whole units instead of trimming
    partial stripes: this is the O(n_extents) transaction census the
    queue-window model (:mod:`repro.core.queue_model`) and the hybrid
    fast path price unscaled streams with.
    """
    starts, sizes = extent_arrays(extents)
    return extent_census(amap, starts, sizes)["units"][0]


def record_touch_counts(amap: AddressMap, extents) -> np.ndarray:
    """Per-channel *record* counts: how many of the given extents touch
    each channel at least once (each record contributes at most 1 per
    channel). This is the per-extent cost census — a record opening a
    channel pays that channel's fixed row-open/ACT path once regardless
    of how many units it then streams, which is the term the queue-window
    model's ``ext_ns_per_rec`` coefficient prices. Vectorized, same
    cyclic-window stripe math as :func:`channel_unit_counts`.
    """
    starts, sizes = extent_arrays(extents)
    return extent_census(amap, starts, sizes)["touches"][0]


def load_balance_ratio(amap: AddressMap,
                       extents: list[tuple[int, int]]) -> float:
    """LBR = mean(channel bytes) / max(channel bytes); 1.0 == perfectly
    balanced. The effective bandwidth of a bulk transfer scales with LBR
    because the slowest (most loaded) channel gates completion."""
    cb = channel_bytes(amap, extents)
    mx = cb.max()
    if mx == 0:
        return 1.0
    return float(cb.mean() / mx)


def effective_bandwidth_fraction(amap: AddressMap,
                                 extents: list[tuple[int, int]]) -> float:
    """Fraction of peak system bandwidth achievable for these extents,
    limited by the most-loaded channel."""
    return load_balance_ratio(amap, extents)
