"""Address mapping: software addresses -> (channel, bank/VBA, row, col).

The paper sweeps address mappings for both baseline and RoMe and picks the
bandwidth-maximizing one (§VI-A). For bulk-sequential LLM traffic that is a
channel-interleaved stripe: consecutive AG_MC-sized units rotate across
channels, then across banks/VBAs (RoMe) or bank groups/banks (HBM4), then
rows. This module provides the stripe math plus the channel load-balance
ratio (LBR, Fig 13) used throughout the perf model.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .timing import MemSystemConfig


@dataclass(frozen=True)
class AddressMap:
    """Stripe-interleaved address map over a multi-cube memory system."""

    n_channels: int            # total channels (cubes * channels_per_cube)
    stripe_bytes: int          # interleave granularity == AG_MC
    banks_per_channel: int     # banks (HBM4) or VBAs (RoMe)
    row_bytes: int             # bytes per effective row

    def channel_of(self, addr: np.ndarray | int):
        return (np.asarray(addr) // self.stripe_bytes) % self.n_channels

    def unit_of(self, addr: np.ndarray | int):
        """Index of the stripe unit within its channel."""
        return (np.asarray(addr) // self.stripe_bytes) // self.n_channels

    def bank_of(self, addr: np.ndarray | int):
        return self.unit_of(addr) % self.banks_per_channel

    def row_of(self, addr: np.ndarray | int):
        units_per_row = max(1, self.row_bytes // self.stripe_bytes)
        return (self.unit_of(addr) // self.banks_per_channel) // units_per_row


def make_address_map(cfg: MemSystemConfig, n_cubes: int = 8) -> AddressMap:
    if cfg.ag_mc_bytes >= cfg.row_bytes:
        banks = cfg.vbas_per_channel            # RoMe: interleave over VBAs
    else:
        banks = cfg.banks_per_channel
    return AddressMap(
        n_channels=cfg.channels_per_cube * n_cubes,
        stripe_bytes=cfg.ag_mc_bytes,
        banks_per_channel=banks,
        row_bytes=cfg.row_bytes,
    )


# ---------------------------------------------------------------------------
# Channel load balance (Fig 13)
# ---------------------------------------------------------------------------

def channel_bytes(amap: AddressMap, extents: list[tuple[int, int]]) -> np.ndarray:
    """Per-channel byte counts for a set of (start_addr, nbytes) extents.

    Exact stripe accounting (vectorized): each extent contributes
    floor/ceil stripes to a cyclic window of channels.
    """
    out = np.zeros(amap.n_channels, dtype=np.int64)
    g = amap.stripe_bytes
    for start, nbytes in extents:
        if nbytes <= 0:
            continue
        first_unit = start // g
        last_unit = (start + nbytes - 1) // g
        n_units = last_unit - first_unit + 1
        full, rem = divmod(n_units, amap.n_channels)
        if full:
            out += full * g
        if rem:
            ch0 = first_unit % amap.n_channels
            idx = (ch0 + np.arange(rem)) % amap.n_channels
            np.add.at(out, idx, g)
        # Trim the partial first/last stripes to exact byte counts.
        head_excess = start - first_unit * g
        tail_excess = (last_unit + 1) * g - (start + nbytes)
        out[first_unit % amap.n_channels] -= head_excess
        out[last_unit % amap.n_channels] -= tail_excess
    return out


def channel_unit_counts(amap: AddressMap,
                        extents: list[tuple[int, int]]) -> np.ndarray:
    """Per-channel *stripe-unit* counts for a set of (addr, nbytes)
    extents — the exact number of MC transactions
    :meth:`repro.core.system_sim.SystemSim.decompose` would create per
    channel (one txn per touched unit, duplicates counted per extent),
    without materializing any of them. Same cyclic-window stripe math as
    :func:`channel_bytes`, but counting whole units instead of trimming
    partial stripes: this is the O(n_extents) transaction census the
    queue-window model (:mod:`repro.core.queue_model`) and the hybrid
    fast path price unscaled streams with.
    """
    out = np.zeros(amap.n_channels, dtype=np.int64)
    g = amap.stripe_bytes
    for start, nbytes in extents:
        if nbytes <= 0:
            continue
        first_unit = start // g
        last_unit = (start + nbytes - 1) // g
        n_units = last_unit - first_unit + 1
        full, rem = divmod(n_units, amap.n_channels)
        if full:
            out += full
        if rem:
            ch0 = first_unit % amap.n_channels
            idx = (ch0 + np.arange(rem)) % amap.n_channels
            np.add.at(out, idx, 1)
    return out


def record_touch_counts(amap: AddressMap,
                        extents: list[tuple[int, int]]) -> np.ndarray:
    """Per-channel *record* counts: how many of the given extents touch
    each channel at least once (each record contributes at most 1 per
    channel). This is the per-extent cost census — a record opening a
    channel pays that channel's fixed row-open/ACT path once regardless
    of how many units it then streams, which is the term the queue-window
    model's ``ext_ns_per_rec`` coefficient prices. O(n_extents), same
    cyclic-window stripe math as :func:`channel_unit_counts`.
    """
    out = np.zeros(amap.n_channels, dtype=np.int64)
    g = amap.stripe_bytes
    nch = amap.n_channels
    for start, nbytes in extents:
        if nbytes <= 0:
            continue
        first_unit = start // g
        last_unit = (start + nbytes - 1) // g
        n_units = last_unit - first_unit + 1
        if n_units >= nch:
            out += 1
        else:
            ch0 = first_unit % nch
            idx = (ch0 + np.arange(n_units)) % nch
            out[idx] += 1
    return out


def load_balance_ratio(amap: AddressMap,
                       extents: list[tuple[int, int]]) -> float:
    """LBR = mean(channel bytes) / max(channel bytes); 1.0 == perfectly
    balanced. The effective bandwidth of a bulk transfer scales with LBR
    because the slowest (most loaded) channel gates completion."""
    cb = channel_bytes(amap, extents)
    mx = cb.max()
    if mx == 0:
        return 1.0
    return float(cb.mean() / mx)


def effective_bandwidth_fraction(amap: AddressMap,
                                 extents: list[tuple[int, int]]) -> float:
    """Fraction of peak system bandwidth achievable for these extents,
    limited by the most-loaded channel."""
    return load_balance_ratio(amap, extents)
