"""MC scheduling-logic and command-generator area model (paper §VI-C).

The paper implements both schedulers in Verilog (7 nm ASAP7) and reports:
  * RoMe MC scheduling logic = 9.1 % of the conventional MC's
    (command scheduler + bank FSMs + request queue; 64-entry vs 4-entry
    FR-FCFS queues),
  * command generator = 4268.8 um^2 per cube (36 channels) = 0.003 % of the
    logic die,
  * +4 channels: 48 extra u-bumps ~ 0.14 mm^2; DRAM die +12 % in the channel
    region => total die overhead ~0.10 %.

We reproduce those numbers with a simple structural gate/bit model whose
coefficients are anchored to the paper's totals; the *ratios* are what the
benchmark asserts.
"""
from __future__ import annotations

from dataclasses import dataclass

from .timing import HBM4_BANK_STATES, ROME_BANK_STATES

# Area coefficients (um^2) in a 7 nm-class process — structural proxies.
UM2_PER_CAM_BIT = 0.95          # request queue CAM cell (search + storage)
UM2_PER_FSM_STATE = 22.0        # one bank-FSM state's worth of logic
UM2_PER_TIMING_PARAM = 160.0    # one tracked timing constraint (counters+cmp)
UM2_SCHED_BASE = 1400.0         # arbiter / age matrix base
UM2_PER_QUEUE_ENTRY_SCHED = 95.0  # per-entry ready/grant logic

REQUEST_ENTRY_BITS = 64         # address + metadata per CAM entry


@dataclass(frozen=True)
class MCArea:
    queue_um2: float
    fsm_um2: float
    timing_um2: float
    sched_um2: float

    @property
    def total_um2(self) -> float:
        return self.queue_um2 + self.fsm_um2 + self.timing_um2 + self.sched_um2


def conventional_mc_area(queue_depth: int = 64,
                         banks_per_pc: int = 64,
                         n_timing: int = 15) -> MCArea:
    """Per-PC scheduling logic of a conventional MC: a bank FSM per bank,
    full timing tracking, deep CAM queue."""
    return MCArea(
        queue_um2=queue_depth * REQUEST_ENTRY_BITS * UM2_PER_CAM_BIT,
        fsm_um2=banks_per_pc * len(HBM4_BANK_STATES) * UM2_PER_FSM_STATE,
        timing_um2=n_timing * UM2_PER_TIMING_PARAM,
        sched_um2=UM2_SCHED_BASE + queue_depth * UM2_PER_QUEUE_ENTRY_SCHED,
    )


def rome_mc_area(queue_depth: int = 4,
                 n_bank_fsms: int = 5,
                 n_timing: int = 10) -> MCArea:
    """RoMe MC: 5 bank FSMs total (2 active + 3 refreshing), 4-state FSMs,
    10 timing parameters, 4-entry queue (§V-A / §VI-C)."""
    return MCArea(
        queue_um2=queue_depth * REQUEST_ENTRY_BITS * UM2_PER_CAM_BIT,
        fsm_um2=n_bank_fsms * len(ROME_BANK_STATES) * UM2_PER_FSM_STATE,
        # Row-to-row gaps need one shared counter per parameter class, not
        # the per-bank replicated comparators of the conventional design.
        timing_um2=n_timing * UM2_PER_TIMING_PARAM * 0.5,
        # Oldest-first VBA interleaving: no FR search, no page-policy logic.
        sched_um2=UM2_SCHED_BASE * 0.2 + queue_depth * UM2_PER_QUEUE_ENTRY_SCHED,
    )


def mc_area_ratio() -> float:
    """RoMe scheduling-logic area / conventional (paper: 9.1 %)."""
    return rome_mc_area().total_um2 / conventional_mc_area().total_um2


# -- command generator & channel expansion ----------------------------------

CMDGEN_UM2_PER_CHANNEL = 4268.8 / 36.0   # paper total / 36 channels
LOGIC_DIE_MM2 = 121.0                     # ~11x11 mm logic die


def command_generator_overhead_frac(n_channels: int = 36) -> float:
    return (CMDGEN_UM2_PER_CHANNEL * n_channels) / (LOGIC_DIE_MM2 * 1e6)


UBUMP_PITCH_UM = 22.0
UBUMPS_PER_EXTRA_CHANNEL = 48 // 4       # 48 total for 4 channels


def extra_channel_area_mm2(n_extra: int = 4) -> float:
    """u-bump field area for the extra channels' TSVs (paper: ~0.14 mm^2)."""
    n_bumps = n_extra * UBUMPS_PER_EXTRA_CHANNEL
    per_bump_mm2 = (UBUMP_PITCH_UM * 1e-3) ** 2
    # Conservative 4x scaling of bumps per channel (paper methodology).
    return n_bumps * 4 * per_bump_mm2
