"""Vectorized analytic service-time model, calibrated against the engine.

Full-model TPOT sweeps touch terabytes of traffic; simulating every 32 B
column transaction is pointless. For bulk-sequential LLM streams the
cycle-level engine shows both controllers settle into a periodic steady
state, so a transfer is characterized by per-channel *efficiency* (fraction
of peak bandwidth sustained) plus a load-balance term. This module extracts
those efficiencies from short engine runs (cached) and exposes closed-form
service times. Tests cross-validate analytic vs engine on overlapping
regimes (tests/test_core_memory.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from . import sched as eng
from .address_map import AddressMap, channel_bytes
from .timing import HBM4Timing, MemSystemConfig, hbm4_config, rome_config


@dataclass(frozen=True)
class ChannelEfficiency:
    """Sustained fraction of peak channel bandwidth for bulk streams."""

    read_eff: float
    write_eff: float
    act_per_kb: float        # activations per KB moved (energy model input)
    col_cmds_per_kb: float   # interposer commands per KB
    refpb_per_us: float      # refresh commands per channel-microsecond


@functools.lru_cache(maxsize=None)
def calibrate_hbm4(queue_depth: int = 64, layout: str = "bg_striped",
                   nbytes: int = 1 << 18,
                   max_ref_postpone: int = 32) -> ChannelEfficiency:
    """The baseline is the paper's *well-tuned* FR-FCFS MC: bandwidth-optimal
    address map and pooled/postponed per-bank refresh (max_ref_postpone=32
    reproduces refresh pooling; see EXPERIMENTS.md)."""
    sim = eng.HBM4ChannelSim(queue_depth=queue_depth,
                             max_ref_postpone=max_ref_postpone)
    r = sim.run(eng.sequential_read_txns_hbm4(nbytes, layout=layout))
    peak = sim.g.bandwidth_gbps
    w = eng.HBM4ChannelSim(queue_depth=queue_depth,
                           max_ref_postpone=max_ref_postpone)
    rw = w.run(eng.sequential_read_txns_hbm4(nbytes, layout=layout,
                                             is_write=True))
    kb = nbytes / 1024
    return ChannelEfficiency(
        read_eff=r.bandwidth_gbps / peak,
        write_eff=rw.bandwidth_gbps / peak,
        act_per_kb=r.cmd_counts["ACT"] / kb,
        col_cmds_per_kb=(r.cmd_counts["RD"] + r.cmd_counts["WR"]) / kb,
        refpb_per_us=r.cmd_counts["REFpb"] / (r.total_ns / 1000.0),
    )


@functools.lru_cache(maxsize=None)
def calibrate_rome(queue_depth: int = 2,
                   nbytes: int = 1 << 20) -> ChannelEfficiency:
    sim = eng.RoMeChannelSim(queue_depth=queue_depth)
    r = sim.run(eng.sequential_read_txns_rome(nbytes))
    peak = sim.g.bandwidth_gbps
    w = eng.RoMeChannelSim(queue_depth=queue_depth)
    rw = w.run(eng.sequential_read_txns_rome(nbytes, is_write=True))
    kb = nbytes / 1024
    return ChannelEfficiency(
        read_eff=r.bandwidth_gbps / peak,
        write_eff=rw.bandwidth_gbps / peak,
        act_per_kb=r.cmd_counts["ACT"] / kb,
        col_cmds_per_kb=r.cmd_counts["row_commands"] / kb,
        refpb_per_us=r.cmd_counts["REFpb"] / (r.total_ns / 1000.0),
    )


def calibrate(cfg: MemSystemConfig) -> ChannelEfficiency:
    if cfg.name == "rome":
        return calibrate_rome(queue_depth=min(cfg.request_queue_depth, 4))
    return calibrate_hbm4(queue_depth=cfg.request_queue_depth)


# ---------------------------------------------------------------------------
# Closed-form service times
# ---------------------------------------------------------------------------

def transfer_time_ns(extents, cfg: MemSystemConfig,
                     amap: AddressMap, is_write: bool = False,
                     eff: ChannelEfficiency | None = None,
                     act_inflation: float = 1.0) -> float:
    """Service time for a set of (addr, nbytes) extents on the full system.

    ``extents`` is either a plain ``[(addr, nbytes)]`` list (one kind,
    selected by ``is_write``) or an :class:`repro.workloads.ExtentStream`,
    in which case reads and writes are timed separately at their own
    calibrated efficiencies and summed (see :func:`stream_time_ns`).

    Completion is gated by the most-loaded channel (LBR effect, Fig 13);
    each channel streams at `eff` fraction of peak. `act_inflation`
    multiplies the calibrated ACT rate for interleaved-stream row conflicts
    (conventional MC only; RoMe's ACT count is structural): the gating
    channel's time is the max of its column-bus time and its row-command
    (ACT) time, so once re-activations push the ACT rate past the row bus's
    issue capacity the transfer becomes ACT-bound. Pass the measured
    multiplier from :func:`repro.perfmodel.energy_model.act_inflation`
    (ACT/KB relative to the 1/KB structural minimum) — the same curve that
    drives the Fig 14 energy accounting.

    Cross-validated at the extent level against
    :class:`repro.core.system_sim.SystemSim` in tests/test_core_memory.py
    (bulk one-kind) and benchmarks/engine_xval.py (mixed streams).
    """
    if hasattr(extents, "records"):          # ExtentStream (duck-typed)
        if is_write:
            raise ValueError(
                "is_write does not apply to an ExtentStream — the "
                "records carry their own kind; build write records "
                "instead of passing is_write=True")
        return stream_time_ns(extents, cfg, amap, eff=eff,
                              act_inflation=act_inflation)
    eff = eff or calibrate(cfg)
    e = eff.write_eff if is_write else eff.read_eff
    per_ch = channel_bytes(amap, extents)
    max_bytes = float(per_ch.max()) if len(per_ch) else 0.0
    if max_bytes == 0.0:
        return 0.0
    bw = cfg.channel_bw_gbps * e                       # GB/s == B/ns
    # RoMe moves whole rows: round the gating channel's bytes up to rows.
    if cfg.ag_mc_bytes >= cfg.row_bytes:
        rows = np.ceil(max_bytes / cfg.row_bytes)
        max_bytes = float(rows) * cfg.row_bytes
        return max_bytes / bw
    col_ns = max_bytes / bw
    if act_inflation > 1.0:
        # Row-command-path roofline: each PC sustains one ACT per
        # max(tRRDS, tFAW/4); inflated ACT counts saturate that before the
        # column bus once streams interleave heavily (cf. the measured
        # act_inflation_curve and Fig 14).
        t = HBM4Timing()
        n_acts = eff.act_per_kb * act_inflation * (max_bytes / 1024.0)
        act_slot_ns = max(t.tRRDS, t.tFAW / 4.0)
        pcs = cfg.geometry.channel.pseudo_channels
        act_ns = n_acts * act_slot_ns / pcs
        return max(col_ns, act_ns)
    return col_ns


def stream_time_ns(stream, cfg: MemSystemConfig, amap: AddressMap,
                   eff: ChannelEfficiency | None = None,
                   act_inflation: float = 1.0) -> float:
    """Closed-form service time of a mixed read/write
    :class:`repro.workloads.ExtentStream`.

    Reads and writes are timed separately at their calibrated
    efficiencies and summed — the column bus serializes the two kinds,
    and the calibration already folds steady-state turnaround costs into
    ``write_eff``. Arrival times are ignored: this is the *service* time,
    valid when the stream keeps the system busy (the regime the TPOT
    model claims). The ACT-inflation roofline applies to the read path
    (conventional MC only), exactly as in :func:`transfer_time_ns`.
    """
    eff = eff or calibrate(cfg)
    reads = stream.extents("read")
    writes = stream.extents("write")
    t = 0.0
    if reads:
        t += transfer_time_ns(reads, cfg, amap, is_write=False, eff=eff,
                              act_inflation=act_inflation)
    if writes:
        t += transfer_time_ns(writes, cfg, amap, is_write=True, eff=eff)
    return t


def stream_bandwidth_gbps(cfg: MemSystemConfig, n_cubes: int = 8,
                          eff: ChannelEfficiency | None = None,
                          is_write: bool = False) -> float:
    """Aggregate sustained bandwidth for a perfectly balanced stream."""
    eff = eff or calibrate(cfg)
    e = eff.write_eff if is_write else eff.read_eff
    return cfg.cube_bw_gbps * n_cubes * e


def act_count(cfg: MemSystemConfig, nbytes: int,
              act_inflation: float = 1.0) -> float:
    """Activation count for `nbytes`: structural minimum for RoMe
    (2 ACTs / 4 KB row), inflated open-page count for HBM4."""
    if cfg.name == "rome":
        return 2.0 * np.ceil(nbytes / cfg.row_bytes)
    base = nbytes / 1024.0          # one ACT per 1 KB bank row minimum
    return base * act_inflation


__all__ = [
    "ChannelEfficiency", "calibrate", "calibrate_hbm4", "calibrate_rome",
    "transfer_time_ns", "stream_time_ns", "stream_bandwidth_gbps",
    "act_count", "hbm4_config", "rome_config",
]
