# The paper's primary contribution: the RoMe row-granularity memory system —
# timing/geometry (Tables II/III/V), the VBA design space (Figs 7-8), the
# logic-die command generator (Figs 9-10), cycle-level controller models for
# conventional HBM4 and RoMe (Fig 4 / Fig 11), the calibrated analytic
# service-time model, address mapping / load balance (Fig 13), and the
# energy & area models (§VI-C).
from .address_map import (AddressMap, channel_bytes, load_balance_ratio,
                          make_address_map)
from .analytic import (ChannelEfficiency, act_count, calibrate,
                       stream_bandwidth_gbps, transfer_time_ns)
from .command_generator import (CommandGenerator, command_issue_latency_ns,
                                extra_channels, freed_pins_per_channel,
                                min_ca_pins, min_required_interval_ns)
from .energy import EnergyBreakdown, EnergyParams, hbm4_energy, rome_energy
from .mc import (MCComplexity, complexity_of_policy,
                 conventional_mc_complexity, max_concurrent_refreshing,
                 registry_census, rome_mc_complexity)
from .sched import (ChannelSimCore, FRFCFSOpenPagePolicy,
                    FRFCFSWriteDrainPolicy, HBM4ChannelSim,
                    HBM4ClosedPagePolicy, HBM4ClosedPageChannelSim,
                    HBM4SIDGroupChannelSim, HBM4SIDGroupPolicy,
                    HBM4WriteDrainChannelSim, PolicySpec, RoMeChannelSim,
                    RoMeRowPolicy, SchedulerPolicy, SimResult, Txn,
                    interleaved_stream_txns_hbm4, make_channel_sim,
                    policy_names, policy_spec, register_policy,
                    registered_policies, sequential_read_txns_hbm4,
                    sequential_read_txns_rome)
from .system_sim import (SystemResult, SystemSim, WarmRunState,
                         bulk_stream_extents)
from .timing import (ChannelGeometry, CubeGeometry, HBM4Timing,
                     MemSystemConfig, RoMeTiming, hbm4_config, rome_config)
from .vba import ADOPTED, ALL_VBA_CONFIGS, BankMode, PCMode, VBAConfig

__all__ = [
    "AddressMap", "channel_bytes", "load_balance_ratio", "make_address_map",
    "ChannelEfficiency", "act_count", "calibrate", "stream_bandwidth_gbps",
    "transfer_time_ns",
    "CommandGenerator", "command_issue_latency_ns", "extra_channels",
    "freed_pins_per_channel", "min_ca_pins", "min_required_interval_ns",
    "EnergyBreakdown", "EnergyParams", "hbm4_energy", "rome_energy",
    "ChannelSimCore", "SchedulerPolicy", "FRFCFSOpenPagePolicy",
    "FRFCFSWriteDrainPolicy", "HBM4ClosedPagePolicy", "HBM4SIDGroupPolicy",
    "RoMeRowPolicy", "make_channel_sim",
    "HBM4ChannelSim", "HBM4ClosedPageChannelSim", "HBM4WriteDrainChannelSim",
    "HBM4SIDGroupChannelSim", "RoMeChannelSim",
    "PolicySpec", "register_policy", "policy_spec", "policy_names",
    "registered_policies",
    "SimResult", "Txn",
    "sequential_read_txns_hbm4", "sequential_read_txns_rome",
    "interleaved_stream_txns_hbm4",
    "SystemSim", "SystemResult", "WarmRunState", "bulk_stream_extents",
    "MCComplexity", "complexity_of_policy", "conventional_mc_complexity",
    "max_concurrent_refreshing", "registry_census", "rome_mc_complexity",
    "ChannelGeometry", "CubeGeometry", "HBM4Timing", "MemSystemConfig",
    "RoMeTiming", "hbm4_config", "rome_config",
    "ADOPTED", "ALL_VBA_CONFIGS", "BankMode", "PCMode", "VBAConfig",
]
