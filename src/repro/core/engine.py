"""Event-driven per-channel cycle-level simulator (Ramulator-lite).

Two controller models share a common transaction format:

* :class:`HBM4ChannelSim` — conventional MC: FR-FCFS over a bounded CAM
  request queue, open-page policy, 7-state bank FSM semantics, bank-group /
  pseudo-channel interleaving, tFAW/tRRD/tCCD/turnaround constraints,
  rotating per-bank refresh.
* :class:`RoMeChannelSim` — the paper's MC: three commands (RD_row, WR_row,
  REF), 4-state VBA FSM, oldest-first VBA interleaving, a queue of depth 2-4,
  VBA-paired refresh (§V-B). All intra-row sequencing is delegated to the
  command generator (statically timed), so the sim only enforces the ten
  Table III row-to-row gaps.

The engine is used for µbenchmarks (Fig 9/10 validation, queue-depth sweep,
VBA design space) and to calibrate the vectorized analytic model used by the
TPOT reproduction. Transactions are one AG_MC unit each (32 B vs 4 KB).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from itertools import islice

import numpy as np

from .command_generator import CommandGenerator
from .timing import ChannelGeometry, HBM4Timing, RoMeTiming


@dataclass
class Txn:
    """One memory transaction at MC access granularity."""

    arrival_ns: float
    bank: int           # flat bank id within the channel (HBM4) / VBA id (RoMe)
    row: int
    col: int = 0        # column index within the row (HBM4 only)
    is_write: bool = False
    sid: int = 0        # stack id (rank)
    stream: int = 0     # software stream tag (for stats only)


@dataclass
class SimResult:
    finish_ns: np.ndarray          # completion time per txn (input order)
    total_ns: float                # makespan
    bytes_moved: int
    cmd_counts: dict = field(default_factory=dict)  # ACT/RD/WR/PRE/REF/row cmds

    @property
    def bandwidth_gbps(self) -> float:
        if self.total_ns <= 0:
            return 0.0
        return self.bytes_moved / self.total_ns  # B/ns == GB/s


class _PendingQueue:
    """Arrival-ordered outstanding transactions with O(1) dequeue.

    ``list.remove`` made every dequeue O(n) worst-case in the number of
    outstanding transactions — and, because it matches by dataclass
    equality, it removed the *wrong object* when two field-identical
    transactions were in flight (one got serviced twice, the other
    never). Removal here is by identity: tombstone the slot via an
    id->slot map, with a head cursor that skips tombstones. The scheduler
    only removes transactions inside the first ``queue_depth`` live
    entries, so at most ``queue_depth`` interior tombstones exist at any
    time and every window scan is O(queue_depth); with no interior
    tombstones (the common head-of-queue dequeue) the window is a plain
    list slice."""

    __slots__ = ("_slots", "_pos", "_head", "_n", "_tomb")

    def __init__(self, txns: list):
        self._slots = list(txns)
        self._pos = {id(tx): i for i, tx in enumerate(self._slots)}
        if len(self._pos) != len(self._slots):
            raise ValueError(
                "trace contains the same Txn object more than once; pass "
                "distinct Txn instances (field-identical copies are fine)")
        self._head = 0
        self._n = len(self._slots)
        self._tomb = 0                 # tombstones at index >= _head

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0

    def _skip_tombstones(self) -> None:
        slots, h = self._slots, self._head
        while h < len(slots) and slots[h] is None:
            h += 1
            self._tomb -= 1
        self._head = h

    def head(self) -> Txn:
        """Oldest outstanding transaction."""
        self._skip_tombstones()
        return self._slots[self._head]

    def first(self, depth: int) -> list:
        """The scheduler window: up to `depth` oldest live transactions."""
        self._skip_tombstones()
        slots, h, tomb = self._slots, self._head, self._tomb
        if tomb == 0:
            return slots[h:h + depth]
        # Every tombstone index t satisfies t < h + depth + tomb (removals
        # only happen inside the window), so this slice is guaranteed to
        # contain the full window; filter/islice keep the scan in C.
        return list(islice(filter(None, slots[h:h + depth + tomb]), depth))

    def remove(self, tx: Txn) -> None:
        self._slots[self._pos.pop(id(tx))] = None
        self._n -= 1
        self._tomb += 1


# ===========================================================================
# Conventional HBM4 channel
# ===========================================================================

class _BankState:
    __slots__ = ("open_row", "t_act", "t_last_rd", "t_last_wr_data",
                 "t_rp_done", "t_ref_done")

    def __init__(self) -> None:
        self.open_row: int | None = None
        self.t_act = -1e18
        self.t_last_rd = -1e18
        self.t_last_wr_data = -1e18
        self.t_rp_done = 0.0
        self.t_ref_done = 0.0


class HBM4ChannelSim:
    """One HBM4 channel = 2 pseudo channels, simulated jointly.

    Each PC owns half the DQ pins and its own banks; the two PCs share C/A
    but we assume C/A is never the bottleneck for the baseline (it has 18
    pins). Bank ids 0..127: pc = bank // 64, bank group = (bank % 64) // 4.
    """

    def __init__(self, timing: HBM4Timing | None = None,
                 geometry: ChannelGeometry | None = None,
                 queue_depth: int = 64,
                 refresh: bool = True,
                 max_ref_postpone: int = 8):
        self.t = timing or HBM4Timing()
        self.g = geometry or ChannelGeometry()
        self.queue_depth = queue_depth
        self.refresh = refresh
        self.max_ref_postpone = max_ref_postpone
        self.banks_per_pc = self.g.banks_per_pc
        self.n_banks = self.g.banks_per_channel
        self.burst_ns = self.g.burst_ns  # 32 B over one PC's pins

    # -- helpers ---------------------------------------------------------------

    def _bg(self, bank: int) -> int:
        return (bank % self.banks_per_pc) // self.g.banks_per_group

    def _pc(self, bank: int) -> int:
        return bank // self.banks_per_pc

    # -- main loop ---------------------------------------------------------

    def run(self, txns: list[Txn]) -> SimResult:
        t = self.t
        order = sorted(range(len(txns)), key=lambda i: txns[i].arrival_ns)
        ordered = [txns[i] for i in order]
        idx_in_finish = {id(tx): order[k] for k, tx in enumerate(ordered)}
        pending = _PendingQueue(ordered)
        finish = np.zeros(len(txns))
        banks = [_BankState() for _ in range(self.n_banks)]
        # Per-PC shared resources.
        pc_bus_free = [0.0, 0.0]              # DQ bus next-free
        pc_last_burst = [-1e18, -1e18]        # last RD/WR cmd time (tCCDS)
        pc_last_burst_bg = [dict(), dict()]   # bg -> last cmd time (tCCDL)
        pc_last_burst_sid = [dict(), dict()]  # sid -> last cmd time (tCCDR)
        pc_last_was_write = [False, False]
        pc_last_rd_cmd = [-1e18, -1e18]
        pc_last_wr_data_end = [-1e18, -1e18]
        pc_act_times = [[], []]               # for tFAW (per PC)
        pc_last_act = [-1e18, -1e18]          # tRRDS
        pc_last_act_bg = [dict(), dict()]     # tRRDL
        counts = {"ACT": 0, "RD": 0, "WR": 0, "PRE": 0, "REFpb": 0,
                  "ca_commands": 0, "ref_backlog_max": 0}
        # Rotating per-bank refresh.
        next_ref_t = t.tREFIpb
        next_ref_bank = 0
        now = 0.0

        def act_ready(bank_id: int, b: _BankState, at: float) -> float:
            pc = self._pc(bank_id)
            bg = self._bg(bank_id)
            r = max(at, b.t_rp_done, b.t_ref_done,
                    pc_last_act[pc] + t.tRRDS,
                    pc_last_act_bg[pc].get(bg, -1e18) + t.tRRDL)
            acts = pc_act_times[pc]
            if len(acts) >= 4:
                r = max(r, acts[-4] + t.tFAW)
            return r

        def col_ready(bank_id: int, b: _BankState, is_write: bool,
                      at: float) -> float:
            pc = self._pc(bank_id)
            bg = self._bg(bank_id)
            trcd = t.tRCDWR if is_write else t.tRCDRD
            r = max(at, b.t_act + trcd, b.t_ref_done,
                    pc_last_burst[pc] + t.tCCDS,
                    pc_last_burst_bg[pc].get(bg, -1e18) + t.tCCDL)
            if is_write and not pc_last_was_write[pc]:
                r = max(r, pc_last_rd_cmd[pc] + t.tRTW)
            if not is_write and pc_last_was_write[pc]:
                r = max(r, pc_last_wr_data_end[pc] + t.tWTRS)
            return r

        def pre_ready(b: _BankState, at: float) -> float:
            return max(at, b.t_act + t.tRAS, b.t_last_rd + t.tRTP,
                       b.t_last_wr_data + t.tWR)

        ref_backlog = 0

        while pending:
            qwin = pending.first(self.queue_depth)

            # -- refresh: rotating REFpb with demand-aware postponement.
            # A REFpb due for a bank with queued demand is postponed (JEDEC
            # allows bounded postponement); once the backlog hits the cap it
            # is forced regardless. Each issue is anchored at its own due
            # time so refreshes of different banks may overlap. ---------------
            while self.refresh and next_ref_t <= now:
                ref_backlog += 1
                next_ref_t += t.tREFIpb
            counts["ref_backlog_max"] = max(counts["ref_backlog_max"],
                                            ref_backlog)
            while ref_backlog > 0:
                demanded = any(tx.bank == next_ref_bank for tx in qwin)
                if demanded and ref_backlog < self.max_ref_postpone:
                    break
                b = banks[next_ref_bank]
                due = next_ref_t - ref_backlog * t.tREFIpb
                start = max(due, b.t_rp_done, b.t_ref_done)
                if b.open_row is not None:
                    pr = pre_ready(b, start)
                    b.t_rp_done = pr + t.tRP
                    b.open_row = None
                    counts["PRE"] += 1
                    start = b.t_rp_done
                b.t_ref_done = start + t.tRFCpb
                counts["REFpb"] += 1
                next_ref_bank = (next_ref_bank + 1) % self.n_banks
                ref_backlog -= 1

            # -- FR-FCFS over the queue window ---------------------------------
            window = [tx for tx in qwin if tx.arrival_ns <= now]
            if not window:
                # Idle: jump to the next event — arrival OR refresh due —
                # so refreshes due during a sparse-arrival gap are issued
                # in the gap (bounded postponement) instead of piling up
                # behind the next arrival.
                cand = pending.head().arrival_ns
                if self.refresh:
                    cand = min(cand, next_ref_t)
                now = max(now + 1e-9, cand)
                continue

            issued = False

            # Row-bus work (runs concurrently with the column bus): progress
            # the oldest row-miss whose bank's open row is no longer needed by
            # any queued hit. This is what deep queues buy the conventional
            # MC — lookahead to overlap ACT/PRE of upcoming rows with the
            # bursts of the current ones.
            prepared: set[int] = set()
            for tx in window:
                b = banks[tx.bank]
                if b.open_row == tx.row or tx.bank in prepared:
                    continue
                if b.open_row is not None:
                    # Keep a row open while queued hits still target it.
                    if any(h.bank == tx.bank and h.row == b.open_row
                           for h in window):
                        prepared.add(tx.bank)
                        continue
                    pr = pre_ready(b, max(tx.arrival_ns, b.t_ref_done))
                    b.t_rp_done = pr + t.tRP
                    b.open_row = None
                    counts["PRE"] += 1
                    counts["ca_commands"] += 1
                    now = max(now, pr)
                else:
                    ar = act_ready(tx.bank, b,
                                   max(tx.arrival_ns, b.t_ref_done))
                    pc = self._pc(tx.bank)
                    bg = self._bg(tx.bank)
                    b.t_act = ar
                    b.open_row = tx.row
                    pc_last_act[pc] = ar
                    pc_last_act_bg[pc][bg] = ar
                    pc_act_times[pc].append(ar)
                    if len(pc_act_times[pc]) > 8:
                        pc_act_times[pc] = pc_act_times[pc][-8:]
                    counts["ACT"] += 1
                    counts["ca_commands"] += 1
                    now = max(now, ar)
                prepared.add(tx.bank)
                issued = True

            # Column-bus work: earliest-ready row hit (FR), oldest on ties.
            # Issue times are governed by per-resource clocks (bank readiness,
            # per-PC burst spacing, DQ bus) — the column C/A path sustains one
            # command per PC per tCCDS, so a pick may legally land before
            # `now` (commands ride independent buses).
            best = None
            best_t = None
            for tx in window:
                b = banks[tx.bank]
                if b.open_row == tx.row and b.t_act <= 1e17:
                    r = col_ready(tx.bank, b, tx.is_write, tx.arrival_ns)
                    if best_t is None or r < best_t - 1e-12:
                        best, best_t = tx, r
            if best is not None:
                tx, r = best, best_t
                b = banks[tx.bank]
                pc = self._pc(tx.bank)
                bg = self._bg(tx.bank)
                lat = t.tCWL if tx.is_write else t.tCL
                data_start = max(r + lat, pc_bus_free[pc])
                # If the bus is the constraint, push the command time too.
                cmd_t = data_start - lat
                data_end = data_start + self.burst_ns
                pc_bus_free[pc] = data_end
                pc_last_burst[pc] = cmd_t
                pc_last_burst_bg[pc][bg] = cmd_t
                pc_last_burst_sid[pc][tx.sid] = cmd_t
                pc_last_was_write[pc] = tx.is_write
                counts["ca_commands"] += 1
                if tx.is_write:
                    b.t_last_wr_data = data_end
                    pc_last_wr_data_end[pc] = data_end
                    counts["WR"] += 1
                else:
                    b.t_last_rd = cmd_t
                    pc_last_rd_cmd[pc] = cmd_t
                    counts["RD"] += 1
                finish[idx_in_finish[id(tx)]] = data_end
                pending.remove(tx)
                now = max(now, cmd_t)
                issued = True

            if not issued:
                # Nothing issueable: jump to the next event (refresh or
                # arrival) to guarantee progress.
                nxt = [tx.arrival_ns for tx in qwin if tx.arrival_ns > now]
                cand = min(nxt) if nxt else now + t.tREFIpb
                if self.refresh:
                    cand = min(cand, next_ref_t)
                now = max(now + 1e-9, cand)

        bytes_moved = len(txns) * self.g.col_bytes
        return SimResult(finish, float(finish.max(initial=0.0)), bytes_moved,
                         counts)


# ===========================================================================
# RoMe channel
# ===========================================================================

class RoMeChannelSim:
    """RoMe MC + command generator for one channel (§V-A).

    Queue of depth `queue_depth` (default 2 — the paper's saturation point),
    oldest-first with VBA interleaving: avoid back-to-back commands to the
    same VBA when another ready request exists. The Table III gaps are the
    only timing state; per-VBA busy-until and refresh-until complete the
    4-state FSM (Idle / Reading / Writing / Refreshing).
    """

    def __init__(self, timing: RoMeTiming | None = None,
                 geometry: ChannelGeometry | None = None,
                 n_vbas: int = 16,
                 queue_depth: int = 2,
                 refresh: bool = True,
                 max_ref_postpone: int = 8):
        self.t = timing or RoMeTiming()
        self.g = geometry or ChannelGeometry()
        self.n_vbas = n_vbas
        self.queue_depth = queue_depth
        self.refresh = refresh
        self.max_ref_postpone = max_ref_postpone
        self.row_bytes = self.g.row_bytes * 2 * self.g.pseudo_channels  # 4 KB
        self._cg = CommandGenerator()

    def run(self, txns: list[Txn]) -> SimResult:
        t = self.t
        order = sorted(range(len(txns)), key=lambda i: txns[i].arrival_ns)
        ordered = [txns[i] for i in order]
        idx_in_finish = {id(tx): order[k] for k, tx in enumerate(ordered)}
        pending = _PendingQueue(ordered)
        finish = np.zeros(len(txns))

        vba_busy_until = np.zeros(self.n_vbas)   # Reading/Writing/Refreshing
        last_cmd_t = -1e18
        last_cmd_write = False
        last_cmd_vba = -1
        last_cmd_sid = -1
        counts = {"ACT": 0, "RD": 0, "WR": 0, "PRE": 0, "REFpb": 0,
                  "row_commands": 0, "ca_commands": 0, "ref_backlog_max": 0}
        sched_rd = self._cg.expand(is_write=False)
        sched_wr = self._cg.expand(is_write=True)
        bursts = 2 * self._cg.bursts_per_bank()

        # VBA-paired refresh every 2*tREFIpb, rotating (§V-B).
        next_ref_t = 2 * t.tREFIpb
        next_ref_vba = 0
        now = 0.0

        def start_time(tx: Txn, at: float) -> float:
            r = max(at, tx.arrival_ns, vba_busy_until[tx.bank])
            if last_cmd_t > -1e17:
                gap = t.gap_ns(last_cmd_write, tx.is_write,
                               same_vba=(tx.bank == last_cmd_vba),
                               same_sid=(tx.sid == last_cmd_sid))
                r = max(r, last_cmd_t + gap)
            return r

        ref_backlog = 0

        while pending:
            qwin = pending.first(self.queue_depth)

            # VBA-paired refresh, anchored at due time (may overlap across
            # VBAs — the paper's "up to three refreshing simultaneously"),
            # with the same demand-aware bounded postponement as the baseline.
            while self.refresh and next_ref_t <= now:
                ref_backlog += 1
                next_ref_t += 2 * t.tREFIpb
            counts["ref_backlog_max"] = max(counts["ref_backlog_max"],
                                            ref_backlog)
            while ref_backlog > 0:
                demanded = any(tx.bank == next_ref_vba for tx in qwin)
                if demanded and ref_backlog < self.max_ref_postpone:
                    break
                v = next_ref_vba
                due = next_ref_t - ref_backlog * 2 * t.tREFIpb
                start = max(due, vba_busy_until[v])
                vba_busy_until[v] = start + t.tRFCpb + t.tRREFpb
                counts["REFpb"] += 2
                counts["row_commands"] += 1
                counts["ca_commands"] += 1
                next_ref_vba = (next_ref_vba + 1) % self.n_vbas
                ref_backlog -= 1

            window = [tx for tx in qwin if tx.arrival_ns <= now]
            if not window:
                # Idle: jump to the next event — arrival OR refresh due —
                # exactly like the conventional-MC path. Jumping straight to
                # the next arrival would skip refreshes that come due during
                # the gap, postponing them without bound behind the arrival
                # instead of issuing them in the idle window.
                cand = pending.head().arrival_ns
                if self.refresh:
                    cand = min(cand, next_ref_t)
                now = max(now + 1e-9, cand)
                continue

            # Oldest-first with VBA interleaving: prefer a request whose VBA
            # differs from the last-issued one if it is ready no later.
            cands = [(start_time(tx, now), i, tx) for i, tx in enumerate(window)]
            cands.sort(key=lambda c: (c[0], c[1]))
            best_t, _, best = cands[0]
            for ct, _, tx in cands:
                if tx.bank != last_cmd_vba and ct <= best_t + 1e-9:
                    best_t, best = ct, tx
                    break

            sched = sched_wr if best.is_write else sched_rd
            svc = t.tWR_row if best.is_write else t.tRD_row
            vba_busy_until[best.bank] = best_t + svc
            last_cmd_t = best_t
            last_cmd_write = best.is_write
            last_cmd_vba = best.bank
            last_cmd_sid = best.sid
            counts["ACT"] += 2
            counts["PRE"] += 2
            counts["WR" if best.is_write else "RD"] += bursts
            counts["row_commands"] += 1
            counts["ca_commands"] += 1
            finish[idx_in_finish[id(best)]] = best_t + sched.last_data_ns
            pending.remove(best)
            now = max(now, best_t)

        bytes_moved = len(txns) * self.row_bytes
        return SimResult(finish, float(finish.max(initial=0.0)), bytes_moved,
                         counts)


# ===========================================================================
# Trace helpers
# ===========================================================================

def sequential_read_txns_hbm4(nbytes: int, geometry: ChannelGeometry | None = None,
                              arrival_ns: float = 0.0,
                              is_write: bool = False,
                              layout: str = "bg_striped") -> list[Txn]:
    """Channel-local sequential stream decomposed into 32 B column txns.

    ``layout`` selects the address map within the channel:

    * ``"bg_striped"`` — consecutive 32 B units alternate pseudo channels,
      then rotate bank groups (so bursts mesh at tCCDS, not tCCDL), then fill
      columns of a row; banks within a bank group ping-pong across row
      boundaries to hide tRC. This is the bandwidth-maximizing sweep winner
      (§VI-A) and needs only modest queue lookahead.
    * ``"row_linear"`` — consecutive units fill one bank's row before moving
      to the next bank group's row (page-interleaved map, classic open-page
      streaming). A single row drains at tCCDL (half rate); saturation
      *requires* the scheduler to interleave bursts from ≥2 open rows in
      different bank groups, i.e. a queue that spans multiple rows — this is
      the regime behind the paper's "HBM4 requires ≥45 entries" claim.
    """
    g = geometry or ChannelGeometry()
    txns: list[Txn] = []
    n_units = nbytes // g.col_bytes
    nbg = g.bank_groups
    cols = g.cols_per_row
    for u in range(n_units):
        pc = u % g.pseudo_channels
        j = u // g.pseudo_channels          # unit index within the PC
        if layout == "bg_striped":
            bg = j % nbg
            k = j // nbg                    # burst index within this BG's stream
            col = k % cols
            rseq = k // cols                # row sequence number within BG
        elif layout == "row_linear":
            col = j % cols
            rrun = j // cols                # consecutive rows
            bg = rrun % nbg
            rseq = rrun // nbg
        else:
            raise ValueError(f"unknown layout {layout!r}")
        bank_in_bg = rseq % g.banks_per_group
        row = rseq // g.banks_per_group
        bank = pc * g.banks_per_pc + bg * g.banks_per_group + bank_in_bg
        txns.append(Txn(arrival_ns, bank=bank, row=row, col=col,
                        is_write=is_write))
    return txns


def sequential_read_txns_rome(nbytes: int, n_vbas: int = 16,
                              arrival_ns: float = 0.0,
                              is_write: bool = False,
                              row_bytes: int = 4096) -> list[Txn]:
    """Channel-local sequential stream as 4 KB row transactions striped
    across VBAs."""
    n_rows = (nbytes + row_bytes - 1) // row_bytes
    return [Txn(arrival_ns, bank=r % n_vbas, row=r // n_vbas,
                is_write=is_write) for r in range(n_rows)]


def interleaved_stream_txns_hbm4(n_streams: int, nbytes_each: int,
                                 geometry: ChannelGeometry | None = None,
                                 seed: int = 0) -> list[Txn]:
    """N concurrent sequential streams interleaved round-robin at 32 B
    granularity (as concurrent GEMM operands / expert streams arrive at the
    MC). Each stream is row_linear with its own bank/row phase. This is the
    ACT-inflation workload: with many streams the per-stream queue window
    shrinks below a row's 32 columns, so rows are served in several visits
    and intervening same-bank activity forces re-activations — the effect
    RoMe eliminates structurally (one RD_row = whole row, §VI-C / Fig 14).
    """
    g = geometry or ChannelGeometry()
    rng = np.random.default_rng(seed)
    streams = []
    for s in range(n_streams):
        txns = sequential_read_txns_hbm4(nbytes_each, g, layout="row_linear")
        # random bank-group/bank/row phase per stream
        bank_off = int(rng.integers(0, g.banks_per_channel))
        row_off = int(rng.integers(0, 1 << 12))
        for t in txns:
            t.bank = (t.bank + bank_off) % g.banks_per_channel
            t.row = t.row + row_off
            t.stream = s
        streams.append(txns)
    out: list[Txn] = []
    for i in range(max(len(s) for s in streams)):
        for s in streams:
            if i < len(s):
                out.append(s[i])
    return out
