"""Compatibility facade over :mod:`repro.core.sched`.

The cycle-level channel simulators used to live here as two
hand-duplicated ~130-line ``run()`` loops; they are now a single shared
event loop (:class:`repro.core.sched.ChannelSimCore`) driven by pluggable
:class:`~repro.core.sched.SchedulerPolicy` implementations:

* :class:`HBM4ChannelSim` — ``FRFCFSOpenPagePolicy``: FR-FCFS over a
  bounded CAM request queue, open-page policy, 7-state bank FSM semantics,
  bank-group / pseudo-channel interleaving, tFAW/tRRD/tCCD (incl. the
  cross-SID tCCDR) and turnaround constraints, rotating per-bank refresh.
  ``page_policy="closed"`` selects the auto-precharge variant.
* :class:`RoMeChannelSim` — ``RoMeRowPolicy``: three commands (RD_row,
  WR_row, REF), 4-state VBA FSM, oldest-first VBA interleaving, a queue of
  depth 2-4, VBA-paired refresh (§V-B). Intra-row sequencing is delegated
  to the statically-timed command generator, so the policy only enforces
  the ten Table III row-to-row gaps.

This module re-exports the whole legacy surface (sims, ``Txn``,
``SimResult``, ``_PendingQueue``, trace helpers) so existing imports keep
working unchanged; new code should import from :mod:`repro.core.sched`
(policies, factory, introspection) and :mod:`repro.core.system_sim`
(multi-channel extent-level runs). The engine backs the µbenchmarks
(Fig 9/10 validation, queue-depth sweep, VBA design space) and calibrates
the vectorized analytic model used by the TPOT reproduction.
"""
from __future__ import annotations

from .sched import (ChannelSimCore, FRFCFSOpenPagePolicy,
                    FRFCFSWriteDrainPolicy, HBM4ChannelSim,
                    HBM4ClosedPagePolicy, HBM4ClosedPageChannelSim,
                    HBM4SIDGroupChannelSim, HBM4SIDGroupPolicy,
                    HBM4WriteDrainChannelSim, RoMeChannelSim, RoMeRowPolicy,
                    SchedulerPolicy, SimResult, Txn, _PendingQueue,
                    hbm4_unit_location, interleaved_stream_txns_hbm4,
                    make_channel_sim, sequential_read_txns_hbm4,
                    sequential_read_txns_rome)

__all__ = [
    "ChannelSimCore", "SchedulerPolicy", "FRFCFSOpenPagePolicy",
    "FRFCFSWriteDrainPolicy", "HBM4ClosedPagePolicy", "HBM4SIDGroupPolicy",
    "RoMeRowPolicy",
    "HBM4ChannelSim", "HBM4ClosedPageChannelSim", "HBM4WriteDrainChannelSim",
    "HBM4SIDGroupChannelSim", "RoMeChannelSim",
    "make_channel_sim", "SimResult", "Txn",
    "hbm4_unit_location", "interleaved_stream_txns_hbm4",
    "sequential_read_txns_hbm4", "sequential_read_txns_rome",
]
