"""Memory-controller complexity comparison (paper Table IV / §V-A).

Structural facts about the two MC architectures, used by the complexity
benchmark and asserted in tests. The cycle-accurate behaviour lives in
:mod:`repro.core.sched`; since the refactor a policy reports its own
hardware census via ``SchedulerPolicy.state_footprint()``, and
:func:`complexity_of_policy` turns that into an :class:`MCComplexity` —
so the Table IV numbers are read out of the code that *is* the scheduler
(benchmarks/tab_mc_complexity.py cross-checks both sources).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .sched import SchedulerPolicy
from .timing import HBM4_BANK_STATES, ROME_BANK_STATES, HBM4Timing, RoMeTiming


@dataclass(frozen=True)
class MCComplexity:
    name: str
    n_timing_params: int
    n_bank_fsms: int              # FSM instances the scheduler tracks
    n_bank_states: int            # states per FSM
    page_policy: str
    scheduling: tuple
    request_queue_depth: int
    #: Extra hardware a policy variant carries beyond the bank FSMs
    #: (write-drain comparators, per-PC SID registers, ...). Empty for
    #: the two paper rows; populated from ``state_footprint()["aux_state"]``
    #: so the extended Table IV census stays honest about what each
    #: design-space point adds.
    aux_state: tuple = ()


def conventional_mc_complexity(banks_per_pc: int = 64) -> MCComplexity:
    return MCComplexity(
        name="hbm4",
        n_timing_params=HBM4Timing().n_managed(),      # 15
        n_bank_fsms=banks_per_pc,                      # one per bank per PC
        n_bank_states=len(HBM4_BANK_STATES),           # 7
        page_policy="open",
        scheduling=("row-buffer locality", "bank group interleaving",
                    "PC interleaving"),
        request_queue_depth=64,
    )


def rome_mc_complexity() -> MCComplexity:
    """RoMe (§V-A): two VBAs operating + up to three refreshing => 5 FSMs;
    4 states; 10 timing parameters; no page policy; queue depth 2 suffices
    for peak throughput (4 provisioned in the area study)."""
    return MCComplexity(
        name="rome",
        n_timing_params=RoMeTiming().n_managed(),      # 10
        n_bank_fsms=5,
        n_bank_states=len(ROME_BANK_STATES),           # 4
        page_policy="none (always precharge after row access)",
        scheduling=("VBA interleaving",),
        request_queue_depth=2,
    )


def complexity_of_policy(policy: SchedulerPolicy,
                         request_queue_depth: int) -> MCComplexity:
    """Build the Table IV row directly from a scheduler policy's
    introspected state footprint."""
    fp = policy.state_footprint()
    return MCComplexity(
        name=fp["name"],
        n_timing_params=fp["timing_params"],
        n_bank_fsms=fp["fsm_instances"],
        n_bank_states=fp["states_per_fsm"],
        page_policy=fp["page_policy"],
        scheduling=tuple(fp["scheduling"]),
        request_queue_depth=request_queue_depth,
        aux_state=tuple(fp.get("aux_state", ())),
    )


def registry_census() -> dict[str, MCComplexity]:
    """Table IV rows for *every* registered scheduling point, read out of
    the policies' own ``state_footprint()`` (benchmarks/policy_sweep.py
    and tab_mc_complexity report this as the extended census)."""
    from .sched import registered_policies
    return {name: complexity_of_policy(spec.make_policy(), spec.queue_depth)
            for name, spec in registered_policies().items()}


def max_concurrent_refreshing(timing: RoMeTiming | None = None) -> int:
    """Refresh-FSM provisioning (§V-A); see
    :meth:`RoMeTiming.max_concurrent_refreshing` for the derivation."""
    return (timing or RoMeTiming()).max_concurrent_refreshing()
