"""Memory-controller complexity comparison (paper Table IV / §V-A).

Structural facts about the two MC architectures, used by the complexity
benchmark and asserted in tests. The cycle-accurate behaviour lives in
:mod:`repro.core.engine`; this module is the architectural census.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .timing import HBM4_BANK_STATES, ROME_BANK_STATES, HBM4Timing, RoMeTiming


@dataclass(frozen=True)
class MCComplexity:
    name: str
    n_timing_params: int
    n_bank_fsms: int              # FSM instances the scheduler tracks
    n_bank_states: int            # states per FSM
    page_policy: str
    scheduling: tuple
    request_queue_depth: int


def conventional_mc_complexity(banks_per_pc: int = 64) -> MCComplexity:
    return MCComplexity(
        name="hbm4",
        n_timing_params=HBM4Timing().n_managed(),      # 15
        n_bank_fsms=banks_per_pc,                      # one per bank per PC
        n_bank_states=len(HBM4_BANK_STATES),           # 7
        page_policy="open",
        scheduling=("row-buffer locality", "bank group interleaving",
                    "PC interleaving"),
        request_queue_depth=64,
    )


def rome_mc_complexity() -> MCComplexity:
    """RoMe (§V-A): two VBAs operating + up to three refreshing => 5 FSMs;
    4 states; 10 timing parameters; no page policy; queue depth 2 suffices
    for peak throughput (4 provisioned in the area study)."""
    return MCComplexity(
        name="rome",
        n_timing_params=RoMeTiming().n_managed(),      # 10
        n_bank_fsms=5,
        n_bank_states=len(ROME_BANK_STATES),           # 4
        page_policy="none (always precharge after row access)",
        scheduling=("VBA interleaving",),
        request_queue_depth=2,
    )


def max_concurrent_refreshing(timing: RoMeTiming | None = None) -> int:
    """Refresh-FSM provisioning (§V-A: 'up to three undergo refresh
    simultaneously'). Steady-state rotation alone needs
    ceil((tRFCpb+tRREFpb)/(2*tREFIpb)) = 2 in-flight; the third FSM covers
    pooled-refresh flushes — when demand-postponed REFpbs drain, the MC
    releases them at tRREFpb spacing but caps in-flight refreshes at 3 so
    an 8-deep pool empties in ~3*(tRFCpb+tRREFpb) < tREFI/4 without
    provisioning a per-VBA FSM."""
    t = timing or RoMeTiming()
    import math
    steady = math.ceil((t.tRFCpb + t.tRREFpb) / (2 * t.tREFIpb))
    return steady + 1
