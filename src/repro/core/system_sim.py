"""Multi-channel system simulator: timed extent streams end to end.

:class:`SystemSim` closes the gap between the single-channel cycle-level
engine and the extent-level analytic model. Its primary entry point is
:meth:`SystemSim.run`, which takes an
:class:`repro.workloads.ExtentStream` — the unified workload currency —
and decomposes every record through
:class:`~repro.core.address_map.AddressMap` into per-channel transaction
streams, honouring each record's kind (read/write), arrival time, and
stream tag (channel selection by stripe rotation; the channel-local
layout is the bandwidth-maximizing map the calibration uses — bg_striped
columns for HBM4, VBA-striped rows for RoMe). Every loaded channel runs
through :class:`~repro.core.sched.ChannelSimCore`; the result reports
per-channel finish times, aggregate bandwidth, and the measured
load-balance ratio. That gives both ``analytic.transfer_time_ns`` and
the TPOT model (``perfmodel.tpot.stream_mem_ns``) a ground-truth
cross-validation path at the extent level (tests/test_core_memory.py,
benchmarks/engine_xval.py). :meth:`run_extents` survives as a thin
wrapper that lifts a homogeneous (addr, nbytes) list into a one-kind
stream.

Channels are independent after address decomposition (no shared resource
is modeled between channels), so they compose by taking the max finish —
exactly the "most-loaded channel gates completion" structure the
analytic model assumes, but measured. That independence also makes the
simulation embarrassingly parallel: ``run(stream, workers=N)`` farms
channels out to a process pool, which is what makes full-cube (32–36
channel) cycle-level runs practical. :meth:`SystemSim.run_steps` extends
that to serving traces: a list of per-step streams simulated either
under per-step **reset** semantics (the default — each step starts on an
idle system, parallel over (step, channel) pairs) or, with
``warm=True``, as one :class:`WarmRunState` session that carries channel
state (open rows, queues, refresh debt) across steps — the contract
chunked-prefill replays need once steps overlap (see the
:meth:`run_steps` docstring and docs/serve_replay.md).
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass, field

import numpy as np

from ..workloads.stream import ExtentRecord, ExtentStream
from .address_map import AddressMap, make_address_map
from .pool import get_pool
from .sched import SimResult, Txn, make_channel_sim
from .sched.channels import CHANNEL_SIM_KINDS
from .sched.traces import hbm4_unit_location, rome_unit_location
from .sched.vectorized import advance_states, run_channels
from .timing import MemSystemConfig

MODES = ("cycle", "analytic", "hybrid")

#: Fraction of the above-threshold queue pressure a warm session carries
#: into the next analytically priced step (see :class:`WarmRunState`):
#: the backlog left at a step boundary is at most the over-threshold
#: excess, and it decays geometrically as later steps absorb it.
WARM_CARRY_FRAC = 0.5


@dataclass
class SystemResult:
    """Outcome of one multi-channel extent-level run."""

    total_ns: float                 # makespan = max finish over channels
    bytes_moved: int                # sum of per-channel bytes (MC granularity)
    channel_bytes: np.ndarray       # bytes per channel (MC granularity)
    channel_finish_ns: np.ndarray   # per-channel makespan (0 for idle)
    channel_results: dict           # channel -> SimResult (loaded channels)
    #: channel -> the exact txn list the channel sim ran, in the input
    #: order its SimResult.finish_ns indexes — so per-txn attribution
    #: (e.g. read latency) never depends on re-running decompose().
    #: Empty for analytically priced runs (no txns are materialized).
    channel_txns: dict = field(default_factory=dict)
    #: how this run was priced: "cycle" (event loop) or "analytic"
    #: (queue-window model) — a hybrid SystemSim stamps each run with
    #: the path it actually took.
    mode: str = "cycle"
    #: modeled queue pressure (queue-window correction / roofline floor);
    #: 0.0 when the classifier did not run (pure cycle mode).
    queue_pressure: float = 0.0

    @property
    def bandwidth_gbps(self) -> float:
        if self.total_ns <= 0:
            return 0.0
        return self.bytes_moved / self.total_ns   # B/ns == GB/s

    @property
    def load_balance_ratio(self) -> float:
        """Measured LBR = mean / max channel bytes (cf. Fig 13)."""
        mx = self.channel_bytes.max(initial=0)
        if mx == 0:
            return 1.0
        return float(self.channel_bytes.mean() / mx)

    @property
    def cmd_counts(self) -> dict:
        out: dict = {}
        for r in self.channel_results.values():
            for k, v in r.cmd_counts.items():
                out[k] = out.get(k, 0) + v
        return out

    @property
    def row_hit_rate(self) -> float:
        """System-wide row-buffer hit rate, ``(RD+WR hits) / column
        commands`` over the summed per-channel command counts
        (:func:`repro.core.sched.counts_row_hit_rate`). 0.0 for
        row-granular (always-precharge) controllers — RoMe has no row
        buffer to hit — and 0.0 on analytically priced runs, which issue
        no commands (``channel_results`` is empty there; check
        :attr:`mode` before reading locality off a hybrid run)."""
        from .sched import counts_row_hit_rate
        return counts_row_hit_rate(self.cmd_counts)


def _run_channel(kind: str, kwargs: dict, txns: list[Txn]) -> SimResult:
    """Simulate one channel — module-level so a process pool can pickle
    the call. Reconstructs the channel sim from its factory spec."""
    return make_channel_sim(kind, **kwargs).run(txns)


class SystemSim:
    """N independent channel sims behind one address map.

    Parameters mirror the single-channel sims; ``n_channels`` (or an
    explicit ``amap``) sets the system width — pass a small count to keep
    serial cycle-level runs tractable, or ``workers=N`` to
    :meth:`run` for full-width systems; the per-channel behaviour is
    identical either way. ``max_ref_postpone`` defaults to 32 (the
    *well-tuned* pooled-refresh MC that the analytic calibration models).

    ``mode`` selects the pricing engine:

    * ``"cycle"`` (default) — every run goes through the per-channel
      event loops (the lockstep vectorized advance in-process, a process
      pool with ``workers > 1``). Ground truth.
    * ``"analytic"`` — every run is priced by the calibrated
      queue-window model (:mod:`repro.core.queue_model`): roofline floor
      plus the fitted per-step/per-txn corrections, O(n_records), no
      transactions materialized. Trustworthy at low queue pressure.
    * ``"hybrid"`` — each run/step is classified by its modeled queue
      pressure: uncontended ones (pressure <= ``pressure_threshold``,
      defaulting to the policy's own *calibrated* cut from the
      queue-window table) are priced analytically, contended ones drop
      into the cycle engine. Runs whose decomposed transaction count would exceed
      ``max_cycle_txns`` are *always* priced analytically — that guard
      is what makes unscaled production traces (GB-scale steps that
      would decompose into millions of transactions) runnable at all.

    ``policy_name`` names the registered :class:`~.sched.PolicySpec`
    whose persisted queue-window calibration the analytic path uses
    (``PolicySpec.system_sim`` threads it automatically); without it the
    family's default point is assumed (``hbm4_frfcfs`` / ``hbm4_closed``
    by page policy, ``rome_qd2``).

    ``check_timing=True`` turns on sanitizer mode: every cycle-path
    channel run emits its command trace and is replayed through the
    independent :mod:`repro.analysis.timing_checker`; any JEDEC/Table III
    protocol violation raises :class:`~repro.analysis.TimingProtocolError`
    (docs/timing_sanitizer.md). Analytically priced runs issue no
    commands, so there is nothing to check on that path.
    """

    def __init__(self, cfg: MemSystemConfig,
                 amap: AddressMap | None = None,
                 n_channels: int | None = None,
                 queue_depth: int | None = None,
                 refresh: bool = True,
                 max_ref_postpone: int = 32,
                 page_policy: str = "open",
                 channel_kind: str | None = None,
                 channel_kwargs: dict | None = None,
                 sids: int = 1,
                 sid_capacity_bytes: int = 64 << 20,
                 mode: str = "cycle",
                 pressure_threshold: float | None = None,
                 max_cycle_txns: int = 500_000,
                 policy_name: str | None = None,
                 check_timing: bool = False):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        self.mode = mode
        self.check_timing = check_timing
        self.max_cycle_txns = max_cycle_txns
        self.policy_name = policy_name
        # None -> the policy's own calibrated cut (resolved lazily with
        # the queue-window params; see QueueWindowParams.pressure_threshold).
        self.pressure_threshold = pressure_threshold
        self._eff = None               # lazy ChannelEfficiency cache
        self._qparams = None           # lazy QueueWindowParams cache
        #: optional :class:`repro.core.queue_model.StepPricer` — when
        #: attached, every feature extraction goes through its signature
        #: memo cache (see :meth:`attach_pricer`).
        self.pricer = None
        #: optional :class:`repro.obs.MetricsProbe` — when attached (see
        #: :meth:`attach_probe`), cycle-path channel sims sample windowed
        #: telemetry and every run/step result is folded into the probe.
        self.probe = None
        self.cfg = cfg
        self.is_rome = cfg.ag_mc_bytes >= cfg.row_bytes
        if channel_kind is not None:
            # The decomposition granularity is set by cfg; a channel kind
            # of the other family would silently mis-shape every txn.
            if (channel_kind == "rome") != self.is_rome:
                raise ValueError(
                    f"channel_kind {channel_kind!r} does not match the "
                    f"{'rome' if self.is_rome else 'hbm4'}-granularity cfg "
                    f"{cfg.name!r}")
        self.channel_kind = channel_kind
        self.channel_kwargs = dict(channel_kwargs or {})
        if sids < 1:
            raise ValueError(f"sids must be >= 1, got {sids}")
        self.sids = sids
        self.sid_capacity_bytes = sid_capacity_bytes
        if amap is None:
            amap = make_address_map(cfg, n_cubes=1)
            if n_channels is not None:
                amap = AddressMap(n_channels=n_channels,
                                  stripe_bytes=amap.stripe_bytes,
                                  banks_per_channel=amap.banks_per_channel,
                                  row_bytes=amap.row_bytes)
        elif n_channels is not None and n_channels != amap.n_channels:
            raise ValueError("pass either amap or n_channels, not both")
        self.amap = amap
        self.queue_depth = (cfg.request_queue_depth if queue_depth is None
                            else queue_depth)
        self.refresh = refresh
        self.max_ref_postpone = max_ref_postpone
        self.page_policy = page_policy

    # -- decomposition -----------------------------------------------------

    def _units_of(self, addr: int, nbytes: int) -> range:
        """Global stripe-unit indices touched by one extent (an extent
        touching any byte of a unit transfers the whole unit — the MC
        access granularity / row-rounding overfetch)."""
        g = self.amap.stripe_bytes
        return range(addr // g, (addr + nbytes - 1) // g + 1)

    def decompose(self, stream: ExtentStream) -> dict[int, list[Txn]]:
        """Per-channel transaction streams for a timed extent stream.

        Each record's units inherit its arrival time, read/write kind,
        and stream tag. Channel selection follows the address map's
        stripe rotation; the channel-local (bank, row, col) placement of
        a unit is a pure function of its channel-local unit index, so
        overlapping extents hit the same locations and contiguous
        extents reproduce the calibration stream on every loaded
        channel. Records are walked in stream (issue) order, so a stream
        sorted by arrival yields arrival-ordered per-channel queues.
        """
        nch = self.amap.n_channels
        geo = self.cfg.geometry.channel
        n_vbas = self.cfg.vbas_per_channel
        per_channel: dict[int, list[Txn]] = {}
        for rec in stream:
            # SID (stack level) from the address region: tenants/buffers
            # in different stack levels exercise the cross-SID (tCCDR /
            # tX2XR) timing paths. sids=1 (the default) keeps every txn
            # on SID 0 — bit-identical to the pre-SID decomposition.
            sid = ((rec.addr // self.sid_capacity_bytes) % self.sids
                   if self.sids > 1 else 0)
            for unit in self._units_of(rec.addr, rec.nbytes):
                c = unit % nch
                u = unit // nch                # channel-local unit index
                if self.is_rome:
                    bank, row, col = rome_unit_location(u, n_vbas)
                else:
                    # bg_striped: the §VI-A bandwidth-maximizing map — the
                    # same one the calibration streams use.
                    bank, row, col = hbm4_unit_location(u, geo)
                per_channel.setdefault(c, []).append(
                    Txn(rec.arrival_ns, bank=bank, row=row, col=col,
                        is_write=rec.is_write, sid=sid,
                        stream=rec.stream_id))
        return per_channel

    def _sim_spec(self) -> tuple[str, dict]:
        """(kind, kwargs) for ``make_channel_sim`` — picklable, so worker
        processes can rebuild the exact channel sim.

        The sims must see the same ChannelGeometry the decomposition
        used, or bank ids and timing would silently desynchronize.
        ``channel_kwargs`` keys the selected channel-sim class does not
        accept raise immediately — a typo'd knob (``quue_depth=2``)
        must never be silently ignored."""
        geo = self.cfg.geometry.channel
        common = dict(geometry=geo, queue_depth=self.queue_depth,
                      refresh=self.refresh,
                      max_ref_postpone=self.max_ref_postpone)
        if self.check_timing:
            common["emit_trace"] = True
        if self.probe is not None:
            common["sample_window_ns"] = self.probe.window_ns
        if self.is_rome:
            common |= {"n_vbas": self.cfg.vbas_per_channel}
        kind = self.channel_kind
        if kind is None:
            if self.is_rome:
                kind = "rome"
            else:
                kind = "hbm4" if self.page_policy == "open" else "hbm4_closed"
        allowed = set(inspect.signature(
            CHANNEL_SIM_KINDS[kind].__init__).parameters) - {"self"}
        unknown = set(self.channel_kwargs) - allowed
        if unknown:
            raise ValueError(
                f"unknown channel_kwargs {sorted(unknown)} for channel kind "
                f"{kind!r}; accepted keys: {sorted(allowed)}")
        # Registered per-policy kwargs (queue_depth, watermarks, variant,
        # ...) win over the SystemSim-level defaults.
        return kind, common | self.channel_kwargs

    def _make_sim(self):
        kind, kwargs = self._sim_spec()
        return make_channel_sim(kind, **kwargs)

    def _sanitize(self, results: "dict[int, SimResult]",
                  step: int | None = None) -> None:
        """Sanitizer mode: replay every loaded channel's command trace
        through the independent :mod:`repro.analysis.timing_checker` and
        raise :class:`~repro.analysis.TimingProtocolError` on the first
        run with any protocol violation. Lazy import — repro.analysis
        depends on repro.core, not the other way around."""
        from ..analysis.timing_checker import (TimingProtocolError,
                                               check_sim_result)
        sim = self._make_sim()
        agg = None
        tag = "" if step is None else f"step {step} "
        for c, r in sorted(results.items()):
            rep = check_sim_result(sim, r, f"{tag}channel {c}")
            if not rep.ok:
                if agg is None:
                    agg = rep
                else:
                    agg.merge(rep)
        if agg is not None:
            raise TimingProtocolError(agg)

    # -- analytic pricing / hybrid classification --------------------------

    def _queue_params(self):
        """The queue-window calibration for this scheduling point
        (explicit ``policy_name`` when threaded from a ``PolicySpec``,
        else the family default)."""
        if self._qparams is None:
            from .queue_model import queue_window_params
            name = self.policy_name
            if name is None:
                kind, _ = self._sim_spec()
                name = {"hbm4": "hbm4_frfcfs", "hbm4_closed": "hbm4_closed",
                        "hbm4_writedrain": "hbm4_writedrain",
                        "hbm4_sidgroup": "hbm4_sidgroup",
                        "rome": "rome_qd2"}[kind]
            self._qparams = queue_window_params(name)
        return self._qparams

    def attach_pricer(self, maxsize: int = 65536, recheck_every: int = 64):
        """Create (or return) this sim's :class:`~repro.core.queue_model
        .StepPricer`: a bounded LRU over step-pricing features keyed on
        an exact stream-shape signature, with sampled hit re-pricing as
        a correctness guard. Decode steps from continuous batching are
        highly repetitive, so the fleet paths attach one pricer per
        cluster and skip re-pricing the repeats."""
        if self.pricer is None:
            from .analytic import calibrate
            from .queue_model import StepPricer
            if self._eff is None:
                self._eff = calibrate(self.cfg)
            self.pricer = StepPricer(self.cfg, self.amap,
                                     self._queue_params(), eff=self._eff,
                                     maxsize=maxsize,
                                     recheck_every=recheck_every)
        return self.pricer

    def attach_probe(self, probe):
        """Attach a :class:`repro.obs.MetricsProbe`: cycle-path channel
        sims start sampling windowed telemetry (``sample_window_ns``
        threads through :meth:`_sim_spec`), and every
        :class:`SystemResult` produced by :meth:`run` / :meth:`run_steps`
        / a warm session is folded into the probe. The probe inherits
        this config's per-channel bus bandwidth as its utilization
        denominator unless it already has one. Pass ``None`` to detach.
        Telemetry never alters simulated results — asserted bit-identical
        in tests/test_obs.py."""
        if probe is not None and getattr(probe, "channel_bw_gbps",
                                         None) is None:
            probe.channel_bw_gbps = self.cfg.channel_bw_gbps
        self.probe = probe
        return probe

    def _features(self, stream: ExtentStream) -> dict:
        return self._features_many([stream])[0]

    def _features_many(self, streams) -> "list[dict]":
        if self.pricer is not None:
            return self.pricer.features_many(streams)
        from .analytic import calibrate
        from .queue_model import stream_features_many
        if self._eff is None:
            self._eff = calibrate(self.cfg)
        return stream_features_many(streams, self.cfg, self.amap,
                                    eff=self._eff)

    def _pressure(self, feats: dict) -> float:
        floor = max(feats["base_ns"], feats["span_ns"])
        if floor <= 0.0:
            return 0.0
        extra = self._queue_params().predict_extra_ns(
            feats["txns_gating"], feats["fine_txns_gating"],
            feats["ext_gating"])
        return extra / floor

    def _threshold(self) -> float:
        """The classification cut: an explicit ``pressure_threshold``
        wins; otherwise the policy's own calibrated threshold."""
        if self.pressure_threshold is not None:
            return self.pressure_threshold
        return self._queue_params().pressure_threshold

    def _use_cycle(self, feats: dict, pressure: float) -> bool:
        """Hybrid classification: contended windows go to the cycle
        engine — unless their decomposed transaction count would blow the
        cycle budget, in which case analytic pricing is the only option
        that keeps unscaled traces runnable."""
        return (pressure > self._threshold()
                and feats["total_txns"] <= self.max_cycle_txns)

    def _analytic_result(self, feats: dict, pressure: float) -> SystemResult:
        """Price one stream with the queue-window model. Byte accounting
        matches the cycle engine exactly (both move whole stripe units);
        per-channel finish times spread the makespan proportionally to
        channel load, with the gating channel defining the makespan."""
        floor = max(feats["base_ns"], feats["span_ns"])
        total = floor + self._queue_params().predict_extra_ns(
            feats["txns_gating"], feats["fine_txns_gating"],
            feats["ext_gating"])
        ch_bytes = feats["mc_channel_bytes"].astype(np.int64)
        mx = ch_bytes.max(initial=0)
        if mx == 0:
            total, ch_finish = 0.0, np.zeros(self.amap.n_channels)
        else:
            ch_finish = total * (ch_bytes / mx)
        return SystemResult(
            total_ns=float(total),
            bytes_moved=int(ch_bytes.sum()),
            channel_bytes=ch_bytes,
            channel_finish_ns=ch_finish,
            channel_results={},
            channel_txns={},
            mode="analytic",
            queue_pressure=pressure,
        )

    # -- run ---------------------------------------------------------------

    def run(self, stream: ExtentStream, workers: int = 1,
            start_ns: float | None = None) -> SystemResult:
        """Simulate or price a timed extent stream on all loaded
        channels; idle channels cost nothing. The pricing engine follows
        this sim's ``mode``: ``"cycle"`` always runs the event loops,
        ``"analytic"`` always uses the queue-window model, ``"hybrid"``
        classifies by modeled queue pressure (see the class docstring).
        ``workers > 1`` simulates cycle-path channels in the shared
        persistent process pool (:mod:`repro.core.pool`; channels share
        no modeled resource, so serial and parallel runs are identical —
        asserted in tests/test_core_memory); in-process, channels
        advance in lockstep via the vectorized driver, which is
        bit-identical to per-channel loops. Returns the system-level
        :class:`SystemResult`, stamped with the path taken.

        ``start_ns`` rebases the stream's arrivals to that clock value
        (equivalent to ``run(stream.shifted(-start_ns))``) — but
        *lazily*: every queue-model feature is shift-invariant, so an
        analytically priced run never materializes the shifted copy.
        That is the fleet fast path: a replay engine passes its clock
        instead of shifting GB-scale step streams it will never cycle-
        simulate."""
        if self.mode != "cycle":
            feats = self._features(stream)
            pressure = self._pressure(feats)
            if self.mode == "analytic" or not self._use_cycle(feats,
                                                              pressure):
                res = self._analytic_result(feats, pressure)
            else:
                res = self._run_cycle(self._rebase(stream, start_ns),
                                      workers, pressure=pressure)
        else:
            res = self._run_cycle(self._rebase(stream, start_ns), workers)
        if self.probe is not None:
            # Cycle-path telemetry clocks are relative to the rebased
            # stream; t0 places the windows back on the caller's clock.
            self.probe.observe_run(res, t0=float(start_ns or 0.0))
        return res

    @staticmethod
    def _rebase(stream: ExtentStream,
                start_ns: float | None) -> ExtentStream:
        if start_ns is None or not start_ns:
            return stream
        return stream.shifted(-start_ns)

    def _run_cycle(self, stream: ExtentStream, workers: int = 1,
                   pressure: float = 0.0) -> SystemResult:
        per_channel = self.decompose(stream)
        items = sorted(per_channel.items())
        results: dict[int, SimResult] = {}
        kind, kwargs = self._sim_spec()
        if workers > 1 and len(items) > 1:
            # Spawn, not fork: the caller's process often has JAX's thread
            # pool alive (fork would risk deadlock). The pool is the
            # process-wide persistent one — interpreter start-up is paid
            # once per process, not once per call.
            pool = get_pool(workers)
            futures = [(c, pool.submit(_run_channel, kind, kwargs, txns))
                       for c, txns in items]
            for c, fut in futures:
                results[c] = fut.result()
        elif items:
            sims = run_channels(kind, kwargs, [txns for _, txns in items])
            results = {c: r for (c, _), r in zip(items, sims)}
        if self.check_timing:
            self._sanitize(results)

        nch = self.amap.n_channels
        ch_bytes = np.zeros(nch, dtype=np.int64)
        ch_finish = np.zeros(nch)
        for c, r in results.items():
            ch_bytes[c] = r.bytes_moved
            ch_finish[c] = r.total_ns
        return SystemResult(
            total_ns=float(ch_finish.max(initial=0.0)),
            bytes_moved=int(ch_bytes.sum()),
            channel_bytes=ch_bytes,
            channel_finish_ns=ch_finish,
            channel_results=results,
            channel_txns=dict(items),
            queue_pressure=pressure,
        )

    def warm_session(self) -> "WarmRunState":
        """Open a warm cross-step session: a :class:`WarmRunState` whose
        per-channel event-loop states persist across :meth:`WarmRunState
        .step` calls (open rows, queues, refresh debt, absolute clock).
        See :meth:`run_steps` for the warm-vs-reset contract."""
        return WarmRunState(self)

    def run_steps(self, streams: "list[ExtentStream]",
                  workers: int = 1,
                  starts_ns: "list[float] | None" = None,
                  warm: bool = False) -> "list[SystemResult]":
        """Simulate a sequence of per-step streams (one serving step
        each) under one of two cross-step contracts:

        **Reset semantics** (``warm=False``, the default): every step
        starts on an idle memory system — no row-buffer, queue, or
        refresh-debt state carries over from the previous step. For
        decode-only replays that is a good model: decode steps are
        separated by kernel-launch/compute gaps long enough (µs at real
        scale) that open rows are precharged by refresh rotation and
        queues drain; what *is* simulated is all intra-step contention
        between tenants. Each stream's arrivals are rebased to its step
        start — the matching entry of ``starts_ns`` when given (pass
        each recorded step's ``StepTrace.start_ns`` to reproduce a
        replay engine's durations exactly, idle lead-in included), else
        the stream's earliest arrival. A step's makespan is then
        directly its duration. Because steps share no simulated state,
        ``workers > 1`` farms (step, channel) sims out to one process
        pool — the batched path for re-simulating a recorded serve
        trace under another policy, where no step-by-step clock
        feedback is needed.

        **Warm semantics** (``warm=True``): the whole sequence runs as
        one :class:`WarmRunState` session on this sim's absolute clock —
        per-channel event loops are suspended at each step boundary and
        resumed with the next step's transactions, so open rows, queued
        backlog and refresh debt carry over. This is the contract
        chunked-prefill replays need: once a prefill burst can leave a
        channel still draining at the step boundary, per-step reset
        would silently forgive the backlog. On uncontended sequences
        (queues drained, gaps long enough for state to quiesce) warm and
        reset agree bit for bit (tests/test_warm_steps.py); on contended
        ones warm can only finish later. Steps are causally ordered, so
        the warm path is sequential — ``workers`` is ignored (suspended
        event-loop states cannot cheaply round-trip a process pool).

        **Hybrid mode** classifies each step by modeled queue pressure:
        an uncontended step (pressure <= ``pressure_threshold``, or a
        decomposed transaction count past ``max_cycle_txns``) is priced
        by the queue-window model, a contended one runs through the
        cycle engine. Under reset semantics both price against an idle
        system and no state flows between steps in *any* mode, so mixing
        pricing engines step-by-step cannot leak contention across a
        step boundary. Under warm semantics the session threads a
        carried-pressure correction through analytically priced steps
        and real channel state through cycle-priced ones (see
        :class:`WarmRunState`). Each returned :class:`SystemResult` is
        stamped with the ``mode`` it took (:func:`hybrid_fraction`
        summarizes the split).
        """
        if starts_ns is not None and len(starts_ns) != len(streams):
            raise ValueError(
                f"starts_ns has {len(starts_ns)} entries for "
                f"{len(streams)} streams")
        if warm:
            sess = self.warm_session()
            out: "list[SystemResult]" = []
            for i, s in enumerate(streams):
                t0 = starts_ns[i] if starts_ns is not None else None
                out.append(sess.step(s, start_ns=t0))
            sess.check()
            return out

        out: list[SystemResult | None] = [None] * len(streams)
        cycle_steps: list[tuple[int, float]] = []    # (step, pressure)
        if self.mode != "cycle":
            # Classification is batched (one vectorized census over every
            # step's records) and runs on the *unshifted* streams — all
            # queue-model features are shift-invariant, so analytically
            # priced steps never materialize a rebased copy.
            feats_all = self._features_many(streams)
            for i, feats in enumerate(feats_all):
                pressure = self._pressure(feats)
                if self.mode == "analytic" or not self._use_cycle(feats,
                                                                  pressure):
                    out[i] = self._analytic_result(feats, pressure)
                else:
                    cycle_steps.append((i, pressure))
        else:
            cycle_steps = [(i, 0.0) for i in range(len(streams))]

        def _cycle_stream(i: int) -> ExtentStream:
            s = streams[i]
            t0 = (starts_ns[i] if starts_ns is not None
                  else min((r.arrival_ns for r in s), default=0.0))
            return s.shifted(-t0) if t0 else s

        prepared = {i: sorted(self.decompose(_cycle_stream(i)).items())
                    for i, _ in cycle_steps}
        all_results: dict[int, dict[int, SimResult]] = {
            i: {} for i in prepared}
        flat = [(i, c, txns) for i, items in prepared.items()
                for c, txns in items]
        kind, kwargs = self._sim_spec()
        if workers > 1 and len(flat) > 1:
            pool = get_pool(workers)
            futures = [(i, c, pool.submit(_run_channel, kind, kwargs,
                                          txns))
                       for i, c, txns in flat]
            for i, c, fut in futures:
                all_results[i][c] = fut.result()
        elif flat:
            sims = run_channels(kind, kwargs, [txns for _, _, txns in flat])
            for (i, c, _), r in zip(flat, sims):
                all_results[i][c] = r
        if self.check_timing:
            for i in sorted(all_results):
                self._sanitize(all_results[i], step=i)
        nch = self.amap.n_channels
        for i, pressure in cycle_steps:
            items = prepared[i]
            results = all_results[i]
            ch_bytes = np.zeros(nch, dtype=np.int64)
            ch_finish = np.zeros(nch)
            for c, r in results.items():
                ch_bytes[c] = r.bytes_moved
                ch_finish[c] = r.total_ns
            out[i] = SystemResult(
                total_ns=float(ch_finish.max(initial=0.0)),
                bytes_moved=int(ch_bytes.sum()),
                channel_bytes=ch_bytes,
                channel_finish_ns=ch_finish,
                channel_results=results,
                channel_txns=dict(items),
                queue_pressure=pressure,
            )
        if self.probe is not None:
            # Reset-mode steps were rebased to their own start; shift each
            # step's telemetry back onto the replay clock before folding.
            for i, res in enumerate(out):
                t0 = (starts_ns[i] if starts_ns is not None
                      else min((r.arrival_ns for r in streams[i]),
                               default=0.0))
                self.probe.observe_run(res, t0=float(t0))
        return out

    def run_extents(self, extents: list[tuple[int, int]],
                    is_write: bool = False,
                    arrival_ns: float = 0.0,
                    workers: int = 1) -> SystemResult:
        """Legacy entry point: one homogeneous batch of (addr, nbytes)
        extents, all one kind, all arriving at once. Thin wrapper that
        lifts the list into a one-kind :class:`ExtentStream` — verified
        bit-for-bit against the pre-stream decomposition
        (tests/test_core_memory.py)."""
        kind = "write" if is_write else "read"
        stream = ExtentStream(
            ExtentRecord(addr, nbytes, kind, arrival_ns)
            for addr, nbytes in extents if nbytes > 0)
        return self.run(stream, workers=workers)


class WarmRunState:
    """A warm cross-step session over one :class:`SystemSim`.

    Where :meth:`SystemSim.run_steps` (reset semantics) starts every step
    on an idle system, a warm session keeps one suspended
    :class:`~repro.core.sched.ChannelRunState` per loaded channel for its
    whole lifetime and runs every step on the same **absolute clock**:

    * **cycle-priced steps** feed the step's transactions (absolute
      arrival times — no rebase) into the persistent per-channel states
      via :meth:`~repro.core.sched.ChannelRunState.feed` and drain them
      with the lockstep vectorized driver. Open rows, per-PC timing
      clocks, queued backlog and refresh debt all carry over; a step's
      duration is its channels' latest absolute finish minus the step
      start, so backlog left by the previous step lands on this step's
      makespan instead of being forgiven.
    * **analytically priced steps** (hybrid/analytic modes) cannot carry
      event-loop state — there is none — so the session threads a scalar
      *carried-pressure* correction instead: each step is classified at
      ``pressure_eff = pressure + carry`` and priced at ``floor + extra +
      carry * floor``; afterwards ``carry = WARM_CARRY_FRAC * max(0,
      pressure_eff - threshold)``. Below the classification threshold the
      carry is exactly zero, so uncontended warm sequences price
      bit-identically to reset mode; above it the correction is a
      first-order, strictly-delaying model of the backlog a real warm
      channel would still be draining. A step that drops into the cycle
      engine resets the carry — the real channel state embodies it.

    Steps must be supplied in clock order (non-decreasing starts); a
    session is single-threaded by construction. With
    ``SystemSim(check_timing=True)``, call :meth:`check` once after the
    last step: it replays each channel's *cumulative* cross-step command
    trace through the independent timing checker — strictly stronger
    than per-step checks, since it also validates protocol spacing
    across step boundaries.
    """

    def __init__(self, system: SystemSim):
        self.system = system
        self._kind, self._kwargs = system._sim_spec()
        self._states: "dict[int, object]" = {}    # channel -> ChannelRunState
        self._carry = 0.0
        self._last_start = 0.0
        self.n_steps = 0

    @property
    def carry(self) -> float:
        """The carried-pressure correction pending for the next
        analytically priced step (0.0 in pure cycle mode)."""
        return self._carry

    def step(self, stream: ExtentStream,
             start_ns: float | None = None) -> SystemResult:
        """Price/simulate one step on the session clock. ``start_ns``
        is the step's start (defaults to the stream's earliest arrival);
        the returned makespan is measured from it. Arrivals are
        interpreted on the absolute session clock — never rebased."""
        sys_ = self.system
        start = (float(start_ns) if start_ns is not None
                 else min((r.arrival_ns for r in stream), default=0.0))
        if start < self._last_start:
            raise ValueError(
                f"warm steps must be clock-ordered: step start {start} ns "
                f"precedes the previous step's start "
                f"{self._last_start} ns")
        self._last_start = start
        self.n_steps += 1
        if sys_.mode != "cycle":
            feats = sys_._features(stream)
            pressure_eff = sys_._pressure(feats) + self._carry
            if sys_.mode == "analytic" or not sys_._use_cycle(feats,
                                                              pressure_eff):
                res = self._analytic_step(feats, pressure_eff)
            else:
                self._carry = 0.0
                res = self._cycle_step(stream, start, pressure_eff)
        else:
            res = self._cycle_step(stream, start, 0.0)
        if sys_.probe is not None:
            # Warm sessions run on the absolute clock already (t0=0);
            # analytic steps still need their start for placement.
            sys_.probe.observe_run(res, t0=0.0, start_ns=start)
        return res

    def _analytic_step(self, feats: dict,
                       pressure_eff: float) -> SystemResult:
        sys_ = self.system
        floor = max(feats["base_ns"], feats["span_ns"])
        extra = sys_._queue_params().predict_extra_ns(
            feats["txns_gating"], feats["fine_txns_gating"],
            feats["ext_gating"])
        total = floor + extra + self._carry * floor
        ch_bytes = feats["mc_channel_bytes"].astype(np.int64)
        mx = ch_bytes.max(initial=0)
        if mx == 0:
            total, ch_finish = 0.0, np.zeros(sys_.amap.n_channels)
        else:
            ch_finish = total * (ch_bytes / mx)
        self._carry = WARM_CARRY_FRAC * max(
            0.0, pressure_eff - sys_._threshold())
        return SystemResult(
            total_ns=float(total),
            bytes_moved=int(ch_bytes.sum()),
            channel_bytes=ch_bytes,
            channel_finish_ns=ch_finish,
            channel_results={},
            channel_txns={},
            mode="analytic",
            queue_pressure=pressure_eff,
        )

    def _cycle_step(self, stream: ExtentStream, start: float,
                    pressure: float) -> SystemResult:
        sys_ = self.system
        items = sorted(sys_.decompose(stream).items())
        stepped = []
        for c, txns in items:
            st = self._states.get(c)
            if st is None:
                st = make_channel_sim(
                    self._kind, **self._kwargs).start_run(txns)
                self._states[c] = st
            else:
                st.feed(txns)
            stepped.append((c, st))
        advance_states([st for _, st in stepped])
        nch = sys_.amap.n_channels
        ch_bytes = np.zeros(nch, dtype=np.int64)
        ch_finish = np.zeros(nch)
        results: "dict[int, SimResult]" = {}
        for c, st in stepped:
            r = st.result()
            results[c] = r
            ch_bytes[c] = r.bytes_moved
            # Finish times are absolute; a step's duration is measured
            # from its own start, so carried backlog shows up here.
            ch_finish[c] = max(0.0, r.total_ns - start)
        return SystemResult(
            total_ns=float(ch_finish.max(initial=0.0)),
            bytes_moved=int(ch_bytes.sum()),
            channel_bytes=ch_bytes,
            channel_finish_ns=ch_finish,
            channel_results=results,
            channel_txns=dict(items),
            queue_pressure=pressure,
        )

    def check(self) -> None:
        """Sanitizer pass for warm sessions: with ``check_timing=True``
        on the underlying sim, replay every channel's cumulative
        cross-step command trace through the independent timing checker
        (no-op otherwise). Call once, after the last step."""
        if not self.system.check_timing or not self._states:
            return
        full = {
            c: SimResult(st.finish, float(st.now),
                         st.n_txns * st.policy.bytes_per_txn,
                         dict(st.counts), trace=st.trace)
            for c, st in self._states.items()
        }
        self.system._sanitize(full)


def hybrid_fraction(results: "list[SystemResult]") -> float:
    """Fraction of runs a hybrid SystemSim priced analytically (1.0 =
    every step took the fast path; 0.0 for an all-cycle run or an empty
    list)."""
    if not results:
        return 0.0
    return sum(r.mode == "analytic" for r in results) / len(results)


def bulk_stream_extents(nbytes: int, n_extents: int = 1,
                        base_addr: int = 0,
                        gap_bytes: int = 0) -> list[tuple[int, int]]:
    """Helper: `n_extents` contiguous extents totalling exactly `nbytes`
    (the last extent absorbs the division remainder), optionally separated
    by `gap_bytes` holes (to exercise load imbalance). The legacy
    extent-list view of :func:`repro.workloads.bulk_stream`."""
    # Lazy import: repro.core.__init__ pulls this module in while
    # workloads.builders is still importing through repro.core.analytic.
    from ..workloads.builders import bulk_stream
    return bulk_stream(nbytes, n_extents, base_addr=base_addr,
                       gap_bytes=gap_bytes).extents()


__all__ = ["SystemSim", "SystemResult", "WarmRunState",
           "bulk_stream_extents", "hybrid_fraction", "MODES",
           "WARM_CARRY_FRAC"]
