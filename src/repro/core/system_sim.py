"""Multi-channel system simulator: (addr, nbytes) extents end to end.

:class:`SystemSim` closes the gap between the single-channel cycle-level
engine and the extent-level analytic model: it takes the same
``(addr, nbytes)`` extents the perf model consumes, decomposes them
through :class:`~repro.core.address_map.AddressMap` into per-channel
transaction streams (channel selection by stripe rotation; the
channel-local layout is the bandwidth-maximizing map the calibration
uses — bg_striped columns for HBM4, VBA-striped rows for RoMe), runs
every channel through :class:`~repro.core.sched.ChannelSimCore`, and
reports per-channel finish times, aggregate bandwidth, and the measured
load-balance ratio. That gives ``analytic.transfer_time_ns`` a
ground-truth cross-validation path at the extent level
(tests/test_core_memory.py) instead of only hand-built single-channel
traces.

Channels are independent after address decomposition (no shared resource
is modeled between channels), so they are simulated one at a time and
composed by taking the max finish — exactly the "most-loaded channel
gates completion" structure the analytic model assumes, but measured.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .address_map import AddressMap, make_address_map
from .sched import SimResult, Txn, make_channel_sim
from .sched.traces import hbm4_unit_location, rome_unit_location
from .timing import MemSystemConfig


@dataclass
class SystemResult:
    """Outcome of one multi-channel extent-level run."""

    total_ns: float                 # makespan = max finish over channels
    bytes_moved: int                # sum of per-channel bytes (MC granularity)
    channel_bytes: np.ndarray       # bytes per channel (MC granularity)
    channel_finish_ns: np.ndarray   # per-channel makespan (0 for idle)
    channel_results: dict           # channel -> SimResult (loaded channels)

    @property
    def bandwidth_gbps(self) -> float:
        if self.total_ns <= 0:
            return 0.0
        return self.bytes_moved / self.total_ns   # B/ns == GB/s

    @property
    def load_balance_ratio(self) -> float:
        """Measured LBR = mean / max channel bytes (cf. Fig 13)."""
        mx = self.channel_bytes.max(initial=0)
        if mx == 0:
            return 1.0
        return float(self.channel_bytes.mean() / mx)

    @property
    def cmd_counts(self) -> dict:
        out: dict = {}
        for r in self.channel_results.values():
            for k, v in r.cmd_counts.items():
                out[k] = out.get(k, 0) + v
        return out


class SystemSim:
    """N independent channel sims behind one address map.

    Parameters mirror the single-channel sims; ``n_channels`` (or an
    explicit ``amap``) sets the system width — pass a small count to keep
    cycle-level runs tractable, the per-channel behaviour is identical.
    ``max_ref_postpone`` defaults to 32 (the *well-tuned* pooled-refresh
    MC that the analytic calibration models).
    """

    def __init__(self, cfg: MemSystemConfig,
                 amap: AddressMap | None = None,
                 n_channels: int | None = None,
                 queue_depth: int | None = None,
                 refresh: bool = True,
                 max_ref_postpone: int = 32,
                 page_policy: str = "open"):
        self.cfg = cfg
        self.is_rome = cfg.ag_mc_bytes >= cfg.row_bytes
        if amap is None:
            amap = make_address_map(cfg, n_cubes=1)
            if n_channels is not None:
                amap = AddressMap(n_channels=n_channels,
                                  stripe_bytes=amap.stripe_bytes,
                                  banks_per_channel=amap.banks_per_channel,
                                  row_bytes=amap.row_bytes)
        elif n_channels is not None and n_channels != amap.n_channels:
            raise ValueError("pass either amap or n_channels, not both")
        self.amap = amap
        self.queue_depth = (cfg.request_queue_depth if queue_depth is None
                            else queue_depth)
        self.refresh = refresh
        self.max_ref_postpone = max_ref_postpone
        self.page_policy = page_policy

    # -- decomposition -----------------------------------------------------

    def _units_of(self, extents: list[tuple[int, int]]) -> np.ndarray:
        """Global stripe-unit indices touched by the extents (an extent
        touching any byte of a unit transfers the whole unit — the MC
        access granularity / row-rounding overfetch)."""
        chunks = []
        g = self.amap.stripe_bytes
        for start, nbytes in extents:
            if nbytes <= 0:
                continue
            first = start // g
            last = (start + nbytes - 1) // g
            chunks.append(np.arange(first, last + 1, dtype=np.int64))
        if not chunks:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate(chunks)

    def decompose(self, extents: list[tuple[int, int]],
                  is_write: bool = False,
                  arrival_ns: float = 0.0) -> dict[int, list[Txn]]:
        """Per-channel transaction streams for the extents.

        Channel selection follows the address map's stripe rotation; the
        channel-local (bank, row, col) placement of a unit is a pure
        function of its channel-local unit index, so overlapping extents
        hit the same locations and contiguous extents reproduce the
        calibration stream on every loaded channel.
        """
        units = self._units_of(extents)
        nch = self.amap.n_channels
        geo = self.cfg.geometry.channel
        n_vbas = self.cfg.vbas_per_channel
        per_channel: dict[int, list[Txn]] = {}
        for unit in units.tolist():
            c = unit % nch
            u = unit // nch                    # channel-local unit index
            if self.is_rome:
                bank, row, col = rome_unit_location(u, n_vbas)
            else:
                # bg_striped: the §VI-A bandwidth-maximizing map — the
                # same one the calibration streams use.
                bank, row, col = hbm4_unit_location(u, geo)
            per_channel.setdefault(c, []).append(
                Txn(arrival_ns, bank=bank, row=row, col=col,
                    is_write=is_write))
        return per_channel

    def _make_sim(self):
        # The sims must see the same ChannelGeometry the decomposition
        # used, or bank ids and timing would silently desynchronize.
        geo = self.cfg.geometry.channel
        if self.is_rome:
            return make_channel_sim(
                "rome", geometry=geo, n_vbas=self.cfg.vbas_per_channel,
                queue_depth=self.queue_depth, refresh=self.refresh,
                max_ref_postpone=self.max_ref_postpone)
        kind = "hbm4" if self.page_policy == "open" else "hbm4_closed"
        return make_channel_sim(
            kind, geometry=geo, queue_depth=self.queue_depth,
            refresh=self.refresh, max_ref_postpone=self.max_ref_postpone)

    # -- run ---------------------------------------------------------------

    def run_extents(self, extents: list[tuple[int, int]],
                    is_write: bool = False,
                    arrival_ns: float = 0.0) -> SystemResult:
        """Simulate the extents on all loaded channels; idle channels cost
        nothing. Returns the system-level :class:`SystemResult`."""
        per_channel = self.decompose(extents, is_write, arrival_ns)
        nch = self.amap.n_channels
        ch_bytes = np.zeros(nch, dtype=np.int64)
        ch_finish = np.zeros(nch)
        results: dict[int, SimResult] = {}
        for c, txns in sorted(per_channel.items()):
            sim = self._make_sim()
            r = sim.run(txns)
            results[c] = r
            ch_bytes[c] = r.bytes_moved
            ch_finish[c] = r.total_ns
        return SystemResult(
            total_ns=float(ch_finish.max(initial=0.0)),
            bytes_moved=int(ch_bytes.sum()),
            channel_bytes=ch_bytes,
            channel_finish_ns=ch_finish,
            channel_results=results,
        )


def bulk_stream_extents(nbytes: int, n_extents: int = 1,
                        base_addr: int = 0,
                        gap_bytes: int = 0) -> list[tuple[int, int]]:
    """Helper: `n_extents` contiguous extents totalling `nbytes`,
    optionally separated by `gap_bytes` holes (to exercise load imbalance)."""
    per = nbytes // n_extents
    out = []
    addr = base_addr
    for _ in range(n_extents):
        out.append((addr, per))
        addr += per + gap_bytes
    return out


__all__ = ["SystemSim", "SystemResult", "bulk_stream_extents"]
