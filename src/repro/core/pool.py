"""Persistent process-pool for cycle-path channel simulation.

``SystemSim.run``/``run_steps`` used to construct (and tear down) a
fresh ``ProcessPoolExecutor`` on every call. With spawn workers — the
only safe start method here, because the caller's process usually has
JAX's thread pool alive and a fork would risk deadlock — that meant one
full interpreter start-up per call: tens to hundreds of milliseconds of
pure churn, paid once per decode step in a replay and once per replica
round in a fleet sweep. This module hoists the pool to process scope:
one long-lived spawn pool, grown on demand, shared by every SystemSim
in the process and shut down at interpreter exit.

Correctness is unaffected: channels share no simulated state, so which
pool (or how old a pool) runs them cannot change results — the serial
path is bit-identical either way (asserted in tests/test_hybrid.py).
"""
from __future__ import annotations

import atexit
import multiprocessing
from concurrent.futures import ProcessPoolExecutor

_pool: ProcessPoolExecutor | None = None
_pool_workers: int = 0


def get_pool(workers: int) -> ProcessPoolExecutor:
    """The shared spawn pool, sized for at least ``workers`` workers.

    The pool persists across calls and callers; asking for more workers
    than the current pool has replaces it with a larger one (existing
    submitted work is drained first). Asking for fewer reuses the
    existing pool — an oversized pool is idle processes, not wrong
    results.
    """
    global _pool, _pool_workers
    if workers < 1:
        raise ValueError(f"workers must be >= 1, got {workers}")
    if _pool is None or _pool_workers < workers:
        if _pool is not None:
            _pool.shutdown(wait=True)
        _pool = ProcessPoolExecutor(
            max_workers=workers,
            mp_context=multiprocessing.get_context("spawn"))
        _pool_workers = workers
    return _pool


def pool_workers() -> int:
    """Current pool size (0 when no pool has been created)."""
    return _pool_workers


def shutdown_pool() -> None:
    """Tear the shared pool down (tests; atexit). Safe to call twice —
    the next :func:`get_pool` simply builds a fresh pool."""
    global _pool, _pool_workers
    if _pool is not None:
        _pool.shutdown(wait=True)
        _pool = None
        _pool_workers = 0


atexit.register(shutdown_pool)

__all__ = ["get_pool", "pool_workers", "shutdown_pool"]
