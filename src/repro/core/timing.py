"""Timing and geometry parameters for HBM4 and RoMe memory systems.

Encodes Tables II, III and V of the paper. All times are in nanoseconds
(float); geometry counts are ints. JEDEC has not finalized HBM4 timings, so
— like the paper — we adopt values from prior studies ([2] Folded Banks,
[51] Fine-Grained DRAM) as listed in Table V.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


# ---------------------------------------------------------------------------
# Geometry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ChannelGeometry:
    """Physical geometry of one (legacy) HBM channel."""

    data_pins: int = 64              # DQ pins per channel (HBM4: 64)
    data_rate_gbps: float = 8.0      # per-pin data rate
    pseudo_channels: int = 2         # PCs per channel (share C/A, split DQ)
    bank_groups: int = 8             # bank groups per PC
    banks_per_group: int = 8         # banks per bank group (128 banks/ch)
    row_bytes: int = 1024            # row size per bank (1 KB)
    col_bytes: int = 32              # column access granularity (32 B)
    sids: int = 4                    # stack IDs (ranks)

    @property
    def banks_per_pc(self) -> int:
        return self.bank_groups * self.banks_per_group

    @property
    def banks_per_channel(self) -> int:
        return self.banks_per_pc * self.pseudo_channels

    @property
    def bandwidth_gbps(self) -> float:
        """Peak channel bandwidth in GB/s."""
        return self.data_pins * self.data_rate_gbps / 8.0

    @property
    def pc_bandwidth_gbps(self) -> float:
        return self.bandwidth_gbps / self.pseudo_channels

    @property
    def burst_ns(self) -> float:
        """Time to move one column (col_bytes) over one PC."""
        return self.col_bytes / self.pc_bandwidth_gbps  # bytes / (B/ns)

    @property
    def cols_per_row(self) -> int:
        return self.row_bytes // self.col_bytes


@dataclass(frozen=True)
class CubeGeometry:
    """One HBM cube (stack)."""

    channels: int = 32               # legacy channels per cube (HBM4: 32)
    channel: ChannelGeometry = ChannelGeometry()

    @property
    def bandwidth_gbps(self) -> float:
        return self.channels * self.channel.bandwidth_gbps  # GB/s

    @property
    def bandwidth_tbps(self) -> float:
        return self.bandwidth_gbps / 1000.0


# ---------------------------------------------------------------------------
# HBM4 (baseline) timing — Table II / Table V left column
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HBM4Timing:
    """Conventional HBM4 timing parameters in ns (Table V)."""

    tRC: float = 45.0
    tRP: float = 16.0
    tRAS: float = 29.0
    tCL: float = 16.0
    tCWL: float = 2.0         # write latency (command to first write data)
    tRCDRD: float = 16.0
    tRCDWR: float = 16.0
    tWR: float = 16.0
    tFAW: float = 12.0
    tCCDL: float = 2.0        # RD/WR to RD/WR, same bank group
    tCCDS: float = 1.0        # RD/WR to RD/WR, different bank group
    tCCDR: float = 2.0        # RD/WR to RD/WR, different SID (rank)
    tRRDS: float = 2.0        # ACT to ACT, different bank group
    tRRDL: float = 2.0        # ACT to ACT, same bank group
    tRTW: float = 4.0         # RD to WR turnaround, same channel
    tWTRS: float = 4.0        # WR to RD, different bank group
    tWTRL: float = 6.0        # WR to RD, same bank group
    tRTP: float = 4.0         # RD to PRE
    # Refresh
    tREFI: float = 3900.0     # all-bank refresh interval
    tRFCab: float = 350.0     # all-bank refresh cycle
    tRFCpb: float = 280.0     # per-bank refresh cycle
    tRREFpb: float = 8.0      # REFpb-to-REFpb, different banks
    refresh_rotation_banks: int = 32  # banks covered by the REFpb rotation

    @property
    def tREFIpb(self) -> float:
        """Per-bank refresh command interval (rotating across banks)."""
        return self.tREFI / self.refresh_rotation_banks

    def n_managed(self) -> int:
        """Number of timing parameters the conventional MC must manage
        (paper Table IV: 15)."""
        return 15


# ---------------------------------------------------------------------------
# RoMe timing — Table III / Table V right column
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RoMeTiming:
    """RoMe row-level timing parameters in ns (Tables III & V).

    `S` suffix = different VBA (same SID); `R` suffix = different SID.
    tRD_row / tWR_row chain within the same VBA.
    """

    tR2RS: float = 64.0
    tR2RR: float = 68.0
    tR2WS: float = 69.0
    tR2WR: float = 73.0
    tW2RS: float = 71.0
    tW2RR: float = 75.0
    tW2WS: float = 64.0
    tW2WR: float = 68.0
    tRD_row: float = 95.0
    tWR_row: float = 115.0
    # Refresh (inherited from the underlying DRAM; §V-B)
    tRFCpb: float = 280.0
    tRREFpb: float = 8.0
    tREFIpb: float = 3900.0 / 32.0

    def n_managed(self) -> int:
        """Number of timing parameters the RoMe MC manages (Table IV: 10)."""
        return 10

    def max_concurrent_refreshing(self) -> int:
        """Refresh-FSM provisioning (§V-A: 'up to three undergo refresh
        simultaneously'). Steady-state rotation alone needs
        ceil((tRFCpb+tRREFpb)/(2*tREFIpb)) = 2 in-flight; the third FSM
        covers pooled-refresh flushes — when demand-postponed REFpbs
        drain, the MC releases them at tRREFpb spacing but caps in-flight
        refreshes at 3 so an 8-deep pool empties in
        ~3*(tRFCpb+tRREFpb) < tREFI/4 without provisioning a per-VBA
        FSM."""
        import math
        steady = math.ceil((self.tRFCpb + self.tRREFpb) / (2 * self.tREFIpb))
        return steady + 1

    def gap_ns(self, prev_is_write: bool, next_is_write: bool,
               same_vba: bool, same_sid: bool) -> float:
        """Minimum start-to-start spacing between two row commands."""
        if same_vba:
            return self.tWR_row if prev_is_write else self.tRD_row
        if not prev_is_write and not next_is_write:
            return self.tR2RS if same_sid else self.tR2RR
        if not prev_is_write and next_is_write:
            return self.tR2WS if same_sid else self.tR2WR
        if prev_is_write and not next_is_write:
            return self.tW2RS if same_sid else self.tW2RR
        return self.tW2WS if same_sid else self.tW2WR


# ---------------------------------------------------------------------------
# System-level configs (Table V)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MemSystemConfig:
    """One cube-level memory-system configuration."""

    name: str
    channels_per_cube: int
    banks_per_channel: int           # banks (HBM4) or VBAs*2 (RoMe)
    row_bytes: int                   # effective row / AG_MC granularity unit
    ag_mc_bytes: int                 # MC access granularity
    data_rate_gbps: float
    channel_bw_gbps: float           # GB/s per channel
    request_queue_depth: int
    geometry: CubeGeometry

    @property
    def cube_bw_gbps(self) -> float:
        return self.channels_per_cube * self.channel_bw_gbps

    @property
    def vbas_per_channel(self) -> int:
        return self.banks_per_channel // 2


def hbm4_config() -> MemSystemConfig:
    geo = CubeGeometry(channels=32, channel=ChannelGeometry())
    return MemSystemConfig(
        name="hbm4",
        channels_per_cube=32,
        banks_per_channel=128,
        row_bytes=1024,
        ag_mc_bytes=32,
        data_rate_gbps=8.0,
        channel_bw_gbps=geo.channel.bandwidth_gbps,
        request_queue_depth=64,
        geometry=geo,
    )


def rome_config(extra_channels: int = 4) -> MemSystemConfig:
    """RoMe cube: 32 legacy channels + `extra_channels` from freed C/A pins
    (§IV-E: 36 channels/cube, +12.5 % bandwidth)."""
    geo = CubeGeometry(channels=32 + extra_channels, channel=ChannelGeometry())
    return MemSystemConfig(
        name="rome",
        channels_per_cube=32 + extra_channels,
        banks_per_channel=32,
        row_bytes=4096,              # effective row: 2 banks x 2 PCs x 1KB
        ag_mc_bytes=4096,
        data_rate_gbps=8.0,
        channel_bw_gbps=geo.channel.bandwidth_gbps,
        request_queue_depth=4,
        geometry=geo,
    )


# Conventional MC bank states (Fig 4 discussion) and RoMe bank states
# (Fig 11(a)).
HBM4_BANK_STATES = (
    "Idle", "Activating", "Active", "Precharging", "Reading", "Writing",
    "Refreshing",
)
ROME_BANK_STATES = ("Idle", "Reading", "Writing", "Refreshing")


def summarize(cfg: MemSystemConfig) -> dict:
    return dataclasses.asdict(cfg) | {
        "cube_bw_gbps": cfg.cube_bw_gbps,
    }
