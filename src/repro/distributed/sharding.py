"""Sharding vocabulary and helpers.

Mesh axes (see repro.launch.mesh):
  * ``pod``   — outer data-parallel axis across pods (multi-pod mesh only)
  * ``data``  — data parallel / FSDP axis within a pod
  * ``model`` — tensor-parallel axis

Model code expresses intent with :func:`shard_hint`, which silently drops
axes that don't exist on the active mesh — so the same model runs on the
single-pod mesh (no ``pod`` axis), the multi-pod mesh, or an unmeshed CPU
test (constraint becomes a no-op).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from ..compat import active_mesh, mesh_axis_sizes, tree_map
from ..compat import active_mesh_axis_names as _active_axis_names

BATCH_AXES = ("pod", "data")    # batch dim shards over both DP axes
TP_AXIS = "model"

# --- activation (sequence-parallel) sharding policy -------------------------
# When set to a mesh axis name (usually "model"), the residual stream h is
# sharded along its sequence dim between blocks — XLA gathers it where a
# block genuinely needs the full sequence and scatters after (standard
# sequence parallelism). Cuts saved-activation memory by the TP degree at
# the cost of per-block collectives; the launch layer enables it for train
# cells whose activations cannot otherwise fit HBM.
_ACT_SEQ_AXIS: list = [None]


class activation_sharding:
    """Trace-time context manager selecting the sequence-parallel axis."""

    def __init__(self, axis):
        self.axis = axis

    def __enter__(self):
        _ACT_SEQ_AXIS.append(self.axis)
        return self

    def __exit__(self, *exc):
        _ACT_SEQ_AXIS.pop()
        return False


def act_seq_axis():
    return _ACT_SEQ_AXIS[-1]


def hint_residual(h):
    """Sharding hint for the residual stream (b, s, d) between blocks."""
    if h.ndim != 3 or h.shape[1] <= 1:
        return shard_hint(h, BATCH_AXES, None, None)
    return shard_hint(h, BATCH_AXES, act_seq_axis(), None)


def filter_spec(entries: tuple, axis_names: tuple) -> tuple:
    """Drop mesh axes that are not present on the active mesh."""
    out = []
    for e in entries:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in axis_names)
            out.append(kept if kept else None)
        else:
            out.append(e if e in axis_names else None)
    return tuple(out)


def spec(*entries) -> P:
    """PartitionSpec filtered to the active mesh's axes (for use *outside*
    jit when building in/out shardings)."""
    return P(*filter_spec(entries, _active_axis_names()))


def shard_hint(x: jax.Array, *entries) -> jax.Array:
    """with_sharding_constraint that degrades gracefully: unknown axes are
    dropped; with no active mesh it is the identity."""
    names = _active_axis_names()
    if not names:
        return x
    cleaned = filter_spec(entries, names)
    if all(c is None for c in cleaned):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*cleaned))
    except Exception:
        return x


# ---------------------------------------------------------------------------
# TP divisibility policy (DESIGN.md §4)
# ---------------------------------------------------------------------------

def constrain_like(tree, specs):
    """with_sharding_constraint every leaf to its named-axis spec tuple,
    filtered to the active mesh and to divisibility (leaf shapes are known
    at trace time). No-op outside a mesh. Used to pin gradient
    accumulators to the parameter sharding so XLA emits per-microbatch
    reduce-scatters instead of full all-reduces (§Perf)."""
    mesh = active_mesh()
    if mesh is None or not mesh.axis_names:
        return tree
    sizes = mesh_axis_sizes(mesh)

    def entry_ok(e, dim):
        axes = [a for a in (e if isinstance(e, (tuple, list)) else (e,))
                if a in sizes]
        while axes:
            prod = 1
            for a in axes:
                prod *= sizes[a]
            if dim % prod == 0:
                break
            axes.pop(0)
        if not axes:
            return None
        return tuple(axes) if len(axes) > 1 else axes[0]

    is_spec = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, tuple, list, type(None))) for e in x)

    def one(x, spec):
        spec = tuple(spec) + (None,) * (x.ndim - len(spec))
        used: set = set()
        entries = []
        for e, d in zip(spec, x.shape):
            c = None if e is None else entry_ok(e, d)
            if c is not None:
                cs = c if isinstance(c, tuple) else (c,)
                cs = tuple(a for a in cs if a not in used)
                used.update(cs)
                c = cs if len(cs) > 1 else (cs[0] if cs else None)
            entries.append(c)
        if all(c is None for c in entries):
            return x
        return jax.lax.with_sharding_constraint(x, P(*entries))

    return tree_map(one, tree, specs, is_leaf=is_spec)


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def padded_heads(n_heads: int, tp: int) -> int:
    """Query heads are padded up to a multiple of TP (vLLM/MaxText
    convention); the pad heads carry zero-initialized projections."""
    return pad_to_multiple(n_heads, tp)


def padded_kv_heads(n_kv_heads: int, tp: int) -> int:
    """KV heads are *replicated* (not padded) when fewer than TP; the
    parameter tensors keep their true size and the replication happens in
    compute via repeat_kv. For sharding purposes the kv projection output
    dim shards over TP only when divisible."""
    return n_kv_heads


def padded_vocab(vocab: int, multiple: int = 128) -> int:
    """Vocab padded to a lane-aligned multiple (whisper: 51865 -> 51968)."""
    return pad_to_multiple(vocab, multiple)
