from .sharding import (BATCH_AXES, TP_AXIS, filter_spec, pad_to_multiple,
                       padded_heads, padded_vocab, shard_hint, spec)

__all__ = [
    "BATCH_AXES", "TP_AXIS", "filter_spec", "pad_to_multiple",
    "padded_heads", "padded_vocab", "shard_hint", "spec",
]
