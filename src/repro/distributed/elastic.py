"""Elastic re-meshing: resume a job on a different device count.

At 1000+-node scale, node loss is routine; rather than waiting for the
exact machine shape to return, the job restarts on whatever divisor-shaped
slice is healthy. Parameters (and optimizer moments) are declared by
*named-axis* PartitionSpecs, so resharding is respecification: build the new
mesh, re-place every leaf under the same spec names, and continue. The spec
is the invariant; the device assignment is not.

``shrink_mesh`` picks the largest (data', model') grid that divides the new
device count while preserving the model-axis divisibility constraints of
the architecture (head counts, FFN width).
"""
from __future__ import annotations

from typing import Iterable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import tree_map
from .sharding import filter_spec


def viable_meshes(n_devices: int, tp_divisors: Iterable[int] = (16, 8, 4, 2, 1)):
    """(data, model) grids available at a device count, best-TP first."""
    out = []
    for tp in tp_divisors:
        if n_devices % tp == 0:
            out.append((n_devices // tp, tp))
    return out


def shrink_mesh(n_devices: int, model_divisibility: int = 16,
                devices=None) -> Mesh:
    """Largest usable (data, model) mesh after an elastic event. The model
    axis must divide `model_divisibility` (the arch's TP-alignment, e.g.
    padded head count)."""
    for data, model in viable_meshes(n_devices):
        if model_divisibility % model == 0 or model <= model_divisibility:
            devs = np.asarray(devices if devices is not None
                              else jax.devices()[:n_devices])
            return Mesh(devs.reshape(data, model), ("data", "model"))
    raise ValueError(f"no viable mesh for {n_devices} devices")


def reshard(tree, specs, mesh: Mesh):
    """Re-place every leaf of `tree` on `mesh` under its named spec.

    specs is a pytree of PartitionSpec *tuples* (the repo convention);
    axes not present on the new mesh are dropped (e.g. 'pod' after
    shrinking to one pod)."""
    names = tuple(mesh.axis_names)

    def place(x, spec):
        cleaned = P(*filter_spec(tuple(spec), names))
        return jax.device_put(x, NamedSharding(mesh, cleaned))

    return tree_map(place, tree, specs,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(e, (str, tuple, type(None)))
                                for e in x))


def elastic_resume(tree, specs, n_devices: int,
                   model_divisibility: int = 16):
    """One-call elastic restart: shrink the mesh and reshard the state."""
    mesh = shrink_mesh(n_devices, model_divisibility)
    return reshard(tree, specs, mesh), mesh
