"""Fault-tolerant checkpointing: per-host sharded npz + manifest with
atomic rename.

Layout (one directory per step):

    <dir>/step_000042/
        manifest.json        # step, tree structure, shard table, status
        host_000.npz         # this host's leaf shards (flat index -> array)

Writes go to ``step_<n>.tmp/`` and are renamed into place only after every
file is fsync'd — a crashed save never shadows the previous good step.
``latest_step()`` scans for the newest complete manifest, so restart always
resumes from the last *committed* checkpoint (node-failure tolerance).

An async mode offloads serialization to a worker thread so the train loop
only blocks on the previous save (standard large-scale practice).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import tree_map

# npz cannot serialize non-native dtypes (bfloat16, fp8): store them as
# same-width unsigned views and reinterpret on restore via the manifest.
_VIEW_BYTES = {2: np.uint16, 1: np.uint8, 4: np.uint32, 8: np.uint64}


def _to_storable(a: np.ndarray) -> np.ndarray:
    if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
        return a.view(_VIEW_BYTES[a.dtype.itemsize])
    return a


def _from_storable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    want = jnp.dtype(dtype_name)
    if a.dtype != want:
        try:
            return a.view(want)
        except (TypeError, ValueError):
            return a.astype(want)
    return a


def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree, host_id: int = 0,
         n_hosts: int = 1) -> str:
    """Synchronous checkpoint save. Returns the committed path."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(directory, f"step_{step:06d}")
    tmp = final + f".tmp{host_id}"
    os.makedirs(tmp, exist_ok=True)

    arrays = {f"leaf_{i}": _to_storable(np.asarray(leaf))
              for i, leaf in enumerate(leaves)}
    shard_path = os.path.join(tmp, f"host_{host_id:03d}.npz")
    with open(shard_path, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())

    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "n_hosts": n_hosts,
        "treedef": str(treedef),
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "status": "complete",
    }
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())

    # Atomic commit: a reader either sees the full directory or nothing.
    if os.path.isdir(final):
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    """Newest step with a complete manifest (skips torn/tmp saves)."""
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        if not name.startswith("step_") or name.endswith(
                tuple(f".tmp{i}" for i in range(64))):
            continue
        mpath = os.path.join(directory, name, "manifest.json")
        try:
            with open(mpath) as f:
                m = json.load(f)
            if m.get("status") == "complete":
                best = max(best or -1, int(m["step"]))
        except (OSError, ValueError, KeyError):
            continue
    return best


def restore(directory: str, step: int, tree_like, host_id: int = 0):
    """Restore into the structure of `tree_like` (its leaves give order)."""
    path = os.path.join(directory, f"step_{step:06d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(tree_like)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"restore target has {len(leaves)} — structure mismatch")
    data = np.load(os.path.join(path, f"host_{host_id:03d}.npz"))
    out = [_from_storable(data[f"leaf_{i}"], manifest["dtypes"][i])
           for i in range(len(leaves))]
    restored = treedef.unflatten(out)
    return tree_map(
        lambda tgt, arr: jnp.asarray(arr, dtype=tgt.dtype)
        if hasattr(tgt, "dtype") else arr, tree_like, restored)


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training: save() returns immediately;
    the next save (or close()) joins the in-flight write first."""

    def __init__(self) -> None:
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, directory: str, step: int, tree, host_id: int = 0,
             n_hosts: int = 1) -> None:
        self.wait()
        # Materialize on host *before* backgrounding so the device buffers
        # are free to be donated/overwritten by the next step.
        host_tree = tree_map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(directory, step, host_tree, host_id, n_hosts)
            except BaseException as e:      # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    close = wait
