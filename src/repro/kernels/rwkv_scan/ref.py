"""Pure-jnp oracle for the chunked RWKV6 time-mix recurrence.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (diag(u) k_t^T v_t + S_{t-1})

r/k/w: (b, s, H, hd); v: (b, s, H, hd); u: (H, hd). All math fp32.
Returns (o (b, s, H, hd), final state (b, H, hd, hd)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv_scan_ref(r, k, v, w, u, S0=None):
    b, s, H, hd = r.shape
    r32, k32, v32, w32 = (x.astype(jnp.float32) for x in (r, k, v, w))
    u32 = u.astype(jnp.float32)
    if S0 is None:
        S0 = jnp.zeros((b, H, hd, hd), jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                          # (b, H, hd)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)        # rank-1 update
        o = jnp.einsum("bhk,bhkv->bhv", r_t,
                       S + u32[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, o

    S, o = jax.lax.scan(
        step, S0,
        (r32.transpose(1, 0, 2, 3), k32.transpose(1, 0, 2, 3),
         v32.transpose(1, 0, 2, 3), w32.transpose(1, 0, 2, 3)))
    return o.transpose(1, 0, 2, 3).astype(r.dtype), S
