"""jit'd public wrapper for the chunked RWKV6 scan."""
from __future__ import annotations

import jax

from .kernel import pick_chunk, rwkv_scan
from .ref import rwkv_scan_ref


def time_mix(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, use_kernel: bool = True,
             interpret: bool = True):
    """Chunk-parallel RWKV6 recurrence; `use_kernel=False` falls back to
    the sequential jnp oracle."""
    if not use_kernel:
        return rwkv_scan_ref(r, k, v, w, u)
    return rwkv_scan(r, k, v, w, u, interpret=interpret)


__all__ = ["time_mix", "pick_chunk"]
