"""Chunked RWKV6 time-mix Pallas TPU kernel — row-granularity streaming of
the attention-free arch's hot loop.

The recurrence  S_t = diag(w_t) S_{t-1} + k_t^T v_t,
               o_t = r_t (diag(u) k_t^T v_t + S_{t-1})
is evaluated chunk-parallel: within a chunk of C tokens all cross-token
terms become (C x C) matmuls using per-channel *log-space* cumulative
decays, and only the (hd x hd) state crosses chunk boundaries (VMEM
scratch). Exponent differences are always <= 0 inside the valid mask, so
no decay underflow/overflow can occur regardless of the data-dependent w.

Chunk size is chosen so one operand chunk (C x hd x 4 B) is a whole number
of 4 KB DRAM rows — each r/k/v/w DMA is one RD_row burst train (C=16,
hd=64 -> exactly one row), the RoMe contract.

Grid: (b, H, n_chunks); the chunk axis is sequential ("arbitrary") and
carries the state in scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...compat.pallas import tpu_compiler_params

DRAM_ROW_BYTES = 4096
NEG_INF = -1e30


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_final_ref, S):
    c_idx = pl.program_id(2)
    C, hd = r_ref.shape[2], r_ref.shape[3]

    @pl.when(c_idx == 0)
    def _init():
        S[...] = jnp.zeros_like(S)

    r = r_ref[0, 0].astype(jnp.float32)              # (C, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)              # decay in (0, 1)
    u = u_ref[0].astype(jnp.float32)                 # (hd,)

    logw = jnp.log(jnp.maximum(w, 1e-38))            # (C, hd), <= 0
    lc = jnp.cumsum(logw, axis=0)                    # inclusive cumulation
    lc_prev = lc - logw                              # lc_{i-1} (exclusive)

    # Intra-chunk mixing matrix A (C x C):
    #   j <  i: sum_d r[i,d] k[j,d] exp(lc_prev[i,d] - lc[j,d])
    #   j == i: sum_d r[i,d] u[d] k[i,d]
    # Exponents are <= 0 inside the mask; masked entries are zeroed *before*
    # exp via a NEG_INF fill, so nothing can overflow.
    expo = lc_prev[:, None, :] - lc[None, :, :]      # (C, C, hd)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    strict = (j_idx < i_idx)[:, :, None]
    decay = jnp.exp(jnp.where(strict, expo, NEG_INF))
    A = jnp.einsum("id,jd,ijd->ij", r, k, decay)
    A = A + jnp.diag(jnp.sum(r * u[None, :] * k, axis=-1))

    # State contribution and output.
    r_dec = r * jnp.exp(lc_prev)                     # (C, hd), exp <= 1
    o = jnp.dot(A, v, preferred_element_type=jnp.float32) \
        + jnp.dot(r_dec, S[...], preferred_element_type=jnp.float32)
    o_ref[0, 0] = o.astype(o_ref.dtype)

    # State update: S' = diag(exp(lc_C)) S + sum_j (k_j * exp(lc_C - lc_j))^T v_j
    lc_last = lc[-1]                                 # (hd,)
    k_dec = k * jnp.exp(lc_last[None, :] - lc)       # exp <= 1
    S[...] = jnp.exp(lc_last)[:, None] * S[...] \
        + jnp.dot(k_dec.T, v, preferred_element_type=jnp.float32)

    @pl.when(c_idx == pl.num_programs(2) - 1)
    def _finish():
        s_final_ref[0, 0] = S[...]


def pick_chunk(s: int, hd: int, itemsize: int = 4) -> int:
    """Chunk length: whole DRAM rows per operand chunk and divides s."""
    c = max(8, DRAM_ROW_BYTES // (hd * itemsize))
    while (c * hd * itemsize) % DRAM_ROW_BYTES and c > 8:
        c -= 8
    while s % c and c > 1:
        c //= 2
    return max(1, c)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, chunk: int | None = None,
              interpret: bool = True):
    """r/k/v/w: (b, s, H, hd); u: (H, hd).
    Returns (o (b, s, H, hd), final state (b, H, hd, hd))."""
    b, s, H, hd = r.shape
    if chunk is None:
        chunk = pick_chunk(s, hd, 4)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # (b, H, s, hd) layout so the chunk dim is contiguous per (b, H).
    tr = lambda x: x.transpose(0, 2, 1, 3)
    rr, kk, vv, ww = tr(r), tr(k), tr(v), tr(w)

    spec = pl.BlockSpec((1, 1, chunk, hd), lambda i, j, c: (i, j, c, 0))
    o, s_final = pl.pallas_call(
        _kernel,
        grid=(b, H, nc),
        in_specs=[spec, spec, spec,
                  spec,
                  pl.BlockSpec((1, hd), lambda i, j, c: (j, 0))],
        out_specs=[spec,
                   pl.BlockSpec((1, 1, hd, hd), lambda i, j, c: (i, j, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, H, s, hd), r.dtype),
                   jax.ShapeDtypeStruct((b, H, hd, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(rr, kk, vv, ww, u)
    return o.transpose(0, 2, 1, 3), s_final
