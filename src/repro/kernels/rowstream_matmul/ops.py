"""jit'd public wrapper for the row-stream matmul."""
from __future__ import annotations

import jax

from .kernel import pick_bk, rowstream_matmul
from .ref import rowstream_matmul_ref


def matmul(x: jax.Array, w: jax.Array, use_kernel: bool = True,
           interpret: bool = True) -> jax.Array:
    """Row-granularity streaming matmul. On CPU the kernel body runs in
    interpret mode (the TPU path compiles the same pallas_call natively);
    `use_kernel=False` falls back to the jnp oracle."""
    if not use_kernel:
        return rowstream_matmul_ref(x, w)
    return rowstream_matmul(x, w, interpret=interpret)


__all__ = ["matmul", "pick_bk"]
