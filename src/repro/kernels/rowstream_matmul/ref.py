"""Pure-jnp oracle for the row-stream matmul."""
from __future__ import annotations

import jax.numpy as jnp


def rowstream_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (m, k) @ w: (k, n) -> (m, n) accumulated in fp32, cast to x dtype."""
    out = jnp.einsum("mk,kn->mn", x, w, preferred_element_type=jnp.float32)
    return out.astype(x.dtype)
