"""Row-stream matmul Pallas TPU kernel — the RD_row analogue on TPU.

RoMe's insight adapted to the TPU memory hierarchy: every HBM->VMEM DMA of
the weight operand is one large *contiguous* block — a multiple of the 4 KB
DRAM row along the streamed (K) dimension with the full N extent — so the
HBM controller sees pure row-granularity streaming (one descriptor ≡ one
RD_row burst train), never strided cache-line gather. Block shapes are
MXU-aligned (multiples of 128 on the contraction/output dims).

Grid: (K // bk,) sequential; the fp32 accumulator lives in the output ref
(revisited each step — Pallas keeps it resident in VMEM across grid steps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DRAM_ROW_BYTES = 4096
MXU = 128


def _kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)


def pick_bk(k: int, n: int, itemsize: int, vmem_budget: int = 1 << 21) -> int:
    """Largest K-block that (a) keeps the weight block under the VMEM
    budget, (b) is a multiple of the MXU tile, and (c) makes the block a
    whole number of DRAM rows (bk * n * itemsize ≡ 0 mod 4096)."""
    bk = min(k, max(MXU, vmem_budget // max(1, n * itemsize)))
    bk -= bk % MXU
    bk = max(MXU, bk)
    while (bk * n * itemsize) % DRAM_ROW_BYTES and bk > MXU:
        bk -= MXU
    while k % bk and bk > MXU:
        bk -= MXU
    return max(MXU, bk)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def rowstream_matmul(x: jax.Array, w: jax.Array, bk: int | None = None,
                     interpret: bool = True) -> jax.Array:
    """x: (m, k) @ w: (k, n) -> (m, n). Weight streamed in row-aligned
    K-blocks of the full N width."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    if bk is None:
        bk = pick_bk(k, n, w.dtype.itemsize)
    assert k % bk == 0, (k, bk)
    grid = (k // bk,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, bk), lambda i: (0, i)),
            pl.BlockSpec((bk, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)
    return out.astype(x.dtype)
