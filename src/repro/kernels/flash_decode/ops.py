"""jit'd public wrapper for GQA flash decode."""
from __future__ import annotations

import jax

from .kernel import flash_decode, pick_block_s
from .ref import flash_decode_ref


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos, use_kernel: bool = True,
                     interpret: bool = True) -> jax.Array:
    """Row-granularity GQA decode attention; falls back to the jnp oracle
    with `use_kernel=False`."""
    if not use_kernel:
        return flash_decode_ref(q, k_cache, v_cache, pos)
    return flash_decode(q, k_cache, v_cache, pos, interpret=interpret)


__all__ = ["decode_attention", "pick_block_s"]
