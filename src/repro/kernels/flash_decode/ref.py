"""Pure-jnp oracle for GQA flash decode."""
from __future__ import annotations

import jax.numpy as jnp


def flash_decode_ref(q, k_cache, v_cache, pos):
    """q: (b, h, d); caches: (b, h_kv, s, d); pos: scalar int.
    Returns (b, h, d). Slots > pos are masked (unwritten)."""
    b, h, d = q.shape
    hkv = k_cache.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    logits = jnp.einsum("bngd,bnsd->bngs", qg, kf) / jnp.sqrt(float(d))
    s = k_cache.shape[2]
    mask = jnp.arange(s)[None, None, None, :] <= pos
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bngs,bnsd->bngd", p, vf)
    return out.reshape(b, h, d).astype(q.dtype)
