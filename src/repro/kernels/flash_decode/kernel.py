"""GQA flash-decode Pallas TPU kernel with row-granularity KV streaming.

One grid instance per (batch, kv-head); the KV sequence is visited in
blocks whose byte size is a whole number of 4 KB DRAM rows (block_s tokens
x head_dim x itemsize ≡ 0 mod 4096) — each KV DMA is one RD_row burst
train, the serving-side contract of the RoMe memory system (the paged KV
cache in repro.serve allocates at exactly this granularity).

Online softmax: running (max, sum, acc) scratch in VMEM across the
sequential S-blocks; the query group (all q heads sharing the kv head)
rides along so the MXU sees a (g x block_s) matmul instead of a GEMV.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...compat.pallas import tpu_compiler_params

DRAM_ROW_BYTES = 4096
NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    s_idx = pl.program_id(2)
    block_s = k_ref.shape[0]

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (g, d)
    k = k_ref[0, 0].astype(jnp.float32)                 # (block_s, d)
    v = v_ref[0, 0].astype(jnp.float32)                 # (block_s, d)
    d = q.shape[-1]
    logits = jnp.dot(q, k.T) / jnp.sqrt(float(d))       # (g, block_s)
    token_idx = s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    logits = jnp.where(token_idx <= pos_ref[0], logits, NEG_INF)

    m_prev = m_ref[...]                                  # (g, 1)
    m_new = jnp.maximum(m_prev, logits.max(-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)                          # (g, block_s)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(s_idx == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def pick_block_s(s: int, d: int, itemsize: int,
                 target_bytes: int = 1 << 16) -> int:
    """KV block length: a whole number of DRAM rows, >= 8 sublanes, and a
    divisor of the (padded) sequence."""
    rows_per_token = d * itemsize            # bytes per token per head
    bs = max(8, target_bytes // rows_per_token)
    while (bs * rows_per_token) % DRAM_ROW_BYTES and bs > 8:
        bs -= 8
    while s % bs and bs > 8:
        bs -= 8
    return max(8, bs)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 pos: jax.Array, block_s: int | None = None,
                 interpret: bool = True) -> jax.Array:
    """q: (b, h, d); caches: (b, h_kv, s, d); pos: scalar int32 (slots >
    pos are unwritten). Returns (b, h, d)."""
    b, h, d = q.shape
    _, hkv, s, _ = k_cache.shape
    g = h // hkv
    if block_s is None:
        block_s = pick_block_s(s, d, k_cache.dtype.itemsize)
    assert s % block_s == 0, (s, block_s)
    qg = q.reshape(b, hkv, g, d)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1,))

    grid = (b, hkv, s // block_s)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d), lambda i, j, k, pos: (i, j, 0, 0)),
                pl.BlockSpec((1, 1, block_s, d),
                             lambda i, j, k, pos: (i, j, k, 0)),
                pl.BlockSpec((1, 1, block_s, d),
                             lambda i, j, k, pos: (i, j, k, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda i, j, k, pos: (i, j, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),    # running max
                pltpu.VMEM((g, 1), jnp.float32),    # running sum
                pltpu.VMEM((g, d), jnp.float32),    # accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos_arr, qg, k_cache, v_cache)
    return out.reshape(b, h, d)
