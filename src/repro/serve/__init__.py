from .kv_cache import RowPagedKVCache, ROW_BYTES, tokens_per_row
from .batching import ContinuousBatcher, Request, RequestTimeline

__all__ = ["RowPagedKVCache", "ROW_BYTES", "tokens_per_row",
           "ContinuousBatcher", "Request", "RequestTimeline"]
