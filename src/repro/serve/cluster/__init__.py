"""Fleet-scale serving simulation: N replica cubes behind a router."""
from .router import (ROUTERS, LeastKVRouter, RoundRobinRouter, Router,
                     SessionAffinityRouter, SLOAwareRouter, make_router)
from .sim import (REJECTED, UNROUTED, ClusterResult, ClusterSim, Replica,
                  RoutedQueue)

__all__ = [
    "ClusterSim", "ClusterResult", "Replica", "RoutedQueue",
    "UNROUTED", "REJECTED",
    "Router", "RoundRobinRouter", "LeastKVRouter", "SessionAffinityRouter",
    "SLOAwareRouter", "ROUTERS", "make_router",
]
