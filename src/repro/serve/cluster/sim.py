"""Fleet-scale serving simulation: N replicas behind a router.

:class:`ClusterSim` scales the single-cube replay loop
(:class:`~repro.serve.replay.engine.ReplayEngine`) to a fleet: each
replica owns a :class:`~repro.serve.replay.recorder.ServeTraceRecorder`
(continuous batcher + row-paged KV pool + the shared weight slice) and
its own clock; one fleet-level
:class:`~repro.serve.replay.arrivals.ArrivalProcess` generates requests;
a pluggable :class:`~.router.Router` places (or rejects) each request at
routing time. One shared hybrid :class:`~repro.core.system_sim.SystemSim`
prices every replica's decode steps — replicas are homogeneous cubes,
and the fleet loop **explicitly opts into per-step reset semantics**
(``warm=False`` on every :meth:`~repro.core.system_sim.SystemSim
.run_steps` call): a whole round of steps can then be priced in one
batched, order-free call. Warm cross-step state
(:class:`~repro.core.system_sim.WarmRunState`) would force one
sequential session per replica and serialize the round — for
prefill-heavy studies that need it, run per-cube
``ReplayEngine(warm=True)`` instead (docs/serve_replay.md).

**Clock semantics.** Replica clocks advance independently; the fleet
loop is a conservative round-based discrete-event simulation. Each
iteration either (a) delivers every arrival up to the next-arrival
frontier to the router — so routing decisions always see replica states
no older than one decode step — or (b) steps, in one batched pricing
call, every replica whose next step starts strictly before that
frontier. Causality is therefore respected to within one decode step:
the same granularity at which the single-cube engine batches admissions
(requests landing mid-step wait for the step boundary there too).
Closed-loop completions are replayed into the arrival process in global
(completion time, rid) order, so seeded runs are bit-reproducible — and
``workers`` only parallelizes cycle-path channel sims, which are
bit-identical to their serial runs, so the worker count can never change
a result.

**Why it scales.** Millions of requests are tractable because every
per-step cost the naive N× replication pays is hoisted or batched: the
queue-window features of a whole fleet round are extracted in one
vectorized census (:func:`~repro.core.queue_model.stream_features_many`),
repeated step shapes hit the :class:`~repro.core.queue_model.StepPricer`
signature cache instead of being re-priced, arrival delivery is a
bisect (not a scan) per round, cycle-path channels run in the shared
persistent process pool, and per-request bookkeeping lives in flat
numpy arrays (:class:`ClusterResult`) with recorder-side dicts pruned at
completion.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field

import numpy as np

from ...core.system_sim import SystemSim
from ..replay.arrivals import ArrivalProcess, RequestSpec
from ..replay.recorder import (KV_BASE_ADDR, ServeTraceRecorder,
                               make_kv_cache, weight_step_stream)
from .router import Router, make_router

#: replica_of sentinel values
UNROUTED = -1
REJECTED = -2


class RoutedQueue:
    """Per-replica arrival queue, duck-typed as the recorder's
    ``ArrivalProcess``. The fleet router pushes specs in global
    (arrival, rid) order — each push is therefore an append — and the
    recorder pops them with the same bisect-pointer ``due`` discipline
    as the real process. ``on_complete`` is a no-op here: closed-loop
    regeneration belongs to the *fleet* arrival process and is driven
    by :class:`ClusterSim` in deterministic completion order.
    """

    def __init__(self):
        self._q: list[RequestSpec] = []
        self._next = 0
        self.closed = False          # fleet arrivals exhausted

    def push(self, spec: RequestSpec) -> None:
        self._q.append(spec)

    def pending(self) -> int:
        return len(self._q) - self._next

    def due(self, now_ns: float) -> list[RequestSpec]:
        q, lo = self._q, self._next
        hi = bisect.bisect_right(q, now_ns, lo=lo,
                                 key=lambda s: s.arrival_ns)
        if hi == lo:
            return []
        out = q[lo:hi]
        self._next = hi
        if self._next > 4096 and self._next * 2 > len(q):
            del q[:self._next]
            self._next = 0
        return out

    def next_arrival_ns(self) -> float | None:
        if self._next >= len(self._q):
            return None
        return self._q[self._next].arrival_ns

    def on_complete(self, now_ns: float) -> None:
        pass

    def exhausted(self) -> bool:
        return self.closed and self._next >= len(self._q)


class Replica:
    """One serving replica: recorder + routed queue + private clock."""

    def __init__(self, index: int, cache, weight_stream, kv_offset_ns,
                 kv_base_addr, n_slots: int):
        self.index = index
        self.n_slots = n_slots
        self.queue = RoutedQueue()
        self.rec = ServeTraceRecorder(self.queue, cache,
                                      weight_stream=weight_stream,
                                      kv_offset_ns=kv_offset_ns,
                                      kv_base_addr=kv_base_addr)
        self.clock = 0.0
        self.ema_step_ns = 0.0
        #: worst-case KV pages of every routed-but-not-finished request —
        #: the admission currency the least_kv router balances.
        self.outstanding_pages = 0
        self._worst: dict[int, int] = {}
        self.n_steps = 0
        self.n_requests = 0

    def backlog(self) -> int:
        """Requests routed here but not yet admitted to a batch slot."""
        return self.queue.pending() + len(self.rec.batcher.queue)

    def push(self, spec: RequestSpec) -> None:
        worst = self.rec.cache.pages_for(spec.prompt_len
                                         + spec.max_new_tokens)
        self._worst[spec.rid] = worst
        self.outstanding_pages += worst
        self.n_requests += 1
        self.queue.push(spec)

    def next_event_ns(self) -> float | None:
        """Earliest time this replica can run a decode step: now if the
        batcher holds work, else its next routed arrival; None when it
        has nothing at all."""
        if not self.rec.idle():
            return self.clock
        nq = self.queue.next_arrival_ns()
        if nq is None:
            return None
        return max(self.clock, nq)

    def begin_step(self):
        """Advance to the next event and emit that step's trace."""
        t = self.next_event_ns()
        self.clock = t
        self.rec.submit_due(t)
        st = self.rec.step(t)
        assert st is not None, "begin_step called with no runnable work"
        return st

    def finish_step(self, st, dur_ns: float) -> float:
        """Fold the measured duration back: advance the clock, update
        the EMA the SLO router reads, release finished requests' page
        reservations, and prune recorder-side bookkeeping so memory
        stays O(live requests) across million-request sweeps."""
        end = self.clock + dur_ns
        self.clock = end
        self.ema_step_ns = (dur_ns if self.ema_step_ns == 0.0
                            else 0.8 * self.ema_step_ns + 0.2 * dur_ns)
        self.n_steps += 1
        for rid in st.finished:
            self.outstanding_pages -= self._worst.pop(rid)
            self.rec.requests.pop(rid, None)
            self.rec.specs.pop(rid, None)
        self.rec.batcher.completed.clear()
        return end


@dataclass
class ClusterResult:
    """Flat-array fleet outcome: per-request timelines indexed by rid
    (numpy, not per-request objects — a million-request sweep stays a
    few hundred MB of arrays, not millions of dataclasses)."""

    n_replicas: int
    arrival_ns: np.ndarray          # -1 = never issued (closed-loop budget)
    admitted_ns: np.ndarray         # -1 = never admitted
    first_token_ns: np.ndarray
    completed_ns: np.ndarray
    n_out: np.ndarray
    replica_of: np.ndarray          # UNROUTED / REJECTED sentinels
    makespan_ns: float
    steps_total: int
    steps_analytic: int
    bytes_moved: int
    occupancy: float
    requests_per_replica: np.ndarray
    steps_per_replica: np.ndarray
    pricer_stats: dict = field(default_factory=dict)

    @property
    def issued(self) -> int:
        return int((self.arrival_ns >= 0).sum())

    @property
    def completed(self) -> int:
        return int((self.completed_ns >= 0).sum())

    @property
    def rejected(self) -> int:
        return int((self.replica_of == REJECTED).sum())

    @property
    def goodput_rps(self) -> float:
        if self.makespan_ns <= 0:
            return 0.0
        return self.completed / (self.makespan_ns / 1e9)

    @property
    def hybrid_fraction(self) -> float:
        if not self.steps_total:
            return 0.0
        return self.steps_analytic / self.steps_total

    @property
    def ttfts_ns(self) -> np.ndarray:
        m = self.first_token_ns >= 0
        return self.first_token_ns[m] - self.arrival_ns[m]

    @property
    def tpots_ns(self) -> np.ndarray:
        m = (self.completed_ns >= 0) & (self.n_out >= 2)
        return ((self.completed_ns[m] - self.first_token_ns[m])
                / (self.n_out[m] - 1))

    def slo_goodput_rps(self, ttft_slo_ns: float,
                        tpot_slo_ns: float = float("inf")) -> float:
        """Completed-*within-deadline* requests per simulated second —
        the metric the SLO-aware router optimizes (a late token is a
        miss, not a partial credit)."""
        if self.makespan_ns <= 0:
            return 0.0
        done = self.completed_ns >= 0
        ttft = self.first_token_ns - self.arrival_ns
        ok = done & (ttft <= ttft_slo_ns)
        multi = done & (self.n_out >= 2)
        tpot = np.zeros_like(self.completed_ns)
        tpot[multi] = ((self.completed_ns[multi]
                        - self.first_token_ns[multi])
                       / (self.n_out[multi] - 1))
        ok &= ~multi | (tpot <= tpot_slo_ns)
        return float(ok.sum()) / (self.makespan_ns / 1e9)

    def percentiles(self, values: np.ndarray,
                    qs=(50, 95, 99)) -> dict:
        if values.size == 0:
            return {f"p{q}": 0.0 for q in qs}
        return {f"p{q}": round(float(np.percentile(values, q)), 1)
                for q in qs}

    def summary(self) -> dict:
        out = {
            "n_replicas": self.n_replicas,
            "n_requests": self.issued,
            "completed": self.completed,
            "rejected": self.rejected,
            "n_steps": self.steps_total,
            "makespan_ns": round(self.makespan_ns, 1),
            "occupancy": round(self.occupancy, 4),
            "goodput_rps": round(self.goodput_rps, 1),
            "bytes_moved": int(self.bytes_moved),
            "hybrid_fraction": round(self.hybrid_fraction, 4),
            "max_replica_share": round(
                float(self.requests_per_replica.max())
                / max(1, self.issued), 4),
        }
        for name, vals in (("ttft", self.ttfts_ns), ("tpot", self.tpots_ns)):
            for k, v in self.percentiles(vals).items():
                out[f"{name}_{k}_ns"] = v
            out[f"{name}_mean_ns"] = (round(float(vals.mean()), 1)
                                      if vals.size else 0.0)
        if self.pricer_stats:
            out["pricer_hit_rate"] = self.pricer_stats.get("hit_rate", 0.0)
        return out


class ClusterSim:
    """N homogeneous replicas + router + one shared pricing SystemSim.

    Construction mirrors :func:`~repro.serve.replay.engine.build_replay`
    per replica (same policy registry, same scaled weight slice, same KV
    sizing); ``router`` is a registered name or a :class:`~.router
    .Router` instance. ``attach_pricer=True`` (default) routes all step
    pricing through a shared :class:`~repro.core.queue_model.StepPricer`
    signature cache whose stats land in the result.
    """

    def __init__(self, workload: str = "deepseek-v3",
                 policy: str = "hbm4_frfcfs",
                 n_replicas: int = 4,
                 router="round_robin",
                 rate_rps: float = 1e5,
                 n_requests: int = 64,
                 kind: str = "poisson",
                 seed: int = 0,
                 length_scale: float = 1 / 32,
                 n_slots: int = 4,
                 n_ops: int = 4,
                 scale: float = 1.0,
                 n_channels: int = 8,
                 sim_mode: str = "hybrid",
                 overhead_ns: float = 0.0,
                 workers: int = 1,
                 mix=None,
                 attach_pricer: bool = True,
                 recheck_every: int = 64,
                 max_steps: int = 20_000_000,
                 keep_sample_streams: int = 0,
                 warm: bool = False,
                 collector=None,
                 **arrival_kw):
        if warm:
            raise NotImplementedError(
                "ClusterSim prices whole fleet rounds in one batched "
                "run_steps call and therefore opts into per-step reset "
                "semantics; warm cross-step state would serialize every "
                "round into per-replica sessions. For warm (prefill-"
                "aware) studies run a per-cube ReplayEngine(warm=True) — "
                "see docs/serve_replay.md.")
        from ...configs.paper_workloads import PAPER_WORKLOADS, SERVING_MIXES
        from ...core.sched.registry import policy_spec
        from ...perfmodel.accelerator import scaled_accelerator
        from ...trace.layergraph import ROW

        spec = policy_spec(policy)
        w = PAPER_WORKLOADS[workload]
        mix = SERVING_MIXES[workload] if mix is None else mix
        acc = scaled_accelerator(spec.family, n_channels=n_channels)
        ws, chain_ns = weight_step_stream(w, acc, n_ops=n_ops, scale=scale)
        w_end = max((r.end for r in ws), default=0)
        kv_base = max(KV_BASE_ADDR, -(-w_end // ROW) * ROW)
        max_tokens = (max(1, round(mix.prompt_max * length_scale))
                      + max(1, round(mix.out_max * length_scale)))
        self.arrivals = ArrivalProcess(kind, rate_rps, n_requests, mix=mix,
                                       length_scale=length_scale, seed=seed,
                                       **arrival_kw)
        self.replicas = [
            Replica(i, make_kv_cache(n_slots, max_tokens), ws, chain_ns,
                    kv_base, n_slots)
            for i in range(n_replicas)]
        self.router: Router = make_router(router)
        self.system: SystemSim = spec.system_sim(n_channels=n_channels,
                                                 mode=sim_mode)
        if attach_pricer:
            self.system.attach_pricer(recheck_every=recheck_every)
        #: optional :class:`repro.obs.ObsCollector` — every replica step
        #: lands as a span event on its replica's track, and the folded
        #: request marks carry the owning replica; a collector-borne
        #: probe also samples the shared system's cycle-path channels.
        self.collector = collector
        if collector is not None and collector.probe is not None:
            self.system.attach_probe(collector.probe)
        self.overhead_ns = overhead_ns
        self.workers = workers
        self.max_steps = max_steps
        self.keep_sample_streams = keep_sample_streams
        self.sample_streams: list = []

    # -- fleet loop ----------------------------------------------------------

    def run(self) -> ClusterResult:
        arr = self.arrivals
        reps = self.replicas
        n = arr.n_requests
        arrival = np.full(n, -1.0)
        admitted = np.full(n, -1.0)
        first_tok = np.full(n, -1.0)
        completed = np.full(n, -1.0)
        n_out = np.zeros(n, np.int64)
        replica_of = np.full(n, UNROUTED, np.int64)
        steps_total = steps_analytic = 0
        bytes_moved = 0

        def route(T: float) -> None:
            for spec in arr.due(T):
                arrival[spec.rid] = spec.arrival_ns
                ri = self.router.place(spec, reps, spec.arrival_ns)
                if ri is None:
                    replica_of[spec.rid] = REJECTED
                    # Closed loop: a rejected user got a fast error and
                    # moves on to their next request after a think time.
                    arr.on_complete(spec.arrival_ns)
                else:
                    replica_of[spec.rid] = ri
                    reps[ri].push(spec)

        while True:
            na = arr.next_arrival_ns()
            live = [(t, i) for i, r in enumerate(reps)
                    if (t := r.next_event_ns()) is not None]
            if not live:
                if na is None:
                    break
                route(na)
                continue
            if na is not None and na <= min(t for t, _ in live):
                # Deliver arrivals before anyone steps past them: the
                # router must never see a replica state from the future.
                route(na)
                continue
            stepping = [i for t, i in live if na is None or t < na]
            traces = [(i, reps[i].begin_step()) for i in stepping]
            # warm=False by contract: rounds mix steps of *different*
            # replicas, so carrying channel state across the batch would
            # couple cubes that share no hardware (module docstring).
            results = self.system.run_steps(
                [st.stream for _, st in traces],
                workers=self.workers,
                starts_ns=[st.start_ns for _, st in traces],
                warm=False)
            completions: list[tuple[float, int]] = []
            for (i, st), res in zip(traces, results):
                dur = res.total_ns + self.overhead_ns
                end = reps[i].finish_step(st, dur)
                if self.collector is not None:
                    self.collector.on_step(st, res, st.start_ns, dur,
                                           replica=i)
                steps_total += 1
                steps_analytic += res.mode == "analytic"
                bytes_moved += res.bytes_moved
                for rid in st.admitted:
                    admitted[rid] = st.start_ns
                for rid in st.active:
                    n_out[rid] += 1
                    if first_tok[rid] < 0:
                        first_tok[rid] = end
                for rid in st.finished:
                    completed[rid] = end
                    completions.append((end, rid))
                if len(self.sample_streams) < self.keep_sample_streams:
                    self.sample_streams.append(st.stream)
            # Deterministic closed-loop regeneration: completions feed
            # the seeded generator in global (time, rid) order no matter
            # which replicas stepped together this round.
            for end, rid in sorted(completions):
                arr.on_complete(end)
            if steps_total > self.max_steps:
                raise RuntimeError(
                    f"cluster exceeded max_steps={self.max_steps}; "
                    f"offered load far beyond fleet capacity?")
        for r in reps:
            r.queue.closed = True
        if self.collector is not None:
            # Per-replica folding: each request's lifecycle marks carry
            # the replica the router placed it on (rejected/unrouted
            # requests fold on replica 0, flagged incomplete).
            for rid in range(n):
                if arrival[rid] < 0:
                    continue
                self.collector.add_request(
                    rid, replica=max(int(replica_of[rid]), 0),
                    arrival_ns=float(arrival[rid]),
                    admitted_ns=float(admitted[rid]),
                    first_token_ns=float(first_tok[rid]),
                    completed_ns=float(completed[rid]),
                    n_out=int(n_out[rid]))

        slot_steps = sum(r.rec.batcher.slot_steps for r in reps)
        busy = sum(r.rec.batcher.busy_slot_steps for r in reps)
        pricer = self.system.pricer
        return ClusterResult(
            n_replicas=len(reps),
            arrival_ns=arrival,
            admitted_ns=admitted,
            first_token_ns=first_tok,
            completed_ns=completed,
            n_out=n_out,
            replica_of=replica_of,
            makespan_ns=float(max((r.clock for r in reps), default=0.0)),
            steps_total=steps_total,
            steps_analytic=steps_analytic,
            bytes_moved=int(bytes_moved),
            occupancy=busy / slot_steps if slot_steps else 0.0,
            requests_per_replica=np.array([r.n_requests for r in reps],
                                          np.int64),
            steps_per_replica=np.array([r.n_steps for r in reps],
                                       np.int64),
            pricer_stats=dict(pricer.stats) if pricer is not None else {},
        )


__all__ = ["ClusterSim", "ClusterResult", "Replica", "RoutedQueue",
           "UNROUTED", "REJECTED"]
