"""Pluggable request placement for :class:`~repro.serve.cluster.ClusterSim`.

A router sees one :class:`~repro.serve.replay.arrivals.RequestSpec` at a
time — in global (arrival, rid) order — plus the live replica states,
and returns the replica index to enqueue it on (or ``None`` to reject
it at admission). The registry mirrors the scheduler-policy registry's
shape: small named strategy classes behind a factory, so sweeps treat
the placement policy as one more axis.

Placement policies:

``round_robin``
    Stateless rotation — the baseline every serving stack ships.
``least_kv``
    Least outstanding worst-case KV pages (committed + routed-but-not-
    admitted): the pool-aware balancer, which tracks the real admission
    currency of :class:`~repro.serve.replay.recorder.ServeTraceRecorder`.
``session_affinity``
    Sticky hashing of a session key onto replicas. Sessions are a
    stand-in keyed by ``rid mod n_sessions`` (the request generator has
    no user identity beyond the closed-loop user count, for which
    ``n_sessions = n_users`` makes the mapping exact at steady state):
    it models the real-world sticky-routing regime where one user's
    requests always land where their KV/prefix state lives — and shows
    its cost, hot replicas that the load-aware policies would shed.
``slo_aware``
    Deadline-aware admission over the least-loaded replica: estimates
    the queue wait from each replica's clock lag, backlog depth, and
    its EMA step duration, places on the minimum, and *rejects* the
    request when even that minimum violates the TTFT deadline — turning
    overload into fast-failure instead of unbounded queueing (goodput,
    not throughput).
"""
from __future__ import annotations

import numpy as np


class Router:
    """Base placement policy. Subclasses override :meth:`place`."""

    name = "base"

    def place(self, spec, replicas, now_ns: float):
        """Replica index for ``spec``, or None to reject at admission."""
        raise NotImplementedError

    @staticmethod
    def est_wait_ns(replica, now_ns: float) -> float:
        """Estimated admission wait on one replica: how far its clock
        already ran ahead of the arrival, plus one EMA step duration per
        backlog wave (``ceil(backlog / slots)`` admission rounds)."""
        backlog = replica.backlog()
        waves = -(-backlog // replica.n_slots) if backlog else 0
        return (max(replica.clock - now_ns, 0.0)
                + (waves + 1) * replica.ema_step_ns)


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self):
        self._i = 0

    def place(self, spec, replicas, now_ns):
        i = self._i % len(replicas)
        self._i += 1
        return i


class LeastKVRouter(Router):
    name = "least_kv"

    def place(self, spec, replicas, now_ns):
        return min(range(len(replicas)),
                   key=lambda i: (replicas[i].outstanding_pages, i))


class SessionAffinityRouter(Router):
    name = "session_affinity"

    #: Knuth multiplicative hash constant — spreads consecutive session
    #: ids across replicas instead of striding them.
    _MULT = 2654435761

    def __init__(self, n_sessions: int = 64):
        if n_sessions < 1:
            raise ValueError(f"n_sessions must be >= 1, got {n_sessions}")
        self.n_sessions = n_sessions

    def place(self, spec, replicas, now_ns):
        session = spec.rid % self.n_sessions
        return (session * self._MULT) % (1 << 32) % len(replicas)


class SLOAwareRouter(Router):
    name = "slo_aware"

    def __init__(self, ttft_slo_ns: float = float("inf")):
        self.ttft_slo_ns = ttft_slo_ns

    def place(self, spec, replicas, now_ns):
        waits = [self.est_wait_ns(r, now_ns) for r in replicas]
        best = int(np.argmin(waits))
        if waits[best] > self.ttft_slo_ns:
            return None
        return best


ROUTERS = {
    "round_robin": RoundRobinRouter,
    "least_kv": LeastKVRouter,
    "session_affinity": SessionAffinityRouter,
    "slo_aware": SLOAwareRouter,
}


def make_router(name, **kwargs) -> Router:
    """Instantiate a registered router by name (a :class:`Router`
    instance passes through unchanged)."""
    if isinstance(name, Router):
        return name
    if name not in ROUTERS:
        raise ValueError(
            f"unknown router {name!r}; registered: {sorted(ROUTERS)}")
    return ROUTERS[name](**kwargs)


__all__ = ["Router", "RoundRobinRouter", "LeastKVRouter",
           "SessionAffinityRouter", "SLOAwareRouter", "ROUTERS",
           "make_router"]
