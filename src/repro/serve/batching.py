"""Continuous batching (Orca-style iteration-level scheduling).

Requests join/leave the running decode batch at token boundaries; a fixed
batch-slot array keeps the jit'd decode step shape-stable (empty slots are
masked). The scheduler is host-side and O(batch) per step; admission is
FIFO with a KV-pool admission check so the pool can never thrash.

**Chunked prefill** (Sarathi-style): with ``prefill_chunk_tokens`` set,
an admitted request does not start decoding immediately — its prompt is
prefilled in chunks drawn from a per-step token budget
(:meth:`ContinuousBatcher.prefill_pack` /
:meth:`~ContinuousBatcher.apply_prefill`), interleaved with the running
decode batch, and the request joins decode only on the step *after* its
last chunk lands. Without the knob, behaviour is exactly the legacy
whole-prompt-at-admission model. How chunks turn into memory traffic —
and whether their fetch overlaps the decode window (packing-prefetch) —
is the replay recorder's job (:mod:`repro.serve.replay`,
docs/serve_replay.md).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class RequestTimeline:
    """Step indices of a request's lifecycle events, maintained by
    :class:`ContinuousBatcher`. An index refers to the decode step *about
    to run* when the event happened (0-based count of completed steps);
    ``-1`` means the event has not happened yet. The serving replay
    (:mod:`repro.serve.replay`) folds memory-system makespans back onto
    these indices to produce TTFT/TPOT in nanoseconds.
    """

    submitted_step: int = -1     # entered the wait queue
    admitted_step: int = -1      # first step it occupies a slot in
    prefill_done_step: int = -1  # step whose prefill pack finished the prompt
    first_token_step: int = -1   # step that produced its first token
    completed_step: int = -1     # step that produced its last token

    @property
    def decode_steps(self) -> int:
        """Steps spent decoding (== tokens produced) once completed."""
        if self.completed_step < 0 or self.admitted_step < 0:
            return 0
        return self.completed_step - self.admitted_step + 1


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    out_tokens: list = field(default_factory=list)
    slot: int = -1
    done: bool = False
    timeline: RequestTimeline = field(default_factory=RequestTimeline)
    #: prompt tokens whose KV has been prefilled so far; reaches
    #: prompt_len instantly at admission in legacy (unchunked) mode.
    prefilled_tokens: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def prefill_done(self) -> bool:
        return self.prefilled_tokens >= self.prompt_len


class ContinuousBatcher:
    """Iteration-level scheduler over a fixed number of batch slots.

    ``prefill_chunk_tokens`` (None = legacy whole-prompt-at-admission)
    sets the per-step prompt-token budget for chunked prefill: each step,
    :meth:`prefill_pack` proposes up to that many prompt tokens across
    the admitted-but-unprefilled requests (FIFO), the caller turns the
    pack into memory traffic, and :meth:`apply_prefill` commits it after
    the step's tokens are accounted — so a request whose last chunk
    lands during step *i* starts decoding at step *i + 1*.
    """

    def __init__(self, n_slots: int, admit: Optional[Callable] = None,
                 prefill_chunk_tokens: Optional[int] = None):
        if prefill_chunk_tokens is not None and prefill_chunk_tokens < 1:
            raise ValueError(
                f"prefill_chunk_tokens must be >= 1 (or None for legacy "
                f"instant prefill), got {prefill_chunk_tokens}")
        self.n_slots = n_slots
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * n_slots
        self.admit = admit or (lambda req: True)
        self.completed: list[Request] = []
        self.steps = 0
        self.slot_steps = 0
        self.busy_slot_steps = 0

    def submit(self, req: Request) -> None:
        req.timeline.submitted_step = self.steps
        self.queue.append(req)

    # -- one scheduling iteration ---------------------------------------------

    def schedule(self) -> list[tuple[int, Request]]:
        """Fill free slots from the queue (FIFO + admission check); returns
        newly admitted (slot, request) pairs — callers run prefill for them."""
        admitted = []
        for slot in range(self.n_slots):
            if self.active[slot] is not None or not self.queue:
                continue
            if not self.admit(self.queue[0]):
                break                        # pool full: preserve FIFO order
            req = self.queue.popleft()
            req.slot = slot
            req.timeline.admitted_step = self.steps
            if self.prefill_chunk_tokens is None:
                # Legacy model: the whole prompt is prefilled at
                # admission (the caller emits it analytically or not at
                # all); the request decodes from its first step.
                req.prefilled_tokens = req.prompt_len
                req.timeline.prefill_done_step = self.steps
            self.active[slot] = req
            admitted.append((slot, req))
        return admitted

    def prefill_pack(self) -> list[tuple[int, "Request", int]]:
        """The next step's prefill work: up to ``prefill_chunk_tokens``
        prompt tokens across admitted-but-unprefilled requests, FIFO by
        admission order. Returns (slot, request, n_tokens) triples —
        pure proposal, commits nothing; hand the pack back to
        :meth:`apply_prefill` once the step it rode in has been
        accounted. Empty in legacy mode."""
        if self.prefill_chunk_tokens is None:
            return []
        budget = self.prefill_chunk_tokens
        pack = []
        pending = sorted(
            ((req.timeline.admitted_step, slot, req)
             for slot, req in enumerate(self.active)
             if req is not None and not req.prefill_done))
        for _, slot, req in pending:
            if budget <= 0:
                break
            take = min(budget, req.prompt_len - req.prefilled_tokens)
            pack.append((slot, req, take))
            budget -= take
        return pack

    def apply_prefill(self, pack: list) -> list["Request"]:
        """Commit a :meth:`prefill_pack` after the step that carried it
        (call *after* :meth:`record_tokens`, so a request finishing its
        prompt during step *i* is decode-eligible at step *i + 1*).
        Returns the requests whose prefill just completed."""
        done = []
        for _, req, take in pack:
            req.prefilled_tokens += take
            if req.prefill_done:
                req.timeline.prefill_done_step = self.steps - 1
                done.append(req)
        return done

    def record_tokens(self, tokens: np.ndarray,
                      decode: bool = True) -> list[Request]:
        """Account one step's sampled tokens (n_slots,); retire finished
        requests. Returns the requests that completed this step.
        Requests still mid-prefill occupy (and are billed for) their
        slot but emit no token. ``decode=False`` accounts a
        prefill-only step — the step counter and slot accounting
        advance, but no slot samples (the no-overlap packing-prefetch
        schedule stalls decode while a prefill chunk streams in)."""
        step = self.steps
        self.steps += 1
        finished = []
        for slot, req in enumerate(self.active):
            self.slot_steps += 1
            if req is None:
                continue
            self.busy_slot_steps += 1
            if not decode or not req.prefill_done:
                continue
            req.out_tokens.append(int(tokens[slot]))
            if len(req.out_tokens) == 1:
                req.timeline.first_token_step = step
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                req.slot = -1
                req.timeline.completed_step = step
                self.active[slot] = None
                self.completed.append(req)
                finished.append(req)
        return finished

    @property
    def occupancy(self) -> float:
        """Fraction of slot-steps that carried a live request."""
        return (self.busy_slot_steps / self.slot_steps
                if self.slot_steps else 0.0)

    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.active)
