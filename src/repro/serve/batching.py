"""Continuous batching (Orca-style iteration-level scheduling).

Requests join/leave the running decode batch at token boundaries; a fixed
batch-slot array keeps the jit'd decode step shape-stable (empty slots are
masked). The scheduler is host-side and O(batch) per step; admission is
FIFO with a KV-pool admission check so the pool can never thrash.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class RequestTimeline:
    """Step indices of a request's lifecycle events, maintained by
    :class:`ContinuousBatcher`. An index refers to the decode step *about
    to run* when the event happened (0-based count of completed steps);
    ``-1`` means the event has not happened yet. The serving replay
    (:mod:`repro.serve.replay`) folds memory-system makespans back onto
    these indices to produce TTFT/TPOT in nanoseconds.
    """

    submitted_step: int = -1     # entered the wait queue
    admitted_step: int = -1      # first decode step it participates in
    first_token_step: int = -1   # step that produced its first token
    completed_step: int = -1     # step that produced its last token

    @property
    def decode_steps(self) -> int:
        """Steps spent decoding (== tokens produced) once completed."""
        if self.completed_step < 0 or self.admitted_step < 0:
            return 0
        return self.completed_step - self.admitted_step + 1


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    out_tokens: list = field(default_factory=list)
    slot: int = -1
    done: bool = False
    timeline: RequestTimeline = field(default_factory=RequestTimeline)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


class ContinuousBatcher:
    """Iteration-level scheduler over a fixed number of batch slots."""

    def __init__(self, n_slots: int, admit: Optional[Callable] = None):
        self.n_slots = n_slots
        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * n_slots
        self.admit = admit or (lambda req: True)
        self.completed: list[Request] = []
        self.steps = 0
        self.slot_steps = 0
        self.busy_slot_steps = 0

    def submit(self, req: Request) -> None:
        req.timeline.submitted_step = self.steps
        self.queue.append(req)

    # -- one scheduling iteration ---------------------------------------------

    def schedule(self) -> list[tuple[int, Request]]:
        """Fill free slots from the queue (FIFO + admission check); returns
        newly admitted (slot, request) pairs — callers run prefill for them."""
        admitted = []
        for slot in range(self.n_slots):
            if self.active[slot] is not None or not self.queue:
                continue
            if not self.admit(self.queue[0]):
                break                        # pool full: preserve FIFO order
            req = self.queue.popleft()
            req.slot = slot
            req.timeline.admitted_step = self.steps
            self.active[slot] = req
            admitted.append((slot, req))
        return admitted

    def record_tokens(self, tokens: np.ndarray) -> list[Request]:
        """Account one decode step's sampled tokens (n_slots,); retire
        finished requests. Returns the requests that completed this step."""
        step = self.steps
        self.steps += 1
        finished = []
        for slot, req in enumerate(self.active):
            self.slot_steps += 1
            if req is None:
                continue
            self.busy_slot_steps += 1
            req.out_tokens.append(int(tokens[slot]))
            if len(req.out_tokens) == 1:
                req.timeline.first_token_step = step
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                req.slot = -1
                req.timeline.completed_step = step
                self.active[slot] = None
                self.completed.append(req)
                finished.append(req)
        return finished

    @property
    def occupancy(self) -> float:
        """Fraction of slot-steps that carried a live request."""
        return (self.busy_slot_steps / self.slot_steps
                if self.slot_steps else 0.0)

    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.active)
