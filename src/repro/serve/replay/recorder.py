"""Serve-trace recording: continuous batching -> per-step extent streams.

:class:`ServeTraceRecorder` is the bridge between the serving layer and
the memory system. It owns a :class:`~repro.serve.batching.ContinuousBatcher`
and a :class:`~repro.serve.kv_cache.RowPagedKVCache`, drives them one
decode step at a time, and emits each step as one multi-tenant
:class:`~repro.workloads.ExtentStream`:

* **weight reads** — a scaled weights-only decode slice built once via
  :func:`weight_step_stream` (``from_layer_ops`` pacing, so intra-step
  op serialization survives), shifted to the step's start time and
  tagged with *negative* stream ids (``-1 - op_index``);
* **KV reads** — one whole-page :meth:`~RowPagedKVCache.read_stream`
  per active slot, retagged with the request id;
* **KV appends** — one :meth:`~RowPagedKVCache.append_stream` per
  active slot (the decoded token's K/V write), retagged likewise;
* **prefill extents** (``prefill_chunk_tokens`` set) — per prefill
  chunk, the chunk-attention *prefix read* (whole-page reads of the
  context prefilled so far) plus the chunk's prompt-scale K/V appends
  coalesced to row-granular page runs
  (:meth:`~RowPagedKVCache.append_chunk_stream`). With
  ``prefill_overlap=True`` (packing-prefetch) the chunk's fetch is
  packed into the concurrent decode step's stream — hidden under the
  decode compute window; with ``prefill_overlap=False`` a pending chunk
  claims a dedicated prefill-only step and decode stalls for its
  duration (classic prefill-priority alternation).

The negative-vs-nonnegative stream-id split is the tagging contract:
consumers can always separate weight traffic from request traffic, and
``of_stream(rid)`` recovers exactly one request's KV records — the
conservation property tests/test_serve_replay.py pins.

Admission control reserves the *worst case* — ``pages_for(prompt +
max_new)`` — against the pool before a request joins the batch, so a
recorded run can never hit ``MemoryError`` mid-decode (the batcher's
FIFO admission check would otherwise only cover the prompt).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from ...trace.layergraph import LayerOp, decode_ops
from ...workloads import (ExtentStream, from_layer_ops, layer_ops_span_ns,
                          scale_layer_ops)
from ..batching import ContinuousBatcher, Request
from ..kv_cache import RowPagedKVCache, tokens_per_row
from .arrivals import ArrivalProcess, RequestSpec

#: Weight records are tagged ``WEIGHT_STREAM_BASE - op_index`` — negative,
#: so they can never collide with request ids (which are >= 0).
WEIGHT_STREAM_BASE = -1

#: Default KV-pool base address: beyond any scaled weight slice's
#: allocator cursor, so weights and KV never alias.
KV_BASE_ADDR = 64 << 20


def weight_ops(w, n_ops: int = 4, n_devices: int = 8) -> list[LayerOp]:
    """The first ``n_ops`` decode layer ops reduced to their *weight*
    reads: KV-read extents and activation/KV writes are stripped (live KV
    traffic comes from the paged cache at replay time). For attention
    ops the weight tensor is the first extent; FFN/MoE ops read only
    weights to begin with."""
    ops = decode_ops(w, batch=1, seq_len=1, n_devices=n_devices)[:n_ops]
    return [LayerOp(op.name, op.kind, op.flops,
                    op.extents[:1] if op.kind == "attn"
                    else list(op.extents))
            for op in ops]


def weight_step_stream(w, acc, n_ops: int = 4,
                       scale: float = 2 ** -15) -> tuple[ExtentStream, float]:
    """One decode step's weight-read stream, byte-scaled for cycle-level
    tractability (cf. ``perfmodel.tpot.xval_decode_stream``) and tagged
    with negative stream ids. Built once per replay and shifted to each
    step's start time.

    Returns ``(stream, chain_ns)`` — the records plus the modeled
    roofline span of the whole op chain
    (:func:`repro.workloads.layer_ops_span_ns`, the same pacing rule
    ``from_layer_ops`` applies between ops). ``chain_ns`` is the natural
    ``kv_offset_ns`` for the recorder: the per-slot KV gather/append
    group then becomes visible exactly like the op *following* the
    slice, which is the serialized-group regime the analytic TPOT model
    (``stream_mem_ns``) is valid in.
    """
    ops = scale_layer_ops(weight_ops(w, n_ops), scale)
    s = from_layer_ops(ops, acc)
    return ExtentStream(
        replace(r, stream_id=WEIGHT_STREAM_BASE - r.stream_id)
        for r in s), layer_ops_span_ns(ops, acc)


def make_kv_cache(n_slots: int, max_seq_tokens: int,
                  n_kv_heads: int = 2, head_dim: int = 64,
                  rows_per_page: int = 1, headroom: int = 2,
                  dtype: str = "bfloat16") -> RowPagedKVCache:
    """A row-paged KV pool sized so ``n_slots`` concurrent sequences of up
    to ``max_seq_tokens`` always fit (plus ``headroom`` spare pages). The
    scaled-down KV geometry mirrors the byte-scaling of the weight slice:
    what the memory system sees is whole-row K/V page streams either way.
    """
    pt = tokens_per_row(head_dim, n_kv_heads, rows_per_page=rows_per_page)
    pages_per_seq = -(-max_seq_tokens // pt)
    return RowPagedKVCache(
        n_pages=n_slots * pages_per_seq + headroom, page_tokens=pt,
        n_kv_heads=n_kv_heads, head_dim=head_dim, max_seqs=n_slots,
        max_pages_per_seq=pages_per_seq, dtype=dtype)


@dataclass(frozen=True)
class StepTrace:
    """One recorded step (decode, prefill, or both)."""

    index: int                     # batcher step index (0-based)
    start_ns: float                # step start on the replay clock
    stream: ExtentStream           # weights + per-slot KV, absolute times
    admitted: tuple[int, ...]      # rids admitted at this step's start
    active: tuple[int, ...]        # rids that decoded this step
    finished: tuple[int, ...]      # rids that produced their last token
    prefilled: tuple = ()          # (rid, n_tokens) prefill chunks packed
    prefill_done: tuple = ()       # rids whose prompt completed this step
    kind: str = "decode"           # "decode" | "prefill" | "mixed"

    @property
    def rids(self) -> tuple:
        """Every request this step served (active decoders followed by
        prefill-chunk owners, deduplicated, order-stable) — the
        participant set :class:`repro.obs.ObsCollector` splits the
        step's memory time across."""
        seen = dict.fromkeys(self.active)
        for rid, _ in self.prefilled:
            seen.setdefault(rid)
        return tuple(seen)


class ServeTraceRecorder:
    """Steps batcher + KV cache and emits per-step extent streams.

    The recorder is clock-agnostic: the caller (normally
    :class:`~repro.serve.replay.engine.ReplayEngine`) advances simulated
    time, feeds it to :meth:`submit_due` / :meth:`step`, and decides how
    long each recorded step took. That keeps the serving trace
    *policy-dependent in the right way* — admission windows shift with
    the measured memory makespans of the policy under test.
    """

    def __init__(self, arrivals: ArrivalProcess, cache: RowPagedKVCache,
                 n_slots: int | None = None,
                 weight_stream: ExtentStream = ExtentStream(),
                 kv_offset_ns: float = 0.0,
                 kv_base_addr: int = KV_BASE_ADDR,
                 prefill_chunk_tokens: int | None = None,
                 prefill_overlap: bool = True):
        n_slots = cache.max_seqs if n_slots is None else n_slots
        if n_slots > cache.max_seqs:
            raise ValueError(
                f"n_slots={n_slots} exceeds cache.max_seqs={cache.max_seqs}")
        w_end = max((r.end for r in weight_stream), default=0)
        if w_end > kv_base_addr:
            # Silent aliasing would make the sim see weight and KV reads
            # hitting the same rows — every SLO metric quietly wrong.
            raise ValueError(
                f"weight slice spans to {w_end} B, past kv_base_addr="
                f"{kv_base_addr}; shrink the slice scale or raise the "
                f"KV base")
        self.arrivals = arrivals
        self.cache = cache
        self.weight_stream = weight_stream
        self.kv_offset_ns = kv_offset_ns
        self.kv_base_addr = kv_base_addr
        self.prefill_overlap = prefill_overlap
        self.batcher = ContinuousBatcher(
            n_slots, admit=self._admit,
            prefill_chunk_tokens=prefill_chunk_tokens)
        self.requests: dict[int, Request] = {}
        self.specs: dict[int, RequestSpec] = {}
        self._committed_pages = 0          # worst-case pages of live reqs
        self._worst_pages: dict[int, int] = {}

    # -- admission -----------------------------------------------------------

    def _worst_case_pages(self, req: Request) -> int:
        return self.cache.pages_for(req.prompt_len + req.max_new_tokens)

    def _admit(self, req: Request) -> bool:
        """Check-and-commit: the reservation is taken the moment the
        batcher's admission predicate says yes. ContinuousBatcher pops
        the request exactly when this returns True, so a True return and
        an admission are one-to-one — committing here (rather than after
        ``schedule()`` returns) is what keeps several admissions in one
        scheduling iteration from each passing against the same stale
        count and overcommitting the pool."""
        worst = self._worst_case_pages(req)
        if self._committed_pages + worst > self.cache.n_pages:
            return False
        self._committed_pages += worst
        self._worst_pages[req.rid] = worst
        return True

    def submit_due(self, now_ns: float) -> list[Request]:
        """Move every arrived spec into the batcher's wait queue."""
        out = []
        for spec in self.arrivals.due(now_ns):
            worst = self.cache.pages_for(spec.prompt_len
                                         + spec.max_new_tokens)
            # Both limits matter: a request over max_pages_per_seq would
            # pass the pool check, then crash in alloc_seq/append_token
            # mid-replay once its page-table row overflows.
            limit = min(self.cache.n_pages, self.cache.max_pages_per_seq)
            if worst > limit:
                raise ValueError(
                    f"request {spec.rid} needs {worst} pages but the cache "
                    f"allows {limit} per sequence "
                    f"(n_pages={self.cache.n_pages}, max_pages_per_seq="
                    f"{self.cache.max_pages_per_seq}); size it with "
                    f"make_kv_cache(max_seq_tokens=...)")
            req = Request(spec.rid,
                          np.zeros(spec.prompt_len, np.int32),
                          max_new_tokens=spec.max_new_tokens)
            self.requests[spec.rid] = req
            self.specs[spec.rid] = spec
            self.batcher.submit(req)
            out.append(req)
        return out

    # -- one decode step -----------------------------------------------------

    def step(self, now_ns: float) -> StepTrace | None:
        """Run one scheduling iteration + step at ``now_ns``.

        Returns the recorded :class:`StepTrace`, or None when no request
        is active (the caller should jump the clock to the next arrival).
        Per decoding slot the emitted order is read-then-append: the
        attention gather sees the pre-append sequence length, the decoded
        token's K/V write lands after it. All slots' KV groups arrive at
        ``now + kv_offset_ns`` — with the offset set to the weight
        chain's span (:func:`weight_step_stream`), the gather behaves
        like the op following the slice; tenants still contend with each
        other inside that window.

        With chunked prefill enabled, each step also carries up to one
        prefill pack (chunk-attention prefix reads + coalesced K/V page
        appends per chunk, at the same KV window). Under
        ``prefill_overlap=True`` the pack rides in the decode step
        (packing-prefetch: the fetch hides under the decode window);
        under ``prefill_overlap=False`` a pending pack claims the whole
        step and decode stalls (``kind="prefill"``). Either way a chunk
        committed during step *i* makes its request decode-eligible at
        step *i + 1*.
        """
        admitted = []
        chunked = self.batcher.prefill_chunk_tokens is not None
        for slot, req in self.batcher.schedule():
            # Pages were reserved in _admit; allocating the prompt here
            # can therefore never exhaust the pool. Chunked prefill
            # starts the sequence empty — its pages arrive chunk by
            # chunk through append_chunk_stream.
            self.cache.alloc_seq(slot, 0 if chunked else req.prompt_len)
            admitted.append(req.rid)
        active = [(slot, req) for slot, req in enumerate(self.batcher.active)
                  if req is not None]
        if not active:
            return None
        pack = self.batcher.prefill_pack()
        prefill_only = bool(pack) and not self.prefill_overlap
        index = self.batcher.steps
        streams = [self.weight_stream.shifted(now_ns)] \
            if self.weight_stream else []
        kv_ns = now_ns + self.kv_offset_ns
        slot_of = {}
        decoding = []
        for slot, req in active:
            slot_of[req.rid] = slot
            if prefill_only or not req.prefill_done:
                continue
            decoding.append(req.rid)
            streams.append(
                self.cache.read_stream(slot, self.kv_base_addr,
                                       arrival_ns=kv_ns).retagged(req.rid)
                + self.cache.append_stream(slot, self.kv_base_addr,
                                           arrival_ns=kv_ns)
                .retagged(req.rid))
        for slot, req, n in pack:
            # Chunk attention reads the context prefilled so far (empty
            # on the first chunk), then the chunk's K/V lands as
            # row-granular page runs.
            streams.append(
                (self.cache.read_stream(slot, self.kv_base_addr,
                                        arrival_ns=kv_ns)
                 + self.cache.append_chunk_stream(slot, n,
                                                  self.kv_base_addr,
                                                  arrival_ns=kv_ns))
                .retagged(req.rid))
        stream = ExtentStream.interleave(streams)
        finished = self.batcher.record_tokens(
            np.zeros(self.batcher.n_slots, np.int32),
            decode=not prefill_only)
        prefill_done = self.batcher.apply_prefill(pack)
        for req in finished:
            self.cache.free_seq(slot_of[req.rid])
            self._committed_pages -= self._worst_pages.pop(req.rid)
        if not decoding:
            kind = "prefill"       # decode stalled or nothing decodable
        else:
            kind = "mixed" if pack else "decode"
        return StepTrace(
            index=index, start_ns=now_ns, stream=stream,
            admitted=tuple(admitted),
            active=tuple(decoding),
            finished=tuple(req.rid for req in finished),
            prefilled=tuple((req.rid, n) for _, req, n in pack),
            prefill_done=tuple(req.rid for req in prefill_done),
            kind=kind)

    def idle(self) -> bool:
        """No queued or active work (arrivals may still be pending)."""
        return self.batcher.idle()

    def drained(self) -> bool:
        """Every request this replay will ever see has completed."""
        return self.batcher.idle() and self.arrivals.exhausted()


__all__ = ["ServeTraceRecorder", "StepTrace", "weight_ops",
           "weight_step_stream", "make_kv_cache",
           "WEIGHT_STREAM_BASE", "KV_BASE_ADDR"]
