"""Replay recorded serve traffic through SystemSim; fold makespans into
request timelines.

:class:`ReplayEngine` runs the closed loop: at each step it asks the
:class:`~.recorder.ServeTraceRecorder` for the step's multi-tenant
extent stream, simulates it on the configured
:class:`~repro.core.system_sim.SystemSim` — under per-step reset
semantics by default, or carrying channel state across steps with
``warm=True`` (a :meth:`SystemSim.warm_session`; see that docstring for
the contract) — and advances the replay clock by the measured makespan.
Warm replay is the right mode once chunked prefill is on: a prefill
burst can leave channels still draining at the step boundary, and only
a warm session charges that backlog to the next step. Because admission
windows depend on the clock, the recorded trace is *policy-dependent*:
a slower memory system admits later and queues longer, which is exactly
the SLO-level effect RoMe's bandwidth claim has to cash out as.

Step duration = memory makespan + ``overhead_ns``. Weight-read arrival
pacing inside the step already carries the compute/roofline serialization
(``from_layer_ops``), so a memory-bound regime needs no extra compute
term; ``overhead_ns`` models per-step launch/sync cost when wanted.

The result (:class:`ReplayResult`) reports per-request TTFT / TPOT (in
simulated ns, from the folded timelines), their p50/p95/p99, slot
occupancy, and goodput against the offered load.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ...core.system_sim import SystemSim
from .recorder import ServeTraceRecorder, StepTrace


@dataclass
class RequestReport:
    """One request's folded timeline (simulated ns)."""

    rid: int
    arrival_ns: float
    prompt_len: int
    max_new_tokens: int
    admitted_ns: float = -1.0
    prefill_done_ns: float = -1.0   # last prompt chunk landed (chunked only)
    first_token_ns: float = -1.0
    completed_ns: float = -1.0
    n_out: int = 0

    @property
    def ttft_ns(self) -> float:
        """Arrival -> first token (queue wait + first decode step)."""
        return self.first_token_ns - self.arrival_ns

    @property
    def tpot_ns(self) -> float | None:
        """Mean time per output token after the first; None for
        single-token outputs."""
        if self.n_out < 2:
            return None
        return (self.completed_ns - self.first_token_ns) / (self.n_out - 1)


@dataclass
class StepSummary:
    index: int
    start_ns: float
    dur_ns: float
    n_active: int
    bytes_moved: int      # MC-granularity bytes the sim moved (overfetch in)
    stream_bytes: int     # request-side bytes of the step's extent stream
    mode: str = "cycle"   # pricing path the SystemSim took for this step
    kind: str = "decode"  # "decode" | "prefill" | "mixed" (StepTrace.kind)


@dataclass
class ReplayResult:
    requests: list[RequestReport]
    steps: list[StepSummary]
    makespan_ns: float
    occupancy: float
    traces: list[StepTrace] = field(default_factory=list)

    @property
    def completed(self) -> int:
        return sum(r.completed_ns >= 0 for r in self.requests)

    @property
    def goodput_rps(self) -> float:
        """Completed requests per simulated second."""
        if self.makespan_ns <= 0:
            return 0.0
        return self.completed / (self.makespan_ns / 1e9)

    @property
    def hybrid_fraction(self) -> float:
        """Fraction of decode steps priced by the queue-window analytic
        model (0.0 for a pure-cycle replay)."""
        if not self.steps:
            return 0.0
        return sum(s.mode == "analytic" for s in self.steps) / len(self.steps)

    @property
    def ttfts_ns(self) -> list[float]:
        return [r.ttft_ns for r in self.requests if r.first_token_ns >= 0]

    @property
    def tpots_ns(self) -> list[float]:
        return [t for r in self.requests
                if (t := r.tpot_ns) is not None]

    def percentiles(self, values: list[float],
                    qs=(50, 95, 99)) -> dict[str, float]:
        if not values:
            return {f"p{q}": 0.0 for q in qs}
        return {f"p{q}": round(float(np.percentile(values, q)), 1)
                for q in qs}

    def summary(self) -> dict:
        """Flat metrics dict (benchmark/baseline currency)."""
        out = {
            "n_requests": len(self.requests),
            "completed": self.completed,
            "n_steps": len(self.steps),
            "makespan_ns": round(self.makespan_ns, 1),
            "occupancy": round(self.occupancy, 4),
            "goodput_rps": round(self.goodput_rps, 1),
            # bytes_moved is what the memory system transferred (MC
            # access granularity) — under RoMe it exceeds stream_bytes
            # by the whole-row rounding of sub-row KV appends (§VII
            # overfetch); stream_bytes is the software-side demand.
            "bytes_moved": int(sum(s.bytes_moved for s in self.steps)),
            "stream_bytes": int(sum(s.stream_bytes for s in self.steps)),
            "hybrid_fraction": round(self.hybrid_fraction, 4),
            "n_prefill_steps": sum(s.kind == "prefill" for s in self.steps),
            "n_mixed_steps": sum(s.kind == "mixed" for s in self.steps),
        }
        for name, vals in (("ttft", self.ttfts_ns), ("tpot", self.tpots_ns)):
            for k, v in self.percentiles(vals).items():
                out[f"{name}_{k}_ns"] = v
            out[f"{name}_mean_ns"] = (round(float(np.mean(vals)), 1)
                                      if vals else 0.0)
        return out


class ReplayEngine:
    """Drive a recorder's decode steps through a SystemSim.

    ``keep_traces=True`` retains every recorded :class:`StepTrace`
    (stream included) on the result — the hook for conservation checks
    and for re-simulating the same trace open-loop under another policy
    via :meth:`SystemSim.run_steps`.

    ``warm=True`` prices the whole replay as one warm cross-step session
    (:meth:`SystemSim.warm_session`): channel state — open rows, queued
    backlog, refresh debt — persists between steps, and any backlog a
    step leaves lands on the next step's duration. Reset (the default)
    remains the cheap decode-only contract.

    ``collector`` attaches a :class:`repro.obs.ObsCollector`: every
    executed step is recorded as a span event on the replay clock and
    the folded request timelines land in the collector at the end — the
    input to the Chrome-trace exporter (docs/observability.md).
    Observation never changes the replay (asserted in tests/test_obs.py).
    """

    def __init__(self, recorder: ServeTraceRecorder, system: SystemSim,
                 overhead_ns: float = 0.0, keep_traces: bool = False,
                 max_steps: int = 100_000, warm: bool = False,
                 collector=None):
        self.recorder = recorder
        self.system = system
        self.overhead_ns = overhead_ns
        self.keep_traces = keep_traces
        self.max_steps = max_steps
        self.warm = warm
        self.collector = collector
        if collector is not None and collector.probe is not None:
            system.attach_probe(collector.probe)

    def run(self) -> ReplayResult:
        rec = self.recorder
        reports: dict[int, RequestReport] = {}
        steps: list[StepSummary] = []
        traces: list[StepTrace] = []
        session = self.system.warm_session() if self.warm else None
        now = 0.0
        while not rec.drained():
            for req in rec.submit_due(now):
                spec = rec.specs[req.rid]
                reports[req.rid] = RequestReport(
                    req.rid, spec.arrival_ns, spec.prompt_len,
                    spec.max_new_tokens)
            st = rec.step(now)
            if st is None:
                nxt = rec.arrivals.next_arrival_ns()
                if nxt is None:
                    break              # nothing queued, nothing to come
                now = max(now, nxt)
                continue
            # start_ns rebases lazily: analytic steps are priced on the
            # recorded stream itself (features are shift-invariant), so
            # the hybrid fast path never copies GB-scale step streams.
            # A warm session never rebases at all — the recorded stream
            # is already on the session's absolute clock.
            if session is not None:
                res = session.step(st.stream, start_ns=now)
            else:
                res = self.system.run(st.stream, start_ns=now)
            dur = res.total_ns + self.overhead_ns
            end = now + dur
            for rid in st.admitted:
                reports[rid].admitted_ns = now
            for rid in st.prefill_done:
                reports[rid].prefill_done_ns = end
            for rid in st.active:
                rep = reports[rid]
                rep.n_out += 1
                if rep.first_token_ns < 0:
                    rep.first_token_ns = end
            for rid in st.finished:
                reports[rid].completed_ns = end
                rec.arrivals.on_complete(end)
            steps.append(StepSummary(st.index, now, dur, len(st.active),
                                     res.bytes_moved,
                                     st.stream.total_bytes,
                                     mode=res.mode, kind=st.kind))
            if self.collector is not None:
                self.collector.on_step(st, res, now, dur)
            if self.keep_traces:
                traces.append(st)
            now = end
            if len(steps) >= self.max_steps:
                raise RuntimeError(
                    f"replay exceeded max_steps={self.max_steps}; "
                    f"offered load too high for the pool/slots?")
        if session is not None:
            session.check()
        result = ReplayResult(
            requests=[reports[rid] for rid in sorted(reports)],
            steps=steps,
            makespan_ns=now,
            occupancy=rec.batcher.occupancy,
            traces=traces)
        if self.collector is not None:
            self.collector.fold_reports(result.requests)
        return result


def build_replay(workload: str = "deepseek-v3",
                 policy: str = "hbm4_frfcfs",
                 rate_rps: float = 1e5,
                 n_requests: int = 16,
                 kind: str = "poisson",
                 seed: int = 0,
                 length_scale: float = 1 / 32,
                 n_slots: int = 4,
                 n_ops: int = 4,
                 scale: float = 2 ** -15,
                 n_channels: int = 2,
                 keep_traces: bool = False,
                 overhead_ns: float = 0.0,
                 mix=None,
                 sim_mode: str = "cycle",
                 warm: bool = False,
                 prefill_chunk_tokens: int | None = None,
                 prefill_overlap: bool = True,
                 collector=None,
                 **arrival_kw):
    """Wire a complete replay for one (workload, policy, load) cell.

    ``policy`` names a :class:`repro.core.sched.registry.PolicySpec` —
    the registered scheduling point whose family (hbm4/rome) also picks
    the scaled accelerator the weight slice is paced on. Returns
    ``(engine, acc)``; ``engine.run()`` produces the
    :class:`ReplayResult`, ``acc`` is the
    :func:`~repro.perfmodel.accelerator.scaled_accelerator` needed for
    the analytic cross-check (``perfmodel.tpot.stream_mem_ns``).

    The default ``scale`` keeps steps tiny for fast structural tests;
    in that regime HBM4 steps are ACT-issue-bound and sit *outside* the
    analytic model's validity. The band-valid cycle regime
    (benchmarks/serve_trace.py) uses ``scale=2**-12`` — ≈240 KB/step,
    large enough that data transfer hides ACT-command serialization,
    which is what the established 15 % engine_xval band assumes.

    ``scale=1.0`` replays the *unscaled* weight slice — decode steps in
    the tens of GB that would decompose into ~1e9 transactions each.
    That path requires ``sim_mode="hybrid"`` (or ``"analytic"``): the
    queue-window model prices the bulk weight stream in O(n_records),
    and the KV pool base auto-raises past the unscaled slice's end (the
    recorder rejects aliasing layouts otherwise). ``sim_mode`` is passed
    straight to :meth:`PolicySpec.system_sim` as the SystemSim ``mode``.

    ``prefill_chunk_tokens`` turns on chunked prefill (real prefill
    extents through the memory system; see
    :class:`~.recorder.ServeTraceRecorder`), ``prefill_overlap``
    selects packing-prefetch vs prefill-priority stalls, and ``warm``
    prices the replay as one warm cross-step session — the recommended
    trio for prefill studies (benchmarks/serve_trace.py).

    ``collector`` threads a :class:`repro.obs.ObsCollector` into the
    engine; a collector carrying a :class:`~repro.obs.MetricsProbe` also
    attaches it to the SystemSim, turning on windowed channel telemetry
    for every cycle-priced step (examples/obs_trace.py).
    """
    from ...configs.paper_workloads import PAPER_WORKLOADS, SERVING_MIXES
    from ...core.sched.registry import policy_spec
    from ...perfmodel.accelerator import scaled_accelerator
    from ...trace.layergraph import ROW
    from .arrivals import ArrivalProcess
    from .recorder import (KV_BASE_ADDR, ServeTraceRecorder, make_kv_cache,
                           weight_step_stream)

    spec = policy_spec(policy)
    w = PAPER_WORKLOADS[workload]
    mix = SERVING_MIXES[workload] if mix is None else mix
    acc = scaled_accelerator(spec.family, n_channels=n_channels)
    ws, chain_ns = weight_step_stream(w, acc, n_ops=n_ops, scale=scale)
    # An unscaled slice overruns the default KV base; park the pool at
    # the first row past the weights so layouts never alias at any scale.
    w_end = max((r.end for r in ws), default=0)
    kv_base = max(KV_BASE_ADDR, -(-w_end // ROW) * ROW)
    max_tokens = (max(1, round(mix.prompt_max * length_scale))
                  + max(1, round(mix.out_max * length_scale)))
    cache = make_kv_cache(n_slots, max_tokens)
    arrivals = ArrivalProcess(kind, rate_rps, n_requests, mix=mix,
                              length_scale=length_scale, seed=seed,
                              **arrival_kw)
    recorder = ServeTraceRecorder(arrivals, cache, weight_stream=ws,
                                  kv_offset_ns=chain_ns,
                                  kv_base_addr=kv_base,
                                  prefill_chunk_tokens=prefill_chunk_tokens,
                                  prefill_overlap=prefill_overlap)
    system = spec.system_sim(n_channels=n_channels, mode=sim_mode)
    engine = ReplayEngine(recorder, system, overhead_ns=overhead_ns,
                          keep_traces=keep_traces, warm=warm,
                          collector=collector)
    return engine, acc


__all__ = ["ReplayEngine", "ReplayResult", "RequestReport", "StepSummary",
           "build_replay"]
