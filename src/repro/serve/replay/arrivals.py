"""Open- and closed-loop request generation for serving replays.

:class:`ArrivalProcess` turns an offered load into a reproducible
sequence of :class:`RequestSpec` entries — arrival time plus sampled
prompt/output token lengths. Three arrival disciplines:

``poisson``
    Open loop: exponential inter-arrival times at ``rate_rps`` requests
    per (simulated) second — the classic offered-load axis.
``bursty``
    Open loop: Poisson *burst* arrivals of ``burst_size`` back-to-back
    requests each, at the same aggregate ``rate_rps`` — the row-thrash
    stressor (many tenants admitted in one scheduling window).
``closed``
    Closed loop: ``n_users`` users, each submitting its next request an
    exponential think time after its previous one completes. Arrivals
    are driven by :meth:`on_complete` callbacks from the replay engine,
    so the offered load self-regulates with service time.

Lengths come from a :class:`~repro.configs.paper_workloads.ServingMix`
(per evaluation model, see ``SERVING_MIXES``), uniformly scaled by
``length_scale`` so cycle-level simulation stays tractable; the mix
*shape* (lognormal prompts, geometric outputs) is what matters to the
memory system. Everything is drawn from one seeded
``numpy.random.Generator`` — a given (mix, seed, load) always produces
the same request sequence.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass

import numpy as np

from ...configs.paper_workloads import SERVING_MIXES, ServingMix

KINDS = ("poisson", "bursty", "closed")


@dataclass(frozen=True)
class RequestSpec:
    """One generated request: identity, arrival, and sampled lengths."""

    rid: int
    arrival_ns: float
    prompt_len: int
    max_new_tokens: int


class ArrivalProcess:
    """Seeded request generator over a serving length mix.

    Open-loop kinds (``poisson``/``bursty``) pre-generate ``n_requests``
    specs at construction; :meth:`due` hands them out as simulated time
    passes. The ``closed`` kind seeds ``n_users`` requests at t=0 and
    emits one more per :meth:`on_complete` until ``n_requests`` have
    been issued.
    """

    def __init__(self, kind: str = "poisson", rate_rps: float = 1e5,
                 n_requests: int = 16, mix: ServingMix | str = "deepseek-v3",
                 length_scale: float = 1.0, seed: int = 0,
                 burst_size: int = 4, n_users: int = 4,
                 think_ns: float = 0.0):
        if kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
        if rate_rps <= 0:
            raise ValueError(f"rate_rps must be positive, got {rate_rps}")
        if n_requests < 1:
            raise ValueError(f"n_requests must be >= 1, got {n_requests}")
        self.kind = kind
        self.rate_rps = rate_rps
        self.n_requests = n_requests
        self.mix = SERVING_MIXES[mix] if isinstance(mix, str) else mix
        self.length_scale = length_scale
        self.burst_size = burst_size
        self.n_users = n_users
        self.think_ns = think_ns
        self._rng = np.random.default_rng(seed)
        self._issued = 0
        # Sorted by (arrival_ns, rid); [_next:] is the undelivered tail.
        # Construction appends in that order by design (poisson/bursty
        # emit non-decreasing times with increasing rids; the closed
        # seeds all arrive at t=0), and on_complete insorts — so due()
        # is a bisect + slice, O(log n) per call instead of rebuilding
        # the whole list (the difference between an O(n²) and an O(n
        # log n) million-request sweep).
        self._pending: list[RequestSpec] = []
        self._next = 0
        if kind == "poisson":
            t = 0.0
            for _ in range(n_requests):
                t += self._rng.exponential(1e9 / rate_rps)
                self._pending.append(self._spec(t))
        elif kind == "bursty":
            t = 0.0
            while self._issued < n_requests:
                t += self._rng.exponential(1e9 * burst_size / rate_rps)
                for _ in range(min(burst_size,
                                   n_requests - self._issued)):
                    self._pending.append(self._spec(t))
        else:                                    # closed loop
            for _ in range(min(n_users, n_requests)):
                self._pending.append(self._spec(0.0))

    # -- sampling ------------------------------------------------------------

    def _sample_lengths(self) -> tuple[int, int]:
        m = self.mix
        sigma = float(np.sqrt(np.log1p(m.prompt_cv ** 2)))
        p = int(round(float(self._rng.lognormal(np.log(m.prompt_median),
                                                sigma)) * self.length_scale))
        o = int(round(float(self._rng.geometric(1.0 / m.out_mean))
                      * self.length_scale))
        p_max = max(1, int(round(m.prompt_max * self.length_scale)))
        o_max = max(1, int(round(m.out_max * self.length_scale)))
        return min(max(p, 1), p_max), min(max(o, 1), o_max)

    def _spec(self, arrival_ns: float) -> RequestSpec:
        prompt, out = self._sample_lengths()
        spec = RequestSpec(self._issued, arrival_ns, prompt, out)
        self._issued += 1
        return spec

    # -- engine interface ----------------------------------------------------

    def due(self, now_ns: float) -> list[RequestSpec]:
        """Pop every spec with ``arrival_ns <= now_ns``, in arrival order
        (ties broken by rid). The pending list is kept sorted by
        (arrival, rid) — :meth:`on_complete` insorts, and rids are
        issued in increasing order so equal-arrival closed-loop
        re-submissions land after their peers — making this a bisect +
        slice instead of a full-list rebuild."""
        p, lo = self._pending, self._next
        hi = bisect.bisect_right(p, now_ns, lo=lo,
                                 key=lambda s: s.arrival_ns)
        if hi == lo:
            return []
        out = p[lo:hi]
        self._next = hi
        # Compact the delivered prefix once it dominates the list, so a
        # million delivered specs don't sit pinned behind the pointer.
        if self._next > 4096 and self._next * 2 > len(p):
            del p[:self._next]
            self._next = 0
        return out

    def next_arrival_ns(self) -> float | None:
        """Earliest not-yet-delivered arrival, or None when drained."""
        if self._next >= len(self._pending):
            return None
        return self._pending[self._next].arrival_ns

    def on_complete(self, now_ns: float) -> None:
        """Completion callback: closed-loop users submit their next
        request one think time later; open-loop kinds ignore it."""
        if self.kind != "closed" or self._issued >= self.n_requests:
            return
        dt = (self._rng.exponential(self.think_ns) if self.think_ns
              else 0.0)
        bisect.insort(self._pending, self._spec(now_ns + dt),
                      lo=self._next, key=lambda s: s.arrival_ns)

    def exhausted(self) -> bool:
        """True once every request this process will ever emit is out."""
        return (self._next >= len(self._pending)
                and (self.kind != "closed"
                     or self._issued >= self.n_requests))


__all__ = ["ArrivalProcess", "RequestSpec", "KINDS"]
