"""End-to-end serving-trace replay: continuous batching -> SystemSim.

This package closes the serving loop the ROADMAP's first open item asks
for: generated requests flow through the real
:class:`~repro.serve.batching.ContinuousBatcher` and
:class:`~repro.serve.kv_cache.RowPagedKVCache`, every decode step is
recorded as one multi-tenant :class:`~repro.workloads.ExtentStream`, and
the streams drive the cycle-level
:class:`~repro.core.system_sim.SystemSim` under any registered
scheduler policy. Measured memory makespans fold back into request
timelines, so the paper's bandwidth claim becomes a measured SLO delta:
per-request TTFT/TPOT, their p50/p95/p99, occupancy, and goodput vs
offered load.

Serving -> memory contract (what is simulated vs analytic)
----------------------------------------------------------
*Simulated, cycle-level:* every decode step's memory traffic — the
byte-scaled weights-only decode slice (``from_layer_ops`` pacing, so the
compute/roofline serialization between layer ops is carried by record
arrival times), whole-row KV page reads, and the decoded token's K/V
append, for all tenants of the step, with all intra-step contention
(bank conflicts, read/write turnarounds, refresh) on the policy under
test. The per-slot KV gather/append group is paced like the op that
*follows* the weight slice (``kv_offset_ns`` = the chain's roofline
span): tenants contend with each other inside that window, and the
construction stays in the serialized-group regime where the analytic
TPOT model is valid. With ``prefill_chunk_tokens`` set, **prefill is
simulated too**: each prompt streams through the memory system in
chunks (chunk-attention prefix reads + row-granular K/V page appends),
either packed into the concurrent decode step (packing-prefetch,
``prefill_overlap=True``) or claiming dedicated prefill steps that
stall decode (``prefill_overlap=False``).

Steps run under **per-step reset** semantics by default
(:meth:`SystemSim.run_steps`): launch/compute gaps between real decode
steps drain queues and close rows, so no warm channel state needs to be
carried. Once chunked prefill can leave channels draining at a step
boundary that assumption breaks — pass ``warm=True``
(:meth:`SystemSim.warm_session`) to carry open rows, queues, and
refresh debt across steps. Warm and reset are asserted bit-identical on
uncontended step sequences (tests/test_warm_steps.py); see
docs/serve_replay.md for the full contract.

*Analytic / not simulated:* prefill **in legacy mode only**
(``prefill_chunk_tokens=None``: admission allocates the prompt's KV
pages instantly — TTFT measures queue wait + first decode step, not
prompt compute), token sampling (outputs are length-only), and per-step
kernel launch overhead (the ``overhead_ns`` knob). Byte scaling follows
``perfmodel.tpot.xval_decode_stream``: shapes and row alignment are
preserved while totals shrink to keep cycle-level replay tractable.

Tagging contract: weight records carry negative stream ids
(``-1 - op_index``); every KV record carries its request id. A
request's KV appends and reads therefore appear exactly once across the
recorded streams — the conservation property tests pin.
"""
from .arrivals import ArrivalProcess, RequestSpec
from .engine import (ReplayEngine, ReplayResult, RequestReport, StepSummary,
                     build_replay)
from .recorder import (KV_BASE_ADDR, WEIGHT_STREAM_BASE, ServeTraceRecorder,
                       StepTrace, make_kv_cache, weight_ops,
                       weight_step_stream)

__all__ = [
    "ArrivalProcess", "RequestSpec",
    "ServeTraceRecorder", "StepTrace",
    "ReplayEngine", "ReplayResult", "RequestReport", "StepSummary",
    "build_replay", "make_kv_cache", "weight_ops", "weight_step_stream",
    "WEIGHT_STREAM_BASE", "KV_BASE_ADDR",
]
