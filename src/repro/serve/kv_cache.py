"""Row-paged KV cache: pages are whole 4 KB DRAM rows.

This is the software side of the RoMe contract — the serving system
allocates KV storage in pages whose byte size is an exact multiple of the
4 KB DRAM row, so every KV read the decode kernel issues is a whole-row
stream (`RD_row`) and every append fills rows sequentially. Compare vLLM's
PagedAttention pages (chosen for dedup/sharing); RoMe chooses page size for
the *memory interface*.

The page table is a dense int32 array (max_seqs, max_pages_per_seq) managed
host-side; the storage pool is one device array the Pallas flash-decode
kernel gathers from. On CPU tests everything is numpy-checkable.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..workloads.stream import ExtentRecord, ExtentStream

ROW_BYTES = 4096


def tokens_per_row(head_dim: int, n_kv_heads: int, itemsize: int = 2,
                   rows_per_page: int = 1) -> int:
    """Tokens that fill exactly `rows_per_page` DRAM rows of K (or V) for
    one layer: tokens * n_kv_heads * head_dim * itemsize == rows * 4096.
    Raises if no integral packing exists (pick rows_per_page accordingly).
    """
    page_bytes = rows_per_page * ROW_BYTES
    per_tok = n_kv_heads * head_dim * itemsize
    if page_bytes % per_tok:
        raise ValueError(
            f"page of {page_bytes} B not an integral number of "
            f"{per_tok} B tokens; use rows_per_page divisible by "
            f"{per_tok // np.gcd(per_tok, ROW_BYTES)}")
    return page_bytes // per_tok


@dataclass
class RowPagedKVCache:
    """Paged KV storage for one layer group.

    pool_k/pool_v: (n_pages, page_tokens, n_kv_heads, head_dim)
    page_table:    (max_seqs, max_pages) int32, -1 = unmapped
    seq_lens:      (max_seqs,) int32
    """

    n_pages: int
    page_tokens: int
    n_kv_heads: int
    head_dim: int
    max_seqs: int
    max_pages_per_seq: int
    dtype: str = "bfloat16"

    pool_k: jax.Array = field(init=False)
    pool_v: jax.Array = field(init=False)
    page_table: np.ndarray = field(init=False)
    seq_lens: np.ndarray = field(init=False)
    _free: list = field(init=False)

    def __post_init__(self) -> None:
        # The RoMe contract the whole memory-system view rides on: pages
        # are exact row multiples (size via tokens_per_row).
        if self.page_bytes % ROW_BYTES:
            raise ValueError(
                f"page of {self.page_bytes} B is not a whole number of "
                f"{ROW_BYTES} B DRAM rows; size page_tokens with "
                f"tokens_per_row()")
        shape = (self.n_pages, self.page_tokens, self.n_kv_heads,
                 self.head_dim)
        dt = jnp.dtype(self.dtype)
        self.pool_k = jnp.zeros(shape, dt)
        self.pool_v = jnp.zeros(shape, dt)
        self.page_table = np.full((self.max_seqs, self.max_pages_per_seq),
                                  -1, np.int32)
        self.seq_lens = np.zeros((self.max_seqs,), np.int32)
        self._free = list(range(self.n_pages - 1, -1, -1))

    # -- bookkeeping (host-side, O(1) per token) -----------------------------

    @property
    def page_bytes(self) -> int:
        return (self.page_tokens * self.n_kv_heads * self.head_dim
                * jnp.dtype(self.dtype).itemsize)

    def rows_per_page(self) -> int:
        assert self.page_bytes % ROW_BYTES == 0
        return self.page_bytes // ROW_BYTES

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` of one sequence — the unit of
        the admission-control arithmetic in :mod:`repro.serve.replay`
        (a request's worst case is ``pages_for(prompt + max_new)``)."""
        return -(-n_tokens // self.page_tokens)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc_seq(self, seq_id: int, n_tokens: int) -> None:
        """Reserve pages for a new sequence of n_tokens (prefill)."""
        n_pages = self.pages_for(n_tokens)
        if n_pages > self.max_pages_per_seq:
            raise ValueError("sequence exceeds max_pages_per_seq")
        if n_pages > len(self._free):
            raise MemoryError("KV pool exhausted")
        for i in range(n_pages):
            self.page_table[seq_id, i] = self._free.pop()
        self.seq_lens[seq_id] = n_tokens

    def append_token(self, seq_id: int) -> tuple[int, int]:
        """Account one decoded token; returns (page_id, slot_in_page).
        Grabs a fresh page on a row boundary — appends never straddle."""
        pos = int(self.seq_lens[seq_id])
        page_idx, slot = divmod(pos, self.page_tokens)
        if self.page_table[seq_id, page_idx] < 0:
            if not self._free:
                raise MemoryError("KV pool exhausted")
            self.page_table[seq_id, page_idx] = self._free.pop()
        self.seq_lens[seq_id] = pos + 1
        return int(self.page_table[seq_id, page_idx]), slot

    def append_chunk(self, seq_id: int,
                     n_tokens: int) -> list[tuple[int, int, int]]:
        """Account ``n_tokens`` appended tokens in bulk (a prefill
        chunk); returns the contiguous (page_id, first_slot, n_slots)
        runs they landed in. Pages are grabbed lazily like
        :meth:`append_token`; runs never straddle a page, so every run
        is a row-aligned write target."""
        runs: list[tuple[int, int, int]] = []
        pos = int(self.seq_lens[seq_id])
        remaining = int(n_tokens)
        while remaining > 0:
            page_idx, slot = divmod(pos, self.page_tokens)
            if page_idx >= self.max_pages_per_seq:
                raise ValueError("sequence exceeds max_pages_per_seq")
            if self.page_table[seq_id, page_idx] < 0:
                if not self._free:
                    raise MemoryError("KV pool exhausted")
                self.page_table[seq_id, page_idx] = self._free.pop()
            take = min(remaining, self.page_tokens - slot)
            runs.append((int(self.page_table[seq_id, page_idx]), slot,
                         take))
            pos += take
            remaining -= take
        self.seq_lens[seq_id] = pos
        return runs

    def free_seq(self, seq_id: int) -> None:
        for i in range(self.max_pages_per_seq):
            p = self.page_table[seq_id, i]
            if p >= 0:
                self._free.append(int(p))
                self.page_table[seq_id, i] = -1
        self.seq_lens[seq_id] = 0

    def utilization(self) -> float:
        return 1.0 - len(self._free) / self.n_pages

    # -- memory-system view (unified workload records) -----------------------
    #
    # The two pools are contiguous device allocations laid out back to
    # back: page p's K rows live at base_addr + p * page_bytes and its V
    # rows at base_addr + pool_span + p * page_bytes. page_bytes is an
    # exact row multiple, so every record below is row-aligned by
    # construction — the RoMe contract.

    @property
    def pool_span_bytes(self) -> int:
        """Byte span of one pool (K or V)."""
        return self.n_pages * self.page_bytes

    def page_addr(self, page_id: int, base_addr: int = 0,
                  pool: str = "k") -> int:
        if pool not in ("k", "v"):
            raise ValueError(f"pool must be 'k' or 'v', got {pool!r}")
        off = 0 if pool == "k" else self.pool_span_bytes
        return base_addr + off + int(page_id) * self.page_bytes

    def read_stream(self, seq_id: int, base_addr: int = 0,
                    arrival_ns: float = 0.0) -> ExtentStream:
        """One decode step's KV gather for a sequence, as the unified
        :class:`~repro.workloads.ExtentStream`: one whole-page read per
        mapped page *per pool* — the flash-decode kernel streams full
        rows of both K and V — tagged with the sequence id."""
        n_pages = self.pages_for(int(self.seq_lens[seq_id]))
        return ExtentStream(
            ExtentRecord(self.page_addr(p, base_addr, pool),
                         self.page_bytes, "read", arrival_ns, seq_id)
            for pool in ("k", "v")
            for p in self.page_table[seq_id, :n_pages])

    def write_stream(self, seq_id: int, page_id: int, slot: int,
                     base_addr: int = 0,
                     arrival_ns: float = 0.0) -> ExtentStream:
        """Pure record emission: the K and V write records for a token at
        ``(page_id, slot)`` — no bookkeeping, safe to call repeatedly
        (e.g. to replay one step against several memory configs)."""
        per_tok = (self.n_kv_heads * self.head_dim
                   * jnp.dtype(self.dtype).itemsize)
        return ExtentStream(
            ExtentRecord(self.page_addr(page_id, base_addr, pool)
                         + slot * per_tok, per_tok, "write",
                         arrival_ns, seq_id)
            for pool in ("k", "v"))

    def append_chunk_stream(self, seq_id: int, n_tokens: int,
                            base_addr: int = 0,
                            arrival_ns: float = 0.0) -> ExtentStream:
        """Account one prefill chunk (side effect — see
        :meth:`append_chunk`) and return its K/V write records,
        coalesced to one record per page run per pool: the prefill
        kernel writes each page's K (and V) slots as one sequential
        burst, which on row-paged storage is a row-granular write —
        exactly the traffic shape RoMe prices at one transaction."""
        per_tok = (self.n_kv_heads * self.head_dim
                   * jnp.dtype(self.dtype).itemsize)
        runs = self.append_chunk(seq_id, n_tokens)
        return ExtentStream(
            ExtentRecord(self.page_addr(page_id, base_addr, pool)
                         + slot * per_tok, n_slots * per_tok, "write",
                         arrival_ns, seq_id)
            for page_id, slot, n_slots in runs
            for pool in ("k", "v"))

    def append_stream(self, seq_id: int, base_addr: int = 0,
                      arrival_ns: float = 0.0) -> ExtentStream:
        """Account one decoded token (side effect — see
        :meth:`append_token`; the token is accounted exactly once) and
        return its write records. To re-emit records for an
        already-accounted token use :meth:`write_stream`."""
        page_id, slot = self.append_token(seq_id)
        return self.write_stream(seq_id, page_id, slot, base_addr,
                                 arrival_ns)

    # -- device-side ops -------------------------------------------------------

    def write(self, page_id: int, slot: int, k: jax.Array, v: jax.Array):
        """Write one token's K/V (n_kv_heads, head_dim) into its page."""
        self.pool_k = self.pool_k.at[page_id, slot].set(k)
        self.pool_v = self.pool_v.at[page_id, slot].set(v)

    def gather_seq(self, seq_id: int) -> tuple[jax.Array, jax.Array]:
        """Materialize a sequence's KV as (seq, n_kv_heads, head_dim) —
        the reference path; the kernel path gathers page-wise."""
        n = int(self.seq_lens[seq_id])
        pages = self.page_table[seq_id, :self.pages_for(n)]
        k = self.pool_k[pages].reshape(-1, self.n_kv_heads, self.head_dim)
        v = self.pool_v[pages].reshape(-1, self.n_kv_heads, self.head_dim)
        return k[:n], v[:n]
