"""Unified workload description: timed, typed extent streams.

Record schema
-------------
:class:`ExtentRecord` is the atom — one contiguous software-level
transfer::

    ExtentRecord(addr, nbytes, kind, arrival_ns, stream_id)

* ``addr``/``nbytes`` — byte range in the row-aligned virtual address
  space the layer-op allocator and paged KV cache hand out; the memory
  system decomposes it into MC-granularity transactions (any touched
  stripe unit moves whole — the over-fetch rule).
* ``kind`` — ``"read"`` or ``"write"``; nothing else.
* ``arrival_ns`` — when the transfer becomes visible to the MC.
* ``stream_id`` — issuing software stream (layer op index, tenant,
  sequence); consumers group by it, schedulers may use it for stats.

:class:`ExtentStream` is an ordered, immutable sequence of records:
sliceable (``s[a:b]``, :meth:`~ExtentStream.limit_bytes`), mergeable
(``+``, :meth:`~ExtentStream.interleave` for arrival-ordered multi-tenant
mixes), and derivable (:meth:`~ExtentStream.shifted`,
:meth:`~ExtentStream.retagged`, :meth:`~ExtentStream.of_kind`).

Builder contract
----------------
Builders return streams whose records are in non-decreasing
``arrival_ns`` (issue order within ties), with row-aligned write
addresses that never overlap read extents of the same trace:

* :func:`from_layer_ops` — the trace-driven path: per-op arrivals from
  the TPOT compute/memory roofline, KV-append/activation writes at real
  allocator addresses.
* :func:`bulk_stream` / :func:`strided_stream` / :func:`sparse_stream` —
  synthetic calibration and stress regimes.
* :meth:`repro.serve.kv_cache.RowPagedKVCache.read_stream` /
  ``append_stream`` — the serving-side producer of the same records;
  :class:`repro.serve.replay.ServeTraceRecorder` interleaves them with
  a weight slice into one multi-tenant stream per decode step.

Consumers: :meth:`repro.core.system_sim.SystemSim.run` (cycle-accurate
ground truth), :func:`repro.core.analytic.stream_time_ns` (closed form),
:func:`repro.perfmodel.tpot.stream_mem_ns` (step memory time).
"""
from .builders import (bulk_stream, from_layer_ops, interleave,
                       layer_ops_span_ns, scale_layer_ops, sparse_stream,
                       strided_stream)
from .stream import KINDS, ExtentRecord, ExtentStream

__all__ = [
    "ExtentRecord", "ExtentStream", "KINDS",
    "from_layer_ops", "scale_layer_ops", "layer_ops_span_ns",
    "bulk_stream", "strided_stream", "sparse_stream", "interleave",
]
