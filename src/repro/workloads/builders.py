"""Builders: layer-op traces and synthetic generators -> ExtentStream.

``from_layer_ops`` is the trace-driven path: it walks a
:class:`repro.trace.layergraph.LayerOp` list and emits every read extent
and every row-aligned write extent as timed records, with per-op arrival
times from the same compute/memory roofline the TPOT model uses (op i+1
becomes visible to the memory system when op i's modeled
``max(mem, comp) + overhead`` elapses). The synthetic generators cover
the calibration regimes: ``bulk_stream`` (contiguous), ``strided_stream``
(gapped, load-imbalance), and ``sparse_stream`` (random row gather, the
§VII over-fetch workload). Multi-tenant mixes come from
:meth:`ExtentStream.interleave` over retagged streams.
"""
from __future__ import annotations

import numpy as np

from ..core.analytic import calibrate
from ..trace.layergraph import ROW, LayerOp, RowAllocator
from .stream import ExtentRecord, ExtentStream


def from_layer_ops(ops: list[LayerOp], acc,
                   start_ns: float = 0.0) -> ExtentStream:
    """Timed stream for a layer-op trace on accelerator ``acc``
    (a :class:`repro.perfmodel.accelerator.AcceleratorSpec`).

    Every op's reads and writes arrive together at the op's start time;
    ``stream_id`` is the op index, so downstream consumers can group
    records back into ops (``perfmodel.tpot.stream_mem_ns``) or tell
    tenants apart after :meth:`ExtentStream.interleave`.
    """
    eff = calibrate(acc.mem_cfg)
    amap = acc.address_map()
    records: list[ExtentRecord] = []
    t = start_ns
    for i, op in enumerate(ops):
        # Zero-byte extents are legal in LayerOp (degenerate toy shapes);
        # they carry no traffic, so skip them like every other consumer.
        for a, n in op.extents:
            if n > 0:
                records.append(ExtentRecord(a, n, "read", t, i))
        for a, n in op.write_extents:
            if n > 0:
                records.append(ExtentRecord(a, n, "write", t, i))
        t += _op_duration_ns(op, acc, eff, amap)
    return ExtentStream(records)


def _op_duration_ns(op: LayerOp, acc, eff, amap) -> float:
    """The pacing rule: op i+1 becomes visible when op i's modeled
    ``max(mem, comp) + overhead`` elapses. The single definition both
    :func:`from_layer_ops` and :func:`layer_ops_span_ns` use."""
    # Lazy: perfmodel.accelerator imports repro.core, whose system_sim
    # pulls this package back in — a module-level import here makes a
    # cold `import repro.perfmodel` (or perfmodel-first benchmark)
    # circular.
    from ..perfmodel.tpot import op_times_ns
    m, c, _ = op_times_ns(op, acc, amap, eff.read_eff, eff.write_eff)
    return max(m, c) + acc.kernel_overhead_ns


def layer_ops_span_ns(ops: list[LayerOp], acc) -> float:
    """Modeled roofline span of a whole op chain — what
    :func:`from_layer_ops` pacing adds up to, exposed so consumers
    (e.g. ``serve.replay``'s KV-group offset) can schedule an event at
    the chain's end without re-deriving the rule."""
    eff = calibrate(acc.mem_cfg)
    amap = acc.address_map()
    return sum(_op_duration_ns(op, acc, eff, amap) for op in ops)


def scale_layer_ops(ops: list[LayerOp], scale: float) -> list[LayerOp]:
    """Byte- and FLOP-scaled copy of a layer-op trace.

    Non-empty extents are re-allocated through a fresh
    :class:`RowAllocator` at ``nbytes * scale`` (floored at one 4 KB
    row); zero-byte extents carry no traffic and are dropped, like every
    other consumer skips them. Extent count (of the non-empty extents),
    op structure, row alignment, and read/write disjointness are
    preserved — this is what makes cycle-level simulation of the paper's
    multi-terabyte decode traces tractable (benchmarks/engine_xval.py).
    """
    if scale <= 0:
        raise ValueError(f"scale must be positive, got {scale}")
    alloc = RowAllocator()
    out = []
    for op in ops:
        ex = [alloc.alloc(max(ROW, int(n * scale)))
              for _, n in op.extents if n > 0]
        wx = [alloc.alloc(max(ROW, int(n * scale)))
              for _, n in op.write_extents if n > 0]
        out.append(LayerOp(op.name, op.kind, op.flops * scale, ex,
                           sum(n for _, n in wx), wx))
    return out


# ---------------------------------------------------------------------------
# Synthetic generators
# ---------------------------------------------------------------------------

def bulk_stream(nbytes: int, n_extents: int = 1, kind: str = "read",
                base_addr: int = 0, gap_bytes: int = 0,
                arrival_ns: float = 0.0, stream_id: int = 0) -> ExtentStream:
    """``n_extents`` contiguous extents totalling exactly ``nbytes``
    (the last extent absorbs the division remainder), optionally
    separated by ``gap_bytes`` holes (gapped == load imbalance)."""
    per, rem = divmod(nbytes, n_extents)
    if per <= 0:
        raise ValueError(
            f"nbytes={nbytes} too small for {n_extents} extents")
    records = []
    addr = base_addr
    for i in range(n_extents):
        n = per + (rem if i == n_extents - 1 else 0)
        records.append(ExtentRecord(addr, n, kind, arrival_ns, stream_id))
        addr += per + gap_bytes
    return ExtentStream(records)


def strided_stream(n_extents: int, extent_bytes: int, stride_bytes: int,
                   kind: str = "read", base_addr: int = 0,
                   arrival_ns: float = 0.0, inter_arrival_ns: float = 0.0,
                   stream_id: int = 0) -> ExtentStream:
    """Fixed-stride access (extent every ``stride_bytes``): the classic
    partial-stripe pattern that skews channel load at coarse granularity.
    ``inter_arrival_ns`` spaces arrivals for open-loop issue."""
    if stride_bytes < extent_bytes:
        raise ValueError("stride_bytes must be >= extent_bytes")
    return ExtentStream(
        ExtentRecord(base_addr + i * stride_bytes, extent_bytes, kind,
                     arrival_ns + i * inter_arrival_ns, stream_id)
        for i in range(n_extents))


def sparse_stream(n_extents: int, extent_bytes: int, space_bytes: int,
                  kind: str = "read", seed: int = 0,
                  arrival_ns: float = 0.0, stream_id: int = 0) -> ExtentStream:
    """Random gather of small extents over a ``space_bytes`` region — the
    DSA-style sparse top-k workload where RoMe's whole-row moves
    over-fetch (§VII, benchmarks/sparse_overfetch.py). Extents are
    sampled without replacement on an ``extent_bytes`` grid and emitted
    in address order (the MC sees a sorted gather list)."""
    slots = space_bytes // extent_bytes
    if n_extents > slots:
        raise ValueError("n_extents exceeds the number of extent slots")
    rng = np.random.default_rng(seed)
    picks = np.sort(rng.choice(slots, size=n_extents, replace=False))
    return ExtentStream(
        ExtentRecord(int(p) * extent_bytes, extent_bytes, kind, arrival_ns,
                     stream_id)
        for p in picks)


interleave = ExtentStream.interleave


__all__ = [
    "from_layer_ops", "scale_layer_ops", "layer_ops_span_ns",
    "bulk_stream", "strided_stream", "sparse_stream", "interleave",
]
