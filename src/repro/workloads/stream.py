"""Timed, typed extent streams — the unified workload description.

An :class:`ExtentStream` is an ordered sequence of :class:`ExtentRecord`
entries, each one contiguous memory transfer at the software level::

    ExtentRecord(addr, nbytes, kind, arrival_ns, stream_id)

``addr``/``nbytes`` address the row-aligned virtual address space the
layer-op allocator (:class:`repro.trace.layergraph.RowAllocator`) and the
paged KV cache hand out; ``kind`` is ``"read"`` or ``"write"``;
``arrival_ns`` is when the transfer becomes visible to the memory
controller; ``stream_id`` tags the issuing software stream (layer op,
tenant, sequence) for grouping and stats.

The stream is the single workload currency of the repo: layer-op traces
(:func:`repro.workloads.from_layer_ops`), synthetic generators
(:func:`bulk_stream`, :func:`strided_stream`, :func:`sparse_stream`),
and the paged KV cache all produce it; the cycle-level
:class:`repro.core.system_sim.SystemSim`, the closed-form
:func:`repro.core.analytic.stream_time_ns`, and the TPOT model
(:func:`repro.perfmodel.tpot.stream_mem_ns`) all consume it.

Streams are immutable values: slicing, merging, shifting, and retagging
return new streams.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Iterable, Iterator

KINDS = ("read", "write")


@dataclass(frozen=True)
class ExtentRecord:
    """One contiguous transfer in the software address space."""

    addr: int
    nbytes: int
    kind: str = "read"          # "read" | "write"
    arrival_ns: float = 0.0
    stream_id: int = 0

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")
        if self.nbytes <= 0:
            raise ValueError(f"nbytes must be positive, got {self.nbytes}")
        if self.addr < 0:
            raise ValueError(f"addr must be non-negative, got {self.addr}")

    @property
    def is_write(self) -> bool:
        return self.kind == "write"

    @property
    def end(self) -> int:
        return self.addr + self.nbytes


class ExtentStream:
    """Ordered, immutable sequence of :class:`ExtentRecord` entries.

    Order is *issue order* — the order transactions reach the memory
    controller for records with equal arrival times. Builders emit
    records in non-decreasing ``arrival_ns``; :meth:`interleave` and
    :meth:`sorted_by_arrival` restore that invariant after merging.
    """

    __slots__ = ("_records", "_memo")

    def __init__(self, records: Iterable[ExtentRecord] = ()) -> None:
        recs = tuple(records)
        for r in recs:
            if not isinstance(r, ExtentRecord):
                raise TypeError(f"expected ExtentRecord, got {type(r)!r}")
        object.__setattr__(self, "_records", recs)
        # Per-instance scratch for derived immutable views (numpy arrays,
        # queue-model features). Never part of equality/hashing.
        object.__setattr__(self, "_memo", {})

    # -- sequence protocol ---------------------------------------------------

    @property
    def records(self) -> tuple[ExtentRecord, ...]:
        return self._records

    def __len__(self) -> int:
        return len(self._records)

    def __iter__(self) -> Iterator[ExtentRecord]:
        return iter(self._records)

    def __bool__(self) -> bool:
        return bool(self._records)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return ExtentStream(self._records[i])
        return self._records[i]

    def __add__(self, other: "ExtentStream") -> "ExtentStream":
        return ExtentStream(self._records + tuple(other))

    def __eq__(self, other) -> bool:
        return (isinstance(other, ExtentStream)
                and self._records == other._records)

    def __hash__(self) -> int:
        return hash(self._records)

    def __repr__(self) -> str:
        return (f"ExtentStream({len(self)} records, "
                f"{self.read_bytes} B read, {self.write_bytes} B write, "
                f"span {self.span_ns:.0f} ns)")

    # -- aggregate views -----------------------------------------------------

    @property
    def total_bytes(self) -> int:
        return sum(r.nbytes for r in self._records)

    @property
    def read_bytes(self) -> int:
        return sum(r.nbytes for r in self._records if not r.is_write)

    @property
    def write_bytes(self) -> int:
        return sum(r.nbytes for r in self._records if r.is_write)

    @property
    def span_ns(self) -> float:
        """Arrival span (last arrival - first arrival); 0 for <=1 record."""
        if len(self._records) < 2:
            return 0.0
        ts = [r.arrival_ns for r in self._records]
        return max(ts) - min(ts)

    @property
    def last_arrival_ns(self) -> float:
        return max((r.arrival_ns for r in self._records), default=0.0)

    @property
    def stream_ids(self) -> tuple[int, ...]:
        seen: dict[int, None] = {}
        for r in self._records:
            seen.setdefault(r.stream_id, None)
        return tuple(seen)

    def extents(self, kind: str | None = None) -> list[tuple[int, int]]:
        """(addr, nbytes) pairs, optionally filtered by kind — the legacy
        extent-list view consumed by ``channel_bytes``/``transfer_time_ns``."""
        if kind is not None and kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
        return [(r.addr, r.nbytes) for r in self._records
                if kind is None or r.kind == kind]

    @property
    def memo(self) -> dict:
        """Per-instance cache for derived views keyed by the deriver.

        Streams are immutable, so anything computed from the records
        (feature censuses, pricing signatures) stays valid for the
        stream's lifetime. Excluded from ``__eq__``/``__hash__``.
        """
        return self._memo

    def arrays(self):
        """Columnar numpy view ``(addr, nbytes, is_write, arrival_ns)``
        of the records, computed once per instance — the input format of
        the vectorized censuses (:func:`repro.core.address_map
        .extent_census`) and the batched queue-model pricer."""
        cached = self._memo.get("arrays")
        if cached is None:
            import numpy as np
            n = len(self._records)
            addr = np.empty(n, np.int64)
            nbytes = np.empty(n, np.int64)
            is_write = np.empty(n, bool)
            arrival = np.empty(n, np.float64)
            for i, r in enumerate(self._records):
                addr[i] = r.addr
                nbytes[i] = r.nbytes
                is_write[i] = r.kind == "write"
                arrival[i] = r.arrival_ns
            for a in (addr, nbytes, is_write, arrival):
                a.setflags(write=False)
            cached = self._memo["arrays"] = (addr, nbytes, is_write, arrival)
        return cached

    # -- derivation ----------------------------------------------------------

    def of_kind(self, kind: str) -> "ExtentStream":
        if kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
        return ExtentStream(r for r in self._records if r.kind == kind)

    def of_stream(self, stream_id: int) -> "ExtentStream":
        return ExtentStream(r for r in self._records
                            if r.stream_id == stream_id)

    def shifted(self, dt_ns: float) -> "ExtentStream":
        """Every arrival moved by ``dt_ns``."""
        return ExtentStream(replace(r, arrival_ns=r.arrival_ns + dt_ns)
                            for r in self._records)

    def retagged(self, stream_id: int) -> "ExtentStream":
        return ExtentStream(replace(r, stream_id=stream_id)
                            for r in self._records)

    def rebased(self, base_addr: int) -> "ExtentStream":
        """Addresses translated so the lowest address becomes ``base_addr``."""
        if not self._records:
            return self
        lo = min(r.addr for r in self._records)
        return ExtentStream(replace(r, addr=r.addr - lo + base_addr)
                            for r in self._records)

    def sorted_by_arrival(self) -> "ExtentStream":
        """Stable sort by arrival time (preserves issue order within ties)."""
        return ExtentStream(sorted(self._records,
                                   key=lambda r: r.arrival_ns))

    def limit_bytes(self, budget: int) -> "ExtentStream":
        """Longest prefix whose total bytes do not exceed ``budget``
        (always keeps at least one record if the stream is non-empty)."""
        out, tot = [], 0
        for r in self._records:
            if out and tot + r.nbytes > budget:
                break
            out.append(r)
            tot += r.nbytes
        return ExtentStream(out)

    def coalesced(self, granularity: int = 1) -> "ExtentStream":
        """Merge same-kind records whose ranges overlap or touch once
        rounded out to ``granularity`` (e.g. the 4 KB row): the MC-side
        request merge that deduplicates row fetches for a sparse gather.
        Merged records keep the earliest arrival and the first
        contributor's stream id; output is ordered by (arrival, addr).
        """
        if granularity < 1:
            raise ValueError(f"granularity must be >= 1, got {granularity}")
        merged: list[list] = []
        for kind in KINDS:
            recs = sorted((r for r in self._records if r.kind == kind),
                          key=lambda r: r.addr)
            cur: list | None = None
            for r in recs:
                lo = (r.addr // granularity) * granularity
                hi = -(-r.end // granularity) * granularity
                if cur is not None and lo <= cur[1]:
                    cur[1] = max(cur[1], hi)
                    cur[2] = min(cur[2], r.arrival_ns)
                else:
                    if cur is not None:
                        merged.append(cur)
                    cur = [lo, hi, r.arrival_ns, r.stream_id, kind]
            if cur is not None:
                merged.append(cur)
        merged.sort(key=lambda c: (c[2], c[0]))
        return ExtentStream(
            ExtentRecord(lo, hi - lo, kind, t, sid)
            for lo, hi, t, sid, kind in merged)

    @staticmethod
    def interleave(streams: Iterable["ExtentStream"]) -> "ExtentStream":
        """Merge streams by arrival time into one multi-tenant stream.

        The merge is stable: records with equal arrivals keep the order of
        the input streams, so per-stream issue order survives. Callers are
        responsible for tagging tenants apart (:meth:`retagged`) if the
        inputs share stream ids.
        """
        tagged = []
        for si, s in enumerate(streams):
            for ri, r in enumerate(s):
                tagged.append((r.arrival_ns, si, ri, r))
        tagged.sort(key=lambda t: (t[0], t[1], t[2]))
        return ExtentStream(t[3] for t in tagged)


__all__ = ["ExtentRecord", "ExtentStream", "KINDS"]
