"""Mixture-of-Experts FFN (granite-moe 40e top-8, phi3.5-moe 16e top-2).

GShard/flaxformer-style capacity-based dispatch: tokens are processed in
groups; within a group each token's top-k experts receive it up to a static
per-expert capacity (overflow tokens are dropped — their combine weight is
zero). Expert weights are stacked (E, d, ff) so the whole layer is three
einsums + routing, which (a) scans cleanly over layers, (b) shards over the
``model`` axis as expert parallelism when E % tp == 0, falling back to
tensor parallelism inside each expert otherwise (granite: 40 experts on
tp=16 -> ff sharding).

RoMe note (paper Fig 13): expert streams are the LBR stress case — each
selected expert's weights are one contiguous row-aligned extent, but only
top-k of E extents are touched per token group. repro.trace reproduces
that access pattern from this exact dispatch math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compat import active_mesh, mesh_axis_sizes
from ..distributed.sharding import shard_hint
from .layers import dense_init


def moe_params(key, cfg, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, m.n_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.expert_d_ff), dtype),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.expert_d_ff), dtype),
        "w_down": dense_init(ks[3], (m.n_experts, m.expert_d_ff, d), dtype),
    }


def moe_param_specs(cfg, fsdp, tp: int) -> dict:
    m = cfg.moe
    ep = (m.n_experts % tp == 0)
    if ep:
        return {
            "router": (None, None),
            "w_gate": ("model", fsdp, None),
            "w_up": ("model", fsdp, None),
            "w_down": ("model", None, fsdp),
        }
    return {
        "router": (None, None),
        "w_gate": (None, fsdp, "model"),
        "w_up": (None, fsdp, "model"),
        "w_down": (None, "model", fsdp),
    }


def pick_group_size(cfg, cap: int = 512) -> int:
    """Routing-group length bounding dispatch overhead.

    The GShard dispatch/combine einsums cost ~2*g^2*k*cf*d FLOPs per group
    vs 6*g*k*d*ff useful expert FLOPs — ratio cf*g/(3*ff). Tiny-expert
    archs (granite: ff=512) need small groups: pick the largest power of
    two with ratio <= ~10 % (EXPERIMENTS.md §Perf, confirmed hypothesis)."""
    m = cfg.moe
    target = max(64, int(0.3 * m.expert_d_ff / m.capacity_factor))
    g = 64
    while g * 2 <= min(cap, target):
        g *= 2
    return g


def moe_ffn(params: dict, x: jax.Array, cfg, group_size: int | None = None,
            impl: str = "einsum") -> jax.Array:
    """x: (b, s, d) -> (b, s, d).

    ``impl="einsum"`` (default) is the classic GShard one-hot dispatch:
    two (t x E x C) einsums move tokens into/out of the expert buffers —
    dense MXU work that partitions cleanly under SPMD.
    ``impl="gather"`` computes identical routing with an (E, C) index
    table and gathers. Measured (EXPERIMENTS.md §Perf): gather LOSES badly
    under SPMD training — the data-dependent scatter lowers to
    all-to-all/collective-permute storms (751 GB/chip on granite train) —
    the same trade GShard made. Kept for single-device serving research.
    """
    m = cfg.moe
    b, s, d = x.shape
    tokens = b * s
    if group_size is None:
        group_size = pick_group_size(cfg)
    g = min(group_size, tokens)
    # Groups must not straddle pods: a group spanning the pod axis forces
    # the dispatch einsum to reduce over it and every pod then runs the
    # GLOBAL expert GEMMs (measured: phi3.5 decode multi-pod, useful
    # flops 0.10 -> 0.75 with the cap). Within a pod XLA partitions the
    # group internally (measured fine on the 16x16 mesh), so only the
    # `pod` axis caps g.
    mesh = active_mesh()
    sizes = mesh_axis_sizes(mesh) if mesh is not None else {}
    pods = sizes.get("pod", 1)
    if pods > 1 and tokens % pods == 0:
        g = max(1, min(g, tokens // pods))
    while tokens % g:
        g -= 1
    n_groups = tokens // g
    xf = x.reshape(n_groups, g, d)

    # Routing in fp32.
    logits = xf.astype(jnp.float32) @ params["router"]          # (G, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)          # (G, g, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # Floor at top_k so tiny (decode-sized) groups cannot structurally
    # drop a token's every slot.
    capacity = max(m.top_k,
                   int(m.capacity_factor * g * m.top_k / m.n_experts))

    def positions(gi):
        """Position of each (token, slot) within its expert, counted
        slot-major so slot-0 assignments win capacity first. (g, k)."""
        oh = jax.nn.one_hot(gi, m.n_experts, dtype=jnp.float32)
        oh_sm = jnp.transpose(oh, (1, 0, 2)).reshape(m.top_k * g,
                                                     m.n_experts)
        pos_sm = jnp.cumsum(oh_sm, axis=0) - oh_sm
        pos = jnp.transpose(pos_sm.reshape(m.top_k, g, m.n_experts),
                            (1, 0, 2))                           # (g, k, E)
        return jnp.sum(pos * oh, -1).astype(jnp.int32), oh       # (g, k)

    def route_einsum(xg, gv, gi):
        pos_tok, oh = positions(gi)
        keep = (pos_tok[..., None] < capacity) & (oh > 0)
        pos_oh = jax.nn.one_hot(pos_tok, capacity, dtype=jnp.float32)
        dispatch = jnp.einsum("tke,tkc->tec", oh * keep, pos_oh)
        combine = jnp.einsum("tk,tke,tkc->tec", gv, oh * keep, pos_oh)
        ein = jnp.einsum("tec,td->ecd", dispatch, xg.astype(jnp.float32))
        ein = ein.astype(x.dtype)
        ein = shard_hint(ein, "model", None, None)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein, params["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", ein, params["w_up"])
        out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
        return jnp.einsum("tec,ecd->td", combine.astype(x.dtype), out)

    def route_gather(xg, gv, gi):
        pos_tok, _ = positions(gi)                               # (g, k)
        keep = pos_tok < capacity
        # (E, C) table of source-token ids; dropped slots point at token 0
        # with zero combine weight.
        table = jnp.zeros((m.n_experts, capacity), jnp.int32)
        tok_ids = jnp.broadcast_to(jnp.arange(g, dtype=jnp.int32)[:, None],
                                   (g, m.top_k))
        e_idx = jnp.where(keep, gi, m.n_experts)       # overflow -> dropped
        c_idx = jnp.clip(pos_tok, 0, capacity - 1)
        table = table.at[e_idx, c_idx].set(tok_ids, mode="drop")
        filled = jnp.zeros((m.n_experts, capacity), jnp.bool_) \
            .at[e_idx, c_idx].set(True, mode="drop")
        ein = xg[table] * filled[..., None].astype(x.dtype)     # (E, C, d)
        ein = shard_hint(ein, "model", None, None)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein, params["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", ein, params["w_up"])
        out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])   # (E, C, d)
        # Pull each (token, slot)'s result back and weight it.
        back = out[gi, c_idx]                                    # (g, k, d)
        w = (gv * keep).astype(x.dtype)
        return jnp.einsum("tk,tkd->td", w, back)

    route = route_gather if impl == "gather" else route_einsum
    y = jax.vmap(route)(xf, gate_vals, gate_idx)
    return y.reshape(b, s, d)


def aux_load_balance_loss(router_probs: jax.Array,
                          gate_idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * p_e."""
    oh = jax.nn.one_hot(gate_idx[..., 0], n_experts, dtype=jnp.float32)
    f = jnp.mean(oh, axis=tuple(range(oh.ndim - 1)))
    p = jnp.mean(router_probs, axis=tuple(range(router_probs.ndim - 1)))
    return n_experts * jnp.sum(f * p)
