"""Arch registry: uniform adapter over the model families.

Every architecture exposes the same surface:

    adapter = get_adapter("qwen3-14b")
    params  = adapter.init(key, tp=16)
    logits  = adapter.forward(params, batch)            # train / prefill
    loss    = adapter.loss(params, batch)
    state   = adapter.init_decode_state(batch, max_seq)
    logits, state = adapter.decode(params, batch, state, pos)

`batch` is a dict: {"tokens": (b, s)} plus per-family extras
("vision_embeds" for vlm, "frames" for audio). The launch layer builds
ShapeDtypeStruct stand-ins from `input_structs()` for the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..configs.registry_configs import ALL_ARCHS
from ..distributed.sharding import padded_vocab
from . import mllama, rwkv6, transformer, whisper, zamba2


def _xent(logits: jax.Array, labels: jax.Array, vocab: int) -> jax.Array:
    """Mean next-token cross entropy; logits (b, s, Vp), labels (b, s)."""
    lg = logits[:, :-1].astype(jnp.float32)
    lb = labels[:, 1:]
    # Padded vocab entries never win: mask them out of the logsumexp.
    # Elementwise where (NOT .at[...].set on a static slice): a tail-slice
    # update is not aligned to the vocab sharding, so XLA would replicate
    # the full fp32 logits on every chip (measured: 13.6 GB/chip on
    # whisper train_4k).
    Vp = lg.shape[-1]
    if Vp > vocab:
        pad = jnp.arange(Vp) >= vocab
        lg = jnp.where(pad, -1e9, lg)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, lb[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


@dataclass
class ModelAdapter:
    cfg: ArchConfig
    _init: Callable
    _forward: Callable            # (params, cfg, batch, remat) -> logits
    _decode: Callable             # (params, cfg, batch, state, pos)
    _init_state: Callable         # (cfg, batch, max_seq, dtype) -> state
    _param_specs: Callable
    _state_specs: Callable
    extra_inputs: tuple = ()

    # -- params ---------------------------------------------------------------

    def init(self, key, tp: int = 1):
        return self._init(self.cfg, key, tp)

    def param_specs(self, fsdp=None, tp: int = 16):
        return self._param_specs(self.cfg, fsdp, tp)

    # -- train / prefill --------------------------------------------------------

    def forward(self, params, batch: dict, remat: bool = False):
        return self._forward(params, self.cfg, batch, remat)

    def loss(self, params, batch: dict, remat: bool = False):
        logits = self.forward(params, batch, remat)
        return _xent(logits, batch["labels"], self.cfg.vocab)

    # -- decode -----------------------------------------------------------------

    def init_decode_state(self, batch: int, max_seq: int,
                          dtype=jnp.bfloat16, tp: int = 1):
        return self._init_state(self.cfg, batch, max_seq, dtype, tp)

    def decode(self, params, batch: dict, state, pos):
        return self._decode(params, self.cfg, batch, state, pos)

    def state_specs(self):
        return self._state_specs(self.cfg)

    # -- dry-run input structures ------------------------------------------------

    def input_structs(self, seq_len: int, global_batch: int,
                      kind: str) -> dict:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        c = self.cfg
        dt = jnp.dtype(c.dtype)
        i32 = jnp.int32
        out: dict[str, Any] = {}
        if kind in ("train", "prefill"):
            out["tokens"] = jax.ShapeDtypeStruct((global_batch, seq_len), i32)
            if kind == "train":
                out["labels"] = jax.ShapeDtypeStruct(
                    (global_batch, seq_len), i32)
        else:  # decode: one new token against a seq_len cache
            out["tokens"] = jax.ShapeDtypeStruct((global_batch, 1), i32)
        if "vision_embeds" in self.extra_inputs:
            out["vision_embeds"] = jax.ShapeDtypeStruct(
                (global_batch, c.n_vision_tokens, c.d_model), dt)
        if "frames" in self.extra_inputs:
            out["frames"] = jax.ShapeDtypeStruct(
                (global_batch, c.n_audio_frames, c.d_model), dt)
        return out

    def supports(self, shape_kind: str, seq_len: int) -> tuple[bool, str]:
        """(runnable, reason-if-not) for an assigned (shape, seq) cell."""
        c = self.cfg
        if seq_len > 100_000 and not c.supports_long_context:
            return False, ("pure full-attention arch: 512K dense-attention "
                           "KV exceeds any sane decode budget (DESIGN.md)")
        return True, ""


# ---------------------------------------------------------------------------
# Family wiring
# ---------------------------------------------------------------------------

def _tfm_forward(params, cfg, batch, remat):
    return transformer.forward(params, cfg, batch["tokens"], remat)


def _tfm_decode(params, cfg, batch, state, pos):
    return transformer.decode_step(params, cfg, batch["tokens"], state, pos)


def _rwkv_forward(params, cfg, batch, remat):
    return rwkv6.forward(params, cfg, batch["tokens"], remat)


def _rwkv_decode(params, cfg, batch, state, pos):
    return rwkv6.decode_step(params, cfg, batch["tokens"], state, pos)


def _rwkv_init_state(cfg, batch, max_seq, dtype, tp=1):
    return rwkv6.init_state(cfg, batch)


def _zamba_forward(params, cfg, batch, remat):
    return zamba2.forward(params, cfg, batch["tokens"], remat)


def _zamba_decode(params, cfg, batch, state, pos):
    return zamba2.decode_step(params, cfg, batch["tokens"], state, pos)


def _mllama_forward(params, cfg, batch, remat):
    return mllama.forward(params, cfg, batch["tokens"],
                          batch["vision_embeds"], remat)


def _mllama_decode(params, cfg, batch, state, pos):
    return mllama.decode_step(params, cfg, batch["tokens"], state, pos)


def _whisper_forward(params, cfg, batch, remat):
    return whisper.forward(params, cfg, batch["tokens"], batch["frames"],
                           remat)


def _whisper_decode(params, cfg, batch, state, pos):
    return whisper.decode_step(params, cfg, batch["tokens"], state, pos)


_FAMILY = {
    "dense": dict(_init=transformer.init, _forward=_tfm_forward,
                  _decode=_tfm_decode, _init_state=transformer.init_cache,
                  _param_specs=transformer.param_specs,
                  _state_specs=transformer.cache_specs),
    "moe": dict(_init=transformer.init, _forward=_tfm_forward,
                _decode=_tfm_decode, _init_state=transformer.init_cache,
                _param_specs=transformer.param_specs,
                _state_specs=transformer.cache_specs),
    "ssm": dict(_init=rwkv6.init, _forward=_rwkv_forward,
                _decode=_rwkv_decode, _init_state=_rwkv_init_state,
                _param_specs=rwkv6.param_specs,
                _state_specs=rwkv6.state_specs),
    "hybrid": dict(_init=zamba2.init, _forward=_zamba_forward,
                   _decode=_zamba_decode, _init_state=zamba2.init_state,
                   _param_specs=zamba2.param_specs,
                   _state_specs=zamba2.state_specs),
    "vlm": dict(_init=mllama.init, _forward=_mllama_forward,
                _decode=_mllama_decode, _init_state=mllama.init_cache,
                _param_specs=mllama.param_specs,
                _state_specs=mllama.cache_specs,
                extra_inputs=("vision_embeds",)),
    "audio": dict(_init=whisper.init, _forward=_whisper_forward,
                  _decode=_whisper_decode, _init_state=whisper.init_cache,
                  _param_specs=whisper.param_specs,
                  _state_specs=whisper.cache_specs,
                  extra_inputs=("frames",)),
}


def make_adapter(cfg: ArchConfig) -> ModelAdapter:
    wiring = dict(_FAMILY[cfg.family])
    extra = wiring.pop("extra_inputs", ())
    return ModelAdapter(cfg=cfg, extra_inputs=extra, **wiring)


def get_adapter(arch_id_or_cfg) -> ModelAdapter:
    cfg = (arch_id_or_cfg if isinstance(arch_id_or_cfg, ArchConfig)
           else ALL_ARCHS[arch_id_or_cfg])
    return make_adapter(cfg)
