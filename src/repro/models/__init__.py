from .registry import ModelAdapter, get_adapter, make_adapter

__all__ = ["ModelAdapter", "get_adapter", "make_adapter"]
