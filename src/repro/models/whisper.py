"""Whisper-small backbone: encoder-decoder transformer (arXiv:2212.04356).
12 encoder + 12 decoder layers, d_model 768, 12 heads, d_ff 3072,
vocab 51865 (padded to 51968).

The conv frontend is a STUB per the brief: `input_specs()` provides
precomputed frame embeddings (b, n_audio_frames, d_model). Positional
information uses sinusoidal embeddings on both sides (the original uses
learned embeddings on the decoder; sinusoids remove the fixed-length table
so the assigned decode_32k cell lowers cleanly — adaptation noted in
DESIGN.md). Pre-LN with biased projections and GELU MLPs, faithful to the
original block structure. Decoder output head ties the token embedding.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..compat import tree_map
from ..distributed.sharding import (hint_residual, padded_heads,
                                    padded_vocab, shard_hint)
from .layers import (CHUNKED_ATTN_THRESHOLD, attention_scores,
                     chunked_attention, dense_init, layernorm, repeat_kv)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def sinusoid_pos(positions: jax.Array, d: int) -> jax.Array:
    """(..., s) int32 -> (..., s, d) float32 sinusoidal embeddings."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _attn_init(key, cfg, nH, dt):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, nH * hd), dt),
        "bq": jnp.zeros((nH * hd,), dt),
        "wk": dense_init(ks[1], (d, nH * hd), dt),
        "wv": dense_init(ks[2], (d, nH * hd), dt),
        "bv": jnp.zeros((nH * hd,), dt),
        "wo": dense_init(ks[3], (nH * hd, d), dt),
        "bo": jnp.zeros((d,), dt),
    }


def _mlp_init(key, cfg, dt):
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, (cfg.d_model, cfg.d_ff), dt),
        "b_up": jnp.zeros((cfg.d_ff,), dt),
        "w_down": dense_init(k2, (cfg.d_ff, cfg.d_model), dt),
        "b_down": jnp.zeros((cfg.d_model,), dt),
    }


def _ln_init(cfg, dt):
    return {"w": jnp.ones((cfg.d_model,), dt),
            "b": jnp.zeros((cfg.d_model,), dt)}


def init(cfg, key, tp: int = 1) -> dict:
    dt = _dtype(cfg)
    nH = padded_heads(cfg.n_heads, tp)
    V = padded_vocab(cfg.vocab)
    keys = jax.random.split(key, 4)

    def enc_block(k):
        ka, km = jax.random.split(k)
        return {"attn": _attn_init(ka, cfg, nH, dt),
                "ln_attn": _ln_init(cfg, dt),
                "mlp": _mlp_init(km, cfg, dt),
                "ln_mlp": _ln_init(cfg, dt)}

    def dec_block(k):
        ka, kx, km = jax.random.split(k, 3)
        return {"attn": _attn_init(ka, cfg, nH, dt),
                "ln_attn": _ln_init(cfg, dt),
                "xattn": _attn_init(kx, cfg, nH, dt),
                "ln_xattn": _ln_init(cfg, dt),
                "mlp": _mlp_init(km, cfg, dt),
                "ln_mlp": _ln_init(cfg, dt)}

    return {
        "embed": dense_init(keys[0], (V, cfg.d_model), dt, scale=0.02),
        "encoder": jax.vmap(enc_block)(
            jax.random.split(keys[1], cfg.encoder_layers)),
        "decoder": jax.vmap(dec_block)(
            jax.random.split(keys[2], cfg.n_layers)),
        "ln_enc": _ln_init(cfg, dt),
        "ln_dec": _ln_init(cfg, dt),
    }


def param_specs(cfg, fsdp=None, tp: int = 16) -> dict:
    attn = {"wq": (fsdp, "model"), "bq": ("model",), "wk": (fsdp, "model"),
            "wv": (fsdp, "model"), "bv": ("model",), "wo": ("model", fsdp),
            "bo": (None,)}
    mlp = {"w_up": (fsdp, "model"), "b_up": ("model",),
           "w_down": ("model", fsdp), "b_down": (None,)}
    ln = {"w": (None,), "b": (None,)}
    enc = {"attn": attn, "ln_attn": ln, "mlp": mlp, "ln_mlp": ln}
    dec = enc | {"xattn": attn, "ln_xattn": ln}
    stack = lambda blk: tree_map(lambda s: (None,) + s, blk,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return {"embed": ("model", fsdp), "encoder": stack(enc),
            "decoder": stack(dec), "ln_enc": ln, "ln_dec": ln}


# ---------------------------------------------------------------------------
# Attention helpers (biased projections, whisper-style)
# ---------------------------------------------------------------------------

def _heads(cfg, x, w, b=None):
    bsz, s, _ = x.shape
    hd = cfg.resolved_head_dim
    y = x @ w
    if b is not None:
        y = y + b
    return y.reshape(bsz, s, -1, hd).transpose(0, 2, 1, 3)


def _attn(params, cfg, x, kv, mask, causal: bool = False):
    q = _heads(cfg, x, params["wq"], params["bq"])
    k = _heads(cfg, kv, params["wk"])
    v = _heads(cfg, kv, params["wv"], params["bv"])
    # Long causal self-attention takes the chunked online-softmax path —
    # the dense (s x s) fp32 logits alone are 8.6 GB/chip at 32K
    # (whisper prefill_32k buffer census, EXPERIMENTS.md).
    if causal and x.shape[1] >= CHUNKED_ATTN_THRESHOLD:
        out = chunked_attention(q, k, v)
    else:
        out = attention_scores(q, k, v, mask)
    b, h, s, hd = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return out @ params["wo"] + params["bo"]


def _mlp(params, x):
    return jax.nn.gelu(x @ params["w_up"] + params["b_up"]) \
        @ params["w_down"] + params["b_down"]


def _ln(p, x, eps=1e-5):
    return layernorm(x, p["w"], p["b"], eps)


# ---------------------------------------------------------------------------
# Encoder / decoder
# ---------------------------------------------------------------------------

def _enc_block(cfg, h, bp):
    h = h + _attn(bp["attn"], cfg, _ln(bp["ln_attn"], h),
                  _ln(bp["ln_attn"], h), None)
    h = h + _mlp(bp["mlp"], _ln(bp["ln_mlp"], h))
    return hint_residual(h)


def encode(params, cfg, frames, remat: bool = False):
    """frames: (b, n_frames, d_model) stub embeddings -> encoder output."""
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = frames + sinusoid_pos(pos, cfg.d_model).astype(frames.dtype)
    h = shard_hint(h, ("pod", "data"), None, None)

    block = _enc_block
    if remat:
        block = jax.checkpoint(_enc_block, static_argnums=(0,))

    def blk(h, bp):
        return block(cfg, h, bp), None

    h, _ = jax.lax.scan(blk, h, params["encoder"])
    return _ln(params["ln_enc"], h)


def _dec_block(cfg, h, bp, enc, mask):
    x = _ln(bp["ln_attn"], h)
    h = h + _attn(bp["attn"], cfg, x, x, mask, causal=True)
    h = h + _attn(bp["xattn"], cfg, _ln(bp["ln_xattn"], h), enc, None)
    h = h + _mlp(bp["mlp"], _ln(bp["ln_mlp"], h))
    return hint_residual(h)


def forward(params, cfg, tokens, frames, remat: bool = False):
    """Teacher-forced training forward: (b, s) tokens + frames -> logits."""
    enc = encode(params, cfg, frames, remat)
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = params["embed"][tokens] + sinusoid_pos(pos, cfg.d_model) \
        .astype(_dtype(cfg))
    from .layers import causal_mask
    mask = causal_mask(s, s)

    block = _dec_block
    if remat:
        block = jax.checkpoint(_dec_block, static_argnums=(0,))

    def blk(h, bp):
        return block(cfg, h, bp, enc, mask), None

    h, _ = jax.lax.scan(blk, h, params["decoder"])
    h = _ln(params["ln_dec"], h)
    logits = h @ params["embed"].T
    return shard_hint(logits, ("pod", "data"), None, "model")


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16,
               tp: int = 1) -> dict:
    hd = cfg.resolved_head_dim
    # MHA: the KV heads are the (TP-padded) query heads.
    nH = padded_heads(cfg.n_heads, tp)
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, nH, max_seq, hd), dtype),
        "v": jnp.zeros((L, batch, nH, max_seq, hd), dtype),
        # cross KV precomputed from encoder output
        "xk": jnp.zeros((L, batch, nH, cfg.n_audio_frames, hd), dtype),
        "xv": jnp.zeros((L, batch, nH, cfg.n_audio_frames, hd), dtype),
    }


def cache_specs(cfg) -> dict:
    s = (None, ("pod", "data"), None, "model", None)
    return {"k": s, "v": s, "xk": s, "xv": s}


def precompute_cross_kv(params, cfg, enc_out):
    def one(bp):
        k = _heads(cfg, enc_out, bp["xattn"]["wk"])
        v = _heads(cfg, enc_out, bp["xattn"]["wv"], bp["xattn"]["bv"])
        return k, v

    return jax.vmap(one)(params["decoder"])


def decode_step(params, cfg, token, cache, pos):
    """fori_loop with in-place per-layer cache updates and the
    context-parallel cached-attention primitive (see
    transformer.decode_step / EXPERIMENTS.md §Perf A.1-A.2)."""
    from .layers import cached_attention_update
    b = token.shape[0]
    posb = jnp.broadcast_to(pos, (b, 1))
    h = params["embed"][token] + sinusoid_pos(posb, cfg.d_model) \
        .astype(_dtype(cfg))
    L = cache["k"].shape[0]

    def blk(i, carry):
        h, kc_all, vc_all = carry
        bp = tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
            params["decoder"])
        kc = jax.lax.dynamic_index_in_dim(kc_all, i, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vc_all, i, 0, keepdims=False)
        xk = jax.lax.dynamic_index_in_dim(cache["xk"], i, 0, keepdims=False)
        xv = jax.lax.dynamic_index_in_dim(cache["xv"], i, 0, keepdims=False)
        x = _ln(bp["ln_attn"], h)
        q = _heads(cfg, x, bp["attn"]["wq"], bp["attn"]["bq"])
        k = _heads(cfg, x, bp["attn"]["wk"])
        v = _heads(cfg, x, bp["attn"]["wv"], bp["attn"]["bv"])
        out, kc, vc = cached_attention_update(q, k, v, kc, vc, pos, pos)
        out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
        h = h + (out @ bp["attn"]["wo"] + bp["attn"]["bo"])
        # cross attention against precomputed encoder KV
        xq = _heads(cfg, _ln(bp["ln_xattn"], h), bp["xattn"]["wq"],
                    bp["xattn"]["bq"])
        xout = attention_scores(xq, xk, xv, None)
        xout = xout.transpose(0, 2, 1, 3).reshape(b, 1, -1)
        h = h + (xout @ bp["xattn"]["wo"] + bp["xattn"]["bo"])
        h = h + _mlp(bp["mlp"], _ln(bp["ln_mlp"], h))
        kc_all = jax.lax.dynamic_update_index_in_dim(kc_all, kc, i, 0)
        vc_all = jax.lax.dynamic_update_index_in_dim(vc_all, vc, i, 0)
        return h, kc_all, vc_all

    h, k_new, v_new = jax.lax.fori_loop(0, L, blk,
                                        (h, cache["k"], cache["v"]))
    h = _ln(params["ln_dec"], h)
    logits = h @ params["embed"].T
    logits = shard_hint(logits, ("pod", "data"), None, "model")
    return logits, {"k": k_new, "v": v_new, "xk": cache["xk"],
                    "xv": cache["xv"]}
