"""Shared model layers: norms, rotary embeddings, attention, FFN.

Pure functions over pytree parameters. Attention supports GQA (grouped KV
heads), optional QKV bias (qwen2), per-head q/k RMSNorm (qwen3), sliding
windows (h2o-danube), cross-attention (mllama/whisper), and single-token
decode against a KV cache.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..compat import active_mesh, mesh_axis_sizes, shard_map

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * w + b


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 1e6) -> jax.Array:
    """x: (..., seq, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

def causal_mask(q_len: int, kv_len: int,
                sliding_window: Optional[int] = None) -> jax.Array:
    """(q_len, kv_len) boolean mask, True = attend. Supports SWA."""
    q_pos = jnp.arange(q_len)[:, None] + (kv_len - q_len)
    kv_pos = jnp.arange(kv_len)[None, :]
    m = kv_pos <= q_pos
    if sliding_window is not None:
        m &= kv_pos > q_pos - sliding_window
    return m


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """(b, h_kv, s, d) -> (b, h_kv*n_rep, s, d)."""
    if n_rep == 1:
        return x
    b, h, s, d = x.shape
    return jnp.broadcast_to(x[:, :, None], (b, h, n_rep, s, d)) \
        .reshape(b, h * n_rep, s, d)


def attention_scores(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: Optional[jax.Array]) -> jax.Array:
    """q: (b, h, sq, d), k/v: (b, h, skv, d) -> (b, h, sq, d).

    Softmax in fp32 for stability regardless of io dtype."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def gqa_project(params: dict, x: jax.Array, cfg) -> tuple:
    """Project hidden states to q/k/v heads: returns (q, k, v) shaped
    (b, h, s, hd) / (b, h_kv, s, hd)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    nH = params["wq"].shape[1] // hd
    nKV = params["wk"].shape[1] // hd
    q = q.reshape(b, s, nH, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, nKV, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, nKV, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


# Above this sequence length the full (s x s) fp32 logits of one layer
# exceed any reasonable HBM budget; switch to the chunked online-softmax
# evaluation (flash attention expressed in HLO: memory O(q_chunk*kv_chunk)
# instead of O(s^2), numerics identical).
CHUNKED_ATTN_THRESHOLD = 8192
Q_CHUNK = 2048
KV_CHUNK = 2048


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      sliding_window: Optional[int] = None,
                      q_chunk: int = Q_CHUNK,
                      kv_chunk: int = KV_CHUNK) -> jax.Array:
    """Causal attention via online softmax over KV blocks, lax.map over
    query blocks (sequential => peak memory one (q_chunk x kv_chunk) tile
    per head). q/k/v: (b, h, s, d) -> (b, h, s, d). Exact."""
    b, h, s, d = q.shape
    scale = 1.0 / math.sqrt(d)
    nq = -(-s // q_chunk)
    nkv = -(-s // kv_chunk)
    pad_q = nq * q_chunk - s
    pad_kv = nkv * kv_chunk - s
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    kb = kp.reshape(b, h, nkv, kv_chunk, d)
    vb = vp.reshape(b, h, nkv, kv_chunk, d)

    def one_q_block(qi):
        qblk = jax.lax.dynamic_slice_in_dim(qp, qi * q_chunk, q_chunk, 2)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            m_prev, l_prev, acc = carry
            kj, vj, kv_idx = inp
            kv_pos = kv_idx * kv_chunk + jnp.arange(kv_chunk)
            logits = jnp.einsum("bhqd,bhkd->bhqk", qblk, kj,
                                preferred_element_type=jnp.float32) * scale
            mask = kv_pos[None, :] <= q_pos[:, None]
            if sliding_window is not None:
                mask &= kv_pos[None, :] > q_pos[:, None] - sliding_window
            mask &= (kv_pos < s)[None, :]
            logits = jnp.where(mask, logits, NEG_INF_F32)
            m_new = jnp.maximum(m_prev, logits.max(-1))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l_prev * alpha + p.sum(-1)
            acc = acc * alpha[..., None] \
                + jnp.einsum("bhqk,bhkd->bhqd", p.astype(vj.dtype), vj)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF_F32, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.transpose(2, 0, 1, 3, 4), vb.transpose(2, 0, 1, 3, 4),
             jnp.arange(nkv)))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    out = jax.lax.map(one_q_block, jnp.arange(nq))   # (nq, b, h, qc, d)
    out = out.transpose(1, 2, 0, 3, 4).reshape(b, h, nq * q_chunk, d)
    return out[:, :, :s]


NEG_INF_F32 = -1e30


def self_attention(params: dict, x: jax.Array, cfg,
                   positions: jax.Array,
                   mask: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence GQA self-attention (train / prefill path). Long
    sequences use the chunked online-softmax path (same math, bounded
    memory)."""
    b, s, _ = x.shape
    q, k, v = gqa_project(params, x, cfg)
    q = apply_rope(q, positions[:, None, :], cfg.rope_theta)
    k = apply_rope(k, positions[:, None, :], cfg.rope_theta)
    n_rep = q.shape[1] // k.shape[1]
    k, v = repeat_kv(k, n_rep), repeat_kv(v, n_rep)
    if mask is None and s >= CHUNKED_ATTN_THRESHOLD:
        out = chunked_attention(q, k, v, cfg.sliding_window)
    else:
        if mask is None:
            mask = causal_mask(s, s, cfg.sliding_window)
        out = attention_scores(q, k, v, mask)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out @ params["wo"]


def cross_attention(params: dict, x: jax.Array, kv_input: jax.Array,
                    cfg) -> jax.Array:
    """Cross-attention: queries from `x`, keys/values from `kv_input`
    (vision patches / encoder output). No RoPE, no causal mask. Long query
    sequences (32K prefill) are evaluated in q-blocks — the unblocked
    (s_q x s_kv) fp32 logits alone are ~6 GB/chip on whisper prefill_32k."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(b, s, -1, hd).transpose(0, 2, 1, 3)
    skv = kv_input.shape[1]
    k = (kv_input @ params["wk"]).reshape(b, skv, -1, hd).transpose(0, 2, 1, 3)
    v = (kv_input @ params["wv"]).reshape(b, skv, -1, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    n_rep = q.shape[1] // k.shape[1]
    k, v = repeat_kv(k, n_rep), repeat_kv(v, n_rep)
    if s >= CHUNKED_ATTN_THRESHOLD:
        nq = s // Q_CHUNK if s % Q_CHUNK == 0 else -(-s // Q_CHUNK)
        pad = nq * Q_CHUNK - s
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))

        def one(qi):
            blk = jax.lax.dynamic_slice_in_dim(qp, qi * Q_CHUNK, Q_CHUNK, 2)
            return attention_scores(blk, k, v, None)

        out = jax.lax.map(one, jnp.arange(nq))
        out = out.transpose(1, 2, 0, 3, 4).reshape(b, q.shape[1],
                                                   nq * Q_CHUNK, hd)
        out = out[:, :, :s]
    else:
        out = attention_scores(q, k, v, None)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out @ params["wo"]


# ---------------------------------------------------------------------------
# Context-parallel cached attention (decode)
# ---------------------------------------------------------------------------
# The KV cache shards its *sequence* dim over the ``model`` axis
# (cache_specs). Left to XLA's SPMD partitioner, the per-step cache append
# (dynamic-update-slice at a dynamic slot) triggers the "involuntary full
# rematerialization" path — the whole cache is replicated, converted to
# f32, and re-partitioned every layer (measured: 26 GB -> 382 GB of HBM
# traffic per step on qwen2-7b decode_32k). cached_attention_update instead
# expresses the step with shard_map: each model-shard masks-writes its own
# slice and computes a partial online softmax; shards combine with one
# pmax/psum of (b, heads, hd)-sized tensors — the cache never moves.


def _batch_axes_for(dim: int, mesh) -> tuple:
    axes = []
    prod = 1
    sizes = mesh_axis_sizes(mesh)
    for a in ("pod", "data"):
        if a in sizes and dim % (prod * sizes[a]) == 0:
            axes.append(a)
            prod *= sizes[a]
    return tuple(axes)


def cached_attention_update(q: jax.Array, k_new: jax.Array,
                            v_new: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, pos: jax.Array,
                            slot: jax.Array) -> tuple:
    """One decode step against a sequence-sharded cache.

    q: (b, h, 1, hd); k_new/v_new: (b, h_kv, 1, hd);
    caches: (b, h_kv, S, hd) sharded (batch, None, 'model', None).
    Returns (out (b, h, 1, hd), new_k_cache, new_v_cache). Falls back to
    the single-shard path when no 'model' axis is available or S does not
    divide."""
    from jax.sharding import PartitionSpec as P

    mesh = None
    m = active_mesh()
    if m is not None and "model" in (m.axis_names or ()):
        mesh = m
    b, hq, _, hd = q.shape
    S = k_cache.shape[2]
    if mesh is None or S % mesh_axis_sizes(mesh)["model"]:
        return _cached_attention_local(q, k_new, v_new, k_cache, v_cache,
                                       pos, slot, None)

    bs = _batch_axes_for(b, mesh)
    bspec = (bs if len(bs) > 1 else (bs[0] if bs else None))
    cache_spec = P(bspec, None, "model", None)
    qkv_spec = P(bspec, None, None, None)

    def inner(q, k_new, v_new, kc, vc, pos, slot):
        return _cached_attention_local(q, k_new, v_new, kc, vc, pos, slot,
                                       "model")

    return shard_map(
        inner, mesh=mesh,
        in_specs=(qkv_spec, qkv_spec, qkv_spec, cache_spec, cache_spec,
                  P(), P()),
        out_specs=(qkv_spec, cache_spec, cache_spec),
    )(q, k_new, v_new, k_cache, v_cache, pos, slot)


def _cached_attention_local(q, k_new, v_new, kc, vc, pos, slot,
                            axis: Optional[str]) -> tuple:
    """Per-shard body: masked local append + partial online softmax.
    Inside shard_map `axis` names the model axis; standalone it is None
    (single shard, exact same math)."""
    b, hq, _, hd = q.shape
    hkv = kc.shape[1]
    S_loc = kc.shape[2]
    g = hq // hkv
    if axis is not None:
        shard = jax.lax.axis_index(axis)
    else:
        shard = 0
    start = shard * S_loc
    loc = slot - start
    writable = (loc >= 0) & (loc < S_loc)
    cl = jnp.clip(loc, 0, S_loc - 1)

    def masked_write(cache, new):
        old = jax.lax.dynamic_slice(cache, (0, 0, cl, 0),
                                    (b, hkv, 1, hd))
        upd = jnp.where(writable, new.astype(cache.dtype), old)
        return jax.lax.dynamic_update_slice(cache, upd, (0, 0, cl, 0))

    kc = masked_write(kc, k_new)
    vc = masked_write(vc, v_new)

    # NOTE on operand dtype (§Perf, hypothesis refuted on this meter):
    # feeding the einsums bf16 operands with preferred_element_type=f32
    # (the TPU-native MXU pattern) made XLA-CPU's copy-insertion clone the
    # ENTIRE cache carry every layer (26 GB/chip/step on qwen2 decode) —
    # worse than the f32 slice converts it saved. The astype path measures
    # best on the CPU artifact; on real TPU revisit the bf16-operand form.
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg,
                        kc.astype(jnp.float32)) * scale     # (b,kv,g,S_loc)
    valid = (start + jnp.arange(S_loc)) <= pos
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF_F32)

    m_loc = logits.max(-1)                                  # (b,kv,g)
    if axis is not None:
        m = jax.lax.pmax(m_loc, axis)
    else:
        m = m_loc
    p = jnp.exp(logits - m[..., None])
    l_loc = p.sum(-1)
    acc_loc = jnp.einsum("bkgs,bksd->bkgd", p, vc.astype(jnp.float32))
    if axis is not None:
        l = jax.lax.psum(l_loc, axis)
        acc = jax.lax.psum(acc_loc, axis)
    else:
        l, acc = l_loc, acc_loc
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return out.reshape(b, hq, 1, hd), kc, vc


def decode_attention(params: dict, x: jax.Array, cfg,
                     k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, slot: jax.Array | None = None) -> tuple:
    """Single-token GQA decode. x: (b, 1, d); caches: (b, h_kv, S, hd).

    `pos` is the true sequence position (drives RoPE and validity);
    `slot` is the cache slot to write (defaults to `pos`; sliding-window
    archs pass ``pos % window`` — the ring buffer *is* the window, so no
    extra window masking is needed: evicted slots are overwritten).

    Returns (out (b, 1, d), new_k_cache, new_v_cache). KV-cache updates are
    row-aligned: one (slot, head) write per step, contiguous along hd — the
    serving layer above groups slots into 4 KB DRAM rows (repro.serve).
    """
    b = x.shape[0]
    if slot is None:
        slot = pos
    q, k, v = gqa_project(params, x, cfg)
    posb = jnp.broadcast_to(pos, (b, 1, 1))
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    out, k_cache, v_cache = cached_attention_update(
        q, k, v, k_cache, v_cache, pos, slot)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return out @ params["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def swiglu(params: dict, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) \
        @ params["w_down"]


def gelu_mlp(params: dict, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ params["w_up"] + params["b_up"]) \
        @ params["w_down"] + params["b_down"]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def attn_params(key, cfg, d_q_heads: int, d_kv_heads: int, dtype) -> dict:
    """GQA projection params; head counts may be TP-padded upstream."""
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, d_q_heads * hd), dtype),
        "wk": dense_init(ks[1], (d, d_kv_heads * hd), dtype),
        "wv": dense_init(ks[2], (d, d_kv_heads * hd), dtype),
        "wo": dense_init(ks[3], (d_q_heads * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((d_q_heads * hd,), dtype)
        p["bk"] = jnp.zeros((d_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((d_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def ffn_params(key, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
    }
