"""RWKV6 "Finch" — attention-free LM with data-dependent decay
(arXiv:2404.05892). rwkv6-3b: 32L, d_model 2560, d_ff 8960, vocab 65536.

Per layer: time-mix (multi-head linear attention with per-channel
data-dependent decay w_t and bonus u) + channel-mix. The recurrence

    S_t = diag(w_t) S_{t-1} + k_t^T v_t,   o_t = r_t (diag(u) k_t^T v_t + S_{t-1})

is evaluated with lax.scan over time for train/prefill and as a single
state update for decode (state is O(1) in sequence length — this arch runs
the long_500k cell).

RoMe note: RWKV6 decode traffic is ~100 % weight streaming (no KV cache) —
the paper's best case; the trace layer models it as pure sequential reads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compat import tree_map
from ..distributed.sharding import hint_residual, padded_vocab, shard_hint
from .layers import dense_init, rmsnorm

LORA_RANK = 64
HEAD_DIM = 64


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def n_heads(cfg) -> int:
    return cfg.d_model // HEAD_DIM


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(cfg, key, tp: int = 1) -> dict:
    dt = _dtype(cfg)
    d = cfg.d_model
    V = padded_vocab(cfg.vocab)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)

    def block_init(k):
        ks = jax.random.split(k, 10)
        return {
            # time mix
            "mu": jnp.full((5, d), 0.5, dt),       # r,k,v,g,w shift mixes
            "wr": dense_init(ks[0], (d, d), dt),
            "wk": dense_init(ks[1], (d, d), dt),
            "wv": dense_init(ks[2], (d, d), dt),
            "wg": dense_init(ks[3], (d, d), dt),
            "wo": dense_init(ks[4], (d, d), dt),
            "w0": jnp.full((d,), -5.0, jnp.float32),      # base decay
            "w_lora_a": dense_init(ks[5], (d, LORA_RANK), dt),
            "w_lora_b": dense_init(ks[6], (LORA_RANK, d), dt, scale=0.01),
            "u": jnp.zeros((n_heads(cfg), HEAD_DIM), jnp.float32),  # bonus
            "ln_x": jnp.ones((d,), dt),            # per-head group norm
            "tm_norm": jnp.ones((d,), dt),
            # channel mix
            "mu_c": jnp.full((2, d), 0.5, dt),
            "ck": dense_init(ks[7], (d, cfg.d_ff), dt),
            "cv": dense_init(ks[8], (cfg.d_ff, d), dt),
            "cr": dense_init(ks[9], (d, d), dt),
            "cm_norm": jnp.ones((d,), dt),
        }

    blocks = jax.vmap(block_init)(jax.random.split(k_blocks, cfg.n_layers))
    return {
        "embed": dense_init(k_embed, (V, d), dt, scale=0.02),
        "blocks": blocks,
        "final_norm": jnp.ones((d,), dt),
        "lm_head": dense_init(k_head, (d, V), dt),
    }


def param_specs(cfg, fsdp=None, tp: int = 16) -> dict:
    block = {
        "mu": (None, None), "wr": (fsdp, "model"), "wk": (fsdp, "model"),
        "wv": (fsdp, "model"), "wg": (fsdp, "model"), "wo": ("model", fsdp),
        "w0": (None,), "w_lora_a": (fsdp, None), "w_lora_b": (None, "model"),
        "u": (None, None), "ln_x": (None,), "tm_norm": (None,),
        "mu_c": (None, None), "ck": (fsdp, "model"), "cv": ("model", fsdp),
        "cr": (fsdp, None), "cm_norm": (None,),
    }
    return {
        "embed": ("model", fsdp),
        "blocks": tree_map(lambda s: (None,) + s, block,
                               is_leaf=lambda x: isinstance(x, tuple)),
        "final_norm": (None,),
        "lm_head": (fsdp, "model"),
    }


# ---------------------------------------------------------------------------
# Core mixing
# ---------------------------------------------------------------------------

def _decay(bp, xw: jax.Array) -> jax.Array:
    """Data-dependent per-channel decay in (0, 1): w = exp(-exp(w0 + lora))."""
    lora = jnp.tanh(xw @ bp["w_lora_a"]) @ bp["w_lora_b"]
    return jnp.exp(-jnp.exp(bp["w0"] + lora.astype(jnp.float32)))


def _time_mix_step(bp, cfg, x, x_prev, S):
    """One token of time mixing. x: (b, d); S: (b, H, hd, hd)."""
    H, hd = n_heads(cfg), HEAD_DIM
    b = x.shape[0]
    mix = x[:, None, :] + (x_prev - x)[:, None, :] * bp["mu"]     # (b, 5, d)
    xr, xk, xv, xg, xw = [mix[:, i] for i in range(5)]
    r = (xr @ bp["wr"]).reshape(b, H, hd).astype(jnp.float32)
    k = (xk @ bp["wk"]).reshape(b, H, hd).astype(jnp.float32)
    v = (xv @ bp["wv"]).reshape(b, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ bp["wg"])
    w = _decay(bp, xw).reshape(b, H, hd)                          # (b,H,hd)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)                        # rank-1
    o = jnp.einsum("bhk,bhkv->bhv", r, S + bp["u"][None, :, :, None] * kv)
    S = w[..., None] * S + kv
    o = o.reshape(b, H * hd)
    # per-head group norm
    o = o.reshape(b, H, hd)
    o = (o - o.mean(-1, keepdims=True)) \
        * jax.lax.rsqrt(o.var(-1, keepdims=True) + 64e-5)
    o = o.reshape(b, H * hd).astype(x.dtype) * bp["ln_x"]
    return (o * g) @ bp["wo"], S


def _channel_mix_step(bp, x, x_prev):
    mix = x[:, None, :] + (x_prev - x)[:, None, :] * bp["mu_c"]
    xk, xr = mix[:, 0], mix[:, 1]
    k = jnp.square(jax.nn.relu(xk @ bp["ck"]))
    return (k @ bp["cv"]) * jax.nn.sigmoid(xr @ bp["cr"])


def _layer_seq(bp, cfg, h):
    """Full-sequence layer via scan over time. h: (b, s, d)."""
    b, s, d = h.shape
    S0 = jnp.zeros((b, n_heads(cfg), HEAD_DIM, HEAD_DIM), jnp.float32)

    def tm(carry, x):
        x_prev, S = carry
        xn = x  # already normed
        o, S = _time_mix_step(bp, cfg, xn, x_prev, S)
        return (xn, S), o

    hn = rmsnorm(h, bp["tm_norm"], cfg.norm_eps)
    (_, _), o = jax.lax.scan(tm, (jnp.zeros((b, d), h.dtype), S0),
                             hn.transpose(1, 0, 2))
    h = h + o.transpose(1, 0, 2)

    hn = rmsnorm(h, bp["cm_norm"], cfg.norm_eps)

    def cm(x_prev, x):
        return x, _channel_mix_step(bp, x, x_prev)

    _, oc = jax.lax.scan(cm, jnp.zeros((b, d), h.dtype),
                         hn.transpose(1, 0, 2))
    return hint_residual(h + oc.transpose(1, 0, 2))


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def forward(params, cfg, tokens, remat: bool = False):
    h = params["embed"][tokens]
    h = shard_hint(h, ("pod", "data"), None, None)
    layer = _layer_seq
    if remat:
        layer = jax.checkpoint(_layer_seq, static_argnums=(1,))

    def scan_fn(h, bp):
        return layer(bp, cfg, h), None

    h, _ = jax.lax.scan(scan_fn, h, params["blocks"])
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"]
    return shard_hint(logits, ("pod", "data"), None, "model")


def init_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    """Recurrent decode state (per layer): previous token activations and
    the (H, hd, hd) linear-attention state — O(1) in sequence length."""
    d, L = cfg.d_model, cfg.n_layers
    return {
        "x_tm": jnp.zeros((L, batch, d), jnp.dtype(cfg.dtype)),
        "x_cm": jnp.zeros((L, batch, d), jnp.dtype(cfg.dtype)),
        "S": jnp.zeros((L, batch, n_heads(cfg), HEAD_DIM, HEAD_DIM),
                       jnp.float32),
    }


def state_specs(cfg) -> dict:
    return {
        "x_tm": (None, ("pod", "data"), None),
        "x_cm": (None, ("pod", "data"), None),
        "S": (None, ("pod", "data"), "model", None, None),
    }


def decode_step(params, cfg, token, state, pos=None):
    """token: (b, 1). Returns (logits (b, 1, V), new_state)."""
    h = params["embed"][token][:, 0]      # (b, d)

    def scan_fn(h, layer):
        bp, x_tm, x_cm, S = layer
        hn = rmsnorm(h, bp["tm_norm"], cfg.norm_eps)
        o, S = _time_mix_step(bp, cfg, hn, x_tm, S)
        h = h + o
        hn2 = rmsnorm(h, bp["cm_norm"], cfg.norm_eps)
        oc = _channel_mix_step(bp, hn2, x_cm)
        return h + oc, (hn, hn2, S)

    h, (x_tm, x_cm, S) = jax.lax.scan(
        scan_fn, h, (params["blocks"], state["x_tm"], state["x_cm"],
                     state["S"]))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = (h @ params["lm_head"])[:, None, :]
    logits = shard_hint(logits, ("pod", "data"), None, "model")
    return logits, {"x_tm": x_tm, "x_cm": x_cm, "S": S}
