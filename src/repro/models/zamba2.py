"""Zamba2 — Mamba2 (SSD) backbone with one *shared* attention block applied
periodically (arXiv:2411.15242). zamba2-1.2b: 38 Mamba2 layers, d_model 2048,
ssm_state 64, one shared GQA(32h/kv32) + FFN(8192) block every
`shared_attn_every` layers (shared parameters across all its invocations —
the Zamba trick).

The SSD recurrence per head h with scalar decay a_t:
    H_t = a_t * H_{t-1} + dt_t * (B_t outer x_t),  y_t = C_t . H_t + D * x_t
is evaluated by lax.scan over time for train/prefill and as a single state
update for decode (O(1) state; this arch runs the long_500k cell — the
shared attention uses a sliding window there, an adaptation recorded in
DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compat import tree_map
from ..configs.base import SSMConfig
from ..distributed.sharding import (hint_residual, padded_heads,
                                    padded_vocab, shard_hint)
from .layers import (attn_params, decode_attention, dense_init, ffn_params,
                     rmsnorm, self_attention, swiglu)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _ssm(cfg) -> SSMConfig:
    return cfg.ssm or SSMConfig()


def inner_dim(cfg) -> int:
    return _ssm(cfg).expand * cfg.d_model


def ssm_heads(cfg) -> int:
    return inner_dim(cfg) // _ssm(cfg).head_dim


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(cfg, key, tp: int = 1) -> dict:
    dt = _dtype(cfg)
    d = cfg.d_model
    s = _ssm(cfg)
    din = inner_dim(cfg)
    nh = ssm_heads(cfg)
    V = padded_vocab(cfg.vocab)
    k_embed, k_blocks, k_shared, k_head = jax.random.split(key, 4)

    def mamba_init(k):
        ks = jax.random.split(k, 3)
        return {
            "in_proj": dense_init(ks[0],
                                  (d, 2 * din + 2 * s.state_dim + nh), dt),
            "conv_w": dense_init(ks[1],
                                 (s.conv_width, din + 2 * s.state_dim), dt,
                                 scale=0.5),
            "A_log": jnp.zeros((nh,), jnp.float32),
            "D": jnp.ones((nh,), jnp.float32),
            "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
            "out_proj": dense_init(ks[2], (din, d), dt),
            "norm": jnp.ones((d,), dt),
            "gate_norm": jnp.ones((din,), dt),
        }

    blocks = jax.vmap(mamba_init)(jax.random.split(k_blocks, cfg.n_layers))
    nH = padded_heads(cfg.n_heads, tp)
    ka, kf = jax.random.split(k_shared)
    shared = {
        "attn": attn_params(ka, cfg, nH, cfg.n_kv_heads, dt),
        "attn_norm": jnp.ones((d,), dt),
        "ffn": ffn_params(kf, d, cfg.d_ff, dt),
        "ffn_norm": jnp.ones((d,), dt),
    }
    return {
        "embed": dense_init(k_embed, (V, d), dt, scale=0.02),
        "blocks": blocks,
        "shared": shared,
        "final_norm": jnp.ones((d,), dt),
        "lm_head": dense_init(k_head, (d, V), dt),
    }


def param_specs(cfg, fsdp=None, tp: int = 16) -> dict:
    mamba = {
        "in_proj": (fsdp, "model"), "conv_w": (None, "model"),
        "A_log": (None,), "D": (None,), "dt_bias": (None,),
        "out_proj": ("model", fsdp), "norm": (None,), "gate_norm": (None,),
    }
    hd = cfg.resolved_head_dim
    kv_shardable = (cfg.n_kv_heads * hd) % tp == 0 and cfg.n_kv_heads >= tp
    shared = {
        "attn": {"wq": (fsdp, "model"),
                 "wk": (fsdp, "model" if kv_shardable else None),
                 "wv": (fsdp, "model" if kv_shardable else None),
                 "wo": ("model", fsdp)},
        "attn_norm": (None,),
        "ffn": {"w_gate": (fsdp, "model"), "w_up": (fsdp, "model"),
                "w_down": ("model", fsdp)},
        "ffn_norm": (None,),
    }
    return {
        "embed": ("model", fsdp),
        "blocks": tree_map(lambda sp: (None,) + sp, mamba,
                               is_leaf=lambda x: isinstance(x, tuple)),
        "shared": shared,
        "final_norm": (None,),
        "lm_head": (fsdp, "model"),
    }


# ---------------------------------------------------------------------------
# Mamba2 core
# ---------------------------------------------------------------------------

def _split_proj(cfg, proj):
    s = _ssm(cfg)
    din = inner_dim(cfg)
    nh = ssm_heads(cfg)
    z, x, B, C, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + s.state_dim,
               2 * din + 2 * s.state_dim], axis=-1)
    return z, x, B, C, dt


def _ssd_scan(bp, cfg, xc: jax.Array, Bc: jax.Array, Cc: jax.Array,
              dt_raw: jax.Array, H0: jax.Array):
    """Sequential SSD over time. xc: (b,s,din); Bc/Cc: (b,s,N);
    dt_raw: (b,s,nh). Returns y (b,s,din), final state (b,nh,hd,N)."""
    s_cfg = _ssm(cfg)
    nh, hd, N = ssm_heads(cfg), s_cfg.head_dim, s_cfg.state_dim
    b = xc.shape[0]
    A = -jnp.exp(bp["A_log"])                                   # (nh,) < 0
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + bp["dt_bias"])

    def step(Hs, inp):
        x_t, B_t, C_t, dt_t = inp                    # (b,din),(b,N),(b,N),(b,nh)
        xh = x_t.reshape(b, nh, hd).astype(jnp.float32)
        a = jnp.exp(dt_t * A)                                   # (b,nh)
        dBx = jnp.einsum("bn,bhp->bhpn", B_t.astype(jnp.float32), xh) \
            * dt_t[..., None, None]
        Hs = a[..., None, None] * Hs + dBx                      # (b,nh,hd,N)
        y = jnp.einsum("bhpn,bn->bhp", Hs, C_t.astype(jnp.float32))
        y = y + bp["D"][None, :, None] * xh
        return Hs, y.reshape(b, nh * hd)

    Hs, ys = jax.lax.scan(
        step, H0,
        (xc.transpose(1, 0, 2), Bc.transpose(1, 0, 2),
         Cc.transpose(1, 0, 2), dt.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2).astype(xc.dtype), Hs


def _causal_conv(conv_w, x):
    """Depthwise causal conv over time. x: (b,s,c); conv_w: (w,c)."""
    w = conv_w.shape[0]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * conv_w[i] for i in range(w))
    return jax.nn.silu(out)


def _mamba_block_seq(bp, cfg, h):
    hn = rmsnorm(h, bp["norm"], cfg.norm_eps)
    proj = hn @ bp["in_proj"]
    z, x, B, C, dtr = _split_proj(cfg, proj)
    xBC = _causal_conv(bp["conv_w"], jnp.concatenate([x, B, C], -1))
    s = _ssm(cfg)
    din = inner_dim(cfg)
    xc, Bc, Cc = jnp.split(xBC, [din, din + s.state_dim], -1)
    H0 = jnp.zeros((h.shape[0], ssm_heads(cfg), s.head_dim, s.state_dim),
                   jnp.float32)
    y, _ = _ssd_scan(bp, cfg, xc, Bc, Cc, dtr, H0)
    y = rmsnorm(y * jax.nn.silu(z), bp["gate_norm"], cfg.norm_eps)
    return hint_residual(h + y @ bp["out_proj"])


def _shared_block_seq(sp, cfg, h, positions):
    a = self_attention(sp["attn"],
                       rmsnorm(h, sp["attn_norm"], cfg.norm_eps),
                       cfg, positions)
    h = h + shard_hint(a, ("pod", "data"), None, "model")
    f = swiglu(sp["ffn"], rmsnorm(h, sp["ffn_norm"], cfg.norm_eps))
    return hint_residual(h + f)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def _pattern(cfg):
    """Layer pattern: shared attention after every `shared_attn_every`
    mamba blocks."""
    k = cfg.shared_attn_every or (cfg.n_layers + 1)
    n_shared = cfg.n_layers // k
    return k, n_shared


def forward(params, cfg, tokens, remat: bool = False):
    b, s = tokens.shape
    h = params["embed"][tokens]
    h = shard_hint(h, ("pod", "data"), None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    k, n_shared = _pattern(cfg)

    mamba = _mamba_block_seq
    if remat:
        mamba = jax.checkpoint(_mamba_block_seq, static_argnums=(1,))

    def unit(h, bps):
        def inner(hh, bp):
            return mamba(bp, cfg, hh), None
        h, _ = jax.lax.scan(inner, h, bps)
        return h

    # n_shared pattern units of (k mamba + shared attn), then the tail.
    n_pattern_layers = n_shared * k
    head_stack = tree_map(lambda a: a[:n_pattern_layers]
                              .reshape((n_shared, k) + a.shape[1:]),
                              params["blocks"])
    tail_stack = tree_map(lambda a: a[n_pattern_layers:],
                              params["blocks"])

    def unit_scan(h, bps):
        h = unit(h, bps)
        h = _shared_block_seq(params["shared"], cfg, h, positions)
        return h, None

    h, _ = jax.lax.scan(unit_scan, h, head_stack)
    if cfg.n_layers - n_pattern_layers > 0:
        h = unit(h, tail_stack)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"]
    return shard_hint(logits, ("pod", "data"), None, "model")


def init_state(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16,
               tp: int = 1) -> dict:
    """Decode state: per-layer SSM state + conv tail, plus a KV cache for
    the shared attention block at each of its application depths (ring
    buffer of the sliding window when configured)."""
    s = _ssm(cfg)
    k, n_shared = _pattern(cfg)
    S = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    hd = cfg.resolved_head_dim
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, ssm_heads(cfg), s.head_dim,
                          s.state_dim), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, s.conv_width - 1,
                           inner_dim(cfg) + 2 * s.state_dim),
                          jnp.dtype(cfg.dtype)),
        "k": jnp.zeros((n_shared, batch, cfg.n_kv_heads, S, hd), dtype),
        "v": jnp.zeros((n_shared, batch, cfg.n_kv_heads, S, hd), dtype),
    }


def state_specs(cfg) -> dict:
    return {
        "ssm": (None, ("pod", "data"), "model", None, None),
        "conv": (None, ("pod", "data"), None, "model"),
        "k": (None, ("pod", "data"), None, "model", None),
        "v": (None, ("pod", "data"), None, "model", None),
    }


def _mamba_block_step(bp, cfg, h, ssm_state, conv_tail):
    """Single-token mamba block. h: (b, d)."""
    s = _ssm(cfg)
    din = inner_dim(cfg)
    hn = rmsnorm(h, bp["norm"], cfg.norm_eps)
    proj = hn @ bp["in_proj"]
    z, x, B, C, dtr = _split_proj(cfg, proj)
    xBC = jnp.concatenate([x, B, C], -1)                        # (b, c)
    win = jnp.concatenate([conv_tail, xBC[:, None, :]], 1)      # (b, w, c)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", win, bp["conv_w"]))
    xc, Bc, Cc = jnp.split(conv_out, [din, din + s.state_dim], -1)
    y, Hs = _ssd_scan(bp, cfg, xc[:, None], Bc[:, None], Cc[:, None],
                      dtr[:, None], ssm_state)
    y = y[:, 0]
    y = rmsnorm(y * jax.nn.silu(z), bp["gate_norm"], cfg.norm_eps)
    return h + y @ bp["out_proj"], Hs, win[:, 1:]


def decode_step(params, cfg, token, state, pos):
    b = token.shape[0]
    h = params["embed"][token][:, 0]
    k, n_shared = _pattern(cfg)
    S = state["k"].shape[3]
    slot = jnp.mod(pos, S) if cfg.sliding_window else pos

    def mamba_scan(h, layer):
        bp, ssm_s, conv_t = layer
        h, ssm_s, conv_t = _mamba_block_step(bp, cfg, h, ssm_s, conv_t)
        return h, (ssm_s, conv_t)

    n_pattern = n_shared * k
    take = lambda a, lo, hi: tree_map(lambda x: x[lo:hi], a)
    new_ssm, new_conv, new_k, new_v = [], [], [], []
    for u in range(n_shared):
        lo, hi = u * k, (u + 1) * k
        h, (ssm_s, conv_t) = jax.lax.scan(
            mamba_scan, h,
            (take(params["blocks"], lo, hi), state["ssm"][lo:hi],
             state["conv"][lo:hi]))
        new_ssm.append(ssm_s)
        new_conv.append(conv_t)
        sp = params["shared"]
        x = rmsnorm(h[:, None, :], sp["attn_norm"], cfg.norm_eps)
        a, kc, vc = decode_attention(sp["attn"], x, cfg,
                                     state["k"][u], state["v"][u], pos, slot)
        h = h + a[:, 0]
        f = swiglu(sp["ffn"], rmsnorm(h, sp["ffn_norm"], cfg.norm_eps))
        h = h + f
        new_k.append(kc)
        new_v.append(vc)
    if cfg.n_layers - n_pattern > 0:
        h, (ssm_s, conv_t) = jax.lax.scan(
            mamba_scan, h,
            (take(params["blocks"], n_pattern, cfg.n_layers),
             state["ssm"][n_pattern:], state["conv"][n_pattern:]))
        new_ssm.append(ssm_s)
        new_conv.append(conv_t)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = (h @ params["lm_head"])[:, None, :]
    logits = shard_hint(logits, ("pod", "data"), None, "model")
    new_state = {
        "ssm": jnp.concatenate(new_ssm, 0),
        "conv": jnp.concatenate(new_conv, 0),
        "k": jnp.stack(new_k, 0),
        "v": jnp.stack(new_v, 0),
    }
    return logits, new_state
