"""Llama-3.2-Vision-90B backbone: dense GQA decoder with cross-attention
layers interleaved every `cross_attn_every` layers (pattern unit =
(cross_attn_every - 1) self layers + 1 cross layer).

The vision frontend is a STUB per the brief: `input_specs()` provides
precomputed patch embeddings (b, n_vision_tokens, d_model); the cross
layers attend to them (keys/values computed once per request and cached for
decode — as a production server would).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compat import tree_map
from ..distributed.sharding import (hint_residual, padded_heads,
                                    padded_vocab, shard_hint)
from .layers import (attn_params, cross_attention, decode_attention,
                     dense_init, ffn_params, rmsnorm, self_attention, swiglu)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _pattern(cfg):
    k = cfg.cross_attn_every
    n_units = cfg.n_layers // k
    return k, n_units


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _self_block_init(key, cfg, nH, dt):
    ka, kf = jax.random.split(key)
    return {
        "attn": attn_params(ka, cfg, nH, cfg.n_kv_heads, dt),
        "attn_norm": jnp.ones((cfg.d_model,), dt),
        "ffn": ffn_params(kf, cfg.d_model, cfg.d_ff, dt),
        "ffn_norm": jnp.ones((cfg.d_model,), dt),
    }


def _cross_block_init(key, cfg, nH, dt):
    p = _self_block_init(key, cfg, nH, dt)
    # mllama gates cross-attention contributions (zero-init tanh gates).
    p["gate_attn"] = jnp.zeros((), jnp.float32)
    p["gate_ffn"] = jnp.zeros((), jnp.float32)
    return p


def init(cfg, key, tp: int = 1) -> dict:
    dt = _dtype(cfg)
    nH = padded_heads(cfg.n_heads, tp)
    V = padded_vocab(cfg.vocab)
    k, n_units = _pattern(cfg)
    k_embed, k_self, k_cross, k_head = jax.random.split(key, 4)
    n_self = n_units * (k - 1)
    self_blocks = jax.vmap(lambda kk: _self_block_init(kk, cfg, nH, dt))(
        jax.random.split(k_self, n_self))
    cross_blocks = jax.vmap(lambda kk: _cross_block_init(kk, cfg, nH, dt))(
        jax.random.split(k_cross, n_units))
    return {
        "embed": dense_init(k_embed, (V, cfg.d_model), dt, scale=0.02),
        "self_blocks": self_blocks,      # stacked (n_units*(k-1), ...)
        "cross_blocks": cross_blocks,    # stacked (n_units, ...)
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": dense_init(k_head, (cfg.d_model, V), dt),
    }


def param_specs(cfg, fsdp=None, tp: int = 16) -> dict:
    hd = cfg.resolved_head_dim
    kv_shardable = (cfg.n_kv_heads * hd) % tp == 0 and cfg.n_kv_heads >= tp
    attn = {"wq": (fsdp, "model"),
            "wk": (fsdp, "model" if kv_shardable else None),
            "wv": (fsdp, "model" if kv_shardable else None),
            "wo": ("model", fsdp)}
    ffn = {"w_gate": (fsdp, "model"), "w_up": (fsdp, "model"),
           "w_down": ("model", fsdp)}
    base = {"attn": attn, "attn_norm": (None,), "ffn": ffn,
            "ffn_norm": (None,)}
    cross = base | {"gate_attn": (), "gate_ffn": ()}
    stack = lambda blk: tree_map(lambda s: (None,) + s, blk,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": ("model", fsdp),
        "self_blocks": stack(base),
        "cross_blocks": stack(cross),
        "final_norm": (None,),
        "lm_head": (fsdp, "model"),
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _self_fwd(cfg, h, bp, positions):
    a = self_attention(bp["attn"], rmsnorm(h, bp["attn_norm"], cfg.norm_eps),
                       cfg, positions)
    h = h + shard_hint(a, ("pod", "data"), None, "model")
    return hint_residual(
        h + swiglu(bp["ffn"], rmsnorm(h, bp["ffn_norm"], cfg.norm_eps)))


def _cross_fwd(cfg, h, bp, vision):
    a = cross_attention(bp["attn"],
                        rmsnorm(h, bp["attn_norm"], cfg.norm_eps), vision,
                        cfg)
    # Gates are fp32 scalars; cast the gate (not the activation) so the
    # residual stream and its cotangents stay in the model dtype.
    h = h + jnp.tanh(bp["gate_attn"]).astype(h.dtype) * a
    f = swiglu(bp["ffn"], rmsnorm(h, bp["ffn_norm"], cfg.norm_eps))
    return hint_residual(h + jnp.tanh(bp["gate_ffn"]).astype(h.dtype) * f)


def forward(params, cfg, tokens, vision_embeds, remat: bool = False):
    """tokens: (b, s); vision_embeds: (b, n_vis, d_model)."""
    b, s = tokens.shape
    k, n_units = _pattern(cfg)
    h = params["embed"][tokens]
    h = shard_hint(h, ("pod", "data"), None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    self_fwd = _self_fwd
    cross_fwd = _cross_fwd
    if remat:
        self_fwd = jax.checkpoint(_self_fwd, static_argnums=(0,))
        cross_fwd = jax.checkpoint(_cross_fwd, static_argnums=(0,))

    self_stack = tree_map(
        lambda a: a.reshape((n_units, k - 1) + a.shape[1:]),
        params["self_blocks"])

    def unit(h, unit_params):
        selfs, cross = unit_params

        def inner(hh, bp):
            return self_fwd(cfg, hh, bp, positions), None

        h, _ = jax.lax.scan(inner, h, selfs)
        return cross_fwd(cfg, h, cross, vision_embeds), None

    h, _ = jax.lax.scan(unit, h, (self_stack, params["cross_blocks"]))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"]
    return shard_hint(logits, ("pod", "data"), None, "model")


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16,
               tp: int = 1) -> dict:
    k, n_units = _pattern(cfg)
    hd = cfg.resolved_head_dim
    n_self = n_units * (k - 1)
    return {
        "k": jnp.zeros((n_self, batch, cfg.n_kv_heads, max_seq, hd), dtype),
        "v": jnp.zeros((n_self, batch, cfg.n_kv_heads, max_seq, hd), dtype),
        # cross-attention KV: computed once from the vision embeddings
        "xk": jnp.zeros((n_units, batch, cfg.n_kv_heads, cfg.n_vision_tokens,
                         hd), dtype),
        "xv": jnp.zeros((n_units, batch, cfg.n_kv_heads, cfg.n_vision_tokens,
                         hd), dtype),
    }


def cache_specs(cfg) -> dict:
    s = (None, ("pod", "data"), None, "model", None)
    return {"k": s, "v": s, "xk": s, "xv": s}


def precompute_cross_kv(params, cfg, vision_embeds):
    """Fill the cross-attention KV cache once per request (prefill side)."""
    hd = cfg.resolved_head_dim

    def one(bp):
        b, nv, _ = vision_embeds.shape
        kk = (vision_embeds @ bp["attn"]["wk"]).reshape(b, nv, -1, hd)
        vv = (vision_embeds @ bp["attn"]["wv"]).reshape(b, nv, -1, hd)
        return kk.transpose(0, 2, 1, 3), vv.transpose(0, 2, 1, 3)

    xk, xv = jax.vmap(one)(params["cross_blocks"])
    return xk, xv


def _cross_decode(cfg, h, bp, xk, xv):
    """Single-token cross attention against precomputed vision KV."""
    from .layers import attention_scores, repeat_kv
    b = h.shape[0]
    hd = cfg.resolved_head_dim
    x = rmsnorm(h, bp["attn_norm"], cfg.norm_eps)
    q = (x @ bp["attn"]["wq"]).reshape(b, 1, -1, hd).transpose(0, 2, 1, 3)
    n_rep = q.shape[1] // xk.shape[1]
    out = attention_scores(q, repeat_kv(xk, n_rep), repeat_kv(xv, n_rep),
                           None)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    a = out @ bp["attn"]["wo"]
    h = h + jnp.tanh(bp["gate_attn"]).astype(h.dtype) * a
    f = swiglu(bp["ffn"], rmsnorm(h, bp["ffn_norm"], cfg.norm_eps))
    return h + jnp.tanh(bp["gate_ffn"]).astype(h.dtype) * f


def decode_step(params, cfg, token, cache, pos):
    """Layer loop = fori_loop carrying the full self-attention cache and
    updating per-layer slices in place (see transformer.decode_step for
    the measured rationale); the cross-attention KV is read-only."""
    b = token.shape[0]
    k, n_units = _pattern(cfg)
    n_self = n_units * (k - 1)
    h = params["embed"][token]

    take = lambda t, i: tree_map(
        lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False), t)

    def self_layer(u, j, carry):
        h, kc_all, vc_all = carry
        i = u * (k - 1) + j
        bp = take(params["self_blocks"], i)
        kc = jax.lax.dynamic_index_in_dim(kc_all, i, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vc_all, i, 0, keepdims=False)
        x = rmsnorm(h, bp["attn_norm"], cfg.norm_eps)
        a, kc, vc = decode_attention(bp["attn"], x, cfg, kc, vc, pos)
        h = h + a
        f = swiglu(bp["ffn"], rmsnorm(h, bp["ffn_norm"], cfg.norm_eps))
        kc_all = jax.lax.dynamic_update_index_in_dim(kc_all, kc, i, 0)
        vc_all = jax.lax.dynamic_update_index_in_dim(vc_all, vc, i, 0)
        return h + f, kc_all, vc_all

    def unit(u, carry):
        h, kc_all, vc_all = carry
        # static (0, k-1) bounds so XLA proves both loops' trip counts
        # (u-dependent bounds defeat known_trip_count and the roofline's
        # flop attribution).
        h, kc_all, vc_all = jax.lax.fori_loop(
            0, k - 1, lambda j, c: self_layer(u, j, c),
            (h, kc_all, vc_all))
        cross = take(params["cross_blocks"], u)
        xk = jax.lax.dynamic_index_in_dim(cache["xk"], u, 0, keepdims=False)
        xv = jax.lax.dynamic_index_in_dim(cache["xv"], u, 0, keepdims=False)
        h = _cross_decode(cfg, h, cross, xk, xv)
        return h, kc_all, vc_all

    h, k_new, v_new = jax.lax.fori_loop(
        0, n_units, unit, (h, cache["k"], cache["v"]))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"]
    logits = shard_hint(logits, ("pod", "data"), None, "model")
    return logits, {"k": k_new, "v": v_new,
                    "xk": cache["xk"], "xv": cache["xv"]}
