"""Dense / MoE decoder-only transformer LM (qwen2, minitron, h2o-danube,
qwen3, granite-moe, phi3.5-moe).

Scan-over-layers with stacked per-layer parameters keeps the HLO one block
deep regardless of depth (critical for 100-layer dry-run compiles).
Supports full-sequence forward (train/prefill) and single-token decode
against a KV cache.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..compat import tree_map
from ..distributed.sharding import (hint_residual, padded_heads,
                                    padded_vocab, shard_hint)
from . import moe as moe_lib
from .layers import (attn_params, decode_attention, dense_init, ffn_params,
                     rmsnorm, self_attention, swiglu)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init(cfg, key, tp: int = 1) -> dict:
    dt = _dtype(cfg)
    nH = padded_heads(cfg.n_heads, tp)
    V = padded_vocab(cfg.vocab)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)

    def block_init(k):
        ka, kf = jax.random.split(k)
        p = {
            "attn": attn_params(ka, cfg, nH, cfg.n_kv_heads, dt),
            "attn_norm": jnp.ones((cfg.d_model,), dt),
            "ffn_norm": jnp.ones((cfg.d_model,), dt),
        }
        if cfg.moe:
            p["moe"] = moe_lib.moe_params(kf, cfg, dt)
        else:
            p["ffn"] = ffn_params(kf, cfg.d_model, cfg.d_ff, dt)
        return p

    blocks = jax.vmap(block_init)(jax.random.split(k_blocks, cfg.n_layers))
    params = {
        "embed": dense_init(k_embed, (V, cfg.d_model), dt, scale=0.02),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, V), dt)
    return params


def param_specs(cfg, fsdp=None, tp: int = 16) -> dict:
    """PartitionSpec tuples mirroring init()'s structure. `fsdp` is the mesh
    axis name for ZeRO-3 parameter sharding (None to replicate over data)."""
    hd = cfg.resolved_head_dim
    kv_shardable = (cfg.n_kv_heads * hd) % tp == 0 and cfg.n_kv_heads >= tp
    attn = {
        "wq": (fsdp, "model"),
        "wk": (fsdp, "model" if kv_shardable else None),
        "wv": (fsdp, "model" if kv_shardable else None),
        "wo": ("model", fsdp),
    }
    if cfg.qkv_bias:
        attn |= {"bq": ("model",),
                 "bk": ("model" if kv_shardable else None,),
                 "bv": ("model" if kv_shardable else None,)}
    if cfg.qk_norm:
        attn |= {"q_norm": (None,), "k_norm": (None,)}
    block = {"attn": attn, "attn_norm": (None,), "ffn_norm": (None,)}
    if cfg.moe:
        block["moe"] = moe_lib.moe_param_specs(cfg, fsdp, tp)
    else:
        block["ffn"] = {"w_gate": (fsdp, "model"), "w_up": (fsdp, "model"),
                        "w_down": ("model", fsdp)}
    specs = {
        "embed": ("model", fsdp),
        "blocks": tree_map(lambda s: (None,) + s, block,
                               is_leaf=lambda x: isinstance(x, tuple)),
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = (fsdp, "model")
    return specs


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_forward(cfg, h, bp, positions):
    a = self_attention(bp["attn"], rmsnorm(h, bp["attn_norm"], cfg.norm_eps),
                       cfg, positions)
    a = shard_hint(a, ("pod", "data"), None, "model")
    h = h + a
    x = rmsnorm(h, bp["ffn_norm"], cfg.norm_eps)
    if cfg.moe:
        f = moe_lib.moe_ffn(bp["moe"], x, cfg)
    else:
        f = swiglu(bp["ffn"], x)
    return hint_residual(h + f)


def forward(params: dict, cfg, tokens: jax.Array,
            remat: bool = False) -> jax.Array:
    """tokens: (b, s) int32 -> logits (b, s, vocab_padded)."""
    b, s = tokens.shape
    h = params["embed"][tokens]
    h = shard_hint(h, ("pod", "data"), None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    step = partial(_block_forward, cfg)
    if remat:
        step = jax.checkpoint(step, static_argnums=())

    def scan_fn(h, bp):
        return step(h, bp, positions), None

    h, _ = jax.lax.scan(scan_fn, h, params["blocks"])
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    return shard_hint(logits, ("pod", "data"), None, "model")


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16,
               tp: int = 1) -> dict:
    hd = cfg.resolved_head_dim
    S = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, S, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs(cfg) -> dict:
    """KV cache shards sequence over `model` (context-parallel decode —
    partial-softmax reductions become XLA all-reduces) and batch over the DP
    axes."""
    s = (None, ("pod", "data"), None, "model", None)
    return {"k": s, "v": s}


def decode_step(params: dict, cfg, token: jax.Array, cache: dict,
                pos: jax.Array) -> tuple:
    """token: (b, 1) int32; pos: scalar int32. Returns (logits, new_cache).

    The layer loop is a fori_loop carrying the FULL stacked KV cache and
    updating each layer's slice in place — NOT a scan with the cache as
    xs/ys. Scanning the cache double-buffers it (xs read + ys stack) and,
    through the ys dynamic-update-slice, rewrites the whole stack every
    iteration in the lowered program (measured on qwen2-7b decode_32k:
    EXPERIMENTS.md §Perf); the fori_loop carry aliases in place and the
    per-layer traffic is one slice read + one slot write.

    With a sliding window the cache is a ring buffer of window size."""
    b = token.shape[0]
    h = params["embed"][token]
    L = cache["k"].shape[0]
    S = cache["k"].shape[3]
    slot = jnp.mod(pos, S) if cfg.sliding_window else pos

    def body(i, carry):
        h, kc_all, vc_all = carry
        bp = tree_map(
            lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False),
            params["blocks"])
        kc = jax.lax.dynamic_index_in_dim(kc_all, i, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vc_all, i, 0, keepdims=False)
        x = rmsnorm(h, bp["attn_norm"], cfg.norm_eps)
        a, kc, vc = decode_attention(bp["attn"], x, cfg, kc, vc, pos, slot)
        h = h + a
        x = rmsnorm(h, bp["ffn_norm"], cfg.norm_eps)
        f = moe_lib.moe_ffn(bp["moe"], x, cfg) if cfg.moe \
            else swiglu(bp["ffn"], x)
        kc_all = jax.lax.dynamic_update_index_in_dim(kc_all, kc, i, 0)
        vc_all = jax.lax.dynamic_update_index_in_dim(vc_all, vc, i, 0)
        return h + f, kc_all, vc_all

    h, k_new, v_new = jax.lax.fori_loop(
        0, L, body, (h, cache["k"], cache["v"]))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    logits = shard_hint(logits, ("pod", "data"), None, "model")
    return logits, {"k": k_new, "v": v_new}
