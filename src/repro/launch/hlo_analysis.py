"""Optimized-HLO analyzer: flops, HBM bytes, and collective bytes with
while-loop (scan-over-layers) trip-count attribution.

Why not cost_analysis()? Two measured deficiencies on the CPU backend
(tests/test_roofline.py pins both):

1. ``compiled.cost_analysis()`` counts a ``while`` body ONCE — a 28-layer
   scan under-reports flops/bytes by ~28x.
2. Collective operands print as bare ``%names``; operand sizes need a
   module-wide symbol table.

This module parses ``compiled.as_text()``:
  * symbol table: instruction name -> result shape bytes,
  * computation graph: fusion ``calls=`` / while ``body=``/``condition=``,
  * while trip counts from the largest integer constant in the condition
    computation (scan emits ``compare(iter, constant(L))``),
  * flops: every ``dot`` (2 * prod(out) * prod(lhs contracting dims)),
    wherever it lives (fused or not), times its computation's multiplier,
  * HBM bytes: operand+result bytes of substantial top-level ops in
    non-fused computations (fusions count at their boundary — interior
    elementwise traffic stays in registers/VMEM),
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (start variants
    counted once), times multiplier.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from ..compat.hlo import normalize_cost_analysis, xla_cost_analysis  # noqa: F401
# Re-exported: every consumer of Compiled.cost_analysis() goes through
# these (the raw return drifted from list[dict] to dict across JAX versions).

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "ragged-all-to-all")

# Ops whose operands+results plausibly cross HBM when not fused away.
_BYTE_OPS = ("fusion", "dot", "convolution", "copy", "scatter", "gather",
             "dynamic-slice", "dynamic-update-slice", "reduce", "sort",
             "transpose", "broadcast", "concatenate", "pad", "select",
             "custom-call", "iota", "reverse", "slice", "reduce-window",
             "cholesky", "triangular-solve") + COLLECTIVE_OPS

_SKIP_BYTE_OPS = ("tuple", "get-tuple-element", "parameter", "constant",
                  "while", "conditional", "call", "bitcast", "reshape",
                  "after-all", "add-dependency", "partition-id",
                  "replica-id", "rng", "compare", "convert")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
# First operand after 'op(': optional inline type then the operand name.
_OPERAND_RE = (r"\(\s*(?:([a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?)\s+)?"
               r"%?([\w\.\-]+)")


def _parse_instr_line(line: str):
    """'%name = <type> op(...)' -> (name, type_str, op) or None.

    The result type may be a parenthesized tuple (while/tuple ops), so the
    type is consumed structurally, not by regex."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):                      # tuple type
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    rtype = rest[:i + 1]
                    tail = rest[i + 1:]
                    break
        else:
            return None
    else:                                         # 'bf16[2,3]{1,0}' token
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype = rest[:sp]
        tail = rest[sp:]
    mo = re.match(r"\s*([\w\-]+)\(", tail)
    if not mo:
        return None
    return name, rtype, mo.group(1)


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(shape_str: str) -> list[tuple[str, tuple]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


@dataclass
class Instruction:
    name: str
    result: str               # result type string
    op: str                   # op kind
    line: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)


@dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    n_collectives: int = 0
    while_trips: dict = field(default_factory=dict)


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, Computation] = {}
        self.symbols: dict[str, str] = {}          # name -> result type str
        self._parse(text)
        self.mult = self._multipliers()

    # -- parsing ---------------------------------------------------------------

    def _parse(self, text: str) -> None:
        cur: Computation | None = None
        for raw in text.splitlines():
            line = raw.strip()
            if not line or line.startswith(("//", "#")):
                continue
            # Computation header: '%name (params...) -> type {' — never has
            # a '%name = ' prefix (instructions do). '/*index=N*/' comments
            # inside the param tuple mean we cannot test for '=' textually.
            if line.endswith("{") and not _NAME_RE.match(line):
                hdr = line[6:].strip() if line.startswith("ENTRY") else line
                m = re.match(r"%?([\w\.\-]+)", hdr)
                if m:
                    cur = Computation(m.group(1))
                    self.comps[cur.name] = cur
                continue
            if line == "}" or line.startswith("}"):
                cur = None
                continue
            parsed = _parse_instr_line(line)
            if parsed and cur is not None:
                name, rtype, op = parsed
                inst = Instruction(name, rtype.strip(), op, line)
                cur.instrs.append(inst)
                self.symbols[name] = rtype.strip()

    def _multipliers(self) -> dict:
        body_trip: dict[str, int] = {}
        parents: dict[str, list] = {}
        fused_bodies: set[str] = set()
        for comp in self.comps.values():
            for inst in comp.instrs:
                if inst.op == "while":
                    mb = re.search(r"body=%?([\w\.\-]+)", inst.line)
                    mc = re.search(r"condition=%?([\w\.\-]+)", inst.line)
                    if not mb:
                        continue
                    trip = 1
                    # Primary: XLA records the trip count it proved.
                    mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}',
                                   inst.line)
                    if mt:
                        trip = int(mt.group(1))
                    elif mc and mc.group(1) in self.comps:
                        consts = []
                        for ci in self.comps[mc.group(1)].instrs:
                            consts += [int(x) for x in re.findall(
                                r"constant\((\d+)\)", ci.line)]
                        if consts:
                            trip = max(consts)
                    body_trip[mb.group(1)] = trip
                    parents.setdefault(mb.group(1), []).append(comp.name)
                    if mc:
                        parents.setdefault(mc.group(1), []).append(comp.name)
                else:
                    for m in re.finditer(r"(?:calls=|to_apply=)%?([\w\.\-]+)",
                                         inst.line):
                        parents.setdefault(m.group(1), []).append(comp.name)
                        if inst.op == "fusion":
                            fused_bodies.add(m.group(1))
        self.fused_bodies = fused_bodies

        mult: dict[str, int] = {}

        def resolve(name: str, seen=()) -> int:
            if name in mult:
                return mult[name]
            if name in seen:
                return 1
            own = body_trip.get(name, 1)
            pm = max((resolve(p, seen + (name,))
                      for p in parents.get(name, [])), default=1)
            mult[name] = own * pm
            return mult[name]

        for name in self.comps:
            resolve(name)
        return mult

    # -- operand handling --------------------------------------------------------

    def _operand_bytes(self, inst: Instruction) -> int:
        """Sum of operand sizes: typed shapes inline, or %name lookups."""
        start = inst.line.find(inst.op + "(")
        if start < 0:
            return 0
        inner = inst.line[start + len(inst.op) + 1:]
        depth, end = 1, len(inner)
        for i, ch in enumerate(inner):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = inner[:end]
        total = shape_bytes(operands)
        if total == 0:
            for nm in re.findall(r"%([\w\.\-]+)", operands):
                total += shape_bytes(self.symbols.get(nm, ""))
        return total

    def _operand_bytes_list(self, inst: Instruction) -> list[int]:
        """Per-operand byte sizes (typed inline or symbol lookup)."""
        start = inst.line.find(inst.op + "(")
        if start < 0:
            return []
        inner = inst.line[start + len(inst.op) + 1:]
        depth, end = 1, len(inner)
        for i, ch in enumerate(inner):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        out = []
        for tok in inner[:end].split(","):
            tok = tok.strip()
            nb = shape_bytes(tok)
            if nb == 0:
                m = re.search(r"%([\w\.\-]+)", tok)
                if m:
                    nb = shape_bytes(self.symbols.get(m.group(1), ""))
            out.append(nb)
        return out

    def _traffic_bytes(self, inst: Instruction) -> int:
        """Approximate HBM traffic of one op.

        Slicing ops read/write only the window, not the whole buffer —
        counting whole operands would charge a 28-layer scan 28 full-cache
        reads per step. In-place update ops alias their big operand.
        Fusions are modeled from their *interior*: a fused operand that is
        only dynamic-sliced contributes its windows, not its full size, and
        a fused root dynamic-update-slice contributes its update window."""
        res = shape_bytes(inst.result)
        ops = self._operand_bytes_list(inst)
        if inst.op in ("dynamic-slice", "slice", "gather"):
            return 2 * res
        if inst.op in ("dynamic-update-slice",):
            upd = ops[1] if len(ops) > 1 else 0
            return 2 * upd
        if inst.op == "scatter":
            return 2 * (ops[-1] if ops else res)
        if inst.op == "iota":
            return res
        if inst.op == "fusion":
            return self._fusion_traffic(inst, ops, res)
        return sum(ops) + res

    @staticmethod
    def _first_operand(ci: Instruction):
        # Operands print as '%name' or 'f32[2,3]{1,0} %name' depending on
        # the XLA version; take the %name of the first operand either way
        # (the shape token contains commas, so no splitting on ',').
        m = re.search(re.escape(ci.op) + _OPERAND_RE, ci.line)
        return m.group(2) if m else None

    def _fusion_traffic(self, inst: Instruction, ops: list[int],
                        res: int) -> int:
        """Model a fusion's HBM traffic from its interior, at *native*
        dtypes. The CPU backend has no bf16 ALUs, so float normalization
        wraps bf16 buffers in convert-to-f32 / convert-back pairs; a cache
        append then reads+writes the whole f32 stack every scan iteration.
        A TPU (native bf16) performs the same fusion as an in-place window
        update. Rules:
          * a param consumed only by (dynamic-)slices contributes its
            windows, not its full size (convert/bitcast wrappers traversed),
          * an effective-root dynamic-update-slice aliases its buffer
            param: full read uncounted, write = the update window,
          * a pure dtype-convert fusion of one param counts once at the
            narrower dtype (the consumer reads the source directly on TPU).
        """
        mc = re.search(r"calls=%?([\w\.\-]+)", inst.line)
        comp = self.comps.get(mc.group(1)) if mc else None
        if comp is None:
            return sum(ops) + res
        name2inst = {ci.name: ci for ci in comp.instrs}

        def resolve(name: str) -> str:
            """Follow convert/bitcast/copy/reshape chains to the source."""
            seen = set()
            while name in name2inst and name not in seen:
                seen.add(name)
                ci = name2inst[name]
                if ci.op in ("convert", "bitcast", "copy", "reshape"):
                    nxt = self._first_operand(ci)
                    if nxt is None:
                        break
                    name = nxt
                else:
                    break
            return name

        param_idx: dict[str, int] = {}
        for ci in comp.instrs:
            if ci.op == "parameter":
                mi = re.search(r"parameter\((\d+)\)", ci.line)
                if mi:
                    param_idx[ci.name] = int(mi.group(1))

        reads = 0
        sliced: set[int] = set()
        for ci in comp.instrs:
            if ci.op in ("dynamic-slice", "slice"):
                src = resolve(self._first_operand(ci) or "")
                if src in param_idx:
                    reads += shape_bytes(ci.result)
                    sliced.add(param_idx[src])

        root = next((ci for ci in reversed(comp.instrs)
                     if ci.line.startswith("ROOT")), None)
        root_eff = name2inst.get(resolve(root.name)) if root else None

        aliased: set[int] = set()
        write = res
        if root_eff is not None and root_eff.op == "dynamic-update-slice":
            names = re.findall(r"%([\w\.\-]+)", root_eff.line.split(
                "dynamic-update-slice(")[-1])
            if names:
                buf = resolve(names[0])
                if buf in param_idx:
                    aliased.add(param_idx[buf])
                if len(names) > 1:
                    upd = self.symbols.get(resolve(names[1]), "")
                    # window at the narrower of stored/native dtype
                    w_upd = shape_bytes(upd)
                    write = min(w_upd, res) if w_upd else res
                    if root_eff is not root:      # converts wrap the DUS
                        write = min(write, shape_bytes(root.result)
                                    * w_upd // max(shape_bytes(
                                        root_eff.result), 1))

        for ci in comp.instrs:
            if ci.op != "parameter":
                continue
            idx = param_idx[ci.name]
            if idx in sliced or idx in aliased:
                continue
            reads += ops[idx] if idx < len(ops) else shape_bytes(ci.result)

        # Pure dtype-cast fusion: one real param, elementwise chain only.
        if (root_eff is not None and root_eff.op == "parameter"
                and len(param_idx) == 1):
            return min(sum(ops), res)
        return max(reads, 0) + write

    @staticmethod
    def _dot_flops(inst: Instruction, symbols: dict) -> float:
        out = 1
        for _, dims in shape_dims(inst.result):
            for d in dims:
                out *= d
        # lhs operand: inline-typed ('f32[64,64]{1,0} %x') on older XLA
        # text, bare '%x' on newer — prefer the inline shape, fall back to
        # the symbol table. The shape token itself contains commas, so the
        # operand cannot be split on ','.
        mlhs = re.search("dot" + _OPERAND_RE, inst.line)
        mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.line)
        contract = 1
        if mlhs and mcd:
            lhs_shape = shape_dims(mlhs.group(1) or "") \
                or shape_dims(symbols.get(mlhs.group(2), ""))
            if lhs_shape:
                dims = lhs_shape[0][1]
                for ix in mcd.group(1).split(","):
                    if ix and int(ix) < len(dims):
                        contract *= dims[int(ix)]
        return 2.0 * out * contract

    # -- public analysis -----------------------------------------------------------

    def analyze(self) -> HloStats:
        st = HloStats()
        for comp in self.comps.values():
            mult = self.mult.get(comp.name, 1)
            fused = comp.name in self.fused_bodies
            for inst in comp.instrs:
                if inst.op == "dot":
                    st.flops += self._dot_flops(inst, self.symbols) * mult
                base = inst.op
                is_coll = any(base.startswith(c) for c in COLLECTIVE_OPS)
                if is_coll and not base.endswith("-done"):
                    kind = next(c for c in COLLECTIVE_OPS
                                if base.startswith(c))
                    nb = self._operand_bytes(inst)
                    st.collective_bytes += nb * mult
                    st.coll_by_kind[kind] = (st.coll_by_kind.get(kind, 0)
                                             + nb * mult)
                    st.n_collectives += mult
                if not fused and inst.op in _BYTE_OPS:
                    nb = self._traffic_bytes(inst)
                    st.bytes_accessed += nb * mult
        # record trips for debugging
        st.while_trips = {k: v for k, v in self.mult.items() if v > 1}
        return st


def analyze_hlo(text: str) -> HloStats:
    return HloModule(text).analyze()
