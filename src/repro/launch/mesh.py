"""Production mesh construction (brief: MULTI-POD DRY-RUN step 1).

``make_production_mesh`` is a FUNCTION, not a module-level constant, so
importing this module never touches jax device state. The single-pod mesh
is 16x16 = 256 chips (one TPU v5e pod); the multi-pod mesh prepends a
``pod`` axis: (2, 16, 16) = 512 chips.

Mesh construction goes through repro.compat so the Auto-axis-type kwarg is
used where the installed JAX has it and dropped where it doesn't.
"""
from __future__ import annotations

from ..compat.sharding import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh (elastic re-mesh, tests)."""
    return _compat_make_mesh(shape, axes)


def mesh_devices(mesh) -> int:
    return mesh.devices.size
