"""Roofline analysis from the compiled dry-run artifact (brief: ROOFLINE
ANALYSIS).

Terms per (arch x shape x mesh), all in per-chip seconds:

    compute    = HLO_FLOPs_per_chip   / peak_FLOP/s          (197e12 bf16)
    memory     = HLO_bytes_per_chip   / HBM_bw               (819e9 B/s)
    collective = coll_bytes_per_chip  / link_bw              (50e9 B/s)

The SPMD-partitioned module is a per-chip program, so all quantities parsed
from it are already per chip.

FLOPs/bytes/collective-bytes come from :mod:`repro.launch.hlo_analysis`
(module-text parse with while-loop trip-count attribution), because
``compiled.cost_analysis()`` counts scan bodies once — a 28-layer scan
would under-report by ~28x (measured; pinned in tests/test_roofline.py).
Raw cost_analysis numbers are recorded alongside for reference.
"""
from __future__ import annotations

from dataclasses import dataclass, field

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link

HBM_PER_CHIP_GB = 16.0       # v5e HBM capacity


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    model_flops_total: float
    model_bytes_total: float
    n_chips: int
    coll_by_kind: dict = field(default_factory=dict)
    mem_per_chip_gb: float = 0.0
    # CPU XLA has no bf16 ALUs: FloatSupport wraps every bf16 all-reduce
    # in convert-to-f32 pairs, so the parsed collective bytes are 2x what
    # a native-bf16 TPU moves. Verified on llama-90b train: all dominant
    # f32 collectives' operand chains begin at bf16 converts. The factor
    # applies to bf16-model cells (all ten archs).
    native_dtype_scale: float = 0.5

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip * self.native_dtype_scale / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Step time lower bound if the three resources never overlap-miss:
        the slowest term gates the step."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        hlo_total = self.flops_per_chip * self.n_chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def t_ideal(self) -> float:
        """The cell's own ideal step time: every chip moving only the
        *model-required* bytes at full HBM bandwidth and computing only the
        model-required flops at peak, whichever is slower."""
        t_c = self.model_flops_total / self.n_chips / PEAK_FLOPS
        t_m = self.model_bytes_total / self.n_chips / HBM_BW
        return max(t_c, t_m)

    @property
    def roofline_fraction(self) -> float:
        """t_ideal / t_bound: how close the compiled program is to the
        arch-intrinsic roofline of this (arch, shape). 1.0 = every byte and
        flop the compiler schedules is model-required and the bottleneck
        resource runs at 100 %."""
        return self.t_ideal / self.t_bound if self.t_bound > 0 else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_ms": self.t_compute * 1e3,
            "t_memory_ms": self.t_memory * 1e3,
            "t_collective_ms": self.t_collective * 1e3,
            "t_bound_ms": self.t_bound * 1e3,
            "t_ideal_ms": self.t_ideal * 1e3,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_total,
            "hlo_flops_total": self.flops_per_chip * self.n_chips,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "coll_by_kind": self.coll_by_kind,
            "mem_per_chip_gb": self.mem_per_chip_gb,
        }


# ---------------------------------------------------------------------------
# MODEL_FLOPS / MODEL_BYTES (the "useful" numerators)
# ---------------------------------------------------------------------------

def model_flops(cfg, shape) -> float:
    """6*N*D for training (MoE: 6*N_active*D); decode: 2*N_active per token
    + exact attention KV term; prefill: 2*N*D + causal attention term."""
    n_active = cfg.n_active_params()
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    hd = cfg.resolved_head_dim
    if shape.kind == "train":
        attn = (4.0 * cfg.n_layers * shape.seq_len * hd * cfg.n_heads
                * tokens * 0.5)           # causal: half the full square
        return 6.0 * n_active * tokens + 3.0 * attn
    if shape.kind == "prefill":
        attn = (4.0 * cfg.n_layers * shape.seq_len * hd * cfg.n_heads
                * tokens * 0.5)
        return 2.0 * n_active * tokens + attn
    # decode: one token against a seq_len-deep cache/state
    if cfg.family == "ssm":
        attn = 4.0 * cfg.n_layers * cfg.d_model * hd * tokens
    else:
        span = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
        attn = 4.0 * cfg.n_layers * span * hd * cfg.n_heads * tokens
    return 2.0 * n_active * tokens + attn


def model_bytes(cfg, shape, bytes_per_param: int = 2) -> float:
    """Minimal HBM traffic for one step: weights once (active subset for
    MoE decode), KV/state read once per decode token, activations once,
    plus the train-side gradient/optimizer traffic."""
    n = cfg.n_params()
    n_active = cfg.n_active_params()
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    act = tokens * d * bytes_per_param * 2 * cfg.n_layers
    if shape.kind == "train":
        # fwd read + bwd read + grad write (bf16) + AdamW moments rw (fp32)
        w_traffic = n * bytes_per_param * 3 + n * 4 * 4
        return w_traffic + act * 3
    if shape.kind == "prefill":
        kv_write = (2 * cfg.n_layers * cfg.n_kv_heads * hd
                    * tokens * bytes_per_param)
        return n * bytes_per_param + act + kv_write
    # decode
    span = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
    if cfg.family == "ssm":
        state = (cfg.n_layers * shape.global_batch * (d // 64) * 64 * 64 * 4)
        kv_read = 2 * state
    else:
        kv_read = (2 * cfg.n_layers * cfg.n_kv_heads * hd * span
                   * shape.global_batch * bytes_per_param)
    return n_active * bytes_per_param + kv_read + act
