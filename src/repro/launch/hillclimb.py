import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Hillclimb harness: lower one cell with a named variant, print the three
roofline terms + per-collective breakdown, and append the iteration to
results/hillclimb.json (the §Perf log).

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch qwen2-7b --shape decode_32k --variant baseline
"""
import argparse
import json
import time

import jax

from ..configs.registry_configs import ALL_ARCHS
from ..configs.shapes import SHAPES
from ..compat import set_mesh
from .hlo_analysis import HloModule
from .mesh import make_production_mesh
from .plans import make_cell
from .roofline import HBM_BW, ICI_BW, PEAK_FLOPS, Roofline, model_bytes, \
    model_flops

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "hillclimb.json")


def measure(arch: str, shape_name: str, mesh_kind: str = "single",
            variant: str = "baseline", opts: dict | None = None,
            dump_hlo: str | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    shape = SHAPES[shape_name]
    cfg = ALL_ARCHS[arch]
    t0 = time.time()
    with set_mesh(mesh):
        plan = make_cell(arch, shape_name, mesh, **(opts or {}))
        compiled = jax.jit(plan.fn, donate_argnums=plan.donate) \
            .lower(*plan.args).compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
    if dump_hlo:
        with open(dump_hlo, "w") as f:
            f.write(hlo)
    st = HloModule(hlo).analyze()
    mem_gb = (mem.argument_size_in_bytes + mem.output_size_in_bytes
              + mem.temp_size_in_bytes) / 1e9
    rf = Roofline(arch=arch, shape=shape_name, mesh=mesh_kind,
                  flops_per_chip=st.flops, bytes_per_chip=st.bytes_accessed,
                  coll_bytes_per_chip=st.collective_bytes,
                  model_flops_total=model_flops(cfg, shape),
                  model_bytes_total=model_bytes(cfg, shape),
                  n_chips=mesh.devices.size,
                  coll_by_kind=dict(st.coll_by_kind), mem_per_chip_gb=mem_gb)
    rec = {"variant": variant, "opts": opts or {},
           "compile_s": round(time.time() - t0, 1), **rf.row()}
    return rec


def log(rec: dict) -> None:
    data = []
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            data = json.load(f)
    data.append(rec)
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(data, f, indent=1)


def show(rec: dict) -> None:
    print(f"[{rec['arch']} x {rec['shape']} x {rec['mesh']}] "
          f"variant={rec['variant']}")
    print(f"  t_compute {rec['t_compute_ms']:.2f} ms | t_memory "
          f"{rec['t_memory_ms']:.2f} ms | t_collective "
          f"{rec['t_collective_ms']:.2f} ms -> bound={rec['bottleneck']}")
    print(f"  roofline_fraction {rec['roofline_fraction']:.4f} "
          f"(ideal {rec['t_ideal_ms']:.2f} ms / bound "
          f"{rec['t_bound_ms']:.2f} ms); mem {rec['mem_per_chip_gb']:.1f} "
          f"GB/chip; useful flops {rec['useful_ratio']:.2f}")
    colls = {k: f"{v/1e9:.2f}GB" for k, v in rec["coll_by_kind"].items()}
    print(f"  collectives: {colls}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--opts", default="{}", help="JSON kwargs for make_cell")
    ap.add_argument("--dump-hlo", default=None)
    args = ap.parse_args(argv)
    rec = measure(args.arch, args.shape, args.mesh, args.variant,
                  json.loads(args.opts), args.dump_hlo)
    show(rec)
    log(rec)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
